#!/usr/bin/env python3
"""Verify that every repo reference in the docs points at something real.

Four checks over README.md, docs/*.md and benchmarks/README.md:

* **paths** - references like ``src/repro/core/sweep.py``,
  ``benchmarks/run.py``, ``examples/...`` or ``tests/...`` (with or
  without an inline-code backtick wrapper) must exist on disk;
* **figures** - every ``Fig. N`` / ``Figs. N-M`` citation must stay
  inside the source paper's figure range (1..MAX_PAPER_FIG), so a typo'd
  figure number can't survive a docs pass;
* **benchmark labels** - every ``--only <labels>`` invocation quoted in
  the docs must name labels that ``benchmarks/run.py`` actually
  registers in ``MODULES``;
* **variant names** - every protocol variant cited in a
  ``variants=("...", ...)`` snippet must be registered in the
  ``repro.core.api`` variant registry (names a snippet itself registers
  via ``register_variant(... name="...")`` are exempt, so the
  add-a-variant walkthrough can introduce new ones);
* **executable-variant names** - every variant a doc snippet *executes*
  (``run_variant("...")`` / ``validate_variant("...")``, or their batched
  siblings ``run_variant_batched`` / ``validate_batched``) must declare
  an execution plane in the registry (doc-locally registered names, via
  ``register_variant`` or ``register_executable``, are exempt);
* **batched-plane names** - every ``batched_execution.<name>`` a doc
  cites must be a def/class in ``src/repro/core/batched_execution.py``.
  That module imports JAX, so it cannot join the synthetic stdlib-only
  package below - its surface is checked by regex over the source;
* **shard-plane names** - every ``ShardingSpec`` / ``Sharded*`` citation
  (``ShardedDeployment``, ``ShardedAutotuneResult``, ...) must resolve
  to a def/class somewhere in ``repro.core``: the stdlib-only modules
  join the synthetic package, the JAX-importing ones (``sweep.py``,
  ``autotune.py``, ``transient.py``, ``batched_execution.py``) are
  regex-scraped like the batched surface;
* **geo-plane names** - every ``GeoSpec`` / ``Geo*`` citation
  (``GeoLatencySurface``, ...) plus the placement-autotune surface
  (``autotune_placement``, ``placement_candidates``,
  ``region_partition_schedule``) must resolve to a def/class in
  ``repro.core``, and every ``geo.<name>`` a doc cites must be a
  top-level def/class in ``src/repro/core/geo.py`` or a ``GeoSpec``
  field/method (so ``geo.region_of(...)`` snippets stay honest);
* **autoscale-plane names** - every ``AutoscalePolicy`` /
  ``Controller`` / ``run_autoscaled`` / ``autoscale_grid`` /
  ``autotune_policy`` / ``reconfiguration_schedule`` /
  ``measured_capacity`` citation (the whole elastic-control surface)
  must resolve to a def/class in ``repro.core``, and every
  ``autoscale.<name>`` must be a top-level def/class in
  ``src/repro/core/autoscale.py`` or an ``AutoscalePolicy``
  field/method.

The registry is loaded through a synthetic package (``api.py`` +
``analytical.py`` + ``execution.py`` and the correctness-plane modules it
pulls in - all stdlib) so this script never imports JAX.

Keeps the paper->code map honest as the tree is refactored.
"""
from __future__ import annotations

import importlib.util
import re
import sys
import types
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

DOC_FILES = [ROOT / "README.md", ROOT / "benchmarks" / "README.md",
             *sorted((ROOT / "docs").glob("*.md"))]

# path-like tokens rooted at a known top-level directory
PATH_RE = re.compile(
    r"\b((?:src/repro|benchmarks|examples|tests|docs|scripts)"
    r"(?:/[A-Za-z0-9_.-]+)*"
    r"(?:\.(?:py|md|sh|txt|json)|/))")

# the source paper's figures run 1..33 (Fig. 33 is the skew study)
MAX_PAPER_FIG = 33
FIG_RE = re.compile(r"Figs?\.\s*(\d+)(?:[a-z])?(?:\s*[-/]\s*(\d+))?")

ONLY_RE = re.compile(r"--only\s+([a-z0-9_,]+)")
MODULE_LABEL_RE = re.compile(r'^\s*\("([a-z0-9_]+)",', re.MULTILINE)

# variants=("a", "b", ...) tuples quoted in doc code snippets
VARIANTS_TUPLE_RE = re.compile(r"variants\s*=\s*\(([^)]*)\)")
QUOTED_NAME_RE = re.compile(r'"([a-z0-9_]+)"')
# a snippet registering its own variant exempts that name - scoped to
# register_variant(...) call sites so unrelated name="..." kwargs (e.g.
# Workload(name="50pct_reads")) don't leak into the exemption set
DOC_LOCAL_VARIANT_RE = re.compile(
    r'register_variant\([\s\S]{0,200}?name\s*=\s*"([a-z0-9_]+)"')
# names a snippet executes must declare an execution plane; a snippet
# attaching one itself (register_executable("name", ...)) is exempt
EXECUTED_VARIANT_RE = re.compile(
    r'(?:run_variant_batched|validate_batched|run_variant|validate_variant)'
    r'\(\s*"([a-z0-9_]+)"')
DOC_LOCAL_EXECUTABLE_RE = re.compile(
    r'register_executable\(\s*"([a-z0-9_]+)"')
# docs cite the batched plane as batched_execution.<name>; the module
# imports JAX, so its public surface is scraped from source, not imported
BATCHED_REF_RE = re.compile(
    r"batched_execution\.(?!py\b)([A-Za-z_][A-Za-z0-9_]*)")
DEF_OR_CLASS_RE = re.compile(r"^(?:def|class)\s+([A-Za-z_][A-Za-z0-9_]*)",
                             re.MULTILINE)
# shard-plane citations: ShardingSpec plus the Sharded* family
# (ShardedDeployment, ShardedAutotuneResult, ...).  Any CamelCase token
# matching this shape must be a real def/class in repro.core.
SHARD_REF_RE = re.compile(r"\b(ShardingSpec|Sharded[A-Z][A-Za-z0-9]*)\b")
# the shard surface spans stdlib-only modules (sharding, execution, api)
# and JAX-importing ones (sweep, autotune, transient, batched_execution);
# a source scrape covers both without importing anything
SHARD_SOURCE_MODULES = ("api", "sharding", "execution", "sweep",
                        "autotune", "transient", "batched_execution")
# geo-plane citations: GeoSpec plus the Geo* family (GeoLatency,
# GeoLatencySurface, ...) and the placement-autotune / region-partition
# surface.  The surface spans stdlib-only modules (api, geo, execution)
# and JAX-importing ones (sweep, autotune, transient,
# batched_execution); the same source scrape covers both.
GEO_REF_RE = re.compile(
    r"\b(GeoSpec|Geo[A-Z][A-Za-z0-9]*|autotune_placement|"
    r"placement_candidates|region_partition_schedule|"
    r"PlacementChoice|PlacementAutotuneResult)\b")
GEO_SOURCE_MODULES = ("api", "geo", "execution", "sweep", "autotune",
                      "transient", "batched_execution")
# docs cite the WAN lowering as geo.<name>: must be a top-level
# def/class in src/repro/core/geo.py or a GeoSpec field/method
# (geo.region_of(...), geo.rtt, ... in worked examples)
GEO_MODREF_RE = re.compile(r"\bgeo\.(?!py\b)([A-Za-z_][A-Za-z0-9_]*)")
# autoscale-plane citations: the policy/controller/trace types plus the
# live-resize and policy-search surface.  Spans stdlib-only modules
# (api, execution) and JAX-importing ones (autoscale, sweep, autotune,
# transient, batched_execution) - same source scrape.
AUTOSCALE_REF_RE = re.compile(
    r"\b(AutoscalePolicy|AutoscaleTrace|AutoscaleAction|"
    r"AutoscaledExecutionTrace|Controller|PolicyChoice|"
    r"PolicyAutotuneResult|autoscale_grid|autotune_policy|"
    r"run_autoscaled|resizable_stations|resize_config|station_knob_map|"
    r"reconfiguration_schedule|diurnal_load|flash_crowd_load|"
    r"measured_capacity)\b")
AUTOSCALE_SOURCE_MODULES = ("api", "autoscale", "execution", "sweep",
                            "autotune", "transient", "batched_execution")
# docs cite the control loop as autoscale.<name>: must be a top-level
# def/class in src/repro/core/autoscale.py or an AutoscalePolicy
# field/method (autoscale.diurnal_load(...), policy.target_high, ...)
AUTOSCALE_MODREF_RE = re.compile(
    r"\bautoscale\.(?!py\b)([A-Za-z_][A-Za-z0-9_]*)")


def batched_api() -> set[str]:
    """Top-level def/class names in the batched execution module."""
    src = (ROOT / "src" / "repro" / "core" / "batched_execution.py")
    return set(DEF_OR_CLASS_RE.findall(src.read_text()))


def shard_api() -> set[str]:
    """def/class names across every module hosting shard-plane surface."""
    core = ROOT / "src" / "repro" / "core"
    names: set[str] = set()
    for mod in SHARD_SOURCE_MODULES:
        names |= set(DEF_OR_CLASS_RE.findall((core / f"{mod}.py").read_text()))
    return names


def geo_api() -> tuple[set[str], set[str]]:
    """(plane-wide def/class names, geo.<name>-citable names).

    The second set is the surface a ``geo.<name>`` citation may touch:
    top-level def/class in geo.py plus GeoSpec fields and methods
    (scraped from the class body in api.py).
    """
    core = ROOT / "src" / "repro" / "core"
    names: set[str] = set()
    for mod in GEO_SOURCE_MODULES:
        names |= set(DEF_OR_CLASS_RE.findall((core / f"{mod}.py").read_text()))
    members = set(DEF_OR_CLASS_RE.findall((core / "geo.py").read_text()))
    api_src = (core / "api.py").read_text()
    m = re.search(r"class GeoSpec\b[\s\S]*?(?=\n(?:class |def |@)|\Z)",
                  api_src)
    if m:
        block = m.group(0)
        members |= set(re.findall(
            r"^\s+def\s+([A-Za-z_][A-Za-z0-9_]*)", block, re.MULTILINE))
        members |= set(re.findall(
            r"^    ([A-Za-z_][A-Za-z0-9_]*)\s*:", block, re.MULTILINE))
    return names, members


def autoscale_api() -> tuple[set[str], set[str]]:
    """(plane-wide def/class names, autoscale.<name>-citable names).

    The second set is the surface an ``autoscale.<name>`` citation may
    touch: top-level def/class in autoscale.py plus AutoscalePolicy
    fields and methods (scraped from the class body in api.py)."""
    core = ROOT / "src" / "repro" / "core"
    names: set[str] = set()
    for mod in AUTOSCALE_SOURCE_MODULES:
        names |= set(DEF_OR_CLASS_RE.findall((core / f"{mod}.py").read_text()))
    members = set(DEF_OR_CLASS_RE.findall(
        (core / "autoscale.py").read_text()))
    api_src = (core / "api.py").read_text()
    m = re.search(
        r"class AutoscalePolicy\b[\s\S]*?(?=\n(?:class |def |@)|\Z)",
        api_src)
    if m:
        block = m.group(0)
        members |= set(re.findall(
            r"^\s+def\s+([A-Za-z_][A-Za-z0-9_]*)", block, re.MULTILINE))
        members |= set(re.findall(
            r"^    ([A-Za-z_][A-Za-z0-9_]*)\s*:", block, re.MULTILINE))
    return names, members


def registered_labels() -> set[str]:
    """Benchmark labels from the MODULES table in benchmarks/run.py."""
    text = (ROOT / "benchmarks" / "run.py").read_text()
    return set(MODULE_LABEL_RE.findall(text))


def registry_variants() -> tuple[set[str], set[str]]:
    """(registered, executable) variant names from repro.core.api, loaded
    WITHOUT the repro package __init__ chain (which would import JAX):
    api.py, analytical.py, execution.py and the self-registering
    multi-leader modules bpaxos.py / iss.py (plus the stdlib-only
    correctness-plane modules they pull in through the package
    machinery) are stitched into a synthetic package; the built-in
    ``register_variant`` / ``register_executable`` calls run on import."""
    core = ROOT / "src" / "repro" / "core"
    pkg = types.ModuleType("_docscheck_core")
    pkg.__path__ = [str(core)]  # makes `from .api import ...` resolvable
    sys.modules["_docscheck_core"] = pkg
    try:
        for name in ("api", "analytical", "execution", "bpaxos", "iss"):
            importlib.import_module(f"_docscheck_core.{name}")
        api = sys.modules["_docscheck_core.api"]
        return set(api.registered_variants()), set(api.executable_variants())
    finally:
        for key in list(sys.modules):
            if key.startswith("_docscheck_core"):
                del sys.modules[key]


def main() -> int:
    missing: list[tuple[Path, str]] = []
    checked = 0
    labels = registered_labels()
    variants, executables = registry_variants()
    batched_names = batched_api()
    shard_names = shard_api()
    geo_names, geo_members = geo_api()
    autoscale_names, autoscale_members = autoscale_api()
    for doc in DOC_FILES:
        if not doc.exists():
            missing.append((doc.relative_to(ROOT), "(doc file itself)"))
            continue
        text = doc.read_text()
        for ref in sorted(set(PATH_RE.findall(text))):
            checked += 1
            if not (ROOT / ref.rstrip("/")).exists():
                missing.append((doc.relative_to(ROOT), ref))
        for m in FIG_RE.finditer(text):
            for num in filter(None, m.groups()):
                checked += 1
                if not 1 <= int(num) <= MAX_PAPER_FIG:
                    missing.append((doc.relative_to(ROOT),
                                    f"{m.group(0)} (paper has figures "
                                    f"1..{MAX_PAPER_FIG})"))
        for m in ONLY_RE.finditer(text):
            for label in m.group(1).split(","):
                checked += 1
                if label and label not in labels:
                    missing.append((doc.relative_to(ROOT),
                                    f"--only {label} (not a benchmarks/run.py "
                                    f"MODULES label)"))
        doc_local = set(DOC_LOCAL_VARIANT_RE.findall(text))
        for m in VARIANTS_TUPLE_RE.finditer(text):
            for name in QUOTED_NAME_RE.findall(m.group(1)):
                checked += 1
                if name not in variants and name not in doc_local:
                    missing.append((doc.relative_to(ROOT),
                                    f'variants=...{name!r} (not registered '
                                    f"in repro.core.api; known: "
                                    f"{sorted(variants)})"))
        doc_local_exec = doc_local | set(DOC_LOCAL_EXECUTABLE_RE.findall(text))
        for m in EXECUTED_VARIANT_RE.finditer(text):
            name = m.group(1)
            checked += 1
            if name not in executables and name not in doc_local_exec:
                missing.append((doc.relative_to(ROOT),
                                f"{m.group(0)}...) (variant has no "
                                f"registered execution plane; executable: "
                                f"{sorted(executables)})"))
        for m in BATCHED_REF_RE.finditer(text):
            checked += 1
            if m.group(1) not in batched_names:
                missing.append((doc.relative_to(ROOT),
                                f"{m.group(0)} (no such def/class in "
                                f"src/repro/core/batched_execution.py)"))
        for name in sorted(set(SHARD_REF_RE.findall(text))):
            checked += 1
            if name not in shard_names:
                missing.append((doc.relative_to(ROOT),
                                f"{name} (no such def/class in any shard-"
                                f"plane module: "
                                f"{', '.join(SHARD_SOURCE_MODULES)})"))
        for name in sorted(set(GEO_REF_RE.findall(text))):
            checked += 1
            if name not in geo_names:
                missing.append((doc.relative_to(ROOT),
                                f"{name} (no such def/class in any geo-"
                                f"plane module: "
                                f"{', '.join(GEO_SOURCE_MODULES)})"))
        for name in sorted(set(GEO_MODREF_RE.findall(text))):
            checked += 1
            if name not in geo_members:
                missing.append((doc.relative_to(ROOT),
                                f"geo.{name} (not a def/class in "
                                f"src/repro/core/geo.py nor a GeoSpec "
                                f"field/method)"))
        for name in sorted(set(AUTOSCALE_REF_RE.findall(text))):
            checked += 1
            if name not in autoscale_names:
                missing.append((doc.relative_to(ROOT),
                                f"{name} (no such def/class in any "
                                f"autoscale-plane module: "
                                f"{', '.join(AUTOSCALE_SOURCE_MODULES)})"))
        for name in sorted(set(AUTOSCALE_MODREF_RE.findall(text))):
            checked += 1
            if name not in autoscale_members:
                missing.append((doc.relative_to(ROOT),
                                f"autoscale.{name} (not a def/class in "
                                f"src/repro/core/autoscale.py nor an "
                                f"AutoscalePolicy field/method)"))
    if missing:
        print("dangling doc references:")
        for doc, ref in missing:
            print(f"  {doc}: {ref}")
        return 1
    print(f"docs-links OK ({checked} references across "
          f"{len(DOC_FILES)} docs)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
