#!/usr/bin/env python3
"""Verify that every repo path referenced in the docs actually exists.

Scans README.md, docs/*.md and benchmarks/README.md for references like
``src/repro/core/sweep.py``, ``benchmarks/run.py``, ``examples/...`` or
``tests/...`` (with or without an inline-code backtick wrapper) and fails
with a listing of any that point at nothing.  Keeps the paper->code map
honest as the tree is refactored.
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parents[1]

DOC_FILES = [ROOT / "README.md", ROOT / "benchmarks" / "README.md",
             *sorted((ROOT / "docs").glob("*.md"))]

# path-like tokens rooted at a known top-level directory
PATH_RE = re.compile(
    r"\b((?:src/repro|benchmarks|examples|tests|docs|scripts)"
    r"(?:/[A-Za-z0-9_.-]+)*"
    r"(?:\.(?:py|md|sh|txt|json)|/))")


def main() -> int:
    missing: list[tuple[Path, str]] = []
    checked = 0
    for doc in DOC_FILES:
        if not doc.exists():
            missing.append((doc.relative_to(ROOT), "(doc file itself)"))
            continue
        text = doc.read_text()
        for ref in sorted(set(PATH_RE.findall(text))):
            checked += 1
            if not (ROOT / ref.rstrip("/")).exists():
                missing.append((doc.relative_to(ROOT), ref))
    if missing:
        print("dangling doc references:")
        for doc, ref in missing:
            print(f"  {doc}: {ref}")
        return 1
    print(f"docs-links OK ({checked} references across "
          f"{len(DOC_FILES)} docs)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
