"""Serve a small LM through the compartmentalized fleet: weight updates are
writes through the replicated log; inference requests are leaderless reads
with watermark consistency (paper sections 3.4/3.6 with inference as the
read op).

  PYTHONPATH=src python examples/serve_replicated.py

``BENCH_SMOKE=1`` (set by ``make examples-smoke``) shrinks the request
counts so the walkthrough finishes faster on CI.
"""
import os

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serving.scheduler import ContinuousBatcher, Request
from repro.serving.server import ServingDeployment

SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")
N_INFER = 3 if SMOKE else 6
N_BATCHED = 4 if SMOKE else 8

cfg = get_config("granite-3-2b").smoke()
params = init_params(cfg, jax.random.key(0))

# --- fleet: 3 model replicas behind a 2x2 acceptor grid -------------------
fleet = ServingDeployment(cfg, n_replicas=3, n_clients=2,
                          consistency="linearizable")
v = fleet.push_weights(params)
print(f"weights v{v} committed through the log")

for i in range(N_INFER):
    version, toks = fleet.infer([1 + i, 2, 3], max_new=4, client=i % 2)
    print(f"request {i}: served at {version}, tokens={list(toks)}")

print(f"replica read loads: {fleet.replica_loads()} (spread, no leader)")

# --- a weight update mid-stream -------------------------------------------
params2 = init_params(cfg, jax.random.key(7))
fleet.push_weights(params2)
version, _ = fleet.infer([1, 2, 3], max_new=2)
assert version == "v2", "linearizable read must see the committed update"
print(f"post-update read served at {version} (read-your-committed-writes)")

# --- continuous batching on one replica ------------------------------------
cb = ContinuousBatcher(cfg, params, n_slots=3, max_len=32)
reqs = [Request(rid=i, prompt=[1, 2, 3, 4], max_new=3)
        for i in range(N_BATCHED)]
for r in reqs:
    cb.submit(r)
cb.run_until_drained()
print(f"continuous batching: {N_BATCHED} requests over 3 slots, "
      f"mean occupancy {cb.mean_occupancy:.2f}, "
      f"outputs ok: {all(len(r.out) == 3 for r in reqs)}")
