"""The paper's story in one script: walk the six compartmentalizations and
watch the bottleneck move and throughput climb (Fig. 29 live).

  PYTHONPATH=src python examples/compartmentalization_demo.py
"""
from repro.core import (
    Workload,
    ablation_steps,
    calibrate_alpha,
    compartmentalized_model,
    mixed_workload_speedup,
    multipaxos_model,
    mva_curve,
)
from repro.core.analytical import PAPER_MULTIPAXOS_UNBATCHED

alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
print(f"calibration: one anchor (vanilla MultiPaxos = 25k cmd/s) "
      f"-> alpha = {alpha:.0f} msgs/s per node\n")

print(f"{'configuration':58s} {'peak cmd/s':>12s}  bottleneck")
for name, model in ablation_steps():
    peak = model.peak_throughput(alpha)
    bn, _ = model.bottleneck()
    bar = "#" * int(peak / 3500)
    print(f"{name:58s} {peak:12,.0f}  {bn:8s} {bar}")

print("\nmixed workloads (the 16x headline), one Workload value each:")
for w in (Workload(name="write-only"),
          Workload(f_write=0.5, name="50% reads"),
          Workload.read_mix(0.9, name="90% reads"),
          Workload.read_mix(1.0, name="100% reads")):
    mp, cm, speedup = mixed_workload_speedup(w, alpha)
    print(f"  {w.name:12s}: MultiPaxos {mp:9,.0f} -> "
          f"Compartmentalized {cm:9,.0f}  ({speedup:.1f}x)")

print("\nlatency-throughput knee (MVA, 512 closed-loop clients):")
model = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=2,
                                grid_cols=2, n_replicas=4)
clients, x, r = mva_curve(model, alpha, n_clients_max=512)
for n in (1, 8, 64, 256, 512):
    print(f"  {n:4d} clients: {x[n-1]:9,.0f} cmd/s at "
          f"{r[n-1]*1e6:7.1f} us median latency")

print("\n(next: examples/autotune_demo.py searches the whole config space "
      "under a machine budget)")
