"""End-to-end fault-tolerant training driver (the assignment's training
example): train a small LM for a few hundred steps with RSM-coordinated
step commits, grid checkpoints, a simulated crash + recovery, a straggler,
and an elastic rescale.

  PYTHONPATH=src python examples/elastic_train.py
"""
import tempfile

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import Trainer

cfg = get_config("granite-3-2b").smoke()
ckpt_dir = tempfile.mkdtemp(prefix="repro_ckpt_")

trainer = Trainer(
    cfg, ckpt_dir,
    opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=200),
    data_cfg=DataConfig(vocab_size=cfg.vocab_size, seq_len=64,
                        global_batch=8, seed=0),
    n_virtual_workers=4, ckpt_every=20)

print(f"training {cfg.name}: {cfg.n_params():,} params, "
      f"4 virtual DP workers, grid checkpoints at {ckpt_dir}")

losses = []
for step in range(120):
    straggler = 3 if step == 40 else None        # worker 3 hangs at step 40
    m = trainer.run_step(straggler=straggler)
    losses.append(m["ce"])
    if step == 40:
        print(f"  step 40: straggler worker/3 noop-filled; "
              f"commit frontier {trainer.coord.view.committed_step}")
    if step == 60:
        print("  step 60: simulating full job crash...")
        restored = trainer.crash_and_recover()
        print(f"  recovered from committed checkpoint at step {restored} "
              f"(grid store, one row read)")
    if step == 80:
        trainer.scale_workers(6)
        print(f"  step 80: elastic scale-up to 6 workers "
              f"(generation {trainer.coord.view.generation}; deterministic "
              f"data pipeline needs no handoff)")
    if step % 20 == 0:
        print(f"step {m['step']:4d} ce={m['ce']:.4f} "
              f"committed={trainer.coord.view.committed_step}")

print(f"\nloss: first5={sum(losses[:5])/5:.4f} last5={sum(losses[-5:])/5:.4f}")
assert sum(losses[-5:]) < sum(losses[:5]), "loss should decrease"
print("done - loss decreased through a straggler, a crash and a rescale.")
