"""Find me the best deployment for a machine budget.

The paper's authors hand-tuned their evaluation deployment (1 leader, 10
proxy leaders, a 2x2 acceptor grid, 4 replicas).  The autotuner searches
the whole discrete config space under a budget and prints the greedy
bottleneck-migration staircase that explains the answer - Fig. 29,
rediscovered by the machine for any workload mix.

  PYTHONPATH=src python examples/autotune_demo.py [budget]
"""
import sys

from repro.core import autotune, calibrate_alpha
from repro.core.analytical import PAPER_MULTIPAXOS_UNBATCHED

budget = int(sys.argv[1]) if len(sys.argv) > 1 else 19
alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
print(f"machine budget: {budget}  (paper's hand-tuned deployment uses 19)\n")

for f_write, label in ((1.0, "write-only"), (0.5, "50% reads"),
                       (0.1, "90% reads")):
    try:
        res = autotune(budget=budget, alpha=alpha, f_write=f_write)
    except ValueError as e:
        raise SystemExit(f"error: {e}")
    c = res.best_config
    print(f"== {label}: best of {res.n_candidates} candidate deployments ==")
    print(f"   {res.best_peak:,.0f} cmd/s on {res.machines} machines "
          f"(bottleneck: {res.best_bottleneck})")
    print(f"   proxies={c['n_proxy_leaders']} "
          f"grid={c['grid_rows']}x{c['grid_cols']} "
          f"replicas={c['n_replicas']}")
    print("   bottleneck migration (greedy staircase):")
    for t in res.trace:
        print(f"     step {t.step:2d}  {t.label:34s} {t.machines:3d} machines "
              f"{t.peak:12,.0f} cmd/s  -> {t.bottleneck}")
    print()

print("with batching enabled (amortizes the sequencing leader):")
res = autotune(budget=budget, alpha=alpha, f_write=1.0, batching=True)
c = res.best_config
print(f"   {res.best_peak:,.0f} cmd/s on {res.machines} machines "
      f"(bottleneck: {res.best_bottleneck}); batchers={c['n_batchers']} "
      f"unbatchers={c['n_unbatchers']} B={c['batch_size']}")
