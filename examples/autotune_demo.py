"""Find me the best deployment for a machine budget.

The paper's authors hand-tuned their evaluation deployment (1 leader, 10
proxy leaders, a 2x2 acceptor grid, 4 replicas).  The autotuner searches
the whole discrete config space under a budget and prints the greedy
bottleneck-migration staircase that explains the answer - Fig. 29,
rediscovered by the machine for any workload mix.

  PYTHONPATH=src python examples/autotune_demo.py [budget]
"""
import sys

from repro.core import Workload, autotune, calibrate_alpha
from repro.core.analytical import PAPER_MULTIPAXOS_UNBATCHED

budget = int(sys.argv[1]) if len(sys.argv) > 1 else 19
alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
print(f"machine budget: {budget}  (paper's hand-tuned deployment uses 19)\n")

for workload in (Workload(name="write-only"),
                 Workload(f_write=0.5, name="50% reads"),
                 Workload.read_mix(0.9, name="90% reads")):
    try:
        res = autotune(budget=budget, alpha=alpha, workload=workload)
    except ValueError as e:
        raise SystemExit(f"error: {e}")
    c = res.best_config
    print(f"== {workload.name}: best of {res.n_candidates} "
          f"candidate deployments ==")
    print(f"   {res.best_peak:,.0f} cmd/s on {res.machines} machines "
          f"(bottleneck: {res.best_bottleneck})")
    print(f"   proxies={c['n_proxy_leaders']} "
          f"grid={c['grid_rows']}x{c['grid_cols']} "
          f"replicas={c['n_replicas']}")
    print("   bottleneck migration (greedy staircase):")
    for t in res.trace:
        print(f"     step {t.step:2d}  {t.label:34s} {t.machines:3d} machines "
              f"{t.peak:12,.0f} cmd/s  -> {t.bottleneck}")
    print()

print("with batching enabled (amortizes the sequencing leader):")
try:
    res = autotune(budget=budget, alpha=alpha, workload=Workload(),
                   batching=True)
except ValueError as e:
    raise SystemExit(f"error: {e}")
c = res.best_config
print(f"   {res.best_peak:,.0f} cmd/s on {res.machines} machines "
      f"(bottleneck: {res.best_bottleneck}); batchers={c['n_batchers']} "
      f"unbatchers={c['n_unbatchers']} B={c['batch_size']}")

print("\nsame budget when batches only half fill (bursty arrivals close "
      "them early):")
res = autotune(budget=budget, alpha=alpha,
               workload=Workload(batch_fill=0.5, arrival="bursty"),
               batching=True)
print(f"   {res.best_peak:,.0f} cmd/s on {res.machines} machines "
      f"(bottleneck: {res.best_bottleneck}) - the Workload carries the "
      f"fill hint; no per-call kwargs")
