"""Quickstart: a linearizable replicated KV store on compartmentalized
MultiPaxos, in ~40 lines.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import full_compartmentalized
from repro.core.linearizability import check_linearizable, check_slot_order

# 10 proxy leaders, a 2x2 acceptor grid, 4 replicas (the paper's deployment)
dep = full_compartmentalized(f=1, n_clients=3, state_machine="kv")

# three concurrent clients
dep.clients[0].run_ops([("put", "lang", "jax"), ("get", "lang")])
dep.clients[1].run_ops([("put", "paper", "compartmentalization"),
                        ("get", "paper")])
dep.clients[2].run_ops([("get", "lang"), ("put", "lang", "pallas"),
                        ("get", "lang")])
dep.run_to_quiescence()

for i, c in enumerate(dep.clients):
    print(f"client {i} results: {c.results}")

# every replica executed the same log
states = [r.sm.snapshot() for r in dep.replicas]
assert all(s == states[0] for s in states), "replica divergence!"
print(f"replicas in sync: {states[0]}")

# the recorded history is linearizable (exhaustive check)
assert check_slot_order(dep.history) == []
assert check_linearizable(dep.history, "kv")
print(f"history of {len(dep.history)} ops verified linearizable")

# message-count economics (the paper's core claim)
leader = dep.leaders[0]
n_writes = 4
print(f"leader handled ~{(leader.msgs_sent + leader.msgs_received)} msgs "
      f"for {n_writes} writes (2/cmd; vanilla MultiPaxos needs 3f+4=7/cmd)")
