"""Transient dynamics: leader failover, bottleneck migration in time, and
batch fill ramps (paper sections 5 / 8.5, Figs. 30-31 dynamics).

Everything here runs on the batched stochastic transient engine
(`repro.core.transient`): every (deployment x seed) lane of each figure is
one jitted ``lax.scan`` call.  Rows:

* failover: crash the leader for the middle 20% of the run - throughput
  dips to zero (pipeline drains) and recovers to the pre-crash plateau;
  p99 latency carries the stall, p50 barely moves.
* scale-up: halve the proxy-leader demand mid-run on a proxy-bottlenecked
  deployment - throughput steps up as the bottleneck migrates to the
  leader (compartmentalization as a *runtime* action).
* batch fill: ramp the batch size 1 -> 100 across windows on the batched
  deployment - throughput ramps accordingly.
* bursty arrivals: the same deployment under ``Workload(arrival="bursty")``
  - demand-surge windows inflate p99 while the steady mean barely moves
  (the workload-first API's arrival hint, lowered to scripted events).
* autotune: rank budget-19 configs by p99 *under the leader crash* - the
  fault-tolerant pick vs the steady-state-mean pick.
"""
import time

import numpy as np

from repro.core import (
    Event,
    Workload,
    autotune,
    calibrate_alpha,
    compartmentalized_model,
    compile_models,
    multipaxos_model,
    schedule_from_demands,
    simulate_transient,
)
from repro.core.analytical import PAPER_MULTIPAXOS_UNBATCHED, stack_demands


def run():
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    rows = []

    # -- leader crash + failover on MultiPaxos vs compartmentalized --------
    mp = multipaxos_model(f=1)
    cmp_u = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=2,
                                    grid_cols=2, n_replicas=4)
    compiled = compile_models([mp, cmp_u])
    t0 = time.perf_counter()
    res = compiled.transient(alpha, workload=Workload(),
                             events=[Event("leader", 0.4, 0.6, 1e9)],
                             n_clients=64, seeds=8, n_steps=6000)
    us = (time.perf_counter() - t0) * 1e6
    _, trace = res.throughput_trace(n_windows=30)
    xm = trace.mean(axis=1)                     # seed-mean [M, 30]
    for i, name in enumerate(("multipaxos", "compartmentalized")):
        pre = xm[i, 3:11].mean()
        dip = xm[i, 13:17].mean()
        post = xm[i, 24:].mean()
        rows.append((f"failover/{name}_trace", us if i == 0 else 0.0,
                     f"pre {pre:.0f} -> crash {dip:.0f} -> recovered "
                     f"{post:.0f} cmd/s ({post/pre:.2f}x of plateau)"))
        rows.append((f"failover/{name}_latency", 0.0,
                     f"p50 {res.latency_p50[i].mean()*1e3:.2f} ms vs p99 "
                     f"{res.latency_p99[i].mean()*1e3:.2f} ms "
                     f"(tail carries the stall)"))

    # -- mid-run scale-up migrates the bottleneck --------------------------
    prx = compartmentalized_model(f=1, n_proxy_leaders=2, grid_rows=3,
                                  grid_cols=1, n_replicas=2)  # proxy-bound
    t0 = time.perf_counter()
    res = compile_models([prx]).transient(
        alpha, workload=Workload(), events=[Event("proxy", 0.5, 1.0, 0.5)],
        n_clients=64, seeds=8, n_steps=6000)
    us = (time.perf_counter() - t0) * 1e6
    _, trace = res.throughput_trace(n_windows=20)
    xm = trace.mean(axis=1)[0]
    before, after = xm[3:9].mean(), xm[14:].mean()
    rows.append(("failover/proxy_scale_up_mid_run", us,
                 f"{before:.0f} -> {after:.0f} cmd/s ({after/before:.2f}x): "
                 f"2->4 proxies at t/2, bottleneck migrates proxy -> leader"))

    # -- batch fill ramp (Figs. 30-31 as dynamics) -------------------------
    batch_sizes = (1, 2, 5, 10, 20, 50, 100)
    models = [compartmentalized_model(f=1, n_proxy_leaders=3, grid_rows=2,
                                      grid_cols=2, n_replicas=2, batch_size=b,
                                      n_batchers=2, n_unbatchers=3)
              for b in batch_sizes]
    d_w, _, _ = stack_demands(models)
    windows = [d_w[i:i + 1] / alpha for i in range(len(models))]
    starts = [i / len(models) for i in range(len(models))]
    # window length and client count chosen so each batch regime spans
    # several saturated round trips: the per-window reading must reflect
    # that regime's own bottleneck, not inter-window backlog drain
    n_steps = 28000
    sched, bounds = schedule_from_demands(windows, starts, n_steps)
    t0 = time.perf_counter()
    res = simulate_transient(sched, bounds, n_clients=96, seeds=4,
                             n_steps=n_steps, warmup_frac=0.02)
    us = (time.perf_counter() - t0) * 1e6
    # per-schedule-window means, transition backlog excluded - each rate
    # must sit under its own window's bottleneck-law cap
    xm = res.window_throughput(bounds, settle=0.5).mean(axis=1)[0]
    rows.append(("failover/batch_fill_ramp", us,
                 f"B={list(batch_sizes)} -> "
                 f"{[f'{x:.0f}' for x in xm]} cmd/s "
                 f"({xm[-1]/xm[0]:.1f}x ramp as batches fill)"))

    # -- bursty arrivals via the Workload API ------------------------------
    sweep = compile_models([cmp_u])
    t0 = time.perf_counter()
    steady = sweep.transient(alpha, workload=Workload(), n_clients=64,
                             seeds=6, n_steps=4000)
    bursty = sweep.transient(
        alpha, workload=Workload(arrival="bursty", burst_factor=4.0,
                                 burst_fraction=0.25),
        n_clients=64, seeds=6, n_steps=4000)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("failover/bursty_arrivals_p99", us,
                 f"steady p99 {steady.seed_mean_p99()[0]*1e3:.2f} ms -> "
                 f"bursty p99 {bursty.seed_mean_p99()[0]*1e3:.2f} ms "
                 f"(4x surges, 25% of the run; one Workload value, "
                 f"lowered to scripted demand windows)"))

    # -- autotune by p99 under faults --------------------------------------
    t0 = time.perf_counter()
    res_p = autotune(budget=19, alpha=alpha, workload=Workload())
    res_f = autotune(budget=19, alpha=alpha, workload=Workload(),
                     objective="p99_under_failover",
                     transient_kwargs=dict(seeds=6, n_steps=2500))
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("failover/autotune_p99_under_crash", us,
                 f"steady-mean pick {res_p.machines} machines @ "
                 f"{res_p.best_peak:.0f} cmd/s; p99-under-crash pick "
                 f"{res_f.machines} machines @ {res_f.best_peak:.0f} cmd/s, "
                 f"p99 {res_f.best_p99*1e3:.2f} ms"))
    return rows
