"""Message-count parity on the *real* protocol clusters - validates the
demand tables every analytical figure is built from.

Paper section 3.1: vanilla leader handles >= 3f+4 messages per command;
the compartmentalized leader handles 2.  Grid section 3.2: each acceptor
sees 1/w of writes.  Sections 6-7: the Mencius and S-Paxos clusters match
their tables (the S-Paxos leader measures **exactly 2** id-only msgs/cmd).
These counts are measured, not modelled.

Since the execution plane joined the registry this module is ONE
zero-branch loop: every variant that declares an
:class:`~repro.core.api.ExecutableSpec` is executed by
``repro.core.execution.validate_variant`` - closed-loop workload, history
collection, linearizability check, measured per-station msgs/cmd bucketed
into canonical ``STATION_ORDER`` slots - and parity-checked against its
own registered demand table.  Per-variant physics (address -> station
bucketing, measured announce/skip/forwarding feedback, tolerances, which
stations are message-exact) is *data* in the registry, not branches here.
A variant registered at runtime with an executable shows up in this
benchmark with zero edits.

``tests/test_variant_models.py`` and ``tests/test_execution.py`` pin the
same parity; ``make parity-smoke`` runs this module shrunk.
"""
import os
import time

from repro.core import (
    MIXED_50_50,
    WRITE_ONLY,
    calibrate_alpha,
    executable_variants,
    validate_variant,
)

#: The paper states its message-count tables for the write-only mix; the
#: 50/50 mix exercises the read paths (leaderless reads, CRAQ chains).
WORKLOADS = (WRITE_ONLY, MIXED_50_50)

SMOKE = bool(os.environ.get("BENCH_SMOKE"))


def run():
    n_commands = 24 if SMOKE else 60
    rows = []
    failures = []

    for name in executable_variants():
        for workload in WORKLOADS:
            t0 = time.perf_counter()
            report = validate_variant(name, workload=workload,
                                      n_commands=n_commands, seed=0)
            wall_us = (time.perf_counter() - t0) * 1e6
            rows.append((f"msgcount/{name}_parity_{workload.name}", wall_us,
                         report.summary()))
            if not report.passed:
                failures.append(str(report))

    # the measured calibration anchor: alpha from an *executed* vanilla run
    t0 = time.perf_counter()
    alpha_measured = calibrate_alpha(measured=True,
                                     n_commands=n_commands, seed=0)
    wall_us = (time.perf_counter() - t0) * 1e6
    rows.append(("msgcount/alpha_measured_anchor", wall_us,
                 f"alpha = {alpha_measured:.0f} msgs/s from the executed "
                 f"vanilla run (table-derived: {calibrate_alpha():.0f})"))

    if failures:
        raise AssertionError(
            "measured-vs-analytical parity failed:\n" + "\n".join(failures))
    return rows
