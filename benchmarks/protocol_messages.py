"""Message-count microbenchmark on the *real* protocol clusters - validates
the demand tables every analytical figure is built from.

Paper section 3.1: vanilla leader handles >= 3f+4 messages per command;
the compartmentalized leader handles 2.  Grid section 3.2: each acceptor
sees 1/w of writes.  These counts are measured, not modelled.

The variant clusters are cross-checked the same way: the measured
per-station messages per command of a Mencius deployment (section 6) and
an S-Paxos deployment (section 7) are compared against
``repro.core.analytical.mencius_model`` / ``spaxos_model`` - the demand
tables ``benchmarks/variants.py`` and the mixed-variant sweep axis are
built from.  ``tests/test_variant_models.py`` pins the same parity with
tolerances.
"""
import time

from repro.core import (
    MenciusDeployment,
    SPaxosDeployment,
    Workload,
    full_compartmentalized,
    mencius_model,
    spaxos_model,
    vanilla_multipaxos,
)

#: The measured clusters run a put-only op stream, i.e. the write-only mix.
MEASURED_WORKLOAD = Workload(name="write_only")


def station_msgs_per_cmd(nodes, n_cmds):
    """Measured (sent + received) messages per command per server."""
    total = sum(n.msgs_sent + n.msgs_received for n in nodes)
    return total / n_cmds / len(nodes)


def measure_mencius(n_ops_per_client=20):
    """Per-station msgs/cmd of a balanced 3-leader Mencius run, plus the
    matching model demands.  Two model quirks of the correctness plane are
    fed back into the table so the comparison is apples-to-apples:
    ``announce_interval=1`` (the plane announces its frontier on every
    command, where the paper's protocol piggybacks it) and the *measured*
    noop-skip parameters (lagging leaders range-fill vacant slots; the
    effective ``skip_fraction`` and per-range amortization ``skip_batch``
    are read off the run instead of assumed)."""
    dep = MenciusDeployment(n_leaders=3, n_proxy_leaders=4, grid=(2, 2),
                            n_replicas=3, n_clients=3)
    for c in dep.clients:
        c.run_ops([("put", f"{c.addr}-k{i}", i) for i in range(n_ops_per_client)])
    dep.net.run(max_steps=500_000)
    assert all(c.done for c in dep.clients)
    n_cmds = 3 * n_ops_per_client
    measured = {
        "leader": station_msgs_per_cmd(dep.leaders, n_cmds),
        "proxy": station_msgs_per_cmd(dep.proxies, n_cmds),
        "acceptor": station_msgs_per_cmd(dep.acceptors, n_cmds),
        "replica": station_msgs_per_cmd(dep.replicas, n_cmds),
    }
    n_ranges = dep.total_skips()
    n_slots = max(r.executed_upto for r in dep.replicas) + 1
    n_noops = max(n_slots - n_cmds, 0)
    kwargs = dict(n_leaders=3, n_proxy_leaders=4, grid_rows=2, grid_cols=2,
                  n_replicas=3, announce_interval=1.0)
    if n_noops and n_ranges:
        kwargs.update(skip_fraction=n_noops / n_slots,
                      skip_batch=n_noops / n_ranges)
    model = mencius_model(**kwargs).demands(MEASURED_WORKLOAD)
    return measured, model, n_ranges, n_noops


def measure_spaxos(n_ops_per_client=20):
    """Per-station msgs/cmd of an S-Paxos run vs the model demands; the
    leader must measure exactly 2 (ProposeId in, Phase2a(id) out) - it
    never touches payloads."""
    dep = SPaxosDeployment(n_clients=2)  # d=2, s=3, p=3, grid 2x2, n=3
    for c in dep.clients:
        c.run_ops([("put", f"{c.addr}-k{i}", i) for i in range(n_ops_per_client)])
    dep.net.run(max_steps=500_000)
    assert all(c.done for c in dep.clients)
    n_cmds = 2 * n_ops_per_client
    measured = {
        "disseminator": station_msgs_per_cmd(dep.disseminators, n_cmds),
        "stabilizer": station_msgs_per_cmd(dep.stabilizers, n_cmds),
        "leader": station_msgs_per_cmd([dep.leader], n_cmds),
        "proxy": station_msgs_per_cmd(dep.proxies, n_cmds),
        "acceptor": station_msgs_per_cmd(dep.acceptors, n_cmds),
        "replica": station_msgs_per_cmd(dep.replicas, n_cmds),
    }
    model = spaxos_model(n_disseminators=2, n_stabilizers=3,
                         n_proxy_leaders=3, grid_rows=2, grid_cols=2,
                         n_replicas=3).demands(MEASURED_WORKLOAD)
    return measured, model


def _parity_row(name, measured, model, note=""):
    pairs = ", ".join(
        f"{k} {measured[k]:.2f}/{model[k]:.2f}" for k in measured)
    return (name, 0.0, f"measured/modelled msgs per cmd per server: {pairs}"
            + (f" ({note})" if note else ""))


def run():
    n_ops = 50
    t0 = time.perf_counter()
    rows = []

    vp = vanilla_multipaxos(f=1, n_clients=1)
    vp.clients[0].run_ops([("put", f"k{i}", i) for i in range(n_ops)])
    vp.run_to_quiescence()
    vl = vp.leaders[0]
    vanilla = (vl.msgs_sent + vl.msgs_received) / n_ops

    cp = full_compartmentalized(f=1, n_clients=1, grid=(2, 3), n_replicas=3)
    cp.clients[0].run_ops([("put", f"k{i}", i) for i in range(n_ops)])
    cp.run_to_quiescence()
    cl = cp.leaders[0]
    comp = (cl.msgs_sent + cl.msgs_received) / n_ops
    per_acceptor = [a.msgs_received / n_ops for a in cp.acceptors]
    proxy_total = sum(p.msgs_sent + p.msgs_received for p in cp.proxies) / n_ops

    # read path: linearizable read touches one acceptor row + one replica
    cp.clients[0].run_ops([("get", "k0")] * 20)
    before = {a.addr: a.msgs_received for a in cp.acceptors}
    cp.run_to_quiescence()
    read_msgs = sum(a.msgs_received - before[a.addr] for a in cp.acceptors) / 20

    wall_us = (time.perf_counter() - t0) * 1e6
    rows.append(("msgcount/cluster_run", wall_us, f"{2*n_ops+20} ops end-to-end"))
    rows.append(("msgcount/vanilla_leader_per_cmd", 0.0,
                 f"{vanilla:.2f} msgs/cmd (paper: >= 3f+4 = 7)"))
    rows.append(("msgcount/compartmentalized_leader_per_cmd", 0.0,
                 f"{comp:.2f} msgs/cmd (paper: 2)"))
    rows.append(("msgcount/proxy_leaders_per_cmd", 0.0,
                 f"{proxy_total:.2f} msgs/cmd across proxies (3f+4 + replicas)"))
    rows.append(("msgcount/acceptor_write_share_2x3_grid", 0.0,
                 f"per-acceptor recv {[f'{x:.2f}' for x in per_acceptor]} "
                 f"msgs/cmd (1/w = 0.33 expected; send+recv = 2/w)"))
    rows.append(("msgcount/read_acceptor_msgs", 0.0,
                 f"{read_msgs:.2f} acceptor msgs/read (one row x Preread+Ack "
                 f"= 2*w/row-count expected ~3)"))

    # variant clusters vs their demand tables (sections 6-7)
    t1 = time.perf_counter()
    m_measured, m_model, skips, noops = measure_mencius()
    s_measured, s_model = measure_spaxos()
    wall_us = (time.perf_counter() - t1) * 1e6
    rows.append(("msgcount/variant_cluster_run", wall_us,
                 "mencius + spaxos end-to-end"))
    rows.append(_parity_row("msgcount/mencius_parity", m_measured, m_model,
                            note=f"{skips} skip ranges / {noops} noop slots "
                                 f"fed back into the table's skip knobs"))
    rows.append(_parity_row("msgcount/spaxos_parity", s_measured, s_model,
                            note="leader exactly 2: ids only, no payloads"))
    return rows
