"""Message-count microbenchmark on the *real* protocol cluster - validates
the demand tables every analytical figure is built from.

Paper section 3.1: vanilla leader handles >= 3f+4 messages per command;
the compartmentalized leader handles 2.  Grid section 3.2: each acceptor
sees 1/w of writes.  These counts are measured, not modelled.
"""
import time

from repro.core import full_compartmentalized, vanilla_multipaxos


def run():
    n_ops = 50
    t0 = time.perf_counter()
    rows = []

    vp = vanilla_multipaxos(f=1, n_clients=1)
    vp.clients[0].run_ops([("put", f"k{i}", i) for i in range(n_ops)])
    vp.run_to_quiescence()
    vl = vp.leaders[0]
    vanilla = (vl.msgs_sent + vl.msgs_received) / n_ops

    cp = full_compartmentalized(f=1, n_clients=1, grid=(2, 3), n_replicas=3)
    cp.clients[0].run_ops([("put", f"k{i}", i) for i in range(n_ops)])
    cp.run_to_quiescence()
    cl = cp.leaders[0]
    comp = (cl.msgs_sent + cl.msgs_received) / n_ops
    per_acceptor = [a.msgs_received / n_ops for a in cp.acceptors]
    proxy_total = sum(p.msgs_sent + p.msgs_received for p in cp.proxies) / n_ops

    # read path: linearizable read touches one acceptor row + one replica
    cp.clients[0].run_ops([("get", "k0")] * 20)
    before = {a.addr: a.msgs_received for a in cp.acceptors}
    cp.run_to_quiescence()
    read_msgs = sum(a.msgs_received - before[a.addr] for a in cp.acceptors) / 20

    wall_us = (time.perf_counter() - t0) * 1e6
    rows.append(("msgcount/cluster_run", wall_us, f"{2*n_ops+20} ops end-to-end"))
    rows.append(("msgcount/vanilla_leader_per_cmd", 0.0,
                 f"{vanilla:.2f} msgs/cmd (paper: >= 3f+4 = 7)"))
    rows.append(("msgcount/compartmentalized_leader_per_cmd", 0.0,
                 f"{comp:.2f} msgs/cmd (paper: 2)"))
    rows.append(("msgcount/proxy_leaders_per_cmd", 0.0,
                 f"{proxy_total:.2f} msgs/cmd across proxies (3f+4 + replicas)"))
    rows.append(("msgcount/acceptor_write_share_2x3_grid", 0.0,
                 f"per-acceptor recv {[f'{x:.2f}' for x in per_acceptor]} "
                 f"msgs/cmd (1/w = 0.33 expected; send+recv = 2/w)"))
    rows.append(("msgcount/read_acceptor_msgs", 0.0,
                 f"{read_msgs:.2f} acceptor msgs/read (one row x Preread+Ack "
                 f"= 2*w/row-count expected ~3)"))
    return rows
