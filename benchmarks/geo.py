"""Geo-replication plane: WAN latency surfaces and placement autotuning.

The paper evaluates compartmentalization inside one datacenter - every
link costs the same - so its whole latency story is queueing.  Deployed
across regions the wire dominates: a commit path that hops
client -> leader -> acceptor quorum -> proxy -> replica pays a different
WAN toll from every region, and *where* the stations sit becomes a knob
as real as how many proxies to run.  This module renders that axis:

* the (config x region) latency surface: per-variant critical-path WAN
  lowering (``repro.core.geo``) composed with the batched MVA queueing
  solve in ONE jitted call (``CompiledSweep.geo_latency``);
* placement autotuning: ``spread`` / ``single/<r>`` / ``hub/<r>``
  candidates ranked by worst client-bearing region p99 - the hub
  placement (ordering core pinned, replica tier spread) beats every
  fully-pinned placement for spread clients;
* measured parity: ``validate_variant(geo=...)`` runs the real cluster
  with the WAN matrix on the wire and checks per-region measured
  latency against the analytical critical path;
* batched region lanes: ``execute_configs(geo=...)`` fans a config into
  per-region closed-loop client populations in one device call;
* a region partition transient: one region drops off the WAN mid-run,
  surviving stations absorb its traffic, ``single``-placed stations
  freeze;
* the geo-stable calibration anchor (``calibrate_alpha(geo=...)``).

``BENCH_SMOKE=1`` (set by ``make geo-smoke``) shrinks command counts and
the candidate grid.
"""
import os
import time

import numpy as np

from repro.core import (
    GeoSpec,
    SweepSpec,
    Workload,
    autotune_placement,
    calibrate_alpha,
    compile_sweep,
    execute_configs,
    geo_variants,
    predict_geo_latency,
    region_partition_schedule,
    simulate_transient,
    validate_variant,
    wan_offsets,
)

SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")
N_CMDS = 30 if SMOKE else 60
PARITY_VARIANTS = (("compartmentalized", "bpaxos") if SMOKE else
                   ("compartmentalized", "multipaxos", "mencius", "bpaxos"))

# a 3-region WAN: us<->eu 8, us<->ap 16, eu<->ap 12 virtual-time ticks
# round trip (small enough that no protocol retry timer fires, so message
# counts stay delay-invariant and parity is meaningful)
GEO = GeoSpec(regions=("us", "eu", "ap"),
              rtt=((0, 8, 16), (8, 0, 12), (16, 12, 0)))
# the same regions at realistic planetary scale for the placement search
GEO_WAN = GeoSpec(regions=("us", "eu", "ap"),
                  rtt=((0, 80, 160), (80, 0, 120), (160, 120, 0)))


def run(alpha=None):
    alpha = alpha if alpha is not None else calibrate_alpha()
    rows = []
    w = Workload(f_write=0.5)

    # -- (config x region) latency surface in one jitted call --------------
    spec = SweepSpec(n_proxy_leaders=(2, 4, 6) if SMOKE else (2, 4, 6, 8),
                     n_replicas=(2, 4))
    grid = compile_sweep(spec)
    t0 = time.perf_counter()
    surf = grid.geo_latency(alpha, GEO_WAN, workload=w, n_clients=64)
    us = (time.perf_counter() - t0) * 1e6
    i = int(surf.worst_p99().argmin())
    per = ", ".join(f"{r}={surf.p99[i, j]:.0f}"
                    for j, r in enumerate(surf.regions))
    rows.append((f"geo/latency_surface_{len(grid)}x{len(surf.regions)}", us,
                 f"one geo_latency call: {len(grid)} configs x "
                 f"{len(surf.regions)} regions; best worst-region p99 "
                 f"{surf.worst_p99()[i]:.0f} ticks (config {i}: {per})"))

    # -- placement autotune: hub beats every pinned placement --------------
    t0 = time.perf_counter()
    tune = autotune_placement(budget=12, alpha=alpha, geo=GEO_WAN,
                              workload=Workload(f_write=0.2), n_clients=64)
    us = (time.perf_counter() - t0) * 1e6
    margin = tune.single_region_best.worst_p99 - tune.best.worst_p99
    rows.append(("geo/placement_autotune_budget12", us,
                 f"winner {tune.best.placement} worst-region p99 "
                 f"{tune.best.worst_p99:.0f} vs best single-region "
                 f"({tune.single_region_best.placement}) "
                 f"{tune.single_region_best.worst_p99:.0f} - spread "
                 f"clients save {margin:.0f} ticks by keeping the replica "
                 f"tier spread ({tune.n_candidates} configs x "
                 f"{len(tune.per_placement)} placements)"))

    # -- measured per-region parity on the real clusters -------------------
    for name in PARITY_VARIANTS:
        t0 = time.perf_counter()
        rep = validate_variant(name, workload=w, n_commands=N_CMDS, seed=0,
                               geo=GEO)
        us = (time.perf_counter() - t0) * 1e6
        assert rep.passed, str(rep)
        lat = [r for r in rep.rows if r.station.startswith("wan_latency/")]
        detail = ", ".join(
            f"{r.station.split('/')[1]} {r.measured:.1f}/{r.predicted:.1f}"
            for r in lat)
        rows.append((f"geo/parity_{name}", us,
                     f"per-region measured/predicted latency (ticks): "
                     f"{detail}; msgs/cmd parity + linearizability hold "
                     f"under the WAN matrix"))

    # -- batched plane: per-region lanes in one device call ----------------
    cfgs = [{"variant": "compartmentalized", "n_proxy_leaders": 2,
             "n_replicas": 2},
            {"variant": "bpaxos"}]
    t0 = time.perf_counter()
    res = execute_configs(cfgs, workload=w, n_commands=N_CMDS, seeds=2,
                          geo=GEO)
    us = (time.perf_counter() - t0) * 1e6
    lat0 = res.region_latency(0, "p99")
    rows.append((f"geo/batched_region_lanes_{len(res)}", us,
                 f"{len(cfgs)} configs -> {len(res)} region lanes, one "
                 f"jitted call; compartmentalized per-region p99 "
                 + ", ".join(f"{r}={v:.1f}" for r, v in sorted(lat0.items()))
                 + " (WAN offset + measured queueing)"))

    # -- transient: one region partitions off the WAN ----------------------
    model = grid.models[i]
    base = grid.demands(w)[i:i + 1] / alpha
    sched, bounds = region_partition_schedule(base, model, GEO_WAN, "us",
                                              start=0.4, stop=0.6)
    t0 = time.perf_counter()
    tr = simulate_transient(sched, bounds, n_clients=32, seeds=4,
                            n_steps=4000)
    us = (time.perf_counter() - t0) * 1e6
    x = tr.window_throughput(bounds)[0].mean(axis=0)
    rows.append(("geo/region_partition_transient", us,
                 f"us drops off the WAN: {x[0]:.0f} -> {x[1]:.0f} -> "
                 f"{x[2]:.0f} cmd/s (survivors absorb the lost region's "
                 f"stations at c/(c-m) demand, then heal)"))

    # -- geo-stable calibration anchor -------------------------------------
    t0 = time.perf_counter()
    a0 = calibrate_alpha(measured=True)
    a_uni = calibrate_alpha(measured=True, geo=GeoSpec.uniform(3))
    a_geo = calibrate_alpha(measured=True, geo=GEO)
    us = (time.perf_counter() - t0) * 1e6
    assert a_uni == a0, (a_uni, a0)
    drift = abs(a_geo - a0) / a0
    assert drift < 0.05, drift
    rows.append(("geo/calibration_stability", us,
                 f"measured anchor: base {a0:.0f}, uniform matrix "
                 f"{a_uni:.0f} (exact), WAN matrix {a_geo:.0f} "
                 f"({100 * drift:.1f}% after modeled-RTT subtraction)"))

    # -- coverage: every executable variant has a WAN lowering -------------
    offs = {}
    for name in geo_variants():
        off = wan_offsets({"variant": name}, GEO, workload=w)
        lat = predict_geo_latency({"variant": name}, GEO)
        offs[name] = max(off)
    rows.append((f"geo/wan_lowering_{len(offs)}_variants", 0.0,
                 "max per-region WAN excess (ticks): "
                 + ", ".join(f"{n}={v:.1f}" for n, v in sorted(offs.items()))))
    return rows
