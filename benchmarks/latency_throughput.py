"""Paper Fig. 28: latency-throughput of MultiPaxos vs Compartmentalized
MultiPaxos vs the unreplicated state machine, batched and unbatched.

Engine: exact MVA over the calibrated demand tables (one anchor:
MultiPaxos unbatched = 25k cmd/s), cross-checked by the batched stochastic
transient engine - all 5 deployments x 8 seeds in one jitted scan call
(the numpy/heapq DES remains the slow reference oracle in
tests/test_transient.py).  Reported `derived` fields: peak throughputs +
speedups vs the paper's measured numbers, plus simulated p50/p99.
"""
import time

import numpy as np

from repro.core.analytical import (
    PAPER_COMPARTMENTALIZED_BATCHED,
    PAPER_COMPARTMENTALIZED_UNBATCHED,
    PAPER_MULTIPAXOS_BATCHED,
    PAPER_MULTIPAXOS_UNBATCHED,
    PAPER_UNREPLICATED_UNBATCHED,
    calibrate_alpha,
    compartmentalized_model,
    multipaxos_model,
    unreplicated_model,
)
from repro.core.api import Workload
from repro.core.sweep import compile_models


def run(alpha=None):
    """``alpha`` overrides the table-derived anchor (headline numbers);
    the measured anchor (``calibrate_alpha(measured=True)``, read off an
    executed vanilla run) is always computed and reported alongside."""
    alpha = alpha if alpha is not None else \
        calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    t0 = time.perf_counter()
    alpha_meas = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED, measured=True)
    anchor_us = (time.perf_counter() - t0) * 1e6
    workload = Workload(name="write_only")  # Fig. 28 is the write-only mix
    mp = multipaxos_model(f=1)
    cmp_u = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=2,
                                    grid_cols=2, n_replicas=4)
    unrep = unreplicated_model()
    mp_b = compartmentalized_model(f=1, n_proxy_leaders=2, grid_rows=3,
                                   grid_cols=1, n_replicas=3, batch_size=100)
    cmp_b = compartmentalized_model(f=1, n_proxy_leaders=3, grid_rows=2,
                                    grid_cols=2, n_replicas=2, batch_size=100,
                                    n_batchers=2, n_unbatchers=3)

    t0 = time.perf_counter()
    compiled = compile_models([mp, cmp_u, unrep, mp_b, cmp_b])
    _, xs, rs = compiled.mva(alpha, n_clients_max=512, workload=workload)
    sweep_us = (time.perf_counter() - t0) * 1e6

    peaks = xs.max(axis=1)
    t0 = time.perf_counter()
    res = compiled.transient(alpha, n_clients=128, workload=workload,
                             seeds=8, n_steps=4000)
    sim_us = (time.perf_counter() - t0) * 1e6
    sim_x = res.seed_mean_throughput()

    rows = [
        ("fig28/mva_sweep_5models_512clients", sweep_us,
         f"jax-MVA full latency-throughput surface, one jitted call"),
        ("fig28/multipaxos_unbatched_peak", 0.0,
         f"{peaks[0]:.0f} cmd/s (paper 25k; calibration anchor)"),
        ("fig28/compartmentalized_unbatched_peak", 0.0,
         f"{peaks[1]:.0f} cmd/s = {peaks[1]/peaks[0]:.2f}x "
         f"(paper 150k = 6x; structural model, msg counts only)"),
        ("fig28/unreplicated_peak", 0.0,
         f"{peaks[2]:.0f} cmd/s (paper 250k; model underpredicts - "
         f"per-msg cost on a bare server is below the protocol-node cost)"),
        ("fig28/multipaxos_batched_peak", 0.0,
         f"{peaks[3]:.0f} cmd/s (paper {PAPER_MULTIPAXOS_BATCHED:.0f})"),
        ("fig28/compartmentalized_batched_peak", 0.0,
         f"{peaks[4]:.0f} cmd/s (paper {PAPER_COMPARTMENTALIZED_BATCHED:.0f})"),
        ("fig28/transient_cross_check", sim_us,
         f"stochastic engine {sim_x[1]:.0f} vs MVA {peaks[1]:.0f} cmd/s "
         f"({100*abs(sim_x[1]-peaks[1])/peaks[1]:.1f}% apart; "
         f"5 deployments x 8 seeds, one jitted scan)"),
        ("fig28/transient_latency_cmp_unbatched", 0.0,
         f"p50 {res.latency_p50[1].mean()*1e3:.2f} ms / "
         f"p99 {res.latency_p99[1].mean()*1e3:.2f} ms at 128 clients "
         f"(MVA mean R {float(rs[1, 127])*1e3:.2f} ms)"),
        # peaks scale linearly in alpha, so the measured anchor re-prices
        # every curve without recompiling the sweep
        ("fig28/measured_anchor", anchor_us,
         f"alpha measured {alpha_meas:.0f} vs table {alpha:.0f} "
         f"({alpha_meas/alpha:.3f}x); compartmentalized unbatched peak "
         f"{peaks[1]*alpha_meas/alpha:.0f} cmd/s under the executed anchor "
         f"(table {peaks[1]:.0f})"),
    ]
    return rows
