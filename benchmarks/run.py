"""Benchmark harness - one module per paper table/figure.

  fig28  latency-throughput (MultiPaxos / Compartmentalized / unreplicated)
  fig29  compartmentalization ablation staircase (+ batched variant)
  fig30/31  read scalability + closed-form law
  fig32  weakly consistent reads
  fig33  skew tolerance vs CRAQ
  msgcount  measured per-role message counts (validates the demand tables)
  roofline  dry-run roofline readout (40 cells x 2 meshes)

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import sys
import time
import traceback

from . import (
    ablation,
    latency_throughput,
    protocol_messages,
    read_scalability,
    roofline_report,
    skew,
    weak_reads,
)

MODULES = [
    ("fig28", latency_throughput),
    ("fig29", ablation),
    ("fig30_31", read_scalability),
    ("fig32", weak_reads),
    ("fig33", skew),
    ("msgcount", protocol_messages),
    ("roofline", roofline_report),
]


def main() -> None:
    print("name,us_per_call,derived")
    failures = 0
    for label, mod in MODULES:
        t0 = time.perf_counter()
        try:
            rows = mod.run()
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{label}/ERROR,0.0,\"{e!r}\"")
            traceback.print_exc(file=sys.stderr)
            continue
        wall_us = (time.perf_counter() - t0) * 1e6
        for name, us, derived in rows:
            d = str(derived).replace('"', "'")
            print(f'{name},{us:.1f},"{d}"')
        print(f"{label}/total,{wall_us:.1f},\"module wall time\"")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
