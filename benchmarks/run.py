"""Benchmark harness - one module per paper table/figure.

  fig28  latency-throughput (MultiPaxos / Compartmentalized / unreplicated)
  fig29  compartmentalization ablation staircase (+ batched variant)
  fig30/31  read scalability + closed-form law
  fig32  weakly consistent reads
  fig33  skew tolerance vs CRAQ (incl. scripted skew ramp)
  failover  transient dynamics: leader crash, mid-run scale-up, batch fill
  msgcount  measured-vs-analytical parity per executable variant (registry loop)
  measured  batched execution plane: a config x seed grid of closed-loop
            clients measured in ONE jitted device call
  sweep  whole-surface config sweep + budget autotune (one jitted call)
  variants  protocol-variant plane: Mencius + S-Paxos vs baselines (Figs. 24-28)
  multileader  BPaxos + ISS-bucket contenders: budget staircase, dep-service
            floor, mixed tensor, measured parity + rotation feedback
  shards  the shard axis: scaling, skew, budget splits, live resharding
  geo  geo-replication plane: WAN latency surfaces, placement autotune,
            per-region measured parity, region-partition transient
  autoscale  elastic control loop: diurnal policy search (autoscaled vs
            static-peak machine-hours at equal p99), flash crowd,
            execution-plane replay with dip parity
  roofline  dry-run roofline readout (40 cells x 2 meshes)

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import argparse
import sys
import time
import traceback

from . import (
    ablation,
    autoscale,
    failover,
    geo,
    latency_throughput,
    measured_surface,
    multileader,
    protocol_messages,
    read_scalability,
    roofline_report,
    shards,
    skew,
    sweep,
    variants,
    weak_reads,
)

MODULES = [
    ("fig28", latency_throughput),
    ("fig29", ablation),
    ("fig30_31", read_scalability),
    ("fig32", weak_reads),
    ("fig33", skew),
    ("failover", failover),
    ("msgcount", protocol_messages),
    ("measured", measured_surface),
    ("sweep", sweep),
    ("variants", variants),
    ("multileader", multileader),
    ("shards", shards),
    ("geo", geo),
    ("autoscale", autoscale),
    ("roofline", roofline_report),
]

EPILOG = """\
benchmarks (label: paper target, typical runtime on one CPU core):
  fig28     Fig. 28  latency-throughput curves, 5 deployments x 512 clients
            via one batched jitted MVA call + stochastic transient
            cross-check (5 deployments x 8 seeds, one scan)     (~10 s)
  fig29     Fig. 29  ablation staircase, batched eval + the autotuner's
            greedy rediscovery of the paper's hand-tuned order  (<1 s)
  fig30_31  Figs. 30-31  read scalability over replicas + closed-form law
            (one compiled replica axis, re-weighted per mix)    (<1 s)
  fig32     Fig. 32  weakly consistent reads skip acceptors; all 6
            deployments per mix on the batched transient engine (~8 s)
  fig33     Fig. 33  skew: flat compartmentalized vs CRAQ dirty-read
            model, a scripted skew ramp p:0->1 mid-run on the batched
            transient engine, + in-process CRAQ cluster         (~15 s)
  failover  transient dynamics on the batched stochastic engine:
            leader crash -> throughput dips to zero and recovers to
            the plateau (p99 carries the stall), mid-run proxy
            scale-up migrating the bottleneck, batch fill ramp
            B:1->100, bursty-arrival p99 via Workload(arrival=
            "bursty"), and p99-under-crash autotuning            (~30 s)
  msgcount  sections 3/6/7  measured-vs-analytical msgs/cmd parity for
            every executable variant (one registry loop: executes the
            real clusters, checks linearizability, validates every
            demand table; BENCH_SMOKE=1 shrinks = make parity-smoke) (~10 s)
  measured  batched execution plane: a config x seed grid of closed-loop
            client populations runs in ONE jitted device call
            (CompiledSweep.execute) with probe-calibrated per-station
            costs; measured msgs/cmd vs the MVA table per grid row,
            validate_batched parity for every executable variant, and
            batched latency p50/p99 off the Pallas histogram kernel;
            BENCH_SMOKE=1 shrinks = make measured-smoke            (~15 s)
  sweep     section 9  "how should a system be compartmentalized":
            300-config surface in one jitted call + budget-19
            autotune for three workload mixes                   (~5 s)
  variants  sections 6-7, Figs. 24-28  "a technique, not a protocol":
            compartmentalized Mencius / S-Paxos beat their vanilla
            baselines; a mixed-variant grid (6 protocols) lowered to
            one demand tensor and solved by one batched MVA call;
            Mencius skip-storm + S-Paxos payload-ramp transients;
            cross-variant budget-19 autotune (which protocol wins?)
            BENCH_SMOKE=1 shrinks the transients                (~10 s)
  multileader  multi-leader family: which protocol wins at budget B?
            the staircase with BPaxos + ISS-bucket contenders, the
            BPaxos dep-service floor vs proposer 1/p split, a mixed
            classic+multi-leader demand tensor in one MVA call, and
            measured parity incl. the ISS rotation/forwarding feedback
            loop; BENCH_SMOKE=1 shrinks = make multileader-smoke (~10 s)
  shards    the shard axis through every plane: uniform shard-count
            scaling (min-law exactly linear, S=1..8 in one flattened
            MVA call), skewed hot shard + autotune_sharded's
            asymmetric budget split, the live-resharding transient
            (hot-shard split under load: dip then recover above the
            pre-split level), and a measured 4-shard deployment with
            per-shard parity + per-key-partition linearizability;
            BENCH_SMOKE=1 shrinks = make shard-smoke            (~10 s)
  geo       geo plane: the (config x region) WAN latency surface in one
            CompiledSweep.geo_latency call, placement autotuning (hub
            beats every pinned placement for spread clients), per-region
            measured parity under the WAN matrix, batched region lanes,
            region-partition transient, calibration stability;
            BENCH_SMOKE=1 shrinks = make geo-smoke              (~15 s)
  autoscale elastic control loop: diurnal policy search in one batched
            replay (autoscaled beats static-peak machine-hours >= 25%
            at equal-or-better worst-window p99, BENCH_autoscale.json),
            flash-crowd re-provisioning under a machine budget, the
            (config x policy) CompiledSweep.autoscale grid, and the
            run_autoscaled execution replay - linearizable across every
            resize, dips parity-checked against the transient;
            BENCH_SMOKE=1 shrinks = make autoscale-smoke        (~60 s)
  roofline  dry-run roofline readout, needs results/dryrun/     (<1 s)

run a subset:    python -m benchmarks.run --only fig28,sweep
full docs:       benchmarks/README.md
"""


def main(argv: list[str] | None = None) -> None:
    parser = argparse.ArgumentParser(
        prog="python -m benchmarks.run",
        description=__doc__.split("\n")[0],
        epilog=EPILOG,
        formatter_class=argparse.RawDescriptionHelpFormatter,
    )
    parser.add_argument(
        "--only", default=None, metavar="LABELS",
        help="comma-separated benchmark labels to run (default: all)")
    args = parser.parse_args(argv)

    selected = MODULES
    if args.only:
        wanted = {w.strip() for w in args.only.split(",")}
        unknown = wanted - {label for label, _ in MODULES}
        if unknown:
            parser.error(f"unknown benchmark label(s): {sorted(unknown)}; "
                         f"choose from {[l for l, _ in MODULES]}")
        selected = [(l, m) for l, m in MODULES if l in wanted]

    print("name,us_per_call,derived")
    failures = 0
    for label, mod in selected:
        t0 = time.perf_counter()
        try:
            rows = mod.run()
        except Exception as e:  # pragma: no cover
            failures += 1
            print(f"{label}/ERROR,0.0,\"{e!r}\"")
            traceback.print_exc(file=sys.stderr)
            continue
        wall_us = (time.perf_counter() - t0) * 1e6
        for name, us, derived in rows:
            d = str(derived).replace('"', "'")
            print(f'{name},{us:.1f},"{d}"')
        print(f"{label}/total,{wall_us:.1f},\"module wall time\"")
    if failures:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
