"""Whole-surface sweep + autotune benchmark (paper section 9: "How should a
system be compartmentalized?").

Compiles a few-hundred-config grid over every compartmentalization knob,
evaluates the full latency-throughput surface in ONE jitted MVA call, and
then asks the autotuner for the best deployment under a machine budget for
three workload mixes - reporting the bottleneck-migration trace that
justifies each answer.
"""
import time

from repro.core.analytical import PAPER_MULTIPAXOS_UNBATCHED, calibrate_alpha
from repro.core.api import Workload
from repro.core.autotune import autotune, candidate_spec
from repro.core.sweep import SweepSpec, compile_models, compile_sweep, model_for

KNOBS = dict(
    n_proxy_leaders=(1, 2, 3, 5, 7, 10),
    grids=((3, 1), (2, 2), (2, 3), (3, 2), (3, 3)),
    n_replicas=(2, 3, 4, 5, 6),
)


def run():
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    # batch_size > 1 only makes sense with a batcher stage in front (the
    # factory amortizes downstream demand by B), so the batched half of the
    # grid carries batchers/unbatchers instead of crossing B with 0 batchers
    spec_unbatched = SweepSpec(**KNOBS)
    spec_batched = SweepSpec(**KNOBS, batch_sizes=(100,), n_batchers=(2,),
                             n_unbatchers=(3,))

    t0 = time.perf_counter()
    configs = list(spec_unbatched.configs()) + list(spec_batched.configs())
    compiled = compile_models([model_for(c) for c in configs], configs)
    compile_us = (time.perf_counter() - t0) * 1e6

    # peak surface: bottleneck law, vectorized over all configs
    t1 = time.perf_counter()
    peaks_w = compiled.peak_throughput(alpha, Workload())
    law_us = (time.perf_counter() - t1) * 1e6

    # full MVA surface: one jitted call over the whole grid
    t2 = time.perf_counter()
    clients, X, _ = compiled.mva(alpha, n_clients_max=256,
                                 workload=Workload())
    mva_us = (time.perf_counter() - t2) * 1e6

    rows = [
        (f"sweep/compile_{len(compiled)}_configs", compile_us,
         "config -> demand-matrix lowering (Python, once)"),
        (f"sweep/bottleneck_law_{len(compiled)}_configs", law_us,
         f"peak surface, max {peaks_w.max():.0f} cmd/s"),
        (f"sweep/mva_one_call_{len(compiled)}x256", mva_us,
         f"X[{X.shape[0]}, {X.shape[1]}] latency-throughput surface, "
         f"single jitted call"),
    ]

    for i, (idx, peak, bn) in enumerate(
            compiled.top_k(alpha, k=3, workload=Workload.read_mix(0.9))):
        cfg = compiled.configs[idx]
        rows.append((f"sweep/top{i+1}_90pct_reads", 0.0,
                     f"{peak:.0f} cmd/s (bn={bn}) p={cfg['n_proxy_leaders']} "
                     f"grid={cfg['grid_rows']}x{cfg['grid_cols']} "
                     f"n={cfg['n_replicas']} B={cfg['batch_size']} "
                     f"batchers={cfg['n_batchers']}"))

    # one compiled candidate space serves all three workload mixes
    candidates = compile_sweep(candidate_spec(budget=19))
    for workload in (Workload(f_write=1.0, name="write_only"),
                     Workload(f_write=0.5, name="50pct_reads"),
                     Workload(f_write=0.1, name="90pct_reads")):
        label = workload.name
        t3 = time.perf_counter()
        res = autotune(budget=19, alpha=alpha, workload=workload,
                       compiled=candidates)
        us = (time.perf_counter() - t3) * 1e6
        migration = " -> ".join(t.bottleneck for t in res.trace)
        rows.append((f"sweep/autotune_budget19_{label}", us,
                     f"best {res.best_peak:.0f} cmd/s @ {res.machines} machines "
                     f"({res.n_candidates} candidates); bottleneck migration: "
                     f"{migration}"))
    return rows
