"""The shard plane: scaling, skew, budget splits and live resharding.

The paper scales a *single* replicated state machine by
compartmentalizing its roles; sharding is the orthogonal axis - N
independent compartmentalized groups behind hash routing, each owning a
key partition.  This module reports that axis end to end:

* shard-count scaling - uniform weights multiply the bottleneck-law peak
  by exactly S (the min-law ``min_s alpha/(w_s d_max)``), evaluated for
  all shard counts in ONE flattened jitted MVA call;
* skewed hot shard - a hot key concentrates traffic on one shard and the
  min-law collapses toward the unsharded peak; ``autotune_sharded``
  splits a machine budget asymmetrically to buy the lost headroom back;
* live resharding - the hot shard splits in two mid-run
  (:func:`repro.core.transient.resharding_schedule`): throughput dips
  during the migration blackout and recovers ABOVE the pre-split level
  (replayed on the real cluster by
  tests/test_sharded_execution.py::test_live_resharding_replay...);
* measured parity - a 4-shard compartmentalized deployment executes on
  the real-cluster plane; per-shard station parity and per-key-partition
  linearizability (``validate_sharded``).

``BENCH_SMOKE=1`` (set by ``make shard-smoke``) shrinks the transient
and the measured run so the module finishes in a few seconds.
"""
import os
import time

import numpy as np

from repro.core import (
    ShardingSpec,
    SweepSpec,
    Workload,
    autotune_sharded,
    calibrate_alpha,
    compile_sweep,
    resharding_schedule,
    simulate_transient,
    validate_sharded,
)

SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")
N_STEPS = 1200 if SMOKE else 4000
SEEDS = 2 if SMOKE else 6
N_CMDS = 48 if SMOKE else 96


def run():
    alpha = calibrate_alpha()
    rows = []
    sweep = compile_sweep(SweepSpec(f=1, n_proxy_leaders=(3,),
                                    grids=((2, 2),), n_replicas=(2,)))
    base_peak = float(sweep.peak_throughput(alpha)[0])

    # -- shard-count scaling (uniform workload) ----------------------------
    t0 = time.perf_counter()
    peaks = [float(sweep.peak_throughput(alpha,
                                         sharding=ShardingSpec(s))[0])
             for s in (1, 2, 4, 8)]
    scale_us = (time.perf_counter() - t0) * 1e6
    rows.append(("shards/uniform_scaling", scale_us,
                 f"S=1,2,4,8 -> {[f'{p:.0f}' for p in peaks]} cmd/s "
                 f"({peaks[2]/peaks[0]:.2f}x at 4 shards; min-law is "
                 f"exactly linear under uniform weights)"))

    # -- skewed hot shard + asymmetric budget split ------------------------
    w = Workload(f_write=1.0, skew_p=0.6)
    sh = ShardingSpec(4)
    skew_peak = float(sweep.peak_throughput(alpha, w, sharding=sh)[0])
    bn = sweep.bottlenecks(w, sharding=sh)[0]
    t0 = time.perf_counter()
    tuned = autotune_sharded(40, alpha, sh, workload=w)
    tune_us = (time.perf_counter() - t0) * 1e6
    budgets = {c.shard: c.budget for c in tuned.shards}
    rows.append(("shards/skewed_hot_shard", 0.0,
                 f"skew p=0.6 on 4 shards: peak {skew_peak:.0f} cmd/s "
                 f"(uniform {peaks[2]:.0f}, unsharded {base_peak:.0f}; "
                 f"bottleneck {bn})"))
    rows.append(("shards/autotune_budget_split", tune_us,
                 f"budget 40 -> per-shard machines {budgets} "
                 f"(hot shard s{sh.hot_shard} gets the surplus); tuned "
                 f"peak {tuned.total_peak:.0f} cmd/s over "
                 f"{tuned.n_candidates} candidate configs"))

    # -- live resharding: hot-shard split under load -----------------------
    w2 = Workload(f_write=1.0, skew_p=0.6)
    sh2 = ShardingSpec(2)
    base = sweep.demands(w2)[0:1] / alpha
    sched, bounds = resharding_schedule(base, sh2, start=0.4, stop=0.55,
                                        n_steps=N_STEPS, workload=w2)
    t0 = time.perf_counter()
    tr = simulate_transient(sched, bounds, n_clients=32, seeds=SEEDS,
                            n_steps=N_STEPS)
    sim_us = (time.perf_counter() - t0) * 1e6
    x = tr.window_throughput(bounds)[0].mean(axis=0)
    rows.append(("shards/live_resharding_transient", sim_us,
                 f"pre {x[0]:.0f} -> migration {x[1]:.0f} -> post "
                 f"{x[2]:.0f} cmd/s ({x[2]/max(x[0], 1e-9):.2f}x recovery: "
                 f"the split halves the hot shard's load; "
                 f"{SEEDS} seeds, one jitted scan)"))

    # -- measured plane: 4-shard parity + per-key linearizability ----------
    t0 = time.perf_counter()
    rep = validate_sharded("compartmentalized", ShardingSpec(4),
                           {"f": 1, "n_proxy_leaders": 3, "grid_rows": 2,
                            "grid_cols": 2, "n_replicas": 2},
                           workload=Workload(f_write=1.0), n_commands=N_CMDS,
                           seed=1)
    meas_us = (time.perf_counter() - t0) * 1e6
    worst = max((r.max_rel_err() for r in rep.reports if r is not None),
                default=0.0)
    rows.append(("shards/measured_4shard_parity", meas_us,
                 f"{'PASS' if rep.passed else 'FAIL'}: "
                 f"{rep.shards_checked} shards checked, per-shard cmds "
                 f"{list(rep.trace.ops_per_shard)}, max station rel err "
                 f"{worst:.3f}, per-key-partition linearizable="
                 f"{rep.trace.linearizable}"))
    return rows
