"""Paper Fig. 32: sequentially / eventually consistent reads.

Weakly consistent reads skip the acceptors entirely (paper section 3.6), so
read throughput scales with replicas alone - even with the *minimal* 2x2
acceptor grid - unlike linearizable reads whose preread path eventually
bottlenecks on acceptor rows.

All 6 deployments (weak vs linearizable x 2/4/6 replicas) are lowered to
one demand tensor and evaluated per read mix by the batched transient
engine in a single jitted call - which also yields latency p50/p99, not
just the bottleneck-law peak.
"""
import time

from repro.core.analytical import (
    PAPER_MULTIPAXOS_UNBATCHED,
    DeploymentModel,
    Station,
    calibrate_alpha,
    compartmentalized_model,
)
from repro.core.api import Workload
from repro.core.sweep import compile_models

REPLICAS = (2, 4, 6)


def weak_read_model(n_replicas: int, f: int = 1) -> DeploymentModel:
    base = compartmentalized_model(f=f, n_proxy_leaders=10, grid_rows=2,
                                   grid_cols=2, n_replicas=n_replicas)
    stations = []
    for s in base.stations:
        if s.name == "acceptor":
            # weak reads never touch acceptors
            stations.append(Station("acceptor", s.servers, s.demand_write, 0.0))
        elif s.name == "replica":
            # no preread wait; same execution path
            stations.append(s)
        else:
            stations.append(s)
    return DeploymentModel(name=f"weak-reads(n={n_replicas})",
                           stations=tuple(stations))


def run():
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    t0 = time.perf_counter()
    rows = []
    compiled = compile_models(
        [weak_read_model(n) for n in REPLICAS]
        + [compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=2,
                                   grid_cols=2, n_replicas=n)
           for n in REPLICAS])
    for frac_read in (0.9, 1.0):
        t1 = time.perf_counter()
        res = compiled.transient(alpha, workload=Workload.read_mix(frac_read),
                                 n_clients=64, seeds=8, n_steps=3000)
        us = (time.perf_counter() - t1) * 1e6
        x = res.seed_mean_throughput()
        p99 = res.seed_mean_p99() * 1e3
        weak, lin = x[:len(REPLICAS)], x[len(REPLICAS):]
        rows.append((f"fig32/weak_{int(frac_read*100)}pct_read", us,
                     f"n=2,4,6 -> {[f'{p:.0f}' for p in weak]} cmd/s, "
                     f"p99 {[f'{p:.2f}' for p in p99[:3]]} ms "
                     f"(2x2 grid only; 6x8 lanes, one jitted call)"))
        rows.append((f"fig32/linearizable_{int(frac_read*100)}pct_read", 0.0,
                     f"n=2,4,6 -> {[f'{p:.0f}' for p in lin]} cmd/s "
                     f"(acceptor rows cap scaling on the same grid)"))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    rows.insert(0, ("fig32/eval", us, "batched transient eval per mix"))
    return rows
