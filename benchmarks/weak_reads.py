"""Paper Fig. 32: sequentially / eventually consistent reads.

Weakly consistent reads skip the acceptors entirely (paper section 3.6), so
read throughput scales with replicas alone - even with the *minimal* 2x2
acceptor grid - unlike linearizable reads whose preread path eventually
bottlenecks on acceptor rows.
"""
import time

from repro.core.analytical import (
    PAPER_MULTIPAXOS_UNBATCHED,
    DeploymentModel,
    Station,
    calibrate_alpha,
    compartmentalized_model,
)


def weak_read_model(n_replicas: int, f: int = 1) -> DeploymentModel:
    base = compartmentalized_model(f=f, n_proxy_leaders=10, grid_rows=2,
                                   grid_cols=2, n_replicas=n_replicas)
    stations = []
    for s in base.stations:
        if s.name == "acceptor":
            # weak reads never touch acceptors
            stations.append(Station("acceptor", s.servers, s.demand_write, 0.0))
        elif s.name == "replica":
            # no preread wait; same execution path
            stations.append(s)
        else:
            stations.append(s)
    return DeploymentModel(name=f"weak-reads(n={n_replicas})",
                           stations=tuple(stations))


def run():
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    t0 = time.perf_counter()
    rows = []
    for frac_read in (0.9, 1.0):
        weak = [weak_read_model(n).peak_throughput(alpha, 1 - frac_read)
                for n in (2, 4, 6)]
        lin = [compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=2,
                                       grid_cols=2, n_replicas=n
                                       ).peak_throughput(alpha, 1 - frac_read)
               for n in (2, 4, 6)]
        rows.append((f"fig32/weak_{int(frac_read*100)}pct_read", 0.0,
                     f"n=2,4,6 -> {[f'{p:.0f}' for p in weak]} "
                     f"(2x2 grid only)"))
        rows.append((f"fig32/linearizable_{int(frac_read*100)}pct_read", 0.0,
                     f"n=2,4,6 -> {[f'{p:.0f}' for p in lin]} "
                     f"(acceptor rows cap scaling on the same grid)"))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    rows.insert(0, ("fig32/eval", us, "per-point model eval"))
    return rows
