"""Paper Figs. 30 + 31: read scalability vs number of replicas, and the
closed-form law T = n*alpha / (n*f_w + f_r).

Checks the two counterintuitive paper claims:
  (1) 1% -> 2% writes halves large-n peak throughput;
  (2) throughput is bounded by alpha/f_w regardless of replica count.
"""
import time

from repro.core.analytical import (
    PAPER_MULTIPAXOS_UNBATCHED,
    calibrate_alpha,
    read_scalability_law,
)
from repro.core.api import Workload
from repro.core.sweep import SweepSpec, compile_sweep


def run(alpha=None):
    """``alpha`` overrides the table-derived anchor; the measured anchor
    is reported alongside (peak columns re-price linearly)."""
    alpha = alpha if alpha is not None else \
        calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    alpha_meas = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED, measured=True)
    t0 = time.perf_counter()
    rows = []
    # the replica axis is compiled once; each read mix is one vectorized
    # re-weighting of the same demand tensors
    compiled = compile_sweep(SweepSpec(n_proxy_leaders=(10,), grids=((4, 4),),
                                       n_replicas=(2, 3, 4, 5, 6)))
    for frac_read in (0.0, 0.6, 0.9, 1.0):
        peaks = list(compiled.peak_throughput(alpha,
                                              Workload.read_mix(frac_read)))
        scale = peaks[-1] / peaks[0]
        rows.append((f"fig30/reads_{int(frac_read*100)}pct", 0.0,
                     f"n=2..6 -> {[f'{p:.0f}' for p in peaks]} "
                     f"(x{scale:.2f} from 2 to 6 replicas)"))
    peaks_ro = compiled.peak_throughput(alpha, Workload.read_mix(1.0))
    rows.append(("fig30/measured_anchor", 0.0,
                 f"alpha measured {alpha_meas:.0f} vs table {alpha:.0f} "
                 f"({alpha_meas/alpha:.3f}x); read-only n=6 peak "
                 f"{float(peaks_ro[-1])*alpha_meas/alpha:.0f} cmd/s under "
                 f"the executed anchor (table {float(peaks_ro[-1]):.0f})"))

    # closed-form law (Fig 31), alpha_repl = 100k as in the paper's plot
    a = 100_000.0
    t1 = read_scalability_law(100_000, 0.01, a)
    t2 = read_scalability_law(100_000, 0.02, a)
    rows.append(("fig31/law_1pct_vs_2pct_writes", 0.0,
                 f"T(1%w)={t1:.0f}, T(2%w)={t2:.0f}, ratio={t1/t2:.2f} "
                 f"(paper: ratio 2 - small write increases halve throughput)"))
    rows.append(("fig31/asymptote_50pct_writes", 0.0,
                 f"T(n=10^5, 50%w)={read_scalability_law(1e5, .5, a):.0f} "
                 f"<= alpha/f_w = {a/0.5:.0f}"))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    rows.insert(0, ("fig30/eval", us,
                    "batched eval (one compiled replica axis)"))
    return rows
