"""Elastic autoscaling: the closed loop from measured load to live resize.

Every other benchmark picks one *static* configuration and holds it;
production traffic breathes.  This module drives the autoscale
controller (``repro.core.autoscale``) through the two canonical arrival
shapes and reports the headline the subsystem exists for:

* the diurnal policy search: a policy grid (plus the frozen static-peak
  baseline) closed-loop over one day of sharpened-cosine load, every
  lane's full-horizon replay in ONE jitted device call
  (``autotune_policy`` / ``autoscale_grid``) - the winner must hold
  equal-or-better worst-window p99 than static-peak provisioning while
  saving >= 25% machine-hours;
* the flash crowd: a controller that had drained to the trough floor
  re-provisions the pipeline inside the crowd plateau, machine budget
  respected;
* the (config x policy) grid through ``CompiledSweep.autoscale`` - the
  policy-search shape, config-major lanes;
* the execution-plane replay: ``run_autoscaled`` re-enacts the emitted
  plan on a real registered-variant cluster - linearizable across every
  resize, warm-phase dips parity-checked against the transient
  prediction (the acceptance gate);
* the capacity anchor: ``measured_capacity`` (batched executor) - the
  execution-plane twin of the transient probe the controller calibrates
  utilization against.

Emits ``BENCH_autoscale.json`` (machine-hours and p99, autoscaled vs
static-peak) - the machine-readable perf anchor; the smoke run
(``BENCH_SMOKE=1``, set by ``make autoscale-smoke``) writes it under
``results/`` instead so the committed anchor stays the full run's.
"""
import json
import os
import time
from pathlib import Path

import numpy as np

from repro.core import (
    AutoscalePolicy,
    Controller,
    SweepSpec,
    Workload,
    autotune_policy,
    calibrate_alpha,
    compile_sweep,
    diurnal_load,
    flash_crowd_load,
    measured_capacity,
    resizable_stations,
    run_autoscaled,
)
from repro.core.api import STATION_ORDER
from repro.core.sweep import model_for

SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")
W_DIURNAL = 20 if SMOKE else 32
N_STEPS = 3000 if SMOKE else 4800
SEEDS = 2 if SMOKE else 3

# the deployment being autoscaled: a peak-provisioned compartmentalized
# pipeline with every independently-scalable tier populated
CFG = {"variant": "compartmentalized", "f": 1, "n_proxy_leaders": 8,
       "grid_rows": 2, "grid_cols": 2, "n_replicas": 6,
       "n_batchers": 3, "n_unbatchers": 3}
# floors keep the drained pipeline's latency floor (sum of per-server
# demands) under the static peak p99 - the "equal p99" budget
FLOORS = (("proxy", 3), ("replica", 2), ("batcher", 2), ("unbatcher", 2))


def _demand_row(cfg, w, alpha):
    m = model_for(dict(cfg), w)
    d_w, d_r, servers = m.demand_slots()
    k = len(STATION_ORDER)
    row = (w.f_write * np.asarray(d_w[:k], dtype=np.float64)
           + (1.0 - w.f_write) * np.asarray(d_r[:k], dtype=np.float64))
    return row / alpha, np.asarray(servers[:k], dtype=np.int64)


def run(alpha=None):
    alpha = alpha if alpha is not None else calibrate_alpha()
    rows = []
    w = Workload(f_write=1.0)
    base, srv = _demand_row(CFG, w, alpha)
    rz = resizable_stations("compartmentalized", CFG)
    static_machines = int(srv.sum())

    # -- headline: diurnal policy search, autoscaled vs static-peak --------
    load = diurnal_load(W_DIURNAL, low=0.15, sharpness=2.0)
    policies = (
        AutoscalePolicy(target_low=0.4, target_high=0.65,
                        cooldown_windows=0, min_counts=FLOORS),
        AutoscalePolicy(target_low=0.35, target_high=0.6,
                        cooldown_windows=0, min_counts=FLOORS),
        AutoscalePolicy(target_low=0.4, target_high=0.65,
                        cooldown_windows=0, min_counts=FLOORS,
                        queue_high=1.0),
    )
    t0 = time.perf_counter()
    tune = autotune_policy(policies, base, srv, load, p99_slack=1.0,
                           seeds=SEEDS, n_steps=N_STEPS,
                           resizable=[rz] * (len(policies) + 1))
    us = (time.perf_counter() - t0) * 1e6
    saved = 1.0 - tune.winner.machine_time / tune.static.machine_time
    assert tune.winner.policy is not None, "no policy beat static-peak"
    assert saved >= 0.25, f"only {saved:.0%} machine-hours saved"
    assert tune.winner.peak_p99 <= tune.static.peak_p99, (
        tune.winner.peak_p99, tune.static.peak_p99)
    rows.append((f"autoscale/diurnal_policy_search_{len(policies) + 1}"
                 f"x{W_DIURNAL}", us,
                 f"{tune.describe()}; {len(tune.winner.trace.actions)} "
                 f"resizes, trough floor "
                 f"{int(tune.winner.trace.machines.min())} of "
                 f"{static_machines} machines"))

    # -- flash crowd: drained floor -> crowd -> re-provisioned -------------
    crowd = flash_crowd_load(16 if not SMOKE else 12, base=0.25,
                             start=0.45, width=0.3)
    pol = AutoscalePolicy(target_low=0.4, target_high=0.65,
                          cooldown_windows=0, min_counts=FLOORS,
                          queue_high=1.0, machine_budget=static_machines)
    t0 = time.perf_counter()
    tr = Controller(pol).run(base, srv, crowd, seeds=SEEDS,
                             n_steps=N_STEPS, resizable=[rz])
    us = (time.perf_counter() - t0) * 1e6
    hit = int(np.argmax(crowd == crowd.max()))
    floor = int(tr.machines[:hit].min())
    recovered = int(tr.machines[hit:].max())
    assert recovered > floor, (floor, recovered)
    assert tr.peak_machines <= static_machines
    rows.append(("autoscale/flash_crowd", us,
                 f"controller had drained to {floor} machines at base "
                 f"load; the crowd (window {hit}) pulls it back to "
                 f"{recovered} (budget {static_machines}), "
                 f"{len(tr.actions)} resizes, machine_time "
                 f"{tr.machine_time:.2f} vs static {static_machines}"))

    # -- (config x policy) grid: CompiledSweep.autoscale -------------------
    spec = SweepSpec(n_proxy_leaders=(4, 8), n_replicas=(4,))
    grid = compile_sweep(spec)
    short = diurnal_load(8, low=0.2, sharpness=2.0)
    t0 = time.perf_counter()
    traces = grid.autoscale(alpha, [policies[0], None], short,
                            workload=w, seeds=SEEDS, n_steps=N_STEPS)
    us = (time.perf_counter() - t0) * 1e6
    best = min((t for t in traces if t.policy is not None),
               key=lambda t: t.machine_time)
    rows.append((f"autoscale/grid_{len(grid)}x2", us,
                 f"{len(grid)} configs x 2 policies = {len(traces)} lanes, "
                 f"probes shared, one batched replay; best lane "
                 f"{best.label}: machine_time {best.machine_time:.2f} "
                 f"(static {int(best.servers0.sum())})"))

    # -- execution plane: replay the plan on a real cluster ----------------
    exe_cfg = {"f": 1, "n_proxy_leaders": 4, "grid_rows": 2,
               "grid_cols": 2, "n_replicas": 3}
    ctl = Controller(AutoscalePolicy(target_low=0.45, target_high=0.75,
                                     cooldown_windows=0))
    plan = ctl.run_config(exe_cfg, diurnal_load(6, low=0.3), alpha=alpha,
                          workload=w, seeds=SEEDS, n_steps=3000)
    t0 = time.perf_counter()
    exe = run_autoscaled("compartmentalized", plan, config=exe_cfg,
                         workload=w, n_commands_per_window=30, seed=3)
    us = (time.perf_counter() - t0) * 1e6
    assert exe.passed, exe.describe()
    dips = ", ".join(f"w{r['window']} {r['measured']:.2f}/"
                     f"{r['predicted']:.2f}" for r in exe.dip_rows
                     if r["predicted"] is not None)
    rows.append(("autoscale/execution_replay", us,
                 f"{len(exe.epochs)} epochs over {len(exe.load)} windows "
                 f"on the real cluster: linearizable across every resize, "
                 f"state carried (continuity {exe.continuity_ok}); "
                 f"measured/predicted resize dips {dips} "
                 f"(tolerance {exe.tolerance:.2f})"))

    # -- the capacity anchor, measured on the execution plane --------------
    t0 = time.perf_counter()
    cap = measured_capacity("compartmentalized", workload=w,
                            n_commands=36 if SMOKE else 72, seeds=2)
    us = (time.perf_counter() - t0) * 1e6
    rows.append(("autoscale/capacity_anchor", us,
                 f"saturated capacity {cap:.0f} cmds/s off the batched "
                 f"executor - the execution-plane twin of the transient "
                 f"probe that anchors u = lambda * d"))

    # -- the machine-readable perf anchor ----------------------------------
    root = Path(__file__).resolve().parents[1]
    out = (root / "results" / "BENCH_autoscale.json" if SMOKE
           else root / "BENCH_autoscale.json")
    out.parent.mkdir(parents=True, exist_ok=True)
    payload = {
        "schedule": "diurnal",
        "windows": int(W_DIURNAL),
        "smoke": SMOKE,
        "static_machines": static_machines,
        "machine_time_autoscaled": round(tune.winner.machine_time, 4),
        "machine_time_static": round(tune.static.machine_time, 4),
        "machine_hours_saved_fraction": round(saved, 4),
        "peak_p99_autoscaled_s": float(tune.winner.peak_p99),
        "peak_p99_static_s": float(tune.static.peak_p99),
        "winner_policy": tune.winner.policy.describe(),
        "trough_floor_machines": int(tune.winner.trace.machines.min()),
        "resizes": len(tune.winner.trace.actions),
        "execution_replay": {
            "variant": "compartmentalized",
            "passed": bool(exe.passed),
            "epochs": len(exe.epochs),
            "windows": len(exe.load),
            "dip_tolerance": exe.tolerance,
        },
    }
    out.write_text(json.dumps(payload, indent=2) + "\n")
    rows.append(("autoscale/bench_json", 0.0,
                 f"wrote {out.relative_to(root)}: "
                 f"{saved:.0%} machine-hours saved at p99 "
                 f"{tune.winner.peak_p99:.2e}s vs static "
                 f"{tune.static.peak_p99:.2e}s"))
    return rows
