"""Multi-leader variant family: which protocol wins at budget B?

The paper's compartmentalized MultiPaxos still funnels every command
through ONE leader (demand 2 msgs/cmd) - the ceiling the whole paper
works around.  The multi-leader family attacks the ceiling itself:

* ``bpaxos``  - n parallel proposers + a replicated dependency service
  (PAPERS.md, arXiv 2003.00331): ordering is decoupled into per-key
  conflict tracking, so the proposer demand splits 1/p - but the
  dependency service inherits a 2 msgs/cmd floor of its own, the
  mirror image of the leader it replaced;
* ``iss``     - ISS-style round-robin log-bucket multiplexing: L leaders
  each sequence their owned buckets into one shared log through the
  unchanged compartmentalized tail, paying a forwarding tax for
  misrouted commands instead of a dependency tier.

This module renders the which-protocol-wins-at-budget-B staircase with
both multi-leader contenders in the pool, the dep-service-floor /
proposer-scaling story on the analytical plane, a mixed-variant demand
tensor (classic + multi-leader variants in ONE batched MVA call), and
measured-vs-analytical parity plus the ISS rotation/forwarding feedback
loop on the real clusters.

``BENCH_SMOKE=1`` (set by ``make multileader-smoke``) shrinks the budget
staircase and the executed command counts.
"""
import os
import time

from repro.core import (
    READ_HEAVY,
    SweepSpec,
    Workload,
    autotune_variants,
    bpaxos_model,
    calibrate_alpha,
    compile_models,
    compile_sweep,
    validate_variant,
)

SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")
BUDGETS = (19, 30) if SMOKE else (10, 15, 19, 25, 30, 40)
N_CMDS = 30 if SMOKE else 60

CONTENDERS = ("compartmentalized", "mencius", "spaxos", "bpaxos", "iss")


def run(alpha=None):
    alpha = alpha if alpha is not None else calibrate_alpha()
    rows = []

    # -- the staircase: winner per machine budget, multi-leader included ---
    t0 = time.perf_counter()
    results = {b: autotune_variants(budget=b, alpha=alpha,
                                    workload=Workload(),
                                    variants=CONTENDERS)
               for b in BUDGETS}
    us = (time.perf_counter() - t0) * 1e6
    stair = "; ".join(
        f"B={b}: {r.winner.variant} {r.winner.peak:.0f}"
        for b, r in results.items())
    rows.append(("multileader/budget_staircase_write_only", us,
                 f"{len(CONTENDERS)} contenders -> {stair} (cmd/s)"))

    # -- detail at the headline budget (acceptance: budget >= 30) ----------
    bmax = max(BUDGETS)
    r = results[bmax]
    per = "; ".join(f"{v}: {c.peak:.0f} @ {c.machines}m (bn={c.bottleneck})"
                    for v, c in sorted(r.per_variant.items()))
    rows.append((f"multileader/budget{bmax}_per_variant", 0.0,
                 f"winner {r.winner.variant} {r.winner.peak:.0f} cmd/s "
                 f"({r.n_candidates} candidates); {per}"))

    # -- read-heavy flip: leaderless reads beat multi-leader ordering ------
    t1 = time.perf_counter()
    rh = autotune_variants(budget=bmax, alpha=alpha, workload=READ_HEAVY,
                           variants=CONTENDERS)
    us = (time.perf_counter() - t1) * 1e6
    ml = {v: c.peak for v, c in rh.per_variant.items() if v in ("bpaxos",
                                                                "iss")}
    rows.append((f"multileader/budget{bmax}_read_heavy", us,
                 f"winner {rh.winner.variant} {rh.winner.peak:.0f} cmd/s - "
                 f"every multi-leader op travels the ordered path, so "
                 f"{'; '.join(f'{v} {p:.0f}' for v, p in sorted(ml.items()))} "
                 f"lose to leaderless reads at 90% reads"))

    # -- the dep-service floor vs the proposer split (analytical) ----------
    p_axis = (1, 2, 3, 4, 6)
    ms = [bpaxos_model(n_proposers=p, n_dep_nodes=3, n_replicas=3)
          for p in p_axis]
    peaks = compile_models(ms).peak_throughput(alpha)
    bns = compile_models(ms).bottlenecks()
    rows.append(("multileader/bpaxos_proposer_scaling", 0.0,
                 f"p={list(p_axis)} -> {[f'{x:.0f}' for x in peaks]} cmd/s "
                 f"(bn {bns[0]} -> {bns[-1]}): the proposer demand splits "
                 f"1/p, then the dependency service's 2 msgs/cmd floor "
                 f"caps at alpha/2 = {alpha / 2:.0f} - the mirror image "
                 f"of the single leader it replaced"))

    # -- mixed demand tensor: classic + multi-leader in ONE MVA call -------
    spec = SweepSpec(
        variants=("compartmentalized", "mencius", "bpaxos", "iss"),
        n_proxy_leaders=(3, 10),
        n_replicas=(3, 4),
        n_leaders=(2, 3),
        knob_values=(("n_proposers", (2, 4)), ("n_buckets", (8,)),
                     ("epoch_length", (64,))),
    )
    t2 = time.perf_counter()
    grid = compile_sweep(spec)
    _, X, _ = grid.mva(alpha, n_clients_max=128, workload=Workload())
    us = (time.perf_counter() - t2) * 1e6
    gp = grid.peak_throughput(alpha, Workload())
    best = {}
    for i, cfg in enumerate(grid.configs):
        v = cfg.get("variant", "compartmentalized")
        if v not in best or gp[i] > gp[best[v]]:
            best[v] = i
    rows.append((f"multileader/mixed_grid_{len(grid)}_configs", us,
                 f"one demand tensor, one MVA call; best peak per variant "
                 f"(cmd/s): "
                 + ", ".join(f"{v}={gp[i]:.0f}" for v, i in sorted(
                     best.items()))))

    # -- measured parity on the real clusters ------------------------------
    for name in ("bpaxos", "iss"):
        t3 = time.perf_counter()
        rep = validate_variant(name, workload=Workload(f_write=0.5),
                               n_commands=N_CMDS)
        us = (time.perf_counter() - t3) * 1e6
        assert rep.passed, str(rep)
        exact = sum(1 for row in rep.rows if row.exact)
        rows.append((f"multileader/parity_{name}", us,
                     f"{len(rep.rows)} stations, {exact} exact, max rel "
                     f"err {max(r.rel_err for r in rep.rows):.4f}, "
                     f"linearizable ({rep.trace.checker})"))

    # -- ISS rotation/forwarding feedback loop -----------------------------
    t4 = time.perf_counter()
    cfg = dict(n_leaders=3, n_buckets=2, epoch_length=2,
               n_proxy_leaders=3, grid_rows=2, grid_cols=2, n_replicas=2)
    rep = validate_variant("iss", config=cfg, workload=Workload(),
                           n_commands=N_CMDS)
    us = (time.perf_counter() - t4) * 1e6
    assert rep.passed, str(rep)
    rows.append(("multileader/iss_rotation_feedback", us,
                 f"rotation-heavy run: measured forward_fraction="
                 f"{rep.model_config['forward_fraction']:.3f}, "
                 f"rotations_per_cmd="
                 f"{rep.model_config['rotations_per_cmd']:.3f} fed back "
                 f"into the leader demand (user config untouched)"))
    return rows
