"""Roofline readout from the dry-run artifacts (results/dryrun/*.json).

Summarises the three terms per cell and names the three hillclimb targets.
(The full per-cell table is written to EXPERIMENTS.md by
``python -m repro.roofline.report``.)
"""
from pathlib import Path

from repro.roofline.analysis import load_cells, pick_hillclimb_cells

RESULTS = Path(__file__).resolve().parents[1] / "results" / "dryrun"


def run():
    if not RESULTS.exists():
        return [("roofline/missing", 0.0,
                 "run `python -m repro.launch.dryrun --all --mesh both` first")]
    cells = load_cells(str(RESULTS))
    ok = [c for c in cells if c.status == "ok"]
    if not ok:
        return [("roofline/empty", 0.0, "no successful dry-run cells yet")]
    rows = [("roofline/cells_ok", 0.0,
             f"{len(ok)} ok / {sum(c.status=='skipped' for c in cells)} "
             f"skipped / {sum(c.status=='error' for c in cells)} errors")]
    by_dom = {}
    for c in ok:
        by_dom.setdefault(c.dominant, []).append(c)
    for dom, cs in sorted(by_dom.items()):
        rows.append((f"roofline/dominant_{dom}", 0.0,
                     f"{len(cs)} cells; worst MFU_est "
                     f"{min(x.mfu_est for x in cs):.3f}"))
    singles = [c for c in ok if c.mesh == "single"]
    if singles:
        picks = pick_hillclimb_cells(cells)
        for k, c in picks.items():
            rows.append((f"roofline/hillclimb_{k}", 0.0,
                         f"{c.arch} x {c.shape} ({c.dominant}-bound, "
                         f"MFU_est {c.mfu_est:.3f})"))
    return rows
