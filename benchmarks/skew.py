"""Paper Fig. 33: skew tolerance - Compartmentalized MultiPaxos (flat) vs
CRAQ (degrades with skew).

Four-level validation:
  (1) analytical: the CRAQ dirty-read model's throughput curve over skew p;
  (2) workload-first: the same contrast as ONE compiled craq +
      compartmentalized sweep evaluated at ``Workload(skew_p=p)`` points -
      the CRAQ rows are reshaped through the variant's registered
      ``workload_adapter`` (dirty reads forward to the tail), the
      key-agnostic rows are untouched;
  (3) transient: ONE batched scan-engine call simulating both systems
      through a skew ramp p: 0 -> 1 scripted mid-run (the CRAQ chain's
      per-window demand vector comes from ``craq_station_demands``; the
      compartmentalized row is key-agnostic, so its windows are constant)
      - CRAQ's throughput trace sags as the ramp tightens, the
      compartmentalized trace stays flat;
  (4) protocol-level: the real in-process CRAQ cluster's tail-forward
      fraction under a skewed workload, which is the mechanism driving
      all of the above.
"""
import time

import numpy as np

from repro.core.analytical import (
    PAPER_MULTIPAXOS_UNBATCHED,
    calibrate_alpha,
    compartmentalized_model,
    craq_model,
    craq_station_demands,
)
from repro.core.api import Workload
from repro.core.craq import CraqDeployment
from repro.core.simulator import demand_vector
from repro.core.sweep import SweepSpec, compile_sweep
from repro.core.transient import schedule_from_demands, simulate_transient

SKEWS = (0.0, 0.25, 0.5, 0.75, 1.0)


def skew_ramp_schedule(alpha: float, n_nodes: int, f_write: float,
                       n_steps: int):
    """[W, 2, K] schedule: row 0 = CRAQ chain at each skew window (demand
    vector at the quasi-static fixed point), row 1 = compartmentalized
    (constant: key-agnostic).  K pads to max(chain length, station count)."""
    cmp_m = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=4,
                                    grid_cols=4, n_replicas=6)
    d_cmp = demand_vector(cmp_m, f_write) / alpha
    k = max(n_nodes, len(d_cmp))
    windows = []
    for p in SKEWS:
        t_fp = craq_model(n_nodes=n_nodes, skew_p=p, f_write=f_write,
                          alpha=alpha)
        d_craq = np.asarray(craq_station_demands(n_nodes, p, f_write, alpha,
                                                 t_fp)) / alpha
        w = np.zeros((2, k))
        w[0, :n_nodes] = d_craq
        w[1, :len(d_cmp)] = d_cmp
        windows.append(w)
    starts = [i / len(SKEWS) for i in range(len(SKEWS))]
    return schedule_from_demands(windows, starts, n_steps)


def run():
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    t0 = time.perf_counter()
    rows = []
    cmp_m = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=4,
                                    grid_cols=4, n_replicas=6)
    cmp_peak = cmp_m.peak_throughput(alpha, f_write=0.05)
    curve = [craq_model(n_nodes=6, skew_p=p, f_write=0.05, alpha=alpha)
             for p in SKEWS]
    rows.append(("fig33/compartmentalized_flat", 0.0,
                 f"{cmp_peak:.0f} cmd/s at every skew (key-agnostic)"))
    rows.append(("fig33/craq_curve", 0.0,
                 f"p=0..1 -> {[f'{c:.0f}' for c in curve]} "
                 f"({curve[0]/curve[-1]:.1f}x degradation; paper ~3x)"))

    # workload-first: one compiled mixed sweep, skew passed once per point
    mixed = compile_sweep(SweepSpec(
        variants=("compartmentalized", "craq"),
        n_proxy_leaders=(10,), grids=((4, 4),), n_replicas=(6,),
        chain_nodes=(6,)))
    t1 = time.perf_counter()
    peaks = [mixed.peak_throughput(
        alpha, Workload(f_write=0.05, skew_p=p, dirty_fraction=0.8))
        for p in SKEWS]
    wl_us = (time.perf_counter() - t1) * 1e6
    rows.append(("fig33/workload_skew_points", wl_us,
                 f"Workload(skew_p=p) over one compiled sweep: craq "
                 f"{[f'{x[1]:.0f}' for x in peaks]} cmd/s sags via its "
                 f"workload_adapter; compartmentalized flat at "
                 f"{peaks[0][0]:.0f} (spread "
                 f"{max(x[0] for x in peaks)/min(x[0] for x in peaks):.2f}x)"))

    # batched transient: both systems through one scripted skew ramp.
    # The near-balanced CRAQ chain relaxes slowly (all stations within
    # ~20% of the bottleneck), so windows are long and the settle fraction
    # deep to read each skew level near its own steady state.
    n_steps = 15000
    sched, bounds = skew_ramp_schedule(alpha, n_nodes=6, f_write=0.05,
                                       n_steps=n_steps)
    t1 = time.perf_counter()
    res = simulate_transient(sched, bounds, n_clients=64, seeds=8,
                             n_steps=n_steps, warmup_frac=0.04)
    ramp_us = (time.perf_counter() - t1) * 1e6
    craq_x, cmp_x = res.window_throughput(bounds, settle=0.5).mean(axis=1)
    rows.append(("fig33/transient_skew_ramp_craq", ramp_us,
                 f"p ramps 0->1 mid-run: {[f'{x:.0f}' for x in craq_x]} "
                 f"cmd/s ({craq_x[0]/max(craq_x[-1], 1):.1f}x sag, "
                 f"8 seeds, one jitted call)"))
    rows.append(("fig33/transient_skew_ramp_compartmentalized", 0.0,
                 f"same run: {[f'{x:.0f}' for x in cmp_x]} cmd/s (flat; "
                 f"spread {cmp_x.max()/cmp_x.min():.2f}x)"))

    # mechanism check on the real protocol cluster
    t1 = time.perf_counter()
    frac = {}
    for label, hot_writes in (("uniform", 0), ("hot", 30)):
        dep = CraqDeployment(n_nodes=3, n_clients=2, seed=1)
        ops0 = ([("put", "hot", i) for i in range(hot_writes)]
                or [("put", f"k{i}", i) for i in range(30)])
        dep.clients[0].run_ops(ops0)
        dep.clients[1].run_ops([("get", "hot")] * 40)
        dep.net.run(max_steps=500_000)
        total_reads = sum(n.reads_served for n in dep.nodes)
        fwd = sum(n.tail_forwards for n in dep.nodes)
        frac[label] = fwd / max(total_reads, 1)
    cluster_us = (time.perf_counter() - t1) * 1e6
    rows.append(("fig33/craq_cluster_tail_forward_fraction", cluster_us,
                 f"uniform={frac['uniform']:.2f} vs hot-key={frac['hot']:.2f} "
                 f"of reads forwarded to the tail (the degradation mechanism)"))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    rows.insert(0, ("fig33/eval", us,
                    "model + transient ramp + protocol-cluster evals"))
    return rows
