"""Paper Fig. 33: skew tolerance - Compartmentalized MultiPaxos (flat) vs
CRAQ (degrades with skew).

Two-level validation:
  (1) analytical: the CRAQ dirty-read model's throughput curve over skew p;
  (2) protocol-level: the real in-process CRAQ cluster's tail-forward
      fraction under a skewed workload, which is the mechanism driving (1).
"""
import time

from repro.core.analytical import (
    PAPER_MULTIPAXOS_UNBATCHED,
    calibrate_alpha,
    compartmentalized_model,
    craq_model,
)
from repro.core.craq import CraqDeployment


def run():
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    t0 = time.perf_counter()
    rows = []
    cmp_m = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=4,
                                    grid_cols=4, n_replicas=6)
    cmp_peak = cmp_m.peak_throughput(alpha, f_write=0.05)
    curve = [craq_model(n_nodes=6, skew_p=p, f_write=0.05, alpha=alpha)
             for p in (0.0, 0.25, 0.5, 0.75, 1.0)]
    rows.append(("fig33/compartmentalized_flat", 0.0,
                 f"{cmp_peak:.0f} cmd/s at every skew (key-agnostic)"))
    rows.append(("fig33/craq_curve", 0.0,
                 f"p=0..1 -> {[f'{c:.0f}' for c in curve]} "
                 f"({curve[0]/curve[-1]:.1f}x degradation; paper ~3x)"))

    # mechanism check on the real protocol cluster
    t1 = time.perf_counter()
    frac = {}
    for label, hot_writes in (("uniform", 0), ("hot", 30)):
        dep = CraqDeployment(n_nodes=3, n_clients=2, seed=1)
        ops0 = ([("put", "hot", i) for i in range(hot_writes)]
                or [("put", f"k{i}", i) for i in range(30)])
        dep.clients[0].run_ops(ops0)
        dep.clients[1].run_ops([("get", "hot")] * 40)
        dep.net.run(max_steps=500_000)
        total_reads = sum(n.reads_served for n in dep.nodes)
        fwd = sum(n.tail_forwards for n in dep.nodes)
        frac[label] = fwd / max(total_reads, 1)
    cluster_us = (time.perf_counter() - t1) * 1e6
    rows.append(("fig33/craq_cluster_tail_forward_fraction", cluster_us,
                 f"uniform={frac['uniform']:.2f} vs hot-key={frac['hot']:.2f} "
                 f"of reads forwarded to the tail (the degradation mechanism)"))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    rows.insert(0, ("fig33/eval", us, "model + protocol-cluster evals"))
    return rows
