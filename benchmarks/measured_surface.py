"""Measured surfaces from the batched execution plane - "measured" at the
cost of "modelled".

The scalar measured plane (``msgcount``) validates one config at a time by
running a Python event loop.  This module shows the tentpole claim of the
batched plane: a whole (config x seed) grid of *closed-loop client
populations* executes in ONE jitted device call
(:meth:`repro.core.sweep.CompiledSweep.execute`), emitting measured
per-station msgs/cmd and latency p50/p99 histograms - the same call shape
as ``.mva`` and ``.transient``, so "three calls, one registry" covers
modelled steady state, modelled dynamics, and measurement.

Rows:
  * one grid row per config: measured msgs/cmd at the bottleneck station
    vs the MVA demand table's prediction (the worked measured-vs-MVA
    comparison cited in docs/PERFORMANCE_MODEL.md);
  * cross-plane agreement: ``validate_batched`` for every executable
    variant at the 50/50 mix - fails the run on any station outside its
    registered tolerance;
  * the latency surface: batched p50/p99 next to the MVA residence time
    at the same client count.

``BENCH_SMOKE=1`` (set by ``make measured-smoke``) shrinks the grid and
command counts.
"""
import os
import time

import numpy as np

from repro.core import (
    MIXED_50_50,
    Workload,
    calibrate_alpha,
    executable_variants,
    validate_batched,
)
from repro.core.sweep import SweepSpec, compile_sweep

SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")


def run():
    rows = []
    failures = []
    w = MIXED_50_50
    n_commands = 24 if SMOKE else 48
    seeds = 2 if SMOKE else 4
    alpha = calibrate_alpha()

    # -- the grid: >= 8 configs x seeds of closed-loop clients, ONE call --
    sw = compile_sweep(SweepSpec(
        variants=("compartmentalized", "multipaxos"),
        n_proxy_leaders=(2, 3) if SMOKE else (2, 3, 4, 5),
        n_replicas=(2,) if SMOKE else (2, 3)))
    t0 = time.perf_counter()
    res = sw.execute(workload=w, n_commands=n_commands, seeds=seeds)
    wall_us = (time.perf_counter() - t0) * 1e6
    rows.append((
        "measured/grid_one_call", wall_us,
        f"{len(res)} configs x {len(res.seeds)} seeds x "
        f"{res.n_clients} clients x {n_commands} cmds in one device call "
        f"({res.n_steps} steps); all lanes drained: "
        f"{bool(np.all(res.completed == n_commands))}"))

    demands = sw.demands(w)  # [M, K] the MVA plane's table
    for m in range(len(res)):
        station_row = res.station_row(m)
        bot = max(station_row, key=station_row.get)
        measured = station_row[bot]
        predicted = float(demands[m].max())
        rows.append((
            f"measured/grid_{m}_{res.variant(m)}", 0.0,
            f"bottleneck {bot}: measured {measured:.3f} vs MVA table "
            f"{predicted:.3f} msgs/cmd "
            f"(p50 {res.latency_p50[m].mean() * 1e6:.1f}us, "
            f"p99 {res.latency_p99[m].mean() * 1e6:.1f}us, "
            f"measured peak ~ {alpha / max(measured, 1e-12):.0f} cmd/s)"))

    # -- cross-plane parity: every executable, batched vs its table -------
    for name in executable_variants():
        t0 = time.perf_counter()
        rep = validate_batched(name, workload=w, n_commands=n_commands,
                               seeds=seeds)
        wall_us = (time.perf_counter() - t0) * 1e6
        verdict = "PASS" if rep.passed else "FAIL"
        rows.append((
            f"measured/{name}_parity", wall_us,
            f"{verdict} max rel err {rep.max_rel_err():.3f} over "
            f"{len(rep.rows)} stations"))
        if not rep.passed:
            failures.append(str(rep))

    if failures:
        raise AssertionError(
            "batched measured-vs-analytical parity failed:\n"
            + "\n".join(failures))
    return rows
