"""Protocol-variant performance plane (paper sections 6-7, Figs. 24-28).

The paper's closing argument is that compartmentalization is "a technique,
not a protocol", demonstrated by compartmentalizing Mencius (Figs. 24-26)
and S-Paxos (Fig. 27) and comparing everything on one axis (Fig. 28).
This module reproduces that argument on the batched performance plane:

* fig25/fig27 - compartmentalized Mencius / S-Paxos vs their vanilla
  baselines (each must win);
* fig26 - compartmentalized Mencius throughput vs the number of leaders
  (sequencing splits 1/m, then the bottleneck migrates off the leaders);
* fig28 - a mixed-variant grid (MultiPaxos, compartmentalized, Mencius,
  S-Paxos, CRAQ, unreplicated) lowered to ONE demand tensor and evaluated
  by ONE batched jitted MVA call - no per-variant Python loops;
* transient scripts - a Mencius slow-leader skip storm and an S-Paxos
  payload-size ramp on the stochastic scan engine;
* autotune - which protocol wins at a fixed machine budget?

``BENCH_SMOKE=1`` (set by ``make bench-smoke``) shrinks the transient
step counts/seeds so the module finishes in a few seconds.
"""
import os
import time

from repro.core import (
    SweepSpec,
    Workload,
    autotune_variants,
    calibrate_alpha,
    compile_models,
    compile_sweep,
    mencius_model,
    mencius_skip_storm_schedule,
    registered_variants,
    simulate_transient,
    spaxos_model,
    spaxos_payload_ramp_schedule,
    vanilla_mencius_model,
    vanilla_spaxos_model,
)

SMOKE = os.environ.get("BENCH_SMOKE", "") not in ("", "0")
N_STEPS = 1200 if SMOKE else 4000
SEEDS = 2 if SMOKE else 6


def run(alpha=None):
    """``alpha`` overrides the table-derived anchor; the measured anchor
    (executed vanilla run) is reported alongside the headline rows."""
    alpha = alpha if alpha is not None else calibrate_alpha()
    alpha_meas = calibrate_alpha(measured=True)
    rows = []

    # -- Figs. 25 / 27: compartmentalized vs vanilla, per variant ----------
    pairs = (
        ("fig25_mencius", vanilla_mencius_model(f=1),
         mencius_model(n_leaders=3, n_proxy_leaders=10, grid_rows=2,
                       grid_cols=2, n_replicas=4)),
        ("fig27_spaxos", vanilla_spaxos_model(f=1),
         spaxos_model(n_disseminators=4, n_stabilizers=5, n_proxy_leaders=4,
                      grid_rows=2, grid_cols=2, n_replicas=3)),
    )
    compiled = compile_models([m for _, v, c in pairs for m in (v, c)])
    peaks = compiled.peak_throughput(alpha)
    bns = compiled.bottlenecks()
    for i, (label, vanilla, comp) in enumerate(pairs):
        pv, pc = peaks[2 * i], peaks[2 * i + 1]
        rows.append((f"variants/{label}_vs_vanilla", 0.0,
                     f"vanilla {pv:.0f} (bn={bns[2*i]}) -> compartmentalized "
                     f"{pc:.0f} cmd/s (bn={bns[2*i+1]}), {pc/pv:.1f}x"))
    rows.append(("variants/measured_anchor", 0.0,
                 f"alpha measured {alpha_meas:.0f} vs table {alpha:.0f} "
                 f"({alpha_meas/alpha:.3f}x); speedup ratios are "
                 f"anchor-invariant, absolute peaks re-price by "
                 f"{alpha_meas/alpha:.3f}"))

    # -- Fig. 26: Mencius scaling with leaders -----------------------------
    m_axis = (1, 2, 3, 4, 5)
    ms = [mencius_model(n_leaders=m, n_proxy_leaders=10, grid_rows=2,
                        grid_cols=2, n_replicas=4) for m in m_axis]
    mp = compile_models(ms).peak_throughput(alpha)
    rows.append(("variants/fig26_mencius_leader_scaling", 0.0,
                 f"m={list(m_axis)} -> {[f'{x:.0f}' for x in mp]} cmd/s "
                 f"(sequencing splits 1/m, then replicas bottleneck)"))

    # -- Fig. 28 as a mixed-variant surface: ONE compile, ONE jitted MVA ---
    spec = SweepSpec(
        variants=("multipaxos", "compartmentalized", "mencius", "spaxos",
                  "craq", "unreplicated"),
        n_proxy_leaders=(3, 5, 10),
        grids=((3, 1), (2, 2)),
        n_replicas=(2, 4, 6),
        n_leaders=(2, 3),
        n_disseminators=(2, 4),
        n_stabilizers=(3,),
        chain_nodes=(3, 5),
    )
    t0 = time.perf_counter()
    grid = compile_sweep(spec)
    compile_us = (time.perf_counter() - t0) * 1e6
    t1 = time.perf_counter()
    _, X, _ = grid.mva(alpha, n_clients_max=128, workload=Workload())
    mva_us = (time.perf_counter() - t1) * 1e6
    gp = grid.peak_throughput(alpha, Workload())
    by_variant = {}
    for i, cfg in enumerate(grid.configs):
        v = cfg.get("variant", "compartmentalized")
        if v not in by_variant or gp[i] > gp[by_variant[v]]:
            by_variant[v] = i
    best = ", ".join(f"{v}={gp[i]:.0f}" for v, i in sorted(by_variant.items()))
    rows.append((f"variants/fig28_mixed_grid_{len(grid)}_configs", compile_us,
                 f"{len(spec.variants)} of the {len(registered_variants())} "
                 f"registered variants in one demand tensor "
                 f"({spec.size()} configs, size() arithmetic)"))
    rows.append((f"variants/fig28_mva_one_call_{X.shape[0]}x{X.shape[1]}",
                 mva_us, f"best peak per variant (cmd/s): {best}"))

    # -- Mencius slow-leader skip storm (transient) ------------------------
    # storm windows need many saturated round trips per client before the
    # per-window mean reflects the storm's own bottleneck, hence the longer
    # run and the smaller closed-loop population
    storm_steps = 4000 if SMOKE else 12000
    kw = dict(n_proxy_leaders=10, grid_rows=2, grid_cols=2, n_replicas=4)
    t2 = time.perf_counter()
    sched, bounds = mencius_skip_storm_schedule(
        alpha, n_leaders=3, skip_fraction=0.5, slow_factor=3.0,
        n_steps=storm_steps, **kw)
    res = simulate_transient(sched, bounds, n_clients=32, seeds=SEEDS,
                             n_steps=storm_steps)
    us = (time.perf_counter() - t2) * 1e6
    # [healthy, storm, healed] per-window means, transition drain excluded
    wt = res.window_throughput(bounds, settle=0.4).mean(axis=1)[0]
    rows.append(("variants/mencius_skip_storm", us,
                 f"healthy {wt[0]:.0f} -> storm {wt[1]:.0f} -> healed "
                 f"{wt[2]:.0f} cmd/s ({wt[1]/wt[0]:.2f}x during the noop "
                 f"flood, lagging leader 3x slower)"))

    # -- S-Paxos payload-size ramp (transient) -----------------------------
    factors = (1.0, 2.0, 4.0, 8.0)
    t3 = time.perf_counter()
    sched, bounds = spaxos_payload_ramp_schedule(
        alpha, payload_factors=factors, n_steps=N_STEPS,
        n_disseminators=4, n_stabilizers=5, n_proxy_leaders=4,
        grid_rows=2, grid_cols=2, n_replicas=3)
    res = simulate_transient(sched, bounds, n_clients=64, seeds=SEEDS,
                             n_steps=N_STEPS)
    us = (time.perf_counter() - t3) * 1e6
    wt = res.window_throughput(bounds).mean(axis=1)[0]
    leader_d = [spaxos_model(payload_factor=p).demands()["leader"]
                for p in factors]
    rows.append(("variants/spaxos_payload_ramp", us,
                 f"P={list(factors)} -> {[f'{x:.0f}' for x in wt]} cmd/s; "
                 f"leader demand flat at {leader_d[0]:g} msgs/cmd for every "
                 f"payload (ids only - the protocol's point)"))

    # -- which protocol wins at budget B? ----------------------------------
    t4 = time.perf_counter()
    res_v = autotune_variants(budget=19, alpha=alpha, workload=Workload())
    us = (time.perf_counter() - t4) * 1e6
    per = "; ".join(f"{v}: {c.peak:.0f} @ {c.machines}m (bn={c.bottleneck})"
                    for v, c in sorted(res_v.per_variant.items()))
    rows.append(("variants/autotune_budget19_write_only", us,
                 f"winner {res_v.winner.variant} {res_v.winner.peak:.0f} "
                 f"cmd/s ({res_v.n_candidates} candidates); {per}"))
    return rows
