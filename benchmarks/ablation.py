"""Paper Fig. 29a: the compartmentalization ablation staircase.

Apply the six compartmentalizations in bottleneck order; at every step
report predicted peak throughput and which component is the bottleneck.
The *sequence of bottlenecks* (leader -> proxies -> leader) is the
reproducible claim; predicted values are from the one-anchor model.

The staircase is evaluated on the batched sweep path (all steps lowered to
one demand matrix, peaks/bottlenecks vectorized), and the autotuner's
greedy bottleneck-following trace is reported alongside it - the machine
rediscovering the paper's hand-tuned order.
"""
import time

from repro.core.analytical import (
    PAPER_MULTIPAXOS_UNBATCHED,
    ablation_steps,
    calibrate_alpha,
    compartmentalized_model,
)
from repro.core.api import Workload
from repro.core.autotune import bottleneck_trace
from repro.core.sweep import compile_models


def run():
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    t0 = time.perf_counter()
    steps = ablation_steps()

    # whole staircase in one compiled batch
    compiled = compile_models([m for _, m in steps])
    peaks = compiled.peak_throughput(alpha)
    bns = compiled.bottlenecks()
    batch_us = (time.perf_counter() - t0) * 1e6

    rows = [("fig29/ablation_batch_eval", batch_us,
             f"{len(compiled)} staircase configs, one demand matrix")]
    prev = None
    for (name, _), peak, bn in zip(steps, peaks, bns):
        delta = "" if prev is None else f" (+{100*(peak/prev-1):.0f}%)"
        rows.append((f"fig29/{name.replace(' ', '_')[:40]}", 0.0,
                     f"{peak:.0f} cmd/s, bottleneck={bn}{delta}"))
        prev = peak

    # autotuner greedy trace: does the machine walk the same staircase?
    t1 = time.perf_counter()
    trace = bottleneck_trace(budget=19, alpha=alpha, workload=Workload())
    trace_us = (time.perf_counter() - t1) * 1e6
    path = " -> ".join(f"{t.bottleneck}" for t in trace)
    rows.append(("fig29/autotune_trace", trace_us,
                 f"greedy rediscovery: {path}; "
                 f"final {trace[-1].peak:.0f} cmd/s @ {trace[-1].machines} machines"))

    # batched staircase (Fig 29b): batchers/unbatchers + batch size sweep
    for B in (10, 50, 100):
        m = compartmentalized_model(f=1, n_proxy_leaders=3, grid_rows=2,
                                    grid_cols=2, n_replicas=2, batch_size=B,
                                    n_batchers=2, n_unbatchers=3)
        rows.append((f"fig29b/batch_size_{B}", 0.0,
                     f"{m.peak_throughput(alpha):.0f} cmd/s, "
                     f"bottleneck={m.bottleneck()[0]}"))
    return rows
