"""Paper Fig. 29a: the compartmentalization ablation staircase.

Apply the six compartmentalizations in bottleneck order; at every step
report predicted peak throughput and which component is the bottleneck.
The *sequence of bottlenecks* (leader -> proxies -> leader) is the
reproducible claim; predicted values are from the one-anchor model.
"""
import time

from repro.core.analytical import (
    PAPER_MULTIPAXOS_UNBATCHED,
    ablation_steps,
    calibrate_alpha,
    compartmentalized_model,
)


def run():
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    t0 = time.perf_counter()
    rows = []
    prev = None
    for name, model in ablation_steps():
        peak = model.peak_throughput(alpha)
        bn, _ = model.bottleneck()
        delta = "" if prev is None else f" (+{100*(peak/prev-1):.0f}%)"
        rows.append((f"fig29/{name.replace(' ', '_')[:40]}", 0.0,
                     f"{peak:.0f} cmd/s, bottleneck={bn}{delta}"))
        prev = peak

    # batched staircase (Fig 29b): batchers/unbatchers + batch size sweep
    for B in (10, 50, 100):
        m = compartmentalized_model(f=1, n_proxy_leaders=3, grid_rows=2,
                                    grid_cols=2, n_replicas=2, batch_size=B,
                                    n_batchers=2, n_unbatchers=3)
        rows.append((f"fig29b/batch_size_{B}", 0.0,
                     f"{m.peak_throughput(alpha):.0f} cmd/s, "
                     f"bottleneck={m.bottleneck()[0]}"))
    us = (time.perf_counter() - t0) * 1e6 / max(len(rows), 1)
    rows.insert(0, ("fig29/ablation_eval", us, "per-configuration model eval"))
    return rows
