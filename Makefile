# Repo checks.  `make test` is the tier-1 gate; the others are fast
# confidence checks for docs and benchmarks.  `make ci` chains everything
# with JAX pinned to CPU (so libtpu metadata probing can't hang a runner).
PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-smoke parity-smoke measured-smoke shard-smoke multileader-smoke geo-smoke autoscale-smoke examples-smoke docs-links check ci clean

test:
	$(PYTHON) -m pytest -x -q

# measured-vs-analytical msgs/cmd parity for every variant that declares
# an execution plane (validate_variant over executable_variants(), shrunk
# command counts): runs the real clusters, checks linearizability, and
# fails on any station outside its declared tolerance
parity-smoke:
	BENCH_SMOKE=1 $(PYTHON) -m benchmarks.run --only msgcount

# the batched execution plane, shrunk: a (config x seed) grid of
# closed-loop client populations measured in ONE jitted device call
# (CompiledSweep.execute), plus validate_batched parity for every
# executable variant - fails on any station outside its tolerance
measured-smoke:
	BENCH_SMOKE=1 $(PYTHON) -m benchmarks.run --only measured

# the shard axis, shrunk: uniform shard-count scaling on the flattened
# MVA path, the skewed hot shard + autotune_sharded budget split, the
# live-resharding transient (dip then recover above pre-split), and a
# measured 4-shard deployment with per-shard parity + per-key-partition
# linearizability
shard-smoke:
	BENCH_SMOKE=1 $(PYTHON) -m benchmarks.run --only shards

# the multi-leader family, shrunk: the which-protocol-wins-at-budget-B
# staircase with BPaxos + ISS-bucket contenders, the BPaxos dep-service
# floor, a mixed classic+multi-leader demand tensor in one MVA call, and
# measured parity (incl. the ISS rotation/forwarding feedback loop)
multileader-smoke:
	BENCH_SMOKE=1 $(PYTHON) -m benchmarks.run --only multileader

# the geo-replication plane, shrunk: the (config x region) latency
# surface in one jitted geo_latency call, placement autotuning (hub
# beats single-region for spread clients), per-region measured parity
# under a WAN matrix, batched per-region lanes, the region-partition
# transient, and the geo-stable measured calibration anchor
geo-smoke:
	BENCH_SMOKE=1 $(PYTHON) -m benchmarks.run --only geo

# the elastic control loop, shrunk: the diurnal policy search (autoscaled
# must beat static-peak machine-hours >= 25% at equal-or-better worst-
# window p99), flash-crowd re-provisioning under a machine budget, the
# (config x policy) CompiledSweep.autoscale grid, and the run_autoscaled
# execution replay (linearizable across every resize, warm-phase dips
# parity-checked against the transient prediction)
autoscale-smoke:
	BENCH_SMOKE=1 $(PYTHON) -m benchmarks.run --only autoscale

# cheap figures + the sweep, transient and variant engines: exercises the
# batched MVA kernel, the stochastic scan engine (failover benchmark), the
# protocol-variant plane (BENCH_SMOKE=1 shrinks its transients), the
# autotuner and the CSV harness end to end in about a minute
bench-smoke:
	BENCH_SMOKE=1 $(PYTHON) -m benchmarks.run --only fig29,fig30_31,failover,sweep,variants

# every runnable walkthrough, end to end (BENCH_SMOKE=1 shrinks the
# heavier ones): quickstart, the ablation story, the workload-first
# autotuner, replicated serving, elastic training
examples-smoke:
	@set -e; for ex in examples/*.py; do \
		echo "== $$ex"; \
		BENCH_SMOKE=1 $(PYTHON) $$ex; \
	done

# every src/repro/... (and benchmarks/, examples/, tests/) path mentioned
# in README.md / docs/*.md / benchmarks/README.md must exist, and every
# variant name the docs cite must be registered in repro.core.api
docs-links:
	$(PYTHON) scripts/check_docs_links.py

check: docs-links test parity-smoke measured-smoke shard-smoke multileader-smoke geo-smoke autoscale-smoke bench-smoke examples-smoke

ci:
	JAX_PLATFORMS=cpu $(MAKE) docs-links
	JAX_PLATFORMS=cpu $(MAKE) test
	JAX_PLATFORMS=cpu $(MAKE) parity-smoke
	JAX_PLATFORMS=cpu $(MAKE) measured-smoke
	JAX_PLATFORMS=cpu $(MAKE) shard-smoke
	JAX_PLATFORMS=cpu $(MAKE) multileader-smoke
	JAX_PLATFORMS=cpu $(MAKE) geo-smoke
	JAX_PLATFORMS=cpu $(MAKE) autoscale-smoke
	JAX_PLATFORMS=cpu $(MAKE) bench-smoke
	JAX_PLATFORMS=cpu $(MAKE) examples-smoke

# stray bytecode trees under src/repro/** (configs, kernels, models, optim,
# runtime, ...) can shadow edited modules after refactors - scrub them all
clean:
	find src benchmarks tests examples scripts -type d -name __pycache__ -prune -exec rm -rf {} + 2>/dev/null || true
	rm -rf .pytest_cache
