# Repo checks.  `make test` is the tier-1 gate; the others are fast
# confidence checks for docs and benchmarks.
PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-smoke docs-links check

test:
	$(PYTHON) -m pytest -x -q

# one cheap figure + the sweep engine: exercises the batched MVA kernel,
# the autotuner and the CSV harness end to end in well under a minute
bench-smoke:
	$(PYTHON) -m benchmarks.run --only fig29,fig30_31,sweep

# every src/repro/... (and benchmarks/, examples/, tests/) path mentioned
# in README.md / docs/*.md / benchmarks/README.md must exist
docs-links:
	$(PYTHON) scripts/check_docs_links.py

check: docs-links test bench-smoke
