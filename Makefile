# Repo checks.  `make test` is the tier-1 gate; the others are fast
# confidence checks for docs and benchmarks.  `make ci` chains everything
# with JAX pinned to CPU (so libtpu metadata probing can't hang a runner).
PYTHON ?= python
export PYTHONPATH := src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-smoke docs-links check ci

test:
	$(PYTHON) -m pytest -x -q

# cheap figures + the sweep and transient engines: exercises the batched
# MVA kernel, the stochastic scan engine (failover benchmark), the
# autotuner and the CSV harness end to end in about a minute
bench-smoke:
	$(PYTHON) -m benchmarks.run --only fig29,fig30_31,failover,sweep

# every src/repro/... (and benchmarks/, examples/, tests/) path mentioned
# in README.md / docs/*.md / benchmarks/README.md must exist
docs-links:
	$(PYTHON) scripts/check_docs_links.py

check: docs-links test bench-smoke

ci:
	JAX_PLATFORMS=cpu $(MAKE) docs-links
	JAX_PLATFORMS=cpu $(MAKE) test
	JAX_PLATFORMS=cpu $(MAKE) bench-smoke
