"""Quorum-system unit + property tests (paper section 3.2)."""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings, strategies as st

from repro.core.quorums import (
    GridQuorums,
    MajorityQuorums,
    pick_read_quorum,
    pick_write_quorum,
)


def test_majority_quorums_intersect():
    for f in (1, 2, 3):
        MajorityQuorums(f=f).validate()


def test_grid_shapes():
    g = GridQuorums(rows=2, cols=3)
    assert g.n == 6
    assert [sorted(q) for q in g.read_quorums()] == [[0, 1, 2], [3, 4, 5]]
    assert [sorted(q) for q in g.write_quorums()] == [[0, 3], [1, 4], [2, 5]]
    g.validate()


@given(rows=st.integers(2, 5), cols=st.integers(2, 5))
@settings(max_examples=30, deadline=None)
def test_grid_quorums_always_intersect(rows, cols):
    GridQuorums(rows=rows, cols=cols).validate()


def test_grid_write_load_scales_with_columns():
    """Paper: with w write quorums every acceptor processes 1/w of writes."""
    for w in (2, 3, 4):
        g = GridQuorums(rows=2, cols=w)
        assert g.write_load() == pytest.approx(1.0 / w)


def test_grid_read_load_scales_with_rows():
    for r in (2, 3, 4):
        g = GridQuorums(rows=r, cols=2)
        assert g.read_load() == pytest.approx(1.0 / r)


def test_majority_write_load_at_least_half():
    """Paper section 2.4: with majorities every acceptor sees >= half."""
    for f in (1, 2, 3):
        m = MajorityQuorums(f=f)
        assert m.write_load() >= 0.5


def test_thrifty_selection_avoids_dead():
    g = GridQuorums(rows=2, cols=3)
    dead = frozenset({0})  # kills column 0 and row 0
    for seed in range(10):
        _, wq = pick_write_quorum(g, seed, dead)
        assert not (wq & dead)
        _, rq = pick_read_quorum(g, seed, dead)
        assert not (rq & dead)


def test_no_live_quorum_raises():
    g = GridQuorums(rows=2, cols=2)
    with pytest.raises(RuntimeError):
        pick_write_quorum(g, 0, dead=frozenset({0, 1}))  # one per column


def test_is_write_quorum_superset():
    g = GridQuorums(rows=2, cols=2)
    assert g.is_write_quorum({0, 2})
    assert g.is_write_quorum({0, 1, 2})
    assert not g.is_write_quorum({0, 1})  # a row is not a write quorum
    assert g.is_read_quorum({0, 1})
