"""Runtime substrate tests: optimizer, compression, data pipeline, grid
checkpoints, coordinator (RSM control plane), end-to-end trainer."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.checkpoint.store import GridCheckpointStore
from repro.data.pipeline import DataConfig, Prefetcher, SyntheticLM, pack_documents
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state, lr_schedule
from repro.optim.compression import (
    compress_tree,
    compression_ratio,
    decompress_tree,
    quantize_int8,
)
from repro.runtime.coordinator import TrainingCoordinator


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_reduces_quadratic_loss():
    cfg = AdamWConfig(lr=0.1, warmup_steps=1, total_steps=100,
                      weight_decay=0.0, clip_norm=10.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    opt = init_opt_state(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(50):
        g = jax.grad(loss)(params)
        params, opt, m = adamw_update(cfg, g, opt, params)
    assert float(loss(params)) < 0.05
    assert int(opt["step"]) == 50


def test_lr_schedule_warmup_and_decay():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(lr_schedule(cfg, jnp.asarray(5))) == pytest.approx(0.5)
    assert float(lr_schedule(cfg, jnp.asarray(10))) == pytest.approx(1.0, abs=0.01)
    assert float(lr_schedule(cfg, jnp.asarray(100))) == pytest.approx(0.1, abs=0.01)


def test_grad_clipping_bounds_update():
    cfg = AdamWConfig(clip_norm=1.0, warmup_steps=0, total_steps=10)
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params)
    huge = {"w": jnp.asarray([1e6, -1e6, 1e6])}
    _, _, metrics = adamw_update(cfg, huge, opt, params)
    assert float(metrics["grad_norm"]) > 1e5  # pre-clip norm reported


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------


def test_int8_roundtrip_error_bounded():
    x = jax.random.normal(jax.random.key(0), (256,))
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize := q.astype(jnp.float32) * s - x)
    assert float(err.max()) <= float(s) * 0.5 + 1e-6


def test_error_feedback_accumulates_residual():
    grads = {"w": jax.random.normal(jax.random.key(1), (64,))}
    qtree, res = compress_tree(grads)
    deq = decompress_tree(qtree)
    np.testing.assert_allclose(np.asarray(deq["w"] + res["w"]),
                               np.asarray(grads["w"]), rtol=1e-5, atol=1e-6)


def test_error_feedback_unbiased_over_steps():
    """With a constant gradient, mean of dequantized updates -> true grad."""
    g = {"w": jnp.asarray([0.001, 0.5, -0.3, 1e-5])}
    res = None
    acc = jnp.zeros(4)
    n = 200
    for _ in range(n):
        qtree, res = compress_tree(g, res)
        acc = acc + decompress_tree(qtree)["w"]
    # EF converges at O(quant_step / n) = (0.5/127)/200 ~= 2e-5
    np.testing.assert_allclose(np.asarray(acc / n), np.asarray(g["w"]),
                               rtol=0.02, atol=3e-5)


def test_compression_ratio_about_one_quarter_fp32():
    grads = {"a": jnp.zeros((1024,), jnp.float32)}
    assert compression_ratio(grads) == pytest.approx(0.251, abs=0.01)


# ---------------------------------------------------------------------------
# data pipeline
# ---------------------------------------------------------------------------


def test_data_deterministic_and_rank_consistent():
    cfg = DataConfig(vocab_size=128, seq_len=16, global_batch=8, seed=3)
    src = SyntheticLM(cfg)
    g = src.global_batch(step=7)
    # shards must tile the global batch exactly
    parts = [src.shard_batch(7, r, 4)["tokens"] for r in range(4)]
    np.testing.assert_array_equal(np.concatenate(parts), g["tokens"])
    # re-sharding to a different world size reproduces the same global batch
    parts2 = [src.shard_batch(7, r, 2)["tokens"] for r in range(2)]
    np.testing.assert_array_equal(np.concatenate(parts2), g["tokens"])


def test_labels_are_shifted_tokens():
    cfg = DataConfig(vocab_size=64, seq_len=8, global_batch=2, seed=0)
    src = SyntheticLM(cfg)
    b = src.global_batch(0)
    np.testing.assert_array_equal(b["tokens"][:, 1:], b["labels"][:, :-1])


def test_markov_stream_is_learnable():
    """The transition kernel is low-entropy: bigram statistics must beat
    uniform (i.e. the synthetic data has learnable structure)."""
    cfg = DataConfig(vocab_size=64, seq_len=512, global_batch=1, seed=1)
    src = SyntheticLM(cfg)
    toks = src.global_batch(0)["tokens"][0]
    # most common next-token given previous should be >> 1/64
    from collections import Counter, defaultdict
    nxt = defaultdict(Counter)
    for a, b in zip(toks[:-1], toks[1:]):
        nxt[int(a)][int(b)] += 1
    top_frac = np.mean([c.most_common(1)[0][1] / sum(c.values())
                        for c in nxt.values() if sum(c.values()) >= 5])
    assert top_frac > 3.0 / 64


def test_pack_documents():
    docs = [np.arange(1, 4), np.arange(1, 6), np.arange(1, 3), np.arange(1, 8)]
    toks, mask, segs = pack_documents(docs, seq_len=8)
    assert toks.shape[1] == 8
    assert mask.max() == 1.0
    # no token loss: total unpadded tokens preserved
    assert int(mask.sum()) == sum(len(d) for d in docs)
    # segment ids distinguish documents within a row
    first_row_segs = set(segs[0][mask[0] > 0])
    assert len(first_row_segs) >= 1


def test_prefetcher_yields_increasing_steps():
    cfg = DataConfig(vocab_size=32, seq_len=8, global_batch=4, seed=0)
    src = SyntheticLM(cfg)
    pf = Prefetcher(src, rank=0, num_ranks=2, depth=2)
    try:
        b0 = pf.next()
        b1 = pf.next()
        assert b1["step"] == b0["step"] + 1
        assert b0["tokens"].shape == (2, 8)
    finally:
        pf.close()


# ---------------------------------------------------------------------------
# grid checkpoint store
# ---------------------------------------------------------------------------


def make_tree():
    return {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.bfloat16),
                   "c": jnp.asarray(7, jnp.int32)},
    }


def test_checkpoint_roundtrip(tmp_path):
    store = GridCheckpointStore(tmp_path, rows=2, cols=2)
    tree = make_tree()
    store.save(3, tree)
    out = store.restore(3, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(out)):
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


def test_checkpoint_survives_node_failures(tmp_path):
    store = GridCheckpointStore(tmp_path, rows=2, cols=3)
    tree = make_tree()
    store.save(1, tree)
    # kill one node in every column of row 0 except col 1, plus (1,1):
    store.fail_node(0, 0)
    store.fail_node(0, 2)
    store.fail_node(1, 1)
    out = store.restore(1, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))


def test_checkpoint_detects_corruption_and_falls_back(tmp_path):
    store = GridCheckpointStore(tmp_path, rows=2, cols=2)
    tree = make_tree()
    store.save(2, tree)
    # corrupt every step-2 payload on row 0
    for f in (store._node_dir(0, 0).glob("step2_*")):
        f.write_bytes(b"garbage")
    for f in (store._node_dir(0, 1).glob("step2_*")):
        f.write_bytes(b"garbage")
    out = store.restore(2, tree)  # row 1 replicas still intact
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))


def test_checkpoint_write_load_spread(tmp_path):
    """Each storage column absorbs ~1/w of bytes (the acceptor-grid law)."""
    store = GridCheckpointStore(tmp_path, rows=2, cols=2)
    tree = {f"leaf{i}": jnp.ones((64,), jnp.float32) for i in range(8)}
    store.save(0, tree)
    frac = store.write_load_fractions()
    for v in frac.values():
        assert v == pytest.approx(0.25, abs=0.05)


def test_async_checkpoint(tmp_path):
    store = GridCheckpointStore(tmp_path, rows=2, cols=2)
    tree = make_tree()
    store.save_async(5, tree)
    store.wait()
    assert store.latest_step() == 5
    out = store.restore(5, tree)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))


# ---------------------------------------------------------------------------
# coordinator (RSM control plane)
# ---------------------------------------------------------------------------


def test_coordinator_commits_steps():
    coord = TrainingCoordinator(n_workers=3)
    for s in range(3):
        for w in range(3):
            coord.report_step(w, s)
    assert coord.view.committed_step == 2
    assert len(coord.view.workers) == 3


def test_coordinator_straggler_noop_fill():
    coord = TrainingCoordinator(n_workers=3, skip_after=1)
    # workers 0,1 report steps 0..3; worker 2 is silent
    for s in range(4):
        for w in (0, 1):
            coord.report_step(w, s)
    assert coord.view.committed_step == -1  # stalled on the straggler
    skipped = coord.mitigate_stragglers(
        3, {"worker/0": 3, "worker/1": 3, "worker/2": -1})
    assert skipped == ["worker/2"]
    assert coord.view.committed_step == 3  # log unblocked by noops


def test_coordinator_membership_and_generation():
    coord = TrainingCoordinator(n_workers=2)
    g0 = coord.view.generation
    coord.join("worker/9")
    assert coord.view.generation == g0 + 1
    coord.leave("worker/9")
    assert coord.view.generation == g0 + 2
    assert "worker/9" not in coord.view.workers


def test_coordinator_survives_leader_failover():
    coord = TrainingCoordinator(n_workers=2)
    for w in range(2):
        coord.report_step(w, 0)
    coord.fail_over()
    for w in range(2):
        coord.report_step(w, 1)
    assert coord.view.committed_step == 1
    coord.commit_checkpoint(1)
    assert coord.view.committed_ckpt == 1
