"""Autoscale-plane tests: the AutoscalePolicy contract, the arrival
shapes, the reconfiguration-spike schedule, the closed-loop controller's
convergence/monotonicity properties, the (config x policy) grid through
CompiledSweep.autoscale, policy autotuning, and the min_counts floors
threading through the variant autotuner."""
import numpy as np
import pytest

from repro.core import (
    AutoscalePolicy,
    Controller,
    SweepSpec,
    Workload,
    autoscale_grid,
    autotune_policy,
    autotune_variants,
    calibrate_alpha,
    compile_sweep,
    diurnal_load,
    flash_crowd_load,
    reconfiguration_schedule,
    variant_candidate_configs,
)
from repro.core.api import STATION_INDEX
from repro.core.sweep import model_for

ALPHA = calibrate_alpha()
W1 = Workload(f_write=1.0)

# a small synthetic 3-station lane: per-server demand seconds at the
# initial provisioning (proxy is the bottleneck tier)
BASE = np.array([30e-6, 12e-6, 20e-6])
SRV = np.array([3, 2, 3])
NAMES = ("proxy", "acceptor", "replica")
FAST = dict(seeds=2, probe_steps=400, n_steps=1200, station_names=NAMES)


# ---------------------------------------------------------------------------
# AutoscalePolicy: the declarative contract
# ---------------------------------------------------------------------------


def test_policy_validates_and_normalizes():
    p = AutoscalePolicy(min_counts=(("proxy", 2),),
                        max_counts=(("proxy", 5), ("replica", 4)))
    assert p.min_for("proxy") == 2
    assert p.min_for("replica") == 1          # unpinned floor defaults to 1
    assert p.max_for("proxy") == 5
    assert p.max_for("acceptor") is None      # unpinned ceiling is unbounded
    assert "band [0.45, 0.75]" in p.describe()
    with pytest.raises(ValueError):
        AutoscalePolicy(target_low=0.8, target_high=0.6)  # inverted band
    with pytest.raises(ValueError):
        AutoscalePolicy(target_high=1.5)                  # band beyond 1
    with pytest.raises(ValueError):
        AutoscalePolicy(queue_high=-1.0)
    with pytest.raises(ValueError):
        AutoscalePolicy(cooldown_windows=-1)
    with pytest.raises(ValueError):
        AutoscalePolicy(min_counts=(("proxy", 0),))       # floor below 1
    with pytest.raises(ValueError):
        AutoscalePolicy(max_counts=(("proxy", 2), ("proxy", 3)))  # dup
    with pytest.raises(ValueError):
        AutoscalePolicy(min_counts=(("proxy", 5),),
                        max_counts=(("proxy", 3),))       # floor > ceiling
    with pytest.raises(ValueError):
        AutoscalePolicy(machine_budget=0)
    with pytest.raises(ValueError):
        AutoscalePolicy(spike_factor=0.9)
    with pytest.raises(TypeError):
        Controller("not a policy")


# ---------------------------------------------------------------------------
# Arrival shapes
# ---------------------------------------------------------------------------


def test_diurnal_load_shape_and_sharpness():
    load = diurnal_load(12, low=0.25, high=1.0)
    assert load.shape == (12,)
    assert np.isclose(load.min(), 0.25, atol=0.02)
    assert np.isclose(load.max(), 1.0, atol=0.02)
    assert load.argmax() in (5, 6)            # peak mid-run
    # sharpness > 1 narrows the peak and widens the trough dwell, so the
    # integral drops while the extremes stay put - the shape that makes
    # elasticity pay
    sharp = diurnal_load(12, low=0.25, sharpness=2.0)
    assert sharp.sum() < load.sum()
    assert np.isclose(sharp.max(), load.max(), atol=0.02)
    with pytest.raises(ValueError):
        diurnal_load(1)
    with pytest.raises(ValueError):
        diurnal_load(8, low=0.0)
    with pytest.raises(ValueError):
        diurnal_load(8, low=0.9, high=0.5)
    with pytest.raises(ValueError):
        diurnal_load(8, sharpness=0.0)


def test_flash_crowd_load_plateau():
    load = flash_crowd_load(16, base=0.3, peak=1.0, start=0.5, width=0.25)
    assert load.shape == (16,)
    assert np.isclose(load.min(), 0.3)
    plateau = np.nonzero(load == 1.0)[0]
    assert len(plateau) == 4                  # width * n_windows
    assert np.array_equal(plateau, np.arange(8, 12))
    with pytest.raises(ValueError):
        flash_crowd_load(1)
    with pytest.raises(ValueError):
        flash_crowd_load(8, base=0.8, peak=0.5)


# ---------------------------------------------------------------------------
# The reconfiguration spike schedule
# ---------------------------------------------------------------------------


def test_reconfiguration_schedule_spikes_one_station_or_whole_row():
    rows = [np.array([2e-5, 1e-5]), np.array([4e-5, 1e-5])]
    starts = [0.0, 0.5]
    # a per-station spike multiplies only that column during the first
    # spike_fraction of the action window
    dem, bounds = reconfiguration_schedule(
        rows, starts, 1000, actions=[(1, "leader")],
        spike_factor=2.0, spike_fraction=0.25)
    assert dem.shape == (3, 1, 2)
    assert np.array_equal(bounds, [0, 500, 625])
    col = STATION_INDEX["leader"]
    assert dem[1, 0, col] == pytest.approx(2.0 * rows[1][col])
    assert dem[1, 0, 1 - col] == pytest.approx(rows[1][1 - col])
    assert np.allclose(dem[2, 0], rows[1])    # spike over, plain window
    # station=None spikes the WHOLE row - migration traffic traverses
    # every station, which is what the execution plane's warm phase does
    dem2, bounds2 = reconfiguration_schedule(
        rows, starts, 1000, actions=[(1, None)],
        spike_factor=2.0, spike_fraction=0.25)
    assert np.array_equal(bounds2, bounds)
    assert np.allclose(dem2[1, 0], 2.0 * rows[1])
    assert np.allclose(dem2[2, 0], rows[1])
    # extra_cuts force shared boundaries even without demand changes
    _, bounds3 = reconfiguration_schedule(rows, starts, 1000,
                                          extra_cuts=[0.25])
    assert np.array_equal(bounds3, [0, 250, 500])
    with pytest.raises(ValueError):
        reconfiguration_schedule(rows, starts, 1000, actions=[(1, "tail")])
    with pytest.raises(ValueError):
        reconfiguration_schedule(rows, starts, 1000, spike_factor=0.5)


# ---------------------------------------------------------------------------
# The closed loop: one elastic lane next to the frozen static baseline
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def two_lane():
    pol = AutoscalePolicy(target_low=0.4, target_high=0.7,
                          cooldown_windows=0, min_counts=(("proxy", 2),))
    return autoscale_grid(
        np.stack([BASE, BASE]), np.stack([SRV, SRV]), [pol, None],
        diurnal_load(6, low=0.3, sharpness=2.0), **FAST)


def test_elastic_lane_breathes_with_the_diurnal_cycle(two_lane):
    el, st = two_lane
    assert el.counts.shape == (6, 3)
    assert len(el.actions) > 0
    # drains into the trough, adds back toward the peak, cheaper overall
    assert el.machines.min() < el.machines.max()
    assert el.machine_time < st.machine_time
    assert np.array_equal(el.machines, el.counts.sum(axis=1))
    assert el.machine_time == pytest.approx(el.machines.mean())
    # the proxy floor from min_counts is never violated
    assert el.counts[:, 0].min() >= 2
    # actions land on windows 1..W-1 (a decision in the last window
    # could only take effect beyond the horizon)
    assert all(1 <= a.window <= 5 for a in el.actions)
    assert "drain" in el.describe() or "add" in el.describe()


def test_static_lane_is_frozen(two_lane):
    _, st = two_lane
    assert st.policy is None
    assert st.actions == ()
    assert (st.counts == st.counts[0]).all()
    assert st.machine_time == pytest.approx(float(SRV.sum()))


def test_replay_grid_and_predicted_dips(two_lane):
    el, st = two_lane
    # one shared refined window grid across lanes, strictly increasing
    assert np.array_equal(el.step_bounds, st.step_bounds)
    assert np.all(np.diff(el.step_bounds) > 0)
    assert el.replay_window.min() == 0 and el.replay_window.max() == 5
    # every action window carries a spike segment whose predicted dip is
    # a genuine slowdown ratio; windows without actions predict None
    action_windows = {a.window for a in el.actions}
    for w in range(6):
        dip = el.predicted_dip(w)
        if w in action_windows:
            assert dip is not None and 0.0 < dip < 1.0
        else:
            assert dip is None
    assert not st.replay_spike.any()
    assert el.replay_spike.any()
    assert el.replay_rates().shape == el.step_bounds.shape


def test_plan_is_plain_data(two_lane):
    el, _ = two_lane
    plan = el.plan()
    assert len(plan) == len(el.actions)
    for row, act in zip(plan, el.actions):
        assert set(row) == {"window", "station", "delta"}
        assert row["station"] in NAMES
        assert row["delta"] in (-1, 1)
        assert row["window"] == act.window


def test_grid_input_validation():
    with pytest.raises(ValueError):
        autoscale_grid(BASE[None, :], SRV[None, :], [None, None],
                       diurnal_load(4))                    # lane mismatch
    with pytest.raises(ValueError):
        autoscale_grid(BASE[None, :], np.array([[3, 2]]), [None],
                       diurnal_load(4))                    # shape mismatch
    with pytest.raises(ValueError):
        Controller(AutoscalePolicy()).run(BASE, SRV, np.array([1.0]))
    with pytest.raises(ValueError):
        Controller(AutoscalePolicy()).run(BASE, SRV,
                                          np.array([0.5, -0.1, 0.5]))
    with pytest.raises(ValueError):
        Controller(AutoscalePolicy()).run(BASE, SRV, diurnal_load(4),
                                          peak_utilization=1.5)
    with pytest.raises(ValueError):
        Controller(AutoscalePolicy()).run(BASE, SRV, diurnal_load(4),
                                          station_names=("a", "b"))


def test_constant_load_converges_to_zero_actions():
    """The hysteresis guard: under constant offered load the controller
    settles - after the initial ramp no window triggers another resize
    (a drain is only taken when its inverse add cannot re-trigger)."""
    pol = AutoscalePolicy(target_low=0.4, target_high=0.75,
                          cooldown_windows=0)
    tr = Controller(pol).run(BASE, SRV, np.full(8, 0.55), **FAST)
    assert all(a.window <= 2 for a in tr.actions)
    # and the settled provisioning holds to the horizon
    assert (tr.counts[3:] == tr.counts[3]).all()


def test_machine_budget_caps_total_provisioning():
    pol = AutoscalePolicy(target_low=0.4, target_high=0.6,
                          cooldown_windows=0, queue_high=1.0,
                          machine_budget=int(SRV.sum()))
    tr = Controller(pol).run(
        BASE, SRV, flash_crowd_load(8, base=0.3, start=0.4, width=0.4),
        **FAST)
    assert tr.peak_machines <= int(SRV.sum())


def test_resizable_restricts_actions_to_named_stations():
    pol = AutoscalePolicy(target_low=0.4, target_high=0.7,
                          cooldown_windows=0)
    tr = Controller(pol).run(BASE, SRV, diurnal_load(6, low=0.3,
                                                     sharpness=2.0),
                             resizable=[("proxy",)], **FAST)
    assert tr.actions and all(a.station == "proxy" for a in tr.actions)
    # non-resizable columns never move
    assert (tr.counts[:, 1] == SRV[1]).all()
    assert (tr.counts[:, 2] == SRV[2]).all()


# ---------------------------------------------------------------------------
# Policy search + the band monotonicity property
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def band_sweep():
    pols = (
        AutoscalePolicy(target_low=0.3, target_high=0.55,
                        cooldown_windows=0),
        AutoscalePolicy(target_low=0.4, target_high=0.65,
                        cooldown_windows=0),
        AutoscalePolicy(target_low=0.5, target_high=0.8,
                        cooldown_windows=0),
    )
    return autotune_policy(pols, BASE, SRV,
                           diurnal_load(6, low=0.3, sharpness=2.0),
                           p99_slack=10.0, **FAST)


def test_machine_time_monotone_in_utilization_band(band_sweep):
    """A hotter utilization target tolerates more load per server, so it
    can never need MORE machines: machine-time is non-increasing as the
    band rises."""
    mts = [c.machine_time for c in band_sweep.choices[:-1]]
    assert all(a >= b for a, b in zip(mts, mts[1:]))


def test_autotune_policy_picks_cheapest_within_slack(band_sweep):
    tune = band_sweep
    assert len(tune.choices) == 4             # 3 policies + static
    assert tune.static.policy is None
    assert tune.static is tune.choices[-1]
    assert tune.winner in tune.choices
    # generous slack: the cheapest lane wins and beats static
    assert tune.winner.machine_time == min(c.machine_time
                                           for c in tune.choices)
    assert tune.winner.machine_time < tune.static.machine_time
    assert "saved" in tune.describe()


def test_autotune_policy_falls_back_to_static_under_tight_slack():
    pol = AutoscalePolicy(target_low=0.5, target_high=0.8,
                          cooldown_windows=0)
    tune = autotune_policy((pol,), BASE, SRV,
                           diurnal_load(4, low=0.3, sharpness=2.0),
                           p99_slack=1e-6, **FAST)
    assert tune.winner.policy is None
    assert tune.winner is tune.static
    with pytest.raises(ValueError):
        autotune_policy((), BASE, SRV, diurnal_load(4))
    with pytest.raises(ValueError):
        autotune_policy((pol,), BASE, SRV, diurnal_load(4), p99_slack=0.0)


# ---------------------------------------------------------------------------
# The (config x policy) grid through the compiled sweep
# ---------------------------------------------------------------------------


def test_compiled_sweep_autoscale_is_config_major():
    pol = AutoscalePolicy(target_low=0.4, target_high=0.7,
                          cooldown_windows=0)
    grid = compile_sweep(SweepSpec(n_proxy_leaders=(3, 4), n_replicas=(3,)))
    traces = grid.autoscale(ALPHA, [pol, None], diurnal_load(4, low=0.35),
                            workload=W1, seeds=2, probe_steps=400,
                            n_steps=1200)
    assert len(traces) == 2 * len(grid)
    assert [t.label for t in traces] == [
        "compartmentalized/p0", "compartmentalized/p1",
        "compartmentalized/p0", "compartmentalized/p1"]
    for m in range(len(grid)):
        assert traces[2 * m].policy is pol
        assert traces[2 * m + 1].policy is None
        assert traces[2 * m + 1].actions == ()
        # lanes carry each config's own provisioning
        srv = grid.models[m].demand_slots()[2]
        assert int(traces[2 * m].servers0.sum()) == int(sum(srv))
    # the two configs differ in proxies, so the lanes genuinely differ
    assert not np.array_equal(traces[0].servers0, traces[2].servers0)


# ---------------------------------------------------------------------------
# min_counts floors thread through the variant autotuner (regression pin)
# ---------------------------------------------------------------------------


def test_min_counts_floor_filters_candidate_configs():
    pol = AutoscalePolicy(min_counts=(("proxy", 6),))
    free = variant_candidate_configs(14, variants=("compartmentalized",))
    floored = variant_candidate_configs(14, variants=("compartmentalized",),
                                        policy=pol)
    assert 0 < len(floored) < len(free)
    col = STATION_INDEX["proxy"]
    for cfg in floored:
        srv = model_for(cfg).demand_slots()[2]
        # stations the config actually provisions must sit on the floor
        assert srv[col] == 0 or srv[col] >= 6


def test_autotune_variants_respects_policy_floors():
    pol = AutoscalePolicy(min_counts=(("proxy", 6),))
    res = autotune_variants(14, ALPHA, W1, variants=("compartmentalized",),
                            policy=pol)
    col = STATION_INDEX["proxy"]
    assert res.winner.model.demand_slots()[2][col] >= 6
    assert res.winner.machines <= 14
