"""Property-based linearizability testing.

hypothesis generates random workloads, fault schedules and network seeds;
every complete history recorded by the compartmentalized protocol must be
linearizable (checked exhaustively on small histories).  Also sanity-checks
the checker itself against known-good and known-bad histories.
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core import full_compartmentalized
from repro.core.history import History
from repro.core.linearizability import check_linearizable, check_slot_order


# ---------------------------------------------------------------------------
# Checker self-tests (paper's Figures 8-9 examples)
# ---------------------------------------------------------------------------


def _history(events):
    """events: list of (kind, client, op, result, t_invoke, t_respond)."""
    h = History()
    ids = {}
    for kind, client, op, result, t0, t1 in events:
        op_id = h.invoke(client, op, t0)
        if t1 is not None:
            h.respond(op_id, result, t1)
    return h


def test_paper_fig8_linearizable():
    # c1: w(0) @ [0, 4];  c2: w(1) @ [1, 3];  c1: r() -> 0 @ [5, 7]
    # linearization: w(1); w(0); r()->0   (paper Fig. 8c)
    h = _history([
        ("w", 1, ("w", 0), "ok", 0.0, 4.0),
        ("w", 2, ("w", 1), "ok", 1.0, 3.0),
        ("r", 1, ("r",), 0, 5.0, 7.0),
    ])
    assert check_linearizable(h, "register")


def test_paper_fig9_not_linearizable():
    # w(0) completes before w(1) starts; a later read returns 0 -> invalid
    h = _history([
        ("w", 1, ("w", 0), "ok", 0.0, 1.0),
        ("w", 2, ("w", 1), "ok", 2.0, 3.0),
        ("r", 1, ("r",), 0, 4.0, 5.0),
    ])
    assert not check_linearizable(h, "register")


def test_pending_write_may_take_effect():
    # paper Fig. 14: pending w(1); a read returns 1 -> must extend history
    h = _history([
        ("w", 1, ("w", 1), None, 0.0, None),  # pending
        ("r", 2, ("r",), 1, 1.0, 2.0),
    ])
    assert check_linearizable(h, "register")


def test_pending_write_may_be_dropped():
    h = _history([
        ("w", 1, ("w", 1), None, 0.0, None),  # pending, never visible
        ("r", 2, ("r",), None, 1.0, 2.0),      # reads initial value None
    ])
    assert check_linearizable(h, "register")


def test_stale_read_rejected():
    h = _history([
        ("w", 1, ("w", 1), "ok", 0.0, 1.0),
        ("w", 1, ("w", 2), "ok", 2.0, 3.0),
        ("r", 2, ("r",), 1, 4.0, 5.0),  # stale: must be 2
    ])
    assert not check_linearizable(h, "register")


# ---------------------------------------------------------------------------
# Protocol runs are linearizable under random workloads / seeds / faults
# ---------------------------------------------------------------------------

op_strategy = st.one_of(
    st.tuples(st.just("w"), st.integers(0, 3)),
    st.tuples(st.just("r")),
)


@given(
    ops0=st.lists(op_strategy, min_size=1, max_size=4),
    ops1=st.lists(op_strategy, min_size=1, max_size=4),
    seed=st.integers(0, 1000),
)
@settings(max_examples=25, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_concurrent_clients_linearizable(ops0, ops1, seed):
    dep = full_compartmentalized(f=1, n_clients=2, seed=seed,
                                 state_machine="register")
    dep.net.jitter = 3.0  # reorder messages across links
    dep.clients[0].run_ops(ops0)
    dep.clients[1].run_ops(ops1)
    dep.run_to_quiescence()
    assert dep.all_done()
    assert check_slot_order(dep.history) == []
    assert check_linearizable(dep.history, "register")


@given(
    ops=st.lists(op_strategy, min_size=2, max_size=5),
    seed=st.integers(0, 500),
    grid=st.sampled_from([(2, 2), (2, 3), (3, 2)]),
)
@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_grid_shapes_linearizable(ops, seed, grid):
    dep = full_compartmentalized(f=1, n_clients=1, seed=seed, grid=grid,
                                 state_machine="register")
    dep.clients[0].run_ops(ops)
    dep.run_to_quiescence()
    assert dep.all_done()
    assert check_linearizable(dep.history, "register")


@given(seed=st.integers(0, 300), failover_after=st.integers(1, 3))
@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_failover_preserves_linearizability(seed, failover_after):
    dep = full_compartmentalized(f=1, n_clients=1, seed=seed,
                                 state_machine="register")
    ops = [("w", i) for i in range(failover_after)]
    dep.clients[0].run_ops(ops)
    dep.run_to_quiescence()
    dep.fail_over(to_leader=1)
    dep.run_to_quiescence()
    dep.clients[0].leader = dep.leader_addrs[1]
    dep.clients[0].run_ops([("r",), ("w", 99), ("r",)])
    dep.run_to_quiescence()
    assert dep.all_done()
    assert check_linearizable(dep.history, "register")
    # the final read must observe the post-failover write
    assert dep.clients[0].results[-1] == 99
