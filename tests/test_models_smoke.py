"""Per-architecture smoke tests: reduced config, one forward + one train
step + one decode step on CPU; asserts shapes and no NaNs (assignment
requirement f)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs, get_config
from repro.models import (
    build_segments,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)

ARCHS = sorted(all_configs().keys())
B, S = 2, 32


def make_batch(cfg, key):
    kt, kf = jax.random.split(key)
    tokens = jax.random.randint(kt, (B, S), 0, cfg.vocab_size)
    batch = {"tokens": tokens, "labels": jnp.roll(tokens, -1, axis=1)}
    if cfg.is_encoder_decoder:
        batch["frames"] = jax.random.normal(
            kf, (B, cfg.encoder_seq_len, cfg.d_model), jnp.float32)
    return batch


@pytest.fixture(scope="module")
def smoke_setups():
    out = {}
    for name in ARCHS:
        cfg = get_config(name).smoke()
        params = init_params(cfg, jax.random.key(0))
        out[name] = (cfg, params)
    return out


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_shapes_and_finite(smoke_setups, arch):
    cfg, params = smoke_setups[arch]
    batch = make_batch(cfg, jax.random.key(1))
    logits, aux = jax.jit(lambda p, b: forward(cfg, p, b["tokens"],
                                               b.get("frames")))(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"
    assert bool(jnp.isfinite(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_decreases_loss_and_is_finite(smoke_setups, arch):
    cfg, params = smoke_setups[arch]
    batch = make_batch(cfg, jax.random.key(2))

    @jax.jit
    def step(p, b):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p_: loss_fn(cfg, p_, b), has_aux=True)(p)
        p_new = jax.tree.map(lambda w, g: w - 0.05 * g.astype(w.dtype), p, grads)
        return loss, metrics, p_new

    loss0, metrics, params1 = step(params, batch)
    assert bool(jnp.isfinite(loss0)), f"{arch}: non-finite loss"
    # gradients must be finite everywhere
    loss1, _, _ = step(params1, batch)
    assert bool(jnp.isfinite(loss1))
    assert float(loss1) < float(loss0) + 0.5  # no blow-up; usually decreases


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_matches_forward(smoke_setups, arch):
    """Prefill on S-1 tokens + 1 decode step == forward logits at the last
    position (the KV-cache path must be numerically consistent)."""
    cfg, params = smoke_setups[arch]
    batch = make_batch(cfg, jax.random.key(3))
    tokens = batch["tokens"]
    frames = batch.get("frames")

    full_logits, _ = forward(cfg, params, tokens, frames)
    last_from_forward = full_logits[:, -1]

    _, caches = prefill(cfg, params, tokens[:, :-1], frames)
    step_logits, _ = decode_step(cfg, params, caches, tokens[:, -1:])

    np.testing.assert_allclose(
        np.asarray(step_logits), np.asarray(last_from_forward),
        rtol=2e-2, atol=2e-2)


@pytest.mark.parametrize("arch", ARCHS)
def test_multi_step_decode_finite(smoke_setups, arch):
    cfg, params = smoke_setups[arch]
    batch = make_batch(cfg, jax.random.key(4))
    _, caches = prefill(cfg, params, batch["tokens"], batch.get("frames"))
    tok = batch["tokens"][:, -1:]
    decode = jax.jit(lambda c, t: decode_step(cfg, params, c, t))
    for _ in range(4):
        logits, caches = decode(caches, tok)
        assert bool(jnp.all(jnp.isfinite(logits)))
        tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)


def test_segments_cover_all_layers():
    for name in ARCHS:
        cfg = get_config(name)
        segs = build_segments(cfg)
        total = sum(len(s.pattern) * s.repeats for s in segs)
        assert total == cfg.n_layers, (name, segs)


def test_recurrentgemma_segments_structure():
    cfg = get_config("recurrentgemma-2b")
    segs = build_segments(cfg)
    # 26 layers = (rglru, rglru, local_attn) x 8 + (rglru, rglru)
    assert segs[0].repeats == 8 and len(segs[0].pattern) == 3
    assert segs[1].repeats == 2 and segs[1].pattern[0][0] == "rglru"


def test_deepseek_segments_structure():
    cfg = get_config("deepseek-moe-16b")
    segs = build_segments(cfg)
    assert segs[0].pattern[0][1] == "mlp" and segs[0].repeats == 1
    assert segs[1].pattern[0][1] == "moe" and segs[1].repeats == 27


def test_param_counts_in_expected_range():
    """Sanity: parameter formulas land near the advertised model sizes."""
    expected = {
        "qwen2-vl-72b": (60e9, 85e9),
        "granite-3-2b": (1.8e9, 3.2e9),
        "nemotron-4-15b": (12e9, 18e9),
        "phi3-medium-14b": (12e9, 16e9),
        "qwen1.5-32b": (28e9, 36e9),
        "qwen3-moe-30b-a3b": (25e9, 34e9),
        "deepseek-moe-16b": (14e9, 20e9),
        "recurrentgemma-2b": (2e9, 3.5e9),
        "rwkv6-7b": (6e9, 9e9),
        "whisper-tiny": (25e6, 80e6),
    }
    for name, (lo, hi) in expected.items():
        n = get_config(name).n_params()
        assert lo <= n <= hi, f"{name}: {n/1e9:.2f}B params out of [{lo/1e9}, {hi/1e9}]"


def test_moe_active_params_much_smaller():
    cfg = get_config("qwen3-moe-30b-a3b")
    assert cfg.n_active_params() < 0.25 * cfg.n_params()
