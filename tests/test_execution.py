"""The generic execution harness (``repro.core.execution``): two planes,
one registry.

* **Parity** - every variant that declares an executable must pass
  ``validate_variant`` (measured per-station msgs/cmd vs its own demand
  table) via the same generic loop the ``msgcount`` benchmark runs, at
  the write-only mix the paper states its tables for *and* at a mixed
  mix exercising the read paths.  The variant list is the registry's,
  not a hand-pin: the ``executable_variant`` fixture (tests/conftest.py)
  iterates ``executable_variants()``, so a newly registered variant
  inherits the whole suite.  Headline counts are pinned exactly:
  compartmentalized leader 2, S-Paxos leader 2 (ids only), unreplicated
  server 2, BPaxos dependency service 2.
* **Linearizability** - the property suite historically exercised
  MultiPaxos only; here Mencius, S-Paxos and CRAQ executions (plus the
  baselines) are checked through the harness's exhaustive Wing-Gong
  verdict on contended workloads across seeds.
* **Calibration** - ``calibrate_alpha(measured=True)`` anchors alpha on
  an *executed* vanilla run.
"""
import pytest

from repro.core import (
    MIXED_50_50,
    STATION_ORDER,
    WRITE_ONLY,
    Workload,
    calibrate_alpha,
    default_config,
    executable_variants,
    registered_variants,
    run_variant,
    validate_variant,
    workload_ops,
)


def test_every_registered_variant_declares_an_executable():
    """Counts and names are derived from the registry, never hand-pinned:
    adding a variant cannot break this test unless it forgets its
    execution plane."""
    names = set(executable_variants())
    assert names == set(registered_variants())
    # the historical eight plus the multi-leader family are all present
    assert {"compartmentalized", "unreplicated", "multipaxos", "mencius",
            "vanilla_mencius", "spaxos", "vanilla_spaxos", "craq",
            "bpaxos", "iss"} <= names


# ---------------------------------------------------------------------------
# Parity: one generic loop, zero per-variant branches
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("workload", [WRITE_ONLY, MIXED_50_50],
                         ids=["write_only", "mixed"])
def test_parity_every_executable_variant(executable_variant, workload):
    report = validate_variant(executable_variant, workload=workload,
                              n_commands=48, seed=0)
    assert report.passed, str(report)
    assert report.trace.linearizable


def test_headline_leader_counts_are_exact():
    """Paper section 3.1 / 7: the compartmentalized leader handles exactly
    2 msgs/cmd, the S-Paxos leader exactly 2 id-only msgs/cmd, and the
    vanilla leader >= 3f+4 - measured, not modelled."""
    comp = validate_variant("compartmentalized", workload=Workload(),
                            n_commands=40, seed=0)
    assert comp.row("leader").exact
    assert comp.row("leader").measured == pytest.approx(2.0, abs=1e-9)

    spax = validate_variant("spaxos", workload=Workload(), n_commands=40,
                            seed=0)
    assert spax.row("leader").measured == pytest.approx(2.0, abs=1e-9)

    vanilla = validate_variant("multipaxos", workload=Workload(),
                               n_commands=40, seed=0)
    assert vanilla.row("leader").measured >= 3 * 1 + 4  # 3f+4, f=1

    unrep = validate_variant("unreplicated", workload=Workload(),
                             n_commands=40, seed=0)
    assert unrep.row("server").measured == pytest.approx(2.0, abs=1e-9)

    # the multi-leader family's structural floor: every BPaxos dep-service
    # node sees every command once and replies once - exactly 2 msgs/cmd,
    # the same ceiling the compartmentalized leader has
    bpax = validate_variant("bpaxos", workload=Workload(), n_commands=40,
                            seed=0)
    assert bpax.row("dep_service").exact
    assert bpax.row("dep_service").measured == pytest.approx(2.0, abs=1e-9)


def test_mencius_feedback_reads_skips_off_the_run():
    report = validate_variant("mencius", workload=Workload(), n_commands=45,
                              seed=0)
    assert report.passed, str(report)
    assert report.model_config["announce_interval"] == 1.0
    assert 0.0 < report.model_config["skip_fraction"] < 1.0
    # the user config is untouched: feedback refines the model side only
    assert "skip_fraction" not in report.config


def test_craq_feedback_measures_dirty_forwarding():
    w = Workload(f_write=0.3, skew_p=0.8)
    report = validate_variant("craq", workload=w, n_commands=60, seed=0)
    assert report.passed, str(report)
    forwarded = sum(n.tail_forwards for n in report.trace.deployment.nodes)
    assert forwarded > 0  # hot-key contention really forwards to the tail
    assert report.model_config["skew_p"] > 0.0
    assert report.model_config["dirty_fraction"] == 1.0


def test_trace_buckets_into_canonical_station_slots():
    trace = run_variant("spaxos", n_commands=20, seed=0)
    row = trace.demand_slots()
    assert len(row) == len(STATION_ORDER)
    for station in ("disseminator", "stabilizer", "leader", "proxy",
                    "acceptor", "replica"):
        assert row[STATION_ORDER.index(station)] > 0
    assert row[STATION_ORDER.index("head")] == 0.0  # no chain stations
    assert trace.station_servers["leader"] == 1
    assert trace.deployment.total_messages()["leader"] == 40  # 2/cmd, hoisted


def test_reads_as_writes_baseline_drives_writes_only():
    """The vanilla table has no read path, so its executable declares
    reads_as_writes: even a read-heavy workload executes as writes."""
    trace = run_variant("multipaxos", workload=Workload.read_mix(0.9),
                        n_commands=30, seed=0)
    assert trace.n_reads == 0
    assert trace.n_writes == 30


# ---------------------------------------------------------------------------
# Linearizability across the variant zoo (satellite: property coverage
# beyond MultiPaxos)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("seed", [0, 1, 2, 3])
def test_contended_executions_linearizable_exhaustive(executable_variant,
                                                      seed):
    """Small contended runs (hot-key skew, mixed reads/writes, concurrent
    closed-loop clients) checked by the exhaustive Wing-Gong search - the
    ground-truth verdict, inherited by every registered executable (the
    multi-leader family included) through the registry fixture."""
    w = Workload(f_write=0.5, skew_p=0.9)
    trace = run_variant(executable_variant, workload=w, n_commands=10,
                        seed=seed)
    assert trace.checker == "exhaustive"
    assert trace.linearizable, trace.violations


@pytest.mark.parametrize("name", ["mencius", "spaxos", "craq"])
def test_variant_executions_linearizable_under_jitter(name):
    """Message reordering across links must not break linearizability of
    the variant clusters (the harness's checker sees the reordered
    history)."""
    hypothesis = pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings, strategies as st

    @given(seed=st.integers(0, 200), f_write=st.sampled_from([0.4, 0.7, 1.0]))
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def check(seed, f_write):
        trace = run_variant(name, workload=Workload(f_write=f_write,
                                                    skew_p=0.8),
                            n_commands=8, seed=seed, jitter=3.0)
        assert trace.checker == "exhaustive"
        assert trace.linearizable, trace.violations

    check()


def test_larger_histories_fall_back_to_slot_order():
    trace = run_variant("compartmentalized", workload=Workload(f_write=0.5),
                        n_commands=60, seed=0)
    assert trace.checker == "slot_order"
    assert trace.linearizable


def test_slotless_histories_never_get_a_vacuous_verdict():
    """CRAQ responses carry no global log position, so the slot-order
    check would be vacuously true on its histories - large CRAQ runs must
    fall back to the exhaustive verdict instead."""
    trace = run_variant("craq", workload=Workload(f_write=0.5, skew_p=0.5),
                        n_commands=60, seed=0)
    assert trace.checker == "exhaustive"
    assert trace.linearizable


# ---------------------------------------------------------------------------
# Measured calibration + harness edges
# ---------------------------------------------------------------------------


def test_calibrate_alpha_measured_matches_wire_counts():
    """The executed vanilla leader handles exactly 3f+4+1 = 8 msgs/cmd
    (client in, 2 p2a out, 2 p2b in, 3 chosen out at 2f+1 replicas), so
    the measured anchor is 25k * 8."""
    alpha = calibrate_alpha(measured=True, n_commands=30)
    assert alpha == pytest.approx(25_000.0 * 8.0)
    # the table-derived anchor folds the fused machine's reply share in
    assert calibrate_alpha() > alpha
    with pytest.raises(TypeError, match="model=None"):
        calibrate_alpha(measured=True, model=object())


def test_workload_ops_realize_the_exact_mix():
    ops = workload_ops(Workload(f_write=0.5), 30, seed=4)
    assert sum(1 for op in ops if op[0] == "put") == 15
    ops = workload_ops(Workload(f_write=1.0, skew_p=1.0), 10, seed=0)
    assert all(op[:2] == ("put", "hot") for op in ops)


def test_default_config_is_first_knob_point():
    assert default_config("craq") == {"variant": "craq", "n_nodes": 3}
    assert default_config("mencius")["n_leaders"] == 3


def test_variant_without_executable_is_diagnosed():
    from repro.core import register_variant, temporary_variants
    from repro.core.analytical import vanilla_mencius_model

    with temporary_variants():
        register_variant(name="table_only_proto",
                         factory=vanilla_mencius_model,
                         stations=("server",))
        with pytest.raises(ValueError, match="no execution plane"):
            run_variant("table_only_proto", n_commands=4)
    with pytest.raises(ValueError, match="unknown variant"):
        run_variant("no_such_protocol", n_commands=4)


# ---------------------------------------------------------------------------
# Batched configs on the measured plane (n_batchers > 0)
# ---------------------------------------------------------------------------


BATCHED_CFG = {"f": 1, "n_proxy_leaders": 3, "grid_rows": 2, "grid_cols": 2,
               "n_replicas": 2, "batch_size": 10, "n_batchers": 1,
               "n_unbatchers": 1}


@pytest.mark.parametrize("mix", [WRITE_ONLY, MIXED_50_50],
                         ids=lambda w: f"fw{w.f_write:g}")
def test_batched_config_parity(mix):
    """A compartmentalized config with a real batcher tier passes parity:
    the model feedback replaces the configured batch size with the
    *measured* fill (timer-flushed batches under a small closed-loop
    client population carry ~n_clients commands, not batch_size), so the
    leader check stays exact at any mix."""
    rep = validate_variant("compartmentalized", BATCHED_CFG, workload=mix,
                           n_commands=60, seed=1)
    assert rep.passed, str(rep)
    leader = rep.row("leader")
    assert leader.exact and leader.measured == leader.predicted
    b_eff = rep.model_config["batch_size"]
    assert 1.0 <= b_eff < BATCHED_CFG["batch_size"]
    assert rep.trace.linearizable


def test_batched_feedback_reconciles_with_batch_fill_adapter():
    """The measured amortization and the ``Workload.batch_fill`` adapter
    are the same knob seen from two sides: feeding the measured effective
    batch back as ``batch_size`` must produce the same leader demand as
    keeping ``batch_size`` and lowering the workload's fill hint to
    ``(b_eff - 1) / (B - 1)`` (the inverse of ``effective_batch_size``)."""
    from dataclasses import replace

    from repro.core import variant_spec
    from repro.core.analytical import effective_batch_size

    rep = validate_variant("compartmentalized", BATCHED_CFG,
                           workload=WRITE_ONLY, n_commands=60, seed=1)
    b_eff = rep.model_config["batch_size"]
    B = BATCHED_CFG["batch_size"]
    fill = (b_eff - 1.0) / (B - 1.0)
    spec = variant_spec("compartmentalized")
    via_feedback = spec.build(rep.model_config).demands(WRITE_ONLY)
    hint_cfg = spec.adapt({k: v for k, v in BATCHED_CFG.items()},
                          replace(WRITE_ONLY, batch_fill=fill))
    via_hint = spec.build(hint_cfg).demands(WRITE_ONLY)
    # effective_batch_size rounds to an integer batch; compare through it
    assert hint_cfg["batch_size"] == effective_batch_size(B, fill)
    assert via_hint["leader"] == pytest.approx(via_feedback["leader"],
                                               rel=0.35)
    # and at fill == measured fill the bottleneck-law peaks agree within
    # the same rounding
    assert abs(hint_cfg["batch_size"] - b_eff) <= 0.5 + 1e-9


def test_batched_station_msgs_include_batcher_tier():
    tr = run_variant("compartmentalized", BATCHED_CFG, workload=WRITE_ONLY,
                     n_commands=60, seed=1)
    assert "batcher" in tr.station_msgs
    assert "unbatcher" in tr.station_msgs
    assert tr.station_msgs["batcher"] > 0
