"""Sweep engine + autotuner tests: the batched path must agree elementwise
with the scalar per-model path, a >=100-config sweep must evaluate in one
jitted call, and the autotuner must recover the paper's hand-tuned Fig. 29
ordering and deployment quality under the same machine budget."""
import numpy as np
import pytest

from repro.core import (
    STATION_ORDER,
    SweepSpec,
    Workload,
    ablation_steps,
    autotune,
    bottleneck_trace,
    calibrate_alpha,
    compartmentalized_model,
    compile_models,
    compile_sweep,
    fluid_throughput,
    multipaxos_model,
    mva_curve,
    stack_demands,
)
from repro.core.analytical import PAPER_MULTIPAXOS_UNBATCHED

ALPHA = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)


def big_spec() -> SweepSpec:
    return SweepSpec(
        n_proxy_leaders=(1, 2, 4, 7, 10),
        grids=((3, 1), (2, 2), (2, 3), (3, 3)),
        n_replicas=(2, 3, 4, 5, 6),
        batch_sizes=(1,),
    )


# ---------------------------------------------------------------------------
# Demand-matrix compiler
# ---------------------------------------------------------------------------


def test_stack_demands_roundtrips_station_demands():
    models = [multipaxos_model(), compartmentalized_model(),
              compartmentalized_model(batch_size=100, n_batchers=2,
                                      n_unbatchers=3)]
    d_w, d_r, machines = stack_demands(models)
    assert d_w.shape == (3, len(STATION_ORDER))
    for i, m in enumerate(models):
        assert machines[i] == m.total_machines()
        for s in m.stations:
            k = STATION_ORDER.index(s.name)
            assert d_w[i, k] == pytest.approx(s.demand_write)
            assert d_r[i, k] == pytest.approx(s.demand_read)
    # slots for absent stations are exactly zero
    assert d_w[0, STATION_ORDER.index("proxy")] == 0.0


def test_compiled_peaks_match_per_model_bottleneck_law():
    compiled = compile_sweep(big_spec())
    assert len(compiled) == 100
    for f_write in (1.0, 0.5, 0.1):
        w = Workload(f_write=f_write)
        peaks = compiled.peak_throughput(ALPHA, w)
        bns = compiled.bottlenecks(w)
        for i, m in enumerate(compiled.models):
            assert peaks[i] == pytest.approx(
                m.peak_throughput(ALPHA, f_write=f_write), rel=1e-12)
            assert bns[i] == m.bottleneck(f_write)[0]


def test_compiled_sweep_carries_configs():
    compiled = compile_sweep(big_spec())
    assert compiled.configs is not None
    for cfg, m in zip(compiled.configs, compiled.models):
        rebuilt = compartmentalized_model(**cfg)
        assert rebuilt.stations == m.stations


# ---------------------------------------------------------------------------
# One jitted call over >= 100 configs == per-config scalar MVA
# ---------------------------------------------------------------------------


def test_batched_mva_matches_per_config_curves_elementwise():
    compiled = compile_sweep(big_spec())
    assert len(compiled) >= 100
    clients, X, R = compiled.mva(ALPHA, n_clients_max=64)
    assert X.shape == (len(compiled), 64)
    for i in range(0, len(compiled), 7):  # sample the grid
        _, x_single, r_single = mva_curve(compiled.models[i], ALPHA,
                                          n_clients_max=64)
        np.testing.assert_allclose(X[i], x_single, rtol=1e-6)
        np.testing.assert_allclose(R[i], r_single, rtol=1e-6)


def test_batched_mva_read_mix_matches_scalar():
    compiled = compile_sweep(SweepSpec(n_proxy_leaders=(5, 10),
                                       grids=((2, 2),),
                                       n_replicas=(4, 6)))
    _, X, _ = compiled.mva(ALPHA, n_clients_max=32,
                           workload=Workload.read_mix(0.9))
    for i, m in enumerate(compiled.models):
        _, x_single, _ = mva_curve(m, ALPHA, n_clients_max=32, f_write=0.1)
        np.testing.assert_allclose(X[i], x_single, rtol=1e-6)


def test_batched_fluid_matches_scalar():
    compiled = compile_models([multipaxos_model(), compartmentalized_model()])
    xs = compiled.fluid(ALPHA, n_clients=128, sim_time=0.05)
    for i, m in enumerate(compiled.models):
        x_single = fluid_throughput(m, ALPHA, n_clients=128, sim_time=0.05)
        assert xs[i] == pytest.approx(x_single, rel=1e-6)


# ---------------------------------------------------------------------------
# Autotuner
# ---------------------------------------------------------------------------


def test_ablation_ordering_recovered_by_batched_eval():
    """The batched sweep must rank the Fig. 29 staircase exactly as the
    scalar hand-tuned path does: monotone nondecreasing, ending at the
    paper deployment's peak."""
    steps = ablation_steps()
    compiled = compile_models([m for _, m in steps])
    peaks = compiled.peak_throughput(ALPHA)
    scalar = [m.peak_throughput(ALPHA) for _, m in steps]
    np.testing.assert_allclose(peaks, scalar, rtol=1e-12)
    assert all(b >= a * 0.999 for a, b in zip(peaks, peaks[1:]))
    # bottleneck identities match the scalar path too
    assert compiled.bottlenecks() == [m.bottleneck()[0] for _, m in steps]


def test_autotune_meets_paper_deployment_at_same_budget():
    paper = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=2,
                                    grid_cols=2, n_replicas=4)
    budget = paper.total_machines()  # 19: leader + 10 proxies + 4 acc + 4 repl
    res = autotune(budget=budget, alpha=ALPHA, workload=Workload())
    assert res.best_peak >= paper.peak_throughput(ALPHA) * (1 - 1e-9)
    assert res.machines <= budget
    # fully compartmentalized write path still bottlenecks on the leader
    assert res.best_bottleneck == "leader"


def test_autotune_trace_walks_paper_bottleneck_migration():
    """Fig. 29a narrative: leader -> proxies (scaled until) -> leader."""
    trace = bottleneck_trace(budget=19, alpha=ALPHA, workload=Workload())
    bns = [t.bottleneck for t in trace]
    assert bns[0] == "leader"          # vanilla MultiPaxos
    assert bns[1] == "proxy"           # right after decoupling
    assert bns[-1] == "leader"         # terminal write-path bottleneck
    peaks = [t.peak for t in trace]
    assert all(b >= a * 0.999 for a, b in zip(peaks, peaks[1:]))
    machines = [t.machines for t in trace]
    assert all(m <= 19 for m in machines)


def test_autotune_read_heavy_scales_replicas():
    res = autotune(budget=19, alpha=ALPHA, workload=Workload.read_mix(0.9))
    res_w = autotune(budget=19, alpha=ALPHA, workload=Workload())
    assert res.best_peak > 2.0 * res_w.best_peak
    assert res.best_config["n_replicas"] > 2
    # the read-heavy staircase must scale replicas at some point
    labels = [t.label for t in res.trace]
    assert any("replica" in l for l in labels)


def test_autotune_batching_beats_unbatched():
    res_b = autotune(budget=19, alpha=ALPHA, workload=Workload(),
                     batching=True)
    res_u = autotune(budget=19, alpha=ALPHA, workload=Workload())
    assert res_b.best_peak > 2.0 * res_u.best_peak
    assert res_b.best_config["n_batchers"] >= 1


def test_autotune_respects_budget():
    for budget in (9, 12, 19):
        res = autotune(budget=budget, alpha=ALPHA,
                       workload=Workload(f_write=0.5))
        assert res.machines <= budget
        assert all(t.machines <= budget for t in res.trace)
    with pytest.raises(ValueError):
        autotune(budget=4, alpha=ALPHA)


def test_autotune_more_budget_never_hurts():
    peaks = [autotune(budget=b, alpha=ALPHA,
                      workload=Workload.read_mix(0.9)).best_peak
             for b in (10, 14, 19, 24)]
    assert all(b >= a * (1 - 1e-9) for a, b in zip(peaks, peaks[1:]))
