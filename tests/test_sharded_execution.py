"""The shard axis on the measured planes, and the live-resharding replay.

Acceptance criteria pinned here:

* a 4-shard compartmentalized MultiPaxos executes on the real-cluster
  plane with per-shard parity within the registered tolerances and
  per-key-partition linearizability passing;
* the live-resharding event (hot-shard split under load) replayed on the
  real cluster shows the same dip-then-recover-above-pre shape the
  transient plane predicts for :func:`resharding_schedule`
  (tests/test_sharding.py::test_resharding_transient_shape);
* the batched executor grows the same shard axis: one jitted call over
  (config x shard x seed) lanes with hash-split command budgets.
"""
import random

import numpy as np
import pytest

from repro.core.api import MIXED_50_50, WRITE_ONLY, ShardingSpec, Workload
from repro.core.batched_execution import execute_configs
from repro.core.execution import (
    ShardedDeployment,
    run_sharded,
    validate_sharded,
)
from repro.core.sharding import (
    check_linearizable_partitioned,
    op_key,
    partition_ops,
)
from repro.core.sweep import SweepSpec, compile_sweep

CFG = {"f": 1, "n_proxy_leaders": 3, "grid_rows": 2, "grid_cols": 2,
       "n_replicas": 2}


# ---------------------------------------------------------------------------
# Acceptance: 4-shard parity + per-key-partition linearizability
# ---------------------------------------------------------------------------


def test_four_shard_parity_acceptance():
    rep = validate_sharded("compartmentalized", ShardingSpec(4), CFG,
                           workload=WRITE_ONLY, n_commands=96, seed=1)
    assert rep.passed, rep.summary()
    assert rep.shards_checked == 4
    assert rep.trace.linearizable
    for tr in rep.trace.shards:
        assert tr.checker.startswith("per_key"), tr.checker
    # shard-scaled tables: each shard's parity rows compare against the
    # same per-command analytical table (per shard-local command)
    for shard_rep in rep.reports:
        assert shard_rep is not None
        assert all(r.ok for r in shard_rep.rows), shard_rep.rows
        leader = shard_rep.row("leader")
        assert leader.exact and leader.measured == leader.predicted


def test_four_shard_mixed_parity():
    rep = validate_sharded("compartmentalized", ShardingSpec(4), CFG,
                           workload=MIXED_50_50, n_commands=96, seed=2)
    assert rep.passed, rep.summary()


def test_run_sharded_routes_and_accounts_every_op():
    tr = run_sharded("compartmentalized", ShardingSpec(4), CFG,
                     workload=WRITE_ONLY, n_commands=64, seed=3)
    assert sum(tr.ops_per_shard) == 64
    assert tr.n_commands == 64
    assert len(tr.shards) == 4
    assert tr.linearizable
    # routing in the deployment matches the spec's hash
    for s, dep in enumerate(tr.deployment.shards):
        for o in dep.history.ops:
            key = op_key(o.op)
            if key is not None:
                assert tr.deployment.route(key) == s


def test_run_sharded_tolerates_empty_shards():
    # 8 shards fed from a small key population: some shards get no ops
    tr = run_sharded("compartmentalized", ShardingSpec(8), CFG,
                     workload=WRITE_ONLY, n_commands=16, seed=4,
                     n_cold_keys=4)
    assert sum(tr.ops_per_shard) == 16
    assert 0 in tr.ops_per_shard
    assert tr.linearizable
    rep = validate_sharded("compartmentalized", ShardingSpec(8), CFG,
                           workload=WRITE_ONLY, n_commands=16, seed=4,
                           n_cold_keys=4)
    assert rep.passed
    assert rep.shards_checked < 8       # empty shards carry no parity row
    assert any(r is None for r in rep.reports)


def test_per_shard_configs_may_differ():
    cfgs = [dict(CFG), dict(CFG, n_proxy_leaders=4)]
    sd = ShardedDeployment("compartmentalized", ShardingSpec(2),
                           configs=cfgs, n_clients=2, seed=5)
    assert len(sd.shards[0].proxies) == 3
    assert len(sd.shards[1].proxies) == 4
    with pytest.raises(ValueError):
        ShardedDeployment("compartmentalized", ShardingSpec(3),
                          configs=cfgs)


# ---------------------------------------------------------------------------
# Batched plane: (config x shard x seed) lanes in one device call
# ---------------------------------------------------------------------------


def test_batched_sharded_lanes():
    w = Workload(f_write=1.0, skew_p=0.6)
    sh = ShardingSpec(2)
    res = execute_configs([dict(CFG, variant="compartmentalized")],
                          workload=w, n_commands=32, seeds=2, sharding=sh)
    assert len(res) == 2
    assert res.lane_shard.tolist() == [0, 1]
    assert res.lane_commands.sum() == 32
    hot = sh.hot_shard
    assert res.lane_commands[hot] > res.lane_commands[1 - hot]
    assert np.all(res.completed == res.lane_commands[:, None])
    # aggregate rate across concurrent shard groups beats any single lane
    agg = res.sharded_throughput(0)
    assert np.all(agg > res.throughput.max(axis=0) * 0.99)
    # unsharded call unchanged: no lane bookkeeping
    res1 = execute_configs([dict(CFG, variant="compartmentalized")],
                           workload=w, n_commands=32, seeds=2)
    assert res1.lane_config is None and len(res1) == 1


def test_sweep_execute_carries_sharding():
    sweep = compile_sweep(SweepSpec(f=1, n_proxy_leaders=(3,),
                                    grids=((2, 2),), n_replicas=(2,)))
    res = sweep.execute(workload=WRITE_ONLY, n_commands=24, seeds=2,
                        sharding=ShardingSpec(2))
    assert len(res) == 2
    assert res.sharding is not None and res.sharding.n_shards == 2


# ---------------------------------------------------------------------------
# The live resharding replay (the PR-6 failover replay's sibling)
# ---------------------------------------------------------------------------


def _keys_on(sharding, shard, prefix, n):
    out, i = [], 0
    while len(out) < n:
        k = f"{prefix}{i}"
        if sharding.shard_of(k) == shard:
            out.append(k)
        i += 1
    return out


def _stream(rng, keys, n, tag):
    ops, v = [], 0
    for _ in range(n):
        k = rng.choice(keys)
        if rng.random() < 0.7:
            ops.append(("put", k, f"{tag}{v}"))
            v += 1
        else:
            ops.append(("get", k))
    return ops


def _completions(dep):
    return len(dep.history.complete())


def test_live_resharding_replay_matches_transient_shape():
    """Replay the resharding_schedule event on the real cluster: steady
    2-shard traffic, a migration blackout of the hot shard, then its key
    range split across two groups.  The completion-rate trace must show
    the transient plane's shape - a dip while the hot shard is dark
    (bounded by the surviving shard's rate) and recovery ABOVE the
    pre-split level (extra capacity serves the former hot traffic) - and
    every history must stay per-key-partition linearizable, with the
    migrated keys' values carried over to the destination group."""
    sh = ShardingSpec(n_shards=2)
    hot = 1
    cold_keys = _keys_on(sh, 0, "c", 4)
    keep_keys = _keys_on(sh, hot, "p", 3)
    move_keys = _keys_on(sh, hot, "m", 3)
    move_set = set(move_keys)

    rng = random.Random(7)
    sd = ShardedDeployment("compartmentalized", sh, config=CFG,
                           n_clients=2, seed=3)
    # budgets sized so no group runs dry inside a measurement window
    # (closed-loop clients park when their queue drains, deflating rates)
    parts = sd.submit(_stream(rng, cold_keys, 1000, "a")
                      + _stream(rng, keep_keys + move_keys, 1400, "h"))
    assert len(parts[0]) == 1000 and len(parts[hot]) == 1400

    # --- pre phase: both shards serve their partitions ------------------
    sd.step_all(until=500.0)
    pre_counts = sd.completed_counts()
    pre = sum(pre_counts) / 500.0
    assert all(c > 0 for c in pre_counts), pre_counts
    served_move = [o for o in sd.shards[hot].history.complete()
                   if op_key(o.op) in move_set]
    assert served_move, "hot shard must serve moved keys pre-split"

    # --- migration blackout: the hot shard goes dark --------------------
    # moved keys leave the hot shard: drop its unissued ops on them (the
    # client tier redirects new traffic at the split)
    for c in sd.shards[hot].clients:
        c.ops[c.op_index:] = [op for op in c.ops[c.op_index:]
                              if op_key(op) not in move_set]
    sd.step_all(until=1300.0, skip=(hot,))
    mid_counts = sd.completed_counts()
    dip = sum(m - p for m, p in zip(mid_counts, pre_counts)) / 800.0
    assert mid_counts[hot] == pre_counts[hot]      # dark means dark

    # --- the split: hand the moved key range to a fresh group -----------
    sd.shards[hot].net.run(until=1320.0)           # drain in-flight ops
    last = {}
    for o in sorted(sd.shards[hot].history.complete(),
                    key=lambda o: o.response_time):
        if o.op[0] == "put" and o.op[1] in move_set:
            last[o.op[1]] = o.op[2]
    assert last, "pre-split writes must exist on the moved range"

    dest = ShardedDeployment("compartmentalized", ShardingSpec(1),
                             config=CFG, n_clients=2, seed=11)
    rng2 = random.Random(11)
    for j, client in enumerate(dest.shards[0].clients):
        mine = [k for i, k in enumerate(move_keys) if i % 2 == j]
        seeded = [k for k in mine if k in last]
        ops = ([("put", k, last[k]) for k in seeded]       # migration copy
               + [("get", k) for k in seeded]              # continuity probe
               + (_stream(rng2, mine, 350, f"d{j}") if mine else []))
        if ops:
            client.run_ops(ops)

    # --- post phase: three groups serve the same key space --------------
    post_base = sd.completed_counts()
    sd.step_all(until=2600.0)
    dest.step_all(until=1300.0)
    post_counts = sd.completed_counts()
    post = (sum(p - b for p, b in zip(post_counts, post_base))
            + dest.completed_counts()[0]) / 1300.0

    # the transient plane's shape booleans, replayed
    assert pre > 0
    assert dip < 0.6 * pre, (dip, pre)
    assert post > 1.1 * pre, (post, pre)

    # safety across the whole event
    for h in sd.histories + dest.histories:
        assert check_linearizable_partitioned(h)
    # migrated values really crossed: the destination's first read of
    # each seeded key returns the hot shard's last committed value
    first_get = {}
    for o in sorted(dest.shards[0].history.complete(),
                    key=lambda o: o.response_time):
        k = op_key(o.op)
        if o.op[0] == "get" and k in last and k not in first_get:
            first_get[k] = o.result
    assert first_get
    for k, v in first_get.items():
        assert v == last[k], (k, v, last[k])
