"""Property-based tests of the shard axis (hypothesis).

Two ISSUE-mandated properties:

1. Hash routing balances keys within tolerance across shards for any
   generic key population (no adversarially colliding generator - crc32
   over distinct strings behaves like a uniform hash).
2. The per-key-partition linearizability decomposition accepts exactly
   the histories the whole-history checker accepts on small cross-shard
   KV workloads (Herlihy & Wing locality, pinned against the
   implementation rather than assumed).
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings, strategies as st

from repro.core.api import ShardingSpec
from repro.core.history import History
from repro.core.linearizability import check_linearizable
from repro.core.sharding import check_linearizable_partitioned


# ---------------------------------------------------------------------------
# Routing balance
# ---------------------------------------------------------------------------


@settings(max_examples=40, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(n_shards=st.integers(min_value=2, max_value=8),
       prefix=st.text(alphabet="abcdefgh", min_size=0, max_size=6),
       n_keys=st.integers(min_value=1500, max_value=4000))
def test_hash_routing_balances_keys(n_shards, prefix, n_keys):
    """Distinct keys spread across shards within 30% of the fair share -
    crc32 routing has no hot shard unless the *workload* has a hot key."""
    sh = ShardingSpec(n_shards=n_shards)
    counts = [0] * n_shards
    for i in range(n_keys):
        counts[sh.shard_of(f"{prefix}key:{i}")] += 1
    fair = n_keys / n_shards
    assert min(counts) > 0.7 * fair, counts
    assert max(counts) < 1.3 * fair, counts


@settings(max_examples=60, deadline=None)
@given(key=st.one_of(st.text(max_size=20), st.integers(), st.tuples(
    st.text(max_size=5), st.integers())),
       n_shards=st.integers(min_value=1, max_value=16))
def test_routing_is_total_and_deterministic(key, n_shards):
    sh = ShardingSpec(n_shards=n_shards)
    s = sh.shard_of(key)
    assert 0 <= s < n_shards
    assert sh.shard_of(key) == s


# ---------------------------------------------------------------------------
# Partitioned linearizability == whole-history linearizability
# ---------------------------------------------------------------------------


def _build(events):
    h = History()
    for client, op, result, t0, t1 in events:
        op_id = h.invoke(client, op, t0)
        h.respond(op_id, result, t1)
    return h


@st.composite
def kv_histories(draw):
    """Small concurrent KV histories over 2-3 keys: puts with known
    values, gets that return either a plausible value (last committed,
    in-flight, or initial None) or - sometimes - garbage, so the strategy
    covers both linearizable and non-linearizable cases."""
    n_ops = draw(st.integers(min_value=2, max_value=7))
    keys = ["x", "y", "z"]
    events = []
    t = 0.0
    committed = {}
    for i in range(n_ops):
        client = draw(st.integers(min_value=1, max_value=3))
        key = draw(st.sampled_from(keys))
        t0 = t + draw(st.floats(min_value=0.0, max_value=0.5))
        t1 = t0 + draw(st.floats(min_value=0.1, max_value=1.0))
        if draw(st.booleans()):
            committed.setdefault(key, []).append(i)
            events.append((client, ("put", key, i), "ok", t0, t1))
        else:
            pool = [None] + committed.get(key, []) + [-1]
            val = draw(st.sampled_from(pool))
            events.append((client, ("get", key), val, t0, t1))
        t = t0
    return events


@settings(max_examples=60, deadline=None,
          suppress_health_check=[HealthCheck.too_slow,
                                 HealthCheck.filter_too_much])
@given(events=kv_histories())
def test_per_key_partition_matches_whole_checker(events):
    whole = check_linearizable(_build(events), sm_kind="kv")
    split = check_linearizable_partitioned(_build(events))
    assert whole == split, events
