"""Protocol-variant performance models (paper sections 6-7, Figs. 24-28).

Three layers of pinning:

1. **Message-count parity** - the Mencius / S-Paxos demand tables must
   match the per-station messages per command *measured* on the
   correctness-plane clusters, via the generic two-plane harness
   (``repro.core.execution.validate_variant`` - the same zero-branch loop
   ``benchmarks/protocol_messages.py`` runs).
2. **Batched == scalar** - a mixed-variant ``compile_sweep`` grid must
   agree elementwise with the per-model bottleneck law and MVA, in one
   jitted call.
3. **Paper ordering** - compartmentalized Mencius / S-Paxos beat their
   vanilla baselines; the cross-variant autotuner respects the budget.
"""
import numpy as np
import pytest

from repro.core import (
    STATION_ORDER,
    SweepSpec,
    Workload,
    autotune_variants,
    calibrate_alpha,
    compartmentalized_model,
    compile_sweep,
    craq_chain_model,
    mencius_model,
    mencius_skip_storm_schedule,
    model_for,
    multipaxos_model,
    mva_curve,
    simulate_transient,
    spaxos_model,
    spaxos_payload_ramp_schedule,
    validate_variant,
    vanilla_mencius_model,
    vanilla_spaxos_model,
)

ALPHA = calibrate_alpha()


# ---------------------------------------------------------------------------
# Message-count parity: correctness plane vs demand tables
# ---------------------------------------------------------------------------


def test_mencius_demands_match_measured_messages():
    """Measured per-station msgs/cmd of a balanced 3-leader Mencius run vs
    the demand table with the run's own announce/skip parameters fed back
    in (the registered ``model_feedback``): message-exact on
    leader/acceptor/replica, the proxy within its declared range-path
    margin."""
    report = validate_variant("mencius", workload=Workload(),
                              n_commands=45, seed=0)
    assert report.passed, str(report)
    # interleaved arrivals force some noop fills, and the feedback must
    # have read them off the run into the table's skip knobs
    assert report.model_config["announce_interval"] == 1.0
    assert report.model_config.get("skip_fraction", 0.0) > 0.0
    for station in ("leader", "acceptor", "replica"):
        assert report.row(station).rel_err <= 0.10, str(report)


def test_spaxos_demands_match_measured_messages():
    """S-Paxos parity is tight on every station - the deployment's write
    path is the table's write path message for message."""
    report = validate_variant("spaxos", workload=Workload(),
                              n_commands=45, seed=0)
    assert report.passed, str(report)
    assert report.max_rel_err() <= 0.10


def test_spaxos_leader_orders_ids_only():
    """The measured leader cost must be exactly 2 msgs/cmd (ProposeId in,
    Phase2a(id) out) - and the table's leader demand must not scale with
    the payload factor."""
    report = validate_variant("spaxos", workload=Workload(),
                              n_commands=30, seed=0)
    leader = report.row("leader")
    assert leader.exact  # the registered executable declares it exact
    assert leader.measured == pytest.approx(2.0, abs=1e-9)
    for payload in (1.0, 8.0, 64.0):
        assert spaxos_model(payload_factor=payload).demands()["leader"] == 2.0


# ---------------------------------------------------------------------------
# Steady-state MVA vs the demand tables
# ---------------------------------------------------------------------------


def test_variant_mva_saturates_at_bottleneck_law():
    """High-population MVA throughput of each variant model must converge
    to alpha / max_k d_k - the law the parity tests above anchor."""
    for model in (mencius_model(), spaxos_model(), vanilla_mencius_model(),
                  vanilla_spaxos_model(), craq_chain_model(3)):
        _, x, _ = mva_curve(model, ALPHA, n_clients_max=256)
        law = model.peak_throughput(ALPHA)
        assert x[-1] == pytest.approx(law, rel=0.05), model.name


def test_compartmentalized_variants_beat_vanilla():
    """Paper Figs. 25 and 27: compartmentalizing Mencius and S-Paxos must
    each give a multiple of the vanilla deployment's peak."""
    assert (mencius_model().peak_throughput(ALPHA)
            > 2.0 * vanilla_mencius_model().peak_throughput(ALPHA))
    assert (spaxos_model(n_disseminators=4, n_stabilizers=5).peak_throughput(ALPHA)
            > 2.0 * vanilla_spaxos_model().peak_throughput(ALPHA))


def test_mencius_sequencing_splits_across_leaders():
    """Fig. 26: per-leader sequencing demand is 2/m, so the leader station
    stops being the bottleneck once m >= 2 (the compartmentalized
    MultiPaxos leader is pinned at 2 msgs/cmd)."""
    demands = [mencius_model(n_leaders=m).demands()["leader"]
               for m in (1, 2, 3, 6)]
    assert demands == [pytest.approx(2.0 / m) for m in (1, 2, 3, 6)]
    assert mencius_model(n_leaders=1).bottleneck()[0] == "leader"
    assert mencius_model(n_leaders=3).bottleneck()[0] != "leader"
    comp = compartmentalized_model(n_proxy_leaders=10, grid_rows=2,
                                   grid_cols=2, n_replicas=4)
    assert (mencius_model(n_leaders=3).peak_throughput(ALPHA)
            > comp.peak_throughput(ALPHA))


def test_skip_storm_raises_chosen_path_demand():
    """Noop fills traverse proxy -> grid -> replicas: every chosen-path
    station's write demand must rise with skip_fraction, amortized by the
    range batching factor."""
    clean = mencius_model().demands()
    storm = mencius_model(skip_fraction=0.5, skip_batch=10.0).demands()
    for station in ("leader", "proxy", "acceptor", "replica"):
        assert storm[station] > clean[station]
    barely = mencius_model(skip_fraction=0.5, skip_batch=1000.0).demands()
    assert barely["proxy"] == pytest.approx(clean["proxy"], rel=0.01)
    with pytest.raises(ValueError):
        mencius_model(skip_fraction=1.0)


# ---------------------------------------------------------------------------
# Mixed-variant batched sweep: one call, scalar agreement
# ---------------------------------------------------------------------------


def mixed_spec() -> SweepSpec:
    return SweepSpec(
        variants=("multipaxos", "compartmentalized", "mencius", "spaxos",
                  "craq", "unreplicated"),
        n_proxy_leaders=(2, 10),
        grids=((3, 1), (2, 2)),
        n_replicas=(2, 4),
        n_leaders=(1, 3),
        n_disseminators=(2, 4),
        n_stabilizers=(3, 5),
        chain_nodes=(3, 5),
    )


def test_mixed_variant_sweep_matches_scalar_elementwise():
    spec = mixed_spec()
    compiled = compile_sweep(spec)
    assert len(compiled) == spec.size()
    variants = {c.get("variant", "compartmentalized")
                for c in compiled.configs}
    assert len(variants) >= 3
    for f_write in (1.0, 0.5):
        w = Workload(f_write=f_write)
        peaks = compiled.peak_throughput(ALPHA, w)
        bns = compiled.bottlenecks(w)
        for i, m in enumerate(compiled.models):
            assert peaks[i] == pytest.approx(
                m.peak_throughput(ALPHA, f_write=f_write), rel=1e-12)
            # the batched argmax and the scalar dict-max may break exact
            # demand ties differently; the saturating *demand* must agree
            scalar_bn, scalar_d = m.bottleneck(f_write)
            assert (bns[i] == scalar_bn
                    or m.demands(f_write)[bns[i]] == pytest.approx(scalar_d))


def test_mixed_variant_mva_one_call_matches_scalar():
    """Heterogeneous station sets (S-Paxos disseminators next to CRAQ
    chains next to MultiPaxos followers) pad into one demand tensor and
    one jitted MVA call must reproduce every scalar curve."""
    compiled = compile_sweep(mixed_spec())
    _, X, _ = compiled.mva(ALPHA, n_clients_max=32)
    assert X.shape == (len(compiled), 32)
    for i in range(0, len(compiled), 5):
        _, x_single, _ = mva_curve(compiled.models[i], ALPHA,
                                   n_clients_max=32)
        np.testing.assert_allclose(X[i], x_single, rtol=1e-6)


def test_model_for_roundtrips_variant_configs():
    compiled = compile_sweep(mixed_spec())
    for cfg, m in zip(compiled.configs, compiled.models):
        assert model_for(cfg).stations == m.stations


def test_station_vocabulary_covers_every_variant():
    for factory in (multipaxos_model, compartmentalized_model, mencius_model,
                    vanilla_mencius_model, spaxos_model, vanilla_spaxos_model,
                    craq_chain_model):
        for s in factory().stations:
            assert s.name in STATION_ORDER


# ---------------------------------------------------------------------------
# Variant transients + cross-variant autotune
# ---------------------------------------------------------------------------


def test_skip_storm_transient_dips_and_recovers():
    sched, bounds = mencius_skip_storm_schedule(
        ALPHA, n_leaders=3, skip_fraction=0.5, slow_factor=3.0,
        n_steps=4000, n_proxy_leaders=10, grid_rows=2, grid_cols=2,
        n_replicas=4)
    res = simulate_transient(sched, bounds, n_clients=32, seeds=4,
                             n_steps=4000)
    healthy, storm, healed = res.window_throughput(
        bounds, settle=0.4).mean(axis=1)[0]
    assert storm < 0.85 * healthy
    assert healed > 0.9 * healthy


def test_payload_ramp_transient_monotone_while_leader_flat():
    factors = (1.0, 3.0, 9.0)
    sched, bounds = spaxos_payload_ramp_schedule(
        ALPHA, payload_factors=factors, n_steps=3000,
        n_disseminators=4, n_stabilizers=5)
    res = simulate_transient(sched, bounds, n_clients=32, seeds=4,
                             n_steps=3000)
    wt = res.window_throughput(bounds, settle=0.4).mean(axis=1)[0]
    assert wt[0] > wt[1] > wt[2]
    leader_col = STATION_ORDER.index("leader")
    np.testing.assert_allclose(sched[:, 0, leader_col],
                               sched[0, 0, leader_col])


def test_autotune_variants_budget_and_winner():
    res = autotune_variants(budget=19, alpha=ALPHA, workload=Workload())
    assert set(res.per_variant) == {"compartmentalized", "mencius", "spaxos"}
    for choice in res.per_variant.values():
        assert choice.machines <= 19
        assert model_for(choice.config).stations == choice.model.stations
    assert res.winner.peak == max(c.peak for c in res.per_variant.values())
    # splitting sequencing across leaders wins the write-only budget race
    assert res.winner.variant == "mencius"
    assert (res.winner.peak
            > res.per_variant["compartmentalized"].peak * (1 - 1e-9))


# ---------------------------------------------------------------------------
# Multi-leader family: demand tables, new station slots, budget verdict
# ---------------------------------------------------------------------------


def test_bpaxos_demand_table_pins():
    from repro.core import bpaxos_model

    m = bpaxos_model(n_proposers=4, n_dep_nodes=5, n_replicas=3)
    d = m.demands()
    # (1 + 2d + n) / p with d=5, n=3: the sequencing work splits 1/p
    assert d["proposer"] == pytest.approx((1 + 10 + 3) / 4)
    # the dependency service inherits the leader's old 2 msgs/cmd floor
    assert d["dep_service"] == pytest.approx(2.0)
    assert d["replica"] == pytest.approx(1 + 1 / 3)
    # no leaderless reads: the read column equals the write column
    assert m.demands(Workload.read_mix(1.0)) == pytest.approx(d)


def test_iss_demand_table_pins():
    from repro.core import iss_model

    # default forwarding fraction (L-1)/L, no rotations
    m = iss_model(n_leaders=4, n_proxy_leaders=5, grid_rows=2, grid_cols=2,
                  n_replicas=4)
    d = m.demands()
    assert d["leader"] == pytest.approx((2 + 2 * (3 / 4)) / 4)
    assert d["acceptor"] == pytest.approx(2 / 2)
    # a single leader never forwards or rotates: exactly the
    # compartmentalized leader's 2 msgs/cmd
    solo = iss_model(n_leaders=1).demands()
    assert solo["leader"] == pytest.approx(2.0)
    # measured-feedback knobs price the handoff broadcasts explicitly
    rot = iss_model(n_leaders=4, forward_fraction=0.5,
                    rotations_per_cmd=0.25).demands()
    assert rot["leader"] == pytest.approx((2 + 1.0 + 2 * 3 * 0.25) / 4)


def test_multileader_station_slots_appended():
    # the registry appended two brand-new slots; classic names keep
    # their columns (append-only vocabulary)
    assert "proposer" in STATION_ORDER and "dep_service" in STATION_ORDER
    assert STATION_ORDER.index("proposer") > STATION_ORDER.index("tail")


def test_bpaxos_rejects_non_intersecting_dep_quorums():
    from repro.core import BPaxosDeployment, bpaxos_model

    with pytest.raises(ValueError, match="2f\\+1"):
        bpaxos_model(n_dep_nodes=2, f=1)
    with pytest.raises(ValueError, match="2f\\+1"):
        BPaxosDeployment(n_dep_nodes=2, f=1)


def test_multileader_mixed_sweep_matches_scalar():
    from repro.core import bpaxos_model, iss_model

    sw = compile_sweep(SweepSpec(
        variants=("compartmentalized", "bpaxos", "iss"),
        knob_values=(("n_proposers", (2, 4)),)))
    assert {c.get("variant", "compartmentalized") for c in sw.configs} == {
        "compartmentalized", "bpaxos", "iss"}
    peaks = sw.peak_throughput(ALPHA, Workload())
    for i, cfg in enumerate(sw.configs):
        v = cfg.get("variant", "compartmentalized")
        if v == "bpaxos":
            scalar = bpaxos_model(**{k: x for k, x in cfg.items()
                                     if k != "variant"})
        elif v == "iss":
            scalar = iss_model(**{k: x for k, x in cfg.items()
                                  if k != "variant"})
        else:
            continue
        assert peaks[i] == pytest.approx(ALPHA / max(
            scalar.demands().values()))


def test_autotune_budget30_with_multileader_contenders():
    """The acceptance run: both multi-leader variants compete at a 30+
    machine budget and a winner is reported."""
    contenders = ("compartmentalized", "mencius", "spaxos", "bpaxos", "iss")
    res = autotune_variants(budget=30, alpha=ALPHA, workload=Workload(),
                            variants=contenders)
    assert set(res.per_variant) == set(contenders)
    for choice in res.per_variant.values():
        assert choice.machines <= 30
    assert res.winner.peak == max(c.peak for c in res.per_variant.values())
    # the thrifty knob lifts bpaxos off its broadcast dependency-service
    # floor (2 msgs/cmd = alpha/2, the single-leader ceiling it
    # replaced): unicasting to a rotating quorum q = d//2 + 1 of d = 3
    # dep nodes costs 2q/d = 4/3 msgs/cmd, so the autotuner finds
    # 3*alpha/4 - proposer and dep service plateau at the same floor
    best = res.per_variant["bpaxos"]
    assert best.config.get("thrifty") is True
    assert best.peak == pytest.approx(3 * ALPHA / 4)
    assert best.bottleneck in ("dep_service", "proposer")
    # bucket rotation reaches the replica bound and ties mencius
    assert res.per_variant["iss"].peak == pytest.approx(
        res.per_variant["mencius"].peak)
