"""The pluggable variant registry + workload-first evaluation API
(``repro.core.api``).

The acceptance-critical claim: a protocol variant registered **at
runtime** - with its own knob space, demand table and even a brand-new
station name - sweeps (``SweepSpec.variants``), budget-autotunes
(``autotune_variants``) and transient-simulates with ZERO edits to
``sweep.py`` / ``analytical.py`` / ``autotune.py``.  Plus: arithmetic
``SweepSpec.size()``, the legacy ``f_write=`` deprecation shims, the
per-variant minimums in ``autotune_variants``'s empty-feasible error, and
``CompiledSweep.subset`` / ``top_k`` edge paths on mixed-variant sweeps.
"""
import warnings

import numpy as np
import pytest

from repro.core import (
    STATION_ORDER,
    VARIANT_MODELS,
    DeploymentModel,
    Station,
    SweepSpec,
    Workload,
    autotune,
    autotune_variants,
    bottleneck_trace,
    calibrate_alpha,
    compile_models,
    compile_sweep,
    knob,
    mencius_skip_storm_schedule,
    model_for,
    register_variant,
    registered_variants,
    transient_throughput,
    unregister_variant,
    variant_spec,
)
from repro.core.analytical import multipaxos_model

ALPHA = calibrate_alpha()


# ---------------------------------------------------------------------------
# A demo variant: scaled-read Raft, registered at runtime
# ---------------------------------------------------------------------------


def scaled_read_raft_model(f: int = 1, n_followers: int = 4,
                           n_read_replicas: int = 2) -> DeploymentModel:
    """Raft with the read path compartmentalized onto dedicated read
    replicas (a new ``read_replica`` station the built-in vocabulary has
    never seen): the leader replicates to ``n_followers`` and streams
    applied entries to the read replicas, which serve all reads."""
    n = n_followers
    quorum = f + 1
    leader_w = 2 + n + quorum + n_read_replicas  # client rt + append/acks + apply
    stations = (
        Station("leader", 1, float(leader_w), 0.0),
        Station("follower", n, 2.0, 0.0),
        Station("read_replica", n_read_replicas, 1.0, 2.0 / n_read_replicas),
    )
    return DeploymentModel(
        name=f"raft_scaled_read(f={f},n={n},rr={n_read_replicas})",
        stations=stations)


def _raft_candidates(budget: int, f: int):
    top = max(budget - 2, f + 1)
    return {"n_followers": tuple(range(f + 1, min(top, 6) + 1)),
            "n_read_replicas": tuple(range(1, min(top, 6) + 1))}


@pytest.fixture
def raft_variant():
    spec = register_variant(
        name="raft_scaled_read",
        factory=scaled_read_raft_model,
        stations=("leader", "follower", "read_replica"),
        knobs=(knob("n_followers", (2, 4)), knob("n_read_replicas", (1, 2))),
        candidate_knobs=_raft_candidates,
        description="runtime-registered demo variant",
    )
    yield spec
    unregister_variant("raft_scaled_read")


def test_runtime_variant_rides_the_whole_stack(raft_variant):
    """Registered at runtime -> appears in SweepSpec.variants sweeps, in
    autotune_variants, and runs .transient - no core-file edits."""
    assert "raft_scaled_read" in registered_variants()
    assert VARIANT_MODELS["raft_scaled_read"] is scaled_read_raft_model

    # sweeps: crossed with a built-in variant in one compiled grid
    spec = SweepSpec(variants=("compartmentalized", "raft_scaled_read"))
    compiled = compile_sweep(spec)
    assert spec.size() == len(compiled) == 1 + 4
    raft_rows = [i for i, c in enumerate(compiled.configs)
                 if c.get("variant") == "raft_scaled_read"]
    assert len(raft_rows) == 4
    peaks = compiled.peak_throughput(ALPHA, Workload(f_write=0.5))
    for i in raft_rows:
        scalar = model_for(compiled.configs[i]).peak_throughput(
            ALPHA, f_write=0.5)
        assert peaks[i] == pytest.approx(scalar, rel=1e-12)

    # the new station occupies a real, decodable slot
    bns = compiled.bottlenecks(Workload.read_mix(0.97))
    assert "read_replica" in {bns[i] for i in raft_rows}

    # budget search across variants, including the runtime one
    res = autotune_variants(budget=12, alpha=ALPHA, workload=Workload(),
                            variants=("compartmentalized",
                                      "raft_scaled_read"))
    assert "raft_scaled_read" in res.per_variant
    assert res.per_variant["raft_scaled_read"].machines <= 12

    # transient dynamics on the same compiled grid, one jitted call
    tr = compiled.transient(ALPHA, n_clients=16, workload=Workload(),
                            n_steps=600, seeds=2)
    assert tr.throughput.shape == (len(compiled), 2)
    assert np.all(tr.seed_mean_throughput() > 0)


def test_runtime_variant_station_allocation_is_append_only(raft_variant):
    base = ("batcher", "leader", "proxy", "acceptor", "replica", "unbatcher",
            "server", "follower", "disseminator", "stabilizer", "head",
            "chain", "tail")
    assert tuple(STATION_ORDER)[:len(base)] == base
    assert "read_replica" in STATION_ORDER
    assert STATION_ORDER.index("read_replica") >= len(base)
    # unregistering must NOT reclaim the slot (column indices are
    # load-bearing for compiled sweeps) - pinned by the fixture teardown
    # plus this re-check in a later test run of the same session


def test_factory_emitting_undeclared_station_is_diagnosed():
    """A factory whose model emits a station with no registered column
    must fail with a ValueError naming the variant and the remedy, not a
    bare KeyError deep in demand_slots."""
    def bad_model():
        return DeploymentModel(name="bad",
                               stations=(Station("warp_core", 1, 1.0),))
    register_variant(name="bad_stations", factory=bad_model,
                     stations=("leader",), takes_f=False)
    try:
        with pytest.raises(ValueError, match="warp_core.*stations="):
            compile_sweep(SweepSpec(variants=("bad_stations",)))
    finally:
        unregister_variant("bad_stations")


def test_autotune_reports_workload_adapted_model():
    """Under a demand-shaping workload the reported model/bottleneck must
    be the *adapted* one the peak was ranked by (an unadapted CRAQ chain
    under heavy skew names the head; the adapted one names the tail)."""
    w = Workload(f_write=0.05, skew_p=0.9, dirty_fraction=1.0)
    res = autotune_variants(budget=7, alpha=ALPHA, workload=w,
                            variants=("craq",))
    choice = res.per_variant["craq"]
    assert choice.bottleneck == choice.model.bottleneck(w)[0]
    assert choice.peak == pytest.approx(
        choice.model.peak_throughput(ALPHA, w))
    assert choice.bottleneck == "tail"  # skewed dirty reads forward here


def test_adapter_noop_keeps_precompiled_rows():
    """A skew-only workload must leave batched (adapter-bearing but
    unaffected) rows exactly equal to the precompiled blend."""
    compiled = compile_sweep(SweepSpec(batch_sizes=(100,), n_batchers=(2,),
                                       n_unbatchers=(3,)))
    plain = compiled.demands(Workload(f_write=0.5))
    skewed = compiled.demands(Workload(f_write=0.5, skew_p=0.9))
    np.testing.assert_array_equal(plain, skewed)


def test_station_order_index_honors_bounds():
    assert STATION_ORDER.index("leader") == 1
    with pytest.raises(ValueError):
        STATION_ORDER.index("leader", 2)


def test_register_variant_validates():
    with pytest.raises(ValueError, match="already registered"):
        register_variant(name="mencius", factory=scaled_read_raft_model,
                         stations=("leader",))
    with pytest.raises(ValueError, match="no stations"):
        register_variant(name="empty_variant",
                         factory=scaled_read_raft_model, stations=())
    with pytest.raises(ValueError, match="reserved"):
        knob("variants", (1, 2))
    with pytest.raises(ValueError, match="not registered"):
        unregister_variant("never_registered")
    with pytest.raises(ValueError, match="unknown variant"):
        list(SweepSpec(variants=("no_such_protocol",)).configs())


def test_knob_values_override_runtime_knobs(raft_variant):
    spec = SweepSpec(variants=("raft_scaled_read",),
                     knob_values=(("n_followers", (2, 3, 4, 5)),
                                  ("n_read_replicas", (1,))))
    cfgs = list(spec.configs())
    assert spec.size() == len(cfgs) == 4
    assert [c["n_followers"] for c in cfgs] == [2, 3, 4, 5]
    assert all(c["n_read_replicas"] == 1 for c in cfgs)
    with pytest.raises(ValueError, match="no knob"):
        list(variant_spec("raft_scaled_read").configs(
            overrides={"n_wizards": (1,)}))


# ---------------------------------------------------------------------------
# SweepSpec.size(): arithmetic, not enumeration
# ---------------------------------------------------------------------------


def test_size_is_arithmetic_and_matches_enumeration():
    spec = SweepSpec(
        variants=("multipaxos", "compartmentalized", "mencius", "spaxos",
                  "craq", "unreplicated"),
        n_proxy_leaders=(1, 2, 5, 10),
        grids=((3, 1), (2, 2), (3, 3)),
        n_replicas=(2, 4, 6),
        batch_sizes=(1, 100),
        n_batchers=(0, 2),
        n_leaders=(1, 2, 3),
        n_disseminators=(2, 4),
        n_stabilizers=(3,),
        chain_nodes=(2, 3, 5),
    )
    enumerated = sum(1 for _ in spec.configs())
    assert spec.size() == enumerated
    # the arithmetic: mp(1) + comp(4*3*3*2*2*1) + mencius(3*4*3*3)
    #                + spaxos(2*1*4*3*3) + craq(3) + unreplicated(1)
    assert spec.size() == 1 + 144 + 108 + 72 + 3 + 1


# ---------------------------------------------------------------------------
# Legacy f_write= kwargs: shimmed, warning, value-identical
# ---------------------------------------------------------------------------


def _deprecated(fn, *args, **kwargs):
    with pytest.warns(DeprecationWarning, match="f_write"):
        return fn(*args, **kwargs)


def test_legacy_f_write_kwargs_warn_and_agree():
    compiled = compile_sweep(SweepSpec(n_proxy_leaders=(2, 10),
                                       n_replicas=(2, 4)))
    w = Workload(f_write=0.3)
    np.testing.assert_allclose(
        _deprecated(compiled.peak_throughput, ALPHA, f_write=0.3),
        compiled.peak_throughput(ALPHA, w))
    np.testing.assert_allclose(
        _deprecated(compiled.demands, f_write=0.3), compiled.demands(w))
    assert (_deprecated(compiled.bottlenecks, f_write=0.3)
            == compiled.bottlenecks(w))
    _, x_old, _ = _deprecated(compiled.mva, ALPHA, 16, f_write=0.3)
    _, x_new, _ = compiled.mva(ALPHA, 16, w)
    np.testing.assert_allclose(x_old, x_new)
    assert (_deprecated(compiled.top_k, ALPHA, k=2, f_write=0.3)
            == compiled.top_k(ALPHA, k=2, workload=w))

    old = _deprecated(autotune, budget=12, alpha=ALPHA, f_write=0.3)
    new = autotune(budget=12, alpha=ALPHA, workload=w)
    assert old.best_config == new.best_config
    assert old.best_peak == new.best_peak

    old_v = _deprecated(autotune_variants, budget=19, alpha=ALPHA,
                        f_write=0.3)
    assert old_v.winner.config == autotune_variants(
        budget=19, alpha=ALPHA, workload=w).winner.config

    old_t = _deprecated(bottleneck_trace, budget=12, alpha=ALPHA,
                        f_write=0.3)
    assert [t.peak for t in old_t] == [
        t.peak for t in bottleneck_trace(budget=12, alpha=ALPHA, workload=w)]

    sched_old, _ = _deprecated(mencius_skip_storm_schedule, ALPHA,
                               n_steps=100, f_write=0.3)
    sched_new, _ = mencius_skip_storm_schedule(ALPHA, n_steps=100,
                                               workload=w)
    np.testing.assert_allclose(sched_old, sched_new)


def test_bare_float_workload_warns():
    compiled = compile_models([multipaxos_model()])
    with pytest.warns(DeprecationWarning, match="scalar"):
        peaks = compiled.peak_throughput(ALPHA, 0.5)
    np.testing.assert_allclose(
        peaks, compiled.peak_throughput(ALPHA, Workload(f_write=0.5)))


def test_workload_and_f_write_together_is_an_error():
    compiled = compile_models([multipaxos_model()])
    with pytest.raises(TypeError, match="not both"):
        compiled.peak_throughput(ALPHA, Workload(), f_write=0.5)


def test_workload_validation():
    with pytest.raises(ValueError, match="f_write"):
        Workload(f_write=1.5)
    with pytest.raises(ValueError, match="arrival"):
        Workload(arrival="chaotic")
    with pytest.raises(ValueError, match="burst_fraction"):
        Workload(burst_fraction=1.0)
    assert Workload.read_mix(0.9).f_write == pytest.approx(0.1)
    assert "90% reads" in Workload.read_mix(0.9).describe()


def test_transient_throughput_shim():
    with pytest.warns(DeprecationWarning, match="f_write"):
        res = transient_throughput(multipaxos_model(), ALPHA, n_clients=8,
                                   f_write=0.5, n_steps=400, seeds=2)
    assert res.throughput.shape == (1, 2)


# ---------------------------------------------------------------------------
# autotune_variants: empty-feasible error names per-variant minimums
# ---------------------------------------------------------------------------


def test_autotune_variants_empty_budget_names_per_variant_minimums():
    with pytest.raises(ValueError) as exc:
        autotune_variants(budget=5, alpha=ALPHA, workload=Workload())
    msg = str(exc.value)
    assert "per-variant minimum machines" in msg
    for variant in ("compartmentalized", "mencius", "spaxos"):
        assert f"{variant} needs >= " in msg
    # the quoted minimums are real: one machine more than the smallest
    # quoted requirement must make at least that variant feasible
    smallest = min(int(part.split(">= ")[1])
                   for part in msg.split("(")[1].rstrip(")").split(", "))
    res = autotune_variants(budget=smallest, alpha=ALPHA, workload=Workload())
    assert res.winner.machines <= smallest


# ---------------------------------------------------------------------------
# CompiledSweep.subset + top_k on mixed-variant sweeps
# ---------------------------------------------------------------------------


def mixed_compiled():
    return compile_sweep(SweepSpec(
        variants=("multipaxos", "compartmentalized", "mencius", "craq"),
        n_proxy_leaders=(10, 11),
        grids=((2, 2),),
        n_replicas=(4,),
        n_leaders=(3,),
        chain_nodes=(3, 5),
    ))


def test_subset_round_trips_configs_and_tensors():
    compiled = mixed_compiled()
    idx = [len(compiled) - 1, 0, 2]
    sub = compiled.subset(idx)
    assert len(sub) == 3
    for j, i in enumerate(idx):
        assert sub.configs[j] == compiled.configs[i]
        assert sub.models[j] is compiled.models[i]
        assert sub.machines[j] == compiled.machines[i]
        np.testing.assert_array_equal(sub.demand_write[j],
                                      compiled.demand_write[i])
    # evaluation on the subset matches the parent rows elementwise
    np.testing.assert_allclose(
        sub.peak_throughput(ALPHA, Workload(f_write=0.5)),
        compiled.peak_throughput(ALPHA, Workload(f_write=0.5))[idx])


def test_subset_without_configs_keeps_configs_none():
    compiled = compile_models([multipaxos_model(),
                               model_for(dict(variant="craq", n_nodes=3))])
    assert compiled.configs is None
    sub = compiled.subset([1])
    assert sub.configs is None
    assert len(sub) == 1


def test_top_k_budget_masks_expensive_configs():
    compiled = mixed_compiled()
    unbounded = compiled.top_k(ALPHA, k=len(compiled), workload=Workload())
    assert len(unbounded) == len(compiled)  # every config has a finite peak
    budget = 10
    bounded = compiled.top_k(ALPHA, k=len(compiled), workload=Workload(),
                             budget=budget)
    assert bounded  # craq(3)/multipaxos fit
    assert all(compiled.machines[i] <= budget for i, _, _ in bounded)
    assert len(bounded) < len(unbounded)


def test_top_k_ties_break_toward_fewer_machines():
    compiled = mixed_compiled()
    # p=10 and p=11 compartmentalized rows are both leader-bound at
    # f_write=1: identical peak, 19 vs 20 machines
    rows = {c.get("n_proxy_leaders"): i
            for i, c in enumerate(compiled.configs)
            if c.get("variant") is None}
    peaks = compiled.peak_throughput(ALPHA, Workload())
    assert peaks[rows[10]] == pytest.approx(peaks[rows[11]])
    ranked = compiled.top_k(ALPHA, k=len(compiled), workload=Workload())
    pos = {i: rank for rank, (i, _, _) in enumerate(ranked)}
    assert pos[rows[10]] < pos[rows[11]]
