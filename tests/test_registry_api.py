"""The pluggable variant registry + workload-first evaluation API
(``repro.core.api``).

The acceptance-critical claim: a protocol variant registered **at
runtime** - with its own knob space, demand table, even a brand-new
station name, and its own *execution plane* (a real cluster on the
deterministic network) - sweeps (``SweepSpec.variants``), budget-autotunes
(``autotune_variants``), transient-simulates, **executes**
(``run_variant``), **parity-checks** (``validate_variant``) and
**linearizability-checks** with ZERO edits to ``sweep.py`` /
``analytical.py`` / ``autotune.py`` / ``execution.py``.  Plus: arithmetic
``SweepSpec.size()``, the legacy ``f_write=`` deprecation shims, the
per-variant minimums in ``autotune_variants``'s empty-feasible error, and
``CompiledSweep.subset`` / ``top_k`` edge paths on mixed-variant sweeps.
"""
import warnings

import numpy as np
import pytest

from repro.core import (
    STATION_ORDER,
    VARIANT_MODELS,
    DeploymentModel,
    ExecutableSpec,
    History,
    Network,
    Node,
    Station,
    SweepSpec,
    Workload,
    autotune,
    autotune_variants,
    bottleneck_trace,
    calibrate_alpha,
    compile_models,
    compile_sweep,
    executable_variants,
    knob,
    mencius_skip_storm_schedule,
    model_for,
    register_variant,
    registered_variants,
    run_variant,
    temporary_variants,
    transient_throughput,
    unregister_variant,
    validate_variant,
    variant_spec,
)
from repro.core.analytical import multipaxos_model
from repro.core.messages import (
    Chosen,
    ClientReply,
    ClientRequest,
    Phase2a,
    Phase2b,
    ReadReply,
    ReplicaRead,
)
from repro.core.protocols import BaseDeployment
from repro.core.quorums import MajorityQuorums
from repro.core.roles import Client
from repro.core.statemachine import make_state_machine

ALPHA = calibrate_alpha()


# ---------------------------------------------------------------------------
# A demo variant: scaled-read Raft, registered at runtime on BOTH planes
# ---------------------------------------------------------------------------


def scaled_read_raft_model(f: int = 1, n_followers: int = 4,
                           n_read_replicas: int = 2) -> DeploymentModel:
    """Raft with the read path compartmentalized onto dedicated read
    replicas (a new ``read_replica`` station the built-in vocabulary has
    never seen): the leader replicates to ``n_followers`` (every follower
    acks) and streams committed entries to the read replicas, which serve
    all reads."""
    n = n_followers
    # client rt (2) + append out / acks in (2n) + commit stream to rrs
    leader_w = 2 + 2 * n + n_read_replicas
    stations = (
        Station("leader", 1, float(leader_w), 0.0),
        Station("follower", n, 2.0, 0.0),
        Station("read_replica", n_read_replicas, 1.0, 2.0 / n_read_replicas),
    )
    return DeploymentModel(
        name=f"raft_scaled_read(f={f},n={n},rr={n_read_replicas})",
        stations=stations)


class _RaftLeader(Node):
    """Orders writes over its followers; streams commits to read replicas."""

    def __init__(self, addr, followers, read_replicas, quorum, sm):
        super().__init__(addr)
        self.followers = list(followers)
        self.read_replicas = list(read_replicas)
        self.quorum = quorum
        self.sm = sm
        self.next_slot = 0
        self.commit_upto = -1
        self.entries = {}
        self.acks = {}

    def on_message(self, src, msg):
        if isinstance(msg, ClientRequest):
            slot = self.next_slot
            self.next_slot += 1
            self.entries[slot] = msg.command
            self.acks[slot] = set()
            for follower in self.followers:
                self.send(follower, Phase2a(slot=slot, ballot=0,
                                            value=msg.command))
        elif isinstance(msg, Phase2b):
            acks = self.acks.get(msg.slot)
            if acks is None:
                return
            acks.add(msg.acceptor_id)
            while len(self.acks.get(self.commit_upto + 1, ())) >= self.quorum:
                slot = self.commit_upto + 1
                self.commit_upto = slot
                del self.acks[slot]
                cmd = self.entries[slot]
                result = self.sm.apply_checked(cmd.op)
                self.send(f"client/{cmd.client_id}",
                          ClientReply(command_uid=cmd.uid, result=result,
                                      slot=slot))
                for rr in self.read_replicas:
                    self.send(rr, Chosen(slot=slot, value=cmd))


class _RaftFollower(Node):
    def __init__(self, addr, index):
        super().__init__(addr)
        self.index = index
        self.log = {}

    def on_message(self, src, msg):
        if isinstance(msg, Phase2a):
            self.log[msg.slot] = msg.value
            self.send(src, Phase2b(slot=msg.slot, ballot=msg.ballot,
                                   acceptor_id=self.index))


class _RaftReadReplica(Node):
    """Applies the commit stream in prefix order; serves watermarked reads
    directly back to the client."""

    def __init__(self, addr, sm):
        super().__init__(addr)
        self.sm = sm
        self.log = {}
        self.executed_upto = -1
        self.pending = []

    def _serve(self, src, msg):
        result = self.sm.apply_checked(msg.command.op)
        self.send(src, ReadReply(command_uid=msg.command.uid, result=result,
                                 executed_slot=self.executed_upto))

    def on_message(self, src, msg):
        if isinstance(msg, Chosen):
            if msg.slot not in self.log:
                self.log[msg.slot] = msg.value
                while (self.executed_upto + 1) in self.log:
                    self.executed_upto += 1
                    self.sm.apply_checked(self.log[self.executed_upto].op)
                still = []
                for wm, rsrc, rmsg in self.pending:
                    if self.executed_upto >= wm:
                        self._serve(rsrc, rmsg)
                    else:
                        still.append((wm, rsrc, rmsg))
                self.pending = still
        elif isinstance(msg, ReplicaRead):
            if self.executed_upto >= msg.watermark:
                self._serve(src, msg)
            else:
                self.pending.append((msg.watermark, src, msg))


class ScaledReadRaftDeployment(BaseDeployment):
    """The demo variant's execution plane: leader + followers + read
    replicas on the deterministic network, driven by the stock closed-loop
    ``Client`` (writes to the leader; reads watermarked to a replica)."""

    def __init__(self, f=1, n_followers=4, n_read_replicas=2, n_clients=2,
                 seed=0, state_machine="kv"):
        self.net = Network(seed=seed)
        self.history = History()
        follower_addrs = [f"follower/{i}" for i in range(n_followers)]
        rr_addrs = [f"read_replica/{i}" for i in range(n_read_replicas)]
        self.leader = _RaftLeader("leader/0", follower_addrs, rr_addrs,
                                  quorum=f + 1,
                                  sm=make_state_machine(state_machine))
        self.followers = [_RaftFollower(a, i)
                          for i, a in enumerate(follower_addrs)]
        self.read_replicas = [
            _RaftReadReplica(a, make_state_machine(state_machine))
            for a in rr_addrs
        ]
        self.clients = [
            Client(f"client/{i}", i, "leader/0", [], MajorityQuorums(f=0),
                   rr_addrs, consistency="sequential", history=self.history,
                   seed=seed)
            for i in range(n_clients)
        ]
        self.net.add_node(self.leader)
        self.net.add_nodes(self.followers)
        self.net.add_nodes(self.read_replicas)
        self.net.add_nodes(self.clients)


def _raft_candidates(budget: int, f: int):
    top = max(budget - 2, f + 1)
    return {"n_followers": tuple(range(f + 1, min(top, 6) + 1)),
            "n_read_replicas": tuple(range(1, min(top, 6) + 1))}


@pytest.fixture
def raft_variant():
    with temporary_variants():
        spec = register_variant(
            name="raft_scaled_read",
            factory=scaled_read_raft_model,
            stations=("leader", "follower", "read_replica"),
            knobs=(knob("n_followers", (2, 4)),
                   knob("n_read_replicas", (1, 2))),
            candidate_knobs=_raft_candidates,
            executable=ExecutableSpec(
                deployment=ScaledReadRaftDeployment,
                rel_tolerance=0.05,
                exact_stations=("leader", "follower"),
                n_clients=2,
            ),
            description="runtime-registered demo variant (both planes)",
        )
        yield spec


def test_runtime_variant_rides_the_whole_stack(raft_variant):
    """Registered at runtime -> appears in SweepSpec.variants sweeps, in
    autotune_variants, and runs .transient - no core-file edits."""
    assert "raft_scaled_read" in registered_variants()
    assert "raft_scaled_read" in executable_variants()
    assert VARIANT_MODELS["raft_scaled_read"] is scaled_read_raft_model

    # sweeps: crossed with a built-in variant in one compiled grid
    spec = SweepSpec(variants=("compartmentalized", "raft_scaled_read"))
    compiled = compile_sweep(spec)
    assert spec.size() == len(compiled) == 1 + 4
    raft_rows = [i for i, c in enumerate(compiled.configs)
                 if c.get("variant") == "raft_scaled_read"]
    assert len(raft_rows) == 4
    peaks = compiled.peak_throughput(ALPHA, Workload(f_write=0.5))
    for i in raft_rows:
        scalar = model_for(compiled.configs[i]).peak_throughput(
            ALPHA, f_write=0.5)
        assert peaks[i] == pytest.approx(scalar, rel=1e-12)

    # the new station occupies a real, decodable slot
    bns = compiled.bottlenecks(Workload.read_mix(0.97))
    assert "read_replica" in {bns[i] for i in raft_rows}

    # budget search across variants, including the runtime one
    res = autotune_variants(budget=12, alpha=ALPHA, workload=Workload(),
                            variants=("compartmentalized",
                                      "raft_scaled_read"))
    assert "raft_scaled_read" in res.per_variant
    assert res.per_variant["raft_scaled_read"].machines <= 12

    # transient dynamics on the same compiled grid, one jitted call
    tr = compiled.transient(ALPHA, n_clients=16, workload=Workload(),
                            n_steps=600, seeds=2)
    assert tr.throughput.shape == (len(compiled), 2)
    assert np.all(tr.seed_mean_throughput() > 0)


def test_runtime_variant_executes_with_parity_and_linearizability(
        raft_variant):
    """The acceptance claim end to end: the runtime-registered variant's
    OWN cluster executes through the generic harness - measured msgs/cmd
    bucketed into canonical slots, analytical-vs-measured parity, and a
    linearizable history - with zero edits to execution.py."""
    # small run: ground-truth exhaustive linearizability check
    trace = run_variant("raft_scaled_read", n_commands=12, seed=3,
                        workload=Workload(f_write=0.5))
    assert trace.linearizable and trace.checker == "exhaustive"
    # the brand-new station is measured into its own registry column
    slots = trace.demand_slots()
    assert slots[STATION_ORDER.index("read_replica")] > 0

    # parity: the deployment was written to match the table message for
    # message, so leader/follower are exact and the rest within 5%
    report = validate_variant("raft_scaled_read",
                              workload=Workload(f_write=0.5),
                              n_commands=40, seed=0)
    assert report.passed, str(report)
    n, rr = 2, 1  # the default config: first point of the knob product
    assert report.config == dict(variant="raft_scaled_read", f=1,
                                 n_followers=n, n_read_replicas=rr)
    # blended at the realized 50/50 mix: reads never touch the leader
    assert report.row("leader").measured == pytest.approx(
        0.5 * (2 + 2 * n + rr), abs=1e-9)
    assert report.row("follower").measured == pytest.approx(0.5 * 2.0,
                                                            abs=1e-9)

    # a non-default config from the variant's own knob space
    cfg = dict(variant="raft_scaled_read", f=1, n_followers=4,
               n_read_replicas=2)
    report2 = validate_variant("raft_scaled_read", config=cfg,
                               workload=Workload(), n_commands=30, seed=1)
    assert report2.passed, str(report2)
    assert report2.row("leader").measured == pytest.approx(2 + 2 * 4 + 2,
                                                           abs=1e-9)


def test_temporary_variants_scope_restores_registry():
    before = registered_variants()
    before_exec = executable_variants()
    with temporary_variants():
        register_variant(name="ephemeral_proto",
                         factory=scaled_read_raft_model,
                         stations=("leader", "follower", "read_replica"))
        assert "ephemeral_proto" in registered_variants()
    assert registered_variants() == before
    assert executable_variants() == before_exec
    # station slots allocated inside the scope stay allocated (append-only
    # vocabulary: compiled tensors address columns by index)
    assert "read_replica" in STATION_ORDER


def test_runtime_variant_station_allocation_is_append_only(raft_variant):
    base = ("batcher", "leader", "proxy", "acceptor", "replica", "unbatcher",
            "server", "follower", "disseminator", "stabilizer", "head",
            "chain", "tail")
    assert tuple(STATION_ORDER)[:len(base)] == base
    assert "read_replica" in STATION_ORDER
    assert STATION_ORDER.index("read_replica") >= len(base)
    # unregistering must NOT reclaim the slot (column indices are
    # load-bearing for compiled sweeps) - pinned by the fixture teardown
    # plus this re-check in a later test run of the same session


def test_factory_emitting_undeclared_station_is_diagnosed():
    """A factory whose model emits a station with no registered column
    must fail with a ValueError naming the variant and the remedy, not a
    bare KeyError deep in demand_slots."""
    def bad_model():
        return DeploymentModel(name="bad",
                               stations=(Station("warp_core", 1, 1.0),))
    with temporary_variants():
        register_variant(name="bad_stations", factory=bad_model,
                         stations=("leader",), takes_f=False)
        with pytest.raises(ValueError, match="warp_core.*stations="):
            compile_sweep(SweepSpec(variants=("bad_stations",)))
    assert "bad_stations" not in registered_variants()


def test_autotune_reports_workload_adapted_model():
    """Under a demand-shaping workload the reported model/bottleneck must
    be the *adapted* one the peak was ranked by (an unadapted CRAQ chain
    under heavy skew names the head; the adapted one names the tail)."""
    w = Workload(f_write=0.05, skew_p=0.9, dirty_fraction=1.0)
    res = autotune_variants(budget=7, alpha=ALPHA, workload=w,
                            variants=("craq",))
    choice = res.per_variant["craq"]
    assert choice.bottleneck == choice.model.bottleneck(w)[0]
    assert choice.peak == pytest.approx(
        choice.model.peak_throughput(ALPHA, w))
    assert choice.bottleneck == "tail"  # skewed dirty reads forward here


def test_adapter_noop_keeps_precompiled_rows():
    """A skew-only workload must leave batched (adapter-bearing but
    unaffected) rows exactly equal to the precompiled blend."""
    compiled = compile_sweep(SweepSpec(batch_sizes=(100,), n_batchers=(2,),
                                       n_unbatchers=(3,)))
    plain = compiled.demands(Workload(f_write=0.5))
    skewed = compiled.demands(Workload(f_write=0.5, skew_p=0.9))
    np.testing.assert_array_equal(plain, skewed)


def test_station_order_index_honors_bounds():
    assert STATION_ORDER.index("leader") == 1
    with pytest.raises(ValueError):
        STATION_ORDER.index("leader", 2)


def test_register_variant_validates():
    with pytest.raises(ValueError, match="already registered"):
        register_variant(name="mencius", factory=scaled_read_raft_model,
                         stations=("leader",))
    with pytest.raises(ValueError, match="no stations"):
        register_variant(name="empty_variant",
                         factory=scaled_read_raft_model, stations=())
    with pytest.raises(ValueError, match="reserved"):
        knob("variants", (1, 2))
    with pytest.raises(ValueError, match="not registered"):
        unregister_variant("never_registered")
    with pytest.raises(ValueError, match="unknown variant"):
        list(SweepSpec(variants=("no_such_protocol",)).configs())


def test_knob_values_override_runtime_knobs(raft_variant):
    spec = SweepSpec(variants=("raft_scaled_read",),
                     knob_values=(("n_followers", (2, 3, 4, 5)),
                                  ("n_read_replicas", (1,))))
    cfgs = list(spec.configs())
    assert spec.size() == len(cfgs) == 4
    assert [c["n_followers"] for c in cfgs] == [2, 3, 4, 5]
    assert all(c["n_read_replicas"] == 1 for c in cfgs)
    with pytest.raises(ValueError, match="no knob"):
        list(variant_spec("raft_scaled_read").configs(
            overrides={"n_wizards": (1,)}))


# ---------------------------------------------------------------------------
# SweepSpec.size(): arithmetic, not enumeration
# ---------------------------------------------------------------------------


def test_size_is_arithmetic_and_matches_enumeration():
    spec = SweepSpec(
        variants=("multipaxos", "compartmentalized", "mencius", "spaxos",
                  "craq", "unreplicated"),
        n_proxy_leaders=(1, 2, 5, 10),
        grids=((3, 1), (2, 2), (3, 3)),
        n_replicas=(2, 4, 6),
        batch_sizes=(1, 100),
        n_batchers=(0, 2),
        n_leaders=(1, 2, 3),
        n_disseminators=(2, 4),
        n_stabilizers=(3,),
        chain_nodes=(2, 3, 5),
    )
    enumerated = sum(1 for _ in spec.configs())
    assert spec.size() == enumerated
    # the arithmetic: mp(1) + comp(4*3*3*2*2*1) + mencius(3*4*3*3)
    #                + spaxos(2*1*4*3*3) + craq(3) + unreplicated(1)
    assert spec.size() == 1 + 144 + 108 + 72 + 3 + 1


# ---------------------------------------------------------------------------
# Legacy f_write= kwargs: shimmed, warning, value-identical
# ---------------------------------------------------------------------------


def _deprecated(fn, *args, **kwargs):
    with pytest.warns(DeprecationWarning, match="f_write"):
        return fn(*args, **kwargs)


def test_legacy_f_write_kwargs_warn_and_agree():
    compiled = compile_sweep(SweepSpec(n_proxy_leaders=(2, 10),
                                       n_replicas=(2, 4)))
    w = Workload(f_write=0.3)
    np.testing.assert_allclose(
        _deprecated(compiled.peak_throughput, ALPHA, f_write=0.3),
        compiled.peak_throughput(ALPHA, w))
    np.testing.assert_allclose(
        _deprecated(compiled.demands, f_write=0.3), compiled.demands(w))
    assert (_deprecated(compiled.bottlenecks, f_write=0.3)
            == compiled.bottlenecks(w))
    _, x_old, _ = _deprecated(compiled.mva, ALPHA, 16, f_write=0.3)
    _, x_new, _ = compiled.mva(ALPHA, 16, w)
    np.testing.assert_allclose(x_old, x_new)
    assert (_deprecated(compiled.top_k, ALPHA, k=2, f_write=0.3)
            == compiled.top_k(ALPHA, k=2, workload=w))

    old = _deprecated(autotune, budget=12, alpha=ALPHA, f_write=0.3)
    new = autotune(budget=12, alpha=ALPHA, workload=w)
    assert old.best_config == new.best_config
    assert old.best_peak == new.best_peak

    old_v = _deprecated(autotune_variants, budget=19, alpha=ALPHA,
                        f_write=0.3)
    assert old_v.winner.config == autotune_variants(
        budget=19, alpha=ALPHA, workload=w).winner.config

    old_t = _deprecated(bottleneck_trace, budget=12, alpha=ALPHA,
                        f_write=0.3)
    assert [t.peak for t in old_t] == [
        t.peak for t in bottleneck_trace(budget=12, alpha=ALPHA, workload=w)]

    sched_old, _ = _deprecated(mencius_skip_storm_schedule, ALPHA,
                               n_steps=100, f_write=0.3)
    sched_new, _ = mencius_skip_storm_schedule(ALPHA, n_steps=100,
                                               workload=w)
    np.testing.assert_allclose(sched_old, sched_new)


def test_bare_float_workload_warns():
    compiled = compile_models([multipaxos_model()])
    with pytest.warns(DeprecationWarning, match="scalar"):
        peaks = compiled.peak_throughput(ALPHA, 0.5)
    np.testing.assert_allclose(
        peaks, compiled.peak_throughput(ALPHA, Workload(f_write=0.5)))


def test_workload_and_f_write_together_is_an_error():
    compiled = compile_models([multipaxos_model()])
    with pytest.raises(TypeError, match="not both"):
        compiled.peak_throughput(ALPHA, Workload(), f_write=0.5)


def test_workload_validation():
    with pytest.raises(ValueError, match="f_write"):
        Workload(f_write=1.5)
    with pytest.raises(ValueError, match="arrival"):
        Workload(arrival="chaotic")
    with pytest.raises(ValueError, match="burst_fraction"):
        Workload(burst_fraction=1.0)
    assert Workload.read_mix(0.9).f_write == pytest.approx(0.1)
    assert "90% reads" in Workload.read_mix(0.9).describe()


def test_transient_throughput_shim():
    with pytest.warns(DeprecationWarning, match="f_write"):
        res = transient_throughput(multipaxos_model(), ALPHA, n_clients=8,
                                   f_write=0.5, n_steps=400, seeds=2)
    assert res.throughput.shape == (1, 2)


# ---------------------------------------------------------------------------
# autotune_variants: empty-feasible error names per-variant minimums
# ---------------------------------------------------------------------------


def test_autotune_variants_empty_budget_names_per_variant_minimums():
    with pytest.raises(ValueError) as exc:
        autotune_variants(budget=5, alpha=ALPHA, workload=Workload())
    msg = str(exc.value)
    assert "per-variant minimum machines" in msg
    for variant in ("compartmentalized", "mencius", "spaxos"):
        assert f"{variant} needs >= " in msg
    # the quoted minimums are real: one machine more than the smallest
    # quoted requirement must make at least that variant feasible
    smallest = min(int(part.split(">= ")[1])
                   for part in msg.split("(")[1].rstrip(")").split(", "))
    res = autotune_variants(budget=smallest, alpha=ALPHA, workload=Workload())
    assert res.winner.machines <= smallest


# ---------------------------------------------------------------------------
# CompiledSweep.subset + top_k on mixed-variant sweeps
# ---------------------------------------------------------------------------


def mixed_compiled():
    return compile_sweep(SweepSpec(
        variants=("multipaxos", "compartmentalized", "mencius", "craq"),
        n_proxy_leaders=(10, 11),
        grids=((2, 2),),
        n_replicas=(4,),
        n_leaders=(3,),
        chain_nodes=(3, 5),
    ))


def test_subset_round_trips_configs_and_tensors():
    compiled = mixed_compiled()
    idx = [len(compiled) - 1, 0, 2]
    sub = compiled.subset(idx)
    assert len(sub) == 3
    for j, i in enumerate(idx):
        assert sub.configs[j] == compiled.configs[i]
        assert sub.models[j] is compiled.models[i]
        assert sub.machines[j] == compiled.machines[i]
        np.testing.assert_array_equal(sub.demand_write[j],
                                      compiled.demand_write[i])
    # evaluation on the subset matches the parent rows elementwise
    np.testing.assert_allclose(
        sub.peak_throughput(ALPHA, Workload(f_write=0.5)),
        compiled.peak_throughput(ALPHA, Workload(f_write=0.5))[idx])


def test_subset_without_configs_keeps_configs_none():
    compiled = compile_models([multipaxos_model(),
                               model_for(dict(variant="craq", n_nodes=3))])
    assert compiled.configs is None
    sub = compiled.subset([1])
    assert sub.configs is None
    assert len(sub) == 1


def test_top_k_budget_masks_expensive_configs():
    compiled = mixed_compiled()
    unbounded = compiled.top_k(ALPHA, k=len(compiled), workload=Workload())
    assert len(unbounded) == len(compiled)  # every config has a finite peak
    budget = 10
    bounded = compiled.top_k(ALPHA, k=len(compiled), workload=Workload(),
                             budget=budget)
    assert bounded  # craq(3)/multipaxos fit
    assert all(compiled.machines[i] <= budget for i, _, _ in bounded)
    assert len(bounded) < len(unbounded)


def test_top_k_ties_break_toward_fewer_machines():
    compiled = mixed_compiled()
    # p=10 and p=11 compartmentalized rows are both leader-bound at
    # f_write=1: identical peak, 19 vs 20 machines
    rows = {c.get("n_proxy_leaders"): i
            for i, c in enumerate(compiled.configs)
            if c.get("variant") is None}
    peaks = compiled.peak_throughput(ALPHA, Workload())
    assert peaks[rows[10]] == pytest.approx(peaks[rows[11]])
    ranked = compiled.top_k(ALPHA, k=len(compiled), workload=Workload())
    pos = {i: rank for rank, (i, _, _) in enumerate(ranked)}
    assert pos[rows[10]] < pos[rows[11]]
