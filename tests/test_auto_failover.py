"""Heartbeat-driven automatic leader failover (no operator intervention)."""
import pytest

from repro.core.linearizability import check_linearizable
from repro.core.protocols import CompartmentalizedMultiPaxos, DeploymentConfig


def make(n_clients=1, seed=0):
    cfg = DeploymentConfig(f=1, n_proxy_leaders=3, grid=(2, 2), n_replicas=2,
                           state_machine="register", seed=seed,
                           client_retries=True, auto_failover=True)
    return CompartmentalizedMultiPaxos(cfg, n_clients=n_clients)


def test_heartbeats_flow_and_no_spurious_promotion():
    dep = make()
    dep.clients[0].run_ops([("w", 1), ("r",)])
    dep.net.run(until=1_000)  # bounded window: hb timers never quiesce
    assert dep.clients[0].results == ["ok", 1]
    # exactly one active leader after a healthy window
    assert [l.active for l in dep.leaders].count(True) == 1
    assert dep.leaders[0].active


def test_automatic_promotion_after_leader_crash():
    dep = make()
    dep.clients[0].run_ops([("w", 1)])
    dep.net.run(until=300)
    assert dep.clients[0].results == ["ok"]
    # crash the active leader; nobody calls fail_over()
    dep.net.crash("leader/0")
    dep.net.run(until=1_500)  # heartbeat timers drive the promotion
    assert dep.leaders[1].active, \
        "follower must self-promote after missed heartbeats"
    # new leader serves writes; previously chosen values survive
    dep.clients[0].leader = "leader/1"
    dep.clients[0].run_ops([("r",), ("w", 2), ("r",)])
    dep.net.run(until=3_500)
    assert dep.clients[0].results[-3:] == [1, "ok", 2]
    assert check_linearizable(dep.history, "register")


def test_old_leader_cannot_commit_after_takeover():
    """The promoted leader's higher ballot fences the old one (Paxos
    safety): a zombie leader's proposals are rejected by acceptors."""
    dep = make()
    dep.clients[0].run_ops([("w", 1)])
    dep.net.run(until=300)
    dep.net.crash("leader/0")
    dep.net.run(until=1_500)
    assert dep.leaders[1].active
    # resurrect the deposed leader as a ZOMBIE: a partitioned leader that
    # never learned about the takeover still believes it is active
    dep.net.recover("leader/0")
    old = dep.leaders[0]
    old.active = True  # partitioned-leader simulation
    ballots_new = dep.leaders[1].ballot
    assert ballots_new > old.ballot
    # the zombie proposes directly; acceptors must reject (no Phase2b at
    # its stale ballot => nothing new chosen in that slot at the old ballot)
    from repro.core.messages import Command, ClientRequest
    zombie_cmd = Command(client_id=99, client_seq=0, op=("w", 666))
    old.on_message("client/99", ClientRequest(command=zombie_cmd))
    dep.net.run(until=dep.net.now + 1_000)
    for replica in dep.replicas:
        for slot, value in replica.log.items():
            if getattr(value, "client_id", None) == 99:
                # if it did get chosen, it must have been re-proposed by the
                # NEW leader (ballot safety), never at the zombie's ballot
                raise AssertionError("zombie write committed")
