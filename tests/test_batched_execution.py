"""Cross-plane agreement: the batched executor vs the measured plane.

The batched execution plane promises that "measured" surfaces (one jitted
device call over a config x seed grid of closed-loop clients) agree with
the scalar measured plane (:func:`run_variant`'s real message-passing
cluster) - probe-calibrated, not copied: the probes run at sizes/seeds
disjoint from every reference run below.  These tests pin that promise
for ALL registered executables - the list comes from the registry via
the ``executable_variant`` fixture (tests/conftest.py), so a newly
registered variant inherits the cross-plane suite with zero edits here -
plus the grid acceptance shape, the quorum-grid acceptor parity, and the
leader-crash replay whose recovery dip must match the transient plane's
prediction.
"""
import numpy as np
import pytest

from repro.core.api import (
    MIXED_50_50,
    WRITE_ONLY,
    Workload,
    register_variant,
    temporary_variants,
    variant_spec,
)
from repro.core.analytical import calibrate_alpha, vanilla_mencius_model
from repro.core.batched_execution import (
    BatchedExecutionResult,
    execute_configs,
    run_variant_batched,
    validate_batched,
)
from repro.core.execution import default_config, run_variant
from repro.core.linearizability import check_linearizable
from repro.core.protocols import CompartmentalizedMultiPaxos, DeploymentConfig
from repro.core.simulator import demand_vector
from repro.core.sweep import SweepSpec, compile_sweep
from repro.core.transient import failover_schedule, simulate_transient

MIXES = [WRITE_ONLY, MIXED_50_50]
N_CMDS = 48

_CACHE = {}


def _batched(name, w, **kw):
    key = (name, w.f_write, tuple(sorted(kw.items())))
    if key not in _CACHE:
        _CACHE[key] = run_variant_batched(name, workload=w,
                                          n_commands=N_CMDS, seeds=2, **kw)
    return _CACHE[key]


# ---------------------------------------------------------------------------
# Satellite: cross-plane agreement for every executable at two mixes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mix", MIXES, ids=lambda w: f"fw{w.f_write:g}")
def test_cross_plane_agreement(executable_variant, mix):
    """Batched per-station msgs/cmd matches run_variant within the
    variant's registered tolerances - exactly on its exact_stations."""
    name = executable_variant
    exe = variant_spec(name).executable
    res = _batched(name, mix)
    ref = run_variant(name, workload=mix, n_commands=N_CMDS, seed=0)
    row = res.station_row(0)
    ref_row = ref.station_msgs
    assert set(row) == set(ref_row), (row, ref_row)
    for st in ref_row:
        m, r = row[st], ref_row[st]
        if st in exe.exact_stations:
            assert abs(m - r) <= 1e-9, (name, st, m, r)
        else:
            tol = exe.tolerance_for(st)
            assert abs(m - r) <= tol * max(r, 1e-12), (name, st, m, r, tol)


def test_quantile_and_drain_pins(executable_variant):
    """p50 <= p99 on every lane; every lane drains its full op budget at
    the exact generator write count; histogram mass == completions."""
    name = executable_variant
    res = _batched(name, MIXED_50_50)
    exe = variant_spec(name).executable
    assert np.all(res.latency_p50 <= res.latency_p99 + 1e-12)
    assert np.all(res.latency_p50 > 0) and np.all(res.latency_mean > 0)
    assert np.all(res.completed == N_CMDS)
    f_eff = 1.0 if exe.reads_as_writes else MIXED_50_50.f_write
    assert res.n_writes[0] == round(N_CMDS * f_eff)
    assert np.all(res.hist.sum(axis=-1) == N_CMDS)
    assert np.all(res.throughput > 0)


def test_latency_monotone_in_load():
    """Closed-loop queueing: more concurrent clients -> strictly more
    queueing delay per command (same budget, same service demands)."""
    lo = _batched("compartmentalized", WRITE_ONLY, n_clients=2)
    hi = _batched("compartmentalized", WRITE_ONLY, n_clients=16)
    assert np.all(hi.latency_mean > lo.latency_mean)
    assert np.all(hi.latency_p99 >= lo.latency_p99)


def test_station_surface_is_seed_independent():
    """The measured msgs/cmd surface depends on the realized mix, not the
    seed: every lane drains round(n * f_write) writes by construction."""
    a = run_variant_batched("compartmentalized", workload=MIXED_50_50,
                            n_commands=N_CMDS, seeds=[0, 1])
    b = run_variant_batched("compartmentalized", workload=MIXED_50_50,
                            n_commands=N_CMDS, seeds=[7, 11])
    np.testing.assert_allclose(a.station_msgs, b.station_msgs, rtol=0, atol=0)


# ---------------------------------------------------------------------------
# Acceptance: one device call over a >= 8-config x >= 4-seed grid
# ---------------------------------------------------------------------------


def test_grid_acceptance_one_call():
    sw = compile_sweep(SweepSpec(
        variants=("compartmentalized", "multipaxos"),
        n_proxy_leaders=(2, 3, 4, 5), n_replicas=(2, 3)))
    assert len(sw.configs) >= 8
    res = sw.execute(workload=MIXED_50_50, n_commands=40, seeds=4)
    assert isinstance(res, BatchedExecutionResult)
    assert len(res) >= 8 and len(res.seeds) >= 4
    assert np.all(res.completed == 40)
    assert np.all(res.latency_p50 <= res.latency_p99 + 1e-12)
    # measured surface of every row agrees with its analytical demand
    # table within the variant's registered tolerances
    for m in range(len(res)):
        name = res.variant(m)
        exe = variant_spec(name).executable
        w = MIXED_50_50
        realized = Workload(
            f_write=1.0 if exe.reads_as_writes else w.f_write)
        predicted = variant_spec(name).model(res.configs[m], w).demands(
            realized)
        for st, mm in res.station_row(m).items():
            p = predicted.get(st, 0.0)
            assert abs(mm - p) <= exe.tolerance_for(st) * max(p, 1e-12), (
                name, st, mm, p)


def test_execute_requires_configs_and_plane():
    with temporary_variants():
        register_variant(name="table_only_bx", factory=vanilla_mencius_model,
                         stations=("server",))
        with pytest.raises(ValueError, match="no execution plane"):
            run_variant_batched("table_only_bx")
        with pytest.raises(ValueError, match="no execution plane"):
            execute_configs([{"variant": "table_only_bx"}])


# ---------------------------------------------------------------------------
# Satellite: measured-vs-analytical parity on the batched plane
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["compartmentalized", "craq",
                                  "vanilla_spaxos", "multipaxos",
                                  "bpaxos", "iss"])
def test_validate_batched_passes(name):
    rep = validate_batched(name, workload=MIXED_50_50, n_commands=N_CMDS,
                           seeds=2)
    assert rep.passed, str(rep)
    assert rep.max_rel_err() < 1.0
    assert "batched" in str(rep)


# ---------------------------------------------------------------------------
# Satellite: 2-row write vs 2-column read quorum grids through the
# executable plane - acceptor msgs/cmd pinned against the analytical table
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mix", MIXES, ids=lambda w: f"fw{w.f_write:g}")
def test_quorum_grid_sweep_acceptor_parity(mix):
    grids = [(2, 2), (2, 3), (3, 2)]
    configs = [{"variant": "compartmentalized",
                "grid_rows": r, "grid_cols": c} for r, c in grids]
    res = execute_configs(configs, workload=mix, n_commands=40, seeds=2)
    spec = variant_spec("compartmentalized")
    acc = []
    for m, cfg in enumerate(configs):
        measured = res.station_row(m)["acceptor"]
        predicted = spec.model(cfg, mix).demands(mix)["acceptor"]
        if mix.f_write >= 1.0:
            # write path is deterministic: exact table parity
            assert abs(measured - predicted) <= 1e-9, (cfg, measured,
                                                       predicted)
        else:
            tol = spec.executable.tolerance_for("acceptor")
            assert abs(measured - predicted) <= tol * predicted, (
                cfg, measured, predicted)
        acc.append(measured)
    # the table's asymmetry: with 2-member write quorums (columns of a
    # 2-row grid), widening the grid spreads the same write traffic over
    # more acceptors - (2, 3) is strictly cheaper per acceptor than (2, 2)
    # and than 3-member write columns ((3, 2)) under writes; at 50/50 the
    # transposed grids tie exactly (write and read quorums swap roles)
    assert acc[1] < acc[0], acc
    if mix.f_write >= 1.0:
        assert acc[1] < acc[2], acc
    else:
        assert abs(acc[1] - acc[2]) <= 1e-9, acc


# ---------------------------------------------------------------------------
# Satellite: transient leader-crash schedule replayed on the correctness
# plane - linearizable across failover, dip shape matching the prediction
# ---------------------------------------------------------------------------


def _completion_rate(history, t0, t1):
    n = sum(1 for o in history.ops
            if o.response_time is not None and t0 <= o.response_time < t1)
    return n / (t1 - t0)


def test_leader_crash_replay_matches_transient_dip():
    """Replay the transient plane's failover schedule (crash the leader
    mid-run, heartbeat-driven promotion, client rediscovery) on the real
    cluster: the history must stay linearizable across the failover, and
    the completion-rate trace must show the same dip-and-recover shape
    the transient engine predicts for the same schedule."""
    # --- prediction: scripted leader crash through the scan engine ------
    alpha = calibrate_alpha()
    model = variant_spec("compartmentalized").model(
        default_config("compartmentalized"), WRITE_ONLY)
    base = demand_vector(model, f_write=1.0) / alpha
    sched, bounds = failover_schedule(base, "leader", start=0.35, stop=0.6,
                                      n_steps=1200)
    tr = simulate_transient(sched, bounds, n_clients=16, seeds=4,
                            n_steps=1200)
    centers, x = tr.throughput_trace(n_windows=24)
    frac = centers[0] / centers[0, -1] / (24 / 23.5)  # window fractions
    pre_p = x[0, :, (frac > 0.05) & (frac < 0.3)].mean()
    dip_p = x[0, :, (frac > 0.4) & (frac < 0.55)].mean()
    post_p = x[0, :, (frac > 0.7)].mean()
    assert dip_p < 0.25 * pre_p, (dip_p, pre_p)
    assert post_p > 0.4 * pre_p, (post_p, pre_p)

    # --- replay: the same schedule against the real cluster -------------
    cfg = DeploymentConfig(f=1, n_proxy_leaders=3, grid=(2, 2),
                           n_replicas=2, state_machine="register", seed=0,
                           client_retries=True, auto_failover=True)
    dep = CompartmentalizedMultiPaxos(cfg, n_clients=2)
    for i, c in enumerate(dep.clients):
        c.run_ops([("w", 1000 * i + j) for j in range(300)])
    dep.net.run(until=400)                      # steady phase
    dep.net.crash("leader/0")
    dep.net.run(until=1_600)                    # outage until promotion
    assert dep.leaders[1].active, "heartbeats must promote a new leader"
    for c in dep.clients:                       # client-side rediscovery
        c.leader = "leader/1"
    dep.net.run(until=3_000)                    # recovery phase

    pre = _completion_rate(dep.history, 0, 400)
    dip = _completion_rate(dep.history, 500, 1_500)
    post = _completion_rate(dep.history, 1_700, 3_000)
    assert pre > 0, "no completions in the steady phase"
    # same shape booleans the transient plane predicted above
    assert dip < 0.25 * pre, (dip, pre)
    assert post > 0.4 * pre, (post, pre)
    assert check_linearizable(dep.history, "register")
