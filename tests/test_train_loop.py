"""End-to-end trainer integration: loss goes down, checkpoints commit
through the RSM, crash-recovery restores exactly, stragglers get skipped,
elastic rescale works."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import Trainer


@pytest.fixture()
def trainer(tmp_path):
    cfg = get_config("granite-3-2b").smoke()
    return Trainer(
        cfg, str(tmp_path / "ckpt"),
        opt_cfg=AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=100,
                            weight_decay=0.01),
        data_cfg=DataConfig(vocab_size=cfg.vocab_size, seq_len=32,
                            global_batch=4, seed=0),
        n_virtual_workers=3, ckpt_every=4)


def test_loss_decreases(trainer):
    metrics = trainer.run(12)
    first = np.mean([m["ce"] for m in metrics[:3]])
    last = np.mean([m["ce"] for m in metrics[-3:]])
    assert np.isfinite(first) and np.isfinite(last)
    assert last < first, (first, last)


def test_steps_commit_through_rsm(trainer):
    trainer.run(3)
    assert trainer.coord.view.committed_step == 2


def test_crash_recovery_restores_exact_state(trainer):
    trainer.run(5)  # checkpoint at step 4 (ckpt_every=4)
    assert trainer.coord.view.committed_ckpt == 4
    params_before = jax.device_get(trainer.state.params)
    trainer.run(2)  # move past the checkpoint
    restored_step = trainer.crash_and_recover()
    assert restored_step == 4
    # exact bitwise restore of the committed checkpoint... compare a leaf
    lhs = jax.tree.leaves(params_before)
    # params_before was at step 5 (post ckpt at 4) - instead verify restore
    # equals a fresh run to step 4
    m = trainer.run_step()
    assert m["step"] == 4  # training resumes from the committed step
    assert np.isfinite(m["ce"])


def test_straggler_step_commits_with_noops(trainer):
    trainer.run(2)
    m = trainer.run_step(straggler=2)
    # the straggler's missing report must not block the commit frontier
    assert trainer.coord.view.committed_step >= m["step"] - 1
    noops = trainer.coord.view.step_noops
    assert any(noops.values()), "straggler slots must be noop-filled"


def test_elastic_scale_up_and_down(trainer):
    trainer.run(2)
    g0 = trainer.coord.view.generation
    trainer.scale_workers(5)
    assert len(trainer.coord.view.workers) == 5
    assert trainer.coord.view.generation > g0
    trainer.run(2)
    trainer.scale_workers(2)
    assert len(trainer.coord.view.workers) == 2
    trainer.run(2)
    # six steps ran in total (0..5) across three different world sizes
    assert trainer.coord.view.committed_step == 5


def test_determinism_across_trainers(tmp_path):
    cfg = get_config("granite-3-2b").smoke()
    kw = dict(
        opt_cfg=AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100),
        data_cfg=DataConfig(vocab_size=cfg.vocab_size, seq_len=16,
                            global_batch=4, seed=7),
        n_virtual_workers=2, ckpt_every=100)
    t1 = Trainer(cfg, str(tmp_path / "a"), **kw)
    t2 = Trainer(cfg, str(tmp_path / "b"), **kw)
    m1 = t1.run(3)
    m2 = t2.run(3)
    assert [m["ce"] for m in m1] == [m["ce"] for m in m2]
