"""Performance-plane tests: demand tables, bottleneck identification, MVA /
fluid / DES agreement, and reproduction of the paper's headline claims."""
import numpy as np
import pytest

from repro.core import (
    ablation_steps,
    calibrate_alpha,
    compartmentalized_model,
    craq_model,
    des_throughput,
    fluid_throughput,
    mixed_workload_speedup,
    multipaxos_model,
    mva_curve,
    mva_curves_batch,
    read_scalability_law,
    unreplicated_model,
)
from repro.core.analytical import (
    PAPER_COMPARTMENTALIZED_UNBATCHED,
    PAPER_MULTIPAXOS_UNBATCHED,
)


def test_multipaxos_leader_is_bottleneck():
    name, _ = multipaxos_model(f=1).bottleneck()
    assert name == "leader"


def test_compartmentalized_write_bottleneck_is_leader():
    """Paper section 8.1: even fully compartmentalized, the (sequencing)
    leader remains the write-path bottleneck."""
    m = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=2,
                                grid_cols=2, n_replicas=4)
    name, _ = m.bottleneck(f_write=1.0)
    assert name == "leader"


def test_decoupling_alone_shifts_bottleneck_to_proxies():
    """Paper Fig. 29a: right after decoupling (2 proxies), proxies bottleneck."""
    m = compartmentalized_model(f=1, n_proxy_leaders=2, grid_rows=3,
                                grid_cols=1, n_replicas=2)
    name, _ = m.bottleneck()
    assert name == "proxy"


def test_write_only_speedup_matches_paper_band():
    """Headline claim: ~6x on write-only workloads.  The structural model
    (message counts only, one calibration anchor) must land in [3.5x, 8x]."""
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    mp = multipaxos_model(f=1).peak_throughput(alpha)
    cm = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=2,
                                 grid_cols=2, n_replicas=4).peak_throughput(alpha)
    assert mp == pytest.approx(PAPER_MULTIPAXOS_UNBATCHED, rel=1e-6)
    speedup = cm / mp
    assert 3.5 <= speedup <= 8.0, f"speedup {speedup:.2f} out of band"


def test_mixed_workload_speedup_exceeds_write_only():
    """Headline claim: 16x on a 90% read workload - reads bypass both the
    leader and all-replica execution, so the mixed speedup must dominate the
    write-only speedup."""
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    _, _, s_write = mixed_workload_speedup(f_write=1.0, alpha=alpha)
    _, _, s_mixed = mixed_workload_speedup(f_write=0.1, alpha=alpha)
    assert s_mixed > 2.0 * s_write
    assert s_mixed >= 10.0


def test_ablation_staircase_is_monotone():
    """Fig. 29a: each compartmentalization step must not reduce throughput."""
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    peaks = [m.peak_throughput(alpha) for _, m in ablation_steps()]
    assert all(b >= a * 0.999 for a, b in zip(peaks, peaks[1:])), peaks
    assert peaks[-1] / peaks[0] >= 3.5


def test_batching_multiplies_throughput():
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    unbatched = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=2,
                                        grid_cols=2, n_replicas=4)
    batched = compartmentalized_model(f=1, n_proxy_leaders=3, grid_rows=2,
                                      grid_cols=2, n_replicas=2, batch_size=100,
                                      n_batchers=2, n_unbatchers=3)
    assert (batched.peak_throughput(alpha)
            > 3.0 * unbatched.peak_throughput(alpha))


def test_read_scalability_law_limits():
    """Paper section 8.3: T -> alpha/f_w as n -> inf; linear for 100% reads."""
    alpha = 100_000.0
    assert read_scalability_law(6, 0.0, alpha) == pytest.approx(6 * alpha)
    t_inf = read_scalability_law(10_000, 0.5, alpha)
    assert t_inf == pytest.approx(alpha / 0.5, rel=0.01)
    # 1% -> 2% writes halves peak throughput (the paper's counterintuitive
    # observation), in the large-n limit
    t1 = read_scalability_law(100_000, 0.01, alpha)
    t2 = read_scalability_law(100_000, 0.02, alpha)
    assert t1 / t2 == pytest.approx(2.0, rel=0.05)


def test_mva_saturates_at_bottleneck():
    model = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=2,
                                    grid_cols=2, n_replicas=4)
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    clients, x, r = mva_curve(model, alpha, n_clients_max=400)
    peak_bound = model.peak_throughput(alpha)
    assert x[-1] <= peak_bound * 1.001
    assert x[-1] >= peak_bound * 0.95       # within 5% of the bound
    assert np.all(np.diff(x) >= -1e-4 * x[:-1])  # monotone (f32 tolerance)
    # latency flat at low load, rising near saturation
    assert r[-1] > r[0] * 2


def test_mva_batch_matches_single():
    models = [multipaxos_model(), compartmentalized_model()]
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    _, xs, _ = mva_curves_batch(models, alpha, n_clients_max=64)
    for i, m in enumerate(models):
        _, x_single, _ = mva_curve(m, alpha, n_clients_max=64)
        np.testing.assert_allclose(xs[i], x_single, rtol=1e-6)


def test_fluid_agrees_with_mva():
    model = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=2,
                                    grid_cols=2, n_replicas=4)
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    _, x_mva, _ = mva_curve(model, alpha, n_clients_max=256)
    x_fluid = fluid_throughput(model, alpha, n_clients=256, sim_time=0.05)
    assert x_fluid == pytest.approx(float(x_mva[-1]), rel=0.15)


def test_des_agrees_with_mva_at_saturation():
    model = multipaxos_model(f=1)
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    x_des, lat = des_throughput(model, alpha, n_clients=64, n_commands=5_000)
    _, x_mva, _ = mva_curve(model, alpha, n_clients_max=64)
    assert x_des == pytest.approx(float(x_mva[-1]), rel=0.1)
    assert lat > 0


def test_craq_skew_degrades_throughput():
    """Fig. 33: CRAQ throughput falls as skew rises; ~3x drop at p=1."""
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    t_uniform = craq_model(n_nodes=6, skew_p=0.0, f_write=0.05, alpha=alpha)
    t_skewed = craq_model(n_nodes=6, skew_p=1.0, f_write=0.05, alpha=alpha)
    assert t_skewed < t_uniform
    assert t_uniform / t_skewed >= 1.5


def test_compartmentalized_is_skew_insensitive():
    """Compartmentalized MultiPaxos ignores keys entirely: same model for
    any skew, so throughput is flat by construction - assert the model has
    no key-dependent inputs by comparing two mixes."""
    m = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=2,
                                grid_cols=2, n_replicas=6)
    alpha = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
    assert (m.peak_throughput(alpha, f_write=0.05)
            == m.peak_throughput(alpha, f_write=0.05))
