"""Property tests for the multi-leader variant family.

The two multi-leader protocols earn their registry seats here:

* BPaxos dependency-graph execution stays linearizable under random
  conflict patterns (write mix x key skew) and network jitter, and every
  replica executes conflicting commands in the same per-key order - the
  proposer froze the dependency sets at commit time, so the graph (and
  the SCC execution rule) is identical everywhere regardless of commit
  arrival order.  A synthetic dependency *cycle* (mutual conflicts
  discovered in opposite orders by different dep nodes) is pinned to
  execute deterministically too.
* ISS bucket rotation never reorders commands within a bucket: under a
  rotation-heavy config (tiny epochs, several leaders) with jitter, each
  replica's per-bucket execution is the contiguous in-order sequence
  0..k-1 and identical across replicas, and the whole history stays
  linearizable.

Each property runs twice: a deterministic pinned-seed sweep that always
executes, and a hypothesis-widened version (skipped when hypothesis is
absent, like test_execution's jitter test) that searches the seed x
workload space for counterexamples.
"""
import pytest

from repro.core.api import Workload
from repro.core.bpaxos import BPaxosCommit, BPaxosDeployment, BPaxosReplica
from repro.core.cluster import Network, Node
from repro.core.execution import default_config, run_variant, workload_ops
from repro.core.iss import IssDeployment
from repro.core.messages import Command
from repro.core.statemachine import make_state_machine


def _run(dep, ops):
    """Split an op stream round-robin across the clients and run the
    cluster to quiescence (mirrors execution._assign_ops/_drive)."""
    per_client = [[] for _ in dep.clients]
    for i, op in enumerate(ops):
        per_client[i % len(per_client)].append(op)
    for client, client_ops in zip(dep.clients, per_client):
        if client_ops:
            client.run_ops(client_ops)
    dep.run_to_quiescence()
    assert dep.all_done(), [c.addr for c in dep.clients if not c.done]


# ---------------------------------------------------------------------------
# BPaxos: linearizable under random conflict patterns + jitter
# ---------------------------------------------------------------------------


def _check_bpaxos_linearizable(seed, f_write, skew_p):
    trace = run_variant("bpaxos",
                        workload=Workload(f_write=f_write, skew_p=skew_p),
                        n_commands=8, seed=seed, jitter=3.0)
    assert trace.checker == "exhaustive"
    assert trace.linearizable, trace.violations


@pytest.mark.parametrize("seed,f_write,skew_p",
                         [(0, 1.0, 0.9), (1, 0.7, 0.5), (2, 0.4, 0.9),
                          (3, 0.7, 0.0)])
def test_bpaxos_linearizable_under_conflicts_and_jitter(seed, f_write,
                                                        skew_p):
    """Pinned conflict patterns x message reordering: the exhaustive
    Wing-Gong search must accept every BPaxos history."""
    _check_bpaxos_linearizable(seed, f_write, skew_p)


def test_bpaxos_linearizable_property():
    """Hypothesis-widened version of the pinned sweep above."""
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings, strategies as st

    @given(seed=st.integers(0, 200),
           f_write=st.sampled_from([0.4, 0.7, 1.0]),
           skew_p=st.sampled_from([0.0, 0.5, 0.9]))
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def check(seed, f_write, skew_p):
        _check_bpaxos_linearizable(seed, f_write, skew_p)

    check()


def _check_bpaxos_replica_agreement(seed, skew_p):
    dep = BPaxosDeployment(n_proposers=3, n_dep_nodes=3, n_replicas=3,
                           n_clients=3, seed=seed)
    dep.net.jitter = 4.0
    ops = workload_ops(Workload(f_write=1.0, skew_p=skew_p), 18, seed=seed)
    _run(dep, ops)
    ref = dep.replicas[0]
    assert len(ref.executed_order) == 18
    for rep in dep.replicas[1:]:
        assert set(rep.executed_order) == set(ref.executed_order)
        assert rep.key_order == ref.key_order


@pytest.mark.parametrize("seed,skew_p", [(0, 0.9), (1, 0.3), (2, 0.9),
                                         (3, 0.3)])
def test_bpaxos_replicas_agree_on_per_key_order(seed, skew_p):
    """Dependency sets are frozen at commit, so all replicas execute
    conflicting commands in the same per-key order - even though jitter
    delivers the commits to each replica in a different order."""
    _check_bpaxos_replica_agreement(seed, skew_p)


def test_bpaxos_replica_agreement_property():
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings, strategies as st

    @given(seed=st.integers(0, 500), skew_p=st.sampled_from([0.3, 0.9]))
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def check(seed, skew_p):
        _check_bpaxos_replica_agreement(seed, skew_p)

    check()


class _Sink(Node):
    def on_message(self, src, msg):
        pass


def _lone_replica():
    net = Network(seed=0)
    rep = BPaxosReplica("replica/0", 0, 1, make_state_machine("kv"))
    net.add_nodes([rep, _Sink("client/0")])
    return rep


def test_bpaxos_dependency_cycle_executes_deterministically():
    """Mutual conflicts (dep nodes saw a and b in opposite orders) form a
    2-vertex SCC; every replica must execute it - and a vertex hanging
    off it - in the same sorted order, whatever the commit arrival
    order, leaving identical state machines."""
    a, b, c = (0, 0), (1, 0), (2, 0)
    commits = {
        a: BPaxosCommit(vertex=a, deps=(b,),
                        command=Command(0, 0, ("put", "x", 1))),
        b: BPaxosCommit(vertex=b, deps=(a,),
                        command=Command(0, 1, ("put", "x", 2))),
        c: BPaxosCommit(vertex=c, deps=(a,),
                        command=Command(0, 2, ("put", "x", 3))),
    }
    orders = [(a, b, c), (c, b, a), (b, c, a), (c, a, b)]
    replicas = []
    for order in orders:
        rep = _lone_replica()
        for v in order:
            rep.on_message("proposer/0", commits[v])
        replicas.append(rep)
    ref = replicas[0]
    assert ref.executed_order == [a, b, c]
    assert ref.sm.apply(("get", "x")) == 3
    for rep in replicas[1:]:
        assert rep.executed_order == ref.executed_order
        assert rep.sm.apply(("get", "x")) == ref.sm.apply(("get", "x"))


# ---------------------------------------------------------------------------
# ISS: bucket rotation never reorders within a bucket
# ---------------------------------------------------------------------------


def _check_iss_bucket_order(seed, f_write):
    dep = IssDeployment(n_leaders=3, n_buckets=2, epoch_length=2,
                        n_proxy_leaders=3, grid=(2, 2), n_replicas=2,
                        n_clients=3, seed=seed)
    dep.net.jitter = 3.0
    ops = workload_ops(Workload(f_write=f_write, skew_p=0.3), 24, seed=seed)
    _run(dep, ops)
    assert dep.total_rotations() > 0, "config must actually rotate buckets"
    ref = dep.replicas[0]
    assert sum(len(v) for v in ref.executed_by_bucket.values()) == 24
    for rep in dep.replicas:
        for b, executed in rep.executed_by_bucket.items():
            seqs = [s for s, _ in executed]
            assert seqs == list(range(len(seqs))), (b, seqs)
            assert executed == ref.executed_by_bucket[b]


@pytest.mark.parametrize("seed,f_write", [(0, 1.0), (1, 0.6), (2, 1.0),
                                          (3, 0.6)])
def test_iss_rotation_never_reorders_within_bucket(seed, f_write):
    """Rotation-heavy config (2-command epochs, 3 leaders) under jitter:
    every replica's per-bucket execution is the contiguous sequence
    0..k-1 in order, identical across replicas - handoffs move the
    bucket's sequencer, never its history."""
    _check_iss_bucket_order(seed, f_write)


def test_iss_bucket_order_property():
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings, strategies as st

    @given(seed=st.integers(0, 300), f_write=st.sampled_from([0.6, 1.0]))
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def check(seed, f_write):
        _check_iss_bucket_order(seed, f_write)

    check()


def _check_iss_linearizable(seed, f_write):
    cfg = dict(default_config("iss"), n_leaders=3, n_buckets=2,
               epoch_length=2)
    trace = run_variant("iss", config=cfg,
                        workload=Workload(f_write=f_write, skew_p=0.8),
                        n_commands=8, seed=seed, jitter=3.0)
    assert trace.checker == "exhaustive"
    assert trace.linearizable, trace.violations


@pytest.mark.parametrize("seed,f_write", [(0, 1.0), (1, 0.5), (2, 0.5),
                                          (3, 1.0)])
def test_iss_linearizable_under_rotation_and_jitter(seed, f_write):
    """The registry path end to end at a rotation-heavy config: the
    exhaustive checker must accept every jittered ISS history."""
    _check_iss_linearizable(seed, f_write)


def test_iss_linearizable_property():
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings, strategies as st

    @given(seed=st.integers(0, 200), f_write=st.sampled_from([0.5, 1.0]))
    @settings(max_examples=10, deadline=None,
              suppress_health_check=[HealthCheck.too_slow])
    def check(seed, f_write):
        _check_iss_linearizable(seed, f_write)

    check()
