"""Mencius, S-Paxos and CRAQ variants (paper sections 6-8.4)."""
import pytest

from repro.core import CraqDeployment, MenciusDeployment, SPaxosDeployment
from repro.core.linearizability import check_linearizable, check_slot_order


def run(dep, ops_per_client, max_steps=500_000):
    for client, ops in zip(dep.clients, ops_per_client):
        client.run_ops(ops)
    dep.net.run(max_steps=max_steps)
    assert all(c.done for c in dep.clients)
    return dep


# ---------------------------------------------------------------------------
# Mencius
# ---------------------------------------------------------------------------


def test_mencius_basic():
    dep = MenciusDeployment(n_leaders=3, n_clients=3)
    run(dep, [[("put", f"k{i}", i), ("get", f"k{i}")] for i in range(3)])
    for i in range(3):
        assert dep.clients[i].results == ["ok", i]


def test_mencius_slot_partitioning():
    """Leader i only sequences slots with slot % m == i."""
    dep = MenciusDeployment(n_leaders=3, n_clients=3)
    run(dep, [[("put", f"c{i}-{j}", j) for j in range(4)] for i in range(3)])
    for replica in dep.replicas:
        for slot, value in replica.log.items():
            if hasattr(value, "client_id") and value.client_id >= 0:
                # command from client c was sequenced by leader c % 3
                assert slot % 3 == value.client_id % 3


def test_mencius_noop_skips_unblock_replicas():
    """A lagging leader's vacant slots are noop-filled so replicas execute."""
    dep = MenciusDeployment(n_leaders=3, n_clients=3)
    # only client 0 (-> leader 0) issues commands; leaders 1, 2 are idle and
    # must skip their slots for the log to stay executable
    run(dep, [[("put", f"k{j}", j) for j in range(5)], [], []])
    assert dep.clients[0].results == ["ok"] * 5
    assert dep.total_skips() > 0
    for replica in dep.replicas:
        assert replica.executed_upto >= 0


def test_mencius_replicas_converge():
    dep = MenciusDeployment(n_leaders=3, n_clients=3)
    run(dep, [[("put", f"x{i}{j}", i * 10 + j) for j in range(3)] for i in range(3)])
    states = [r.sm.snapshot() for r in dep.replicas]
    common = {}
    for s in states:
        common.update(s)
    # every replica that executed the full prefix agrees on overlapping keys
    for s in states:
        for k, v in s.items():
            assert common[k] == v


def test_mencius_linearizable_history():
    dep = MenciusDeployment(n_leaders=2, n_clients=2, state_machine="register")
    run(dep, [[("w", 1), ("r",)], [("w", 2), ("r",)]])
    assert check_slot_order(dep.history) == []
    assert check_linearizable(dep.history, "register")


# ---------------------------------------------------------------------------
# S-Paxos
# ---------------------------------------------------------------------------


def test_spaxos_basic():
    dep = SPaxosDeployment(n_clients=2)
    run(dep, [
        [("put", "x", 1), ("get", "x")],
        [("put", "y", 2), ("get", "y")],
    ])
    assert dep.clients[0].results == ["ok", 1]
    assert dep.clients[1].results == ["ok", 2]


def test_spaxos_leader_never_sees_payloads():
    """The whole point of S-Paxos: the leader orders ids, not commands."""
    dep = SPaxosDeployment(n_clients=2)
    run(dep, [[("put", "big" * 100, 1)], [("put", "blob" * 100, 2)]])
    # leader only handled ProposeId messages (and sent Phase2a with ids)
    assert dep.leader.msgs_received == 2
    assert dep.leader.next_slot == 2


def test_spaxos_replicas_converge():
    dep = SPaxosDeployment(n_clients=3)
    run(dep, [[("put", f"k{i}", i)] for i in range(3)])
    states = [r.sm.snapshot() for r in dep.replicas]
    assert all(s == states[0] for s in states)


def test_spaxos_linearizable():
    dep = SPaxosDeployment(n_clients=2, state_machine="register")
    run(dep, [[("w", 1), ("r",)], [("w", 2), ("r",)]])
    assert check_linearizable(dep.history, "register")


# ---------------------------------------------------------------------------
# CRAQ
# ---------------------------------------------------------------------------


def test_craq_basic():
    dep = CraqDeployment(n_nodes=3, n_clients=2)
    run(dep, [
        [("put", "x", 1), ("get", "x")],
        [("put", "y", 2), ("get", "y")],
    ])
    assert dep.clients[0].results == ["ok", 1]
    assert dep.clients[1].results == ["ok", 2]


def test_craq_linearizable_history():
    dep = CraqDeployment(n_nodes=3, n_clients=2)
    run(dep, [
        [("put", "x", 1), ("get", "x"), ("put", "x", 3)],
        [("put", "x", 2), ("get", "x")],
    ])
    assert check_linearizable(dep.history, "kv")


def test_chain_replication_reads_at_tail_only():
    dep = CraqDeployment(n_nodes=3, n_clients=1, reads_anywhere=False)
    run(dep, [[("put", "x", 1)] + [("get", "x")] * 5])
    assert dep.nodes[-1].reads_served == 5
    assert dep.nodes[0].reads_served == 0


def test_craq_skew_forwards_to_tail():
    """Reads of a dirty hot key must be forwarded to the tail - the
    mechanism behind the paper's Fig. 33 skew sensitivity."""
    dep = CraqDeployment(n_nodes=3, n_clients=2)
    # client 0 hammers writes to the hot key while client 1 reads it
    dep.clients[0].run_ops([("put", "hot", i) for i in range(20)])
    dep.clients[1].run_ops([("get", "hot")] * 20)
    dep.net.run(max_steps=500_000)
    assert all(c.done for c in dep.clients)
    total_fwd = sum(n.tail_forwards for n in dep.nodes)
    assert total_fwd > 0, "concurrent writes must dirty the hot key"


def test_craq_uniform_reads_spread_load():
    dep = CraqDeployment(n_nodes=3, n_clients=1, seed=3)
    run(dep, [[("put", f"k{i}", i) for i in range(5)]
              + [("get", f"k{i % 5}") for i in range(30)]])
    served = [n.reads_served for n in dep.nodes]
    assert sum(served) == 30
    assert dep.tail_load_fraction() < 0.8  # not funnelled to the tail
