"""Multi-device tests (shard_map collectives, sharding policy, distributed
flash-decode).  These need >1 device, so each test body runs in a
subprocess with ``xla_force_host_platform_device_count`` - the main test
process keeps seeing 1 device (dry-run hygiene)."""
import json
import subprocess
import sys
import textwrap

import pytest

PREAMBLE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count={n}"
import jax, jax.numpy as jnp, numpy as np
"""


def run_sub(body: str, n_devices: int = 4, timeout: int = 480) -> str:
    code = PREAMBLE.format(n=n_devices) + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_hierarchical_allreduce_matches_psum():
    run_sub("""
    from jax.sharding import PartitionSpec as P
    from repro.runtime.compat import shard_map
    from repro.runtime.collectives import hierarchical_allreduce
    mesh = jax.make_mesh((2, 2), ("pod", "data"))
    x = jnp.arange(32, dtype=jnp.float32).reshape(4, 8)

    def mean_all(v):
        return hierarchical_allreduce(v, in_pod_axis="data",
                                      cross_pod_axis="pod")
    f = jax.jit(shard_map(mean_all, mesh=mesh,
                          in_specs=P(), out_specs=P(),
                          check_vma=False))
    out = f(x)
    np.testing.assert_allclose(np.asarray(out), np.asarray(x), rtol=1e-6)
    print("OK")
    """)


def test_hierarchical_allreduce_compressed_close():
    run_sub("""
    from jax.sharding import PartitionSpec as P
    from repro.runtime.compat import shard_map
    from repro.runtime.collectives import hierarchical_allreduce
    mesh = jax.make_mesh((2, 2), ("pod", "data"))
    key = jax.random.key(0)
    x = jax.random.normal(key, (8, 16))

    def mean_c(v):
        return hierarchical_allreduce(v, in_pod_axis="data",
                                      cross_pod_axis="pod",
                                      compress_cross_pod=True)
    f = jax.jit(shard_map(mean_c, mesh=mesh, in_specs=P(),
                          out_specs=P(), check_vma=False))
    out = f(x)
    err = float(jnp.abs(out - x).max())
    scale = float(jnp.abs(x).max()) / 127.0
    assert err <= scale + 1e-6, (err, scale)
    print("OK")
    """)


def test_distributed_flash_decode_matches_ref():
    run_sub("""
    from repro.runtime.collectives import make_distributed_flash_decode
    from repro.kernels.ref import ref_decode
    mesh = jax.make_mesh((2, 2), ("data", "model"))
    B, H, H_kv, S, D = 4, 8, 2, 64, 16
    ks = jax.random.split(jax.random.key(1), 4)
    q = jax.random.normal(ks[0], (B, H, D))
    k = jax.random.normal(ks[1], (B, S, H_kv, D))
    v = jax.random.normal(ks[2], (B, S, H_kv, D))
    cache_len = jnp.asarray([64, 17, 33, 5], jnp.int32)
    fn = jax.jit(make_distributed_flash_decode(mesh, seq_axis="model",
                                               batch_axes=("data",)))
    out = fn(q, k, v, cache_len)
    expect = ref_decode(q, k.transpose(0, 2, 1, 3), v.transpose(0, 2, 1, 3),
                        cache_len)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)
    print("OK")
    """)


def test_sharding_policy_on_small_mesh():
    """Params/batch/cache shardings must be constructible and lay out a
    smoke model on a real (2x2) mesh; one jitted train step must run."""
    run_sub("""
    import dataclasses
    from repro.configs import get_config
    from repro.runtime.sharding import ShardingPolicy
    from repro.runtime.steps import input_specs, make_train_step
    from repro.configs.shapes import ShapeSpec
    from repro.models import init_params
    from repro.optim.adamw import init_opt_state

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = dataclasses.replace(get_config("granite-3-2b").smoke(),
                              n_kv_heads=2, vocab_size=128)
    policy = ShardingPolicy(cfg, mesh)
    shape = ShapeSpec("tiny", seq_len=16, global_batch=4, kind="train")
    specs = input_specs(cfg, shape)
    p_sh = policy.params_shardings(specs["params"])
    o_sh = policy.opt_state_shardings(specs["params"])
    b_sh = policy.batch_shardings(specs["batch"])
    step = jax.jit(make_train_step(cfg), in_shardings=(p_sh, o_sh, b_sh),
                   out_shardings=(p_sh, o_sh, None))
    params = init_params(cfg, jax.random.key(0))
    opt = init_opt_state(params)
    batch = {"tokens": jnp.zeros((4, 16), jnp.int32) + 3,
             "labels": jnp.zeros((4, 16), jnp.int32) + 5}
    p2, o2, metrics = step(params, opt, batch)
    assert np.isfinite(float(metrics["loss"]))
    # the embedding table must actually be sharded over "model"
    emb_sh = p2["embed"]["tokens"].sharding
    assert "model" in str(emb_sh.spec), emb_sh
    print("OK", float(metrics["loss"]))
    """)


def test_zero1_shards_optimizer_state():
    run_sub("""
    import dataclasses
    from repro.configs import get_config
    from repro.runtime.sharding import ShardingPolicy
    from repro.runtime.steps import input_specs
    from repro.configs.shapes import ShapeSpec

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = get_config("granite-3-2b").smoke()
    policy = ShardingPolicy(cfg, mesh, zero1=True)
    shape = ShapeSpec("tiny", seq_len=16, global_batch=4, kind="train")
    specs = input_specs(cfg, shape)
    o_sh = policy.opt_state_shardings(specs["params"])
    flat = jax.tree.leaves(o_sh["m"])
    n_data_sharded = sum("data" in str(s.spec) for s in flat)
    assert n_data_sharded > len(flat) * 0.8, \
        f"ZeRO-1 must shard most moments over data ({n_data_sharded}/{len(flat)})"
    print("OK")
    """)
