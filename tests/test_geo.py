"""Geo plane: WAN matrices, placement autotuning, cross-plane parity.

The ISSUE-mandated properties:

* **registry-derived conformance** - every executable variant holds
  msgs/cmd parity, linearizability AND per-region measured-vs-predicted
  latency under the 3-region ``geo3`` matrix (conftest fixture), with
  zero per-variant test edits;
* **uniform-RTT degenerates exactly** - a uniform (all-zero) matrix
  reproduces today's numbers bit-for-bit on all three planes: executed
  traces, the MVA queueing surface, and the batched lanes;
* **placement-autotune invariance under region relabeling** -
  ``autotune_placement`` canonicalizes the labeling, so every
  permutation of the same physical WAN yields bit-identical scores;
* **timer locality / jitter stacking** - a ``latency_fn`` on the wire
  never stretches self-addressed timers, and jitter adds on top of the
  matrix delay rather than replacing it;
* **calibration regression pin** - ``calibrate_alpha(measured=True)``
  is exactly unchanged by a uniform matrix and drifts < 5% under a
  spread one (the modeled-RTT subtraction at work);
* **thrifty bpaxos** - the EPaxos-style dependency-quorum knob is
  message-exact on both execution planes.
"""
import itertools
import math

import numpy as np
import pytest

from repro.core import (
    GeoSpec,
    STATION_ORDER,
    SweepSpec,
    Workload,
    autotune_placement,
    calibrate_alpha,
    compile_sweep,
    execute_configs,
    geo_variants,
    region_partition_schedule,
    run_variant,
    validate_batched,
    validate_variant,
    wan_offsets,
)
from repro.core.cluster import Network, Node

W = Workload(f_write=0.5)
# planetary-scale RTTs: analytical-only paths (surfaces, autotune,
# transient schedules) - too large for executed runs, where retry
# timers would fire and break message-count delay-invariance
GEO_WAN = GeoSpec(regions=("us", "eu", "ap"),
                  rtt=((0, 80, 160), (80, 0, 120), (160, 120, 0)))


# ---------------------------------------------------------------------------
# Registry-derived conformance: every executable, one 3-region matrix
# ---------------------------------------------------------------------------


def test_geo_conformance(executable_variant, geo3):
    """Parity + linearizability + per-region latency, per executable."""
    rep = validate_variant(executable_variant, workload=W, n_commands=30,
                           seed=0, geo=geo3)
    assert rep.passed, str(rep)
    assert rep.trace.linearizable, rep.trace.violations
    lat = [r for r in rep.rows if r.station.startswith("wan_latency/")]
    # one row per *client-bearing* region (variants with few clients may
    # leave a region empty; never more rows than regions)
    rows = {r.station.split("/")[1] for r in lat}
    assert 2 <= len(rows) <= 3 and rows <= set(geo3.regions)
    for r in lat:
        assert r.measured > 0.0 and r.predicted > 0.0, r


# ---------------------------------------------------------------------------
# Uniform-RTT degenerates exactly to today's numbers
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", ["compartmentalized", "bpaxos"])
def test_uniform_geo_trace_is_identical(name):
    """A uniform matrix puts ``local_delay`` (== the Network default) on
    every link: the executed trace must match a no-geo run exactly."""
    plain = run_variant(name, workload=W, n_commands=24, seed=3)
    uni = run_variant(name, workload=W, n_commands=24, seed=3,
                      geo=GeoSpec.uniform(3))
    assert uni.linearizable
    assert uni.station_msgs == plain.station_msgs
    assert uni.region_latency is not None


def test_uniform_surface_is_plain_mva():
    """wan == 0 and queueing == the plain MVA residence, bit-for-bit."""
    grid = compile_sweep(SweepSpec(n_proxy_leaders=(2, 4, 6),
                                   n_replicas=(2, 4)))
    alpha = calibrate_alpha()
    surf = grid.geo_latency(alpha, GeoSpec.uniform(3), workload=W,
                            n_clients=32)
    assert surf.wan.shape == (len(grid), 3)
    assert np.all(surf.wan == 0.0)
    _, _, resid = grid.mva(alpha, n_clients_max=32, workload=W)
    np.testing.assert_array_equal(surf.queueing, resid[:, -1])
    np.testing.assert_array_equal(surf.mean, surf.queueing[:, None]
                                  + surf.wan)


def test_uniform_wan_offsets_zero_for_every_variant():
    uni = GeoSpec.uniform(3)
    names = geo_variants()
    assert len(names) >= 8
    for name in names:
        off = wan_offsets({"variant": name}, uni, workload=W)
        assert np.allclose(off, 0.0), (name, off)


# ---------------------------------------------------------------------------
# The (config x region) latency surface
# ---------------------------------------------------------------------------


def test_geo_latency_surface_composition():
    """p50/p99 are the WAN offset plus ln(2)/ln(100) queueing quantiles;
    worst/blended reductions follow the client weights."""
    grid = compile_sweep(SweepSpec(n_proxy_leaders=(2, 4, 6),
                                   n_replicas=(2, 4)))
    assert len(grid) >= 6
    alpha = calibrate_alpha()
    surf = grid.geo_latency(alpha, GEO_WAN, workload=W, n_clients=32)
    assert surf.p99.shape == (len(grid), 3)
    np.testing.assert_allclose(
        surf.p99, surf.wan + math.log(100.0) * surf.queueing[:, None])
    np.testing.assert_allclose(
        surf.p50, surf.wan + math.log(2.0) * surf.queueing[:, None])
    assert np.all(surf.wan > 0.0)  # every region pays some WAN excess
    np.testing.assert_allclose(surf.blended_p99(), surf.p99 @ surf.weights)
    np.testing.assert_array_equal(surf.worst_p99(), surf.p99.max(axis=1))


# ---------------------------------------------------------------------------
# Placement autotuning
# ---------------------------------------------------------------------------


def test_autotune_placement_beats_single_region():
    """For spread clients the winner must strictly beat every
    fully-pinned placement on worst client-bearing region p99."""
    tune = autotune_placement(budget=12, alpha=calibrate_alpha(),
                              geo=GEO_WAN, workload=Workload(f_write=0.2),
                              n_clients=64)
    assert tune.best.machines <= 12
    assert tune.single_region_best is not None
    assert tune.best.worst_p99 < tune.single_region_best.worst_p99
    assert len(tune.best.region_p99) == len(GEO_WAN.regions)
    assert tune.best.worst_p99 == max(tune.best.region_p99)


def test_autotune_placement_invariant_under_relabeling():
    """Exhaustive over all 3! relabelings of the same physical WAN: the
    winner and every per-placement score are bit-identical (the search
    canonicalizes the labeling before generating candidates)."""
    alpha = calibrate_alpha()
    w = Workload(f_write=0.2)
    base = autotune_placement(budget=9, alpha=alpha, geo=GEO_WAN,
                              workload=w, n_clients=32)
    for perm in itertools.permutations(range(3)):
        tune = autotune_placement(budget=9, alpha=alpha,
                                  geo=GEO_WAN.relabeled(perm),
                                  workload=w, n_clients=32)
        assert tune.best.placement == base.best.placement
        assert tune.best.worst_p99 == base.best.worst_p99  # bit-exact
        assert set(tune.per_placement) == set(base.per_placement)
        for name, choice in base.per_placement.items():
            assert tune.per_placement[name].worst_p99 == choice.worst_p99
            assert tune.per_placement[name].machines == choice.machines


def test_relabeled_validates_and_round_trips():
    perm = (2, 0, 1)
    g = GEO_WAN.relabeled(perm)
    assert g.regions == ("ap", "us", "eu")
    assert g.rtt[g.regions.index("us")][g.regions.index("eu")] == 80
    inv = tuple(perm.index(i) for i in range(3))
    assert g.relabeled(inv) == GEO_WAN
    with pytest.raises(ValueError):
        GEO_WAN.relabeled((0, 0, 2))


def test_directed_rtt_matrix_is_legal_and_reads_per_direction():
    """Asymmetric matrices (a congested heal path after a region outage)
    validate, and every hop reads its own directed half-RTT; the
    ``symmetric`` property tells the two worlds apart."""
    g = GeoSpec(regions=("us", "eu"), rtt=((0.0, 80.0), (120.0, 0.0)))
    assert not g.symmetric
    assert GEO_WAN.symmetric
    assert g.one_way(0, 1) == 40.0
    assert g.one_way(1, 0) == 60.0        # the slow return direction
    assert g.one_way(0, 0) == 0.0
    assert g.hop_delay(0, 1) == g.local_delay + 40.0
    assert g.hop_delay(1, 0) == g.local_delay + 60.0
    # relabeling transposes coherently: the directed pair swaps with it
    r = g.relabeled((1, 0))
    assert not r.symmetric
    assert r.rtt[r.regions.index("eu")][r.regions.index("us")] == 120.0
    # the usual shape validation still bites
    with pytest.raises(ValueError):
        GeoSpec(regions=("us", "eu"), rtt=((0.0, -1.0), (1.0, 0.0)))
    with pytest.raises(ValueError):
        GeoSpec(regions=("us", "eu"), rtt=((5.0, 80.0), (80.0, 0.0)))


# ---------------------------------------------------------------------------
# Wire semantics: timers stay local, jitter stacks
# ---------------------------------------------------------------------------


class _Probe(Node):
    def __init__(self, addr):
        super().__init__(addr)
        self.arrivals = []

    def on_message(self, src, msg):
        self.arrivals.append((src, msg, self.net.now))


def test_latency_fn_never_stretches_timers():
    """A WAN matrix on the wire must not slow self-addressed timer
    deliveries: set_timer passes an explicit delay, which wins."""
    net = Network(latency_fn=lambda s, d: 50.0)
    a, b = _Probe("a"), _Probe("b")
    net.add_nodes([a, b])
    a.send("b", "wire")
    a.set_timer("tick", 2.0)
    net.run()
    assert b.arrivals[0][2] == 50.0       # matrix delay on the wire
    (_, timer, t), = [x for x in a.arrivals]
    assert t == 2.0                       # timer fired at its local delay


def test_jitter_stacks_on_matrix_delay():
    net = Network(seed=7, jitter=3.0, latency_fn=lambda s, d: 50.0)
    a, b = _Probe("a"), _Probe("b")
    net.add_nodes([a, b])
    for _ in range(16):
        a.send("b", "x")
    net.run()
    times = [t for _, _, t in b.arrivals]
    assert all(50.0 <= t < 53.0 for t in times), times
    assert max(times) > 50.0              # jitter actually drawn


# ---------------------------------------------------------------------------
# Batched plane: per-region lanes
# ---------------------------------------------------------------------------


def test_batched_geo_lanes():
    cfgs = [{"variant": "compartmentalized", "n_proxy_leaders": 2,
             "n_replicas": 2}]
    geo = GeoSpec(regions=("us", "eu", "ap"),
                  rtt=((0, 8, 16), (8, 0, 12), (16, 12, 0)))
    res = execute_configs(cfgs, workload=W, n_commands=24, seeds=2, geo=geo)
    assert len(res) == 3                  # one lane per region
    assert res.lane_region is not None and res.wan_offset is not None
    assert np.all(res.wan_offset > 0.0)
    lat = res.region_latency(0, "p99")
    assert set(lat) == set(geo.regions)
    assert all(v > 0.0 for v in lat.values())
    # lane command split follows the client weights (uniform -> even-ish)
    lanes = res.shard_lanes(0)
    assert int(res.lane_commands[lanes].sum()) == 24


def test_batched_uniform_geo_matches_plain():
    """Uniform matrix: zero WAN offset, and the per-station measured
    msgs/cmd aggregate to the same totals as a no-geo run."""
    cfg = {"variant": "compartmentalized", "n_proxy_leaders": 2,
           "n_replicas": 2}
    plain = execute_configs([cfg], workload=W, n_commands=24, seeds=2)
    uni = execute_configs([cfg], workload=W, n_commands=24, seeds=2,
                          geo=GeoSpec.uniform(3))
    assert np.all(uni.wan_offset == 0.0)
    lanes = uni.shard_lanes(0)
    agg = uni.station_msgs[lanes].sum(axis=0) * (1.0 / len(lanes))
    # same engine, same per-command behavior: station totals agree
    np.testing.assert_allclose(agg.sum(), plain.station_msgs[0].sum(),
                               rtol=0.2)


def test_validate_batched_under_geo():
    rep = validate_batched("compartmentalized", workload=W, n_commands=24,
                           seeds=2,
                           geo=GeoSpec(regions=("us", "eu", "ap"),
                                       rtt=((0, 8, 16), (8, 0, 12),
                                            (16, 12, 0))))
    assert rep.passed, str(rep)


def test_batched_geo_and_sharding_are_exclusive():
    from repro.core import ShardingSpec
    with pytest.raises(ValueError):
        execute_configs([{"variant": "compartmentalized"}], workload=W,
                        n_commands=8, seeds=1,
                        sharding=ShardingSpec(n_shards=2),
                        geo=GeoSpec.uniform(3))


# ---------------------------------------------------------------------------
# Region-partition transient schedule
# ---------------------------------------------------------------------------


def test_region_partition_schedule_factors():
    """Survivors absorb c/(c-m); a fully-pinned station freezes."""
    from repro.core import compile_models, model_for
    cfg = {"variant": "compartmentalized", "n_proxy_leaders": 2,
           "n_replicas": 2}
    model = model_for(cfg)
    base = compile_models([model], [cfg]).demands(W) / 2e5
    # pin the leader tier entirely inside us; everything else round-robin
    geo = GeoSpec(regions=("us", "eu", "ap"),
                  rtt=((0, 80, 160), (80, 0, 120), (160, 120, 0)),
                  placement=(("leader", (0,)),))
    sched, bounds = region_partition_schedule(base, model, geo, "us",
                                              start=0.4, stop=0.6,
                                              n_steps=1000)
    assert sched.shape[0] == len(bounds) == 3      # pre / during / post
    np.testing.assert_array_equal(sched[0], sched[2])  # heals exactly
    np.testing.assert_array_equal(sched[0], np.asarray(base))
    k_leader = STATION_ORDER.index("leader")
    k_proxy = STATION_ORDER.index("proxy")
    assert sched[1, 0, k_leader] > 1e6 * sched[0, 0, k_leader]  # CRASH
    # 2 proxies round-robin -> one lost -> survivors double up
    np.testing.assert_allclose(sched[1, 0, k_proxy],
                               2.0 * sched[0, 0, k_proxy])
    with pytest.raises(ValueError):
        region_partition_schedule(base, model, geo, "nowhere")
    with pytest.raises(ValueError):
        region_partition_schedule(base, model, geo, "us", start=0.7,
                                  stop=0.2)


# ---------------------------------------------------------------------------
# Calibration regression pin
# ---------------------------------------------------------------------------


def test_calibrate_alpha_geo_regression_pin(geo3):
    """The measured anchor is exactly unchanged by a uniform matrix and
    drifts < 5% under a spread one (modeled-RTT subtraction); the
    analytical anchor refuses a geo matrix outright."""
    a0 = calibrate_alpha(measured=True)
    assert calibrate_alpha(measured=True, geo=GeoSpec.uniform(3)) == a0
    a_geo = calibrate_alpha(measured=True, geo=geo3)
    assert abs(a_geo - a0) / a0 < 0.05
    with pytest.raises(TypeError):
        calibrate_alpha(measured=False, geo=geo3)


# ---------------------------------------------------------------------------
# Thrifty bpaxos: message-exact on both planes
# ---------------------------------------------------------------------------


def test_bpaxos_thrifty_parity_both_planes():
    rep = validate_variant("bpaxos", {"thrifty": True}, workload=W,
                           n_commands=30, seed=0)
    assert rep.passed, str(rep)
    brep = validate_batched("bpaxos", {"thrifty": True}, workload=W,
                            n_commands=24, seeds=2)
    assert brep.passed, str(brep)


def test_bpaxos_thrifty_sends_fewer_dep_messages():
    full = run_variant("bpaxos", {"thrifty": False}, workload=W,
                       n_commands=24, seed=0)
    thrifty = run_variant("bpaxos", {"thrifty": True}, workload=W,
                          n_commands=24, seed=0)
    assert thrifty.linearizable
    assert (thrifty.station_msgs["dep_service"]
            < full.station_msgs["dep_service"])
