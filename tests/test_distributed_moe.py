"""All-to-all expert-parallel MoE (runtime/moe_a2a.py) vs the dense oracle.

Runs on a 2x2 forced-device mesh in a subprocess (1-device hygiene in the
main process).  With generous capacity the a2a path is drop-free and must
match ``apply_moe_dense`` numerically."""
import subprocess
import sys
import textwrap


def run_sub(body: str, n_devices: int = 4, timeout: int = 480) -> str:
    code = ("import os\n"
            f'os.environ["XLA_FLAGS"] = '
            f'"--xla_force_host_platform_device_count={n_devices}"\n'
            "import jax, jax.numpy as jnp, numpy as np\n"
            + textwrap.dedent(body))
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_a2a_moe_matches_dense_oracle():
    run_sub("""
    from repro.models.moe import MoEConfig, init_moe, apply_moe_dense
    from repro.runtime.moe_a2a import make_moe_a2a

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=32, n_shared=1,
                    capacity_factor=8.0)  # generous: drop-free
    d_model = 16
    params = init_moe(jax.random.key(0), d_model, cfg, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 8, d_model))

    fn = make_moe_a2a(mesh, cfg, "swiglu", d_model)
    out, aux = jax.jit(fn)(params, x)
    expect, aux_e = apply_moe_dense(params, x, cfg, "swiglu")
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)
    # aux is the pmean of per-shard load-balance losses (the standard
    # distributed estimator) vs the oracle's global one: close, not equal
    np.testing.assert_allclose(float(aux), float(aux_e), rtol=0.25)
    print("OK")
    """)


def test_a2a_moe_emits_all_to_all_not_gather():
    """The point of the exercise: the compiled HLO must contain all-to-alls
    and no token all-gathers."""
    run_sub("""
    from repro.models.moe import MoEConfig, init_moe
    from repro.runtime.moe_a2a import make_moe_a2a

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=32, capacity_factor=2.0)
    d_model = 16
    params = init_moe(jax.random.key(0), d_model, cfg, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 8, d_model))
    fn = make_moe_a2a(mesh, cfg, "swiglu", d_model)
    hlo = jax.jit(fn).lower(params, x).compile().as_text()
    assert "all-to-all" in hlo, "dispatch must lower to all-to-all"
    print("OK")
    """)


def test_a2a_moe_capacity_drops_are_bounded():
    """With tight capacity some (token, expert) pairs drop; outputs must
    still be finite and within the convex hull of expert outputs."""
    run_sub("""
    from repro.models.moe import MoEConfig, init_moe
    from repro.runtime.moe_a2a import make_moe_a2a

    mesh = jax.make_mesh((2, 2), ("data", "model"))
    cfg = MoEConfig(n_experts=8, top_k=2, d_expert=32, capacity_factor=0.5)
    d_model = 16
    params = init_moe(jax.random.key(0), d_model, cfg, "swiglu", jnp.float32)
    x = jax.random.normal(jax.random.key(1), (4, 8, d_model))
    fn = make_moe_a2a(mesh, cfg, "swiglu", d_model)
    out, aux = jax.jit(fn)(params, x)
    assert bool(jnp.all(jnp.isfinite(out)))
    print("OK")
    """)
