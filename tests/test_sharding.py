"""The shard axis on the analytical planes.

Pins the acceptance criteria of the sharding PR that live below the
execution plane: visit-ratio demand lowering (uniform S shards scale the
bottleneck-law peak exactly S-fold, >= 3.5x at S = 4), the flattened
sharded MVA path agreeing with per-shard scalar MVA, hash routing
balancing keys, skew-aware budget splits from the sharded autotuner, the
per-key linearizability decomposition agreeing with the whole-history
checker, and the resharding transient schedule's dip/recover shape.
"""
import numpy as np
import pytest

from repro.core.analytical import STATION_ORDER, calibrate_alpha
from repro.core.api import (
    WRITE_ONLY,
    ShardingSpec,
    UNSHARDED,
    Workload,
)
from repro.core.autotune import autotune_sharded
from repro.core.history import History
from repro.core.linearizability import check_linearizable
from repro.core.sharding import (
    check_linearizable_partitioned,
    flatten_shards,
    partition_history,
    partition_ops,
    shard_column,
    shard_demands,
    shard_weights,
    split_counts,
    split_weights,
)
from repro.core.sweep import SweepSpec, compile_sweep
from repro.core.transient import resharding_schedule, simulate_transient

ALPHA = calibrate_alpha()


def _sweep(**axes):
    defaults = dict(f=1, n_proxy_leaders=(3,), grids=((2, 2),),
                    n_replicas=(2,))
    defaults.update(axes)
    return compile_sweep(SweepSpec(**defaults))


# ---------------------------------------------------------------------------
# ShardingSpec: validation, weights, routing
# ---------------------------------------------------------------------------


def test_spec_validation():
    with pytest.raises(ValueError):
        ShardingSpec(n_shards=0)
    with pytest.raises(ValueError):
        ShardingSpec(n_shards=2, weights=(1.0,))          # wrong arity
    with pytest.raises(ValueError):
        ShardingSpec(n_shards=2, weights=(-1.0, 2.0))     # negative
    with pytest.raises(ValueError):
        ShardingSpec(n_shards=2, weights=(0.0, 0.0))      # zero sum
    assert UNSHARDED.n_shards == 1


def test_routing_is_stable_and_total():
    sh = ShardingSpec(n_shards=4)
    for key in ["hot", "k0", 17, ("a", 1)]:
        s = sh.shard_of(key)
        assert 0 <= s < 4
        assert sh.shard_of(key) == s         # crc32, not PYTHONHASHSEED
    assert sh.hot_shard == sh.shard_of("hot")


def test_routing_balances_uniform_keys():
    # deterministic sibling of the hypothesis property: crc32 routing
    # spreads a generic key population evenly within tolerance
    for n_shards in (2, 4, 8):
        sh = ShardingSpec(n_shards=n_shards)
        counts = np.zeros(n_shards)
        n_keys = 4000
        for i in range(n_keys):
            counts[sh.shard_of(f"user:{i}")] += 1
        assert counts.min() > 0
        # each shard within 25% of the fair share
        fair = n_keys / n_shards
        assert np.all(np.abs(counts - fair) < 0.25 * fair), counts


def test_resolved_weights_uniform_and_skewed():
    assert ShardingSpec(4).resolved_weights() == (0.25,) * 4
    w = Workload(f_write=1.0, skew_p=0.6)
    sh = ShardingSpec(4)
    ws = sh.resolved_weights(w)
    hot = sh.hot_shard
    base = (1.0 - 0.6) / 4
    assert ws[hot] == pytest.approx(base + 0.6)
    for s in range(4):
        if s != hot:
            assert ws[s] == pytest.approx(base)
    assert sum(ws) == pytest.approx(1.0)
    # explicit weights win and are normalized
    ws2 = ShardingSpec(2, weights=(3.0, 1.0)).resolved_weights(w)
    assert ws2 == pytest.approx((0.75, 0.25))


def test_split_counts_exact_and_fair():
    c = split_counts(48, [0.25] * 4)
    assert c.tolist() == [12, 12, 12, 12]
    c = split_counts(10, [0.7, 0.1, 0.1, 0.1])
    assert c.sum() == 10 and c[0] == 7
    c = split_counts(7, [0.5, 0.5])
    assert sorted(c.tolist()) == [3, 4]


# ---------------------------------------------------------------------------
# Demand lowering: the bottleneck law scales, the MVA path agrees
# ---------------------------------------------------------------------------


def test_shard_demands_shape_and_scale():
    d = np.array([[2.0, 4.0, 0.0, 1.0]])
    sh = ShardingSpec(2, weights=(0.75, 0.25))
    sd = shard_demands(d, sh)
    assert sd.shape == (1, 2, 4)
    np.testing.assert_allclose(sd[0, 0], 0.75 * d[0])
    np.testing.assert_allclose(sd[0, 1], 0.25 * d[0])
    flat = flatten_shards(sd)
    assert flat.shape == (1, 8)
    assert flat[0, shard_column(1, 1, 4)] == pytest.approx(1.0)


@pytest.mark.parametrize("n_shards", [1, 2, 4, 8])
def test_uniform_peak_scales_linearly(n_shards):
    """ISSUE acceptance: uniform-workload peak throughput scales >= 3.5x
    over 1 shard at 4 shards on the analytical plane (it is exactly S)."""
    sweep = _sweep()
    base = sweep.peak_throughput(ALPHA, WRITE_ONLY)
    sharded = sweep.peak_throughput(ALPHA, WRITE_ONLY,
                                    sharding=ShardingSpec(n_shards))
    np.testing.assert_allclose(sharded, n_shards * base, rtol=1e-12)
    if n_shards == 4:
        assert float(sharded[0]) >= 3.5 * float(base[0])


def test_skewed_peak_is_hot_shard_bound():
    w = Workload(f_write=1.0, skew_p=0.6)
    sh = ShardingSpec(4)
    sweep = _sweep()
    peak = sweep.peak_throughput(ALPHA, w, sharding=sh)
    hot_w = max(sh.resolved_weights(w))
    expect = sweep.peak_throughput(ALPHA, w) / hot_w
    np.testing.assert_allclose(peak, expect, rtol=1e-12)
    # and the named bottleneck points at the hot shard
    name = sweep.bottlenecks(w, sharding=sh)[0]
    assert name.startswith(f"s{sh.hot_shard}/")


def test_sharded_mva_matches_per_shard_scalar_mva():
    """Flattened [M, S*K] through the one jitted call == solving each
    shard's scaled demand vector independently."""
    sh = ShardingSpec(2, weights=(0.7, 0.3))
    sweep = _sweep()
    n, x, r = sweep.mva(ALPHA, n_clients_max=64, workload=WRITE_ONLY,
                        sharding=sh)
    assert x.shape == (1, 64)
    # reference: each shard alone is a 1-row sweep with scaled demands
    d = sweep.demands(WRITE_ONLY)
    from repro.core.simulator import mva_curves_from_demands
    xs = []
    for wgt in (0.7, 0.3):
        _, x_s, _ = mva_curves_from_demands(wgt * d / ALPHA, 64)
        xs.append(x_s[0])
    # the joint tandem visits every shard's stations per command, so the
    # flattened curve is bounded by (and converges to) the min-law of the
    # slowest shard at saturation
    assert float(x[0, -1]) == pytest.approx(min(float(v[-1]) for v in xs),
                                            rel=0.05)


def test_sharded_demands_tensor_orientation():
    w = Workload(f_write=1.0, skew_p=0.5)
    sh = ShardingSpec(2)
    sweep = _sweep()
    d3 = sweep.demands(w, sharding=sh)
    assert d3.ndim == 3 and d3.shape[1] == 2
    np.testing.assert_allclose(d3.sum(axis=1), sweep.demands(w), rtol=1e-12)


# ---------------------------------------------------------------------------
# Sharded autotune: budget splits follow the skew
# ---------------------------------------------------------------------------


def test_autotune_sharded_uniform_is_balanced():
    res = autotune_sharded(40, ALPHA, ShardingSpec(4), workload=WRITE_ONLY)
    budgets = [c.budget for c in res.shards]
    assert sum(budgets) <= 40
    assert max(budgets) - min(budgets) <= 1, budgets
    assert res.total_peak > 0


def test_autotune_sharded_skew_shifts_machines_to_hot_shard():
    w = Workload(f_write=1.0, skew_p=0.6)
    sh = ShardingSpec(4)
    res = autotune_sharded(40, ALPHA, sh, workload=w)
    budgets = {c.shard: c.budget for c in res.shards}
    hot = sh.hot_shard
    assert all(budgets[hot] > b for s, b in budgets.items() if s != hot), \
        budgets
    # effective (weight-deflated) peaks are what the min-law sees; the
    # greedy split must not leave the hot shard as a trivial outlier
    effs = [c.effective for c in res.shards]
    assert res.total_peak == pytest.approx(min(effs))
    assert res.bottleneck_shard in budgets


def test_autotune_sharded_rejects_starving_budgets():
    with pytest.raises(ValueError):
        autotune_sharded(7, ALPHA, ShardingSpec(4), workload=WRITE_ONLY)


# ---------------------------------------------------------------------------
# Linearizability decomposition: per-key == whole history
# ---------------------------------------------------------------------------


def _kv_history(events):
    h = History()
    for client, op, result, t0, t1 in events:
        op_id = h.invoke(client, op, t0)
        h.respond(op_id, result, t1)
    return h


def _good_history():
    return _kv_history([
        (1, ("put", "a", 1), "ok", 0.0, 2.0),
        (2, ("put", "b", 9), "ok", 0.5, 1.5),
        (1, ("get", "a"), 1, 3.0, 4.0),
        (2, ("get", "b"), 9, 3.0, 4.0),
    ])


def _bad_history():
    # stale read on key "a": put committed long before the get
    return _kv_history([
        (1, ("put", "a", 1), "ok", 0.0, 1.0),
        (2, ("get", "a"), None, 2.0, 3.0),
        (1, ("put", "b", 5), "ok", 0.0, 1.0),
        (2, ("get", "b"), 5, 2.0, 3.0),
    ])


def test_partitioned_checker_accepts_good_rejects_bad():
    assert check_linearizable_partitioned(_good_history())
    assert not check_linearizable_partitioned(_bad_history())


def test_partition_agrees_with_whole_checker_on_random_histories():
    """Deterministic sibling of the hypothesis property: on small random
    cross-key histories (some valid, some corrupted) the per-key
    decomposition and the whole-history checker return the same verdict.
    Locality guarantees this; the test pins the implementation."""
    import random
    rng = random.Random(1234)
    n_agree = 0
    for trial in range(40):
        events = []
        t = 0.0
        state = {}
        for i in range(8):
            client = rng.randrange(2) + 1
            key = rng.choice(["x", "y", "z"])
            t0 = t + rng.random() * 0.3
            t1 = t0 + 0.5 + rng.random() * 0.4
            if rng.random() < 0.5:
                state[key] = i
                events.append((client, ("put", key, i), "ok", t0, t1))
            else:
                val = state.get(key)
                if rng.random() < 0.2:      # corrupt some reads
                    val = -1
                events.append((client, ("get", key), val, t0, t1))
            t = t0
        h = _kv_history(events)
        h2 = _kv_history(events)
        whole = check_linearizable(h, sm_kind="kv")
        split = check_linearizable_partitioned(h2)
        assert whole == split, events
        n_agree += 1
    assert n_agree == 40


def test_partition_history_groups_by_part_of():
    h = _good_history()
    sh = ShardingSpec(2)
    parts = partition_history(h, sh.shard_of)
    assert sum(len(p.ops) for p in parts.values()) == len(h.ops)
    for part, sub in parts.items():
        for o in sub.ops:
            assert sh.shard_of(o.op[1]) == part
    # per-shard grouping passes wherever per-key does (coarser grouping)
    assert check_linearizable_partitioned(h, part_of=sh.shard_of)


def test_partition_ops_routes_by_key_and_keyless_to_zero():
    sh = ShardingSpec(3)
    ops = [("put", f"k{i}", i) for i in range(30)] + [("w", 7)]
    parts = partition_ops(ops, sh)
    assert sum(len(v) for v in parts.values()) == 31
    assert ("w", 7) in parts[0]
    for s, sub in parts.items():
        for op in sub:
            if op[0] == "put":
                assert sh.shard_of(op[1]) == s


# ---------------------------------------------------------------------------
# Resharding schedule: hot-shard split predicts dip-then-overshoot
# ---------------------------------------------------------------------------


def test_split_weights_halves_the_hot_shard():
    w = Workload(f_write=1.0, skew_p=0.6)
    sh = ShardingSpec(2)
    pre, post, hot = split_weights(sh, w)
    assert pre.shape == (3,) and post.shape == (3,)
    assert pre[-1] == 0.0
    assert post[hot] == pytest.approx(pre[hot] / 2)
    assert post[-1] == pytest.approx(pre[hot] / 2)
    assert pre.sum() == pytest.approx(1.0) == pytest.approx(post.sum())


def test_resharding_transient_shape():
    """The scripted hot-shard split: throughput dips during migration
    (the hot shard is dark) and recovers ABOVE the pre-split level (its
    traffic is now served by two groups) - the prediction the live
    replay in test_sharded_execution must reproduce."""
    w = Workload(f_write=1.0, skew_p=0.6)
    sh = ShardingSpec(2)
    sweep = _sweep()
    base = sweep.demands(w)[0:1] / ALPHA
    sched, bounds = resharding_schedule(base, sh, start=0.4, stop=0.55,
                                        n_steps=1200, workload=w)
    assert sched.shape[0] == 3                       # pre / migration / post
    k = len(STATION_ORDER)
    assert sched.shape[-1] == 3 * k                  # S + 1 shard lanes
    tr = simulate_transient(sched, bounds, n_clients=32, seeds=4,
                            n_steps=1200)
    x = tr.window_throughput(bounds)[0].mean(axis=0)  # [3] windows
    pre_x, dip_x, post_x = float(x[0]), float(x[1]), float(x[2])
    assert pre_x > 0
    assert dip_x < 0.6 * pre_x, (dip_x, pre_x)
    assert post_x > 1.1 * pre_x, (post_x, pre_x)


def test_resharding_schedule_validates_window():
    sweep = _sweep()
    base = sweep.demands(WRITE_ONLY)[0:1] / ALPHA
    with pytest.raises(ValueError):
        resharding_schedule(base, ShardingSpec(2), start=0.7, stop=0.6)


def test_shard_weights_vector_matches_spec():
    w = Workload(f_write=1.0, skew_p=0.4)
    sh = ShardingSpec(4)
    np.testing.assert_allclose(shard_weights(sh, w),
                               np.asarray(sh.resolved_weights(w)))
