"""Serving-plane tests: weight updates as writes, inference as leaderless
reads, consistency modes, batcher/unbatcher path, continuous batching."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import decode_step, init_params, prefill
from repro.serving.scheduler import ContinuousBatcher, Request
from repro.serving.server import ServingDeployment


@pytest.fixture(scope="module")
def smoke_model():
    cfg = get_config("granite-3-2b").smoke()
    params = init_params(cfg, jax.random.key(0))
    return cfg, params


@pytest.fixture()
def fleet(smoke_model):
    cfg, params = smoke_model
    dep = ServingDeployment(cfg, n_replicas=3, n_clients=2)
    dep.push_weights(params)
    return dep


def test_inference_is_a_read_not_a_log_write(fleet):
    slots_before = fleet.rsm.leader.next_slot
    fleet.infer([1, 2, 3], max_new=2)
    assert fleet.rsm.leader.next_slot == slots_before, \
        "inference must bypass the leader (leaderless read path)"


def test_inference_returns_tokens(fleet, smoke_model):
    cfg, params = smoke_model
    version, toks = fleet.infer([1, 2, 3], max_new=3)
    assert version == "v1"
    assert len(toks) == 3
    assert all(0 <= t < cfg.vocab_size for t in toks)


def test_inference_matches_direct_decode(fleet, smoke_model):
    """The serving fleet must produce exactly the single-model answer."""
    cfg, params = smoke_model
    prompt = [5, 6, 7, 8]
    _, served = fleet.infer(prompt, max_new=4)

    tokens = jnp.asarray(prompt, jnp.int32)[None]
    _, caches = prefill(cfg, params, tokens, cache_len=len(prompt) + 4)
    tok = tokens[:, -1:]
    direct = []
    for _ in range(4):
        logits, caches = decode_step(cfg, params, caches, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        direct.append(int(tok[0, 0]))
    assert list(served) == direct


def test_weight_update_visible_to_subsequent_reads(fleet, smoke_model):
    cfg, _ = smoke_model
    v1, toks1 = fleet.infer([1, 2, 3], max_new=2)
    new_params = init_params(cfg, jax.random.key(42))
    fleet.push_weights(new_params)
    v2, toks2 = fleet.infer([1, 2, 3], max_new=2)
    assert v1 == "v1" and v2 == "v2", \
        "linearizable read must observe the committed weight update"


def test_reads_spread_across_replicas(fleet):
    fleet.submit_many([[1, 2]] * 12, max_new=1)
    loads = fleet.replica_loads()
    assert sum(loads) >= 12
    assert max(loads) < sum(loads), "reads must not funnel to one replica"


def test_eventual_consistency_skips_acceptors(smoke_model):
    cfg, params = smoke_model
    dep = ServingDeployment(cfg, n_replicas=2, n_clients=1,
                            consistency="eventual")
    dep.push_weights(params)
    acceptor_msgs_before = sum(a.msgs_received for a in dep.rsm.acceptors)
    dep.infer([1, 2], max_new=1)
    acceptor_msgs_after = sum(a.msgs_received for a in dep.rsm.acceptors)
    assert acceptor_msgs_after == acceptor_msgs_before, \
        "eventual reads must not touch the acceptors (paper section 3.6)"


def test_linearizable_read_prereads_a_quorum(smoke_model):
    cfg, params = smoke_model
    dep = ServingDeployment(cfg, n_replicas=2, n_clients=1,
                            consistency="linearizable")
    dep.push_weights(params)
    before = sum(a.msgs_received for a in dep.rsm.acceptors)
    dep.infer([1, 2], max_new=1)
    after = sum(a.msgs_received for a in dep.rsm.acceptors)
    assert after > before, "linearizable reads preread the acceptor grid"


# ---------------------------------------------------------------------------
# continuous batching
# ---------------------------------------------------------------------------


def test_continuous_batcher_drains_all_requests(smoke_model):
    cfg, params = smoke_model
    cb = ContinuousBatcher(cfg, params, n_slots=3, max_len=32)
    reqs = [Request(rid=i, prompt=[1, 2, 3, 4], max_new=3) for i in range(7)]
    for r in reqs:
        cb.submit(r)
    cb.run_until_drained()
    assert all(r.done for r in reqs)
    assert all(len(r.out) == 3 for r in reqs)
    # slots were reused: more requests than slots, decent occupancy
    assert cb.mean_occupancy > 1.5


def test_continuous_batcher_matches_sequential_decode(smoke_model):
    cfg, params = smoke_model
    prompt = [2, 3, 4]
    cb = ContinuousBatcher(cfg, params, n_slots=2, max_len=16)
    r = Request(rid=0, prompt=prompt, max_new=3)
    cb.submit(r)
    cb.run_until_drained()

    tokens = jnp.asarray(prompt, jnp.int32)[None]
    _, caches = prefill(cfg, params, tokens, cache_len=16)
    tok = tokens[:, -1:]
    expect = []
    for _ in range(3):
        logits, caches = decode_step(cfg, params, caches, tok)
        tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
        expect.append(int(tok[0, 0]))
    assert r.out == expect
