"""End-to-end protocol behaviour: compartmentalized MultiPaxos, vanilla
MultiPaxos, failover, acceptor failures, batching, read consistency modes."""
import pytest

from repro.core import (
    CompartmentalizedMultiPaxos,
    DeploymentConfig,
    UnreplicatedStateMachine,
    full_compartmentalized,
    vanilla_multipaxos,
)
from repro.core.linearizability import (
    check_linearizable,
    check_register_reads,
    check_slot_order,
)


def run_workload(dep, ops_per_client):
    for client, ops in zip(dep.clients, ops_per_client):
        client.run_ops(ops)
    dep.run_to_quiescence()
    assert dep.all_done(), "all clients must finish"
    return dep


def test_vanilla_multipaxos_basic():
    dep = vanilla_multipaxos(f=1, n_clients=2)
    run_workload(dep, [
        [("put", "x", 1), ("get", "x")],
        [("put", "y", 2), ("get", "y")],
    ])
    assert dep.results_of(0) == ["ok", 1]
    assert dep.results_of(1) == ["ok", 2]


def test_compartmentalized_basic():
    dep = full_compartmentalized(f=1, n_clients=3)
    run_workload(dep, [
        [("put", "a", i), ("get", "a")] for i in range(3)
    ])
    for i in range(3):
        res = dep.results_of(i)
        assert res[0] == "ok"
        assert res[1] in (0, 1, 2)  # one of the concurrently written values


def test_replicas_stay_in_sync():
    dep = full_compartmentalized(f=1, n_clients=2)
    run_workload(dep, [
        [("put", f"k{i}", i) for i in range(5)],
        [("put", f"j{i}", i) for i in range(5)],
    ])
    states = [r.sm.snapshot() for r in dep.replicas]
    assert all(s == states[0] for s in states), "replica state divergence"
    logs = [dict(r.log) for r in dep.replicas]
    assert all(l == logs[0] for l in logs), "replica log divergence"


def test_linearizable_history_slot_order():
    dep = full_compartmentalized(f=1, n_clients=3, state_machine="register")
    run_workload(dep, [
        [("w", 10), ("r",), ("w", 11)],
        [("r",), ("w", 20), ("r",)],
        [("w", 30), ("r",)],
    ])
    assert check_slot_order(dep.history) == []
    assert check_register_reads(dep.history) == []
    assert check_linearizable(dep.history, "register")


def test_exhaustive_linearizability_small():
    dep = full_compartmentalized(f=1, n_clients=2, state_machine="register")
    run_workload(dep, [
        [("w", 1), ("r",)],
        [("w", 2), ("r",)],
    ])
    assert check_linearizable(dep.history, "register")


def test_leader_failover_preserves_chosen_values():
    dep = full_compartmentalized(f=1, n_clients=1)
    dep.clients[0].run_ops([("put", "x", 1), ("put", "y", 2)])
    dep.run_to_quiescence()
    assert dep.results_of(0) == ["ok", "ok"]

    # crash leader 0, promote leader 1; previously chosen values must survive
    dep.fail_over(to_leader=1)
    dep.run_to_quiescence()
    assert dep.leaders[1].active

    dep.clients[0].leader = dep.leader_addrs[1]
    dep.clients[0].run_ops([("get", "x"), ("get", "y"), ("put", "z", 3)])
    dep.run_to_quiescence()
    assert dep.results_of(0)[2:] == [1, 2, "ok"]
    assert check_slot_order(dep.history) == []


def test_acceptor_failure_tolerated():
    """Killing one acceptor of a 2x2 grid leaves a live column via the
    non-thrifty retry path."""
    dep = full_compartmentalized(f=1, n_clients=1, grid=(2, 2))
    dep.net.crash("acceptor/0")
    dep.clients[0].run_ops([("put", "x", 1), ("get", "x")])
    dep.run_to_quiescence()
    assert dep.results_of(0) == ["ok", 1]


def test_proxy_leader_failure_is_routed_around():
    """With >= f+1 proxy leaders, losing one must not lose commands that the
    leader retries (client retries drive re-proposal)."""
    dep = full_compartmentalized(f=1, n_clients=1, n_proxy_leaders=3,
                                 client_retries=True)
    dep.net.crash("proxy/0")
    dep.clients[0].run_ops([("put", "a", 1), ("put", "b", 2), ("put", "c", 3)])
    dep.run_to_quiescence(max_steps=100_000)
    assert dep.results_of(0) == ["ok", "ok", "ok"]


def test_sequential_consistency_mode():
    dep = full_compartmentalized(f=1, n_clients=2, consistency="sequential",
                                 state_machine="register")
    run_workload(dep, [
        [("w", 1), ("r",)],
        [("w", 2), ("r",)],
    ])
    # read-your-writes: each client's own read must see its write or a later one
    assert dep.results_of(0)[1] in (1, 2)
    assert dep.results_of(1)[1] in (1, 2)


def test_eventual_consistency_mode():
    dep = full_compartmentalized(f=1, n_clients=1, consistency="eventual")
    run_workload(dep, [[("put", "x", 5), ("get", "x")]])
    # single client, quiesced network: must observe its own write
    assert dep.results_of(0) == ["ok", 5]


def test_batching_end_to_end():
    dep = full_compartmentalized(
        f=1, n_clients=4, n_batchers=2, n_unbatchers=2, batch_size=3)
    run_workload(dep, [
        [("put", f"k{i}", i), ("get", f"k{i}")] for i in range(4)
    ])
    for i in range(4):
        assert dep.results_of(i) == ["ok", i]


def test_unreplicated_state_machine():
    dep = UnreplicatedStateMachine(n_clients=2)
    run_workload(dep, [
        [("put", "x", 1), ("get", "x")],
        [("put", "y", 2), ("get", "y")],
    ])
    assert dep.results_of(0) == ["ok", 1]
    assert dep.results_of(1) == ["ok", 2]


def test_message_drops_with_retries_still_complete():
    cfg_kwargs = dict(f=1, n_clients=1, client_retries=True)
    dep = full_compartmentalized(**cfg_kwargs)
    dep.net.drop_rate = 0.05
    dep.clients[0].run_ops([("put", "x", 1), ("get", "x")])
    dep.run_to_quiescence(max_steps=500_000)
    assert dep.all_done()
    assert dep.results_of(0) == ["ok", 1]


def test_leader_message_load_drops_with_proxies():
    """The core claim of compartmentalization 1: leader handles 3f+4 msgs/cmd
    without proxies, 2 with."""
    n_ops = 20
    vp = vanilla_multipaxos(f=1, n_clients=1)
    vp.clients[0].run_ops([("put", f"k{i}", i) for i in range(n_ops)])
    vp.run_to_quiescence()
    vl = vp.leaders[0]
    vanilla_per_cmd = (vl.msgs_sent + vl.msgs_received) / n_ops

    cp = full_compartmentalized(f=1, n_clients=1)
    cp.clients[0].run_ops([("put", f"k{i}", i) for i in range(n_ops)])
    cp.run_to_quiescence()
    cl = cp.leaders[0]
    comp_per_cmd = (cl.msgs_sent + cl.msgs_received) / n_ops

    assert vanilla_per_cmd >= 3 * 1 + 4  # 3f+4 with f=1
    assert comp_per_cmd <= 2.5           # ~2 (allow phase-1 amortization)


def test_grid_acceptor_write_load():
    """Acceptors in a 2x3 grid each see ~1/3 of writes (paper Fig. 5)."""
    n_ops = 60
    dep = full_compartmentalized(f=1, n_clients=1, grid=(2, 3), n_replicas=2)
    dep.clients[0].run_ops([("put", f"k{i}", i) for i in range(n_ops)])
    dep.run_to_quiescence()
    # each write should touch exactly one column (2 acceptors, 2 msgs each)
    total_acceptor_msgs = sum(a.msgs_received for a in dep.acceptors)
    assert total_acceptor_msgs == pytest.approx(n_ops * 2, rel=0.1)
    per_acceptor = [a.msgs_received for a in dep.acceptors]
    assert max(per_acceptor) <= n_ops  # nobody sees every write
