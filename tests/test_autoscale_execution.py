"""Execution-plane autoscale tests: the registry-derived resize handles
(station_knob_map / resize_config - zero per-variant branches), the
run_autoscaled epoch replay on plain plans, and the pinned end-to-end
loop: a Controller plan from the transient plane replayed live on a
real compartmentalized cluster, linearizable across every resize, with
measured warm-phase dips parity-checking the transient prediction."""
import numpy as np
import pytest

from repro.core import (
    AutoscalePolicy,
    Controller,
    Workload,
    calibrate_alpha,
    default_config,
    diurnal_load,
    resizable_stations,
    resize_config,
    run_autoscaled,
    station_knob_map,
    variant_spec,
)
from repro.core.api import STATION_ORDER

W = Workload(f_write=0.5)


# ---------------------------------------------------------------------------
# Registry-derived resize handles, over every executable variant
# ---------------------------------------------------------------------------


def test_station_knob_map_is_a_true_resize_handle(executable_variant):
    """For every executable variant: each mapped knob moves exactly its
    station's server count by exactly one and nothing else - the
    property the map is derived from, re-checked against the variant's
    own analytical model."""
    name = executable_variant
    mapping = station_knob_map(name)
    assert resizable_stations(name) == tuple(sorted(mapping))
    spec = variant_spec(name)
    cfg = default_config(name)
    base = list(spec.model(cfg, W).demand_slots()[2])
    for station, key in mapping.items():
        assert station in list(STATION_ORDER)
        col = list(STATION_ORDER).index(station)
        up = resize_config(name, cfg, station, +1)
        assert up[key] == cfg[key] + 1
        srv = list(spec.model(up, W).demand_slots()[2])
        assert srv[col] == base[col] + 1
        srv[col] -= 1
        assert srv == base                     # no other station moved
    if not mapping:
        # knobless variants (unreplicated, the vanilla baselines) have
        # no elastic handles - resize is a hard error, not a silent noop
        with pytest.raises(ValueError):
            resize_config(name, cfg, "proxy", +1)


def test_resize_config_validation():
    cfg = default_config("compartmentalized")
    with pytest.raises(ValueError):
        resize_config("compartmentalized", cfg, "acceptor", +1)  # coupled
    with pytest.raises(ValueError):
        resize_config("compartmentalized", cfg, "tail", +1)      # no such
    small = dict(cfg, n_replicas=1)
    with pytest.raises(ValueError):
        resize_config("compartmentalized", small, "replica", -1)  # below 1
    # the original dict is never mutated
    out = resize_config("compartmentalized", cfg, "proxy", -1)
    assert out["n_proxy_leaders"] == cfg["n_proxy_leaders"] - 1
    assert cfg == default_config("compartmentalized")


# ---------------------------------------------------------------------------
# run_autoscaled on a plain-data plan
# ---------------------------------------------------------------------------


def test_run_autoscaled_plain_plan_adds_a_proxy():
    exe = run_autoscaled(
        "compartmentalized",
        [{"window": 1, "station": "proxy", "delta": 1}],
        load=[1.0, 1.0, 0.6], workload=W, n_commands_per_window=18, seed=1)
    assert exe.passed and exe.linearizable and exe.continuity_ok
    assert len(exe.epochs) == 2
    assert (exe.final_config["n_proxy_leaders"]
            == exe.initial_config["n_proxy_leaders"] + 1)
    # machine accounting follows the resize from its window on
    assert exe.machines[1] == exe.machines[0] + 1
    assert exe.machines[2] == exe.machines[1]
    # a plain plan carries no transient prediction: the dip row is
    # recorded but trivially ok
    assert len(exe.dip_rows) == 1
    assert exe.dip_rows[0]["predicted"] is None and exe.dip_rows[0]["ok"]
    # the warm phase costs real virtual time in the action window
    assert exe.window_rates[1] < exe.serve_rates[1]
    assert "autoscaled over 3 windows" in exe.describe()


def test_every_resizable_variant_replays_linearizably(executable_variant):
    """Zero core edits for any registry variant: every executable with
    resize handles replays a one-action plan live - linearizable,
    state-continuous, machine accounting moving with the resize."""
    name = executable_variant
    rz = resizable_stations(name)
    if not rz:
        pytest.skip(f"{name} declares no resize handles")
    exe = run_autoscaled(name,
                         [{"window": 1, "station": rz[0], "delta": 1}],
                         load=[1.0, 1.0], workload=W,
                         n_commands_per_window=12, seed=2)
    assert exe.passed, exe.describe()
    assert exe.machines[1] == exe.machines[0] + 1
    assert len(exe.epochs) == 2


def test_run_autoscaled_rejects_bad_plans():
    with pytest.raises(ValueError):
        run_autoscaled("compartmentalized",
                       [{"window": 9, "station": "proxy", "delta": 1}],
                       load=[1.0, 1.0], workload=W)
    with pytest.raises(ValueError):
        run_autoscaled("compartmentalized",
                       [{"window": 1, "station": "acceptor", "delta": 1}],
                       load=[1.0, 1.0], workload=W)
    with pytest.raises(ValueError):
        run_autoscaled("compartmentalized", [], load=[], workload=W)
    with pytest.raises(ValueError):
        run_autoscaled("vanilla_multipaxos",
                       [{"window": 1, "station": "proxy", "delta": 1}],
                       load=[1.0, 1.0], workload=W)


# ---------------------------------------------------------------------------
# The pinned end-to-end loop: transient plan -> live cluster replay
# ---------------------------------------------------------------------------


def test_controller_plan_replays_linearizably_with_dip_parity():
    """The acceptance gate, shrunk: close the loop on the transient
    plane for a small compartmentalized deployment over a diurnal cycle,
    then replay the emitted plan on the real cluster.  Every resize must
    stay linearizable and state-continuous, and each action window's
    measured dip (serve rate over serve+reconfiguration rate) must match
    the transient prediction within the replay tolerance."""
    alpha = calibrate_alpha()
    w = Workload(f_write=1.0)
    exe_cfg = {"f": 1, "n_proxy_leaders": 4, "grid_rows": 2,
               "grid_cols": 2, "n_replicas": 3}
    ctl = Controller(AutoscalePolicy(target_low=0.45, target_high=0.75,
                                     cooldown_windows=0))
    plan = ctl.run_config(exe_cfg, diurnal_load(5, low=0.35), alpha=alpha,
                          workload=w, seeds=2, probe_steps=500,
                          n_steps=2000)
    assert plan.label == "compartmentalized"
    assert len(plan.actions) > 0
    # run_config restricts actions to the registry's live-resizable set
    allowed = set(resizable_stations("compartmentalized", exe_cfg))
    assert {a.station for a in plan.actions} <= allowed

    exe = run_autoscaled("compartmentalized", plan, config=exe_cfg,
                         workload=w, n_commands_per_window=24, seed=3)
    assert exe.passed, exe.describe()
    assert exe.linearizable and exe.continuity_ok and exe.dips_ok
    # one epoch per distinct action window, plus the initial one
    assert len(exe.epochs) == len({a.window for a in plan.actions}) + 1
    # machine accounting agrees with the transient plan window for window
    assert list(exe.machines) == [int(m) for m in plan.machines]
    # at least one dip row carries a genuine transient prediction and
    # every one sits within tolerance
    preds = [r for r in exe.dip_rows if r["predicted"] is not None]
    assert preds
    for r in preds:
        assert abs(r["measured"] - r["predicted"]) <= exe.tolerance
    # continuity probes returned the pre-resize committed values
    assert all(got == want for _, want, got in exe.continuity)
