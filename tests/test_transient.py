"""Transient-engine tests: steady-state agreement with the MVA / fluid /
DES engines, seeded determinism across vmapped lanes, scripted-event
dynamics (failover dip + recovery, mid-run scale-up), and the batched
(deployments x seeds)-in-one-jitted-call contract."""
import numpy as np
import pytest

from repro.core import (
    Event,
    calibrate_alpha,
    compartmentalized_model,
    compile_sweep,
    des_throughput,
    fluid_throughput,
    multipaxos_model,
    mva_curve,
    scale_schedule,
    schedule_from_demands,
    simulate_transient,
    transient_throughput,
    unreplicated_model,
    SweepSpec,
)
from repro.core.analytical import PAPER_MULTIPAXOS_UNBATCHED
from repro.core.simulator import demand_vector
from repro.core.transient import build_schedule, failover_schedule

ALPHA = calibrate_alpha(PAPER_MULTIPAXOS_UNBATCHED)
CMP = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=2,
                              grid_cols=2, n_replicas=4)


# ---------------------------------------------------------------------------
# Steady-state agreement with the other engines
# ---------------------------------------------------------------------------


def test_steady_state_matches_mva_within_5pct():
    """Acceptance bar: the unbatched compartmentalized deployment's
    post-warmup throughput within 5% of exact MVA (the engine simulates
    the exponential FIFO network MVA solves in closed form)."""
    res = transient_throughput(CMP, ALPHA, n_clients=64, seeds=8,
                               n_steps=4000)
    _, x_mva, r_mva = mva_curve(CMP, ALPHA, n_clients_max=64)
    x = float(res.throughput.mean())
    assert x == pytest.approx(float(x_mva[-1]), rel=0.05)
    # mean latency must satisfy Little's law / match MVA's residence time
    assert float(res.latency_mean.mean()) == pytest.approx(
        float(r_mva[-1]), rel=0.10)
    # quantiles are ordered and bracket the mean sensibly
    assert np.all(res.latency_p50 <= res.latency_p99)
    assert float(res.latency_p99.mean()) > float(res.latency_p50.mean())


def test_steady_state_matches_fluid():
    res = transient_throughput(CMP, ALPHA, n_clients=64, seeds=8,
                               n_steps=4000)
    x_fluid = fluid_throughput(CMP, ALPHA, n_clients=64, sim_time=0.05)
    assert float(res.throughput.mean()) == pytest.approx(x_fluid, rel=0.05)


def test_des_is_the_reference_oracle():
    """The numpy/heapq DES (exact FIFO event order) anchors the scan
    engine: same network, same service distribution, same answer."""
    mp = multipaxos_model(f=1)
    x_des, _ = des_throughput(mp, ALPHA, n_clients=64, n_commands=5000,
                              deterministic_service=False)
    res = transient_throughput(mp, ALPHA, n_clients=64, seeds=8,
                               n_steps=4000)
    assert float(res.throughput.mean()) == pytest.approx(x_des, rel=0.10)


def test_des_warmup_removes_coldstart_bias():
    """`done / t` from t=0 folded the ramp-up into the steady-state
    estimate; the post-warmup window must sit orders of magnitude closer
    to the MVA fixed point (deterministic service: exact)."""
    _, x_mva, _ = mva_curve(CMP, ALPHA, n_clients_max=64)
    x_cold, _ = des_throughput(CMP, ALPHA, n_clients=64, n_commands=2000,
                               warmup_commands=0)
    x_warm, _ = des_throughput(CMP, ALPHA, n_clients=64, n_commands=2000)
    err_cold = abs(x_cold - x_mva[-1]) / x_mva[-1]
    err_warm = abs(x_warm - x_mva[-1]) / x_mva[-1]
    assert err_warm < err_cold
    assert err_warm < 1e-6


def test_single_station_deployment():
    """Self-loop routing (one active station) must still satisfy the
    bottleneck law."""
    un = unreplicated_model()
    res = transient_throughput(un, ALPHA, n_clients=16, seeds=8,
                               n_steps=4000)
    assert float(res.throughput.mean()) == pytest.approx(
        un.peak_throughput(ALPHA), rel=0.10)


# ---------------------------------------------------------------------------
# Batched contract + determinism
# ---------------------------------------------------------------------------


def test_batched_sweep_16x8_lanes_one_call():
    """Acceptance bar: >= 16 deployments x >= 8 seeds in one jitted call,
    each row agreeing with its own bottleneck-law peak at saturation."""
    compiled = compile_sweep(SweepSpec(n_proxy_leaders=(2, 4, 6, 10),
                                       grids=((3, 1), (2, 2)),
                                       n_replicas=(2, 4)))
    assert len(compiled) == 16
    res = compiled.transient(ALPHA, n_clients=64, seeds=8, n_steps=3000)
    assert res.throughput.shape == (16, 8)
    assert res.flows.shape == (16, 8, 3000)
    peaks = compiled.peak_throughput(ALPHA)
    x = res.seed_mean_throughput()
    np.testing.assert_allclose(x, peaks, rtol=0.10)


def test_seeded_determinism_and_seed_independence():
    d = demand_vector(CMP) / ALPHA
    a = simulate_transient(d, n_clients=32, seeds=(0, 1, 2, 3), n_steps=2000)
    b = simulate_transient(d, n_clients=32, seeds=(0, 1, 2, 3), n_steps=2000)
    np.testing.assert_array_equal(a.flows, b.flows)
    np.testing.assert_array_equal(a.hist, b.hist)
    # different seeds explore different sample paths...
    c = simulate_transient(d, n_clients=32, seeds=(7, 8, 9, 10), n_steps=2000)
    assert not np.array_equal(a.flows, c.flows)
    # ...but agree on the steady state
    assert float(c.throughput.mean()) == pytest.approx(
        float(a.throughput.mean()), rel=0.10)


def test_deterministic_service_is_seed_invariant():
    d = demand_vector(CMP) / ALPHA
    res = simulate_transient(d, n_clients=32, seeds=4, n_steps=2000,
                             exponential_service=False)
    assert float(res.throughput.std()) == 0.0
    assert float(res.throughput.mean()) == pytest.approx(
        CMP.peak_throughput(ALPHA), rel=0.05)


# ---------------------------------------------------------------------------
# Scripted events
# ---------------------------------------------------------------------------


def test_failover_trace_dips_and_recovers():
    """Leader crash over [0.4, 0.6): throughput must fall below 20% of the
    pre-crash plateau during the outage and recover to >= 85% of it."""
    d = demand_vector(CMP) / ALPHA            # model order: leader is col 0
    sched, bounds = failover_schedule(d, station=0, start=0.4, stop=0.6,
                                      n_steps=5000)
    res = simulate_transient(sched, bounds, n_clients=64, seeds=8,
                             n_steps=5000)
    _, trace = res.throughput_trace(n_windows=20)
    xm = trace.mean(axis=1)[0]                # seed-mean trace
    pre = xm[3:8].mean()                      # post-warmup, pre-crash
    dip = xm[9:11].mean()                     # inside the outage
    post = xm[15:].mean()                     # after recovery
    assert pre > 0
    assert dip < 0.2 * pre
    assert post > 0.85 * pre
    # the stall lives in the tail, not the median
    assert float(res.latency_p99.mean()) > 2.0 * float(res.latency_p50.mean())


def test_scale_up_steps_throughput():
    """Halving the proxy demand mid-run on a proxy-bound deployment must
    roughly double throughput (bottleneck migrates proxy -> leader)."""
    m = compartmentalized_model(f=1, n_proxy_leaders=2, grid_rows=3,
                                grid_cols=1, n_replicas=2)
    assert m.bottleneck()[0] == "proxy"
    d = demand_vector(m) / ALPHA              # model order: proxy is col 1
    sched, bounds = scale_schedule(d, station=1, at=0.5, factor=0.5,
                                   n_steps=5000)
    res = simulate_transient(sched, bounds, n_clients=64, seeds=8,
                             n_steps=5000)
    _, trace = res.throughput_trace(n_windows=20)
    xm = trace.mean(axis=1)[0]
    before, after = xm[4:9].mean(), xm[14:].mean()
    assert after == pytest.approx(2.0 * before, rel=0.15)


def test_zero_demand_window_serves_instead_of_stalling():
    """A window that zeroes an active station's demand means 'free', not
    'crashed': throughput must rise toward the remaining bottleneck, not
    collapse to zero."""
    m = compartmentalized_model(f=1, n_proxy_leaders=2, grid_rows=3,
                                grid_cols=1, n_replicas=2)  # proxy-bound
    d = demand_vector(m) / ALPHA
    sched, bounds = scale_schedule(d, station=1, at=0.5, factor=0.0,
                                   n_steps=5000)
    res = simulate_transient(sched, bounds, n_clients=64, seeds=8,
                             n_steps=5000)
    xm = res.window_throughput(bounds, settle=0.3).mean(axis=1)[0]
    assert xm[1] > 1.5 * xm[0]


def test_step_bounds_must_start_at_zero():
    d = demand_vector(CMP) / ALPHA
    sched = np.repeat(d[None, None, :], 2, axis=0)
    with pytest.raises(ValueError):
        simulate_transient(sched, np.array([100, 300]), n_steps=1000)
    with pytest.raises(ValueError):
        simulate_transient(sched, np.array([0, -5]), n_steps=1000)


def test_window_throughput_respects_bottleneck_caps():
    """Per-window means (transition backlog excluded) must not exceed each
    window's own bottleneck-law cap - the raw trace can, while a faster
    window drains a slower window's queue."""
    m_slow = compartmentalized_model(f=1, n_proxy_leaders=2, grid_rows=3,
                                     grid_cols=1, n_replicas=2)
    m_fast = compartmentalized_model(f=1, n_proxy_leaders=10, grid_rows=2,
                                     grid_cols=2, n_replicas=4)
    windows = [demand_vector(m_slow) / ALPHA, demand_vector(m_fast) / ALPHA]
    sched, bounds = schedule_from_demands(windows, [0.0, 0.5], n_steps=6000)
    res = simulate_transient(sched, bounds, n_clients=128, seeds=8,
                             n_steps=6000)
    xm = res.window_throughput(bounds, settle=0.5).mean(axis=1)[0]
    caps = (m_slow.peak_throughput(ALPHA), m_fast.peak_throughput(ALPHA))
    for x, cap in zip(xm, caps):
        assert x <= cap * 1.05
        assert x >= cap * 0.80


def test_schedule_builders():
    base = np.array([[1.0, 2.0, 0.0]])
    sched, bounds = build_schedule(
        base, [Event(0, 0.25, 0.75, 10.0), Event(1, 0.5, 0.75, 2.0)],
        n_steps=100)
    assert list(bounds) == [0, 25, 50, 75]
    np.testing.assert_allclose(sched[:, 0, 0], [1.0, 10.0, 10.0, 1.0])
    np.testing.assert_allclose(sched[:, 0, 1], [2.0, 2.0, 4.0, 2.0])
    # named stations resolve through the canonical slot table
    s2, _ = build_schedule(np.ones((1, 8)), [Event("leader", 0.0, 1.0, 3.0)],
                           n_steps=10)
    assert s2[0, 0, 1] == 3.0                 # STATION_ORDER[1] == "leader"

    with pytest.raises(ValueError):
        schedule_from_demands([base, base], [0.1, 0.5], n_steps=100)
    with pytest.raises(ValueError):
        schedule_from_demands([base], [0.0, 0.5], n_steps=100)
    sched2, bounds2 = schedule_from_demands([base, 2 * base], [0.0, 0.5],
                                            n_steps=100)
    assert list(bounds2) == [0, 50]
    np.testing.assert_allclose(sched2[1], 2 * base)
