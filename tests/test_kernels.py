"""Per-kernel validation: Pallas (interpret mode on CPU) vs pure-jnp oracle,
swept over shapes and dtypes (assignment requirement c).

Tolerances follow public kernel-test practice: fp32 rtol 1e-5-ish, bf16
rtol >= 1e-2 (long reductions).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.decode_attention import flash_decode
from repro.kernels.flash_attention import flash_attention
from repro.kernels.rglru_scan import rglru_scan
from repro.kernels.rwkv6_scan import wkv6
from repro.models.rwkv6 import wkv6_chunked, wkv6_serial
from repro.models.attention import chunked_attention


def tol(dtype):
    return dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

ATTN_SHAPES = [
    # (B, H, H_kv, S, D, block_q, block_k)
    (1, 2, 2, 64, 32, 16, 16),
    (2, 4, 2, 128, 64, 32, 64),   # GQA group 2, uneven blocks
    (1, 8, 1, 64, 16, 64, 16),    # MQA
    (2, 2, 2, 96, 32, 32, 32),    # S not a power of two
]


@pytest.mark.parametrize("shape", ATTN_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(shape, dtype, causal):
    B, H, H_kv, S, D, bq, bk = shape
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, H_kv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, H_kv, S, D), dtype)
    out = flash_attention(q, k, v, causal=causal, block_q=bq, block_k=bk,
                          interpret=True)
    expect = ref.ref_attention(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


def test_chunked_attention_matches_ref():
    """The model's jnp streaming attention is bit-comparable to the oracle
    (it is the dry-run path, so it must be exact)."""
    ks = jax.random.split(jax.random.key(1), 3)
    B, H, H_kv, S, D = 2, 4, 2, 96, 32
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H_kv, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H_kv, D), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, q_block=32)
    expect = ref.ref_attention(q.transpose(0, 2, 1, 3),
                               k.transpose(0, 2, 1, 3),
                               v.transpose(0, 2, 1, 3), causal=True)
    np.testing.assert_allclose(np.asarray(out.transpose(0, 2, 1, 3)),
                               np.asarray(expect), rtol=2e-5, atol=2e-5)


def test_chunked_attention_window_matches_masked_ref():
    ks = jax.random.split(jax.random.key(2), 3)
    B, H, S, D, W = 1, 2, 64, 16, 8
    q = jax.random.normal(ks[0], (B, S, H, D), jnp.float32)
    k = jax.random.normal(ks[1], (B, S, H, D), jnp.float32)
    v = jax.random.normal(ks[2], (B, S, H, D), jnp.float32)
    out = chunked_attention(q, k, v, causal=True, window=W, q_block=16)
    # reference: full attention with band mask
    import math
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(D)
    idx = jnp.arange(S)
    mask = (idx[:, None] >= idx[None, :]) & (idx[:, None] - idx[None, :] < W)
    s = jnp.where(mask[None, None], s, -1e30)
    expect = jnp.einsum("bhqk,bkhd->bqhd", jax.nn.softmax(s, -1), v)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=2e-5, atol=2e-5)


# ---------------------------------------------------------------------------
# flash decode
# ---------------------------------------------------------------------------

DECODE_SHAPES = [
    # (B, H, H_kv, S_max, D, block_k)
    (2, 4, 2, 128, 32, 32),
    (1, 8, 1, 256, 64, 64),
    (3, 4, 4, 64, 16, 16),
]


@pytest.mark.parametrize("shape", DECODE_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_decode_matches_ref(shape, dtype):
    B, H, H_kv, S, D, bk = shape
    ks = jax.random.split(jax.random.key(3), 4)
    q = jax.random.normal(ks[0], (B, H, D), dtype)
    k = jax.random.normal(ks[1], (B, H_kv, S, D), dtype)
    v = jax.random.normal(ks[2], (B, H_kv, S, D), dtype)
    cache_len = jax.random.randint(ks[3], (B,), 1, S + 1)
    out = flash_decode(q, k, v, cache_len, block_k=bk, interpret=True)
    expect = ref.ref_decode(q, k, v, cache_len)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


# ---------------------------------------------------------------------------
# RG-LRU scan
# ---------------------------------------------------------------------------

RGLRU_SHAPES = [
    (1, 64, 128, 32, 128),   # (B, S, D, chunk, block_d)
    (2, 128, 256, 64, 128),
    (2, 96, 128, 32, 64),
]


@pytest.mark.parametrize("shape", RGLRU_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rglru_scan_matches_ref(shape, dtype):
    B, S, D, chunk, bd = shape
    ks = jax.random.split(jax.random.key(4), 2)
    x = jax.random.normal(ks[0], (B, S, D), dtype)
    a = jax.random.uniform(ks[1], (B, S, D), jnp.float32, 0.5, 0.999).astype(dtype)
    out = rglru_scan(x, a, chunk=chunk, block_d=bd, interpret=True)
    expect = ref.ref_rglru(x, a)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **tol(dtype))


def test_rglru_assoc_scan_matches_serial():
    from repro.models.rglru import rglru_scan as assoc
    ks = jax.random.split(jax.random.key(5), 2)
    x = jax.random.normal(ks[0], (2, 77, 32), jnp.float32)
    a = jax.random.uniform(ks[1], (2, 77, 32), jnp.float32, 0.3, 0.99)
    h, h_last = assoc(x, a)
    expect = ref.ref_rglru(x, a)
    np.testing.assert_allclose(np.asarray(h), np.asarray(expect),
                               rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(h_last), np.asarray(expect[:, -1]),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# RWKV-6 WKV
# ---------------------------------------------------------------------------

WKV_SHAPES = [
    (1, 2, 64, 16, 16),   # (B, H, S, D, chunk)
    (2, 2, 96, 32, 32),
    (1, 4, 128, 64, 32),
]


def _wkv_inputs(shape, dtype):
    B, H, S, D, chunk = shape
    ks = jax.random.split(jax.random.key(6), 5)
    r = jax.random.normal(ks[0], (B, H, S, D), dtype)
    k = jax.random.normal(ks[1], (B, H, S, D), dtype)
    v = jax.random.normal(ks[2], (B, H, S, D), dtype)
    logw = -jnp.exp(jax.random.normal(ks[3], (B, H, S, D)) - 1.0)
    logw = jnp.maximum(logw, -5.0).astype(jnp.float32)
    u = (jax.random.normal(ks[4], (H, D)) * 0.1).astype(jnp.float32)
    return r, k, v, logw, u, chunk


@pytest.mark.parametrize("shape", WKV_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_kernel_matches_serial_ref(shape, dtype):
    r, k, v, logw, u, chunk = _wkv_inputs(shape, dtype)
    out = wkv6(r, k, v, logw, u, chunk=chunk, interpret=True)
    expect = ref.ref_wkv6(r, k, v, logw, u)
    # chunked vs serial differ in f32 reduction order: rtol 1e-3 (long
    # reductions; see kernel-taxonomy Part E)
    t = dict(rtol=2e-2, atol=2e-2) if dtype == jnp.bfloat16 \
        else dict(rtol=1e-3, atol=1e-4)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(expect, np.float32), **t)


def test_wkv6_chunked_model_path_matches_serial():
    """The model's chunked jnp path (B, S, H, D layout) vs serial oracle."""
    B, H, S, D = 2, 2, 80, 16
    r, k, v, logw, u, _ = _wkv_inputs((B, H, S, D, 16), jnp.float32)
    to_bshd = lambda t: t.transpose(0, 2, 1, 3)
    y_c, s_c = wkv6_chunked(to_bshd(r), to_bshd(k), to_bshd(v),
                            to_bshd(logw), u, chunk=16)
    y_s, s_s = wkv6_serial(to_bshd(r), to_bshd(k), to_bshd(v),
                           to_bshd(logw), u)
    np.testing.assert_allclose(np.asarray(y_c), np.asarray(y_s),
                               rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_c), np.asarray(s_s),
                               rtol=1e-4, atol=1e-4)


def test_wkv6_state_carry_across_calls():
    """Splitting a sequence into two serial calls must equal one call."""
    B, H, S, D = 1, 2, 32, 16
    r, k, v, logw, u, _ = _wkv_inputs((B, H, S, D, 16), jnp.float32)
    to_bshd = lambda t: t.transpose(0, 2, 1, 3)
    r2, k2, v2, lw2 = map(to_bshd, (r, k, v, logw))
    y_full, s_full = wkv6_serial(r2, k2, v2, lw2, u)
    h = S // 2
    y1, s1 = wkv6_serial(r2[:, :h], k2[:, :h], v2[:, :h], lw2[:, :h], u)
    y2, s2 = wkv6_serial(r2[:, h:], k2[:, h:], v2[:, h:], lw2[:, h:], u, s0=s1)
    np.testing.assert_allclose(np.asarray(jnp.concatenate([y1, y2], 1)),
                               np.asarray(y_full), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(s2), np.asarray(s_full),
                               rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# latency histogram (batched execution plane)
# ---------------------------------------------------------------------------

HIST_SHAPES = [
    # (L lanes, N samples, B bins)
    (1, 64, 8),
    (4, 128, 16),
    (3, 96, 24),   # N, B not powers of two
]


def _hist_inputs(shape, seed=0):
    L, N, B = shape
    ks = jax.random.split(jax.random.key(seed), 2)
    # log-spaced edges per lane (the transient plane's convention)
    lo = 0.5 + jnp.arange(L, dtype=jnp.float32)[:, None]
    edges = lo * jnp.logspace(0.0, 2.0, B + 1)[None, :]
    samples = jax.random.uniform(ks[0], (L, N), jnp.float32,
                                 minval=0.1, maxval=200.0)
    valid = (jax.random.uniform(ks[1], (L, N)) < 0.7).astype(jnp.float32)
    return samples, valid, edges


@pytest.mark.parametrize("shape", HIST_SHAPES)
def test_latency_hist_kernel_matches_ref(shape):
    from repro.kernels.latency_hist import latency_hist

    samples, valid, edges = _hist_inputs(shape)
    out = latency_hist(samples, valid, edges, interpret=True)
    expect = ref.ref_latency_hist(samples, valid, edges)
    np.testing.assert_array_equal(np.asarray(out), np.asarray(expect))
    # masked samples never land anywhere; every valid one lands somewhere
    assert int(out.sum()) == int(valid.sum())


def test_latency_hist_matches_searchsorted_binning():
    """The oracle's bin convention is exactly transient.py's
    searchsorted(edges) - 1 with end-bin clamping."""
    L, N, B = 2, 40, 12
    samples, valid, edges = _hist_inputs((L, N, B), seed=3)
    # include exact-edge and out-of-range samples
    samples = samples.at[:, 0].set(edges[:, 3]).at[:, 1].set(1e9)
    samples = samples.at[:, 2].set(0.0)
    hist = ref.ref_latency_hist(samples, valid, edges)
    for l in range(L):
        bins = np.clip(np.searchsorted(np.asarray(edges[l]),
                                       np.asarray(samples[l])) - 1, 0, B - 1)
        expect = np.zeros(B, np.int32)
        for b, v in zip(bins, np.asarray(valid[l])):
            expect[b] += int(v)
        np.testing.assert_array_equal(np.asarray(hist[l]), expect)


def test_latency_hist_ops_dispatch():
    from repro.kernels.ops import latency_hist as op

    samples, valid, edges = _hist_inputs((2, 64, 8), seed=5)
    cpu = op(samples, valid, edges)                  # ref fast path
    pallas = op(samples, valid, edges, use_pallas=True)  # interpret mode
    np.testing.assert_array_equal(np.asarray(cpu), np.asarray(pallas))
