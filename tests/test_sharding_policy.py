"""Sharding-policy rules on a realistic (1 x 16) model axis.

Spec computation needs a real mesh, so these run in a subprocess with 16
forced host devices (the main process keeps 1 device)."""
import subprocess
import sys
import textwrap

PREAMBLE = """
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=16"
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from repro.configs import get_config
from repro.runtime.sharding import ShardingPolicy
mesh = jax.make_mesh((1, 16), ("data", "model"))
"""


def run_sub(body: str, timeout: int = 300) -> str:
    code = PREAMBLE + textwrap.dedent(body)
    proc = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        timeout=timeout, env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                              "JAX_PLATFORMS": "cpu"},
        cwd="/root/repo")
    assert proc.returncode == 0, f"STDOUT:\n{proc.stdout}\nSTDERR:\n{proc.stderr}"
    return proc.stdout


def test_gqa_kv_replicated_when_heads_dont_divide():
    run_sub("""
    cfg = get_config("granite-3-2b")  # 32 q heads, 8 kv heads
    pol = ShardingPolicy(cfg, mesh)
    assert pol.param_spec("segments/0/0/attn/w_q", (2048, 2048)) == P(None, "model")
    # kv heads (8) don't divide 16 -> replicate K/V projections
    assert pol.param_spec("segments/0/0/attn/w_k", (2048, 512)) == P(None, None)
    assert pol.param_spec("segments/0/0/attn/w_o", (2048, 2048)) == P("model", None)
    """)


def test_non_dividing_q_heads_replicate_attention():
    run_sub("""
    cfg = get_config("qwen1.5-32b")  # 40 heads
    pol = ShardingPolicy(cfg, mesh)
    assert pol.param_spec("segments/0/0/attn/w_q", (5120, 5120)) == P(None, None)
    flat = ShardingPolicy(cfg, mesh, shard_qkv_by_flat_dim=True)
    assert flat.param_spec("segments/0/0/attn/w_q", (5120, 5120)) == P(None, "model")
    """)


def test_expert_parallelism():
    run_sub("""
    cfg = get_config("qwen3-moe-30b-a3b")
    pol = ShardingPolicy(cfg, mesh)
    spec = pol.param_spec("segments/0/0/moe/experts/w_up", (128, 2048, 768))
    assert spec == P("model", None, None), spec
    # EP survives the dp_only layout (experts cannot be replicated)
    dp = ShardingPolicy(cfg, mesh, dp_only=True)
    assert dp.param_spec("segments/0/0/moe/experts/w_up",
                         (128, 2048, 768)) == P("model", None, None)
    assert dp.param_spec("segments/0/0/attn/w_q", (2048, 2048)) == P(None, None)
    """)


def test_fsdp_shards_first_divisible_dim():
    run_sub("""
    cfg = get_config("qwen1.5-32b")
    pol = ShardingPolicy(cfg, mesh, fsdp=True)
    assert pol.param_spec("segments/0/0/attn/w_q", (5120, 5120)) == P("model", None)
    assert pol.param_spec("embed/tokens", (152064, 5120)) == P("model", None)
    # non-divisible everywhere -> replicated
    assert pol.param_spec("segments/0/0/ln1/scale", (5121,)) == P(None)
    """)


def test_dp_for_subset_search():
    run_sub("""
    mesh3 = jax.make_mesh((2, 4, 2), ("pod", "data", "model"))
    cfg = get_config("granite-3-2b")
    pol = ShardingPolicy(cfg, mesh3, dp_only=True)
    # 8 % (2*4*2 = 16) fails -> falls to some size-8 subset
    combo = pol.dp_for(8)
    size = 1
    for a in combo:
        size *= mesh3.shape[a]
    assert size == 8, combo
    assert pol.dp_for(16) == ("pod", "data", "model")
    assert pol.dp_for(7) is None
    """)


def test_zero1_respects_divisibility():
    run_sub("""
    import jax.numpy as jnp
    mesh44 = jax.make_mesh((4, 4), ("data", "model"))
    cfg = get_config("granite-3-2b")
    pol = ShardingPolicy(cfg, mesh44, zero1=True)
    params_shape = {"embed": {"tokens": jax.ShapeDtypeStruct((49155, 2048),
                                                             jnp.bfloat16)}}
    o_sh = pol.opt_state_shardings(params_shape)
    spec = o_sh["m"]["embed"]["tokens"].spec
    # 49155 % 4 != 0 on dim0 -> ZeRO lands on dim1 (2048 divisible)
    assert spec[0] is None and spec[1] == "data", spec
    """)


def test_rwkv_and_rglru_rules():
    run_sub("""
    cfg = get_config("rwkv6-7b")
    pol = ShardingPolicy(cfg, mesh)
    assert pol.param_spec("segments/0/0/tm/w_r", (4096, 4096)) == P(None, "model")
    assert pol.param_spec("segments/0/0/tm/w_o", (4096, 4096)) == P("model", None)
    cfg2 = get_config("recurrentgemma-2b")
    pol2 = ShardingPolicy(cfg2, mesh)
    assert pol2.param_spec("segments/0/0/rec/w_in_rnn", (2560, 2560)) == P(None, "model")
    assert pol2.param_spec("segments/0/0/rec/lambda", (2560,)) == P("model")
    assert pol2.param_spec("segments/0/0/rec/w_out", (2560, 2560)) == P("model", None)
    """)


def test_cache_sharding_seq_over_model():
    run_sub("""
    import jax.numpy as jnp
    cfg = get_config("granite-3-2b")
    pol = ShardingPolicy(cfg, mesh)
    cache_shape = {"k": jax.ShapeDtypeStruct((40, 128, 32768, 8, 64),
                                             jnp.bfloat16),
                   "pos": jax.ShapeDtypeStruct((40,), jnp.int32)}
    sh = pol.cache_shardings(cache_shape)
    spec = sh["k"].spec
    assert spec[0] is None and "data" in str(spec[1]), spec
    assert spec[2] == "model" and spec[3] is None, spec  # seq over model
    assert sh["pos"].spec == P(None)
    """)
