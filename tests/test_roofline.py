"""Roofline machinery unit tests: HLO collective parsing, extrapolation
math, term computation."""
import pytest

from repro.roofline.analysis import (
    CellRoofline,
    HBM_BW,
    ICI_BW,
    PEAK_FLOPS,
    analyze_record,
    model_flops_for,
)
from repro.roofline.hlo import collective_stats, total_collective_bytes

HLO_SAMPLE = """
HloModule jit_step

fused_computation {
  ...
}

ENTRY main {
  %p0 = bf16[2048,512]{1,0} parameter(0)
  %ar = bf16[2048,512]{1,0} all-reduce(%p0), replica_groups={}
  %ag = f32[128,64]{1,0} all-gather(%ar), dimensions={0}
  %rs = f32[64,64]{1,0} reduce-scatter(%ag), dimensions={0}
  %a2a = bf16[32]{0} all-to-all(%rs), dimensions={0}
  %cp = s32[16]{0} collective-permute(%a2a), source_target_pairs={{0,1}}
  %ars = bf16[100]{0} all-reduce-start(%cp)
  %ard = bf16[100]{0} all-reduce-done(%ars)
  ROOT %out = bf16[100]{0} copy(%ard)
}
"""


def test_collective_stats_counts_and_bytes():
    stats = collective_stats(HLO_SAMPLE)
    assert stats["all-reduce"]["count"] == 2  # plain + -start (not -done)
    assert stats["all-reduce"]["bytes"] == 2048 * 512 * 2 + 100 * 2
    assert stats["all-gather"]["bytes"] == 128 * 64 * 4
    assert stats["reduce-scatter"]["bytes"] == 64 * 64 * 4
    assert stats["all-to-all"]["bytes"] == 32 * 2
    assert stats["collective-permute"]["bytes"] == 16 * 4
    assert total_collective_bytes(HLO_SAMPLE) == sum(
        v["bytes"] for v in stats.values())


def _fake_record(flops=1e14, bytes_acc=1e12, ar_bytes=5e10, n_dev=256):
    return {
        "arch": "granite-3-2b", "shape": "train_4k", "mesh": "single",
        "status": "ok", "n_devices": n_dev,
        "cost_analysis": {"flops": flops, "bytes accessed": bytes_acc},
        "collectives": {"all-reduce": {"count": 10, "bytes": ar_bytes}},
        "memory_analysis": {"argument_size_in_bytes": 3e9,
                            "output_size_in_bytes": 3e9},
    }


def test_roofline_terms():
    cell = analyze_record(_fake_record())
    assert cell.compute_s == pytest.approx(1e14 / PEAK_FLOPS)
    assert cell.collective_s == pytest.approx(5e10 / ICI_BW)
    assert cell.memory_hlo_upper_s == pytest.approx(1e12 / HBM_BW)
    assert cell.memory_s > 6e9 / HBM_BW  # args+outputs+activations
    assert cell.dominant in ("compute", "memory", "collective")
    assert cell.step_s == max(cell.compute_s, cell.memory_s, cell.collective_s)
    assert 0 < cell.mfu_est < 1.5


def test_model_flops_scales_with_kind():
    train = model_flops_for("granite-3-2b", "train_4k")
    prefill = model_flops_for("granite-3-2b", "prefill_32k")
    decode = model_flops_for("granite-3-2b", "decode_32k")
    # same token count => train = 3x prefill per token
    assert train / (256 * 4096) == pytest.approx(
        3 * prefill / (32 * 32768), rel=1e-6)
    assert decode == pytest.approx(prefill / (32 * 32768) * 128, rel=1e-6)


def test_moe_uses_active_params():
    dense_like = model_flops_for("qwen3-moe-30b-a3b", "train_4k")
    from repro.configs import get_config
    cfg = get_config("qwen3-moe-30b-a3b")
    assert dense_like == pytest.approx(
        6.0 * cfg.n_active_params() * 256 * 4096)
    assert cfg.n_active_params() < 0.25 * cfg.n_params()


def test_skipped_record_passthrough():
    rec = {"arch": "granite-3-2b", "shape": "long_500k", "mesh": "single",
           "status": "skipped", "skip_reason": "full attention"}
    cell = analyze_record(rec)
    assert cell.status == "skipped"
    assert "full attention" in cell.note
