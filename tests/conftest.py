"""Registry-derived conformance fixtures.

Any test (in any file under ``tests/``) that takes the
``executable_variant`` fixture is automatically parametrized over every
variant that declares an execution plane - the registry is the single
source of truth, so registering a new variant (e.g. the multi-leader
family: ``bpaxos``, ``iss``) makes it inherit the whole conformance
suite (parity, linearizability, batched<->scalar cross-plane agreement)
with zero test edits, and can never break an unrelated hand-pinned list.
"""
import pytest

from repro.core import GeoSpec, executable_variants


def pytest_generate_tests(metafunc):
    if "executable_variant" in metafunc.fixturenames:
        metafunc.parametrize("executable_variant",
                             list(executable_variants()))


@pytest.fixture
def registered_executables():
    """The registry's executable-variant names, resolved at test time."""
    return tuple(executable_variants())


@pytest.fixture
def geo3():
    """A 3-region WAN (us<->eu 8, us<->ap 16, eu<->ap 12 ticks round
    trip) for the registry-derived geo conformance suite: small enough
    that no protocol retry timer fires (the tightest is the proxy
    leader's p2 retry at 40 ticks), so message counts stay
    delay-invariant and every executable variant must hold msgs/cmd
    parity, linearizability AND per-region measured-vs-predicted
    latency under it."""
    return GeoSpec(regions=("us", "eu", "ap"),
                   rtt=((0, 8, 16), (8, 0, 12), (16, 12, 0)))
