"""Registry-derived conformance fixtures.

Any test (in any file under ``tests/``) that takes the
``executable_variant`` fixture is automatically parametrized over every
variant that declares an execution plane - the registry is the single
source of truth, so registering a new variant (e.g. the multi-leader
family: ``bpaxos``, ``iss``) makes it inherit the whole conformance
suite (parity, linearizability, batched<->scalar cross-plane agreement)
with zero test edits, and can never break an unrelated hand-pinned list.
"""
import pytest

from repro.core import executable_variants


def pytest_generate_tests(metafunc):
    if "executable_variant" in metafunc.fixturenames:
        metafunc.parametrize("executable_variant",
                             list(executable_variants()))


@pytest.fixture
def registered_executables():
    """The registry's executable-variant names, resolved at test time."""
    return tuple(executable_variants())
