"""Architecture configs (one module per assigned arch) + shape sets."""
from .base import ModelConfig, all_configs, get_config, register
from .shapes import SHAPES, ShapeSpec, all_cells, applicable_shapes, skip_reason

_LOADED = False


def _load_all() -> None:
    global _LOADED
    if _LOADED:
        return
    _LOADED = True
    from . import (  # noqa: F401
        deepseek_moe_16b,
        granite_3_2b,
        nemotron_4_15b,
        phi3_medium_14b,
        qwen1_5_32b,
        qwen2_vl_72b,
        qwen3_moe_30b_a3b,
        recurrentgemma_2b,
        rwkv6_7b,
        whisper_tiny,
    )


__all__ = [
    "SHAPES", "ModelConfig", "ShapeSpec", "all_cells", "all_configs",
    "applicable_shapes", "get_config", "register", "skip_reason",
]
