"""Qwen1.5-32B [hf:Qwen/Qwen1.5-32B]: QKV bias, near-MHA GQA (kv=40)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen1.5-32b",
    family="dense",
    n_layers=64,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=27392,
    vocab_size=152064,
    mlp_kind="swiglu",
    qkv_bias=True,
    rope_mode="rope",
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    source="hf:Qwen/Qwen1.5-32B (family ref hf:Qwen/Qwen1.5-0.5B)",
))
