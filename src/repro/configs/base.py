"""Model configuration schema + registry for the assigned architectures.

Every architecture in the assignment pool is a ``ModelConfig``; reduced
smoke variants (same family, tiny dims) come from ``.smoke()`` and are what
the CPU tests instantiate.  The full configs are exercised only through the
dry-run (ShapeDtypeStruct lowering, no allocation).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Dict, Optional, Tuple

import jax.numpy as jnp

from repro.models.moe import MoEConfig


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                      # dense | moe | hybrid | ssm | audio | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0                  # 0 -> d_model // n_heads
    # block structure: cycled over layers.  types: attn | local_attn |
    # rglru | rwkv6 | xattn (decoder self+cross)
    block_pattern: Tuple[str, ...] = ("attn",)
    attn_window: Optional[int] = None   # for local_attn
    # channel mixer
    mlp_kind: str = "swiglu"
    moe: Optional[MoEConfig] = None
    moe_layer_start: int = 0         # layers < start use a dense MLP
    d_ff_dense: int = 0              # dense-MLP width for pre-MoE layers
    # attention details
    qkv_bias: bool = False
    rope_mode: str = "rope"          # rope | mrope | none
    rope_theta: float = 10_000.0
    mrope_sections: Tuple[int, int, int] = (16, 24, 24)
    norm: str = "rmsnorm"            # rmsnorm | layernorm
    tie_embeddings: bool = False
    # recurrent details
    d_rnn: int = 0                   # 0 -> d_model
    conv_width: int = 4
    # encoder-decoder (whisper)
    is_encoder_decoder: bool = False
    n_encoder_layers: int = 0
    encoder_seq_len: int = 1500      # whisper 30s @ 50Hz after conv stem
    # execution policy
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    q_block: int = 512               # chunked-attention query block
    moe_impl: str = "gshard"
    remat: bool = True
    # "full": recompute everything (min memory); "dots": save matmul
    # outputs (skips refwd matmuls AND their all-reduces at ~activation
    # memory cost - Megatron-style selective recompute)
    remat_policy: str = "full"
    # dry-run only: fully unroll lax.scans so XLA cost analysis counts every
    # iteration (while bodies are otherwise counted once)
    unroll: bool = False
    # KV-cache storage dtype ("" -> param_dtype).  "int8" is the
    # bandwidth-study variant (production int8-KV adds per-head scale
    # tensors, +1.6% bytes - see EXPERIMENTS.md section Perf)
    cache_dtype: str = ""

    def kv_dtype(self):
        import jax.numpy as _jnp
        return _jnp.dtype(self.cache_dtype or self.param_dtype)
    # citation / provenance
    source: str = ""

    # -- derived -------------------------------------------------------------
    @property
    def head_dim(self) -> int:
        return self.d_head or self.d_model // self.n_heads

    @property
    def rnn_width(self) -> int:
        return self.d_rnn or self.d_model

    def dtype(self):
        return jnp.dtype(self.param_dtype)

    def cdtype(self):
        return jnp.dtype(self.compute_dtype)

    def layer_types(self) -> Tuple[str, ...]:
        p = self.block_pattern
        return tuple(p[i % len(p)] for i in range(self.n_layers))

    def channel_kind(self, layer_idx: int) -> str:
        """"mlp" | "moe" | "rwkv_cm" for layer ``layer_idx``."""
        if self.layer_types()[layer_idx] == "rwkv6":
            return "rwkv_cm"
        if self.moe is not None and layer_idx >= self.moe_layer_start:
            return "moe"
        return "mlp"

    def n_params(self) -> int:
        """Approximate parameter count (for 6ND roofline accounting)."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        for i, t in enumerate(self.layer_types()):
            if t in ("attn", "local_attn", "xattn"):
                hd = self.head_dim
                attn = d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd \
                    + self.n_heads * hd * d
                if t == "xattn":
                    attn *= 2
                total += attn
            elif t == "rglru":
                r = self.rnn_width
                total += 2 * d * r + self.conv_width * r + 2 * r * r + r * d
            elif t == "rwkv6":
                total += 4 * d * d + d * d  # r,k,v,g + out
            ck = self.channel_kind(i)
            n_mats = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
            if ck == "mlp":
                ff = self.d_ff_dense or self.d_ff
                total += n_mats * d * ff
            elif ck == "moe":
                m = self.moe
                total += d * m.n_experts
                total += m.n_experts * n_mats * d * m.d_expert
                if m.n_shared:
                    total += n_mats * d * m.d_expert * m.n_shared
            elif ck == "rwkv_cm":
                total += 2 * d * self.d_ff + d * d
        if self.is_encoder_decoder:
            hd = self.head_dim
            per_enc = (d * self.n_heads * hd + 2 * d * self.n_kv_heads * hd
                       + self.n_heads * hd * d + 2 * d * self.d_ff)
            total += self.n_encoder_layers * per_enc
        return int(total)

    def n_active_params(self) -> int:
        """Active params per token (MoE: top_k + shared experts only)."""
        if self.moe is None:
            return self.n_params()
        d = self.d_model
        n_mats = 3 if self.mlp_kind in ("swiglu", "geglu") else 2
        m = self.moe
        inactive_per_layer = (m.n_experts - m.top_k) * n_mats * d * m.d_expert
        n_moe_layers = sum(1 for i in range(self.n_layers)
                           if self.channel_kind(i) == "moe")
        return int(self.n_params() - n_moe_layers * inactive_per_layer)

    # -- reduced variants ----------------------------------------------------
    def smoke(self) -> "ModelConfig":
        """Tiny same-family config for CPU tests."""
        pattern_len = len(self.block_pattern)
        n_layers = max(pattern_len, 2)
        if self.moe_layer_start > 0:
            n_layers = max(n_layers, self.moe_layer_start + 1)
        moe = None
        if self.moe is not None:
            moe = dataclasses.replace(self.moe, n_experts=8,
                                      top_k=min(self.moe.top_k, 2),
                                      d_expert=32, group_size=16,
                                      n_shared=min(self.moe.n_shared, 1))
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=n_layers,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            d_head=16,
            d_ff=96,
            d_ff_dense=96 if self.d_ff_dense else 0,
            vocab_size=128,
            d_rnn=64 if self.d_rnn or "rglru" in self.block_pattern else 0,
            attn_window=(8 if self.attn_window else None),
            moe=moe,
            n_encoder_layers=2 if self.is_encoder_decoder else 0,
            encoder_seq_len=16 if self.is_encoder_decoder else self.encoder_seq_len,
            mrope_sections=(4, 2, 2) if self.rope_mode == "mrope" else self.mrope_sections,
            param_dtype="float32",
            compute_dtype="float32",
            q_block=16,
            # exact (drop-free) MoE for numerical decode==forward checks;
            # the capacity-dispatch path is tested separately in test_moe.py
            moe_impl="dense" if self.moe is not None else self.moe_impl,
            remat=False,
        )


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

_REGISTRY: Dict[str, ModelConfig] = {}


def register(cfg: ModelConfig) -> ModelConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ModelConfig:
    from . import _load_all  # noqa - populate registry
    _load_all()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def all_configs() -> Dict[str, ModelConfig]:
    from . import _load_all
    _load_all()
    return dict(_REGISTRY)
