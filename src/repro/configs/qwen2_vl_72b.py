"""Qwen2-VL-72B language backbone [arXiv:2409.12191; hf].

VLM: M-RoPE (multimodal rotary: temporal/height/width sections), dynamic
resolution.  The vision encoder is a STUB per the assignment - dry-run
``input_specs`` provide token ids / patch-embedding stand-ins; M-RoPE is
implemented faithfully with text positions (t = h = w).
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen2-vl-72b",
    family="vlm",
    n_layers=80,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=29568,
    vocab_size=152064,
    mlp_kind="swiglu",
    qkv_bias=True,          # Qwen2 attention uses QKV bias
    rope_mode="mrope",
    rope_theta=1_000_000.0,
    mrope_sections=(16, 24, 24),
    norm="rmsnorm",
    source="arXiv:2409.12191; hf:Qwen/Qwen2-VL-72B-Instruct",
))
