"""RWKV-6 "Finch" 7B [arXiv:2404.05892]: attention-free, data-dependent
decay; head size 64.  Runs ``long_500k`` (O(1) state)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="rwkv6-7b",
    family="ssm",
    n_layers=32,
    d_model=4096,
    n_heads=64,              # d_model / 64 heads of size 64
    n_kv_heads=64,
    d_head=64,
    d_ff=14336,
    vocab_size=65536,
    block_pattern=("rwkv6",),
    mlp_kind="relu",         # channel-mix uses relu^2 internally
    rope_mode="none",
    norm="layernorm",
    source="arXiv:2404.05892; hf:RWKV/rwkv-6-world-7b",
))
