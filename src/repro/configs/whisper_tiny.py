"""Whisper-tiny [arXiv:2212.04356]: encoder-decoder; conv frontend is a
STUB (input_specs supply precomputed 50Hz frame embeddings)."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="whisper-tiny",
    family="audio",
    n_layers=4,              # decoder layers
    n_encoder_layers=4,
    is_encoder_decoder=True,
    encoder_seq_len=1500,
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab_size=51865,
    block_pattern=("xattn",),
    mlp_kind="gelu",
    rope_mode="none",        # Whisper uses learned absolute positions
    norm="layernorm",
    tie_embeddings=True,
    source="arXiv:2212.04356; hf:openai/whisper-tiny",
))
