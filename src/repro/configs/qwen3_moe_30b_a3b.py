"""Qwen3-30B-A3B [hf:Qwen/Qwen3-30B-A3B]: 128 experts, top-8, no shared."""
from repro.models.moe import MoEConfig

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="qwen3-moe-30b-a3b",
    family="moe",
    n_layers=48,
    d_model=2048,
    n_heads=32,
    n_kv_heads=4,
    d_ff=768,               # per-expert width
    vocab_size=151936,
    mlp_kind="swiglu",
    moe=MoEConfig(n_experts=128, top_k=8, d_expert=768, n_shared=0,
                  capacity_factor=1.25, group_size=512),
    rope_mode="rope",
    rope_theta=1_000_000.0,
    norm="rmsnorm",
    source="hf:Qwen/Qwen3-30B-A3B",
))
