"""RecurrentGemma-2B (Griffin) [arXiv:2402.19427]: RG-LRU + local attention
in a 2:1 pattern (recurrent, recurrent, local-attn), window 2048.

Runs ``long_500k``: recurrent state + windowed cache are O(1) in context.
"""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,            # MQA in the local-attention layers
    d_head=256,
    d_ff=7680,
    vocab_size=256000,
    block_pattern=("rglru", "rglru", "local_attn"),
    attn_window=2048,
    d_rnn=2560,              # lru_width
    conv_width=4,
    mlp_kind="geglu",        # Gemma-family gated GELU
    rope_mode="rope",
    rope_theta=10_000.0,
    norm="rmsnorm",
    tie_embeddings=True,
    source="arXiv:2402.19427; hf:google/recurrentgemma-2b",
))
