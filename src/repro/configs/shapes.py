"""Assigned input-shape sets for the LM-family architectures.

Each (arch x shape) pair is one dry-run/roofline cell.  ``decode_*`` /
``long_*`` lower ``serve_step`` (one new token against a KV cache of
``seq_len``); ``train_4k`` lowers ``train_step``; ``prefill_32k`` lowers
``prefill_step``.

``long_500k`` requires sub-quadratic context state: it runs only for the
hybrid/ssm architectures (recurrentgemma-2b, rwkv6-7b); pure full-attention
archs skip it (recorded in DESIGN.md section 5).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from .base import ModelConfig


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}

# families whose context state is O(1)/O(window) in seq_len
SUBQUADRATIC_FAMILIES = ("hybrid", "ssm")


def applicable_shapes(cfg: ModelConfig) -> List[ShapeSpec]:
    out = []
    for spec in SHAPES.values():
        if spec.name == "long_500k" and cfg.family not in SUBQUADRATIC_FAMILIES:
            continue  # full-attention archs skip long-context decode
        out.append(spec)
    return out


def skip_reason(cfg: ModelConfig, shape_name: str) -> Optional[str]:
    if (shape_name == "long_500k"
            and cfg.family not in SUBQUADRATIC_FAMILIES):
        return ("full-attention KV cache at 524k context is quadratic-cost; "
                "assignment: run long_500k only for SSM/hybrid archs")
    return None


def all_cells() -> List[Tuple[str, str]]:
    """Every (arch, shape) cell in the assignment - including skipped ones."""
    from .base import all_configs
    cells = []
    for name in sorted(all_configs()):
        for shape in SHAPES:
            cells.append((name, shape))
    return cells
