"""DeepSeekMoE 16B [arXiv:2401.06066]: fine-grained experts, 2 shared +
64 routed top-6; layer 0 is a dense MLP (the published model)."""
from repro.models.moe import MoEConfig

from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="deepseek-moe-16b",
    family="moe",
    n_layers=28,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,               # per-expert width (fine-grained)
    vocab_size=102400,
    mlp_kind="swiglu",
    moe=MoEConfig(n_experts=64, top_k=6, d_expert=1408, n_shared=2,
                  capacity_factor=1.25, group_size=512),
    moe_layer_start=1,
    d_ff_dense=10944,        # dense layer-0 FFN width
    rope_mode="rope",
    rope_theta=10_000.0,
    norm="rmsnorm",
    source="arXiv:2401.06066; hf:deepseek-ai/deepseek-moe-16b-base",
))
