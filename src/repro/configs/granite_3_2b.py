"""IBM Granite 3.0 2B base [hf:ibm-granite/granite-3.0-2b-base]."""
from .base import ModelConfig, register

CONFIG = register(ModelConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    d_ff=8192,
    vocab_size=49155,
    mlp_kind="swiglu",
    rope_mode="rope",
    rope_theta=10_000.0,
    norm="rmsnorm",
    tie_embeddings=True,
    source="hf:ibm-granite/granite-3.0-2b-base",
))
