"""Training launcher.

On a real TPU pod slice this runs the pjit'd train step on the production
mesh; on this CPU container it runs the same code end-to-end at smoke scale
(``--smoke``), exercising the full stack: synthetic data pipeline -> jitted
train_step -> RSM coordinator -> grid checkpoints.

  PYTHONPATH=src python -m repro.launch.train --arch granite-3-2b --smoke \
      --steps 50 --ckpt-dir /tmp/ckpt
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.data.pipeline import DataConfig
from repro.optim.adamw import AdamWConfig
from repro.runtime.train_loop import Trainer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=10)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--workers", type=int, default=4)
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="simulate a crash at this step and recover")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.smoke:
        cfg = cfg.smoke()
    print(f"arch={cfg.name} params={cfg.n_params():,} "
          f"devices={len(jax.devices())}")

    trainer = Trainer(
        cfg, args.ckpt_dir,
        opt_cfg=AdamWConfig(lr=args.lr, warmup_steps=max(args.steps // 10, 1),
                            total_steps=args.steps),
        data_cfg=DataConfig(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                            global_batch=args.batch),
        n_virtual_workers=args.workers, ckpt_every=args.ckpt_every)

    t0 = time.time()
    for step in range(args.steps):
        if step == args.fail_at:
            print(f"[failure injection] crashing at step {step}...")
            restored = trainer.crash_and_recover()
            print(f"[recovery] resumed from committed checkpoint step {restored}")
        m = trainer.run_step()
        if step % 5 == 0 or step == args.steps - 1:
            print(f"step {m['step']:4d} ce={m['ce']:.4f} "
                  f"grad_norm={m['grad_norm']:.3f} lr={m['lr']:.2e} "
                  f"committed={trainer.coord.view.committed_step}")
    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({dt / args.steps * 1e3:.0f} ms/step); "
          f"last committed ckpt: {trainer.coord.view.committed_ckpt}")


if __name__ == "__main__":
    main()
