import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (architecture x input-shape x
mesh) cell and record memory / cost / collective statistics.

The two lines above MUST stay first: jax locks the device count on first
initialisation, and the production meshes need 512 placeholder host devices.
Tests and benchmarks must NOT import this module (they see 1 device).

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch granite-3-2b \
      --shape train_4k --mesh single
  PYTHONPATH=src python -m repro.launch.dryrun --all          # every cell
  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh multi

Each cell writes ``results/dryrun/<arch>__<shape>__<mesh>[__tag].json`` with
compile status, ``compiled.memory_analysis()``, ``compiled.cost_analysis()``
and per-collective byte counts parsed from the partitioned HLO - the inputs
to the roofline analysis (EXPERIMENTS.md section Roofline).
"""
import argparse
import dataclasses
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import SHAPES, all_configs, get_config, skip_reason
from repro.configs.shapes import ShapeSpec
from repro.launch.mesh import make_production_mesh
from repro.roofline.hlo import collective_stats
from repro.runtime.sharding import ShardingPolicy
from repro.runtime.steps import (
    input_specs,
    make_prefill_step,
    make_serve_step,
    make_train_step,
)

RESULTS_DIR = Path(__file__).resolve().parents[3] / "results" / "dryrun"


def _memory_analysis_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception as e:  # some backends don't implement it
        return {"error": repr(e)}
    if ma is None:
        return {}
    out = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes", "peak_memory_in_bytes"):
        if hasattr(ma, attr):
            out[attr] = int(getattr(ma, attr))
    if not out:
        out["repr"] = repr(ma)
    return out


def _cost_analysis_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception as e:
        return {"error": repr(e)}
    if ca is None:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float))}


def _jit_for(cfg, shape, policy):
    specs = input_specs(cfg, shape)
    if shape.kind == "train":
        fn = make_train_step(cfg)
        p_sh = policy.params_shardings(specs["params"])
        o_sh = policy.opt_state_shardings(specs["params"])
        b_sh = policy.batch_shardings(specs["batch"])
        jitted = jax.jit(fn, in_shardings=(p_sh, o_sh, b_sh),
                         out_shardings=(p_sh, o_sh, None),
                         donate_argnums=(0, 1))
        args = (specs["params"], specs["opt_state"], specs["batch"])
    elif shape.kind == "prefill":
        fn = make_prefill_step(cfg)
        p_sh = policy.params_shardings(specs["params"])
        b_sh = policy.batch_shardings(specs["batch"])
        jitted = jax.jit(fn, in_shardings=(p_sh, b_sh))
        args = (specs["params"], specs["batch"])
    else:  # decode
        fn = make_serve_step(cfg)
        p_sh = policy.params_shardings(specs["params"])
        c_sh = policy.cache_shardings(specs["caches"])
        t_sh = policy.batch_shardings(specs["token"])
        jitted = jax.jit(fn, in_shardings=(p_sh, c_sh, t_sh),
                         out_shardings=(t_sh, None, c_sh),
                         donate_argnums=(1,))
        args = (specs["params"], specs["caches"], specs["token"])
    return jitted, args


def _compile_once(cfg, shape, mesh, policy_kwargs):
    from repro.runtime.mesh_context import use_mesh
    policy = ShardingPolicy(cfg, mesh, **(policy_kwargs or {}))
    jitted, args = _jit_for(cfg, shape, policy)
    t0 = time.time()
    with mesh, use_mesh(mesh):
        lowered = jitted.lower(*args)
        compiled = lowered.compile()
    dt = time.time() - t0
    return compiled, dt


def _reduced_depths(cfg) -> tuple:
    """Two reduced layer counts (L_a, L_b) preserving the segment pattern.

    (2, 4) periods rather than (1, 2): the slope is extrapolated ~n_layers
    times, and single-period models see boundary fusion (first/last layer
    fusing with embed/head) that biases the slope; 2->4 amortizes it
    (validated against a full unroll in EXPERIMENTS.md - within ~5%)."""
    prefix = cfg.moe_layer_start
    period = len(cfg.block_pattern)
    return prefix + 2 * period, prefix + 4 * period


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             policy_kwargs: dict | None = None, tag: str = "",
             verbose: bool = True, cfg_overrides: dict | None = None) -> dict:
    """One dry-run cell, three compiles:

    1. *production pass*: full depth, scanned layers (the deployment path) -
       proves the cell lowers+compiles on the mesh; records memory analysis
       and the steady-state collective schedule.
    2./3. *accounting passes*: reduced depths (1 and 2 pattern periods),
       scans fully unrolled.  XLA cost analysis counts while bodies once, so
       unrolled reduced-depth compiles + affine extrapolation in layer count
       give exact per-cell FLOPs / bytes / collective bytes:
           total(L) = intercept + slope * L,
       fitted from the two depths (layer costs are identical across depth).
    """
    cfg0 = get_config(arch)
    cfg0 = dataclasses.replace(cfg0, **(cfg_overrides or {}))
    shape: ShapeSpec = SHAPES[shape_name]
    record: dict = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                    "kind": shape.kind, "tag": tag,
                    "policy": dict(policy_kwargs or {})}

    reason = skip_reason(cfg0, shape_name)
    if reason:
        record.update(status="skipped", skip_reason=reason)
        return record

    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
    record["n_devices"] = int(mesh.devices.size)
    try:
        # -- pass 1: production (scanned, full depth) ---------------------
        compiled, dt = _compile_once(cfg0, shape, mesh, policy_kwargs)
        record.update(
            status="ok",
            compile_seconds=round(dt, 2),
            memory_analysis=_memory_analysis_dict(compiled),
            scheduled_collectives=collective_stats(compiled.as_text()),
        )

        # -- passes 2+3: unrolled accounting at reduced depths -------------
        L_a, L_b = _reduced_depths(cfg0)
        L_a, L_b = min(L_a, cfg0.n_layers), min(L_b, cfg0.n_layers)
        acct = {}
        for L in {L_a, L_b}:
            cfg_r = dataclasses.replace(
                cfg0, n_layers=L, unroll=True,
                q_block=min(2048, cfg0.q_block * 8))
            c_r, dt_r = _compile_once(cfg_r, shape, mesh, policy_kwargs)
            acct[L] = {
                "cost": _cost_analysis_dict(c_r),
                "collectives": collective_stats(c_r.as_text()),
                "compile_seconds": round(dt_r, 2),
            }
        record["accounting_depths"] = sorted(acct)
        record["accounting"] = {str(k): v for k, v in acct.items()}

        # affine extrapolation to the true depth
        L = cfg0.n_layers
        if L_b > L_a:
            ca, cb = acct[L_a]["cost"], acct[L_b]["cost"]
            extr = {}
            for key in set(ca) & set(cb):
                slope = (cb[key] - ca[key]) / (L_b - L_a)
                extr[key] = ca[key] + slope * (L - L_a)
            coll_a, coll_b = acct[L_a]["collectives"], acct[L_b]["collectives"]
            coll = {}
            for op in set(coll_a) | set(coll_b):
                a = coll_a.get(op, {"count": 0, "bytes": 0})
                b = coll_b.get(op, {"count": 0, "bytes": 0})
                coll[op] = {
                    f: a[f] + (b[f] - a[f]) / (L_b - L_a) * (L - L_a)
                    for f in ("count", "bytes")}
        else:  # model already at 1-2 periods (whisper): exact
            extr = acct[L_a]["cost"]
            coll = acct[L_a]["collectives"]
        record["cost_analysis"] = extr
        record["collectives"] = coll

        if verbose:
            ma = record["memory_analysis"]
            fl = extr.get("flops", 0)
            cb_total = sum(v["bytes"] for v in coll.values())
            print(f"[ok] {arch} x {shape_name} x {mesh_kind}"
                  f" compile={dt:.1f}s flops/dev={fl:.3e}"
                  f" coll_bytes/dev={cb_total:.3e}"
                  f" args={ma.get('argument_size_in_bytes', 0)/2**30:.2f}GiB"
                  f" temp={ma.get('temp_size_in_bytes', 0)/2**30:.2f}GiB")
    except Exception as e:
        record.update(status="error", error=repr(e),
                      traceback=traceback.format_exc()[-4000:])
        if verbose:
            print(f"[ERROR] {arch} x {shape_name} x {mesh_kind}: {e!r}")
    return record


def save_record(record: dict, out_dir: Path = RESULTS_DIR) -> Path:
    out_dir.mkdir(parents=True, exist_ok=True)
    tag = f"__{record['tag']}" if record.get("tag") else ""
    path = out_dir / (f"{record['arch']}__{record['shape']}"
                      f"__{record['mesh']}{tag}.json")
    path.write_text(json.dumps(record, indent=1, default=str))
    return path


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(SHAPES))
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true", help="run every cell")
    ap.add_argument("--tag", default="", help="policy-variant tag for output")
    ap.add_argument("--zero1", action="store_true")
    ap.add_argument("--flat-qkv", action="store_true",
                    help="shard q/k/v on flat head*dim even if heads don't divide")
    ap.add_argument("--kv-dtype", default="",
                    help="KV-cache dtype override (e.g. int8)")
    ap.add_argument("--pad-heads", type=int, default=0,
                    help="zero-pad attention heads to this count (exact "
                         "math: padded w_o rows are zero); makes head-wise "
                         "TP divide the model axis")
    ap.add_argument("--pad-kv-heads", type=int, default=0)
    ap.add_argument("--fsdp", action="store_true",
                    help="FSDP over the model axis (params gathered per use)")
    ap.add_argument("--seq-dp", action="store_true",
                    help="context parallelism: sequence dim over the pod axis "
                         "when the batch can't use it")
    ap.add_argument("--remat-policy", default="",
                    choices=["", "full", "dots"])
    ap.add_argument("--no-remat", action="store_true",
                    help="disable activation checkpointing entirely")
    ap.add_argument("--moe-impl", default="",
                    choices=["", "gshard", "dense", "a2a"])
    ap.add_argument("--dp-only", action="store_true",
                    help="pure data parallelism: replicate params, batch over "
                         "(pod,data,model); pair with --zero1")
    ap.add_argument("--no-seq-cache", action="store_true",
                    help="disable sequence sharding of decode caches")
    ap.add_argument("--resume", action="store_true",
                    help="skip cells whose result JSON already exists")
    ap.add_argument("--out", default=str(RESULTS_DIR))
    args = ap.parse_args()

    policy_kwargs = {}
    if args.zero1:
        policy_kwargs["zero1"] = True
    if args.flat_qkv:
        policy_kwargs["shard_qkv_by_flat_dim"] = True
    if args.no_seq_cache:
        policy_kwargs["seq_shard_cache"] = False
    if args.dp_only:
        policy_kwargs["dp_only"] = True
    if args.fsdp:
        policy_kwargs["fsdp"] = True
    if args.seq_dp:
        policy_kwargs["seq_dp"] = True
    cfg_overrides = {}
    if args.kv_dtype:
        cfg_overrides["cache_dtype"] = args.kv_dtype
    if args.remat_policy:
        cfg_overrides["remat_policy"] = args.remat_policy
    if args.no_remat:
        cfg_overrides["remat"] = False
    if args.moe_impl:
        cfg_overrides["moe_impl"] = args.moe_impl
    if args.pad_heads:
        base = get_config(args.arch) if args.arch else None
        cfg_overrides["n_heads"] = args.pad_heads
        cfg_overrides["n_kv_heads"] = args.pad_kv_heads or args.pad_heads
        if base is not None:
            cfg_overrides["d_head"] = base.head_dim
    cfg_overrides = cfg_overrides or None

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]
    if args.all:
        archs = sorted(all_configs())
        shapes = list(SHAPES)
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        archs, shapes = [args.arch], [args.shape]

    out_dir = Path(args.out)
    n_ok = n_skip = n_err = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape in shapes:
                tag = f"__{args.tag}" if args.tag else ""
                existing = out_dir / f"{arch}__{shape}__{mesh_kind}{tag}.json"
                if args.resume and existing.exists():
                    rec = json.loads(existing.read_text())
                    if rec.get("status") in ("ok", "skipped"):
                        n_ok += rec["status"] == "ok"
                        n_skip += rec["status"] == "skipped"
                        continue
                rec = run_cell(arch, shape, mesh_kind,
                               policy_kwargs=policy_kwargs, tag=args.tag,
                               cfg_overrides=cfg_overrides)
                save_record(rec, out_dir)
                n_ok += rec["status"] == "ok"
                n_skip += rec["status"] == "skipped"
                n_err += rec["status"] == "error"
                # one process runs ~80 cells: drop compiled executables and
                # tracing caches or memory accumulates into swap thrash
                jax.clear_caches()
                import gc
                gc.collect()
    print(f"done: ok={n_ok} skipped={n_skip} errors={n_err}")
    if n_err:
        raise SystemExit(1)


if __name__ == "__main__":
    main()
