"""Serving launcher: compartmentalized inference fleet at smoke scale.

Brings up batchers -> leader/proxies/acceptor-grid -> model replicas ->
unbatchers, pushes weights through the replicated log, then serves batched
inference requests as leaderless reads.

  PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
      --requests 12 --replicas 3 --consistency linearizable
"""
from __future__ import annotations

import argparse
import time

import jax

from repro.configs import get_config
from repro.models import init_params
from repro.serving.server import ServingDeployment


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="granite-3-2b")
    ap.add_argument("--replicas", type=int, default=3)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-new", type=int, default=4)
    ap.add_argument("--consistency", default="linearizable",
                    choices=["linearizable", "sequential", "eventual"])
    ap.add_argument("--push-update-midway", action="store_true")
    args = ap.parse_args()

    cfg = get_config(args.arch).smoke()
    params = init_params(cfg, jax.random.key(0))
    fleet = ServingDeployment(cfg, n_replicas=args.replicas, n_clients=2,
                              consistency=args.consistency)
    v = fleet.push_weights(params)
    print(f"arch={cfg.name} replicas={args.replicas} weights v{v} installed")

    t0 = time.time()
    half = args.requests // 2
    for i in range(args.requests):
        if args.push_update_midway and i == half:
            params2 = init_params(cfg, jax.random.key(1))
            v = fleet.push_weights(params2)
            print(f"[weight update] v{v} committed through the log")
        version, toks = fleet.infer([1 + i % 7, 2, 3], max_new=args.max_new,
                                    client=i % 2)
        print(f"req {i:3d} served at weights {version}: tokens={list(toks)}")
    dt = time.time() - t0
    loads = fleet.replica_loads()
    print(f"done: {args.requests} requests in {dt:.1f}s; "
          f"per-replica read loads: {loads} "
          f"(leaderless reads spread across replicas)")


if __name__ == "__main__":
    main()
