"""Production mesh definitions.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches jax device state; the dry-run sets
``xla_force_host_platform_device_count=512`` before any jax import and then
calls it.

Mesh axes:
  single-pod : (data=16, model=16)            - 256 chips (one v5e pod slice)
  multi-pod  : (pod=2, data=16, model=16)     - 512 chips across 2 pods

Axis roles (see repro.runtime.sharding):
  "pod"   - outermost data parallelism; gradient reduction across pods rides
            this axis (optionally int8-compressed - the S-Paxos control/data
            decoupling), or it becomes the pipeline axis when pp=2.
  "data"  - in-pod data parallelism (batch) + ZeRO-1 optimizer sharding.
  "model" - tensor/expert parallelism (heads, ffn, experts, vocab) and the
            sequence axis of decode KV caches.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(n_data: int = 2, n_model: int = 2):
    """Small mesh for unit tests (requires forced host devices)."""
    return jax.make_mesh((n_data, n_model), ("data", "model"))


def data_axes(mesh) -> tuple:
    """All axes that carry batch parallelism."""
    names = mesh.axis_names
    return tuple(a for a in ("pod", "data") if a in names)


def model_axis(mesh) -> str:
    return "model"
