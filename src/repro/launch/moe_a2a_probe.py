import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Layer-level probe for EXPERIMENTS.md §Perf Cell D iteration 2.

Compiles one qwen3-moe MoE layer (fwd + bwd) at the train_4k cell's true
per-shard token counts on the production 16x16 mesh, in both formulations:

  * ``gshard``: the automatic-SPMD one-hot dispatch (the baseline path),
    with tokens sharded over (data x model) and experts over model - the
    layout measured in Cell D iteration 1;
  * ``a2a``: the explicit shard_map all-to-all dispatch
    (runtime/moe_a2a.py).

Reports per-layer collective bytes + flops for each; the cell-level totals
in EXPERIMENTS.md scale by the 48 MoE layers.

  PYTHONPATH=src python -m repro.launch.moe_a2a_probe
"""
import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import get_config
from repro.launch.mesh import make_production_mesh
from repro.models.moe import apply_moe_gshard, init_moe
from repro.roofline.hlo import collective_stats
from repro.runtime.moe_a2a import make_moe_a2a


def main() -> None:
    cfg = get_config("qwen3-moe-30b-a3b")
    moe = cfg.moe
    mesh = make_production_mesh()  # (data=16, model=16)
    d = cfg.d_model
    # train_4k: 1,048,576 global tokens
    B, S = 256, 4096

    params = jax.eval_shape(
        lambda: init_moe(jax.random.key(0), d, moe, cfg.mlp_kind, cfg.dtype()))
    x_spec = jax.ShapeDtypeStruct((B, S, d), cfg.cdtype())

    def param_shardings():
        def assign(path, leaf):
            pstr = "/".join(str(getattr(q, "key", q)) for q in path)
            if "experts" in pstr:
                return NamedSharding(mesh, P(*(("model",)
                                               + (None,) * (leaf.ndim - 1))))
            return NamedSharding(mesh, P(*((None,) * leaf.ndim)))
        return jax.tree_util.tree_map_with_path(assign, params)

    x_sh = NamedSharding(mesh, P(("data", "model"), None, None))

    results = {}
    for name in ("gshard", "a2a"):
        if name == "gshard":
            def loss_fn(p, x):
                out, aux = apply_moe_gshard(p, x, moe, cfg.mlp_kind)
                return jnp.sum(out.astype(jnp.float32)) + aux
        else:
            layer = make_moe_a2a(mesh, moe, cfg.mlp_kind, d)

            def loss_fn(p, x):
                out, aux = layer(p, x)
                return jnp.sum(out.astype(jnp.float32)) + aux

        step = jax.jit(jax.grad(loss_fn), in_shardings=(param_shardings(),
                                                        x_sh))
        with mesh:
            compiled = step.lower(params, x_spec).compile()
        stats = collective_stats(compiled.as_text())
        cost = compiled.cost_analysis() or {}
        total = sum(v["bytes"] for v in stats.values())
        results[name] = (total, stats, float(cost.get("flops", 0.0)))
        print(f"{name:7s} per-layer collective bytes/dev = {total:.3e}  "
              f"flops/dev = {results[name][2]:.3e}")
        for op, v in sorted(stats.items()):
            print(f"         {op}: n={v['count']} bytes={v['bytes']:.3e}")

    g, a = results["gshard"][0], results["a2a"][0]
    print(f"\nper-layer dispatch traffic: gshard {g:.3e} B -> a2a {a:.3e} B "
          f"({g / max(a, 1):.1f}x reduction)")
    print(f"cell-level (x48 layers): {48*g/50e9:.2f}s -> {48*a/50e9:.2f}s "
          f"collective term")


if __name__ == "__main__":
    main()
