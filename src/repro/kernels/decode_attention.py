"""Split-KV flash-decode as a Pallas TPU kernel.

One query token (per sequence) attends to a long KV cache.  The cache is
split along the sequence axis; each grid step computes a partial
(max, sum, weighted-value) triple over its split, merged online in VMEM
scratch - FlashDecoding adapted to the TPU's sequential grid (no atomics:
the kv-split axis is the innermost grid dimension).

This kernel is also the single-chip building block of the *distributed*
split-KV decode in ``repro.runtime.collectives``: each model-axis shard
runs it over its sequence shard and the partials are merged with a psum
(log-sum-exp) - the sharding scheme that lets 4-10 KV-head GQA models use a
16-wide model axis (heads alone don't divide it).

Grid: (B, H_kv, n_splits); all `group` query heads of a kv head are
processed together (block rows = group, MXU-friendly when group >= 8).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref, acc_ref,
                   *, scale: float, block_k: int, n_splits: int, group: int):
    si = pl.program_id(2)

    @pl.when(si == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0].astype(jnp.float32)              # (group, d)
    k = k_ref[0, 0].astype(jnp.float32)              # (block_k, d)
    v = v_ref[0, 0].astype(jnp.float32)
    s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                            preferred_element_type=jnp.float32) * scale
    # mask positions beyond the cache length
    k_pos = si * block_k + jax.lax.broadcasted_iota(jnp.int32,
                                                    (group, block_k), 1)
    valid = k_pos < len_ref[0]
    s = jnp.where(valid, s, NEG_INF)

    m_prev = m_ref[...]
    m_cur = jnp.max(s, axis=1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = (alpha * acc_ref[...]
                    + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                          preferred_element_type=jnp.float32))
    m_ref[...] = m_new

    @pl.when(si == n_splits - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_decode(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                 cache_len: jnp.ndarray, *, block_k: int = 512,
                 interpret: bool = False) -> jnp.ndarray:
    """q: (B, H, d) one token per sequence; caches: (B, H_kv, S_max, d);
    cache_len: (B,) int32.  Returns (B, H, d)."""
    B, H, D = q.shape
    H_kv, S_max = k_cache.shape[1], k_cache.shape[2]
    group = H // H_kv
    scale = 1.0 / math.sqrt(D)
    block_k = min(block_k, S_max)
    assert S_max % block_k == 0, (S_max, block_k)
    n_splits = S_max // block_k

    qg = q.reshape(B, H_kv, group, D)
    kernel = functools.partial(_decode_kernel, scale=scale, block_k=block_k,
                               n_splits=n_splits, group=group)
    out = pl.pallas_call(
        kernel,
        grid=(B, H_kv, n_splits),
        in_specs=[
            pl.BlockSpec((1, 1, group, D), lambda b, h, si: (b, h, 0, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, si: (b, h, si, 0)),
            pl.BlockSpec((1, 1, block_k, D), lambda b, h, si: (b, h, si, 0)),
            pl.BlockSpec((1,), lambda b, h, si: (b,),
                         memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, 1, group, D), lambda b, h, si: (b, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H_kv, group, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, 1), jnp.float32),
            pltpu.VMEM((group, D), jnp.float32),
        ],
        interpret=interpret,
    )(qg, k_cache, v_cache, cache_len.astype(jnp.int32))
    return out.reshape(B, H, D)
