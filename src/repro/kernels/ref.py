"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are small, obviously-correct implementations (full-softmax attention,
serial scans); the model code's chunked paths are themselves tested against
these same oracles, so kernels and models share one ground truth.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def ref_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                  causal: bool = True) -> jnp.ndarray:
    """q: (B, H, S, d); k/v: (B, H_kv, S, d).  Full-softmax reference."""
    B, H, S, D = q.shape
    H_kv = k.shape[1]
    group = H // H_kv
    qg = q.reshape(B, H_kv, group, S, D).astype(jnp.float32)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qg, kf) / math.sqrt(D)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, vf)
    return out.reshape(B, H, S, D).astype(q.dtype)


def ref_decode(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
               cache_len: jnp.ndarray) -> jnp.ndarray:
    """q: (B, H, d); caches: (B, H_kv, S, d); cache_len: (B,)."""
    B, H, D = q.shape
    H_kv, S = k_cache.shape[1], k_cache.shape[2]
    group = H // H_kv
    qg = q.reshape(B, H_kv, group, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qg, k_cache.astype(jnp.float32))
    s = s / math.sqrt(D)
    valid = jnp.arange(S)[None, :] < cache_len[:, None]
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(B, H, D).astype(q.dtype)


def ref_rglru(x: jnp.ndarray, a: jnp.ndarray) -> jnp.ndarray:
    """Serial h_t = a_t h_{t-1} + x_t.  x/a: (B, S, D)."""
    xf = x.astype(jnp.float32)
    af = a.astype(jnp.float32)

    def step(h, inp):
        a_t, x_t = inp
        h = a_t * h + x_t
        return h, h

    h0 = jnp.zeros_like(xf[:, 0])
    _, hs = jax.lax.scan(step, h0, (af.swapaxes(0, 1), xf.swapaxes(0, 1)))
    return hs.swapaxes(0, 1).astype(x.dtype)


def ref_latency_hist(samples: jnp.ndarray, valid: jnp.ndarray,
                     edges: jnp.ndarray) -> jnp.ndarray:
    """Masked histogram per lane.  samples/valid: (L, N); edges: (L, B+1).
    Bin = searchsorted-left(edges, sample) - 1, clipped to [0, B) - the
    transient plane's binning, so quantile reads agree across planes."""
    n_bins = edges.shape[-1] - 1
    idx = jnp.sum((edges[:, None, :] < samples[..., None]).astype(jnp.int32),
                  axis=-1) - 1
    idx = jnp.clip(idx, 0, n_bins - 1)
    onehot = jax.nn.one_hot(idx, n_bins, dtype=jnp.int32)
    onehot = onehot * (valid > 0).astype(jnp.int32)[..., None]
    return onehot.sum(axis=1)


def ref_wkv6(r, k, v, logw, u):
    """Serial RWKV-6 recurrence.  r/k/v/logw: (B, H, S, d); u: (H, d)."""
    B, H, S, D = k.shape
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    lw = logw.astype(jnp.float32)

    def step(s, inp):
        r_t, k_t, v_t, lw_t = inp  # (B,H,D) each
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = jnp.exp(lw_t)[..., None] * s + kv
        return s, y

    s0 = jnp.zeros((B, H, D, D), jnp.float32)
    xs = tuple(t.transpose(2, 0, 1, 3) for t in (rf, kf, vf, lw))
    _, ys = jax.lax.scan(step, s0, xs)
    return ys.transpose(1, 2, 0, 3).astype(r.dtype)
