"""RWKV-6 WKV recurrence as a Pallas TPU kernel (chunked / GLA form).

Per (batch, head), the state S in R^{dk x dv} is carried in VMEM scratch
across sequence chunks (innermost sequential grid dim).  Each chunk does
three MXU contractions (intra-chunk scores, intra-chunk output, state
update) plus VPU exponentials - the same math as
``repro.models.rwkv6.wkv6_chunked`` (the oracle), with the same log-domain
recentering so f32 never overflows.

Layout: head_dim=64 pairs two heads per 128-lane register on real TPUs; we
keep one head per grid step for clarity (the d=64 tiles still map to the
MXU's 128x128 with 2x padding - noted as future work in EXPERIMENTS.md).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _wkv6_kernel(r_ref, k_ref, v_ref, lw_ref, u_ref, o_ref, s_ref, *,
                 chunk: int, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        s_ref[...] = jnp.zeros_like(s_ref)

    r = r_ref[0, 0].astype(jnp.float32)      # (chunk, dk)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)      # (chunk, dv)
    lw = lw_ref[0, 0].astype(jnp.float32)    # (chunk, dk)
    u = u_ref[0].astype(jnp.float32)         # (dk,)

    cum = jnp.cumsum(lw, axis=0)             # inclusive
    cume = cum - lw                          # exclusive
    total = cum[-1]                          # (dk,)

    # intra-chunk, recentered at theta = total/2 (bounded exponents)
    theta = 0.5 * total[None, :]
    q_in = r * jnp.exp(cume - theta)
    k_in = k * jnp.exp(theta - cum)
    scores = jax.lax.dot_general(q_in, k_in, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)
    i_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    j_idx = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    scores = jnp.where(i_idx > j_idx, scores, 0.0)
    diag = jnp.sum(r * u[None, :] * k, axis=1, keepdims=True)  # (chunk, 1)

    y = jax.lax.dot_general(scores, v, (((1,), (0,)), ((), ())),
                            preferred_element_type=jnp.float32)
    y = y + diag * v
    # inter-chunk: y += (r * exp(cume)) @ S
    y = y + jax.lax.dot_general(r * jnp.exp(cume), s_ref[...],
                                (((1,), (0,)), ((), ())),
                                preferred_element_type=jnp.float32)
    o_ref[0, 0] = y.astype(o_ref.dtype)

    # state update: S = exp(total) * S + (k * exp(total - cum))^T @ v
    k_carry = k * jnp.exp(total[None, :] - cum)
    s_ref[...] = (jnp.exp(total)[:, None] * s_ref[...]
                  + jax.lax.dot_general(k_carry, v, (((0,), (0,)), ((), ())),
                                        preferred_element_type=jnp.float32))


def wkv6(r: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, logw: jnp.ndarray,
         u: jnp.ndarray, *, chunk: int = 32, interpret: bool = False
         ) -> jnp.ndarray:
    """r/k/v: (B, H, S, d); logw: (B, H, S, d) f32 (clamped >= -5);
    u: (H, d).  Returns y: (B, H, S, d)."""
    B, H, S, D = k.shape
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n_chunks = S // chunk

    kernel = functools.partial(_wkv6_kernel, chunk=chunk, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_chunks),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, 1, chunk, D), lambda b, h, ci: (b, h, ci, 0)),
            pl.BlockSpec((1, D), lambda b, h, ci: (h, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, D), lambda b, h, ci: (b, h, ci, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), r.dtype),
        scratch_shapes=[pltpu.VMEM((D, D), jnp.float32)],
        interpret=interpret,
    )(r, k, v, logw, u)
