"""Pallas TPU kernels for the data plane's compute hot spots.

The paper itself is protocol-level (no kernel contribution); these kernels
are the perf-critical compute layers of the training/serving substrate:

  flash_attention  - training forward (causal, GQA)
  decode_attention - split-KV flash-decode (the single-chip block of the
                     distributed sequence-sharded decode)
  rglru_scan       - RG-LRU linear recurrence (RecurrentGemma)
  rwkv6_scan       - RWKV-6 WKV chunked recurrence
  latency_hist     - masked per-lane latency histogramming for the batched
                     execution plane's p50/p99 surfaces

Each ships with ``ops.py`` (jitted wrapper, backend dispatch) and ``ref.py``
(pure-jnp oracle); validated in interpret mode on CPU.
"""
from . import ops, ref

__all__ = ["ops", "ref"]
