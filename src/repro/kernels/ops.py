"""Jitted wrappers with backend dispatch for the Pallas kernels.

On TPU the Pallas path runs compiled; everywhere else (this CPU container,
debugging) the same kernel body executes under ``interpret=True``, or the
caller can force the jnp reference.  Model code calls these wrappers; the
dry-run lowers the jnp path (CPU backend), which is what the roofline reads
- the kernels are the TPU fast path validated by tests/test_kernels*.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from . import ref
from .decode_attention import flash_decode as _flash_decode
from .flash_attention import flash_attention as _flash_attention
from .latency_hist import latency_hist as _latency_hist
from .rglru_scan import rglru_scan as _rglru_scan
from .rwkv6_scan import wkv6 as _wkv6


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad_heads_dim(x, multiple: int = 128):
    d = x.shape[-1]
    pad = (-d) % multiple
    if pad == 0:
        return x, d
    widths = [(0, 0)] * (x.ndim - 1) + [(0, pad)]
    return jnp.pad(x, widths), d


@functools.partial(jax.jit, static_argnames=("causal", "use_pallas"))
def flash_attention(q, k, v, causal: bool = True,
                    use_pallas: Optional[bool] = None):
    """q: (B, H, S, d); k/v: (B, H_kv, S, d)."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas and not _on_tpu():
        # CPU fast path for tests that don't exercise the kernel body
        return ref.ref_attention(q, k, v, causal=causal)
    qp, d0 = _pad_heads_dim(q)
    kp, _ = _pad_heads_dim(k)
    vp, _ = _pad_heads_dim(v)
    out = _flash_attention(qp, kp, vp, causal=causal,
                           interpret=not _on_tpu())
    return out[..., :d0]


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def flash_decode(q, k_cache, v_cache, cache_len,
                 use_pallas: Optional[bool] = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas and not _on_tpu():
        return ref.ref_decode(q, k_cache, v_cache, cache_len)
    return _flash_decode(q, k_cache, v_cache, cache_len,
                         interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def rglru_scan(x, a, use_pallas: Optional[bool] = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas and not _on_tpu():
        return ref.ref_rglru(x, a)
    return _rglru_scan(x, a, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def latency_hist(samples, valid, edges, use_pallas: Optional[bool] = None):
    """samples/valid: (L, N); edges: (L, B+1) -> (L, B) int32."""
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas and not _on_tpu():
        return ref.ref_latency_hist(samples, valid, edges)
    return _latency_hist(samples, valid, edges, interpret=not _on_tpu())


@functools.partial(jax.jit, static_argnames=("use_pallas",))
def wkv6(r, k, v, logw, u, use_pallas: Optional[bool] = None):
    if use_pallas is None:
        use_pallas = _on_tpu()
    if not use_pallas and not _on_tpu():
        return ref.ref_wkv6(r, k, v, logw, u)
    return _wkv6(r, k, v, logw, u, interpret=not _on_tpu())
