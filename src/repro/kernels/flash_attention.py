"""Flash attention (training forward) as a Pallas TPU kernel.

TPU-native design (not a CUDA port):
  * grid = (batch, q_heads, num_q_blocks, num_kv_blocks); the kv dimension is
    the innermost sequential ("arbitrary") dimension, so the online-softmax
    running state lives in VMEM scratch and persists across kv steps - the
    TPU analogue of a CUDA thread-block loop, matched to the sequential grid
    execution of the scalar core.
  * Block shapes are MXU-aligned: q/kv blocks of 128 rows, head_dim padded to
    the 128-lane register width by the caller.
  * GQA is handled in the index maps: kv specs select head ``h // group``, so
    grouped queries re-read the same K/V block from HBM->VMEM (the fusion the
    roofline analysis credits over the jnp reference).

Causal masking: kv blocks strictly above the diagonal are skipped with
``pl.when`` (no MXU work), diagonal blocks apply an elementwise mask.

Numerics follow the reference exactly: f32 scores, online max/sum in f32,
output cast back to the input dtype.
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  scale: float, causal: bool, block_q: int, block_k: int,
                  n_kv_blocks: int):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q_start = qi * block_q
    k_start = ki * block_k

    run = True
    if causal:
        # skip blocks entirely above the diagonal
        run = k_start <= q_start + block_q - 1

    @pl.when(run)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # (block_q, d)
        k = k_ref[0, 0].astype(jnp.float32)          # (block_k, d)
        v = v_ref[0, 0].astype(jnp.float32)          # (block_k, d)
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        if causal:
            q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 0)
            k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32,
                                                       (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)

        m_prev = m_ref[...]                          # (block_q, 1)
        m_cur = jnp.max(s, axis=1, keepdims=True)
        m_new = jnp.maximum(m_prev, m_cur)
        p = jnp.exp(s - m_new)                       # (block_q, block_k)
        alpha = jnp.exp(m_prev - m_new)              # rescale old state
        l_ref[...] = alpha * l_ref[...] + jnp.sum(p, axis=1, keepdims=True)
        acc_ref[...] = (alpha * acc_ref[...]
                        + jax.lax.dot_general(p, v, (((1,), (0,)), ((), ())),
                                              preferred_element_type=jnp.float32))
        m_ref[...] = m_new

    @pl.when(ki == n_kv_blocks - 1)
    def _finalize():
        o_ref[0, 0] = (acc_ref[...]
                       / jnp.maximum(l_ref[...], 1e-30)).astype(o_ref.dtype)


def flash_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
                    causal: bool = True, block_q: int = 128,
                    block_k: int = 128, interpret: bool = False
                    ) -> jnp.ndarray:
    """q: (B, H, S, d); k/v: (B, H_kv, S, d).  Returns (B, H, S, d).

    S must be divisible by the block sizes; d should be a multiple of 128 on
    real TPUs (the ops.py wrapper pads)."""
    B, H, S, D = q.shape
    H_kv = k.shape[1]
    group = H // H_kv
    scale = 1.0 / math.sqrt(D)
    block_q = min(block_q, S)
    block_k = min(block_k, S)
    assert S % block_q == 0 and S % block_k == 0, (S, block_q, block_k)
    n_q = S // block_q
    n_k = S // block_k

    kernel = functools.partial(_flash_kernel, scale=scale, causal=causal,
                               block_q=block_q, block_k=block_k,
                               n_kv_blocks=n_k)
    return pl.pallas_call(
        kernel,
        grid=(B, H, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, D), lambda b, h, qi, ki: (b, h, qi, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, D),
                         lambda b, h, qi, ki, g=group: (b, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, D),
                               lambda b, h, qi, ki: (b, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((B, H, S, D), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),   # running max
            pltpu.VMEM((block_q, 1), jnp.float32),   # running sum
            pltpu.VMEM((block_q, D), jnp.float32),   # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
