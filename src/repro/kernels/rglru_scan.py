"""RG-LRU linear recurrence as a Pallas TPU kernel.

The recurrence  h_t = a_t * h_{t-1} + x_t  is elementwise per channel (VPU
work, no MXU).  TPU-native shape: the channel axis is blocked to the 128-lane
width and the sequence is walked in VMEM-resident chunks; the carried state
h lives in VMEM scratch across chunk grid steps (innermost sequential grid
dimension), so HBM traffic is exactly one read of (x, a) and one write of h -
the memory-bound roofline for this op.

Grid: (B, n_channel_blocks, n_seq_chunks); within a chunk a
``jax.lax.associative_scan`` (log-depth) runs on the VPU.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _rglru_kernel(x_ref, a_ref, o_ref, h_ref, *, n_chunks: int):
    ci = pl.program_id(2)

    @pl.when(ci == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)        # (chunk, block_d)
    a = a_ref[0].astype(jnp.float32)

    # fold carried state into the first step: h_0 = a_0 * h_in + x_0
    x = x.at[0].add(a[0] * h_ref[0])

    def combine(e1, e2):
        a1, x1 = e1
        a2, x2 = e2
        return a1 * a2, a2 * x1 + x2

    _, h = jax.lax.associative_scan(combine, (a, x), axis=0)
    o_ref[0] = h.astype(o_ref.dtype)
    h_ref[0] = h[-1]


def rglru_scan(x: jnp.ndarray, a: jnp.ndarray, *, chunk: int = 256,
               block_d: int = 128, interpret: bool = False) -> jnp.ndarray:
    """x, a: (B, S, D).  Returns h: (B, S, D) with h_t = a_t h_{t-1} + x_t."""
    B, S, D = x.shape
    chunk = min(chunk, S)
    block_d = min(block_d, D)
    assert S % chunk == 0 and D % block_d == 0, (S, chunk, D, block_d)
    n_chunks = S // chunk
    n_db = D // block_d

    kernel = functools.partial(_rglru_kernel, n_chunks=n_chunks)
    return pl.pallas_call(
        kernel,
        grid=(B, n_db, n_chunks),
        in_specs=[
            pl.BlockSpec((1, chunk, block_d), lambda b, di, ci: (b, ci, di)),
            pl.BlockSpec((1, chunk, block_d), lambda b, di, ci: (b, ci, di)),
        ],
        out_specs=pl.BlockSpec((1, chunk, block_d),
                               lambda b, di, ci: (b, ci, di)),
        out_shape=jax.ShapeDtypeStruct((B, S, D), x.dtype),
        scratch_shapes=[pltpu.VMEM((1, block_d), jnp.float32)],
        interpret=interpret,
    )(x, a)
