"""Masked latency histogramming as a Pallas TPU kernel.

The batched execution plane (``repro.core.batched_execution``) emits one
(latency, valid) sample per protocol step per lane; turning those streams
into p50/p99 surfaces means binning every sample against its lane's
log-spaced edge vector - the same ``searchsorted(edges) - 1`` convention
``transient.py`` uses, so quantiles read identically across planes.

The bin update is a scatter-add in spirit, but TPUs hate scatters: the
kernel instead materialises the (samples x bins) one-hot comparison matrix
in VMEM and reduces over the sample axis - pure VPU work, one HBM read of
the samples and one write of the histogram per lane.  Grid: one program
per lane (a lane = one config x seed x client stream), so a whole sweep's
histograms build in a single launch.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hist_kernel(s_ref, v_ref, e_ref, o_ref):
    lat = s_ref[0]                     # (N,) f32 latencies
    valid = v_ref[0]                   # (N,) f32 mask (> 0 = real sample)
    edges = e_ref[0]                   # (B+1,) ascending bin edges
    n_bins = o_ref.shape[-1]
    # searchsorted-left minus one: #{j : edges_j < lat} - 1, clipped - the
    # exact binning transient.py applies, expressed as a comparison matrix
    idx = jnp.sum((edges[None, :] < lat[:, None]).astype(jnp.int32),
                  axis=1) - 1
    idx = jnp.clip(idx, 0, n_bins - 1)
    bin_ids = jax.lax.broadcasted_iota(jnp.int32, (lat.shape[0], n_bins), 1)
    onehot = (idx[:, None] == bin_ids) & (valid[:, None] > 0)
    o_ref[0] = jnp.sum(onehot.astype(jnp.int32), axis=0)


def latency_hist(samples: jnp.ndarray, valid: jnp.ndarray,
                 edges: jnp.ndarray, *, interpret: bool = False
                 ) -> jnp.ndarray:
    """samples/valid: (L, N); edges: (L, B+1).  Returns (L, B) int32 counts
    of valid samples per bin (out-of-range samples clamp to the end bins,
    matching the transient plane's convention)."""
    L, N = samples.shape
    B = edges.shape[-1] - 1
    assert edges.shape[0] == L and valid.shape == (L, N), (
        samples.shape, valid.shape, edges.shape)
    return pl.pallas_call(
        _hist_kernel,
        grid=(L,),
        in_specs=[
            pl.BlockSpec((1, N), lambda l: (l, 0)),
            pl.BlockSpec((1, N), lambda l: (l, 0)),
            pl.BlockSpec((1, B + 1), lambda l: (l, 0)),
        ],
        out_specs=pl.BlockSpec((1, B), lambda l: (l, 0)),
        out_shape=jax.ShapeDtypeStruct((L, B), jnp.int32),
        interpret=interpret,
    )(samples, valid.astype(jnp.float32), edges)
