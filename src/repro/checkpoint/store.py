"""Grid-quorum checkpoint store - compartmentalization 2 applied to
checkpoint I/O.

Storage nodes form an ``r x w`` grid (paper section 3.2).  A checkpoint is
split into per-leaf shards; shard ``i`` is assigned to column ``i % w`` and
written to **every row of that column** (a write quorum).  A restore picks
any **row** (a read quorum): every row intersects every column, so one row
holds at least one replica of every shard.

Consequences (mirroring the paper's acceptor-load argument):
  * each storage node absorbs ~1/w of checkpoint write bytes -> scale write
    bandwidth by adding columns;
  * each node serves ~1/r of restore reads -> scale restore/validation
    bandwidth by adding rows;
  * any f < r node failures per column leave a live replica; any f < w
    column outages still leave recovery via other rows' copies of other
    columns... (grid tolerates one full row AND one full column loss).

Saves are asynchronous (background thread) with crc32 integrity; the
manifest is the unit the training coordinator orders through the RSM log
(CKPT_COMMIT) - control path carries manifests, data path carries tensor
bytes (the S-Paxos split).
"""
from __future__ import annotations

import json
import threading
import time
import zlib
from dataclasses import dataclass
from pathlib import Path
from typing import Any, Dict, List, Optional, Set, Tuple

import jax
import numpy as np


@dataclass
class Manifest:
    step: int
    leaves: Dict[str, dict]   # name -> {column, shape, dtype, crc32, bytes}
    treedef_repr: str
    created_at: float

    def to_json(self) -> str:
        return json.dumps({"step": self.step, "leaves": self.leaves,
                           "treedef_repr": self.treedef_repr,
                           "created_at": self.created_at})

    @staticmethod
    def from_json(s: str) -> "Manifest":
        d = json.loads(s)
        return Manifest(step=d["step"], leaves=d["leaves"],
                        treedef_repr=d["treedef_repr"],
                        created_at=d["created_at"])


def _leaf_names(tree) -> Tuple[List[str], List[Any], Any]:
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    names, leaves = [], []
    for path, leaf in flat:
        names.append(jax.tree_util.keystr(path))
        leaves.append(leaf)
    return names, leaves, jax.tree_util.tree_structure(tree)


class GridCheckpointStore:
    def __init__(self, base_dir: str, rows: int = 2, cols: int = 2) -> None:
        self.base = Path(base_dir)
        self.rows, self.cols = rows, cols
        self.dead: Set[Tuple[int, int]] = set()
        self.write_bytes_per_node: Dict[Tuple[int, int], int] = {}
        for r in range(rows):
            for c in range(cols):
                self._node_dir(r, c).mkdir(parents=True, exist_ok=True)
        self._async_threads: List[threading.Thread] = []

    # -- fault injection ------------------------------------------------------
    def fail_node(self, row: int, col: int) -> None:
        self.dead.add((row, col))

    def recover_node(self, row: int, col: int) -> None:
        self.dead.discard((row, col))

    def _node_dir(self, row: int, col: int) -> Path:
        return self.base / f"node_r{row}_c{col}"

    # -- save -------------------------------------------------------------------
    def save(self, step: int, tree) -> Manifest:
        names, leaves, treedef = _leaf_names(tree)
        manifest_leaves: Dict[str, dict] = {}
        for i, (name, leaf) in enumerate(zip(names, leaves)):
            # bf16 has no numpy dtype: store as a uint16 view
            dtype_str = str(leaf.dtype)
            if dtype_str == "bfloat16":
                arr = np.asarray(jax.numpy.asarray(leaf).view(np.uint16))
            else:
                arr = np.asarray(leaf)
            data = arr.tobytes()
            col = i % self.cols
            crc = zlib.crc32(data)
            fname = f"step{step}_{i:05d}.bin"
            for row in range(self.rows):  # write quorum = the whole column
                if (row, col) in self.dead:
                    continue
                path = self._node_dir(row, col) / fname
                path.write_bytes(data)
                key = (row, col)
                self.write_bytes_per_node[key] = (
                    self.write_bytes_per_node.get(key, 0) + len(data))
            manifest_leaves[name] = {
                "index": i, "column": col, "shape": list(arr.shape),
                "dtype": dtype_str, "crc32": crc, "bytes": len(data),
                "file": fname,
            }
        manifest = Manifest(step=step, leaves=manifest_leaves,
                            treedef_repr=str(treedef), created_at=time.time())
        (self.base / f"manifest_step{step}.json").write_text(manifest.to_json())
        return manifest

    def save_async(self, step: int, tree) -> threading.Thread:
        """Snapshot to host first (cheap), then write in the background -
        training continues while bytes hit 'storage'."""
        host_tree = jax.tree.map(np.asarray, jax.device_get(tree))
        t = threading.Thread(target=self.save, args=(step, host_tree),
                             daemon=True)
        t.start()
        self._async_threads.append(t)
        return t

    def wait(self) -> None:
        for t in self._async_threads:
            t.join()
        self._async_threads.clear()

    # -- restore ----------------------------------------------------------------
    def latest_step(self) -> Optional[int]:
        steps = sorted(int(p.stem.split("step")[1])
                       for p in self.base.glob("manifest_step*.json"))
        return steps[-1] if steps else None

    def restore(self, step: int, like_tree) -> Any:
        """Read one live row (read quorum); per leaf fall back across rows of
        its column if a node is dead or the payload is corrupt."""
        manifest = Manifest.from_json(
            (self.base / f"manifest_step{step}.json").read_text())
        names, leaves, treedef = _leaf_names(like_tree)
        out_leaves = []
        # pick a starting row that is maximally alive
        row_order = sorted(range(self.rows),
                           key=lambda r: sum((r, c) in self.dead
                                             for c in range(self.cols)))
        for name, like in zip(names, leaves):
            meta = manifest.leaves[name]
            col = meta["column"]
            data = None
            for row in row_order:
                if (row, col) in self.dead:
                    continue
                path = self._node_dir(row, col) / meta["file"]
                if not path.exists():
                    continue
                blob = path.read_bytes()
                if zlib.crc32(blob) != meta["crc32"]:
                    continue  # bit rot: try the next replica
                data = blob
                break
            if data is None:
                raise IOError(
                    f"no intact replica of {name} (column {col}) - more than "
                    f"f failures in that column")
            dtype = meta["dtype"]
            if dtype == "bfloat16":
                arr = np.frombuffer(data, np.uint16).reshape(meta["shape"])
                leaf = jax.numpy.asarray(arr).view(jax.numpy.bfloat16)
            else:
                arr = np.frombuffer(data, np.dtype(dtype)).reshape(meta["shape"])
                leaf = jax.numpy.asarray(arr)
            out_leaves.append(leaf)
        return jax.tree_util.tree_unflatten(treedef, out_leaves)

    # -- accounting ---------------------------------------------------------------
    def write_load_fractions(self) -> Dict[str, float]:
        total = sum(self.write_bytes_per_node.values())
        if not total:
            return {}
        return {f"r{r}c{c}": b / total
                for (r, c), b in sorted(self.write_bytes_per_node.items())}
