"""Explicit all-to-all MoE dispatch (shard_map) - the structural fix for
EXPERIMENTS.md §Perf Cell D.

Under automatic SPMD, the GShard one-hot dispatch einsum with tokens
sharded over (data x model) and experts sharded over model lowers to token
*all-gathers* (each expert shard pulls every token) - measured 25% more
collective bytes than baseline TP. The correct pattern is an
**all-to-all**: each source shard packs per-expert capacity buckets and
ships each bucket only to the shard that owns that expert.

Per model-axis shard (inside shard_map):
  1. route local tokens: top-k experts + weights (router is replicated);
  2. scatter tokens into a (E, C_loc, d) capacity buffer (E = global
     expert count, C_loc = local capacity per expert);
  3. ``jax.lax.all_to_all`` over the model axis: (E, C_loc, d) ->
     (E_loc, M * C_loc, d) - every shard now holds exactly the tokens
     bound for ITS experts;
  4. run the local experts' FFN;
  5. reverse all-to-all; combine with routing weights locally.

Bytes per device per layer: 2 x (top_k * T_loc * cf * d) - independent of
the expert count, vs the gather formulation's E-fold token replication.

Numerics match ``models.moe.apply_moe_dense`` exactly when capacity is
sufficient (drop-free); validated on a 4-device mesh in
tests/test_distributed_moe.py.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.moe import MoEConfig
from repro.runtime.compat import shard_map


def _local_dispatch(x, top_w, top_i, n_experts: int, capacity: int):
    """Scatter local tokens into per-expert capacity buckets.

    x: (T, d); top_w/top_i: (T, k).  Returns (buf (E, C, d),
    slot_of (T, k) int32 [-1 if dropped], kept (T, k) bool)."""
    T, k = top_i.shape
    flat_e = top_i.reshape(-1)                      # (T*k,)
    onehot = jax.nn.one_hot(flat_e, n_experts, dtype=jnp.int32)
    pos = jnp.cumsum(onehot, axis=0) - onehot       # position within expert
    slot = jnp.sum(pos * onehot, axis=1)            # (T*k,)
    kept = slot < capacity
    dest = jnp.where(kept, flat_e * capacity + slot, n_experts * capacity)
    buf = jnp.zeros((n_experts * capacity + 1, x.shape[-1]), x.dtype)
    src = jnp.repeat(x, k, axis=0)                  # (T*k, d)
    buf = buf.at[dest].set(src)                     # drops land in the pad row
    return (buf[:-1].reshape(n_experts, capacity, x.shape[-1]),
            jnp.where(kept, slot, -1).reshape(T, k),
            kept.reshape(T, k))


def make_moe_a2a(mesh: Mesh, cfg: MoEConfig, mlp_kind: str, d_model: int,
                 axis: str = "model", dp_axis: str = "data"):
    """Returns fn(params, x) -> (out, aux) running expert-parallel MoE with
    explicit all-to-alls.  params: as ``models.moe.init_moe`` but with the
    expert leaves sharded (E_loc, ...) over ``axis``; x: (B, S, d) with
    batch sharded over ``dp_axis``."""
    from repro.models.layers import apply_mlp
    from repro.models.moe import router_probs

    M = mesh.shape[axis]
    assert cfg.n_experts % M == 0, (cfg.n_experts, M)
    e_loc = cfg.n_experts // M

    def shard_fn(params, x):
        B, S, D = x.shape
        T = B * S
        xt = x.reshape(T, D)
        gates, top_w, top_i = router_probs(params, xt, cfg)
        capacity = max(int(math.ceil(cfg.top_k * T * cfg.capacity_factor
                                     / cfg.n_experts)), cfg.top_k)
        buf, slot, kept = _local_dispatch(xt, top_w, top_i,
                                          cfg.n_experts, capacity)
        # (E, C, d) -> (e_loc, M*C, d): expert blocks are contiguous, so a
        # tiled all-to-all ships block m to shard m and concatenates the M
        # incoming capacity buckets for MY experts
        recv = jax.lax.all_to_all(buf, axis, split_axis=0, concat_axis=1,
                                  tiled=True)

        def per_expert(ep, xin):
            return apply_mlp(ep, xin, mlp_kind)

        out_loc = jax.vmap(per_expert)(params["experts"], recv)
        # reverse: (e_loc, M*C, d) -> (E, C, d) rows back to their sources
        sent = jax.lax.all_to_all(out_loc, axis, split_axis=1, concat_axis=0,
                                  tiled=True)
        # gather my tokens' results and combine with routing weights
        flat_e = top_i.reshape(-1)
        flat_s = jnp.maximum(slot.reshape(-1), 0)
        vals = sent[flat_e, flat_s]                  # (T*k, d)
        vals = vals * kept.reshape(-1, 1).astype(vals.dtype)
        w = top_w.reshape(-1, 1).astype(vals.dtype)
        out = jnp.sum((vals * w).reshape(T, cfg.top_k, D), axis=1)
        if "shared" in params:
            out = out + apply_mlp(params["shared"], xt, mlp_kind)
        from repro.models.moe import load_balance_loss
        aux = load_balance_loss(gates, top_i, cfg.n_experts)
        aux = jax.lax.pmean(jax.lax.pmean(aux, dp_axis), axis)
        return out.reshape(B, S, D), aux

    def specs_for(params):
        def assign(path, leaf):
            pstr = "/".join(str(getattr(q, "key", q)) for q in path)
            if "experts" in pstr:
                return P(*(("model",) + (None,) * (leaf.ndim - 1)))
            return P(*((None,) * leaf.ndim))
        return jax.tree_util.tree_map_with_path(assign, params)

    def fn(params, x):
        # tokens partitioned over BOTH axes (EP+DP): each shard routes and
        # dispatches only its own tokens - this is what the automatic-SPMD
        # formulation failed to express (it gathered instead)
        tok_spec = P((dp_axis, axis), None, None)
        in_specs = (specs_for(params), tok_spec)
        return shard_map(shard_fn, mesh=mesh, in_specs=in_specs,
                         out_specs=(tok_spec, P()),
                         check_vma=False)(params, x)

    return fn
