"""JAX version-compatibility shims for the runtime substrate.

The only shim today is :func:`shard_map`.  The API moved twice upstream:

* ``jax.experimental.shard_map.shard_map(f, mesh, in_specs, out_specs,
  check_rep=...)`` - the home on JAX <= 0.4.x / 0.5.x (0.4.37 is what this
  container ships);
* ``jax.shard_map(f, mesh=..., in_specs=..., out_specs=..., check_vma=...)``
  - the stable top-level home from 0.6, where ``check_rep`` was renamed
  ``check_vma``.

Callers here always use the new keyword (``check_vma``); the shim forwards
it as ``check_rep`` when falling back to the experimental entry point.
"""
from __future__ import annotations

from typing import Any

import jax

_NEW_SHARD_MAP = getattr(jax, "shard_map", None)


def shard_map(f, *, mesh, in_specs, out_specs, check_vma: bool = True) -> Any:
    """``jax.shard_map`` on new JAX, ``jax.experimental.shard_map`` otherwise."""
    if _NEW_SHARD_MAP is not None:
        return _NEW_SHARD_MAP(f, mesh=mesh, in_specs=in_specs,
                              out_specs=out_specs, check_vma=check_vma)
    from jax.experimental.shard_map import shard_map as _old
    return _old(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                check_rep=check_vma)
