"""Step functions (train / prefill / serve) + dry-run input specs.

These are the functions the launcher jits, the dry-run lowers for every
(arch x shape x mesh) cell, and the roofline reads.  They close over the
static ModelConfig; all array state is explicit.
"""
from __future__ import annotations

from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import ShapeSpec
from repro.models import model as model_lib
from repro.optim.adamw import AdamWConfig, adamw_update, init_opt_state


def make_train_step(cfg: ModelConfig, opt_cfg: Optional[AdamWConfig] = None):
    opt_cfg = opt_cfg or AdamWConfig()

    def train_step(params, opt_state, batch):
        (loss, metrics), grads = jax.value_and_grad(
            lambda p: model_lib.loss_fn(cfg, p, batch), has_aux=True)(params)
        new_params, new_opt, opt_metrics = adamw_update(opt_cfg, grads,
                                                        opt_state, params)
        metrics = dict(metrics)
        metrics.update(opt_metrics)
        return new_params, new_opt, metrics

    return train_step


def make_prefill_step(cfg: ModelConfig):
    def prefill_step(params, batch):
        return model_lib.prefill(cfg, params, batch["tokens"],
                                 frames=batch.get("frames"),
                                 cache_len=batch["tokens"].shape[1])
    return prefill_step


def make_serve_step(cfg: ModelConfig):
    """One decode step: greedy next token against a filled KV cache."""

    def serve_step(params, caches, token):
        logits, new_caches = model_lib.decode_step(cfg, params, caches, token)
        next_token = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        return next_token, logits, new_caches

    return serve_step


# ---------------------------------------------------------------------------
# dry-run input specs (ShapeDtypeStruct stand-ins; no allocation)
# ---------------------------------------------------------------------------


def batch_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    B, S = shape.global_batch, shape.seq_len
    specs = {
        "tokens": jax.ShapeDtypeStruct((B, S), jnp.int32),
        "labels": jax.ShapeDtypeStruct((B, S), jnp.int32),
    }
    if cfg.is_encoder_decoder:
        # modality frontend stub: precomputed frame embeddings
        specs["frames"] = jax.ShapeDtypeStruct(
            (B, cfg.encoder_seq_len, cfg.d_model), jnp.dtype(cfg.compute_dtype))
    return specs


def params_specs(cfg: ModelConfig):
    return jax.eval_shape(lambda: model_lib.init_params(cfg, jax.random.key(0)))


def opt_state_specs(params_shape):
    return jax.eval_shape(init_opt_state, params_shape)


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int):
    return jax.eval_shape(
        lambda: model_lib.init_cache(cfg, batch, cache_len))


def input_specs(cfg: ModelConfig, shape: ShapeSpec) -> Dict[str, Any]:
    """Every model input for the given cell, as ShapeDtypeStructs."""
    if shape.kind == "train":
        p = params_specs(cfg)
        return {
            "params": p,
            "opt_state": opt_state_specs(p),
            "batch": batch_specs(cfg, shape),
        }
    if shape.kind == "prefill":
        return {
            "params": params_specs(cfg),
            "batch": batch_specs(cfg, shape),
        }
    if shape.kind == "decode":
        return {
            "params": params_specs(cfg),
            "caches": cache_specs(cfg, shape.global_batch, shape.seq_len),
            "token": jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32),
        }
    raise ValueError(shape.kind)
