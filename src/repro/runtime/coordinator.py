"""RSM-backed training coordinator: the paper's control plane driving the
data plane.

The coordinator is a *replicated state machine over training-control
commands*, ordered by compartmentalized MultiPaxos (repro.core):

    ("step_commit", step, worker_digest)   - global step barrier record
    ("ckpt_commit", step, manifest_id)     - checkpoint becomes restorable
    ("join", worker) / ("leave", worker)   - elastic membership
    ("noop_fill", worker, step)            - Mencius-style straggler skip

Why an RSM?  At 1000+ nodes the coordinator must survive node failures and
partitions; commands are tiny (ids and digests - the S-Paxos control path),
while tensors move through collectives and the checkpoint grid (data path).
The log is the single source of truth for "which step/checkpoint is
committed", exactly like the paper's replicas executing a deterministic log.

Straggler policy (paper section 6, Mencius): each training step owns one
log slot per worker report; a worker lagging more than ``skip_after`` steps
behind the frontier gets its slots noop-filled - the step commits with a
``scale_factor`` recording the missing microbatch fraction (bounded
staleness, keeps the log hole-free so commits never stall on one slow
host).
"""
from __future__ import annotations

import json
from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Set, Tuple

from repro.core.protocols import (
    CompartmentalizedMultiPaxos,
    DeploymentConfig,
)
from repro.core.statemachine import StateMachine


@dataclass
class ClusterView:
    """Deterministic state produced by replaying the control log."""
    workers: List[str] = field(default_factory=list)
    committed_step: int = -1
    step_reports: Dict[int, Set[str]] = field(default_factory=dict)
    step_noops: Dict[int, Set[str]] = field(default_factory=dict)
    committed_ckpt: Optional[int] = None
    generation: int = 0  # bumps on membership change -> mesh rebuild


def apply_command(view: ClusterView, op: Tuple) -> Any:
    kind = op[0]
    if kind == "join":
        _, worker = op
        if worker not in view.workers:
            view.workers.append(worker)
            view.generation += 1
        return ("joined", view.generation)
    if kind == "leave":
        _, worker = op
        if worker in view.workers:
            view.workers.remove(worker)
            view.generation += 1
        return ("left", view.generation)
    if kind == "report":
        _, worker, step = op
        view.step_reports.setdefault(step, set()).add(worker)
        return _maybe_commit(view, step)
    if kind == "noop_fill":
        _, worker, step = op
        view.step_noops.setdefault(step, set()).add(worker)
        return _maybe_commit(view, step)
    if kind == "ckpt_commit":
        _, step = op
        view.committed_ckpt = step
        return ("ckpt", step)
    raise ValueError(f"unknown control op {op!r}")


def _maybe_commit(view: ClusterView, step: int):
    done = view.step_reports.get(step, set()) | view.step_noops.get(step, set())
    if set(view.workers) <= done and view.workers:
        if step == view.committed_step + 1:
            view.committed_step = step
            # roll forward through any already-complete successors
            nxt = step + 1
            while (set(view.workers)
                   <= (view.step_reports.get(nxt, set())
                       | view.step_noops.get(nxt, set()))):
                view.committed_step = nxt
                nxt += 1
        n_noop = len(view.step_noops.get(step, set()))
        scale = 1.0 - n_noop / max(len(view.workers), 1)
        return ("committed", view.committed_step, scale)
    return ("pending", view.committed_step, None)


class ControlStateMachine(StateMachine):
    """Adapter: the repro.core replica state-machine interface."""

    def __init__(self) -> None:
        self.view = ClusterView()

    def apply(self, op: Tuple) -> Any:
        if op and op[0] == "put_control":  # client write wrapper
            op = op[1]
        return apply_command(self.view, op)

    def is_read(self, op: Tuple) -> bool:
        return op[0] == "read_view"

    def snapshot(self) -> Any:
        return json.dumps({
            "workers": self.view.workers,
            "committed_step": self.view.committed_step,
            "generation": self.view.generation,
            "committed_ckpt": self.view.committed_ckpt,
        })

    def restore(self, snap: Any) -> None:
        d = json.loads(snap)
        self.view = ClusterView(workers=list(d["workers"]),
                                committed_step=d["committed_step"],
                                generation=d["generation"],
                                committed_ckpt=d["committed_ckpt"])


class TrainingCoordinator:
    """Drives training-control commands through a compartmentalized RSM.

    ``skip_after``: a worker whose last report is more than this many steps
    behind the frontier gets noop-filled (straggler mitigation)."""

    def __init__(self, n_workers: int, skip_after: int = 2, seed: int = 0,
                 n_proxy_leaders: int = 3, grid: Tuple[int, int] = (2, 2)):
        cfg = DeploymentConfig(f=1, n_proxy_leaders=n_proxy_leaders, grid=grid,
                               n_replicas=2, state_machine="kv", seed=seed)
        # replace the KV state machine with the control state machine
        self.rsm = CompartmentalizedMultiPaxos(cfg, n_clients=1)
        for replica in self.rsm.replicas:
            replica.sm = ControlStateMachine()
        self.client = self.rsm.clients[0]
        self.skip_after = skip_after
        self.n_workers = n_workers
        self._submitted: List[Tuple] = []
        for w in range(n_workers):
            self.submit(("join", f"worker/{w}"))

    # -- command plumbing ------------------------------------------------------
    def submit(self, op: Tuple) -> Any:
        self.client.run_ops([("put_control", op)])
        # control ops are writes through the leader; KVStore semantics are
        # bypassed - replicas run ControlStateMachine.apply on the op payload
        self.rsm.run_to_quiescence()
        return self.client.results[-1]

    @property
    def view(self) -> ClusterView:
        return self.rsm.replicas[0].sm.view  # type: ignore[attr-defined]

    # -- training-facing API -------------------------------------------------------
    def report_step(self, worker: int, step: int) -> Any:
        return self.submit(("report", f"worker/{worker}", step))

    def commit_checkpoint(self, step: int) -> Any:
        return self.submit(("ckpt_commit", step))

    def join(self, worker: str) -> Any:
        return self.submit(("join", worker))

    def leave(self, worker: str) -> Any:
        return self.submit(("leave", worker))

    def mitigate_stragglers(self, frontier_step: int,
                            last_report: Dict[str, int]) -> List[str]:
        """Noop-fill every worker lagging more than ``skip_after`` behind."""
        skipped = []
        for w in list(self.view.workers):
            behind = frontier_step - last_report.get(w, -1)
            if behind > self.skip_after:
                for s in range(last_report.get(w, -1) + 1, frontier_step + 1):
                    self.submit(("noop_fill", w, s))
                skipped.append(w)
        return skipped

    def fail_over(self) -> None:
        """Kill the RSM leader; training control continues on the backup."""
        self.rsm.fail_over(to_leader=1)
        self.rsm.run_to_quiescence()
        self.client.leader = self.rsm.leader_addrs[1]
