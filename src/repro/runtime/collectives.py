"""Distributed collectives: hierarchical gradient reduction, compressed
cross-pod exchange, and the distributed split-KV decode combine.

These are the shard_map building blocks behind the perf levers recorded in
EXPERIMENTS.md section Perf:

* ``hierarchical_allreduce`` - reduce-scatter inside the pod (cheap ICI),
  exchange only 1/|data| of the gradient across pods, all-gather back.
  Cross-pod bytes: 2/|data| of a flat all-reduce.
* int8 cross-pod compression (+ error feedback in the optimizer wrapper) -
  the S-Paxos control/data split: tiny f32 scales ride with int8 payloads.
* ``distributed_flash_decode_combine`` - merges per-shard (m, l, acc)
  partial attention over a sequence-sharded KV cache with one psum
  (log-sum-exp algebra); the multi-chip form of kernels/decode_attention.
"""
from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.optim.compression import dequantize_int8, quantize_int8
from repro.runtime.compat import shard_map


def hierarchical_allreduce(x: jnp.ndarray, *, in_pod_axis: str = "data",
                           cross_pod_axis: Optional[str] = "pod",
                           compress_cross_pod: bool = False) -> jnp.ndarray:
    """Mean-reduce ``x`` over (pod, data) inside a shard_map region.

    reduce_scatter(in-pod) -> [quantize] -> psum(cross-pod) -> [dequantize]
    -> all_gather(in-pod).  Equivalent to psum over both axes (up to int8
    rounding when compression is on), with cross-pod traffic reduced by
    |data| x (and a further 4x with int8)."""
    n_in = jax.lax.psum(1, in_pod_axis)
    shard = jax.lax.psum_scatter(x, in_pod_axis, scatter_dimension=0,
                                 tiled=True)
    if cross_pod_axis is not None:
        if compress_cross_pod:
            q, scale = quantize_int8(shard)
            q_sum = jax.lax.psum(q.astype(jnp.int32), cross_pod_axis)
            scale = jax.lax.pmax(scale, cross_pod_axis)
            shard = (q_sum.astype(jnp.float32) * scale).astype(shard.dtype)
        else:
            shard = jax.lax.psum(shard, cross_pod_axis)
    out = jax.lax.all_gather(shard, in_pod_axis, axis=0, tiled=True)
    n_cross = (jax.lax.psum(1, cross_pod_axis)
               if cross_pod_axis is not None else 1)
    return out / (n_in * n_cross)


def make_hierarchical_grad_mean(mesh: Mesh, compress_cross_pod: bool = False):
    """Returns a jit-able fn averaging a replicated-gradient pytree over all
    data axes via shard_map (for gradients produced per-DP-rank)."""
    has_pod = "pod" in mesh.axis_names

    def one(g):
        flat = g.reshape(-1)
        pad = (-flat.shape[0]) % mesh.shape["data"]
        flat = jnp.pad(flat, (0, pad))
        out = hierarchical_allreduce(
            flat, in_pod_axis="data",
            cross_pod_axis="pod" if has_pod else None,
            compress_cross_pod=compress_cross_pod)
        return out[:g.size].reshape(g.shape)

    def grad_mean(grads):
        return jax.tree.map(one, grads)

    spec = P()  # gradients replicated per rank inside the region
    return jax.jit(
        shard_map(grad_mean, mesh=mesh, in_specs=spec, out_specs=spec,
                  check_vma=False))


# ---------------------------------------------------------------------------
# distributed split-KV flash decode
# ---------------------------------------------------------------------------


def flash_decode_partial(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                         valid: jnp.ndarray
                         ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Per-shard partial attention.  q: (B, H, d); k/v: (B, S_loc, H_kv, d);
    valid: (B, S_loc) bool.  Returns (m, l, acc) with shapes
    ((B, H, 1), (B, H, 1), (B, H, d))."""
    import math
    B, H, D = q.shape
    H_kv = k.shape[2]
    group = H // H_kv
    qg = q.reshape(B, H_kv, group, D).astype(jnp.float32)
    s = jnp.einsum("bhgd,bkhd->bhgk", qg, k.astype(jnp.float32))
    s = s / math.sqrt(D)
    s = jnp.where(valid[:, None, None, :], s, -1e30)
    m = jnp.max(s, axis=-1, keepdims=True)            # (B, H_kv, g, 1)
    p = jnp.exp(s - m)
    l = jnp.sum(p, axis=-1, keepdims=True)
    acc = jnp.einsum("bhgk,bkhd->bhgd", p, v.astype(jnp.float32))
    return (m.reshape(B, H, 1), l.reshape(B, H, 1), acc.reshape(B, H, D))


def combine_partials(m, l, acc, axis: str) -> jnp.ndarray:
    """Merge per-shard softmax partials over a mesh axis with psums."""
    m_glob = jax.lax.pmax(m, axis)
    corr = jnp.exp(m - m_glob)
    l_glob = jax.lax.psum(l * corr, axis)
    acc_glob = jax.lax.psum(acc * corr, axis)
    return acc_glob / jnp.maximum(l_glob, 1e-30)


def make_distributed_flash_decode(mesh: Mesh, seq_axis: str = "model",
                                  batch_axes=("data",)):
    """Decode attention over a sequence-sharded KV cache.

    q is replicated over the sequence axis; each shard computes its partial
    and one (m,l,acc) psum of size O(B*H*d) merges them - instead of
    all-gathering an O(B*S*H_kv*d) cache."""

    def fn(q, k_cache, v_cache, cache_len):
        # local positions owned by this shard
        idx = jax.lax.axis_index(seq_axis)
        s_loc = k_cache.shape[1]
        start = idx * s_loc
        pos = start + jnp.arange(s_loc)[None, :]
        valid = pos < cache_len[:, None]
        m, l, acc = flash_decode_partial(q, k_cache, v_cache, valid)
        return combine_partials(m, l, acc, seq_axis)

    b = batch_axes
    return shard_map(
        fn, mesh=mesh,
        in_specs=(P(b, None, None), P(b, seq_axis, None, None),
                  P(b, seq_axis, None, None), P(b)),
        out_specs=P(b, None, None),
        check_vma=False)
