"""End-to-end training loop: data pipeline -> jitted step -> coordinator ->
grid checkpoints, with failure recovery and elastic rescaling.

This is the CPU-scale integration of every subsystem (exercised in
tests/test_train_loop.py and examples/elastic_train.py); the same loop body
is what launch/train.py runs on a real mesh.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint.store import GridCheckpointStore
from repro.configs.base import ModelConfig
from repro.data.pipeline import DataConfig, SyntheticLM
from repro.models import init_params
from repro.optim.adamw import AdamWConfig, init_opt_state
from repro.runtime.coordinator import TrainingCoordinator
from repro.runtime.steps import make_train_step


@dataclass
class TrainState:
    params: Any
    opt_state: Any
    step: int = 0


class Trainer:
    """Single-host trainer with RSM coordination + grid checkpoints.

    ``n_virtual_workers`` simulates the DP group for the coordinator
    (per-worker step reports; straggler noop-fill)."""

    def __init__(self, cfg: ModelConfig, ckpt_dir: str,
                 opt_cfg: Optional[AdamWConfig] = None,
                 data_cfg: Optional[DataConfig] = None,
                 n_virtual_workers: int = 4, seed: int = 0,
                 ckpt_every: int = 5) -> None:
        self.cfg = cfg
        self.opt_cfg = opt_cfg or AdamWConfig(warmup_steps=5, total_steps=200)
        self.data_cfg = data_cfg or DataConfig(
            vocab_size=cfg.vocab_size, seq_len=32, global_batch=8, seed=seed)
        self.data = SyntheticLM(self.data_cfg)
        self.ckpt = GridCheckpointStore(ckpt_dir, rows=2, cols=2)
        self.coord = TrainingCoordinator(n_workers=n_virtual_workers, seed=seed)
        self.n_workers = n_virtual_workers
        self.ckpt_every = ckpt_every

        params = init_params(cfg, jax.random.key(seed))
        self.state = TrainState(params=params,
                                opt_state=init_opt_state(params))
        self._step_fn = jax.jit(make_train_step(cfg, self.opt_cfg))
        self.metrics_log: List[Dict[str, float]] = []

    # -- steps ---------------------------------------------------------------
    def run_step(self, straggler: Optional[int] = None) -> Dict[str, float]:
        step = self.state.step
        batch = self.data.global_batch(step)
        batch = {k: jnp.asarray(v) for k, v in batch.items()}
        params, opt_state, metrics = self._step_fn(
            self.state.params, self.state.opt_state, batch)
        self.state = TrainState(params=params, opt_state=opt_state,
                                step=step + 1)
        # per-worker completion reports through the RSM; a straggler's
        # report is withheld and (if lagging) noop-filled
        last_report = {}
        for w in range(self.n_workers):
            if w == straggler:
                last_report[f"worker/{w}"] = step - self.coord.skip_after - 1
                continue
            self.coord.report_step(w, step)
            last_report[f"worker/{w}"] = step
        if straggler is not None:
            self.coord.mitigate_stragglers(step, last_report)
        m = {k: float(v) for k, v in metrics.items()}
        m["step"] = step
        self.metrics_log.append(m)
        if (step + 1) % self.ckpt_every == 0:
            self.checkpoint()
        return m

    def run(self, n_steps: int) -> List[Dict[str, float]]:
        return [self.run_step() for _ in range(n_steps)]

    # -- checkpoint / restore ----------------------------------------------------
    def checkpoint(self) -> None:
        tree = {"params": self.state.params, "opt": self.state.opt_state,
                "step": jnp.asarray(self.state.step)}
        self.ckpt.save(self.state.step, tree)
        self.coord.commit_checkpoint(self.state.step)

    def restore_latest(self) -> int:
        step = self.coord.view.committed_ckpt
        if step is None:
            raise RuntimeError("no committed checkpoint")
        like = {"params": self.state.params, "opt": self.state.opt_state,
                "step": jnp.asarray(self.state.step)}
        tree = self.ckpt.restore(step, like)
        self.state = TrainState(params=tree["params"], opt_state=tree["opt"],
                                step=int(tree["step"]))
        return self.state.step

    # -- failure / elasticity ---------------------------------------------------
    def crash_and_recover(self) -> int:
        """Simulate losing the training job: rebuild from the last
        *committed* checkpoint (the RSM knows which one that is)."""
        params = init_params(self.cfg, jax.random.key(999))  # garbage state
        self.state = TrainState(params=params,
                                opt_state=init_opt_state(params))
        return self.restore_latest()

    def scale_workers(self, new_n: int) -> None:
        """Elastic rescale: membership changes through the log; the
        deterministic data pipeline needs no state handoff."""
        for w in range(self.n_workers, new_n):
            self.coord.join(f"worker/{w}")
        for w in range(new_n, self.n_workers):
            self.coord.leave(f"worker/{w}")
        self.n_workers = new_n
