"""Trace-time mesh context for shard_map layers inside pjit'd model code.

The model zoo is mesh-agnostic jnp; the one exception is the explicit
all-to-all MoE layer (``moe_impl="a2a"``), whose shard_map needs the Mesh
object at trace time.  The launcher/dry-run sets it around ``.lower()``.
"""
from __future__ import annotations

import contextlib
from typing import Optional

from jax.sharding import Mesh

_CURRENT: Optional[Mesh] = None


def current_mesh() -> Mesh:
    if _CURRENT is None:
        raise RuntimeError(
            "moe_impl='a2a' needs a mesh: wrap lowering in "
            "repro.runtime.mesh_context.use_mesh(mesh)")
    return _CURRENT


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    global _CURRENT
    prev = _CURRENT
    _CURRENT = mesh
    try:
        yield mesh
    finally:
        _CURRENT = prev
