"""Sharding rules: parameter / batch / cache PartitionSpecs per architecture.

Baseline policy (v1 - the recorded roofline baseline; §Perf iterates on it):

  * vocab & unembed         -> "model" (sharded logits + sharded logsumexp CE)
  * attention q/o           -> "model" over heads, only when n_heads % |model|
                               == 0 (reshape-safe propagation); else replicate
  * attention k/v           -> "model" only when n_kv_heads % |model| == 0
                               (GQA with few KV heads replicates K/V - the
                               MaxText convention)
  * mlp / experts           -> "model" (column-, then row-parallel; experts
                               sharded on the expert axis = EP)
  * rglru channel axis      -> "model" (gates, conv, state all channel-local)
  * rwkv6 projections       -> "model" (64 heads divide 16)
  * batch                   -> ("pod", "data")
  * decode KV cache         -> batch over data axes, sequence over "model"
                               (distributed split-KV decode)
  * optimizer moments       -> same as params, or ZeRO-1 (first divisible dim
                               over "data") when enabled

Specs are assigned by tree-path pattern over the params pytree, so they stay
correct for every architecture's parameter structure automatically.

Perf levers beyond the baseline (each an EXPERIMENTS.md §Perf iteration):
  zero1                  - ZeRO-1: f32 moments sharded over "data"
  shard_qkv_by_flat_dim  - shard q/k/v on the flat head*dim axis
  dp_only                - pure DP: params replicated, batch over every axis
  fsdp                   - params sharded over "model", gathered per use
  seq_dp                 - context parallelism: sequence over the "pod" axis
  cache_dtype (config)   - int8 KV cache for decode bandwidth
"""
from __future__ import annotations

import re
from functools import partial
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig


def _path_str(path) -> str:
    parts = []
    for p in path:
        if hasattr(p, "key"):
            parts.append(str(p.key))
        elif hasattr(p, "idx"):
            parts.append(str(p.idx))
        else:
            parts.append(str(p))
    return "/".join(parts)


def _divisible(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


class ShardingPolicy:
    """Computes PartitionSpecs for params/batches/caches on a given mesh."""

    def __init__(self, cfg: ModelConfig, mesh: Mesh,
                 zero1: bool = False,
                 shard_qkv_by_flat_dim: bool = False,
                 seq_shard_cache: bool = True,
                 dp_only: bool = False,
                 fsdp: bool = False,
                 seq_dp: bool = False):
        self.cfg = cfg
        self.mesh = mesh
        self.model_size = mesh.shape["model"]
        self.dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
        self.zero1 = zero1
        # perf-iteration lever: shard q/k/v on the flattened head*dim axis
        # even when head counts don't divide the model axis
        self.shard_qkv_by_flat_dim = shard_qkv_by_flat_dim
        self.seq_shard_cache = seq_shard_cache
        # perf-iteration lever: pure data parallelism - replicate all params,
        # spread the batch over (pod, data, model); pair with zero1 so the
        # f32 moments fit (small/medium models where TP activation
        # all-reduces dominate the roofline)
        self.dp_only = dp_only
        # perf-iteration lever: FSDP over the model axis - params sharded on
        # their first divisible dim, gathered per-layer at use (param bytes
        # << activation bytes for big-d models); batch over all axes
        self.fsdp = fsdp
        if dp_only or fsdp:
            self.dp_axes = self.dp_axes + ("model",)
        # perf-iteration lever: context parallelism - when the batch dim
        # cannot use every dp axis (global_batch < |dp|), shard the sequence
        # dim over the leftover "pod" axis; causal attention all-gathers the
        # (small, GQA) K/V per layer
        self.seq_dp = seq_dp

    # -- parameter specs -----------------------------------------------------
    def param_spec(self, path: str, shape: Tuple[int, ...]) -> P:
        cfg, M = self.cfg, self.model_size
        if self.dp_only:
            # MoE experts stay expert-parallel over "model" even under the
            # otherwise-pure-DP layout (EP+DP: dispatch all-to-alls replace
            # activation all-reduces; replicating 128 experts would not fit)
            if re.search(r"moe/experts/", path) and _divisible(shape[0], M):
                return P(*(["model"] + [None] * (len(shape) - 1)))
            return P(*([None] * len(shape)))
        if self.fsdp:
            for i, dim in enumerate(shape):
                if _divisible(dim, M):
                    spec = [None] * len(shape)
                    spec[i] = "model"
                    return P(*spec)
            return P(*([None] * len(shape)))
        heads_ok = _divisible(cfg.n_heads, M)
        kv_ok = _divisible(cfg.n_kv_heads, M)
        q_out = cfg.n_heads * cfg.head_dim
        kv_out = cfg.n_kv_heads * cfg.head_dim

        def last_dim_model_if(cond):
            if cond and _divisible(shape[-1], M):
                return P(*([None] * (len(shape) - 1) + ["model"]))
            return P(*([None] * len(shape)))

        # embeddings
        if re.search(r"embed/tokens$", path):
            return P("model", None) if _divisible(shape[0], M) else P(None, None)
        if re.search(r"embed/unembed$", path):
            return last_dim_model_if(_divisible(shape[-1], M))

        # attention
        if re.search(r"(attn|xattn)/w_q$", path):
            return last_dim_model_if(heads_ok or self.shard_qkv_by_flat_dim)
        if re.search(r"(attn|xattn)/w_[kv]$", path):
            return last_dim_model_if(kv_ok or self.shard_qkv_by_flat_dim)
        if re.search(r"(attn|xattn)/b_q$", path):
            return (P("model") if (heads_ok or self.shard_qkv_by_flat_dim)
                    and _divisible(shape[-1], M) else P(None))
        if re.search(r"(attn|xattn)/b_[kv]$", path):
            return (P("model") if (kv_ok or self.shard_qkv_by_flat_dim)
                    and _divisible(shape[-1], M) else P(None))
        if re.search(r"(attn|xattn)/w_o$", path):
            if (heads_ok or self.shard_qkv_by_flat_dim) and _divisible(shape[0], M):
                return P("model", None)
            return P(None, None)

        # MoE
        if re.search(r"moe/router$", path):
            return P(None, None)
        if re.search(r"moe/experts/", path):
            # leaves are stacked (E, d_in, d_out): expert parallelism
            if _divisible(shape[0], M):
                return P(*(["model"] + [None] * (len(shape) - 1)))
            return P(*([None] * len(shape)))
        if re.search(r"moe/shared/w_(gate|up)$", path):
            return last_dim_model_if(True)
        if re.search(r"moe/shared/w_down$", path):
            return (P("model", None) if _divisible(shape[0], M)
                    else P(None, None))

        # dense MLP
        if re.search(r"mlp/w_(gate|up)$", path):
            return last_dim_model_if(True)
        if re.search(r"mlp/w_down$", path):
            return (P("model", None) if _divisible(shape[0], M)
                    else P(None, None))

        # RG-LRU: channel axis (last dim of in-projs, both dims of gates)
        if re.search(r"rec/w_in_(rnn|gate)$", path):
            return last_dim_model_if(True)
        if re.search(r"rec/conv_[wb]$", path):
            return last_dim_model_if(True)
        if re.search(r"rec/w_[ax]$", path):
            # (r, r): column-parallel; contraction insertion handled by XLA
            return last_dim_model_if(True)
        if re.search(r"rec/b_[ax]$", path) or re.search(r"rec/lambda$", path):
            return P("model") if _divisible(shape[-1], M) else P(None)
        if re.search(r"rec/w_out$", path):
            return (P("model", None) if _divisible(shape[0], M)
                    else P(None, None))

        # RWKV6 time-mix / channel-mix
        if re.search(r"tm/w_[rkvg]$", path):
            return last_dim_model_if(_divisible(cfg.n_heads, M))
        if re.search(r"tm/w_o$", path):
            return (P("model", None)
                    if _divisible(cfg.n_heads, M) and _divisible(shape[0], M)
                    else P(None, None))
        if re.search(r"tm/u$", path):
            return (P("model", None) if _divisible(shape[0], M)
                    else P(None, None))
        if re.search(r"tm/ln_x_(scale|bias)$", path):
            return P("model") if _divisible(cfg.n_heads, M) else P(None)
        if re.search(r"cm/w_k$", path):
            return last_dim_model_if(True)
        if re.search(r"cm/w_v$", path):
            return (P("model", None) if _divisible(shape[0], M)
                    else P(None, None))

        # norms, small loras, mus, biases: replicated
        return P(*([None] * len(shape)))

    def params_shardings(self, params_shape) -> Any:
        """NamedSharding pytree matching a params shape pytree.

        Segment params carry a leading stacked (repeats,) scan axis: the
        per-layer spec is computed on the unstacked shape and shifted."""

        def assign(path, leaf):
            p = _path_str(path)
            if p.startswith("segments") or p.startswith("enc_segments"):
                spec = P(*((None,) + tuple(self.param_spec(p, leaf.shape[1:]))))
            else:
                spec = self.param_spec(p, leaf.shape)
            return NamedSharding(self.mesh, spec)

        return jax.tree_util.tree_map_with_path(assign, params_shape)

    def opt_state_shardings(self, params_shape) -> Any:
        p_sh = self.params_shardings(params_shape)
        if not self.zero1:
            m = p_sh
        else:
            m = jax.tree.map(self._zero1_of, p_sh, params_shape)
        return {"m": m, "v": m,
                "step": NamedSharding(self.mesh, P())}

    def _zero1_of(self, sharding: NamedSharding, leaf) -> NamedSharding:
        """ZeRO-1: additionally shard the first *divisible* unsharded dim of
        the f32 moments over "data" (falls back to the param sharding)."""
        n_data = self.mesh.shape["data"]
        spec = list(sharding.spec)
        # pad spec to rank (PartitionSpec may be shorter than ndim)
        spec = spec + [None] * (len(leaf.shape) - len(spec))
        for i, s in enumerate(spec):
            if s is None and _divisible(leaf.shape[i], n_data):
                spec[i] = "data"
                return NamedSharding(self.mesh, P(*spec))
        return sharding

    # -- data / activation specs ----------------------------------------------
    def dp_for(self, n: int):
        """Largest data-parallel axis subset that evenly divides ``n``.

        Tries subsets of the dp axes largest-first: e.g. global batch 256 on
        the (pod=2, data=16, model=16) mesh with dp_only lands on
        ("data", "model") = 256-way DP with the pod axis left for the
        gradient all-reduce."""
        from itertools import combinations
        axes = self.dp_axes
        candidates = []
        for r in range(len(axes), 0, -1):
            for combo in combinations(axes, r):
                size = 1
                for a in combo:
                    size *= self.mesh.shape[a]
                candidates.append((size, combo))
        candidates.sort(key=lambda t: -t[0])
        for size, combo in candidates:
            if _divisible(n, size):
                return combo
        return None

    def batch_spec(self) -> P:
        return P(self.dp_axes)  # batch dim over (pod, data)

    def batch_shardings(self, batch_shape) -> Any:
        def assign(path, leaf):
            b_axes = self.dp_for(leaf.shape[0])
            spec = [b_axes] + [None] * (len(leaf.shape) - 1)
            if (self.seq_dp and leaf.ndim >= 2
                    and "pod" in self.mesh.axis_names
                    and "pod" not in (b_axes or ())
                    and _divisible(leaf.shape[1], self.mesh.shape["pod"])):
                spec[1] = "pod"
            return NamedSharding(self.mesh, P(*spec))
        return jax.tree_util.tree_map_with_path(assign, batch_shape)

    def activation_spec(self) -> P:
        return P(self.dp_axes, None, None)

    # -- cache specs -------------------------------------------------------------
    def cache_shardings(self, cache_shape) -> Any:
        """Decode caches: (repeats, B, S, H_kv, d) -> batch over data axes,
        sequence over "model" (distributed split-KV); recurrent states:
        batch over data axes, channels over "model" when divisible."""
        M = self.model_size

        def assign(path, leaf):
            p = _path_str(path)
            shape = leaf.shape
            if re.search(r"(?:^|/)(k|v|cross_k|cross_v)$", p) and len(shape) == 5:
                seq_ok = self.seq_shard_cache and _divisible(shape[2], M)
                return NamedSharding(
                    self.mesh,
                    P(None, self.dp_for(shape[1]), "model" if seq_ok else None,
                      None, None))
            if re.search(r"(?:^|/)pos$", p):
                return NamedSharding(self.mesh, P(*([None] * len(shape))))
            if re.search(r"(?:^|/)wkv$", p) and len(shape) == 5:
                # (repeats, B, H, K, V): heads over model
                h_ok = _divisible(shape[2], M)
                return NamedSharding(
                    self.mesh,
                    P(None, self.dp_for(shape[1]), "model" if h_ok else None,
                      None, None))
            if re.search(r"(?:^|/)(h|conv)$", p):
                # rglru state: channel axis (last) over model
                ch_ok = _divisible(shape[-1], M)
                spec = ([None, self.dp_for(shape[1])]
                        + [None] * (len(shape) - 3)
                        + (["model"] if ch_ok else [None]))
                return NamedSharding(self.mesh, P(*spec))
            if re.search(r"(?:^|/)shift$", p):
                return NamedSharding(self.mesh,
                                     P(None, self.dp_for(shape[1]), None))
            if len(shape) >= 2:
                spec = [None, self.dp_for(shape[1])] + [None] * (len(shape) - 2)
                return NamedSharding(self.mesh, P(*spec))
            return NamedSharding(self.mesh, P(*([None] * len(shape))))

        return jax.tree_util.tree_map_with_path(assign, cache_shape)

    def logits_spec(self) -> P:
        M = self.model_size
        v_ok = _divisible(self.cfg.vocab_size, M)
        return P(self.dp_axes, None, "model" if v_ok else None)
