"""Continuous-batching scheduler for the decode loop.

A model replica executes decode steps over a fixed number of batch *slots*;
sequences are admitted into free slots as requests arrive and evicted when
they emit EOS or hit their token budget (Orca-style iteration-level
scheduling [OSDI'22], the standard LLM-serving discipline).  The batcher
role of compartmentalization 5 feeds this queue; slots decouple batch
*occupancy* from request boundaries.

This module is pure slot bookkeeping + a jitted padded decode step; it is
exercised end-to-end in tests/test_serving.py with a real (smoke) model.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import decode_step, init_cache, prefill


@dataclass
class Request:
    rid: int
    prompt: List[int]
    max_new: int
    out: List[int] = field(default_factory=list)
    done: bool = False


class ContinuousBatcher:
    """Fixed-slot continuous batching over a single model replica."""

    def __init__(self, cfg: ModelConfig, params, n_slots: int = 4,
                 max_len: int = 128, eos_id: Optional[int] = None) -> None:
        self.cfg = cfg
        self.params = params
        self.n_slots = n_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.queue: List[Request] = []
        self.slots: List[Optional[Request]] = [None] * n_slots
        self.caches = init_cache(cfg, n_slots, max_len)
        self.tokens = jnp.zeros((n_slots, 1), jnp.int32)
        self.steps_executed = 0
        self.occupancy_sum = 0
        self._decode = jax.jit(
            lambda c, t: decode_step(cfg, self.params, c, t))

    # -- admission -------------------------------------------------------------
    def submit(self, req: Request) -> None:
        # slot caches share one absolute write position per layer, so all
        # prompts must be admitted at a common length (left-pad upstream in
        # the batcher; real fleets do the same for slot alignment)
        if any(s is not None for s in self.slots) or self.queue:
            ref = (self.queue[0].prompt if self.queue
                   else next(s for s in self.slots if s is not None).prompt)
            assert len(req.prompt) == len(ref), "pad prompts to equal length"
        self.queue.append(req)

    def _admit(self) -> None:
        for i in range(self.n_slots):
            if self.slots[i] is None and self.queue:
                req = self.queue.pop(0)
                self.slots[i] = req
                # per-slot prefill: run the prompt through a fresh cache and
                # splice that slot's state into the batch cache
                toks = jnp.asarray(req.prompt, jnp.int32)[None]
                _, cache1 = prefill(self.cfg, self.params, toks,
                                    cache_len=self.max_len)
                self.caches = _splice_slot(self.caches, cache1, i)
                self.tokens = self.tokens.at[i, 0].set(req.prompt[-1])

    # -- decode loop -----------------------------------------------------------
    def step(self) -> None:
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s is not None]
        if not active:
            return
        logits, self.caches = self._decode(self.caches, self.tokens)
        next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        self.tokens = next_tok[:, None]
        self.steps_executed += 1
        self.occupancy_sum += len(active)
        for i in active:
            req = self.slots[i]
            tok = int(next_tok[i])
            req.out.append(tok)
            if len(req.out) >= req.max_new or tok == self.eos_id:
                req.done = True
                self.slots[i] = None

    def run_until_drained(self, max_steps: int = 10_000) -> None:
        steps = 0
        while (self.queue or any(self.slots)) and steps < max_steps:
            self.step()
            steps += 1

    @property
    def mean_occupancy(self) -> float:
        return self.occupancy_sum / max(self.steps_executed, 1)


def _splice_slot(batch_cache, single_cache, slot: int):
    """Copy a 1-sequence cache into batch position ``slot``.

    Batch is axis 1 of every leaf ((repeats, B, ...)); scalar-per-layer
    leaves like "pos" (repeats,) are taken from the incoming cache (all
    slots share absolute positions up to max_len semantics: per-slot "pos"
    is folded into validity via cache_len masks at attention time)."""

    batch_size = jax.tree.leaves(batch_cache)[0].shape[1]

    def splice(b, s):
        if b.ndim >= 2 and s.ndim >= 2 and b.shape[1] == batch_size \
                and s.shape[1] == 1:
            return b.at[:, slot:slot + 1].set(s.astype(b.dtype))
        return jnp.maximum(b, s.astype(b.dtype))

    return jax.tree.map(splice, batch_cache, single_cache)
