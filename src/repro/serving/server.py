"""Compartmentalized model serving: the paper's read/write decoupling with
*inference as the read operation*.

Mapping (paper section 3.4 / 4):
  * the replicated log orders **weight updates** (writes) - e.g. a trainer
    pushing fresh checkpoints into the serving fleet;
  * an **inference request is a leaderless read**: the client prereads a
    vote watermark from an acceptor row, then any single model replica that
    has applied the log up to that watermark runs the forward pass;
  * batchers group requests (one preread per read batch), unbatchers fan
    results back out - compartmentalizations 5/6 are literally the
    continuous-batching front-end of an LLM server.

Consistency menu: "linearizable" (read the newest committed weights),
"sequential" (monotone versions per client), "eventual" (any replica, its
current weights) - paper section 3.6, with the same trade-offs.

Weight payloads move via a side store keyed by id (the S-Paxos data path);
the log carries only ("update", version, ref).
"""
from __future__ import annotations

from typing import Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.core.protocols import CompartmentalizedMultiPaxos, DeploymentConfig
from repro.core.statemachine import StateMachine
from repro.models import decode_step, init_params, prefill


class ParamStore:
    """Content-addressed weight payload store (data path)."""

    def __init__(self) -> None:
        self._store: Dict[int, Any] = {}
        self._next = 0

    def put(self, params) -> int:
        ref = self._next
        self._next += 1
        self._store[ref] = params
        return ref

    def get(self, ref: int):
        return self._store[ref]


class ModelServingSM(StateMachine):
    """State machine executed by every serving replica.

    Writes: ("update", version, ref) - install new weights.
    Reads:  ("infer", prompt_tokens, max_new) - greedy decode.
    """

    def __init__(self, cfg: ModelConfig, store: ParamStore) -> None:
        self.cfg = cfg
        self.store = store
        self.params = None
        self.version = -1
        self.inferences = 0

    def apply(self, op: Tuple) -> Any:
        kind = op[0]
        if kind == "update":
            _, version, ref = op
            if version > self.version:
                self.params = self.store.get(ref)
                self.version = version
            return ("installed", self.version)
        if kind == "infer":
            _, prompt, max_new = op
            assert self.params is not None, "no weights installed"
            self.inferences += 1
            tokens = jnp.asarray(prompt, jnp.int32)[None, :]
            _, caches = prefill(self.cfg, self.params, tokens,
                                cache_len=tokens.shape[1] + max_new)
            tok = tokens[:, -1:]
            out: List[int] = []
            for _ in range(max_new):
                logits, caches = decode_step(self.cfg, self.params, caches, tok)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)[:, None]
                out.append(int(tok[0, 0]))
            return ("v%d" % self.version, tuple(out))
        raise ValueError(f"unknown op {op!r}")

    def is_read(self, op: Tuple) -> bool:
        return op[0] == "infer"

    def snapshot(self) -> Any:
        return (self.version,)

    def restore(self, snap: Any) -> None:
        self.version = snap[0]


class ServingDeployment:
    """Compartmentalized serving fleet over the in-process cluster."""

    def __init__(self, cfg: ModelConfig, n_replicas: int = 3,
                 n_proxy_leaders: int = 3, grid: Tuple[int, int] = (2, 2),
                 n_clients: int = 2, consistency: str = "linearizable",
                 n_batchers: int = 0, n_unbatchers: int = 0,
                 seed: int = 0) -> None:
        self.cfg = cfg
        self.store = ParamStore()
        dep_cfg = DeploymentConfig(
            f=1, n_proxy_leaders=n_proxy_leaders, grid=grid,
            n_replicas=n_replicas, consistency=consistency,
            n_batchers=n_batchers, n_unbatchers=n_unbatchers,
            batch_size=4, seed=seed)
        self.rsm = CompartmentalizedMultiPaxos(dep_cfg, n_clients=n_clients)
        for replica in self.rsm.replicas:
            replica.sm = ModelServingSM(cfg, self.store)
        self.clients = self.rsm.clients
        self.version = 0

    # -- control plane ---------------------------------------------------------
    def push_weights(self, params, client: int = 0) -> int:
        """Trainer-side weight update (a write through the log)."""
        self.version += 1
        ref = self.store.put(params)
        self.clients[client].run_ops([("update", self.version, ref)])
        self.rsm.run_to_quiescence()
        return self.version

    # -- request plane ---------------------------------------------------------
    def infer(self, prompt: List[int], max_new: int = 4, client: int = 0
              ) -> Tuple[str, Tuple[int, ...]]:
        """Issue one inference request as a (leaderless) read."""
        self.clients[client].run_ops([("infer", tuple(prompt), max_new)])
        self.rsm.run_to_quiescence()
        return self.clients[client].results[-1]

    def submit_many(self, prompts: List[List[int]], max_new: int = 4) -> None:
        """Round-robin closed-loop submission across clients."""
        for i, p in enumerate(prompts):
            c = self.clients[i % len(self.clients)]
            c.run_ops([("infer", tuple(p), max_new)])
        self.rsm.run_to_quiescence()

    def replica_loads(self) -> List[int]:
        return [r.sm.inferences for r in self.rsm.replicas]  # type: ignore
