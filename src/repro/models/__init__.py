"""Pure-JAX model zoo for the assigned architectures."""
from .model import (
    build_segments,
    decode_step,
    forward,
    init_cache,
    init_params,
    loss_fn,
    prefill,
)

__all__ = ["build_segments", "decode_step", "forward", "init_cache",
           "init_params", "loss_fn", "prefill"]
