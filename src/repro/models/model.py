"""Model assembly: configs -> params -> train / prefill / decode fns.

Layers are grouped into **segments**: maximal runs of a repeating layer
signature, each executed as one ``lax.scan`` over stacked parameters (with
optional remat).  This keeps compiled HLO size O(pattern) instead of
O(n_layers) - an 80-layer model compiles one scanned body - which is what
makes the 40-cell dry-run tractable and the roofline honest (no unrolled
duplication).

Heterogeneous stacks are handled by the segment splitter:
  * uniform decoders (most archs)          -> 1 segment
  * deepseek-moe (dense layer 0, MoE rest) -> [1-layer segment, 27-layer scan]
  * recurrentgemma (rglru,rglru,attn)x8+2  -> [3-layer-pattern scan x8, 2-layer scan]
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from functools import partial
from typing import TYPE_CHECKING, Any, Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp

if TYPE_CHECKING:  # avoid circular import (configs.base imports models.moe)
    from repro.configs.base import ModelConfig

from . import attention as attn_lib
from . import moe as moe_lib
from . import rglru as rglru_lib
from . import rwkv6 as rwkv_lib
from .layers import (
    embed_tokens,
    init_embedding,
    init_layernorm,
    init_mlp,
    init_rmsnorm,
    layernorm,
    rmsnorm,
    softmax_cross_entropy,
    text_mrope_positions,
    unembed,
)

LayerSig = Tuple[str, str]  # (mixer, channel): ("attn", "mlp"), ...


@dataclass(frozen=True)
class Segment:
    pattern: Tuple[LayerSig, ...]
    repeats: int


# ---------------------------------------------------------------------------
# segment construction
# ---------------------------------------------------------------------------


def layer_signatures(cfg: ModelConfig) -> List[LayerSig]:
    return [(t, cfg.channel_kind(i)) for i, t in enumerate(cfg.layer_types())]


def split_segments(sigs: List[LayerSig]) -> List[Segment]:
    segments: List[Segment] = []
    i = 0
    while i < len(sigs):
        rest = sigs[i:]
        q_best, reps_best = len(rest), 1
        for q in range(1, len(rest) + 1):
            reps = len(rest) // q
            if reps >= 2 and all(rest[j] == rest[j % q] for j in range(reps * q)):
                q_best, reps_best = q, reps
                break
        if reps_best == 1 and len(rest) > 1:
            # no repeating prefix: emit the leading run of identical sigs
            r = 1
            while r < len(rest) and rest[r] == rest[0]:
                r += 1
            q_best, reps_best = 1, r
        segments.append(Segment(pattern=tuple(rest[:q_best]), repeats=reps_best))
        i += q_best * reps_best
    return segments


def build_segments(cfg: ModelConfig) -> List[Segment]:
    return split_segments(layer_signatures(cfg))


# ---------------------------------------------------------------------------
# per-layer init
# ---------------------------------------------------------------------------


def _init_norm(cfg: ModelConfig, dtype) -> dict:
    return (init_rmsnorm(cfg.d_model, dtype) if cfg.norm == "rmsnorm"
            else init_layernorm(cfg.d_model, dtype))


def _norm(cfg: ModelConfig, params: dict, x):
    return rmsnorm(params, x) if cfg.norm == "rmsnorm" else layernorm(params, x)


def init_layer(cfg: ModelConfig, sig: LayerSig, key) -> dict:
    mixer, channel = sig
    dtype = cfg.dtype()
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params: Dict[str, Any] = {"ln1": _init_norm(cfg, dtype)}
    if mixer in ("attn", "local_attn", "enc_attn"):
        params["attn"] = attn_lib.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dtype,
            qkv_bias=cfg.qkv_bias)
    elif mixer == "xattn":
        params["attn"] = attn_lib.init_attention(
            k1, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dtype,
            qkv_bias=cfg.qkv_bias)
        params["ln_x"] = _init_norm(cfg, dtype)
        params["xattn"] = attn_lib.init_attention(
            k4, cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.head_dim, dtype,
            qkv_bias=cfg.qkv_bias)
    elif mixer == "rglru":
        params["rec"] = rglru_lib.init_rglru_block(
            k1, cfg.d_model, cfg.rnn_width, cfg.conv_width, dtype)
    elif mixer == "rwkv6":
        params["tm"] = rwkv_lib.init_rwkv6_time_mix(
            k1, cfg.d_model, cfg.n_heads, dtype)
    else:
        raise ValueError(f"unknown mixer {mixer!r}")

    params["ln2"] = _init_norm(cfg, dtype)
    if channel == "mlp":
        ff = cfg.d_ff_dense or cfg.d_ff
        params["mlp"] = init_mlp(k2, cfg.d_model, ff, cfg.mlp_kind, dtype)
    elif channel == "moe":
        params["moe"] = moe_lib.init_moe(k2, cfg.d_model, cfg.moe,
                                         cfg.mlp_kind, dtype)
    elif channel == "rwkv_cm":
        params["cm"] = rwkv_lib.init_rwkv6_channel_mix(
            k2, cfg.d_model, cfg.d_ff, dtype)
    else:
        raise ValueError(f"unknown channel {channel!r}")
    return params


def init_segment(cfg: ModelConfig, seg: Segment, key) -> Tuple[dict, ...]:
    """Returns a tuple (per pattern position) of stacked (repeats, ...) params."""
    out = []
    for pos, sig in enumerate(seg.pattern):
        keys = jax.random.split(jax.random.fold_in(key, pos), seg.repeats)
        stacked = jax.vmap(lambda k: init_layer(cfg, sig, k))(keys)
        out.append(stacked)
    return tuple(out)


def init_params(cfg: ModelConfig, key) -> dict:
    dtype = cfg.dtype()
    ke, kd, kenc = jax.random.split(key, 3)
    params: Dict[str, Any] = {
        "embed": init_embedding(ke, cfg.vocab_size, cfg.d_model, dtype,
                                tied=cfg.tie_embeddings),
        "final_norm": _init_norm(cfg, dtype),
    }
    segs = build_segments(cfg)
    params["segments"] = [init_segment(cfg, s, jax.random.fold_in(kd, i))
                          for i, s in enumerate(segs)]
    if cfg.is_encoder_decoder:
        enc_sigs = [("enc_attn", "mlp")] * cfg.n_encoder_layers
        enc_segs = split_segments(enc_sigs)
        params["enc_segments"] = [
            init_segment(cfg, s, jax.random.fold_in(kenc, i))
            for i, s in enumerate(enc_segs)]
        params["enc_final_norm"] = _init_norm(cfg, dtype)
    return params


# ---------------------------------------------------------------------------
# sinusoidal positions (whisper-style absolute)
# ---------------------------------------------------------------------------


def sinusoid_positions(seq: int, dim: int, offset=0) -> jnp.ndarray:
    pos = jnp.arange(seq, dtype=jnp.float32) + offset
    inv = jnp.exp(-jnp.arange(0, dim, 2, dtype=jnp.float32)
                  * (math.log(10_000.0) / dim))
    ang = pos[:, None] * inv[None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)[None]


# ---------------------------------------------------------------------------
# block application (full-sequence mode: train / prefill)
# ---------------------------------------------------------------------------


def apply_block_seq(cfg: ModelConfig, sig: LayerSig, params: dict,
                    x: jnp.ndarray, positions, ctx: Optional[jnp.ndarray],
                    collect_cache: bool, cache_len: Optional[int] = None
                    ) -> Tuple[jnp.ndarray, Any, jnp.ndarray]:
    """Full-sequence block.  Returns (x, cache_entry|None, aux_loss)."""
    mixer, channel = sig
    aux = jnp.zeros((), jnp.float32)
    h = _norm(cfg, params["ln1"], x)
    cache_entry = None
    akw = dict(n_heads=cfg.n_heads, n_kv_heads=cfg.n_kv_heads,
               d_head=cfg.head_dim, rope_mode=cfg.rope_mode,
               rope_theta=cfg.rope_theta, mrope_sections=cfg.mrope_sections,
               q_block=cfg.q_block)

    if mixer in ("attn", "local_attn", "enc_attn", "xattn"):
        window = cfg.attn_window if mixer == "local_attn" else None
        causal = mixer != "enc_attn"
        B, S, _ = x.shape
        q, k, v = attn_lib.qkv_project(params["attn"], h, cfg.n_heads,
                                       cfg.n_kv_heads, cfg.head_dim)
        q, k = attn_lib._rope_qk(q, k, positions, cfg.rope_mode,
                                 cfg.rope_theta, cfg.mrope_sections)
        out = attn_lib.chunked_attention(q, k, v, causal=causal, window=window,
                                         q_block=cfg.q_block,
                                         unroll=cfg.unroll)
        out = out.reshape(B, S, cfg.n_heads * cfg.head_dim) @ params["attn"]["w_o"]
        if collect_cache:
            if window is not None:
                # ring buffer: global position p lives at slot p % window
                w = window
                if S >= w:
                    kw = jnp.roll(k[:, -w:], S % w, axis=1)
                    vw = jnp.roll(v[:, -w:], S % w, axis=1)
                else:
                    kw = jnp.pad(k, ((0, 0), (0, w - S), (0, 0), (0, 0)))
                    vw = jnp.pad(v, ((0, 0), (0, w - S), (0, 0), (0, 0)))
                cache_entry = {"k": kw, "v": vw,
                               "pos": jnp.asarray(S, jnp.int32)}
            else:
                cl = max(cache_len or S, S)
                kp = jnp.pad(k, ((0, 0), (0, cl - S), (0, 0), (0, 0)))
                vp = jnp.pad(v, ((0, 0), (0, cl - S), (0, 0), (0, 0)))
                cache_entry = {"k": kp, "v": vp, "pos": jnp.asarray(S, jnp.int32)}
        x = x + out
        if mixer == "xattn":
            hx = _norm(cfg, params["ln_x"], x)
            qx, kx, vx = attn_lib.qkv_project(params["xattn"], hx, cfg.n_heads,
                                              cfg.n_kv_heads, cfg.head_dim)
            # cross-attn keys/values come from the encoder output
            Bc, Sc, _ = ctx.shape
            _, kc, vc = attn_lib.qkv_project(params["xattn"], ctx, cfg.n_heads,
                                             cfg.n_kv_heads, cfg.head_dim)
            outx = attn_lib.chunked_attention(qx, kc, vc, causal=False,
                                              q_block=cfg.q_block,
                                              unroll=cfg.unroll)
            outx = outx.reshape(B, S, cfg.n_heads * cfg.head_dim) \
                @ params["xattn"]["w_o"]
            if collect_cache:
                cache_entry = {"self": cache_entry, "cross_k": kc, "cross_v": vc}
            x = x + outx
    elif mixer == "rglru":
        out, state = rglru_lib.apply_rglru_block(params["rec"], h)
        if collect_cache:
            cache_entry = state
        x = x + out
    elif mixer == "rwkv6":
        out, state = rwkv_lib.apply_time_mix(params["tm"], h, cfg.n_heads,
                                             unroll=cfg.unroll)
        if collect_cache:
            cache_entry = state
        x = x + out
    else:
        raise ValueError(mixer)

    h2 = _norm(cfg, params["ln2"], x)
    if channel == "mlp":
        from .layers import apply_mlp
        x = x + apply_mlp(params["mlp"], h2, cfg.mlp_kind)
        cm_cache = None
    elif channel == "moe":
        if cfg.moe_impl == "a2a":
            from repro.runtime.mesh_context import current_mesh
            from repro.runtime.moe_a2a import make_moe_a2a
            fn = make_moe_a2a(current_mesh(), cfg.moe, cfg.mlp_kind,
                              cfg.d_model)
            out, aux = fn(params["moe"], h2)
        else:
            out, aux = moe_lib.apply_moe(params["moe"], h2, cfg.moe,
                                         cfg.mlp_kind, impl=cfg.moe_impl)
        x = x + out
        cm_cache = None
    elif channel == "rwkv_cm":
        out, cm_state = rwkv_lib.apply_channel_mix(params["cm"], h2)
        x = x + out
        cm_cache = cm_state if collect_cache else None
    else:
        raise ValueError(channel)

    if collect_cache and sig[0] == "rwkv6":
        cache_entry = {"tm": cache_entry, "cm": cm_cache}
    return x, cache_entry, aux


def apply_segment_seq(cfg: ModelConfig, seg: Segment, seg_params, x, positions,
                      ctx=None, collect_cache: bool = False,
                      cache_len: Optional[int] = None):
    """Scan a segment over its repeats.  Returns (x, caches|None, aux_sum)."""

    def body(carry, layer_params):
        x, aux_acc = carry
        caches = []
        for pos, sig in enumerate(seg.pattern):
            x, cache_entry, aux = apply_block_seq(
                cfg, sig, layer_params[pos], x, positions, ctx, collect_cache,
                cache_len)
            caches.append(cache_entry)
        return (x, aux_acc + aux), (tuple(caches) if collect_cache else None)

    if cfg.remat:
        policy = (jax.checkpoint_policies.dots_saveable
                  if cfg.remat_policy == "dots" else None)
        body = jax.checkpoint(body, policy=policy)
    (x, aux), caches = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)),
                                    seg_params, unroll=cfg.unroll)
    return x, caches, aux


def _positions_for(cfg: ModelConfig, batch: int, seq: int, offset=0):
    if cfg.rope_mode == "mrope":
        return text_mrope_positions(batch, seq, offset)
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    return jnp.broadcast_to(pos, (batch, seq))


def encode(cfg: ModelConfig, params: dict, frames: jnp.ndarray) -> jnp.ndarray:
    """Whisper encoder over precomputed frame embeddings (conv stub)."""
    x = frames.astype(cfg.cdtype())
    x = x + sinusoid_positions(x.shape[1], cfg.d_model).astype(x.dtype)
    enc_sigs = [("enc_attn", "mlp")] * cfg.n_encoder_layers
    positions = _positions_for(cfg, x.shape[0], x.shape[1])
    for seg, seg_params in zip(split_segments(enc_sigs), params["enc_segments"]):
        x, _, _ = apply_segment_seq(cfg, seg, seg_params, x, positions)
    return _norm(cfg, params["enc_final_norm"], x)


def forward(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
            frames: Optional[jnp.ndarray] = None
            ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Full-sequence forward.  Returns (logits (B,S,V) f32, aux loss)."""
    B, S = tokens.shape
    x = embed_tokens(params["embed"], tokens).astype(cfg.cdtype())
    if cfg.rope_mode == "none" and not cfg.is_encoder_decoder:
        pass  # rwkv: no positional signal
    if cfg.is_encoder_decoder:
        x = x + sinusoid_positions(S, cfg.d_model).astype(x.dtype)
    ctx = encode(cfg, params, frames) if cfg.is_encoder_decoder else None
    positions = _positions_for(cfg, B, S)
    aux_total = jnp.zeros((), jnp.float32)
    for seg, seg_params in zip(build_segments(cfg), params["segments"]):
        x, _, aux = apply_segment_seq(cfg, seg, seg_params, x, positions, ctx)
        aux_total = aux_total + aux
    x = _norm(cfg, params["final_norm"], x)
    logits = unembed(params["embed"], x).astype(jnp.float32)
    return logits, aux_total


def loss_fn(cfg: ModelConfig, params: dict, batch: Dict[str, jnp.ndarray],
            aux_coef: float = 0.01) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, aux = forward(cfg, params, batch["tokens"],
                          frames=batch.get("frames"))
    ce = softmax_cross_entropy(logits, batch["labels"],
                               mask=batch.get("loss_mask"))
    loss = ce + aux_coef * aux
    return loss, {"loss": loss, "ce": ce, "aux": aux}


# ---------------------------------------------------------------------------
# prefill + decode
# ---------------------------------------------------------------------------


def prefill(cfg: ModelConfig, params: dict, tokens: jnp.ndarray,
            frames: Optional[jnp.ndarray] = None,
            cache_len: Optional[int] = None):
    """Forward + KV/state cache collection.  Returns (last_logits, caches).

    ``cache_len`` reserves room in the KV caches for subsequent decode
    steps (defaults to S + 128)."""
    B, S = tokens.shape
    cache_len = cache_len or (S + 128)
    x = embed_tokens(params["embed"], tokens).astype(cfg.cdtype())
    if cfg.is_encoder_decoder:
        x = x + sinusoid_positions(S, cfg.d_model).astype(x.dtype)
    ctx = encode(cfg, params, frames) if cfg.is_encoder_decoder else None
    positions = _positions_for(cfg, B, S)
    caches = []
    for seg, seg_params in zip(build_segments(cfg), params["segments"]):
        x, seg_cache, _ = apply_segment_seq(cfg, seg, seg_params, x, positions,
                                            ctx, collect_cache=True,
                                            cache_len=cache_len)
        caches.append(seg_cache)
    x = _norm(cfg, params["final_norm"], x[:, -1:])
    logits = unembed(params["embed"], x).astype(jnp.float32)
    return logits[:, 0], caches


def apply_block_decode(cfg: ModelConfig, sig: LayerSig, params: dict,
                       x: jnp.ndarray, cache: Any
                       ) -> Tuple[jnp.ndarray, Any]:
    """One-token block step.  x: (B, 1, d)."""
    mixer, channel = sig
    h = _norm(cfg, params["ln1"], x)
    if mixer in ("attn", "local_attn", "xattn"):
        window = cfg.attn_window if mixer == "local_attn" else None
        self_cache = cache["self"] if mixer == "xattn" else cache
        out, new_self = attn_lib.decode_attention_block(
            params["attn"], h, self_cache, n_heads=cfg.n_heads,
            n_kv_heads=cfg.n_kv_heads, d_head=cfg.head_dim,
            rope_mode=cfg.rope_mode, rope_theta=cfg.rope_theta,
            mrope_sections=cfg.mrope_sections, window=window)
        x = x + out
        if mixer == "xattn":
            hx = _norm(cfg, params["ln_x"], x)
            qx, _, _ = attn_lib.qkv_project(params["xattn"], hx, cfg.n_heads,
                                            cfg.n_kv_heads, cfg.head_dim)
            S_enc = cache["cross_k"].shape[1]
            outx = attn_lib.decode_attention(qx, cache["cross_k"],
                                             cache["cross_v"],
                                             jnp.asarray(S_enc, jnp.int32))
            B = x.shape[0]
            outx = outx.reshape(B, 1, cfg.n_heads * cfg.head_dim) \
                @ params["xattn"]["w_o"]
            x = x + outx
            new_cache = {"self": new_self, "cross_k": cache["cross_k"],
                         "cross_v": cache["cross_v"]}
        else:
            new_cache = new_self
    elif mixer == "rglru":
        out, new_cache = rglru_lib.apply_rglru_block(params["rec"], h,
                                                     state=cache)
        x = x + out
    elif mixer == "rwkv6":
        out, new_tm = rwkv_lib.apply_time_mix(params["tm"], h, cfg.n_heads,
                                              state=cache["tm"], impl="serial")
        x = x + out
        new_cache = {"tm": new_tm, "cm": cache["cm"]}
    else:
        raise ValueError(mixer)

    h2 = _norm(cfg, params["ln2"], x)
    if channel == "mlp":
        from .layers import apply_mlp
        x = x + apply_mlp(params["mlp"], h2, cfg.mlp_kind)
    elif channel == "moe":
        out, _ = moe_lib.apply_moe(params["moe"], h2, cfg.moe, cfg.mlp_kind,
                                   impl=cfg.moe_impl)
        x = x + out
    elif channel == "rwkv_cm":
        out, new_cm = rwkv_lib.apply_channel_mix(params["cm"], h2,
                                                 state=cache["cm"])
        x = x + out
        new_cache = {"tm": new_cache["tm"], "cm": new_cm}
    return x, new_cache


def decode_step(cfg: ModelConfig, params: dict, caches: List, token: jnp.ndarray
                ) -> Tuple[jnp.ndarray, List]:
    """One decode step.  token: (B, 1) int32.  Returns (logits (B,V), caches)."""
    B = token.shape[0]
    x = embed_tokens(params["embed"], token).astype(cfg.cdtype())
    if cfg.is_encoder_decoder:
        # absolute position = current cache pos of the first decoder layer
        pos = _first_attn_pos(caches)
        x = x + _sinusoid_at(pos, cfg.d_model).astype(x.dtype)

    new_caches = []
    for seg, seg_params, seg_cache in zip(build_segments(cfg),
                                          params["segments"], caches):
        def body(x, inputs):
            layer_params, layer_cache = inputs
            new_layer_cache = []
            for pos, sig in enumerate(seg.pattern):
                x, nc = apply_block_decode(cfg, sig, layer_params[pos], x,
                                           layer_cache[pos])
                new_layer_cache.append(nc)
            return x, tuple(new_layer_cache)

        x, new_seg_cache = jax.lax.scan(body, x, (seg_params, seg_cache),
                                        unroll=cfg.unroll)
        new_caches.append(new_seg_cache)
    x = _norm(cfg, params["final_norm"], x)
    logits = unembed(params["embed"], x).astype(jnp.float32)
    return logits[:, 0], new_caches


def _first_attn_pos(caches):
    for seg_cache in caches:
        for entry in seg_cache:
            if isinstance(entry, dict):
                if "pos" in entry:
                    return entry["pos"][0]
                if "self" in entry:
                    return entry["self"]["pos"][0]
    return jnp.zeros((), jnp.int32)


def _sinusoid_at(pos, dim):
    inv = jnp.exp(-jnp.arange(0, dim, 2, dtype=jnp.float32)
                  * (math.log(10_000.0) / dim))
    ang = pos.astype(jnp.float32) * inv
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)])[None, None, :]


def init_cache(cfg: ModelConfig, batch: int, cache_len: int,
               fill_pos: int = 0) -> List:
    """Zeroed cache pytree (leading (repeats,) axis per segment position)."""
    dtype = cfg.kv_dtype()
    segs = build_segments(cfg)
    caches = []
    for seg in segs:
        seg_cache = []
        for sig in seg.pattern:
            mixer, _ = sig
            if mixer in ("attn", "xattn"):
                entry = attn_lib.init_kv_cache(batch, cache_len,
                                               cfg.n_kv_heads, cfg.head_dim,
                                               dtype)
                entry["pos"] = jnp.asarray(fill_pos, jnp.int32)
                if mixer == "xattn":
                    entry = {"self": entry,
                             "cross_k": jnp.zeros((batch, cfg.encoder_seq_len,
                                                   cfg.n_kv_heads, cfg.head_dim),
                                                  dtype),
                             "cross_v": jnp.zeros((batch, cfg.encoder_seq_len,
                                                   cfg.n_kv_heads, cfg.head_dim),
                                                  dtype)}
            elif mixer == "local_attn":
                w = min(cfg.attn_window or cache_len, cache_len)
                entry = attn_lib.init_kv_cache(batch, w, cfg.n_kv_heads,
                                               cfg.head_dim, dtype)
                entry["pos"] = jnp.asarray(fill_pos, jnp.int32)
            elif mixer == "rglru":
                entry = rglru_lib.init_rglru_state(batch, cfg.rnn_width,
                                                   cfg.conv_width, dtype)
            elif mixer == "rwkv6":
                entry = rwkv_lib.init_rwkv6_state(batch, cfg.d_model,
                                                  cfg.n_heads, dtype)
            else:
                raise ValueError(mixer)
            # stack over repeats
            entry = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (seg.repeats,) + a.shape),
                entry)
            seg_cache.append(entry)
        caches.append(tuple(seg_cache))
    return caches


def cache_specs(cfg: ModelConfig, batch: int, cache_len: int):
    """ShapeDtypeStruct pytree mirroring ``init_cache`` (dry-run inputs)."""
    cache = jax.eval_shape(lambda: init_cache(cfg, batch, cache_len))
    return cache
