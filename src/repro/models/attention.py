"""Attention: GQA/MHA with chunked-query training path and cached decode.

Training/prefill uses *chunked-query* attention: an ``lax.scan`` over query
blocks so the compiled HLO never materialises the full S x S score matrix
(peak extra memory is ``q_block * S`` per head).  This keeps the dry-run
memory/roofline analysis honest at 32k context and doubles as the reference
oracle for the Pallas flash-attention kernel.

Decode attends one query token against a (possibly sequence-sharded) KV
cache; the distributed split-KV combine lives in ``repro.runtime``.
"""
from __future__ import annotations

import math
from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_mrope, apply_rope, dense_init

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def init_attention(key, d_model: int, n_heads: int, n_kv_heads: int,
                   d_head: int, dtype, qkv_bias: bool = False) -> dict:
    k1, k2, k3, k4 = jax.random.split(key, 4)
    params = {
        "w_q": dense_init(k1, d_model, n_heads * d_head, dtype),
        "w_k": dense_init(k2, d_model, n_kv_heads * d_head, dtype),
        "w_v": dense_init(k3, d_model, n_kv_heads * d_head, dtype),
        "w_o": dense_init(k4, n_heads * d_head, d_model, dtype),
    }
    if qkv_bias:  # Qwen1.5 [hf:Qwen/Qwen1.5-*]
        params["b_q"] = jnp.zeros((n_heads * d_head,), dtype)
        params["b_k"] = jnp.zeros((n_kv_heads * d_head,), dtype)
        params["b_v"] = jnp.zeros((n_kv_heads * d_head,), dtype)
    return params


def qkv_project(params: dict, x: jnp.ndarray, n_heads: int, n_kv_heads: int,
                d_head: int) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    B, S, _ = x.shape
    q = x @ params["w_q"]
    k = x @ params["w_k"]
    v = x @ params["w_v"]
    if "b_q" in params:
        q = q + params["b_q"]
        k = k + params["b_k"]
        v = v + params["b_v"]
    return (q.reshape(B, S, n_heads, d_head),
            k.reshape(B, S, n_kv_heads, d_head),
            v.reshape(B, S, n_kv_heads, d_head))


def _rope_qk(q, k, positions, rope_mode: str, theta: float, mrope_sections):
    if rope_mode == "none":
        return q, k
    if rope_mode == "mrope":
        return (apply_mrope(q, positions, mrope_sections, theta),
                apply_mrope(k, positions, mrope_sections, theta))
    return (apply_rope(q, positions, theta), apply_rope(k, positions, theta))


# ---------------------------------------------------------------------------
# chunked-query attention (training / prefill)
# ---------------------------------------------------------------------------


def chunked_attention(q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray,
                      causal: bool = True, window: Optional[int] = None,
                      q_block: int = 512, unroll: bool = False) -> jnp.ndarray:
    """q: (B, S, H, d); k/v: (B, S_kv, H_kv, d) with H % H_kv == 0.

    Scans over query blocks; each block sees the full (or windowed) key row.
    Exact softmax (no running-max needed: one full row per query).
    """
    B, S, H, D = q.shape
    S_kv, H_kv = k.shape[1], k.shape[2]
    group = H // H_kv
    scale = 1.0 / math.sqrt(D)
    q_block = min(q_block, S)
    n_blocks = -(-S // q_block)
    pad = n_blocks * q_block - S
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    # (B, nb, bs, H_kv, group, D)
    qb = q.reshape(B, n_blocks, q_block, H_kv, group, D)

    kv_pos = jnp.arange(S_kv)

    def block(carry, inputs):
        blk_idx, q_i = inputs  # q_i: (B, bs, H_kv, group, D)
        q_pos = blk_idx * q_block + jnp.arange(q_block)
        scores = jnp.einsum("bqhgd,bkhd->bhgqk", q_i.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        mask = jnp.ones((q_block, S_kv), dtype=bool)
        if causal:
            mask &= q_pos[:, None] >= kv_pos[None, :]
        if window is not None:
            mask &= (q_pos[:, None] - kv_pos[None, :]) < window
        scores = jnp.where(mask[None, None, None], scores, NEG_INF)
        probs = jax.nn.softmax(scores, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", probs.astype(v.dtype), v)
        return carry, out

    _, outs = jax.lax.scan(
        block, None, (jnp.arange(n_blocks), jnp.moveaxis(qb, 1, 0)),
        unroll=unroll)
    out = jnp.moveaxis(outs, 0, 1).reshape(B, n_blocks * q_block, H, D)
    if pad:
        out = out[:, :S]
    return out


def attention_block(params: dict, x: jnp.ndarray, *, n_heads: int,
                    n_kv_heads: int, d_head: int, positions: jnp.ndarray,
                    causal: bool = True, window: Optional[int] = None,
                    rope_mode: str = "rope", rope_theta: float = 10_000.0,
                    mrope_sections=(16, 24, 24), q_block: int = 512,
                    unroll: bool = False) -> jnp.ndarray:
    """Full attention sub-layer: qkv -> rope -> chunked attn -> output proj."""
    B, S, _ = x.shape
    q, k, v = qkv_project(params, x, n_heads, n_kv_heads, d_head)
    q, k = _rope_qk(q, k, positions, rope_mode, rope_theta, mrope_sections)
    out = chunked_attention(q, k, v, causal=causal, window=window,
                            q_block=q_block, unroll=unroll)
    return out.reshape(B, S, n_heads * d_head) @ params["w_o"]


# ---------------------------------------------------------------------------
# decode (single query token against a KV cache)
# ---------------------------------------------------------------------------


def decode_attention(q: jnp.ndarray, k_cache: jnp.ndarray, v_cache: jnp.ndarray,
                     cache_len: jnp.ndarray) -> jnp.ndarray:
    """q: (B, 1, H, d); caches: (B, S_max, H_kv, d); cache_len: () or (B,).

    Returns (B, 1, H, d).  Masked full-row softmax over the cache."""
    B, S_max, H_kv, D = k_cache.shape
    H = q.shape[2]
    group = H // H_kv
    scale = 1.0 / math.sqrt(D)
    qg = q.reshape(B, H_kv, group, D)
    scores = jnp.einsum("bhgd,bkhd->bhgk", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    valid = jnp.arange(S_max)[None, :] < jnp.reshape(cache_len, (-1, 1))
    scores = jnp.where(valid[:, None, None, :], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgk,bkhd->bhgd", probs.astype(v_cache.dtype), v_cache)
    return out.reshape(B, 1, H, D)


def decode_attention_block(params: dict, x: jnp.ndarray, cache: dict, *,
                           n_heads: int, n_kv_heads: int, d_head: int,
                           rope_mode: str = "rope",
                           rope_theta: float = 10_000.0,
                           mrope_sections=(16, 24, 24),
                           window: Optional[int] = None,
                           ) -> Tuple[jnp.ndarray, dict]:
    """One decode step.  cache: {"k": (B, S_max, H_kv, d), "v": ..., "pos": ()}.

    For windowed attention the cache is a ring buffer of size ``window``.
    Returns (output (B, 1, d_model), updated cache)."""
    B = x.shape[0]
    pos = cache["pos"]
    q, k, v = qkv_project(params, x, n_heads, n_kv_heads, d_head)
    positions = (jnp.full((B, 1), pos, jnp.int32) if rope_mode != "mrope"
                 else jnp.full((3, B, 1), pos, jnp.int32))
    q, k = _rope_qk(q, k, positions, rope_mode, rope_theta, mrope_sections)

    S_max = cache["k"].shape[1]
    # windowed caches are ring buffers of size == window
    slot = pos % S_max if window is not None else pos
    k_cache = jax.lax.dynamic_update_slice_in_dim(cache["k"], k.astype(cache["k"].dtype), slot, axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(cache["v"], v.astype(cache["v"].dtype), slot, axis=1)
    cache_len = jnp.minimum(pos + 1, S_max)
    out = decode_attention(q, k_cache, v_cache, cache_len)
    new_cache = {"k": k_cache, "v": v_cache, "pos": pos + 1}
    return out.reshape(B, 1, n_heads * d_head) @ params["w_o"], new_cache


def init_kv_cache(batch: int, s_max: int, n_kv_heads: int, d_head: int,
                  dtype) -> dict:
    return {
        "k": jnp.zeros((batch, s_max, n_kv_heads, d_head), dtype),
        "v": jnp.zeros((batch, s_max, n_kv_heads, d_head), dtype),
        "pos": jnp.zeros((), jnp.int32),
    }
