"""RG-LRU recurrent block (Griffin / RecurrentGemma [arXiv:2402.19427]).

Block structure (the paper's "recurrent block"):
    x -> linear (2 branches) -> [branch1: gelu] ; [branch2: conv1d -> RG-LRU]
      -> elementwise product -> linear out

RG-LRU recurrence (real-gated linear recurrent unit), per channel:
    r_t = sigmoid(W_a x_t + b_a)                     (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)                     (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)           (decay in (0, 1))
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

The sequence form is a first-order linear recurrence - evaluated with an
associative scan (O(log S) depth) so both CPU smoke tests and the TPU
lowering avoid a serial S-step loop.  The Pallas kernel in
``repro.kernels.rglru_scan`` implements the same contraction with explicit
VMEM blocking; this module is its oracle.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init

C_FACTOR = 8.0  # Griffin's fixed scaling constant


def init_rglru_block(key, d_model: int, d_rnn: int, conv_width: int,
                     dtype) -> dict:
    ks = jax.random.split(key, 7)
    # Lambda init so that a ~ Uniform(0.9, 0.999)^c (Griffin appendix)
    u = jax.random.uniform(ks[0], (d_rnn,), jnp.float32, 0.9, 0.999)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / C_FACTOR))  # softplus^-1
    return {
        "w_in_rnn": dense_init(ks[1], d_model, d_rnn, dtype),
        "w_in_gate": dense_init(ks[2], d_model, d_rnn, dtype),
        "conv_w": (jax.random.normal(ks[3], (conv_width, d_rnn), jnp.float32)
                   * (1.0 / math.sqrt(conv_width))).astype(dtype),
        "conv_b": jnp.zeros((d_rnn,), dtype),
        "w_a": dense_init(ks[4], d_rnn, d_rnn, dtype),
        "b_a": jnp.zeros((d_rnn,), dtype),
        "w_x": dense_init(ks[5], d_rnn, d_rnn, dtype),
        "b_x": jnp.zeros((d_rnn,), dtype),
        "lambda": lam,  # f32
        "w_out": dense_init(ks[6], d_rnn, d_model, dtype),
    }


def causal_conv1d(x: jnp.ndarray, w: jnp.ndarray, b: jnp.ndarray,
                  state: Optional[jnp.ndarray] = None
                  ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Depthwise causal conv.  x: (B, S, D); w: (W, D).

    state: (B, W-1, D) left context (decode); returns (y, new_state)."""
    W = w.shape[0]
    if state is None:
        state = jnp.zeros((x.shape[0], W - 1, x.shape[2]), x.dtype)
    xp = jnp.concatenate([state, x], axis=1)  # (B, S+W-1, D)
    y = sum(xp[:, i:i + x.shape[1]] * w[i] for i in range(W)) + b
    new_state = xp[:, -(W - 1):] if W > 1 else state
    return y.astype(x.dtype), new_state


def rglru_scan(x: jnp.ndarray, a: jnp.ndarray,
               h0: Optional[jnp.ndarray] = None
               ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """h_t = a_t * h_{t-1} + x_t via associative scan.

    x, a: (B, S, D) f32.  Returns (h (B,S,D), h_last (B,D))."""

    def combine(e1, e2):
        a1, x1 = e1
        a2, x2 = e2
        return a1 * a2, a2 * x1 + x2

    if h0 is not None:
        x = x.at[:, 0].add(a[:, 0] * h0)
    a_c, h = jax.lax.associative_scan(combine, (a, x), axis=1)
    return h, h[:, -1]


def rglru(params: dict, x: jnp.ndarray, h0: Optional[jnp.ndarray] = None,
          ) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """RG-LRU over a sequence.  x: (B, S, D_rnn).  f32 state math."""
    xf = x.astype(jnp.float32)
    r = jax.nn.sigmoid(xf @ params["w_a"].astype(jnp.float32)
                       + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(xf @ params["w_x"].astype(jnp.float32)
                       + params["b_x"].astype(jnp.float32))
    log_a = -C_FACTOR * jax.nn.softplus(params["lambda"]) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (i * xf)
    h, h_last = rglru_scan(gated, a, h0)
    return h.astype(x.dtype), h_last


def apply_rglru_block(params: dict, x: jnp.ndarray,
                      state: Optional[dict] = None
                      ) -> Tuple[jnp.ndarray, dict]:
    """Full Griffin recurrent block.  x: (B, S, d_model).

    state (decode): {"h": (B, D_rnn) f32, "conv": (B, W-1, D_rnn)}."""
    gate = jax.nn.gelu(x @ params["w_in_gate"])
    u = x @ params["w_in_rnn"]
    conv_state = state["conv"] if state is not None else None
    u, new_conv = causal_conv1d(u, params["conv_w"], params["conv_b"], conv_state)
    h0 = state["h"] if state is not None else None
    h, h_last = rglru(params, u, h0)
    out = (h * gate) @ params["w_out"]
    return out, {"h": h_last, "conv": new_conv}


def init_rglru_state(batch: int, d_rnn: int, conv_width: int, dtype) -> dict:
    return {
        "h": jnp.zeros((batch, d_rnn), jnp.float32),
        "conv": jnp.zeros((batch, conv_width - 1, d_rnn), dtype),
    }
