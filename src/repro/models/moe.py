"""Mixture-of-experts FFN - TPU-native formulations.

Two numerically-matching implementations:

* ``gshard``: capacity-factor dense dispatch via one-hot einsums
  [GShard arXiv:2006.16668, Switch arXiv:2101.03961].  This is the
  pjit/SPMD-friendly path: sharding the expert axis makes XLA insert
  all-to-alls; no data-dependent shapes.  Tokens are processed in *groups*
  to bound the dispatch tensor: (G, S_g, E, C) with C = k * S_g / E * cf.

* ``dense``: every token through every expert, weighted by the (sparse)
  gate matrix.  O(E/k) more FLOPs - only for tiny smoke shapes and as the
  drop-free oracle the Pallas/gshard paths are tested against.

Supports fine-grained + shared experts (DeepSeekMoE [arXiv:2401.06066]) and
128-expert top-8 routing (Qwen3-MoE [hf:Qwen/Qwen3-30B-A3B]).
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import apply_mlp, dense_init, init_mlp


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    d_expert: int          # per-expert FFN width (fine-grained: small)
    n_shared: int = 0      # DeepSeekMoE shared experts (always active)
    capacity_factor: float = 1.25
    group_size: int = 512  # dispatch group size (bounds one-hot tensors)
    renormalize: bool = True  # renormalize top-k gate weights


def init_moe(key, d_model: int, cfg: MoEConfig, mlp_kind: str, dtype) -> dict:
    kr, ke, ks = jax.random.split(key, 3)
    n_mats = 3 if mlp_kind in ("swiglu", "geglu") else 2
    keys = jax.random.split(ke, cfg.n_experts)

    def one_expert(k):
        return init_mlp(k, d_model, cfg.d_expert, mlp_kind, dtype)

    experts = jax.vmap(one_expert)(keys)  # stacked: leaf (E, ...)
    params = {
        "router": dense_init(kr, d_model, cfg.n_experts, dtype),
        "experts": experts,
    }
    if cfg.n_shared > 0:
        params["shared"] = init_mlp(ks, d_model, cfg.d_expert * cfg.n_shared,
                                    mlp_kind, dtype)
    return params


def router_probs(params: dict, x: jnp.ndarray, cfg: MoEConfig
                 ) -> Tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Returns (gates (T, E) post-softmax f32, top-k weights (T, k),
    top-k indices (T, k))."""
    logits = (x.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    gates = jax.nn.softmax(logits, axis=-1)
    top_w, top_i = jax.lax.top_k(gates, cfg.top_k)
    if cfg.renormalize:
        top_w = top_w / jnp.maximum(top_w.sum(-1, keepdims=True), 1e-9)
    return gates, top_w, top_i


def load_balance_loss(gates: jnp.ndarray, top_i: jnp.ndarray, n_experts: int
                      ) -> jnp.ndarray:
    """Switch-style auxiliary loss: E * sum_e f_e * P_e."""
    f = jnp.mean(jax.nn.one_hot(top_i, n_experts, dtype=jnp.float32), axis=(0, 1))
    p = jnp.mean(gates, axis=0)
    return n_experts * jnp.sum(f * p)


# ---------------------------------------------------------------------------
# dense (oracle) path
# ---------------------------------------------------------------------------


def apply_moe_dense(params: dict, x: jnp.ndarray, cfg: MoEConfig,
                    mlp_kind: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Every token through every expert; exact (no capacity drops)."""
    B, S, D = x.shape
    xt = x.reshape(B * S, D)
    gates, top_w, top_i = router_probs(params, xt, cfg)
    # sparse gate matrix (T, E)
    combine = jnp.zeros_like(gates).at[
        jnp.arange(xt.shape[0])[:, None], top_i].set(top_w)

    def per_expert(expert_params):
        return apply_mlp(expert_params, xt, mlp_kind)  # (T, D)

    all_out = jax.vmap(per_expert)(params["experts"])  # (E, T, D)
    out = jnp.einsum("te,etd->td", combine.astype(x.dtype), all_out)
    if "shared" in params:
        out = out + apply_mlp(params["shared"], xt, mlp_kind)
    aux = load_balance_loss(gates, top_i, cfg.n_experts)
    return out.reshape(B, S, D), aux


# ---------------------------------------------------------------------------
# GShard capacity-factor dispatch (SPMD path)
# ---------------------------------------------------------------------------


def _capacity(cfg: MoEConfig, group_tokens: int) -> int:
    c = int(math.ceil(cfg.top_k * group_tokens * cfg.capacity_factor
                      / cfg.n_experts))
    return max(c, cfg.top_k)


def apply_moe_gshard(params: dict, x: jnp.ndarray, cfg: MoEConfig,
                     mlp_kind: str) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Capacity-factor one-hot dispatch (GShard).  x: (B, S, D).

    Tokens are flattened and regrouped into groups of ``cfg.group_size``;
    each group dispatches into (E, C) expert slots.  Overflow tokens are
    dropped (their combine weight is 0) - matching TPU MoE practice.
    """
    B, S, D = x.shape
    T = B * S
    g_sz = min(cfg.group_size, T)
    if T % g_sz:  # largest divisor of T not exceeding the target group size
        g_sz = math.gcd(T, g_sz)
        if g_sz == 1:
            g_sz = T
    n_groups = T // g_sz
    xt = x.reshape(n_groups, g_sz, D)

    gates, top_w, top_i = router_probs(params, x.reshape(T, D), cfg)
    top_w = top_w.reshape(n_groups, g_sz, cfg.top_k)
    top_i = top_i.reshape(n_groups, g_sz, cfg.top_k)

    C = _capacity(cfg, g_sz)
    E = cfg.n_experts
    # position of each (token, choice) within its expert queue, per group
    onehot = jax.nn.one_hot(top_i, E, dtype=jnp.float32)  # (G, S, k, E)
    # rank choices: order by (slot in k, then token index) - cumulative sum
    flat = onehot.transpose(0, 2, 1, 3).reshape(n_groups, cfg.top_k * g_sz, E)
    pos = jnp.cumsum(flat, axis=1) - flat  # (G, k*S, E)
    pos = pos.reshape(n_groups, cfg.top_k, g_sz, E).transpose(0, 2, 1, 3)
    within_cap = pos < C
    keep = onehot * within_cap  # (G, S, k, E)

    pos_cap = jnp.minimum(pos, C - 1)
    pos_onehot = jax.nn.one_hot(pos_cap.astype(jnp.int32), C,
                                dtype=jnp.float32)  # (G, S, k, E, C)
    dispatch = jnp.einsum("gske,gskec->gsec", keep, pos_onehot)  # (G,S,E,C)
    combine = jnp.einsum("gsk,gske,gskec->gsec", top_w, keep, pos_onehot)

    expert_in = jnp.einsum("gsec,gsd->egcd", dispatch.astype(x.dtype), xt)

    def per_expert(expert_params, xin):  # xin: (G, C, D)
        return apply_mlp(expert_params, xin, mlp_kind)

    expert_out = jax.vmap(per_expert)(params["experts"], expert_in)  # (E,G,C,D)
    out = jnp.einsum("gsec,egcd->gsd", combine.astype(x.dtype), expert_out)
    out = out.reshape(B, S, D)
    if "shared" in params:
        out = out + apply_mlp(params["shared"], x, mlp_kind)
    aux = load_balance_loss(gates, top_i.reshape(T, cfg.top_k), cfg.n_experts)
    return out, aux


def apply_moe(params: dict, x: jnp.ndarray, cfg: MoEConfig, mlp_kind: str,
              impl: str = "gshard") -> Tuple[jnp.ndarray, jnp.ndarray]:
    if impl == "dense":
        return apply_moe_dense(params, x, cfg, mlp_kind)
    if impl == "gshard":
        return apply_moe_gshard(params, x, cfg, mlp_kind)
    raise ValueError(f"unknown moe impl {impl!r}")
