"""RWKV-6 "Finch" [arXiv:2404.05892]: attention-free, data-dependent decay.

Time-mix recurrence (per head, state S in R^{dk x dv}):

    S_t = diag(w_t) S_{t-1} + k_t^T v_t
    y_t = r_t (S_{t-1} + diag(u) k_t^T v_t)

with per-channel decay w_t = exp(-exp(w0 + lora_w(x))) data-dependent (the
v6 novelty) and token-shift ddlerp mixing on every projection input.

Sequence evaluation uses the *chunked* linear-attention form (GLA-style
[arXiv:2312.06635]): within-chunk quadratic contraction + cross-chunk state
carry, all decays handled in log space.  ``rwkv6_serial`` is the O(S) oracle
the chunked path and the Pallas kernel are tested against.
"""
from __future__ import annotations

import math
from typing import Optional, Tuple

import jax
import jax.numpy as jnp

from .layers import dense_init

DDLERP_DIM = 32   # TIME_MIX_EXTRA_DIM
DECAY_DIM = 64    # TIME_DECAY_EXTRA_DIM


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def init_rwkv6_time_mix(key, d_model: int, n_heads: int, dtype) -> dict:
    d_head = d_model // n_heads
    ks = jax.random.split(key, 12)
    mu = lambda k: jax.random.uniform(k, (d_model,), jnp.float32).astype(dtype)
    return {
        "mu_x": mu(ks[0]), "mu_w": mu(ks[1]), "mu_k": mu(ks[2]),
        "mu_v": mu(ks[3]), "mu_r": mu(ks[4]), "mu_g": mu(ks[5]),
        "tm_w1": dense_init(ks[6], d_model, 5 * DDLERP_DIM, dtype),
        "tm_w2": (jax.random.normal(ks[6], (5, DDLERP_DIM, d_model), jnp.float32)
                  * 0.01).astype(dtype),
        "td_w1": dense_init(ks[7], d_model, DECAY_DIM, dtype),
        "td_w2": (jax.random.normal(ks[7], (DECAY_DIM, d_model), jnp.float32)
                  * 0.01).astype(dtype),
        # w0 init: decays spread over (-6, -1) pre-exp (slow..fast)
        "w0": jnp.linspace(-6.0, -1.0, d_model, dtype=jnp.float32),
        "w_r": dense_init(ks[8], d_model, d_model, dtype),
        "w_k": dense_init(ks[9], d_model, d_model, dtype),
        "w_v": dense_init(ks[10], d_model, d_model, dtype),
        "w_g": dense_init(ks[11], d_model, d_model, dtype),
        "u": (jax.random.normal(ks[8], (n_heads, d_head), jnp.float32)
              * 0.1).astype(jnp.float32),
        "ln_x_scale": jnp.ones((d_model,), dtype),
        "ln_x_bias": jnp.zeros((d_model,), dtype),
        "w_o": dense_init(ks[9], d_model, d_model, dtype),
    }


def init_rwkv6_channel_mix(key, d_model: int, d_ff: int, dtype) -> dict:
    ks = jax.random.split(key, 5)
    mu = lambda k: jax.random.uniform(k, (d_model,), jnp.float32).astype(dtype)
    return {
        "mu_k": mu(ks[0]), "mu_r": mu(ks[1]),
        "w_k": dense_init(ks[2], d_model, d_ff, dtype),
        "w_v": dense_init(ks[3], d_ff, d_model, dtype),
        "w_r": dense_init(ks[4], d_model, d_model, dtype),
    }


# ---------------------------------------------------------------------------
# token shift + ddlerp
# ---------------------------------------------------------------------------


def _shift(x: jnp.ndarray, prev: Optional[jnp.ndarray]) -> jnp.ndarray:
    """x_{t-1} along the sequence.  prev: (B, D) last token of the previous
    segment (decode), else zeros."""
    if prev is None:
        prev = jnp.zeros_like(x[:, :1])
    else:
        prev = prev[:, None, :].astype(x.dtype)
    return jnp.concatenate([prev, x[:, :-1]], axis=1)


def ddlerp_inputs(params: dict, x: jnp.ndarray, x_prev: jnp.ndarray
                  ) -> Tuple[jnp.ndarray, ...]:
    """Data-dependent lerp producing the 5 projection inputs (w, k, v, r, g)."""
    xx = x_prev - x
    xxx = x + xx * params["mu_x"]
    # (B, S, 5*DD) -> (5, B, S, DD) -> (5, B, S, D)
    mix = jnp.tanh(xxx @ params["tm_w1"])
    B, S, _ = x.shape
    mix = mix.reshape(B, S, 5, DDLERP_DIM).transpose(2, 0, 1, 3)
    dyn = jnp.einsum("nbsd,ndm->nbsm", mix, params["tm_w2"].astype(mix.dtype))
    mus = jnp.stack([params["mu_w"], params["mu_k"], params["mu_v"],
                     params["mu_r"], params["mu_g"]]).astype(x.dtype)
    outs = x[None] + xx[None] * (mus[:, None, None, :] + dyn.astype(x.dtype))
    return tuple(outs[i] for i in range(5))


def decay_log(params: dict, xw: jnp.ndarray) -> jnp.ndarray:
    """log w_t = -exp(w0 + lora(xw))  (negative; w_t in (0,1)).  f32.

    Clamped at -5 (w >= 6.7e-3 per step): contributions older than a few
    steps under faster decay are < 1e-10 of the state - numerically
    indistinguishable - and the clamp bounds the log-domain range so the
    chunked path cannot overflow f32 (see ``wkv6_chunked``)."""
    lora = jnp.tanh(xw @ params["td_w1"]) @ params["td_w2"]
    return jnp.maximum(-jnp.exp(params["w0"] + lora.astype(jnp.float32)), -5.0)


def _group_norm(x: jnp.ndarray, scale, bias, n_heads: int,
                eps: float = 64e-5) -> jnp.ndarray:
    """Per-head LayerNorm over the head channel dim (RWKV's ln_x)."""
    B, S, D = x.shape
    xh = x.reshape(B, S, n_heads, D // n_heads).astype(jnp.float32)
    mean = xh.mean(-1, keepdims=True)
    var = xh.var(-1, keepdims=True)
    xh = (xh - mean) * jax.lax.rsqrt(var + eps)
    out = xh.reshape(B, S, D) * scale.astype(jnp.float32) + bias.astype(jnp.float32)
    return out


# ---------------------------------------------------------------------------
# WKV evaluation: serial oracle + chunked form
# ---------------------------------------------------------------------------


def wkv6_serial(r, k, v, logw, u, s0=None):
    """Serial scan oracle.  r/k/v: (B, S, H, K|V); logw: (B, S, H, K) f32;
    u: (H, K).  Returns (y (B,S,H,V), s_last (B,H,K,V) f32)."""
    B, S, H, K = k.shape
    V = v.shape[-1]
    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    s = jnp.zeros((B, H, K, V), jnp.float32) if s0 is None else s0

    def step(s, inp):
        r_t, k_t, v_t, lw_t = inp  # (B,H,K),(B,H,K),(B,H,V),(B,H,K)
        kv = k_t[..., :, None] * v_t[..., None, :]          # (B,H,K,V)
        y = jnp.einsum("bhk,bhkv->bhv", r_t, s + u[None, :, :, None] * kv)
        s = jnp.exp(lw_t)[..., None] * s + kv
        return s, y

    xs = (rf.transpose(1, 0, 2, 3), kf.transpose(1, 0, 2, 3),
          vf.transpose(1, 0, 2, 3), logw.transpose(1, 0, 2, 3))
    s_last, ys = jax.lax.scan(step, s, xs)
    return ys.transpose(1, 0, 2, 3).astype(r.dtype), s_last


def wkv6_chunked(r, k, v, logw, u, s0=None, chunk: int = 32,
                 unroll: bool = False):
    """Chunk-parallel evaluation (GLA form).  Same signature as serial.

    Intra-chunk decay ratios exp(cume_i - cum_j) are computed with a
    per-(chunk, channel) recentering constant theta = total/2 so that both
    factors stay within exp(+-|total|/2); with the -5 clamp in
    ``decay_log`` and chunk=32 this is bounded by exp(80) < f32 max."""
    B, S, H, K = k.shape
    V = v.shape[-1]
    pad = (-S) % chunk
    if pad:
        zf = lambda t: jnp.pad(t, ((0, 0), (0, pad), (0, 0), (0, 0)))
        r, k, v = zf(r), zf(k), zf(v)
        logw = jnp.pad(logw, ((0, 0), (0, pad), (0, 0), (0, 0)))
    n_chunks = (S + pad) // chunk
    shape_c = (B, n_chunks, chunk, H)
    rf = r.astype(jnp.float32).reshape(*shape_c, K)
    kf = k.astype(jnp.float32).reshape(*shape_c, K)
    vf = v.astype(jnp.float32).reshape(*shape_c, V)
    lw = logw.reshape(*shape_c, K)

    cum = jnp.cumsum(lw, axis=2)            # inclusive cumulative log decay
    cum_excl = cum - lw                     # exclusive (decay before step i)
    total = cum[:, :, -1]                   # (B, nc, H, K)

    s0 = (jnp.zeros((B, H, K, V), jnp.float32) if s0 is None else s0)

    def chunk_step(s, inp):
        r_c, k_c, v_c, cum_c, cume_c, tot_c = inp  # (B, chunk, H, ...)
        # intra-chunk: A[i,j] = r_i . (exp(cume_i - cum_j) * k_j), j < i
        theta = 0.5 * tot_c[:, None]                  # (B, 1, H, K)
        q_in = r_c * jnp.exp(cume_c - theta)
        k_in = k_c * jnp.exp(theta - cum_c)
        scores = jnp.einsum("bihk,bjhk->bhij", q_in, k_in)
        i_idx = jnp.arange(chunk)
        mask = i_idx[:, None] > i_idx[None, :]
        scores = jnp.where(mask[None, None], scores, 0.0)
        # diagonal bonus: r_i . (u * k_i)
        diag = jnp.einsum("bihk,hk,bihk->bhi", r_c, u, k_c)
        y = jnp.einsum("bhij,bjhv->bihv", scores, v_c)
        y = y + diag.transpose(0, 2, 1)[..., None] * v_c
        # inter-chunk: y_i += (r_i * exp(cume_i)) @ s   (exponent <= 0: safe)
        y = y + jnp.einsum("bihk,bhkv->bihv", r_c * jnp.exp(cume_c), s)
        # state update: s = exp(tot) * s + sum_j exp(tot - cum_j) k_j^T v_j
        k_carry = k_c * jnp.exp(tot_c[:, None] - cum_c)
        s = (jnp.exp(tot_c)[..., None] * s
             + jnp.einsum("bjhk,bjhv->bhkv", k_carry, v_c))
        return s, y

    xs = tuple(t.transpose(1, 0, 2, 3, 4) for t in (rf, kf, vf, cum, cum_excl))
    xs = xs + (total.transpose(1, 0, 2, 3),)
    # NOTE: the chunk scan stays a while loop even under dry-run unrolling
    # (unroll is capped): unrolling S/chunk = 128+ chunk bodies explodes
    # compile time, while WKV intra-chunk flops are ~2% of the layer's
    # projection flops (documented undercount in EXPERIMENTS.md).
    s_last, ys = jax.lax.scan(chunk_step, s0, xs,
                              unroll=min(4, n_chunks) if unroll else 1)
    y = ys.transpose(1, 0, 2, 3, 4).reshape(B, n_chunks * chunk, H, V)
    if pad:
        y = y[:, :S]
    return y.astype(r.dtype), s_last


# ---------------------------------------------------------------------------
# full blocks
# ---------------------------------------------------------------------------


def apply_time_mix(params: dict, x: jnp.ndarray, n_heads: int,
                   state: Optional[dict] = None, impl: str = "chunked",
                   unroll: bool = False) -> Tuple[jnp.ndarray, dict]:
    """x: (B, S, D).  state: {"shift": (B, D), "wkv": (B, H, K, V) f32}."""
    B, S, D = x.shape
    d_head = D // n_heads
    prev = state["shift"] if state is not None else None
    x_prev = _shift(x, prev)
    xw, xk, xv, xr, xg = ddlerp_inputs(params, x, x_prev)
    r = (xr @ params["w_r"]).reshape(B, S, n_heads, d_head)
    k = (xk @ params["w_k"]).reshape(B, S, n_heads, d_head)
    v = (xv @ params["w_v"]).reshape(B, S, n_heads, d_head)
    g = jax.nn.silu(xg @ params["w_g"])
    logw = decay_log(params, xw).reshape(B, S, n_heads, d_head)
    s0 = state["wkv"] if state is not None else None
    if impl == "chunked":
        y, s_last = wkv6_chunked(r, k, v, logw, params["u"], s0,
                                 unroll=unroll)
    else:
        y, s_last = wkv6_serial(r, k, v, logw, params["u"], s0)
    y = _group_norm(y.reshape(B, S, D), params["ln_x_scale"],
                    params["ln_x_bias"], n_heads).astype(x.dtype)
    out = (y * g) @ params["w_o"]
    return out, {"shift": x[:, -1], "wkv": s_last}


def apply_channel_mix(params: dict, x: jnp.ndarray,
                      state: Optional[dict] = None
                      ) -> Tuple[jnp.ndarray, dict]:
    prev = state["shift"] if state is not None else None
    x_prev = _shift(x, prev)
    xx = x_prev - x
    xk = x + xx * params["mu_k"]
    xr = x + xx * params["mu_r"]
    k = jnp.square(jax.nn.relu(xk @ params["w_k"]))
    kv = k @ params["w_v"]
    out = jax.nn.sigmoid(xr @ params["w_r"]) * kv
    return out, {"shift": x[:, -1]}


def init_rwkv6_state(batch: int, d_model: int, n_heads: int, dtype) -> dict:
    d_head = d_model // n_heads
    return {
        "tm": {"shift": jnp.zeros((batch, d_model), dtype),
               "wkv": jnp.zeros((batch, n_heads, d_head, d_head), jnp.float32)},
        "cm": {"shift": jnp.zeros((batch, d_model), dtype)},
    }
