"""Shared layer primitives for the model zoo.

Everything is functional: ``init_*`` builds a params pytree (nested dicts of
jnp arrays), ``apply`` functions consume (params, inputs).  Dtype policy:
parameters in ``param_dtype`` (bf16 for the production configs), activations
in ``compute_dtype``, normalization statistics and softmax in f32.
"""
from __future__ import annotations

import math
from typing import Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------


def dense_init(key, in_dim: int, out_dim: int, dtype) -> jnp.ndarray:
    scale = 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


def embed_init(key, vocab: int, dim: int, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, (vocab, dim), jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# normalization
# ---------------------------------------------------------------------------


def init_rmsnorm(dim: int, dtype) -> dict:
    return {"scale": jnp.ones((dim,), dtype)}


def rmsnorm(params: dict, x: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps)
    return (out * params["scale"].astype(jnp.float32)).astype(dtype)


def init_layernorm(dim: int, dtype) -> dict:
    return {"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)}


def layernorm(params: dict, x: jnp.ndarray, eps: float = 1e-5) -> jnp.ndarray:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    mean = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mean) * jax.lax.rsqrt(var + eps)
    out = out * params["scale"].astype(jnp.float32) + params["bias"].astype(jnp.float32)
    return out.astype(dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (RoPE + Qwen2-VL M-RoPE)
# ---------------------------------------------------------------------------


def rope_frequencies(d_head: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, d_head, 2, dtype=np.float64) / d_head))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float = 10_000.0
               ) -> jnp.ndarray:
    """x: (..., S, H, d_head); positions: broadcastable to (..., S)."""
    d_head = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(d_head, theta), jnp.float32)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (..., S, d/2)
    cos = jnp.cos(angles)[..., :, None, :]  # (..., S, 1, d/2)
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(x: jnp.ndarray, positions_3d: jnp.ndarray,
                sections: Tuple[int, int, int] = (16, 24, 24),
                theta: float = 1_000_000.0) -> jnp.ndarray:
    """Qwen2-VL multimodal RoPE [arXiv:2409.12191].

    The d_head/2 frequency dims are split into (temporal, height, width)
    sections; each section rotates by its own position stream.

    x: (B, S, H, d_head); positions_3d: (3, B, S).  For pure text all three
    streams are the ordinary position index.
    """
    d_head = x.shape[-1]
    assert sum(sections) == d_head // 2, (sections, d_head)
    freqs = jnp.asarray(rope_frequencies(d_head, theta), jnp.float32)  # (d/2,)
    # per-frequency-dim section id: 0 -> t, 1 -> h, 2 -> w
    sec_id = np.concatenate([np.full(s, i) for i, s in enumerate(sections)])
    pos = positions_3d.astype(jnp.float32)  # (3, B, S)
    pos_per_dim = jnp.take(pos, jnp.asarray(sec_id), axis=0)  # (d/2, B, S)
    angles = jnp.einsum("dbs,d->bsd", pos_per_dim, freqs)  # (B, S, d/2)
    cos = jnp.cos(angles)[:, :, None, :]
    sin = jnp.sin(angles)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


def text_mrope_positions(batch: int, seq: int, offset=0) -> jnp.ndarray:
    """(3, B, S) positions for text-only inputs (t = h = w = index)."""
    pos = jnp.arange(seq, dtype=jnp.int32)[None, :] + offset
    pos = jnp.broadcast_to(pos, (batch, seq))
    return jnp.broadcast_to(pos[None], (3, batch, seq))


# ---------------------------------------------------------------------------
# MLP variants
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, kind: str, dtype) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, d_model, d_ff, dtype),
            "w_up": dense_init(k2, d_model, d_ff, dtype),
            "w_down": dense_init(k3, d_ff, d_model, dtype),
        }
    # plain 2-matrix MLP (gelu / relu / squared_relu)
    return {
        "w_up": dense_init(k1, d_model, d_ff, dtype),
        "w_down": dense_init(k2, d_ff, d_model, dtype),
    }


def apply_mlp(params: dict, x: jnp.ndarray, kind: str) -> jnp.ndarray:
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif kind == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"]) * (x @ params["w_up"])
    elif kind == "gelu":
        h = jax.nn.gelu(x @ params["w_up"])
    elif kind == "squared_relu":  # Nemotron-4 [arXiv:2402.16819]
        h = jnp.square(jax.nn.relu(x @ params["w_up"]))
    elif kind == "relu":
        h = jax.nn.relu(x @ params["w_up"])
    else:
        raise ValueError(f"unknown mlp kind {kind!r}")
    return h @ params["w_down"]


# ---------------------------------------------------------------------------
# embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, dtype,
                   tied: bool = False) -> dict:
    k1, k2 = jax.random.split(key)
    params = {"tokens": embed_init(k1, vocab, d_model, dtype)}
    if not tied:
        params["unembed"] = dense_init(k2, d_model, vocab, dtype)
    return params


def embed_tokens(params: dict, tokens: jnp.ndarray) -> jnp.ndarray:
    return jnp.take(params["tokens"], tokens, axis=0)


def unembed(params: dict, x: jnp.ndarray) -> jnp.ndarray:
    if "unembed" in params:
        return x @ params["unembed"]
    return x @ params["tokens"].T.astype(x.dtype)


# ---------------------------------------------------------------------------
# losses
# ---------------------------------------------------------------------------


def softmax_cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                          mask: Optional[jnp.ndarray] = None) -> jnp.ndarray:
    """Mean next-token CE.  logits (B, S, V) - computed in f32; labels (B, S).

    Written as logsumexp - gather so it stays correct when V is sharded
    (XLA inserts the cross-partition reduction for the logsumexp)."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    # one-hot contraction (shard-friendly; avoids take_along_axis gather)
    label_logit = jnp.sum(
        logits * jax.nn.one_hot(labels, logits.shape[-1], dtype=jnp.float32),
        axis=-1)
    nll = lse - label_logit
    if mask is not None:
        nll = nll * mask
        return jnp.sum(nll) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
