"""Gradient compression: int8 quantization with error feedback.

The S-Paxos lesson (paper section 7) applied to training: keep the control
path (step ordering, tiny) separate from the data path (gradient payloads,
huge) and compress the expensive hop.  Cross-pod links are the scarce
resource in a multi-pod mesh, so gradients crossing the "pod" axis are
quantized to int8 with per-tensor scales; the quantization residual is fed
back into the next step (error feedback keeps SGD convergence [Karimireddy
et al. 2019 - standard EF-signSGD analysis]).
"""
from __future__ import annotations

from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Symmetric per-tensor int8.  Returns (q int8, scale f32)."""
    xf = x.astype(jnp.float32)
    scale = jnp.max(jnp.abs(xf)) / 127.0
    scale = jnp.maximum(scale, 1e-30)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return q.astype(jnp.float32) * scale


def compress_tree(grads, residuals: Optional[Any] = None):
    """Quantize a gradient pytree with error feedback.

    Returns (quantized tree of (q, scale), new_residuals).  ``residuals``
    from the previous step are added before quantization."""
    if residuals is None:
        residuals = jax.tree.map(lambda g: jnp.zeros_like(g, jnp.float32), grads)

    def one(g, r):
        corrected = g.astype(jnp.float32) + r
        q, scale = quantize_int8(corrected)
        new_r = corrected - dequantize_int8(q, scale)
        return (q, scale), new_r

    flat_g, treedef = jax.tree.flatten(grads)
    flat_r = treedef.flatten_up_to(residuals)
    out = [one(g, r) for g, r in zip(flat_g, flat_r)]
    qtree = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_res = jax.tree.unflatten(treedef, [o[1] for o in out])
    return qtree, new_res


def decompress_tree(qtree):
    return jax.tree.map(lambda leaf: dequantize_int8(*leaf), qtree,
                        is_leaf=lambda l: isinstance(l, tuple))


def compression_ratio(grads) -> float:
    """Bytes(int8+scale) / bytes(original)."""
    orig = sum(l.size * l.dtype.itemsize for l in jax.tree.leaves(grads))
    comp = sum(l.size * 1 + 4 for l in jax.tree.leaves(grads))
    return comp / orig
