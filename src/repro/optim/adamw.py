"""AdamW from scratch (no optax in this environment).

Mixed precision: parameters stay in their stored dtype (bf16 in production
configs); first/second moments are f32.  Global-norm gradient clipping and a
linear-warmup + cosine schedule are included.  Optimizer-state sharding
(replicated vs ZeRO-1 over the data axis) is decided by
``repro.runtime.sharding`` - this module is sharding-agnostic.
"""
from __future__ import annotations

import math
from dataclasses import dataclass
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    beta1: float = 0.9
    beta2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def init_opt_state(params) -> Dict[str, Any]:
    zeros_like_f32 = lambda p: jnp.zeros(p.shape, jnp.float32)
    return {
        "m": jax.tree.map(zeros_like_f32, params),
        "v": jax.tree.map(zeros_like_f32, params),
        "step": jnp.zeros((), jnp.int32),
    }


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step_f = step.astype(jnp.float32)
    warm = step_f / jnp.maximum(cfg.warmup_steps, 1)
    progress = jnp.clip((step_f - cfg.warmup_steps)
                        / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                        0.0, 1.0)
    cosine = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (
        1.0 + jnp.cos(math.pi * progress))
    return cfg.lr * jnp.where(step_f < cfg.warmup_steps, warm, cosine)


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(grads, max_norm: float) -> Tuple[Any, jnp.ndarray]:
    norm = global_norm(grads)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(norm, 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), grads), norm


def adamw_update(cfg: AdamWConfig, grads, opt_state, params
                 ) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    """One AdamW step.  Returns (new_params, new_state, metrics)."""
    grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
    grads, grad_norm = clip_by_global_norm(grads, cfg.clip_norm)
    step = opt_state["step"] + 1
    lr = lr_schedule(cfg, step)
    b1, b2 = cfg.beta1, cfg.beta2
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p, g, m, v):
        m_new = b1 * m + (1 - b1) * g
        v_new = b2 * v + (1 - b2) * jnp.square(g)
        m_hat = m_new / bc1
        v_hat = v_new / bc2
        delta = m_hat / (jnp.sqrt(v_hat) + cfg.eps)
        if cfg.weight_decay > 0 and p.ndim >= 2:  # no decay on norms/bias
            delta = delta + cfg.weight_decay * p.astype(jnp.float32)
        p_new = p.astype(jnp.float32) - lr * delta
        return p_new.astype(p.dtype), m_new, v_new

    flat_p, treedef = jax.tree.flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_params = jax.tree.unflatten(treedef, [o[0] for o in out])
    new_m = jax.tree.unflatten(treedef, [o[1] for o in out])
    new_v = jax.tree.unflatten(treedef, [o[2] for o in out])
    new_state = {"m": new_m, "v": new_v, "step": step}
    return new_params, new_state, {"grad_norm": grad_norm, "lr": lr}
