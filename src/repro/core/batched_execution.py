"""Batched closed-loop execution: "measured" as cheap as "modelled".

:func:`repro.core.execution.run_variant` is the ground truth of the
measured plane - a Python event loop over a real message-passing cluster,
linearizability-checked, ~milliseconds per few dozen commands.  Perfect
for parity smoke, hopeless for *surfaces*: the paper's measured
throughput/latency figures sweep config grids x client populations, and
the analytical planes (:meth:`CompiledSweep.mva`, ``.transient``) already
answer those in one jitted call each.  This module closes the gap: it
lowers a registered variant's execution plane into the same
``lax.scan``-over-steps / ``vmap``-over-(config x seed) shape
:mod:`repro.core.transient` uses, so a whole grid of closed-loop client
populations executes in ONE device call and emits *measured* per-station
msgs/cmd plus latency p50/p99 histograms.

How "measured" stays honest
---------------------------
The per-station message costs are **probe-calibrated, not copied from the
table**: for each config the real cluster runs once write-only and (for
mixed workloads) once at the target mix through :func:`run_variant`, at a
probe size and seed disjoint from anything the parity tests compare
against.  The probes yield per-class per-station msgs/cmd vectors
``cost_write``/``cost_read``; the jitted engine then *executes* the
client populations - every lane realizes exactly
``round(n_commands * f_write)`` writes, shuffled per seed and split
round-robin across clients, mirroring :func:`workload_ops` - and the
measured surface is the completion-weighted blend of the probed costs.
Cross-plane agreement with ``run_variant`` at different sizes and seeds
(within each :class:`~repro.core.api.ExecutableSpec`'s tolerances, exact
on its ``exact_stations``) is pinned by ``tests/test_batched_execution``.

The engine itself mirrors ``transient._one_lane``: stations are FIFO
queues draining work at ``dt / d_k`` per step, with the service demand
chosen per the *class of the command at the head* (writes traverse the
write path's demands, reads the read path's), commands walking the active
stations in canonical slot order.  Clients park once their op budget
drains, so the run has a makespan - measured throughput is
``n_commands / t_last`` - and every completion emits a latency sample;
the samples are histogrammed post-scan by the Pallas
:func:`repro.kernels.ops.latency_hist` kernel with the transient plane's
binning, so p50/p99 read identically across planes.

Entry points: :func:`run_variant_batched` (one config),
:func:`execute_configs` (any config list, e.g. a sweep's),
:meth:`repro.core.sweep.CompiledSweep.execute` (the compiled-grid method),
and :func:`validate_batched` (measured-vs-analytical parity on the
batched surface, the ``validate_variant`` analogue).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, replace
from functools import partial
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .analytical import STATION_ORDER, calibrate_alpha
from .api import Config, ShardingSpec, Workload, resolve_workload, variant_spec
from .execution import StationParity, default_config, run_variant
from .sharding import shard_weights, split_counts
from .sweep import config_variant
from .transient import _quantile_from_hist
from ..kernels.ops import latency_hist

__all__ = [
    "BatchedExecutionResult", "BatchedParityReport", "execute_configs",
    "measured_capacity", "run_variant_batched", "validate_batched",
]


# ---------------------------------------------------------------------------
# Probe calibration: per-class per-station msgs/cmd off the real cluster
# ---------------------------------------------------------------------------


def _probe_costs(name: str, cfg: Config, w: Workload, exe: Any,
                 probe_n: int, probe_seed: int, state_machine: str
                 ) -> Tuple[np.ndarray, np.ndarray, Any]:
    """Calibrate (cost_write[K], cost_read[K], feedback_trace) for one
    config by executing the real cluster.

    The write costs come from a write-only probe run.  Read costs come
    from a probe at the *target* mix, decomposed against the write probe -
    so read-path costs that only exist under concurrent writers (CRAQ's
    dirty-read forwarding) are captured at the mix they occur at."""
    k = len(STATION_ORDER)
    t_w = run_variant(name, cfg, replace(w, f_write=1.0),
                      n_commands=probe_n, seed=probe_seed,
                      state_machine=state_machine)
    cost_w = np.asarray(t_w.demand_slots(), dtype=np.float64)[:k]
    if exe.reads_as_writes or w.f_write >= 1.0:
        return cost_w, cost_w.copy(), t_w
    t_mix = run_variant(name, cfg, w, n_commands=probe_n,
                        seed=probe_seed + 1, state_machine=state_machine)
    mix = np.asarray(t_mix.demand_slots(), dtype=np.float64)[:k]
    n_wr, n_rd = t_mix.n_writes, probe_n - t_mix.n_writes
    if n_rd == 0:
        return cost_w, cost_w.copy(), t_mix
    cost_r = np.maximum((mix * probe_n - cost_w * n_wr) / n_rd, 0.0)
    return cost_w, cost_r, t_mix


def _class_streams(n_commands: int, f_write: float, n_clients: int,
                   seeds: np.ndarray, base_seed: int
                   ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Per-seed per-client op-class streams: exactly
    ``round(n_commands * f_write)`` writes (class 1), shuffled per seed
    and split round-robin across clients - the same realized mix
    :func:`repro.core.execution.workload_ops` produces, so the write
    count is seed-independent.  Returns (cls[S, N, L] int32,
    budget[N] int32, n_writes)."""
    n_w = round(n_commands * f_write)
    length = max(-(-n_commands // n_clients), 1)
    cls = np.zeros((len(seeds), n_clients, length), dtype=np.int32)
    budget = np.zeros((n_clients,), dtype=np.int32)
    for i in range(n_commands):
        budget[i % n_clients] += 1
    for si, s in enumerate(seeds):
        flags = np.array([1] * n_w + [0] * (n_commands - n_w), np.int32)
        np.random.default_rng([base_seed, int(s)]).shuffle(flags)
        pos = np.zeros((n_clients,), dtype=np.int64)
        for i in range(n_commands):
            c = i % n_clients
            cls[si, c, pos[c]] = flags[i]
            pos[c] += 1
    return cls, budget, n_w


# ---------------------------------------------------------------------------
# The jitted scan engine (one lane = one config x seed client population)
# ---------------------------------------------------------------------------


def _one_exec_lane(d_w, d_r, entry, nxt, cls_stream, budget, dt, key,
                   n_steps: int, n_clients: int, exponential: bool):
    """d_w/d_r: [K] per-class service seconds; nxt: [K] tandem routing;
    cls_stream: [N, L] int32 op classes per client; budget: [N]."""
    k = d_w.shape[0]
    n_ops = cls_stream.shape[1]
    if exponential:
        draws = jax.random.exponential(key, (n_steps + 1, k))
    else:
        draws = jnp.ones((n_steps + 1, k))

    finishes_at = nxt == k
    arrive_at = jnp.where(finishes_at, entry, nxt)

    alive0 = budget > 0
    stage0 = jnp.where(alive0, entry, k).astype(jnp.int32)  # k = parked
    rank0 = jnp.cumsum(alive0.astype(jnp.int32)) - 1
    enter0 = jnp.zeros((n_clients,))
    q0 = (jnp.zeros((k,), jnp.int32)
          .at[entry].add(jnp.sum(alive0.astype(jnp.int32))))
    work0 = jnp.zeros((k,)).at[entry].set(draws[0, entry])

    def step(state, xs):
        stage, rank, enter_t, op_i, q, work, done_w, done_r, t_last = state
        i, draw_i = xs
        t_end = (i + 1).astype(work.dtype) * dt

        cls_cur = jnp.take_along_axis(
            cls_stream, jnp.clip(op_i, 0, n_ops - 1)[:, None], axis=1)[:, 0]
        # the head command's class picks each station's service demand
        # (parked clients sit at stage == k and scatter out of bounds)
        head_cls = (jnp.zeros((k,), jnp.int32)
                    .at[stage].add(jnp.where(rank == 0, cls_cur, 0),
                                   mode="drop"))
        d_now = jnp.where(head_cls > 0, d_w, d_r)
        # a zero demand for the head's class (a read at the leader) drains
        # instantly - still one completion per step, like transient.py
        rate = jnp.where(d_now > 0, dt / jnp.maximum(d_now, 1e-30), 1e30)

        busy = q > 0
        work = jnp.where(busy, work - rate, work)
        complete = busy & (work <= 0.0)                        # [K]

        alive = stage < k
        stage_c = jnp.clip(stage, 0, k - 1)
        dep_here = alive & complete[stage_c]                   # [N]
        moving = dep_here & (rank == 0)
        fin = moving & finishes_at[stage_c]                    # op done
        lat = t_end - enter_t
        done_w = done_w + jnp.sum((fin & (cls_cur == 1)).astype(jnp.int32))
        done_r = done_r + jnp.sum((fin & (cls_cur == 0)).astype(jnp.int32))
        t_last = jnp.where(jnp.any(fin), t_end, t_last)

        op_next = op_i + fin.astype(jnp.int32)
        more = op_next < budget
        enters = moving & (~fin | more)    # next hop, or next op; else park
        dest = arrive_at[stage_c]
        q_dep = q - complete.astype(q.dtype)
        stage_new = jnp.where(moving, jnp.where(enters, dest, k), stage)
        enter_new = jnp.where(fin, t_end, enter_t)
        rank_new = jnp.where(
            moving, q_dep[dest],
            rank - (dep_here & (rank > 0)).astype(rank.dtype))
        arrivals = (jnp.zeros_like(q)
                    .at[jnp.where(enters, dest, k)]
                    .add(1, mode="drop"))
        q_new = q_dep + arrivals
        fresh = (complete & (q_new > 0)) | (~busy & (arrivals > 0))
        work_new = jnp.where(
            fresh, draw_i + jnp.where(complete, work, 0.0), work)

        return ((stage_new, rank_new, enter_new, op_next, q_new, work_new,
                 done_w, done_r, t_last), (fin, lat))

    state0 = (stage0, rank0, enter0,
              jnp.zeros((n_clients,), jnp.int32), q0, work0,
              jnp.asarray(0, jnp.int32), jnp.asarray(0, jnp.int32),
              jnp.asarray(0.0))
    xs = (jnp.arange(n_steps, dtype=jnp.int32), draws[1:])
    (state_f, (fin, lat)) = jax.lax.scan(step, state0, xs)
    _, _, _, _, _, _, done_w, done_r, t_last = state_f
    return fin, lat, done_w, done_r, t_last


@partial(jax.jit, static_argnames=("n_clients", "n_steps", "exponential"))
def _execute_batch(d_w, d_r, entry, nxt, cls, budget, dt, seeds,
                   n_clients: int, n_steps: int, exponential: bool):
    """The ONE device call: vmap lanes over configs (M) x seeds (S).

    d_w/d_r: [M, K]; entry: [M]; nxt: [M, K]; cls: [M, S, N, L];
    budget: [M, N]; dt: [M]; seeds: [S].  Returns
    (fin[M, S, n_steps, N] bool, lat[M, S, n_steps, N], done_w[M, S],
    done_r[M, S], t_last[M, S])."""
    m_ids = jnp.arange(d_w.shape[0], dtype=jnp.int32)

    def per_config(d_w_m, d_r_m, entry_m, nxt_m, cls_m, budget_m, dt_m, mi):
        def per_seed(cls_ms, s):
            key = jax.random.fold_in(jax.random.fold_in(jax.random.key(1),
                                                        mi), s)
            return _one_exec_lane(d_w_m, d_r_m, entry_m, nxt_m, cls_ms,
                                  budget_m, dt_m, key, n_steps, n_clients,
                                  exponential)
        return jax.vmap(per_seed)(cls_m, seeds)

    return jax.vmap(per_config)(d_w, d_r, entry, nxt, cls, budget, dt, m_ids)


def _routing(active: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Tandem routing over active stations (transient.py's convention):
    entry[M], next_station[M, K] with K = completion."""
    m, k = active.shape
    entry = np.zeros(m, dtype=np.int32)
    nxt = np.full((m, k), k, dtype=np.int32)
    for i in range(m):
        idx = np.nonzero(active[i])[0]
        if idx.size == 0:
            raise ValueError(f"config row {i} has no active station")
        entry[i] = idx[0]
        nxt[i, idx[:-1]] = idx[1:]
    return entry, nxt


# ---------------------------------------------------------------------------
# Public surface
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class BatchedExecutionResult:
    """One batched execution: M configs x S seeds of closed-loop clients.

    ``station_msgs[m]`` is the measured per-station msgs/cmd/server row
    (canonical :data:`STATION_ORDER` columns) - probe-calibrated per-class
    costs blended by the completions the engine realized; it is
    seed-independent because every lane drains its full op budget at the
    exact generator mix.  Latency/throughput are per (config, seed)."""

    configs: Tuple[Config, ...]
    workload: Workload
    n_commands: int
    n_clients: int
    seeds: np.ndarray              # [S]
    station_msgs: np.ndarray       # [M, K] msgs/cmd/server
    n_writes: np.ndarray           # [M] realized writes per lane
    cost_write: np.ndarray         # [M, K] probe-calibrated write costs
    cost_read: np.ndarray          # [M, K] probe-calibrated read costs
    throughput: np.ndarray         # [M, S] cmds/s (n_commands / makespan)
    latency_mean: np.ndarray       # [M, S] seconds
    latency_p50: np.ndarray        # [M, S]
    latency_p99: np.ndarray        # [M, S]
    completed: np.ndarray          # [M, S] ops drained (== lane budget)
    hist: np.ndarray               # [M, S, B]
    bin_edges: np.ndarray          # [M, B + 1]
    dt: np.ndarray                 # [M] seconds per step
    n_steps: int
    alpha: float
    # Shard axis (sharded runs only): rows become M_cfg x n_shards lanes
    # in config-major order; ``lane_config[m]`` / ``lane_shard[m]`` map a
    # lane back to its (config, shard) and ``lane_commands[m]`` is its
    # command budget (largest-remainder split of ``n_commands`` by the
    # shard traffic weights).  All None when no ShardingSpec was given.
    sharding: Optional[ShardingSpec] = None
    lane_config: Optional[np.ndarray] = None   # [M] config index
    lane_shard: Optional[np.ndarray] = None    # [M] shard index
    lane_commands: Optional[np.ndarray] = None  # [M] per-lane op budget
    # Geo axis (``geo=`` runs only, mutually exclusive with sharding):
    # rows become M_cfg x n_regions lanes in config-major order - one
    # closed-loop client population per region, command budgets split by
    # the region client weights.  ``wan_offset[m]`` is the lane's
    # analytical WAN latency excess (repro.core.geo.wan_offsets; zero for
    # a uniform matrix), already folded into latency_mean/p50/p99 and
    # bin_edges.
    geo: Optional[Any] = None
    lane_region: Optional[np.ndarray] = None   # [M] region index
    wan_offset: Optional[np.ndarray] = None    # [M]

    def __len__(self) -> int:
        return len(self.configs)

    def variant(self, m: int) -> str:
        return config_variant(self.configs[m])

    def shard_lanes(self, config_index: int = 0) -> np.ndarray:
        """Row indices of config ``config_index``'s shard (or region)
        lanes - the whole row range when the run was neither sharded nor
        geo-replicated."""
        if self.lane_config is None:
            return np.asarray([config_index])
        return np.nonzero(self.lane_config == config_index)[0]

    def region_latency(self, config_index: int = 0,
                       which: str = "p99") -> Dict[str, float]:
        """Seed-mean latency per client-bearing region for one config
        (geo runs only).  ``which`` is ``"mean"``, ``"p50"`` or
        ``"p99"``."""
        if self.geo is None or self.lane_region is None:
            raise ValueError("region_latency needs a geo= run")
        stat = {"mean": self.latency_mean, "p50": self.latency_p50,
                "p99": self.latency_p99}[which]
        out: Dict[str, float] = {}
        for lane in self.shard_lanes(config_index):
            if self.lane_commands is not None \
                    and self.lane_commands[lane] == 0:
                continue  # no clients in this region
            region = self.geo.regions[int(self.lane_region[lane])]
            out[region] = float(stat[lane].mean())
        return out

    def sharded_throughput(self, config_index: int = 0) -> np.ndarray:
        """Aggregate cmds/s of one config across its shard lanes, per
        seed.  Shard groups are independent clusters draining their
        traffic fractions concurrently, so the system rate is the sum of
        the per-shard rates."""
        return self.throughput[self.shard_lanes(config_index)].sum(axis=0)

    def station_row(self, m: int) -> Dict[str, float]:
        """Measured msgs/cmd/server of config m, keyed by station name
        (nonzero columns only) - the same vocabulary as
        ``ExecutionTrace.station_msgs``."""
        return {STATION_ORDER[k]: float(v)
                for k, v in enumerate(self.station_msgs[m]) if v > 0.0}

    def describe(self, m: int = 0) -> str:
        pairs = ", ".join(f"{s} {d:.2f}"
                          for s, d in self.station_row(m).items())
        return (f"{self.variant(m)}: {self.n_commands} cmds x "
                f"{len(self.seeds)} seeds ({int(self.n_writes[m])} writes); "
                f"msgs/cmd/server: {pairs}; "
                f"p50 {self.latency_p50[m].mean():.2e}s "
                f"p99 {self.latency_p99[m].mean():.2e}s")


def execute_configs(
    configs: Sequence[Config],
    workload: Optional[Union[Workload, float]] = None,
    n_commands: int = 48,
    seeds: Union[int, Sequence[int]] = 4,
    n_clients: int = 8,
    alpha: Optional[float] = None,
    probe_n: Optional[int] = None,
    probe_seed: int = 7919,
    exponential_service: bool = False,
    oversample: float = 4.0,
    n_bins: int = 64,
    state_machine: str = "kv",
    max_steps: int = 200_000,
    sharding: Optional[ShardingSpec] = None,
    geo: Optional[Any] = None,
) -> BatchedExecutionResult:
    """Execute a grid of registered-variant configs as one batched device
    call of closed-loop client populations.

    Per config: probe-calibrate per-class per-station message costs off
    the real cluster (:func:`run_variant` at ``probe_n``/``probe_seed``,
    disjoint from reference runs), lower the variant's demand table to
    per-class service times, build per-seed op-class streams at the exact
    generator mix, then run every (config x seed) lane through ONE jitted
    vmapped ``lax.scan`` and histogram the emitted latency samples with
    the Pallas :func:`repro.kernels.ops.latency_hist` kernel.

    ``exponential_service=False`` (default) is the parity mode: service is
    deterministic, the makespan is bounded, and every lane provably drains
    its budget.  ``True`` matches the MVA product-form assumptions for
    latency-surface work.

    With a :class:`~repro.core.api.ShardingSpec` each config expands to
    ``n_shards`` lanes - independent shard groups sharing the config's
    probe calibration, each draining its largest-remainder slice of
    ``n_commands`` (per the shard traffic weights) behind its own client
    population.  Rows of the result are then (config x shard) in
    config-major order; ``lane_config`` / ``lane_shard`` /
    ``lane_commands`` map them back and
    :meth:`BatchedExecutionResult.sharded_throughput` aggregates.

    With a :class:`~repro.core.api.GeoSpec` (mutually exclusive with
    sharding) each config instead expands to ``n_regions`` lanes - one
    closed-loop client population per region, command budgets split by
    the region client weights - and every lane's latency statistics
    (mean/p50/p99/histogram edges) carry the analytical WAN latency
    *excess* of its region (:func:`repro.core.geo.wan_offsets`, same
    units as ``1 / alpha``; exactly zero for a uniform matrix, so
    uniform-geo lanes read today's numbers unchanged).  The queueing
    part stays measured; the WAN part is deterministic wire time the
    step engine has no wires for."""
    if not configs:
        raise ValueError("execute_configs: empty config list")
    if geo is not None and sharding is not None:
        raise ValueError(
            "execute_configs: geo= and sharding= are mutually exclusive "
            "(region lanes and shard lanes both multiply the row axis)")
    w = resolve_workload(workload, where="execute_configs")
    if isinstance(seeds, (int, np.integer)):
        seeds_arr = np.arange(int(seeds), dtype=np.int32)
    else:
        seeds_arr = np.asarray(list(seeds), dtype=np.int32)
    if seeds_arr.size == 0:
        raise ValueError("execute_configs: need at least one seed")
    n_probe = probe_n if probe_n is not None else n_commands
    k = len(STATION_ORDER)
    n_cfg = len(configs)
    a = alpha if alpha is not None else calibrate_alpha()

    sharded = sharding is not None and sharding.n_shards > 1
    geoed = geo is not None and geo.n_regions > 1
    n_sh = (sharding.n_shards if sharded
            else geo.n_regions if geoed else 1)
    if sharded:
        lane_n = np.tile(split_counts(n_commands, shard_weights(sharding, w)),
                         n_cfg).astype(np.int64)
    elif geoed:
        lane_n = np.tile(
            split_counts(n_commands,
                         np.asarray(geo.resolved_client_weights())),
            n_cfg).astype(np.int64)
    else:
        lane_n = np.full((n_cfg,), n_commands, dtype=np.int64)
    m = n_cfg * n_sh
    lane_cfg = np.repeat(np.arange(n_cfg), n_sh)
    lane_shard = np.tile(np.arange(n_sh), n_cfg)

    wan_off = np.zeros((m,))
    if geo is not None:
        from .geo import wan_offsets
        for i, raw in enumerate(configs):
            cfg = dict(raw)
            cfg.setdefault("variant", "compartmentalized")
            off = wan_offsets(cfg, geo, workload=w, n_clients=n_clients)
            wan_off[i * n_sh:(i + 1) * n_sh] = np.asarray(off)[:n_sh]

    cost_w = np.zeros((n_cfg, k))
    cost_r = np.zeros((n_cfg, k))
    d_w_cfg = np.zeros((n_cfg, k))
    d_r_cfg = np.zeros((n_cfg, k))
    f_eff = np.zeros((n_cfg,))
    for i, raw in enumerate(configs):
        cfg = dict(raw)
        cfg.setdefault("variant", "compartmentalized")
        name = config_variant(cfg)
        spec = variant_spec(name)
        if spec.executable is None:
            raise ValueError(
                f"config {i}: variant {name!r} declares no execution plane")
        exe = spec.executable
        cost_w[i], cost_r[i], _ = _probe_costs(
            name, cfg, w, exe, n_probe, probe_seed, state_machine)
        dw_row, dr_row, _ = spec.model(cfg, w).demand_slots()
        d_w_cfg[i, :len(dw_row)] = np.asarray(dw_row[:k]) / a
        d_r_cfg[i, :len(dr_row)] = np.asarray(dr_row[:k]) / a
        f_eff[i] = 1.0 if exe.reads_as_writes else w.f_write

    # expand configs to lanes: shards of a config share its probe costs
    # and per-command demands - a shard runs the full deployment, it just
    # sees a fraction of the traffic
    cost_w = np.repeat(cost_w, n_sh, axis=0)
    cost_r = np.repeat(cost_r, n_sh, axis=0)
    d_w = np.repeat(d_w_cfg, n_sh, axis=0)
    d_r = np.repeat(d_r_cfg, n_sh, axis=0)
    f_eff = np.repeat(f_eff, n_sh)

    cls_all: List[np.ndarray] = []
    budget_all: List[np.ndarray] = []
    n_writes = np.zeros((m,), dtype=np.int64)
    for i in range(m):
        cls, budget, n_w = _class_streams(int(lane_n[i]), f_eff[i],
                                          n_clients, seeds_arr,
                                          base_seed=probe_seed + i)
        cls_all.append(cls)
        budget_all.append(budget)
        n_writes[i] = n_w
    length = max(c.shape[2] for c in cls_all)
    cls_all = [np.pad(c, ((0, 0), (0, 0), (0, length - c.shape[2])))
               for c in cls_all]

    blend = f_eff[:, None] * d_w + (1.0 - f_eff[:, None]) * d_r
    # station activity is a property of the *config's* mix, not of any one
    # shard's integer split: a zero-command lane still routes through its
    # config's active stations (and trivially drains nothing)
    cfg_w = np.zeros((m,), dtype=bool)
    cfg_r = np.zeros((m,), dtype=bool)
    for i in range(n_cfg):
        rows = slice(i * n_sh, (i + 1) * n_sh)
        cfg_w[rows] = bool(n_writes[rows].sum() > 0)
        cfg_r[rows] = bool(n_writes[rows].sum() < int(lane_n[rows].sum()))
    active = ((cfg_w[:, None] & (d_w > 0))
              | (cfg_r[:, None] & (d_r > 0)))               # [M, K]
    entry, nxt = _routing(active)
    dt = blend.max(axis=1) / oversample
    if np.any(dt <= 0):
        raise ValueError("a config row has zero effective demand")

    # deterministic makespan bound: each station serves every command at
    # most once, plus one step per (command, station) for instant drains
    d_hot = np.where(active, np.maximum(d_w, d_r), 0.0)
    span = (lane_n + n_clients) * d_hot.sum(axis=1)
    steps = span / dt + (lane_n + n_clients) * active.sum(axis=1)
    margin = 4.0 if exponential_service else 1.3
    n_steps = int(math.ceil(margin * float(steps.max()))) + 8
    n_steps = -(-n_steps // 256) * 256  # bucket: reuse the jit cache
    if n_steps > max_steps:
        raise ValueError(
            f"execute_configs: bound of {n_steps} steps exceeds max_steps="
            f"{max_steps}; raise max_steps or shrink the grid")

    rtt = np.maximum((blend * active).sum(axis=1), 1e-12)
    lo = rtt * 0.5
    hi = np.maximum(n_steps * dt, lo * 10.0)
    ratio = (hi / lo) ** (1.0 / n_bins)
    edges = lo[:, None] * ratio[:, None] ** np.arange(n_bins + 1)[None, :]

    fin, lat, done_w, done_r, t_last = _execute_batch(
        jnp.asarray(d_w), jnp.asarray(d_r), jnp.asarray(entry),
        jnp.asarray(nxt), jnp.asarray(np.stack(cls_all)),
        jnp.asarray(np.stack(budget_all)), jnp.asarray(dt),
        jnp.asarray(seeds_arr), n_clients=n_clients, n_steps=n_steps,
        exponential=bool(exponential_service))

    done_w = np.asarray(done_w, dtype=np.int64)
    done_r = np.asarray(done_r, dtype=np.int64)
    done = done_w + done_r
    if not np.all(done == lane_n[:, None]):
        short = np.argwhere(done != lane_n[:, None])
        raise RuntimeError(
            f"execute_configs: lanes {short.tolist()} drained "
            f"{done[tuple(short.T)].tolist()} of their op budgets in "
            f"{n_steps} steps - raise oversample margin or max_steps")

    s = seeds_arr.size
    lanes_lat = np.asarray(lat).reshape(m * s, -1)
    lanes_fin = np.asarray(fin).reshape(m * s, -1).astype(np.float32)
    lane_edges = np.repeat(edges, s, axis=0)
    hist = np.asarray(latency_hist(jnp.asarray(lanes_lat),
                                   jnp.asarray(lanes_fin),
                                   jnp.asarray(lane_edges)))
    hist = hist.reshape(m, s, n_bins)
    if geo is not None:
        # shift the (geometric) bin edges by each lane's deterministic WAN
        # offset AFTER binning: a sample in [e_k, e_k+1) is in
        # [e_k + wan, e_k+1 + wan) of the shifted edges, so histogram and
        # quantiles both read as total (wire + queueing) latency
        edges = edges + wan_off[:, None]

    lat_np = np.asarray(lat, dtype=np.float64)
    fin_np = np.asarray(fin)
    lat_sum = np.where(fin_np, lat_np, 0.0).sum(axis=(2, 3))
    t_last = np.asarray(t_last, dtype=np.float64)

    # completion-weighted blend of the probe-calibrated per-class costs:
    # the measured msgs/cmd surface (float64, so exact stations stay exact)
    msgs = (done_w[:, 0, None] * cost_w + done_r[:, 0, None] * cost_r) \
        / np.maximum(lane_n, 1)[:, None]

    return BatchedExecutionResult(
        configs=tuple(dict(configs[int(ci)]) for ci in lane_cfg),
        workload=w,
        n_commands=n_commands,
        n_clients=n_clients,
        seeds=seeds_arr,
        station_msgs=msgs,
        n_writes=done_w[:, 0].copy(),
        cost_write=cost_w,
        cost_read=cost_r,
        throughput=lane_n[:, None] / np.maximum(t_last, 1e-30),
        latency_mean=lat_sum / np.maximum(done, 1) + wan_off[:, None],
        latency_p50=_quantile_from_hist(hist, edges, 0.50),
        latency_p99=_quantile_from_hist(hist, edges, 0.99),
        completed=done.astype(np.float64),
        hist=hist,
        bin_edges=edges,
        dt=dt,
        n_steps=n_steps,
        alpha=a,
        sharding=sharding if sharded else None,
        lane_config=lane_cfg if (sharded or geoed) else None,
        lane_shard=lane_shard if sharded else None,
        lane_commands=lane_n if (sharded or geoed) else None,
        geo=geo,
        lane_region=lane_shard if geoed else None,
        wan_offset=wan_off if geo is not None else None,
    )


def run_variant_batched(name: str,
                        config: Optional[Config] = None,
                        workload: Optional[Union[Workload, float]] = None,
                        n_commands: int = 48,
                        seeds: Union[int, Sequence[int]] = 4,
                        n_clients: Optional[int] = None,
                        **kwargs: Any) -> BatchedExecutionResult:
    """One variant config through the batched executor (M = 1): the
    jitted sibling of :func:`repro.core.execution.run_variant`."""
    spec = variant_spec(name)
    if spec.executable is None:
        raise ValueError(
            f"variant {name!r} declares no execution plane; the batched "
            f"executor drives registered executables only")
    cfg = dict(config) if config is not None else default_config(name)
    cfg.setdefault("variant", name)
    n_cl = n_clients if n_clients is not None else spec.executable.n_clients
    return execute_configs([cfg], workload=workload, n_commands=n_commands,
                           seeds=seeds, n_clients=n_cl, **kwargs)


def measured_capacity(name: str,
                      config: Optional[Config] = None,
                      workload: Optional[Union[Workload, float]] = None,
                      n_commands: int = 96,
                      seeds: Union[int, Sequence[int]] = 3,
                      n_clients: Optional[int] = None,
                      **kwargs: Any) -> float:
    """Saturated cmds/s of one variant config off the batched executor:
    the execution-plane twin of the transient capacity anchor that
    :func:`repro.core.autoscale.autoscale_grid` probes with
    ``simulate_transient`` at the saturation population.

    A closed population this deep pins the bottleneck station near full
    utilization, so the seed-mean makespan rate IS the config's peak
    service rate - the ``lam_peak`` an :class:`~repro.core.api.\
AutoscalePolicy` band is anchored against, only measured on the
    message-level cluster instead of the token simulator."""
    spec = variant_spec(name)
    n_cl = n_clients if n_clients is not None else max(
        8, 2 * spec.executable.n_clients if spec.executable else 8)
    res = run_variant_batched(name, config=config, workload=workload,
                              n_commands=n_commands, seeds=seeds,
                              n_clients=n_cl, **kwargs)
    return float(res.throughput[0].mean())


# ---------------------------------------------------------------------------
# Parity: batched-measured vs analytical (the validate_variant analogue)
# ---------------------------------------------------------------------------


@dataclass
class BatchedParityReport:
    """Measured-vs-analytical msgs/cmd parity for one batched config."""

    variant: str
    config: Config
    model_config: Config
    workload: Workload
    rows: Tuple[StationParity, ...]
    result: BatchedExecutionResult

    @property
    def passed(self) -> bool:
        return all(r.ok for r in self.rows)

    def row(self, station: str) -> StationParity:
        for r in self.rows:
            if r.station == station:
                return r
        raise KeyError(f"no parity row for station {station!r}")

    def max_rel_err(self) -> float:
        return max((r.rel_err for r in self.rows), default=0.0)

    def __str__(self) -> str:
        lines = [f"{self.variant} @ {self.workload.describe()} [batched]: "
                 f"{'PASS' if self.passed else 'FAIL'}"]
        lines += [f"  {r.describe()}" for r in self.rows]
        return "\n".join(lines)


def validate_batched(name: str,
                     config: Optional[Config] = None,
                     workload: Optional[Union[Workload, float]] = None,
                     n_commands: int = 48,
                     seeds: Union[int, Sequence[int]] = 4,
                     **kwargs: Any) -> BatchedParityReport:
    """Parity-check the batched executor's measured per-station msgs/cmd
    against the variant's analytical demand table - the
    :func:`~repro.core.execution.validate_variant` analogue on the
    batched plane, with the same feedback loop: measured-parameter
    refinement comes off a real probe run of this very grid cell."""
    spec = variant_spec(name)
    if spec.executable is None:
        raise ValueError(f"variant {name!r} declares no execution plane")
    exe = spec.executable
    cfg = dict(config) if config is not None else default_config(name)
    cfg.setdefault("variant", name)
    w = resolve_workload(workload, where="validate_batched")
    res = run_variant_batched(name, cfg, w, n_commands=n_commands,
                              seeds=seeds, **kwargs)

    model_cfg = spec.adapt(cfg, w)
    if exe.model_feedback is not None:
        # the feedback statistics (skip rates, forwarding fractions) come
        # off a fresh probe run at this config - same loop as the scalar
        # plane, measured not assumed
        probe = run_variant(name, cfg,
                            replace(w, f_write=1.0) if exe.reads_as_writes
                            else w,
                            n_commands=n_commands,
                            seed=kwargs.get("probe_seed", 7919))
        model_cfg = exe.model_feedback(dict(model_cfg), probe)
    if res.geo is not None and res.lane_config is not None:
        # geo runs fan the config into region lanes; parity is against the
        # command-weighted aggregate (regions share the config's costs)
        lanes = res.shard_lanes(0)
        weights = res.lane_commands[lanes].astype(float)
        nw = float(res.n_writes[lanes].sum())
        agg = ((res.station_msgs[lanes] * weights[:, None]).sum(axis=0)
               / max(weights.sum(), 1.0))
        measured = {STATION_ORDER[j]: float(v)
                    for j, v in enumerate(agg) if v > 0.0}
    else:
        nw = float(res.n_writes[0])
        measured = res.station_row(0)
    realized = replace(w, f_write=nw / n_commands)
    predicted = spec.build(model_cfg).demands(realized)

    stations = list(measured)
    stations += [s for s, d in predicted.items()
                 if s not in measured and d > 0.0]
    rows = []
    for station in sorted(stations, key=STATION_ORDER.index):
        mm = measured.get(station, 0.0)
        p = predicted.get(station, 0.0)
        exact = station in exe.exact_stations
        tol = exe.tolerance_for(station)
        rel = abs(mm - p) / max(abs(p), 1e-12)
        ok = abs(mm - p) <= 1e-9 if exact else rel <= tol
        rows.append(StationParity(station=station, measured=mm, predicted=p,
                                  rel_err=rel, tolerance=tol, exact=exact,
                                  ok=ok))
    return BatchedParityReport(variant=name, config=cfg,
                               model_config=model_cfg, workload=w,
                               rows=tuple(rows), result=res)
