"""Vectorized compartmentalization sweeps.

The paper's evaluation is not one deployment but a *surface*: throughput as
a function of every compartmentalization knob (proxy leaders, acceptor grid
shape, replicas, batchers, batch size) - and, since the paper's sections 6-7
argue compartmentalization is "a technique, not a protocol", of the
**protocol variant** itself - under every workload.  This module lowers
a grid of configurations into dense demand tensors once
(:func:`compile_sweep`) and then answers whole-surface questions with
vectorized numpy (bottleneck law), a single jitted JAX call (full MVA /
fluid curves), or one batched stochastic scan (``.transient``) instead of a
Python loop over ``DeploymentModel`` objects.

Pipeline:

    SweepSpec  --configs()-->  knob dicts (one ``variant`` axis value each)
               --compile_sweep-->  CompiledSweep (demand_write/read [M, K])
               --.peak_throughput/.bottlenecks-->  bottleneck-law surface
               --.mva/.fluid-->  one jitted call, X[M, N] curves
               --.transient-->  one jitted scan, scripted dynamics

The variant axis is the **registry** (:mod:`repro.core.api`): every
registered :class:`~repro.core.api.VariantSpec` declares its knob space,
so :meth:`SweepSpec.configs`, :func:`model_for` and the autotuner's
candidate generators are generic loops with zero per-variant branches -
a variant registered at runtime sweeps here with no edits to this file.
``K = len(STATION_ORDER)`` is the canonical (registry-derived) station
vocabulary; a config's missing components occupy zero-demand slots, which
are exactly inert under both MVA and the fluid model, so heterogeneous
deployments - MultiPaxos next to Mencius next to S-Paxos next to CRAQ -
batch together losslessly and one vmapped call evaluates the whole
mixed-variant grid.

Evaluation methods take a :class:`~repro.core.api.Workload` - write
fraction, per-key skew, arrival pattern, batch-fill hints, passed once -
with the legacy ``f_write=`` scalar kwarg kept behind a
``DeprecationWarning`` shim.

:mod:`repro.core.autotune` builds on this to search the config space under
a machine budget (including across variants: ``autotune_variants``).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, Iterator, List, Optional, Sequence, Tuple, Union

import numpy as np

from .analytical import (
    STATION_ORDER,
    DeploymentModel,
    stack_demands,
)
from .api import (
    Config,
    ShardingSpec,
    Workload,
    resolve_workload,
    variant_spec,
)
from .sharding import flatten_shards, shard_demands
from .simulator import fluid_throughput_from_demands, mva_curves_from_demands
from .transient import (
    Event,
    TransientResult,
    build_schedule,
    burst_events,
    simulate_transient,
)


def _sharded_events(events: Sequence[Event], n_stations: int,
                    n_shards: int) -> List[Event]:
    """Expand station-named events to every shard's flattened column.

    After :func:`~repro.core.sharding.flatten_shards` the demand columns
    are ``shard * K + station``; an event naming a station (or a raw
    single-deployment column index) applies to that station in *every*
    shard group.  Events already addressing the flattened space (int
    column >= K) pass through untouched."""
    out: List[Event] = []
    for ev in events:
        col = ev.column()
        if isinstance(ev.station, int) and ev.station >= n_stations:
            out.append(ev)  # already a flattened (shard, station) address
            continue
        out.extend(
            Event(station=s * n_stations + col, start=ev.start,
                  stop=ev.stop, factor=ev.factor)
            for s in range(n_shards))
    return out

#: SweepSpec fields that are knob value iterables for the built-in
#: variants (knob name == field name); everything else is sweep plumbing.
_LEGACY_KNOB_FIELDS = (
    "n_proxy_leaders", "grids", "n_replicas", "batch_sizes", "n_batchers",
    "n_unbatchers", "n_leaders", "n_disseminators", "n_stabilizers",
    "chain_nodes",
)


@dataclass(frozen=True)
class SweepSpec:
    """A cartesian grid over the compartmentalization knobs, swept per
    protocol ``variant``.

    ``variants`` is the protocol axis: any name in the variant registry
    (:func:`repro.core.api.registered_variants`), including variants
    registered at runtime.  Each variant consumes exactly the knobs its
    :class:`~repro.core.api.VariantSpec` declares; per-knob values come
    from (highest priority first):

    1. ``knob_values`` - generic ``((knob name, values), ...)`` overrides,
       the only way to sweep knobs of runtime-registered variants;
    2. the named legacy field below, when the knob name matches one
       (``grids`` entries are ``(rows, cols)`` - write quorums are
       columns with ``rows`` members, read quorums rows with ``cols``);
    3. the variant's declared knob defaults.

    For backward compatibility, configs of the default
    ``compartmentalized`` variant omit the ``variant`` key
    (:func:`model_for` defaults it).
    """

    f: int = 1
    variants: Tuple[str, ...] = ("compartmentalized",)
    n_proxy_leaders: Tuple[int, ...] = (10,)
    grids: Tuple[Tuple[int, int], ...] = ((2, 2),)
    n_replicas: Tuple[int, ...] = (4,)
    batch_sizes: Tuple[int, ...] = (1,)
    n_batchers: Tuple[int, ...] = (0,)
    n_unbatchers: Tuple[int, ...] = (0,)
    n_leaders: Tuple[int, ...] = (3,)          # mencius
    n_disseminators: Tuple[int, ...] = (2,)    # spaxos
    n_stabilizers: Tuple[int, ...] = (3,)      # spaxos
    chain_nodes: Tuple[int, ...] = (3,)        # craq
    knob_values: Tuple[Tuple[str, Tuple[Any, ...]], ...] = ()

    def knob_space(self, variant: str) -> Dict[str, Tuple[Any, ...]]:
        """The per-knob value overrides this spec supplies for one
        variant (only knobs the variant declares; see class docstring
        for precedence)."""
        spec = variant_spec(variant)
        generic = {name: tuple(values) for name, values in self.knob_values}
        space: Dict[str, Tuple[Any, ...]] = {}
        for name in spec.knob_names():
            if name in generic:
                space[name] = generic[name]
            elif name in _LEGACY_KNOB_FIELDS:
                space[name] = tuple(getattr(self, name))
        return space

    def size(self) -> int:
        """Number of configs - computed arithmetically from the knob-space
        cardinalities (O(#variants)), never by enumerating the product."""
        return sum(variant_spec(v).size(self.knob_space(v))
                   for v in self.variants)

    def configs(self) -> Iterator[Config]:
        """One generic loop over the registry: each variant's declared
        knob space crossed into config dicts (zero per-variant branches)."""
        for variant in self.variants:
            spec = variant_spec(variant)  # raises on unknown variants
            yield from spec.configs(f=self.f,
                                    overrides=self.knob_space(variant))


def model_for(config: Config,
              workload: Optional[Workload] = None) -> DeploymentModel:
    """The per-config ``DeploymentModel`` a compiled sweep row corresponds
    to (the scalar reference path the batched path is tested against).
    Dispatches on ``config["variant"]`` through the variant registry; a
    config without the key is a compartmentalized-MultiPaxos knob dict
    (the pre-variant format the autotuner's greedy moves still emit).
    With a ``workload``, the variant's ``workload_adapter`` (if any) may
    reshape the config first (skew, batch-fill hints)."""
    variant = config.get("variant", "compartmentalized")
    return variant_spec(variant).model(config, workload)


def config_variant(config: Config) -> str:
    """The variant a sweep config belongs to (display/grouping helper)."""
    return str(config.get("variant", "compartmentalized"))


@dataclass(frozen=True)
class GeoLatencySurface:
    """A (config x region) latency surface from ONE jitted MVA call.

    ``wan[m, r]`` is the *extra* critical-path wire time the WAN matrix
    adds for config ``m`` seen from region ``r`` on top of the
    uniform-delay baseline (workload-blended, :func:`repro.core.geo.
    wan_offsets` - exactly zero for a uniform matrix, so the surface then
    reads identically to the plain MVA percentiles); ``queueing[m]`` the
    closed-loop MVA residence time at the evaluated client population.
    Assuming exponential queueing on top of a deterministic WAN offset,
    the percentiles are ``p50 = wan + ln(2) * queueing`` and ``p99 = wan
    + ln(100) * queueing``.  The RTT matrix must be expressed in the same
    time unit as ``1 / alpha`` for the sum to be meaningful.
    """

    regions: Tuple[str, ...]
    weights: np.ndarray    # [R] resolved client weights (rows sum to 1)
    wan: np.ndarray        # [M, R]
    queueing: np.ndarray   # [M]
    mean: np.ndarray       # [M, R]
    p50: np.ndarray        # [M, R]
    p99: np.ndarray        # [M, R]

    def worst_p99(self) -> np.ndarray:
        """[M] max p99 over client-bearing regions (fairness objective:
        the latency the worst-placed client population experiences)."""
        mask = self.weights > 0
        return self.p99[:, mask].max(axis=1)

    def blended_p99(self) -> np.ndarray:
        """[M] client-weighted mean p99 across regions."""
        return self.p99 @ self.weights


@dataclass(frozen=True)
class CompiledSweep:
    """A grid of deployments lowered to dense demand tensors.

    ``demand_write``/``demand_read`` are [M, K] per-server service demands
    in canonical :data:`STATION_ORDER` slots; ``machines`` is [M] total
    servers.  All evaluation methods are vectorized over the M axis and
    take a :class:`~repro.core.api.Workload` (legacy ``f_write=`` kwarg
    shimmed with a ``DeprecationWarning``).
    """

    models: Tuple[DeploymentModel, ...]
    demand_write: np.ndarray
    demand_read: np.ndarray
    machines: np.ndarray
    configs: Optional[Tuple[Config, ...]] = None

    def __len__(self) -> int:
        return len(self.models)

    def demands(self, workload: Optional[Union[Workload, float]] = None,
                f_write: Optional[float] = None,
                sharding: Optional[ShardingSpec] = None) -> np.ndarray:
        """Effective [M, K] demand matrix under a workload.

        The write/read blend is a vectorized re-weighting of the
        precompiled tensors.  When the workload carries demand-*shaping*
        hints (skew, partial batch fill) and this sweep carries configs,
        rows of variants that declare a ``workload_adapter`` are
        recomputed through it (CRAQ rows pick up dirty-read forwarding,
        batched rows lose amortization).

        With a :class:`~repro.core.api.ShardingSpec` the tensor gains a
        shard axis - [M, S, K] with row ``[m, s]`` the per-command table
        scaled by shard *s*'s traffic fraction (visit-ratio lowering;
        shard weights derive from the workload's skew).  Note the
        shard-local hot key is what the *sharding* weights model; the
        per-row variant adapters still see the same workload."""
        w = resolve_workload(workload, f_write, where="CompiledSweep.demands")
        if sharding is not None:
            base = self.demands(w)
            return shard_demands(base, sharding, w)
        out = (w.f_write * self.demand_write
               + (1.0 - w.f_write) * self.demand_read)
        if not (w.adapts_demands and self.configs is not None):
            return out
        k = out.shape[1]
        for i, cfg in enumerate(self.configs):
            spec = variant_spec(config_variant(cfg))
            if spec.workload_adapter is None:
                continue
            stripped = {key: v for key, v in cfg.items() if key != "variant"}
            adapted = spec.workload_adapter(stripped, w)
            if adapted is stripped:
                continue  # adapter no-op: the precompiled row stands
            model = spec.build(adapted)
            d_w, d_r, _ = model.demand_slots()
            row = (w.f_write * np.asarray(d_w[:k])
                   + (1.0 - w.f_write) * np.asarray(d_r[:k]))
            if len(d_w) > k and (any(d_w[k:]) or any(d_r[k:])):
                raise ValueError(
                    f"config {i} ({model.name}) emits stations beyond this "
                    f"compiled sweep's {k} columns - recompile the sweep")
            out[i] = row
        return out

    def peak_throughput(self, alpha: float,
                        workload: Optional[Union[Workload, float]] = None,
                        f_write: Optional[float] = None,
                        sharding: Optional[ShardingSpec] = None) -> np.ndarray:
        """Bottleneck-law peak throughput, [M] cmds/s.

        Sharded, the law becomes ``min_s alpha / (w_s * max_k d[m, k])``
        (every shard must keep up with its traffic share) - the max over
        the flattened (shard, station) columns computes exactly that, so
        uniform weights scale peak by ``n_shards``."""
        d = self.demands(workload, f_write, sharding)
        d_max = d.reshape(d.shape[0], -1).max(axis=1)
        with np.errstate(divide="ignore"):
            return np.where(d_max > 0, alpha / np.maximum(d_max, 1e-300),
                            np.inf)

    def bottleneck_indices(self,
                           workload: Optional[Union[Workload, float]] = None,
                           f_write: Optional[float] = None,
                           sharding: Optional[ShardingSpec] = None,
                           ) -> np.ndarray:
        d = self.demands(workload, f_write, sharding)
        return d.reshape(d.shape[0], -1).argmax(axis=1)

    def bottlenecks(self, workload: Optional[Union[Workload, float]] = None,
                    f_write: Optional[float] = None,
                    sharding: Optional[ShardingSpec] = None) -> List[str]:
        """Name of the saturating station per config, [M] (sharded:
        ``s<shard>/<station>``)."""
        idx = self.bottleneck_indices(workload, f_write, sharding)
        if sharding is None:
            return [STATION_ORDER[i] for i in idx]
        k = self.demand_write.shape[1]
        return [f"s{i // k}/{STATION_ORDER[i % k]}" for i in idx]

    def mva(self, alpha: float, n_clients_max: int = 512,
            workload: Optional[Union[Workload, float]] = None,
            f_write: Optional[float] = None,
            sharding: Optional[ShardingSpec] = None,
            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Full closed-loop latency-throughput surface in ONE jitted call.

        Returns (clients[N], X[M, N] cmds/s, R[M, N] seconds).  Sharded
        rows flatten the [M, S, K] tensor to [M, S*K] first: the same
        jitted MVA kernel then solves every shard's station loads jointly
        (each column's demand is already visit-ratio-scaled)."""
        d = self.demands(workload, f_write, sharding)
        if sharding is not None:
            d = flatten_shards(d)
        return mva_curves_from_demands(d / alpha, n_clients_max)

    def geo_latency(self, alpha: float, geo: Any,
                    workload: Optional[Union[Workload, float]] = None,
                    f_write: Optional[float] = None,
                    n_clients: int = 64) -> GeoLatencySurface:
        """Per-region latency surface for the whole grid in ONE jitted call.

        Composes the per-config WAN latency excess (:func:`repro.core.geo.
        wan_offsets`, O(M) Python, no device work) with the batched MVA
        queueing solve (one jitted call over all M configs) to a
        (config x region) :class:`GeoLatencySurface`.  ``geo`` is a
        :class:`~repro.core.api.GeoSpec`; its placement decides which
        region each station sits in and its client weights decide the
        per-region blend.  Batched configs have no WAN lowering and raise
        ``ValueError``."""
        from .geo import wan_offsets
        if self.configs is None:
            raise ValueError(
                "CompiledSweep.geo_latency needs per-row configs; compile "
                "with compile_sweep(spec) rather than compile_models(models)")
        w = resolve_workload(workload, f_write,
                             where="CompiledSweep.geo_latency")
        _, _, resid = self.mva(alpha, n_clients_max=n_clients, workload=w)
        queueing = np.asarray(resid[:, -1], dtype=float)
        regions = tuple(geo.regions)
        weights = np.asarray(geo.resolved_client_weights(), dtype=float)
        wan = np.empty((len(self), len(regions)), dtype=float)
        for i, cfg in enumerate(self.configs):
            wan[i] = wan_offsets(cfg, geo, workload=w, n_clients=n_clients)
        mean = wan + queueing[:, None]
        p50 = wan + float(np.log(2.0)) * queueing[:, None]
        p99 = wan + float(np.log(100.0)) * queueing[:, None]
        return GeoLatencySurface(regions=regions, weights=weights, wan=wan,
                                 queueing=queueing, mean=mean, p50=p50,
                                 p99=p99)

    def fluid(self, alpha: float, n_clients: int,
              workload: Optional[Union[Workload, float]] = None,
              f_write: Optional[float] = None,
              sharding: Optional[ShardingSpec] = None,
              sim_time: float = 1.0, n_steps: int = 2000) -> np.ndarray:
        """Batched fluid cross-check, [M] cmds/s in one jitted call."""
        d = self.demands(workload, f_write, sharding)
        if sharding is not None:
            d = flatten_shards(d)
        return fluid_throughput_from_demands(
            d / alpha, n_clients, sim_time, n_steps)

    def transient(self, alpha: float, n_clients: int = 64,
                  workload: Optional[Union[Workload, float]] = None,
                  f_write: Optional[float] = None,
                  events: Optional[Sequence[Event]] = None,
                  sharding: Optional[ShardingSpec] = None,
                  n_steps: int = 4000, **kwargs) -> TransientResult:
        """Batched stochastic transient run over every config in ONE jitted
        call: (M deployments x S seeds) lanes of the scan engine, with
        optional scripted :class:`~repro.core.transient.Event`s (leader
        crash, scale-up, ...) applied to the demand tensor mid-run.  A
        workload with ``arrival="bursty"`` contributes demand-surge
        windows (composable with explicit events - a crash during a
        burst is one schedule).  Returns per-window throughput traces and
        latency p50/p99 - the figure-of-merit surface the autotuner ranks
        by under faults."""
        w = resolve_workload(workload, f_write,
                             where="CompiledSweep.transient")
        evs = list(events) if events else []
        if sharding is None:
            base = self.demands(w) / alpha
        else:
            base = flatten_shards(self.demands(w, sharding=sharding)) / alpha
            evs = _sharded_events(evs, self.demand_write.shape[1],
                                  sharding.n_shards)
        if w.arrival == "bursty":
            evs.extend(burst_events(base.shape[1], factor=w.burst_factor,
                                    fraction=w.burst_fraction,
                                    n_bursts=w.n_bursts))
        if evs:
            sched, bounds = build_schedule(base, evs, n_steps)
        else:
            sched, bounds = base[None, :, :], None
        return simulate_transient(sched, bounds, n_clients=n_clients,
                                  n_steps=n_steps, **kwargs)

    def execute(self, workload: Optional[Union[Workload, float]] = None,
                n_commands: int = 48, seeds: Union[int, Sequence[int]] = 4,
                sharding: Optional[ShardingSpec] = None,
                **kwargs):
        """*Measure* every config in the sweep: probe-calibrate each
        variant's execution plane off the real cluster, then run the whole
        (config x seed) grid of closed-loop client populations in ONE
        jitted device call (:func:`repro.core.batched_execution.
        execute_configs`).  The third plane next to :meth:`mva` (steady
        state) and :meth:`transient` (faults): same grid, same one-call
        shape, but the per-station msgs/cmd surface is measured, not
        modelled.  Requires a config-bearing sweep (``compile_sweep``)
        whose variants all register executables.  With a ``sharding``
        every config becomes ``n_shards`` independent lanes sharing one
        probe, command budgets split by shard weight."""
        if self.configs is None:
            raise ValueError(
                "CompiledSweep.execute needs per-row configs; compile with "
                "compile_sweep(spec) rather than compile_models(models)")
        from .batched_execution import execute_configs
        return execute_configs(self.configs, workload=workload,
                               n_commands=n_commands, seeds=seeds,
                               sharding=sharding, **kwargs)

    def autoscale(self, alpha: float, policies: Sequence[Any],
                  load: np.ndarray,
                  workload: Optional[Union[Workload, float]] = None,
                  **kwargs):
        """Close the elastic loop over the whole (config x policy) grid
        (:func:`repro.core.autoscale.autoscale_grid`): every config row
        crossed with every :class:`~repro.core.api.AutoscalePolicy`
        (``None`` = the frozen static baseline) becomes one lane, probes
        are shared batched calls, and the full-horizon replay - actions
        lowered onto :func:`~repro.core.transient.
        reconfiguration_schedule` demand spikes - evaluates ALL lanes in
        ONE jitted device call, so policy search is one `lax.scan` shape
        away.  Returns traces in config-major order
        (``traces[m * len(policies) + p]``)."""
        w = resolve_workload(workload, where="CompiledSweep.autoscale")
        base = self.demands(w) / alpha
        servers = np.asarray([m.demand_slots()[2] for m in self.models],
                             dtype=np.int64)
        n_m, n_p = base.shape[0], len(policies)
        bases = np.repeat(base, n_p, axis=0)
        srv = np.repeat(servers, n_p, axis=0)
        pols = [policies[i % n_p] for i in range(n_m * n_p)]
        if self.configs is not None:
            labels = [f"{config_variant(self.configs[i // n_p])}/p{i % n_p}"
                      for i in range(n_m * n_p)]
            if "resizable" not in kwargs:
                # restrict each config's actions to its registry-derived
                # live-resizable stations, so every plan replays on the
                # execution plane unchanged
                from .execution import resizable_stations
                per_cfg = [resizable_stations(config_variant(c), c)
                           for c in self.configs]
                kwargs["resizable"] = [per_cfg[i // n_p]
                                       for i in range(n_m * n_p)]
        else:
            labels = [f"m{i // n_p}/p{i % n_p}" for i in range(n_m * n_p)]
        from .autoscale import autoscale_grid
        return autoscale_grid(bases, srv, pols, load, labels=labels,
                              **kwargs)

    def subset(self, indices: Sequence[int]) -> "CompiledSweep":
        """Row-select a sweep (e.g. a shortlist for the expensive
        transient objective); carries configs when present."""
        idx = list(int(i) for i in indices)
        return CompiledSweep(
            models=tuple(self.models[i] for i in idx),
            demand_write=self.demand_write[idx],
            demand_read=self.demand_read[idx],
            machines=self.machines[idx],
            configs=(tuple(self.configs[i] for i in idx)
                     if self.configs is not None else None))

    def top_k(self, alpha: float, k: int = 5,
              workload: Optional[Union[Workload, float]] = None,
              f_write: Optional[float] = None,
              budget: Optional[int] = None,
              sharding: Optional[ShardingSpec] = None,
              ) -> List[Tuple[int, float, str]]:
        """Best configs by bottleneck-law peak: [(index, peak, bottleneck)].

        Ties in peak break toward fewer machines; ``budget`` masks out
        deployments using more than that many servers (sharded: more than
        ``budget / n_shards`` per group - every shard runs a copy)."""
        w = resolve_workload(workload, f_write, where="CompiledSweep.top_k")
        peaks = self.peak_throughput(alpha, w, sharding=sharding)
        machines = self.machines * (sharding.n_shards if sharding else 1)
        if budget is not None:
            peaks = np.where(machines <= budget, peaks, -np.inf)
        order = np.lexsort((machines, -peaks))
        names = self.bottlenecks(w, sharding=sharding)
        return [(int(i), float(peaks[i]), names[i])
                for i in order[:k] if np.isfinite(peaks[i]) and peaks[i] > 0]


def compile_models(models: Sequence[DeploymentModel],
                   configs: Optional[Sequence[Config]] = None) -> CompiledSweep:
    """Lower an explicit list of deployments (e.g. the Fig. 29 ablation
    steps, or hand-built models) into a batched sweep."""
    d_w, d_r, machines = stack_demands(models)
    return CompiledSweep(models=tuple(models), demand_write=d_w,
                         demand_read=d_r, machines=machines,
                         configs=tuple(configs) if configs is not None else None)


def compile_sweep(spec: SweepSpec) -> CompiledSweep:
    """Compile a knob grid into demand tensors (the config -> demand-matrix
    compiler).  O(size) Python work happens once, here; everything after is
    vectorized."""
    configs = list(spec.configs())
    models = [model_for(c) for c in configs]
    compiled = compile_models(models, configs)
    return compiled
