"""Vectorized compartmentalization sweeps.

The paper's evaluation is not one deployment but a *surface*: throughput as
a function of every compartmentalization knob (proxy leaders, acceptor grid
shape, replicas, batchers, batch size) - and, since the paper's sections 6-7
argue compartmentalization is "a technique, not a protocol", of the
**protocol variant** itself - under every workload mix.  This module lowers
a grid of configurations into dense demand tensors once
(:func:`compile_sweep`) and then answers whole-surface questions with
vectorized numpy (bottleneck law), a single jitted JAX call (full MVA /
fluid curves), or one batched stochastic scan (``.transient``) instead of a
Python loop over ``DeploymentModel`` objects.

Pipeline:

    SweepSpec  --configs()-->  knob dicts (one ``variant`` axis value each)
               --compile_sweep-->  CompiledSweep (demand_write/read [M, K])
               --.peak_throughput/.bottlenecks-->  bottleneck-law surface
               --.mva/.fluid-->  one jitted call, X[M, N] curves
               --.transient-->  one jitted scan, scripted dynamics

``K = len(STATION_ORDER)`` is the canonical station vocabulary from
:mod:`repro.core.analytical`; a config's missing components occupy
zero-demand slots, which are exactly inert under both MVA and the fluid
model, so heterogeneous deployments - MultiPaxos next to Mencius next to
S-Paxos next to CRAQ - batch together losslessly and one vmapped call
evaluates the whole mixed-variant grid.

:mod:`repro.core.autotune` builds on this to search the config space under
a machine budget (including across variants: ``autotune_variants``).
"""
from __future__ import annotations

import itertools
from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from .analytical import (
    STATION_ORDER,
    VARIANT_MODELS,
    DeploymentModel,
    compartmentalized_model,
    stack_demands,
)
from .simulator import fluid_throughput_from_demands, mva_curves_from_demands
from .transient import Event, TransientResult, build_schedule, simulate_transient

Config = Dict[str, int]


@dataclass(frozen=True)
class SweepSpec:
    """A cartesian grid over the compartmentalization knobs, swept per
    protocol ``variant``.

    Each field lists the values that knob takes; :meth:`configs` yields the
    per-variant product.  ``grids`` entries are ``(rows, cols)`` - write
    quorums are columns (``rows`` members), read quorums are rows (``cols``
    members).

    ``variants`` is the protocol axis (keys of
    :data:`repro.core.analytical.VARIANT_MODELS`).  Each variant consumes
    the knobs its demand table understands: ``compartmentalized`` takes the
    full product including batching; ``mencius`` crosses ``n_leaders`` with
    proxies/grids/replicas; ``spaxos`` crosses
    ``n_disseminators`` x ``n_stabilizers`` with proxies/grids/replicas;
    ``craq`` takes ``chain_nodes``; the vanilla baselines
    (``multipaxos``, ``vanilla_mencius``, ``vanilla_spaxos``,
    ``unreplicated``) are single knobless configs.  For backward
    compatibility, configs of the default ``compartmentalized`` variant
    omit the ``variant`` key (:func:`model_for` defaults it).
    """

    f: int = 1
    variants: Tuple[str, ...] = ("compartmentalized",)
    n_proxy_leaders: Tuple[int, ...] = (10,)
    grids: Tuple[Tuple[int, int], ...] = ((2, 2),)
    n_replicas: Tuple[int, ...] = (4,)
    batch_sizes: Tuple[int, ...] = (1,)
    n_batchers: Tuple[int, ...] = (0,)
    n_unbatchers: Tuple[int, ...] = (0,)
    n_leaders: Tuple[int, ...] = (3,)          # mencius
    n_disseminators: Tuple[int, ...] = (2,)    # spaxos
    n_stabilizers: Tuple[int, ...] = (3,)      # spaxos
    chain_nodes: Tuple[int, ...] = (3,)        # craq

    def size(self) -> int:
        return sum(1 for _ in self.configs())

    def configs(self) -> Iterator[Config]:
        for variant in self.variants:
            if variant not in VARIANT_MODELS:
                raise ValueError(
                    f"unknown variant {variant!r}; choose from "
                    f"{sorted(VARIANT_MODELS)}")
            if variant == "compartmentalized":
                for p, (r, w), n, B, b, u in itertools.product(
                        self.n_proxy_leaders, self.grids, self.n_replicas,
                        self.batch_sizes, self.n_batchers, self.n_unbatchers):
                    yield dict(f=self.f, n_proxy_leaders=p, grid_rows=r,
                               grid_cols=w, n_replicas=n, batch_size=B,
                               n_batchers=b, n_unbatchers=u)
            elif variant == "mencius":
                for m, p, (r, w), n in itertools.product(
                        self.n_leaders, self.n_proxy_leaders, self.grids,
                        self.n_replicas):
                    yield dict(variant=variant, f=self.f, n_leaders=m,
                               n_proxy_leaders=p, grid_rows=r, grid_cols=w,
                               n_replicas=n)
            elif variant == "spaxos":
                for d, s, p, (r, w), n in itertools.product(
                        self.n_disseminators, self.n_stabilizers,
                        self.n_proxy_leaders, self.grids, self.n_replicas):
                    yield dict(variant=variant, f=self.f, n_disseminators=d,
                               n_stabilizers=s, n_proxy_leaders=p,
                               grid_rows=r, grid_cols=w, n_replicas=n)
            elif variant == "craq":
                for k in self.chain_nodes:
                    yield dict(variant=variant, n_nodes=k)
            elif variant == "unreplicated":
                yield dict(variant=variant)
            else:  # multipaxos / vanilla_mencius / vanilla_spaxos
                yield dict(variant=variant, f=self.f)


def model_for(config: Config) -> DeploymentModel:
    """The per-config ``DeploymentModel`` a compiled sweep row corresponds
    to (the scalar reference path the batched path is tested against).
    Dispatches on ``config["variant"]`` through
    :data:`repro.core.analytical.VARIANT_MODELS`; a config without the key
    is a compartmentalized-MultiPaxos knob dict (the pre-variant format
    the autotuner's greedy moves still emit)."""
    cfg = dict(config)
    variant = cfg.pop("variant", "compartmentalized")
    return VARIANT_MODELS[variant](**cfg)


def config_variant(config: Config) -> str:
    """The variant a sweep config belongs to (display/grouping helper)."""
    return str(config.get("variant", "compartmentalized"))


@dataclass(frozen=True)
class CompiledSweep:
    """A grid of deployments lowered to dense demand tensors.

    ``demand_write``/``demand_read`` are [M, K] per-server service demands
    in canonical :data:`STATION_ORDER` slots; ``machines`` is [M] total
    servers.  All evaluation methods are vectorized over the M axis.
    """

    models: Tuple[DeploymentModel, ...]
    demand_write: np.ndarray
    demand_read: np.ndarray
    machines: np.ndarray
    configs: Optional[Tuple[Config, ...]] = None

    def __len__(self) -> int:
        return len(self.models)

    def demands(self, f_write: float = 1.0) -> np.ndarray:
        """Effective [M, K] demand matrix at write fraction ``f_write``."""
        return (f_write * self.demand_write
                + (1.0 - f_write) * self.demand_read)

    def peak_throughput(self, alpha: float, f_write: float = 1.0) -> np.ndarray:
        """Bottleneck-law peak throughput, [M] cmds/s."""
        d_max = self.demands(f_write).max(axis=1)
        with np.errstate(divide="ignore"):
            return np.where(d_max > 0, alpha / np.maximum(d_max, 1e-300),
                            np.inf)

    def bottleneck_indices(self, f_write: float = 1.0) -> np.ndarray:
        return self.demands(f_write).argmax(axis=1)

    def bottlenecks(self, f_write: float = 1.0) -> List[str]:
        """Name of the saturating station per config, [M]."""
        return [STATION_ORDER[i] for i in self.bottleneck_indices(f_write)]

    def mva(self, alpha: float, n_clients_max: int = 512,
            f_write: float = 1.0
            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
        """Full closed-loop latency-throughput surface in ONE jitted call.

        Returns (clients[N], X[M, N] cmds/s, R[M, N] seconds)."""
        return mva_curves_from_demands(self.demands(f_write) / alpha,
                                       n_clients_max)

    def fluid(self, alpha: float, n_clients: int, f_write: float = 1.0,
              sim_time: float = 1.0, n_steps: int = 2000) -> np.ndarray:
        """Batched fluid cross-check, [M] cmds/s in one jitted call."""
        return fluid_throughput_from_demands(self.demands(f_write) / alpha,
                                             n_clients, sim_time, n_steps)

    def transient(self, alpha: float, n_clients: int = 64,
                  f_write: float = 1.0,
                  events: Optional[Sequence[Event]] = None,
                  n_steps: int = 4000, **kwargs) -> TransientResult:
        """Batched stochastic transient run over every config in ONE jitted
        call: (M deployments x S seeds) lanes of the scan engine, with
        optional scripted :class:`~repro.core.transient.Event`s (leader
        crash, scale-up, ...) applied to the demand tensor mid-run.
        Returns per-window throughput traces and latency p50/p99 - the
        figure-of-merit surface the autotuner ranks by under faults."""
        base = self.demands(f_write) / alpha
        if events:
            sched, bounds = build_schedule(base, events, n_steps)
        else:
            sched, bounds = base[None, :, :], None
        return simulate_transient(sched, bounds, n_clients=n_clients,
                                  n_steps=n_steps, **kwargs)

    def subset(self, indices: Sequence[int]) -> "CompiledSweep":
        """Row-select a sweep (e.g. a shortlist for the expensive
        transient objective); carries configs when present."""
        idx = list(int(i) for i in indices)
        return CompiledSweep(
            models=tuple(self.models[i] for i in idx),
            demand_write=self.demand_write[idx],
            demand_read=self.demand_read[idx],
            machines=self.machines[idx],
            configs=(tuple(self.configs[i] for i in idx)
                     if self.configs is not None else None))

    def top_k(self, alpha: float, k: int = 5, f_write: float = 1.0,
              budget: Optional[int] = None) -> List[Tuple[int, float, str]]:
        """Best configs by bottleneck-law peak: [(index, peak, bottleneck)].

        Ties in peak break toward fewer machines; ``budget`` masks out
        deployments using more than that many servers."""
        peaks = self.peak_throughput(alpha, f_write)
        if budget is not None:
            peaks = np.where(self.machines <= budget, peaks, -np.inf)
        order = np.lexsort((self.machines, -peaks))
        names = self.bottlenecks(f_write)
        return [(int(i), float(peaks[i]), names[i])
                for i in order[:k] if np.isfinite(peaks[i]) and peaks[i] > 0]


def compile_models(models: Sequence[DeploymentModel],
                   configs: Optional[Sequence[Config]] = None) -> CompiledSweep:
    """Lower an explicit list of deployments (e.g. the Fig. 29 ablation
    steps, or hand-built models) into a batched sweep."""
    d_w, d_r, machines = stack_demands(models)
    return CompiledSweep(models=tuple(models), demand_write=d_w,
                         demand_read=d_r, machines=machines,
                         configs=tuple(configs) if configs is not None else None)


def compile_sweep(spec: SweepSpec) -> CompiledSweep:
    """Compile a knob grid into demand tensors (the config -> demand-matrix
    compiler).  O(size) Python work happens once, here; everything after is
    vectorized."""
    configs = list(spec.configs())
    models = [model_for(c) for c in configs]
    compiled = compile_models(models, configs)
    return compiled
