"""Operation histories (Herlihy & Wing) recorded during protocol runs.

A history is a sequence of invocation and response events.  The recorder
assigns each operation a unique id; pending operations (no response) stay in
the history, which matters for linearizability checking (the checker may
*extend* the history with responses for pending writes - paper section 3.5).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Dict, List, Optional, Tuple


@dataclass
class Operation:
    op_id: int
    client_id: int
    op: Tuple
    invoke_time: float
    response_time: Optional[float] = None
    result: Any = None
    slot: Optional[int] = None  # log index written to / read from

    @property
    def pending(self) -> bool:
        return self.response_time is None

    @property
    def is_read(self) -> bool:
        return self.op[0] in ("get", "r", "read")


class History:
    def __init__(self) -> None:
        self.ops: List[Operation] = []
        self._next = 0

    def invoke(self, client_id: int, op: Tuple, now: float) -> int:
        op_id = self._next
        self._next += 1
        self.ops.append(Operation(op_id=op_id, client_id=client_id, op=op,
                                  invoke_time=now))
        return op_id

    def respond(self, op_id: int, result: Any, now: float,
                slot: Optional[int] = None) -> None:
        o = self.ops[op_id]
        o.response_time = now
        o.result = result
        o.slot = slot

    # -- views ----------------------------------------------------------------
    def complete(self) -> List[Operation]:
        return [o for o in self.ops if not o.pending]

    def pending(self) -> List[Operation]:
        return [o for o in self.ops if o.pending]

    def client_subhistory(self, client_id: int) -> List[Operation]:
        return [o for o in self.ops if o.client_id == client_id]

    def happens_before(self, a: Operation, b: Operation) -> bool:
        """a <_H b iff a's response precedes b's invocation (real time)."""
        return (a.response_time is not None
                and a.response_time < b.invoke_time)

    def __len__(self) -> int:
        return len(self.ops)

    def __iter__(self):
        return iter(self.ops)
