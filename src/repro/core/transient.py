"""Batched stochastic transient simulator of the closed queueing network.

The paper's headline claims are about *dynamics*, not just steady state:
throughput dips and recovers when a leader fails (section 5), degrades
under skew for CRAQ but not for the compartmentalized deployment
(Fig. 33), and ramps as batches fill (Figs. 30-31).  :mod:`simulator`
models steady state (MVA / fluid / DES); this module simulates the same
closed network *through time*, stochastically, entirely inside one jitted
``jax.lax.scan`` - ``vmap``-ed over (deployment x seed), so a whole
transient figure (dozens of deployments, many seeds) is one compiled call
instead of a Python event loop per cell.

Model
-----
N closed-loop clients, one outstanding command each (the paper's
benchmark harness).  Each station is a FIFO queue with per-command service
demand ``d_k`` seconds (exponential with mean ``d_k``, or deterministic);
commands traverse the active stations in slot order and re-enter on
completion (zero think time).  With exponential service this is exactly
the product-form network MVA solves, so steady-state throughput must
match :func:`repro.core.simulator.mva_curve` - ``tests/test_transient.py``
pins the agreement.

Time advances in fixed steps ``dt`` (default: slowest station's demand /
``oversample``).  Remaining service is tracked in *work* units (fractions
of one service) and drained at ``dt / d_k(t)`` per step, so
**time-varying demands act on in-flight work**: a crashed station
(demand x ~1e9) freezes mid-service and resumes after recovery, a scaled
station drains faster from the next step on.  Completion residuals carry
into the next service, so a saturated server's long-run rate is exactly
``1/d_k`` with no discretization bias.

Scripted events
---------------
Demands are piecewise-constant in time: ``demands[w]`` holds during steps
``step_bounds[w] <= i < step_bounds[w+1]``.  Builders:

* :func:`failover_schedule` - multiply one station's demand inside a
  window (``factor=CRASH`` freezes it: leader crash + failover);
* :func:`scale_schedule` - step a station's demand at one instant
  (component scale-up/down, bottleneck migration in time);
* :func:`schedule_from_demands` - arbitrary per-window demand matrices
  (batch fill ramps, time-varying skew via the CRAQ demand mapping);
* :func:`mencius_skip_storm_schedule` / :func:`spaxos_payload_ramp_schedule`
  - protocol-variant scripts (a lagging Mencius leader noop-flooding the
  chosen path; S-Paxos payloads growing while the id-ordering leader's
  demand stays flat);
* :func:`resharding_schedule` - a live hot-shard split under load over
  flattened ``(shard, station)`` columns: steady skewed traffic, a
  stop-the-world migration window, then the rebalanced (higher-peak)
  post-split weights;
* :func:`reconfiguration_schedule` - an autoscale action plan lowered
  onto a piecewise demand schedule: each add/drain pays a transient
  demand spike on the resized station at the window it lands in (the
  controller's modelled reconfiguration cost).

Outputs: per-step completion traces (-> per-window throughput), post-
warmup mean throughput, and latency mean / p50 / p99 from a log-spaced
in-scan histogram.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Any, List, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from .analytical import (
    STATION_INDEX,
    STATION_ORDER,
    DeploymentModel,
    mencius_model,
    spaxos_model,
)
from .api import ShardingSpec, Workload, resolve_workload
from .simulator import demand_vector

#: Demand multiplier that effectively freezes a station (a crash: in-flight
#: service stalls and resumes on recovery when the multiplier lifts).
CRASH = 1e9


# ---------------------------------------------------------------------------
# Scripted-event schedules (piecewise-constant demand tensors)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Event:
    """Multiply ``station``'s demand by ``factor`` during a run fraction.

    ``station`` is a canonical :data:`repro.core.analytical.STATION_ORDER`
    name or a raw column index; ``start``/``stop`` are fractions of the
    simulated horizon in [0, 1]."""

    station: Union[str, int]
    start: float
    stop: float
    factor: float

    def column(self) -> int:
        if isinstance(self.station, str):
            return STATION_INDEX[self.station]
        return int(self.station)


def _as_base(demands: np.ndarray) -> np.ndarray:
    """Coerce [K] / [M, K] / [W, M, K] to a [M, K] window-0 base."""
    d = np.asarray(demands, dtype=np.float64)
    if d.ndim == 1:
        d = d[None, :]
    if d.ndim == 3:
        d = d[0]
    return d


def build_schedule(base: np.ndarray, events: Sequence[Event], n_steps: int
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Lower events over a [M, K] base matrix to (demands[W, M, K],
    step_bounds[W]).  Overlapping events compose multiplicatively."""
    base = _as_base(base)
    cuts = {0}
    spans = []
    for e in events:
        lo = int(round(np.clip(e.start, 0.0, 1.0) * n_steps))
        hi = int(round(np.clip(e.stop, 0.0, 1.0) * n_steps))
        spans.append((lo, hi, e.column(), e.factor))
        cuts.update(c for c in (lo, hi) if 0 <= c < n_steps)
    bounds = np.array(sorted(cuts), dtype=np.int32)
    out = np.repeat(base[None, :, :], len(bounds), axis=0)
    for w, b in enumerate(bounds):
        for lo, hi, col, factor in spans:
            if lo <= b < hi:
                out[w, :, col] *= factor
    return out, bounds


def failover_schedule(base: np.ndarray, station: Union[str, int] = "leader",
                      start: float = 0.35, stop: float = 0.6,
                      factor: float = CRASH, n_steps: int = 4000
                      ) -> Tuple[np.ndarray, np.ndarray]:
    """Crash ``station`` during [start, stop) of the run, then recover."""
    return build_schedule(base, [Event(station, start, stop, factor)], n_steps)


def scale_schedule(base: np.ndarray, station: Union[str, int], at: float,
                   factor: float, n_steps: int = 4000
                   ) -> Tuple[np.ndarray, np.ndarray]:
    """Step ``station``'s demand by ``factor`` at run fraction ``at`` for
    the rest of the run (factor < 1 = scale-up, > 1 = scale-down)."""
    return build_schedule(base, [Event(station, at, 1.0, factor)], n_steps)


def burst_events(n_stations: int, factor: float = 4.0,
                 fraction: float = 0.25, n_bursts: int = 3) -> List[Event]:
    """Arrival bursts as scripted events: ``n_bursts`` evenly spaced
    surge windows covering ``fraction`` of the run, during which EVERY
    station's demand is multiplied by ``factor`` (offered load transiently
    exceeding provisioned capacity, in the closed-network approximation).
    One :class:`Event` per station column per surge, so bursts compose
    multiplicatively with any other scripted event (a leader crash during
    a burst is just one schedule).  This is how
    ``Workload(arrival="bursty")`` lowers onto the engine."""
    if n_bursts < 1:
        raise ValueError(f"n_bursts must be >= 1: {n_bursts}")
    if not 0.0 < fraction < 1.0:
        raise ValueError(f"burst fraction must be in (0, 1): {fraction}")
    events: List[Event] = []
    seg = 1.0 / n_bursts
    surge = fraction * seg
    for b in range(n_bursts):
        start = b * seg + (seg - surge) / 2.0
        events.extend(Event(k, start, start + surge, factor)
                      for k in range(n_stations))
    return events


def schedule_from_demands(windows: Sequence[np.ndarray],
                          starts: Sequence[float], n_steps: int
                          ) -> Tuple[np.ndarray, np.ndarray]:
    """Arbitrary piecewise schedule: ``windows[w]`` ([M, K] or [K]) holds
    from run fraction ``starts[w]`` (first must be 0) to the next start.
    This is how batch-fill ramps and time-varying skew are scripted: build
    each window's demand matrix from the analytical model and stack."""
    if len(windows) != len(starts):
        raise ValueError(f"{len(windows)} windows vs {len(starts)} starts")
    if starts[0] != 0.0:
        raise ValueError("first window must start at fraction 0")
    if list(starts) != sorted(starts):
        raise ValueError("window starts must be nondecreasing")
    mats = [_as_base(w) for w in windows]
    if len({m.shape for m in mats}) != 1:
        raise ValueError("all windows must share the same [M, K] shape")
    bounds = np.array([int(round(s * n_steps)) for s in starts],
                      dtype=np.int32)
    return np.stack(mats), bounds


def _demand_row(model: DeploymentModel, f_write: float = 1.0) -> np.ndarray:
    """One model's effective demand scattered into canonical slots, [1, K]."""
    d_w, d_r, _ = model.demand_slots()
    row = (f_write * np.asarray(d_w, dtype=np.float64)
           + (1.0 - f_write) * np.asarray(d_r, dtype=np.float64))
    return row[None, :]


def mencius_skip_storm_schedule(
    alpha: float,
    n_leaders: int = 3,
    start: float = 0.35,
    stop: float = 0.7,
    skip_fraction: float = 0.5,
    slow_factor: float = 3.0,
    skip_batch: float = 10.0,
    n_steps: int = 4000,
    workload: Optional[Workload] = None,
    f_write: Optional[float] = None,
    **mencius_kwargs,
) -> Tuple[np.ndarray, np.ndarray]:
    """Mencius slow-leader skip storm (paper section 6 dynamics).

    During ``[start, stop)`` one of the ``n_leaders`` lags: its owned slots
    are noop-filled at ``skip_fraction`` of the log (the Phase2aRange skip
    traffic loads proxies, the grid and the replicas per
    :func:`repro.core.analytical.mencius_model`), and the leader station
    itself drains ``slow_factor`` x slower (the hot lane is the laggard's).
    After ``stop`` the leader catches up and demands return to the healthy
    table.  Returns ``(demands[W, 1, K], step_bounds[W])`` ready for
    :func:`simulate_transient` (demands already divided by ``alpha``)."""
    w = resolve_workload(workload, f_write,
                         where="mencius_skip_storm_schedule")
    healthy = _demand_row(
        mencius_model(n_leaders=n_leaders, **mencius_kwargs),
        w.f_write) / alpha
    storm = _demand_row(
        mencius_model(n_leaders=n_leaders, skip_fraction=skip_fraction,
                      skip_batch=skip_batch, **mencius_kwargs),
        w.f_write) / alpha
    storm = storm.copy()
    storm[0, STATION_INDEX["leader"]] *= slow_factor
    return schedule_from_demands([healthy, storm, healthy],
                                 [0.0, start, stop], n_steps)


def spaxos_payload_ramp_schedule(
    alpha: float,
    payload_factors: Sequence[float] = (1.0, 2.0, 4.0, 8.0),
    n_steps: int = 4000,
    workload: Optional[Workload] = None,
    f_write: Optional[float] = None,
    **spaxos_kwargs,
) -> Tuple[np.ndarray, np.ndarray]:
    """S-Paxos payload-size ramp (paper section 7 dynamics).

    Each window scales payload-carrying messages by the next
    ``payload_factors`` entry via
    :func:`repro.core.analytical.spaxos_model`: the data path
    (disseminators, stabilizers, replicas) drains slower window by window
    while the id-ordering leader's demand stays exactly flat - the
    decoupling the protocol exists for, as dynamics.  Returns
    ``(demands[W, 1, K], step_bounds[W])`` for
    :func:`simulate_transient` (demands already divided by ``alpha``)."""
    if len(payload_factors) < 2:
        raise ValueError("need >= 2 payload windows to ramp")
    w = resolve_workload(workload, f_write,
                         where="spaxos_payload_ramp_schedule")
    windows = [
        _demand_row(spaxos_model(payload_factor=p, **spaxos_kwargs),
                    w.f_write) / alpha
        for p in payload_factors
    ]
    starts = [i / len(windows) for i in range(len(windows))]
    return schedule_from_demands(windows, starts, n_steps)


def resharding_schedule(
    base: np.ndarray,
    sharding: "ShardingSpec",
    start: float = 0.4,
    stop: float = 0.55,
    migration_factor: float = CRASH,
    n_steps: int = 4000,
    workload: Optional[Workload] = None,
    f_write: Optional[float] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Live resharding: split the hot shard in two, under load.

    Three windows over ``(n_shards + 1) * K`` flattened columns (the
    original shards plus the destination group, idle pre-split):

    1. ``[0, start)`` - steady state at the sharding's (skew-derived)
       weights; the destination shard carries zero demand.
    2. ``[start, stop)`` - the migration window: the hot shard freezes
       while its state streams out (``migration_factor`` multiplies its
       every station; the default :data:`CRASH` models a full
       stop-the-world handoff), so hot-partition traffic stalls and
       overall throughput dips.
    3. ``[stop, 1)`` - post-split: the hot shard's traffic is halved,
       the freed half served by the destination - the bottleneck law's
       ``min_s alpha/(w_s d_max)`` *rises*, so throughput recovers above
       its pre-split level.

    ``base`` is a single deployment's per-command demand row ([K] or
    [1, K]), already divided by ``alpha`` like the other schedule
    builders.  Returns ``(demands[3, 1, (S+1)*K], step_bounds[3])`` for
    :func:`simulate_transient`; replayed on the real cluster by
    ``tests/test_sharded_execution.py``, mirroring the PR-6 failover
    replay."""
    from .sharding import flatten_shards, shard_demands, split_weights
    if not 0.0 < start < stop < 1.0:
        raise ValueError(
            f"need 0 < start < stop < 1: start={start}, stop={stop}")
    w = resolve_workload(workload, f_write, where="resharding_schedule")
    row = _as_base(base)  # [1, K]
    pre_w, post_w, hot = split_weights(sharding, w)
    pre = flatten_shards(shard_demands(row, sharding, weights=pre_w))
    post = flatten_shards(shard_demands(row, sharding, weights=post_w))
    k = row.shape[1]
    mig = pre.copy()
    mig[:, hot * k:(hot + 1) * k] *= migration_factor
    return schedule_from_demands([pre, mig, post], [0.0, start, stop],
                                 n_steps)


def reconfiguration_schedule(
    windows: Sequence[np.ndarray],
    starts: Sequence[float],
    n_steps: int,
    *,
    actions: Sequence[Tuple[int, Union[str, int]]] = (),
    spike_factor: float = 1.5,
    spike_fraction: float = 0.25,
    extra_cuts: Sequence[float] = (),
) -> Tuple[np.ndarray, np.ndarray]:
    """An autoscale plan as a piecewise demand schedule, spikes included.

    ``windows[w]`` ([M, K] or [K]) holds from run fraction ``starts[w]``
    to the next start - the controller's post-action demand matrices
    (resized stations already rescaled by ``c0 / c``).  Each ``actions``
    entry ``(window, station)`` marks a resize landing at the start of
    that window: the marked demand is additionally multiplied by
    ``spike_factor`` during the first ``spike_fraction`` of the window
    (state transfer / warm-up traffic riding the reconfiguration, the
    ISS-style epoch-rotation cost).  ``station`` is a canonical station
    name, a raw column index (flattened shard columns), or ``None`` to
    spike the *whole row* - migration commands traverse every station of
    the pipeline, which is what the execution plane's warm phase
    (:func:`repro.core.execution.run_autoscaled`) actually replays.

    ``extra_cuts`` forces additional window boundaries (run fractions)
    even where no demand changes - lanes of a batched policy grid must
    share ONE ``step_bounds`` vector, so the union of every lane's cut
    fractions is passed to each lane's schedule.

    Composes through :func:`schedule_from_demands`; returns
    ``(demands[W', M, K], step_bounds[W'])`` for
    :func:`simulate_transient`."""
    if len(windows) != len(starts):
        raise ValueError(f"{len(windows)} windows vs {len(starts)} starts")
    if spike_factor < 1.0:
        raise ValueError(f"spike_factor must be >= 1: {spike_factor}")
    if not 0.0 <= spike_fraction <= 1.0:
        raise ValueError(
            f"spike_fraction must be in [0, 1]: {spike_fraction}")
    mats = [_as_base(m) for m in windows]
    base_starts = [float(s) for s in starts]
    ends = base_starts[1:] + [1.0]

    spans = []  # (spike_start, spike_stop, column)
    for w, station in actions:
        w = int(w)
        if not 0 <= w < len(mats):
            raise ValueError(
                f"action window {w} out of range for {len(mats)} windows")
        if station is None:
            col = None
        else:
            col = (STATION_INDEX[station] if isinstance(station, str)
                   else int(station))
            if not 0 <= col < mats[w].shape[1]:
                raise ValueError(
                    f"action column {col} out of range for K="
                    f"{mats[w].shape[1]}")
        lo = base_starts[w]
        hi = lo + spike_fraction * (ends[w] - lo)
        spans.append((lo, hi, col))

    cuts = set(base_starts)
    cuts.update(hi for _, hi, _ in spans if hi < 1.0)
    cuts.update(float(c) for c in extra_cuts if 0.0 <= float(c) < 1.0)
    refined = sorted(cuts)

    out = []
    for f in refined:
        w = max(i for i, s in enumerate(base_starts) if s <= f)
        mat = mats[w].copy()
        for lo, hi, col in spans:
            if lo <= f < hi:
                if col is None:
                    mat *= spike_factor
                else:
                    mat[:, col] *= spike_factor
        out.append(mat)
    return schedule_from_demands(out, refined, n_steps)


def region_partition_schedule(
    base: np.ndarray,
    model: DeploymentModel,
    geo: "Any",
    region: Union[str, int],
    start: float = 0.4,
    stop: float = 0.6,
    n_steps: int = 4000,
) -> Tuple[np.ndarray, np.ndarray]:
    """A whole region drops off the WAN during [start, stop), then heals.

    For each station with ``c`` servers of which ``m`` sit in the
    partitioned region (per the :class:`~repro.core.api.GeoSpec`'s
    placement cycles), the surviving ``c - m`` servers absorb the
    station's full traffic - demand per surviving server rises by
    ``c / (c - m)``.  A station entirely inside the region freezes
    (:data:`CRASH`) until the partition heals: that is the failure
    mode a ``single/<region>`` placement risks and a spread placement
    amortizes, so this schedule is how the placement autotuner's
    choices get stress-tested under faults.

    ``base`` is the deployment's per-command demand row ([K] or
    [1, K]) already divided by ``alpha``; ``model`` supplies the
    per-station server counts.  Returns ``(demands[W, M, K],
    step_bounds[W])`` for :func:`simulate_transient`."""
    if not 0.0 < start < stop < 1.0:
        raise ValueError(
            f"need 0 < start < stop < 1: start={start}, stop={stop}")
    if isinstance(region, str):
        r = list(geo.regions).index(region)
    else:
        r = int(region)
        if not 0 <= r < geo.n_regions:
            raise ValueError(
                f"region index {r} out of range for {geo.n_regions} regions")
    _, _, servers = model.demand_slots()
    events: List[Event] = []
    for k, c in enumerate(servers):
        if c <= 0:
            continue
        kind = STATION_ORDER[k]
        lost = sum(1 for i in range(c) if geo.region_of(kind, i) == r)
        if lost == 0:
            continue
        factor = CRASH if lost >= c else c / float(c - lost)
        events.append(Event(k, start, stop, factor))
    return build_schedule(base, events, n_steps)


# ---------------------------------------------------------------------------
# The jitted scan engine
# ---------------------------------------------------------------------------


def _routing(active: np.ndarray) -> Tuple[np.ndarray, np.ndarray]:
    """Per-deployment tandem routing over active stations.

    active: [M, K] bool.  Returns (entry[M], next_station[M, K]) where
    ``next_station[m, k] == K`` marks command completion after station k
    (inactive rows point to K too; they never host commands)."""
    m, k = active.shape
    entry = np.zeros(m, dtype=np.int32)
    nxt = np.full((m, k), k, dtype=np.int32)
    for i in range(m):
        idx = np.nonzero(active[i])[0]
        if idx.size == 0:
            raise ValueError(f"deployment row {i} has no active station")
        entry[i] = idx[0]
        nxt[i, idx[:-1]] = idx[1:]
    return entry, nxt


def _one_lane(demands_w, step_bounds, dt, entry, nxt, bin_edges, key,
              n_clients: int, n_steps: int, warmup_steps: int,
              n_bins: int, exponential: bool):
    """Simulate one (deployment, seed) lane.  demands_w: [W, K] seconds;
    dt/entry scalars; nxt: [K]; bin_edges: [n_bins + 1]."""
    n_windows, k = demands_w.shape
    if exponential:
        draws = jax.random.exponential(key, (n_steps + 1, k))
    else:
        draws = jnp.ones((n_steps + 1, k))

    finishes_at = nxt == k                     # station k completes commands
    arrive_at = jnp.where(finishes_at, entry, nxt)   # [K] ring routing

    stage0 = jnp.full((n_clients,), entry, dtype=jnp.int32)
    rank0 = jnp.arange(n_clients, dtype=jnp.int32)
    enter0 = jnp.zeros((n_clients,))
    q0 = jnp.zeros((k,), jnp.int32).at[entry].add(n_clients)
    work0 = jnp.zeros((k,)).at[entry].set(draws[0, entry])

    def step(state, xs):
        stage, rank, enter_t, q, work, done, lat_sum, hist, qsum = state
        i, draw_i = xs
        t_end = (i + 1).astype(work.dtype) * dt

        w = jnp.searchsorted(step_bounds, i, side="right") - 1
        d_now = demands_w[w]                                   # [K]
        # a window may zero an active station's demand ("free" service):
        # drain instantly rather than stall (still capped at one
        # completion per step, i.e. 1/dt per station)
        rate = jnp.where(d_now > 0, dt / jnp.maximum(d_now, 1e-30), 1e30)

        busy = q > 0
        work = jnp.where(busy, work - rate, work)
        complete = busy & (work <= 0.0)                        # [K]

        dep_here = complete[stage]                             # [N]
        moving = dep_here & (rank == 0)
        fin = moving & finishes_at[stage]                      # command done
        lat = t_end - enter_t
        rec = fin & (i >= warmup_steps)
        done = done + jnp.sum(rec)
        lat_sum = lat_sum + jnp.sum(jnp.where(rec, lat, 0.0))
        bins = jnp.clip(jnp.searchsorted(bin_edges, lat) - 1, 0, n_bins - 1)
        hist = hist.at[bins].add(rec.astype(jnp.int32))

        dest = arrive_at[stage]                                # [N]
        q_dep = q - complete.astype(q.dtype)
        stage_new = jnp.where(moving, dest, stage)
        enter_new = jnp.where(fin, t_end, enter_t)
        rank_new = jnp.where(
            moving, q_dep[dest],
            rank - (dep_here & (rank > 0)).astype(rank.dtype))
        arrivals = (jnp.zeros_like(q)
                    .at[arrive_at].add(complete.astype(q.dtype)))
        q_new = q_dep + arrivals
        # per-window queue-depth integral: the autoscale controller's
        # second signal (utilization says "how busy", queue depth says
        # "how far behind") - a [W, K] running sum is ~n_steps/W cheaper
        # to carry out of the scan than per-step queue traces
        qsum = qsum.at[w].add(q_new.astype(qsum.dtype))
        # new head enters service: carry the completion residual on a busy
        # server (unbiased long-run rate), fresh draw on an idle one
        fresh = (complete & (q_new > 0)) | (~busy & (arrivals > 0))
        work_new = jnp.where(
            fresh, draw_i + jnp.where(complete, work, 0.0), work)

        out_flow = jnp.sum(fin).astype(jnp.int32)
        return ((stage_new, rank_new, enter_new, q_new, work_new,
                 done, lat_sum, hist, qsum), out_flow)

    state0 = (stage0, rank0, enter0, q0, work0,
              jnp.asarray(0, jnp.int32), jnp.asarray(0.0),
              jnp.zeros((n_bins,), jnp.int32),
              jnp.zeros((n_windows, k)))
    xs = (jnp.arange(n_steps, dtype=jnp.int32), draws[1:])
    (_, _, _, _, _, done, lat_sum, hist, qsum), flows = jax.lax.scan(
        step, state0, xs)
    return flows, done, lat_sum, hist, qsum


@partial(jax.jit, static_argnames=("n_clients", "n_steps", "warmup_steps",
                                   "n_bins", "exponential"))
def _transient_batch(demands_w, step_bounds, dt, entry, nxt, bin_edges,
                     seeds, n_clients: int, n_steps: int, warmup_steps: int,
                     n_bins: int, exponential: bool):
    """vmap lanes: deployments (M) x seeds (S), one compiled call.

    demands_w: [W, M, K]; dt/entry: [M]; nxt: [M, K];
    bin_edges: [M, n_bins+1]; seeds: [S] int32.
    Returns (flows[M, S, n_steps] int32, done[M, S], lat_sum[M, S],
    hist[M, S, n_bins], qsum[M, S, W, K])."""
    keys = jax.vmap(lambda s: jax.random.fold_in(jax.random.key(0), s))(seeds)

    def per_deployment(d_w, dt_m, entry_m, nxt_m, edges_m):
        return jax.vmap(
            lambda key: _one_lane(d_w, step_bounds, dt_m, entry_m, nxt_m,
                                  edges_m, key, n_clients, n_steps,
                                  warmup_steps, n_bins, exponential))(keys)

    return jax.vmap(per_deployment, in_axes=(1, 0, 0, 0, 0))(
        demands_w, dt, entry, nxt, bin_edges)


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class TransientResult:
    """Batched transient run over M deployments x S seeds.

    ``flows[m, s, i]`` is completions during step i (dt[m] seconds each);
    scalar summaries are post-warmup.  Latency quantiles come from a
    log-spaced histogram (``hist``/``bin_edges``), so they are exact to
    within one bin width (~11% with the default 96 bins per 4 decades)."""

    dt: np.ndarray                 # [M] seconds per step
    flows: np.ndarray              # [M, S, n_steps] completions per step
    throughput: np.ndarray         # [M, S] post-warmup cmds/s
    latency_mean: np.ndarray       # [M, S] seconds
    latency_p50: np.ndarray        # [M, S] seconds
    latency_p99: np.ndarray        # [M, S] seconds
    completed: np.ndarray          # [M, S] post-warmup completions
    hist: np.ndarray               # [M, S, n_bins]
    bin_edges: np.ndarray          # [M, n_bins + 1]
    n_steps: int
    warmup_steps: int
    queue_sums: np.ndarray = None  # [M, S, W, K] per-window queue integral

    def throughput_trace(self, n_windows: int = 40
                         ) -> Tuple[np.ndarray, np.ndarray]:
        """Per-window throughput: (t_centers[M, n_windows] seconds,
        X[M, S, n_windows] cmds/s).  The transient figure primitive."""
        per = self.n_steps // n_windows
        used = per * n_windows
        f = self.flows[:, :, :used].reshape(
            self.flows.shape[0], self.flows.shape[1], n_windows, per)
        x = f.sum(axis=3) / (per * self.dt[:, None, None])
        centers = (np.arange(n_windows) + 0.5) * per * self.dt[:, None]
        return centers, x

    def window_throughput(self, step_bounds: np.ndarray,
                          settle: float = 0.3) -> np.ndarray:
        """Mean throughput per *schedule* window, [M, S, W] cmds/s.

        The first ``settle`` fraction of each window is excluded: after a
        demand change (or the cold start) the trace spends a few round
        trips draining backlog queued under the previous window's
        demands, and that transition would otherwise bias the window mean
        - reported per-window rates could even exceed the window's own
        bottleneck-law cap."""
        bounds = [int(b) for b in step_bounds] + [self.n_steps]
        out = []
        for w in range(len(bounds) - 1):
            lo, hi = bounds[w], bounds[w + 1]
            lo = min(lo + int((hi - lo) * settle), max(hi - 1, lo))
            out.append(self.flows[:, :, lo:hi].sum(axis=2)
                       / ((hi - lo) * self.dt[:, None]))
        return np.stack(out, axis=-1)

    def window_queue_depth(self, step_bounds: np.ndarray) -> np.ndarray:
        """Mean queue depth per *schedule* window and station,
        [M, S, W, K] commands - the controller's backlog signal.

        ``queue_sums[..., w, k]`` integrates station k's queue over every
        step of window w; dividing by the window's step count gives the
        time-average depth (waiters + the one in service).  Pass the same
        ``step_bounds`` the run was scheduled with."""
        if self.queue_sums is None:
            raise ValueError("this result carries no queue_sums surface")
        bounds = [int(b) for b in step_bounds] + [self.n_steps]
        steps = np.maximum(np.diff(np.asarray(bounds, dtype=np.float64)), 1.0)
        return self.queue_sums / steps[None, None, :, None]

    def seed_mean_throughput(self) -> np.ndarray:
        """[M] post-warmup throughput averaged over seeds."""
        return self.throughput.mean(axis=1)

    def seed_mean_p99(self) -> np.ndarray:
        """[M] p99 latency averaged over seeds."""
        return self.latency_p99.mean(axis=1)


def _quantile_from_hist(hist: np.ndarray, edges: np.ndarray, q: float
                        ) -> np.ndarray:
    """hist: [M, S, B]; edges: [M, B+1] (log-spaced).  Returns [M, S]
    latency at quantile q, log-interpolated inside the landing bin."""
    cum = hist.cumsum(axis=2)
    total = np.maximum(cum[:, :, -1], 1)
    target = q * total
    idx = np.minimum((cum < target[:, :, None]).sum(axis=2),
                     hist.shape[2] - 1)
    lo = np.take_along_axis(np.broadcast_to(edges[:, None, :-1], hist.shape),
                            idx[:, :, None], axis=2)[:, :, 0]
    hi = np.take_along_axis(np.broadcast_to(edges[:, None, 1:], hist.shape),
                            idx[:, :, None], axis=2)[:, :, 0]
    below = np.where(idx > 0,
                     np.take_along_axis(cum, np.maximum(idx - 1, 0)[:, :, None],
                                        axis=2)[:, :, 0], 0)
    inbin = np.maximum(
        np.take_along_axis(hist, idx[:, :, None], axis=2)[:, :, 0], 1)
    frac = np.clip((target - below) / inbin, 0.0, 1.0)
    return lo * (hi / lo) ** frac


def simulate_transient(
    demands: np.ndarray,
    step_bounds: Optional[np.ndarray] = None,
    *,
    n_clients: int = 64,
    seeds: Union[int, Sequence[int]] = 8,
    n_steps: int = 4000,
    dt: Optional[Union[float, np.ndarray]] = None,
    oversample: float = 4.0,
    exponential_service: bool = True,
    warmup_frac: float = 0.25,
    n_bins: int = 96,
) -> TransientResult:
    """Run the batched engine over a (possibly scheduled) demand tensor.

    demands: [W, M, K] piecewise windows (or [M, K] / [K] for a single
    steady window), in seconds per command per station - i.e. already
    divided by alpha, like :func:`simulator.mva_curves_from_demands`.
    ``step_bounds[w]`` is the first step of window w (from
    :func:`build_schedule` et al.); omitted = one window from step 0.
    ``seeds`` is a count or explicit list; every (deployment, seed) lane
    runs in ONE jitted call.  ``dt`` defaults per deployment to the
    window-0 bottleneck demand / ``oversample``."""
    d = np.asarray(demands, dtype=np.float64)
    if d.ndim == 1:
        d = d[None, :]
    if d.ndim == 2:
        d = d[None, :, :]
    if step_bounds is None:
        step_bounds = np.zeros((d.shape[0],), dtype=np.int32)
    step_bounds = np.asarray(step_bounds, dtype=np.int32)
    if step_bounds.shape[0] != d.shape[0]:
        raise ValueError(f"{d.shape[0]} windows vs "
                         f"{step_bounds.shape[0]} step bounds")
    if step_bounds[0] != 0:
        raise ValueError("step_bounds[0] must be 0 (the first window "
                         "covers the start of the run)")
    if np.any(np.diff(step_bounds) < 0):
        raise ValueError("step_bounds must be nondecreasing")
    _, m, k = d.shape

    active = d.max(axis=0) > 0                     # [M, K]
    entry, nxt = _routing(active)
    if dt is None:
        # resolve the *fastest* window's bottleneck: each station completes
        # at most once per step, so dt must stay below the smallest
        # per-window bottleneck demand (crash windows only raise the max,
        # so they never shrink dt)
        dt_arr = d.max(axis=2).min(axis=0) / oversample
    else:
        dt_arr = np.broadcast_to(np.asarray(dt, dtype=np.float64), (m,))
    if np.any(dt_arr <= 0):
        raise ValueError("dt must be positive (zero-demand window 0 row?)")

    # log-spaced latency bins: from half the fastest window's zero-load
    # round-trip up to the simulated horizon (the longest observable wait)
    rtt = np.maximum((d * active[None]).sum(axis=2).min(axis=0), 1e-12)
    lo = rtt * 0.5
    hi = np.maximum(n_steps * dt_arr, lo * 10.0)
    ratio = (hi / lo) ** (1.0 / n_bins)
    bin_edges = lo[:, None] * ratio[:, None] ** np.arange(n_bins + 1)[None, :]

    if isinstance(seeds, (int, np.integer)):
        seeds_arr = np.arange(int(seeds), dtype=np.int32)
    else:
        seeds_arr = np.asarray(list(seeds), dtype=np.int32)
    warmup_steps = int(n_steps * warmup_frac)

    flows, done, lat_sum, hist, qsum = _transient_batch(
        jnp.asarray(d), jnp.asarray(step_bounds), jnp.asarray(dt_arr),
        jnp.asarray(entry), jnp.asarray(nxt), jnp.asarray(bin_edges),
        jnp.asarray(seeds_arr), n_clients=n_clients, n_steps=n_steps,
        warmup_steps=warmup_steps, n_bins=n_bins,
        exponential=bool(exponential_service))
    flows = np.asarray(flows)
    done = np.asarray(done)
    lat_sum = np.asarray(lat_sum)
    hist = np.asarray(hist)
    qsum = np.asarray(qsum)

    measured = dt_arr[:, None] * (n_steps - warmup_steps)
    return TransientResult(
        dt=dt_arr,
        flows=flows,
        throughput=done / measured,
        latency_mean=lat_sum / np.maximum(done, 1),
        latency_p50=_quantile_from_hist(hist, bin_edges, 0.50),
        latency_p99=_quantile_from_hist(hist, bin_edges, 0.99),
        completed=done,
        hist=hist,
        bin_edges=bin_edges,
        n_steps=n_steps,
        warmup_steps=warmup_steps,
        queue_sums=qsum,
    )


def transient_throughput(model: DeploymentModel, alpha: float,
                         n_clients: int = 64,
                         workload: Optional[Workload] = None,
                         f_write: Optional[float] = None,
                         **kwargs) -> TransientResult:
    """Single-deployment convenience wrapper (M = 1): the transient
    engine's answer to :func:`simulator.mva_curve`'s steady state."""
    w = resolve_workload(workload, f_write, where="transient_throughput")
    d = demand_vector(model, w.f_write) / alpha
    return simulate_transient(d[None, :], n_clients=n_clients, **kwargs)
