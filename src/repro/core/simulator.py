"""JAX performance simulators for the protocol deployments.

Two engines, both deterministic:

* :func:`mva_curve` - exact Mean Value Analysis of the closed queueing
  network induced by a deployment's demand table (N closed-loop clients, one
  outstanding command each - exactly the paper's benchmark setup).  Written
  as a ``jax.lax.scan`` over the client count and ``vmap``-able over
  deployments, so one jitted call sweeps a whole latency-throughput figure
  (paper Fig. 28).

* :func:`fluid_curve` - a slot-stepped fluid simulation of the same network
  (service-rate-limited token buckets per station).  Independent dynamics
  from MVA; used as a cross-check and for transient experiments (e.g. what
  happens when a component is scaled mid-run).

Service demands come from :mod:`repro.core.analytical`; time units are
``1/alpha`` (one message's processing time).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import List, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .analytical import DeploymentModel


def demand_vector(model: DeploymentModel, f_write: float = 1.0) -> np.ndarray:
    """Per-station service demand of one command (units of 1/alpha)."""
    return np.array([s.demand(f_write) for s in model.stations], dtype=np.float64)


@partial(jax.jit, static_argnames=("n_max",))
def _mva_scan(demands: jnp.ndarray, think: jnp.ndarray, n_max: int):
    """Exact single-class MVA.

    demands: [K] per-station demand (already per-server / load-balanced).
    Returns (X[n_max], R[n_max]) for N = 1..n_max.
    """

    def step(q, n):
        r_k = demands * (1.0 + q)          # residence time per station
        r = jnp.sum(r_k)
        x = n / (think + r)                # closed-loop throughput
        q_new = x * r_k                    # Little's law per station
        return q_new, (x, r)

    q0 = jnp.zeros_like(demands)
    _, (xs, rs) = jax.lax.scan(step, q0, jnp.arange(1, n_max + 1, dtype=demands.dtype))
    return xs, rs


def mva_curve(model: DeploymentModel, alpha: float, n_clients_max: int = 512,
              f_write: float = 1.0, think: float = 0.0
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(clients, throughput cmds/s, median-ish latency seconds) curves."""
    d = jnp.asarray(demand_vector(model, f_write) / alpha)
    xs, rs = _mva_scan(d, jnp.asarray(think), n_clients_max)
    clients = np.arange(1, n_clients_max + 1)
    return clients, np.asarray(xs), np.asarray(rs)


def mva_curves_batch(models: Sequence[DeploymentModel], alpha: float,
                     n_clients_max: int = 512, f_write: float = 1.0
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """vmapped MVA over several deployments (padded to a common station
    count).  Returns (clients, X[m, N], R[m, N])."""
    ds = [demand_vector(m, f_write) / alpha for m in models]
    k = max(len(d) for d in ds)
    padded = np.stack([np.pad(d, (0, k - len(d))) for d in ds])
    xs, rs = jax.vmap(lambda d: _mva_scan(d, jnp.asarray(0.0), n_clients_max))(
        jnp.asarray(padded))
    return np.arange(1, n_clients_max + 1), np.asarray(xs), np.asarray(rs)


# ---------------------------------------------------------------------------
# Fluid (slot-stepped) simulation
# ---------------------------------------------------------------------------


@partial(jax.jit, static_argnames=("n_steps",))
def _fluid_scan(demands: jnp.ndarray, n_clients: jnp.ndarray, dt: jnp.ndarray,
                n_steps: int):
    """Pipeline fluid model.

    State: q[K] work queued at each station (in commands), plus a pool of
    clients with one outstanding command each.  Commands flow client ->
    station 0 -> ... -> station K-1 -> client.  Each station drains at rate
    1/demand_k per unit time (aggregate, demand already per-server).
    """
    k = demands.shape[0]

    def step(state, _):
        q, done = state
        # per-station service rate in commands per unit time
        rate = jnp.where(demands > 0, 1.0 / jnp.maximum(demands, 1e-12), jnp.inf)
        served = jnp.minimum(q, rate * dt)
        q = q - served
        # completions at last station return to the client pool and re-enter
        # station 0 instantly (closed loop, zero think time)
        inflow = jnp.concatenate([served[-1:], served[:-1]])
        q = q + inflow
        done = done + served[-1]
        return (q, done), served[-1]

    q0 = jnp.zeros((k,)).at[0].set(n_clients)
    (qf, done), flows = jax.lax.scan(step, (q0, jnp.asarray(0.0)), None,
                                     length=n_steps)
    return done, flows


def fluid_throughput(model: DeploymentModel, alpha: float, n_clients: int,
                     f_write: float = 1.0, sim_time: float = 1.0,
                     n_steps: int = 2000) -> float:
    """Steady-state throughput (cmds/s) of the fluid pipeline."""
    d = demand_vector(model, f_write) / alpha
    dt = sim_time / n_steps
    done, flows = _fluid_scan(jnp.asarray(d), jnp.asarray(float(n_clients)),
                              jnp.asarray(dt), n_steps)
    # measure over the second half (post-transient)
    half = n_steps // 2
    return float(np.asarray(flows)[half:].sum() / (dt * (n_steps - half)))


# ---------------------------------------------------------------------------
# Discrete-event cross-validation (numpy; exact FIFO multi-server queues)
# ---------------------------------------------------------------------------


def des_throughput(model: DeploymentModel, alpha: float, n_clients: int,
                   f_write: float = 1.0, n_commands: int = 20_000,
                   seed: int = 0, deterministic_service: bool = True
                   ) -> Tuple[float, float]:
    """Event-driven simulation of the closed network.  Returns
    (throughput cmds/s, mean latency s).  Cross-validates MVA/fluid."""
    import heapq

    rng = np.random.default_rng(seed)
    demands = demand_vector(model, f_write) / alpha  # seconds per station
    k = len(demands)
    servers = np.array([s.servers for s in model.stations])
    # each station: per-server demand d means one server finishes a command
    # in d*servers... demands are already per-server shares of the command;
    # total work per command at station = d * servers, split across servers.
    work = demands * servers

    free_at = [np.zeros(s) for s in servers]  # next-free time per server
    events: List[Tuple[float, int, int, int]] = []  # (time, seq, cmd, stage)
    seq = 0
    for c in range(n_clients):
        heapq.heappush(events, (0.0, seq, c, 0))
        seq += 1
    start = np.zeros(n_clients)
    done = 0
    total_latency = 0.0
    t = 0.0
    while done < n_commands and events:
        t, _, cmd, stage = heapq.heappop(events)
        if stage == 0:
            start[cmd] = t
        if stage == k:
            done += 1
            total_latency += t - start[cmd]
            heapq.heappush(events, (t, seq, cmd, 0))
            seq += 1
            continue
        svc = work[stage]
        if not deterministic_service:
            svc = rng.exponential(svc)
        i = int(np.argmin(free_at[stage]))
        begin = max(t, free_at[stage][i])
        finish = begin + svc
        free_at[stage][i] = finish
        heapq.heappush(events, (finish, seq, cmd, stage + 1))
        seq += 1
    throughput = done / t if t > 0 else 0.0
    return throughput, total_latency / max(done, 1)
