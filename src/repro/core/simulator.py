"""JAX performance simulators for the protocol deployments.

Two engines, both deterministic:

* :func:`mva_curve` - exact Mean Value Analysis of the closed queueing
  network induced by a deployment's demand table (N closed-loop clients, one
  outstanding command each - exactly the paper's benchmark setup).  Written
  as a ``jax.lax.scan`` over the client count and ``vmap``-able over
  deployments, so one jitted call sweeps a whole latency-throughput figure
  (paper Fig. 28).

* :func:`fluid_curve` - a slot-stepped fluid simulation of the same network
  (service-rate-limited token buckets per station).  Independent dynamics
  from MVA; used as a cross-check and for transient experiments (e.g. what
  happens when a component is scaled mid-run).

Service demands come from :mod:`repro.core.analytical`; time units are
``1/alpha`` (one message's processing time).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .analytical import DeploymentModel


def demand_vector(model: DeploymentModel, f_write: float = 1.0) -> np.ndarray:
    """Per-station service demand of one command (units of 1/alpha)."""
    return np.array([s.demand(f_write) for s in model.stations], dtype=np.float64)


def _mva_scan_impl(demands: jnp.ndarray, think: jnp.ndarray, n_max: int):
    """Exact single-class MVA.

    demands: [K] per-station demand (already per-server / load-balanced).
    Returns (X[n_max], R[n_max]) for N = 1..n_max.
    """

    def step(q, n):
        r_k = demands * (1.0 + q)          # residence time per station
        r = jnp.sum(r_k)
        x = n / (think + r)                # closed-loop throughput
        q_new = x * r_k                    # Little's law per station
        return q_new, (x, r)

    q0 = jnp.zeros_like(demands)
    _, (xs, rs) = jax.lax.scan(step, q0, jnp.arange(1, n_max + 1, dtype=demands.dtype))
    return xs, rs


_mva_scan = partial(jax.jit, static_argnames=("n_max",))(_mva_scan_impl)


@partial(jax.jit, static_argnames=("n_max",))
def _mva_scan_batch(demands: jnp.ndarray, think: jnp.ndarray, n_max: int):
    """Batched MVA: one compiled call over a [M, K] demand matrix.

    Zero-demand columns are inert (they add nothing to residence time), so
    heterogeneous deployments padded to a common K evaluate exactly as their
    unpadded selves.  Returns (X[M, n_max], R[M, n_max]).
    """
    return jax.vmap(lambda d: _mva_scan_impl(d, think, n_max))(demands)


def mva_curve(model: DeploymentModel, alpha: float, n_clients_max: int = 512,
              f_write: float = 1.0, think: float = 0.0
              ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """(clients, throughput cmds/s, median-ish latency seconds) curves."""
    d = jnp.asarray(demand_vector(model, f_write) / alpha)
    xs, rs = _mva_scan(d, jnp.asarray(think), n_clients_max)
    clients = np.arange(1, n_clients_max + 1)
    return clients, np.asarray(xs), np.asarray(rs)


def mva_curves_from_demands(demands: np.ndarray, n_clients_max: int = 512,
                            think: float = 0.0
                            ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batched MVA straight from a [M, K] demand matrix (units: seconds per
    command per station, i.e. already divided by alpha).  One jitted call
    regardless of M - this is the kernel the sweep engine drives with
    thousands of compiled configs at once.  Returns (clients, X[M, N], R[M, N])."""
    xs, rs = _mva_scan_batch(jnp.asarray(demands), jnp.asarray(think),
                             n_clients_max)
    return np.arange(1, n_clients_max + 1), np.asarray(xs), np.asarray(rs)


def _padded_demands(models: Sequence[DeploymentModel], alpha: float,
                    f_write: float) -> np.ndarray:
    """[M, K] demand matrix, padded to the widest station count."""
    ds = [demand_vector(m, f_write) / alpha for m in models]
    k = max(len(d) for d in ds)
    return np.stack([np.pad(d, (0, k - len(d))) for d in ds])


def mva_curves_batch(models: Sequence[DeploymentModel], alpha: float,
                     n_clients_max: int = 512, f_write: float = 1.0
                     ) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Batched MVA over several deployments (padded to a common station
    count), one jitted call.  Returns (clients, X[m, N], R[m, N])."""
    return mva_curves_from_demands(_padded_demands(models, alpha, f_write),
                                   n_clients_max)


# ---------------------------------------------------------------------------
# Fluid (slot-stepped) simulation
# ---------------------------------------------------------------------------


def _fluid_scan_impl(demands: jnp.ndarray, n_clients: jnp.ndarray,
                     dt: jnp.ndarray, n_steps: int):
    """Pipeline fluid model.

    State: q[K] work queued at each station (in commands), plus a pool of
    clients with one outstanding command each.  Commands flow client ->
    station 0 -> ... -> station K-1 -> client.  Each station drains at rate
    1/demand_k per unit time (aggregate, demand already per-server).
    """
    k = demands.shape[0]

    def step(state, _):
        q, done = state
        # per-station service rate in commands per unit time
        rate = jnp.where(demands > 0, 1.0 / jnp.maximum(demands, 1e-12), jnp.inf)
        served = jnp.minimum(q, rate * dt)
        q = q - served
        # completions at last station return to the client pool and re-enter
        # station 0 instantly (closed loop, zero think time)
        inflow = jnp.concatenate([served[-1:], served[:-1]])
        q = q + inflow
        done = done + served[-1]
        return (q, done), served[-1]

    q0 = jnp.zeros((k,)).at[0].set(n_clients)
    (qf, done), flows = jax.lax.scan(step, (q0, jnp.asarray(0.0)), None,
                                     length=n_steps)
    return done, flows


_fluid_scan = partial(jax.jit, static_argnames=("n_steps",))(_fluid_scan_impl)


@partial(jax.jit, static_argnames=("n_steps",))
def _fluid_scan_batch(demands: jnp.ndarray, n_clients: jnp.ndarray,
                      dt: jnp.ndarray, n_steps: int):
    """Batched fluid pipeline over a [M, K] demand matrix, one compiled call.

    Zero-demand stations serve at effectively infinite rate (see the
    ``jnp.where`` guard in the step), so canonical-slot padding is inert
    here too.  Returns (done[M], flows[M, n_steps])."""
    return jax.vmap(lambda d: _fluid_scan_impl(d, n_clients, dt, n_steps))(demands)


def fluid_throughput(model: DeploymentModel, alpha: float, n_clients: int,
                     f_write: float = 1.0, sim_time: float = 1.0,
                     n_steps: int = 2000) -> float:
    """Steady-state throughput (cmds/s) of the fluid pipeline."""
    d = demand_vector(model, f_write) / alpha
    dt = sim_time / n_steps
    done, flows = _fluid_scan(jnp.asarray(d), jnp.asarray(float(n_clients)),
                              jnp.asarray(dt), n_steps)
    # measure over the second half (post-transient)
    half = n_steps // 2
    return float(np.asarray(flows)[half:].sum() / (dt * (n_steps - half)))


def fluid_throughput_from_demands(demands: np.ndarray, n_clients: int,
                                  sim_time: float = 1.0, n_steps: int = 2000
                                  ) -> np.ndarray:
    """Batched fluid throughput (cmds/s) straight from a [M, K] demand
    matrix (seconds per command per station), one compiled call.
    Returns X[M]."""
    dt = sim_time / n_steps
    _, flows = _fluid_scan_batch(jnp.asarray(demands),
                                 jnp.asarray(float(n_clients)),
                                 jnp.asarray(dt), n_steps)
    half = n_steps // 2
    return np.asarray(flows)[:, half:].sum(axis=1) / (dt * (n_steps - half))


def fluid_throughput_batch(models: Sequence[DeploymentModel], alpha: float,
                           n_clients: int, f_write: float = 1.0,
                           sim_time: float = 1.0, n_steps: int = 2000
                           ) -> np.ndarray:
    """Steady-state fluid throughput (cmds/s) of several deployments in one
    compiled call.  Returns X[M]."""
    return fluid_throughput_from_demands(
        _padded_demands(models, alpha, f_write), n_clients, sim_time, n_steps)


# ---------------------------------------------------------------------------
# Discrete-event cross-validation (numpy; exact FIFO multi-server queues)
# ---------------------------------------------------------------------------


def des_throughput(model: DeploymentModel, alpha: float, n_clients: int,
                   f_write: float = 1.0, n_commands: int = 20_000,
                   seed: int = 0, deterministic_service: bool = True,
                   warmup_commands: Optional[int] = None
                   ) -> Tuple[float, float]:
    """Event-driven simulation of the closed network.  Returns
    (throughput cmds/s, mean latency s), both measured over a post-warmup
    window (the first ``warmup_commands`` completions - default 10% - are
    discarded, so the cold-start ramp where all N clients burst into
    station 0 at t=0 doesn't bias the steady-state estimate this function
    cross-validates against MVA/fluid and the transient engine)."""
    import heapq

    rng = np.random.default_rng(seed)
    if warmup_commands is None:
        warmup_commands = n_commands // 10
    demands = demand_vector(model, f_write) / alpha  # seconds per station
    k = len(demands)
    servers = np.array([s.servers for s in model.stations])
    # each station: per-server demand d means one server finishes a command
    # in d*servers... demands are already per-server shares of the command;
    # total work per command at station = d * servers, split across servers.
    work = demands * servers

    free_at = [np.zeros(s) for s in servers]  # next-free time per server
    events: List[Tuple[float, int, int, int]] = []  # (time, seq, cmd, stage)
    seq = 0
    for c in range(n_clients):
        heapq.heappush(events, (0.0, seq, c, 0))
        seq += 1
    start = np.zeros(n_clients)
    done = 0
    measured = 0
    total_latency = 0.0
    t = 0.0
    t_warm = 0.0
    while done < n_commands and events:
        t, _, cmd, stage = heapq.heappop(events)
        if stage == 0:
            start[cmd] = t
        if stage == k:
            done += 1
            if done <= warmup_commands:
                t_warm = t
            else:
                measured += 1
                total_latency += t - start[cmd]
            heapq.heappush(events, (t, seq, cmd, 0))
            seq += 1
            continue
        svc = work[stage]
        if not deterministic_service:
            svc = rng.exponential(svc)
        i = int(np.argmin(free_at[stage]))
        begin = max(t, free_at[stage][i])
        finish = begin + svc
        free_at[stage][i] = finish
        heapq.heappush(events, (finish, seq, cmd, stage + 1))
        seq += 1
    throughput = measured / (t - t_warm) if t > t_warm else 0.0
    return throughput, total_latency / max(measured, 1)
