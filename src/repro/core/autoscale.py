"""Elastic autoscaling: close the loop from measured load to live resize.

Every other plane picks one *static* configuration (the autotuner's
verdict) and holds it; production traffic breathes - diurnal cycles,
flash crowds, region outages.  This module adds the controller the
ROADMAP's last open tentpole asks for: an
:class:`~repro.core.api.AutoscalePolicy` (utilization band, hysteresis
guard, cooldown, per-station floors/ceilings, machine budget) driven by
a :class:`Controller` that watches the **transient engine's own measured
signals** - per-window throughput and the per-window queue-depth surface
(:meth:`~repro.core.transient.TransientResult.window_queue_depth`) - and
resizes stations one server at a time, with every resize paying a
modelled reconfiguration spike
(:func:`~repro.core.transient.reconfiguration_schedule`, the ISS-style
epoch-rotation cost).

How load breathes in a closed network
-------------------------------------
The engine is closed-loop with zero think time, which means a
population alone cannot carry a low-load signal: even a handful of
clients pin the bottleneck near 1 (``X(N)`` saturates at the tiny
population ``sum(d)/max(d)``).  The controller therefore splits its two
signals honestly.  *Utilization* is the utilization law
``u_k = lambda_w * d_k`` on the offered rate, anchored in the engine's
own units by ONE saturated probe of the initial provisioning
(``lambda_peak = peak_utilization x measured capacity`` - real
queueing included, not just ``1/max(d)``); it is exact, can exceed 1
under a flash crowd, and responds to every resize through ``d_k``.
*Queue depth, throughput and p99* are measured per window by
population-shaped probes (``round(n_peak * load[w] / max(load))``
clients) - one batched :func:`~repro.core.transient.simulate_transient`
call over ALL (config x policy) lanes per window, so a whole policy
grid shares each probe.  The final full-horizon replay uses the
complementary approximation the repo's burst machinery already uses
(offered load as a demand multiplier): the whole (policy x seed) grid,
actions lowered to one piecewise schedule with spikes, in ONE jitted
``lax.scan`` device call - that is the trace
:func:`repro.core.execution.run_autoscaled` parity-checks the real
cluster's dip/recovery shape against.

Why constant load converges (the hysteresis guard)
--------------------------------------------------
A drain is only taken when the *predicted* post-drain utilization
``u * c / (c - 1)`` stays at or under ``target_high``; an add requires
``u > target_high``.  After a drain, measured utilization can only land
at or below the prediction (the probe's throughput falls when demand
rises), so the inverse add can never trigger - counts move monotonically
until the band, a floor, or the guard stops them, and a constant-load
trace reaches zero actions.  ``tests/test_autoscale.py`` pins this
property, plus machine-time monotonicity in the band.

Entry points: :func:`autoscale_grid` (the batched (config x policy)
grid), :class:`Controller` (one policy, the scalar wrapper),
:meth:`repro.core.sweep.CompiledSweep.autoscale` (the compiled-grid
method), :func:`diurnal_load` / :func:`flash_crowd_load` (arrival
shapes), and :func:`repro.core.autotune.autotune_policy` (policy search
on the grid).
"""
from __future__ import annotations

from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

import numpy as np

from .api import (
    STATION_ORDER,
    AutoscalePolicy,
    Config,
    Workload,
    resolve_workload,
)
from .transient import (
    TransientResult,
    reconfiguration_schedule,
    simulate_transient,
)

__all__ = [
    "AutoscaleAction", "AutoscaleTrace", "Controller", "autoscale_grid",
    "diurnal_load", "flash_crowd_load",
]


# ---------------------------------------------------------------------------
# Arrival shapes
# ---------------------------------------------------------------------------


def diurnal_load(n_windows: int = 12, low: float = 0.25,
                 high: float = 1.0, phase: float = 0.0,
                 sharpness: float = 1.0) -> np.ndarray:
    """One diurnal cycle as per-window load multipliers, [W]: a raised
    cosine from ``low`` (trough) to ``high`` (peak), peak mid-run.
    ``sharpness > 1`` raises the cosine to a power - a narrower peak and
    a wider trough dwell, the shape real diurnal traffic has and the one
    that makes elasticity pay."""
    if n_windows < 2:
        raise ValueError(f"need >= 2 windows: {n_windows}")
    if not 0.0 < low <= high:
        raise ValueError(f"need 0 < low <= high: ({low}, {high})")
    if sharpness <= 0.0:
        raise ValueError(f"sharpness must be positive: {sharpness}")
    t = (np.arange(n_windows) + 0.5) / n_windows
    shape = 0.5 * (1.0 - np.cos(2.0 * np.pi * (t + phase)))
    return low + (high - low) * shape ** sharpness


def flash_crowd_load(n_windows: int = 12, base: float = 0.3,
                     peak: float = 1.0, start: float = 0.5,
                     width: float = 0.25) -> np.ndarray:
    """A flash crowd, [W]: steady ``base`` load with a sudden ``peak``
    plateau covering ``width`` of the run from fraction ``start``."""
    if n_windows < 2:
        raise ValueError(f"need >= 2 windows: {n_windows}")
    if not 0.0 < base <= peak:
        raise ValueError(f"need 0 < base <= peak: ({base}, {peak})")
    t = (np.arange(n_windows) + 0.5) / n_windows
    out = np.full(n_windows, float(base))
    out[(t >= start) & (t < start + width)] = float(peak)
    return out


# ---------------------------------------------------------------------------
# Trace types
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AutoscaleAction:
    """One resize decision: ``delta`` servers (+1 add / -1 drain) on
    ``station``, effective from control window ``window``; ``count`` is
    the post-action server count and ``utilization`` / ``queue_depth``
    the measured signals that triggered it."""

    window: int
    station: str
    column: int
    delta: int
    count: int
    utilization: float
    queue_depth: float

    def describe(self) -> str:
        verb = "add" if self.delta > 0 else "drain"
        return (f"w{self.window}: {verb} {self.station} -> {self.count} "
                f"(u={self.utilization:.2f}, q={self.queue_depth:.1f})")


def _slice_lane(res: TransientResult, m: int) -> TransientResult:
    """Row-select one lane (M = 1) of a batched TransientResult."""
    sl = slice(m, m + 1)
    return replace(res, dt=res.dt[sl], flows=res.flows[sl],
                   throughput=res.throughput[sl],
                   latency_mean=res.latency_mean[sl],
                   latency_p50=res.latency_p50[sl],
                   latency_p99=res.latency_p99[sl],
                   completed=res.completed[sl], hist=res.hist[sl],
                   bin_edges=res.bin_edges[sl],
                   queue_sums=(None if res.queue_sums is None
                               else res.queue_sums[sl]))


@dataclass(frozen=True)
class AutoscaleTrace:
    """One lane's closed-loop autoscale run: what the controller saw,
    what it did, and what it cost.

    Window metrics (``utilization``/``queue_depth``/``throughput``/
    ``p99``) are *measured* per control window off the population-shaped
    probes; ``counts[w]`` is the provisioning in effect during window w
    and ``machine_time`` its integral in machine x run-fraction units
    (multiply by the wall horizon for machine-hours; a static deployment
    of ``m`` machines scores exactly ``m``).  ``result`` is the lane's
    slice of the final batched full-horizon replay
    (:func:`~repro.core.transient.reconfiguration_schedule` demands over
    ``step_bounds``), whose dip/recovery shape the execution plane
    parity-checks."""

    policy: Optional[AutoscalePolicy]
    stations: Tuple[str, ...]      # [K] column names
    servers0: np.ndarray           # [K] initial provisioning
    load: np.ndarray               # [W] offered-load multipliers
    population: np.ndarray         # [W] probe client populations
    counts: np.ndarray             # [W, K] servers in effect per window
    actions: Tuple[AutoscaleAction, ...]
    utilization: np.ndarray        # [W, K] u = lambda_w * d (anchored)
    queue_depth: np.ndarray        # [W, K] mean queue depth (probe)
    throughput: np.ndarray         # [W] probe seed-mean cmds/s
    p99: np.ndarray                # [W] probe seed-mean p99 seconds
    machines: np.ndarray           # [W] total servers per window
    machine_time: float            # machine x run-fraction integral
    result: TransientResult        # full-horizon replay, M = 1
    step_bounds: np.ndarray        # [W'] replay schedule bounds (steps)
    replay_window: np.ndarray      # [W'] control window per replay window
    replay_spike: np.ndarray       # [W'] bool: reconfiguration spike seg
    label: str = ""

    @property
    def n_windows(self) -> int:
        return len(self.load)

    @property
    def peak_machines(self) -> int:
        return int(self.machines.max())

    def peak_p99(self) -> float:
        """Worst window p99 - the "at equal p99" comparison point (quiet
        windows are trivially fast; the peak window is what provisioning
        is for)."""
        return float(self.p99.max())

    def replay_rates(self) -> np.ndarray:
        """Seed-mean replay throughput per replay window, [W']."""
        return self.result.window_throughput(self.step_bounds)[0].mean(axis=0)

    def predicted_dip(self, window: int) -> Optional[float]:
        """The transient prediction of the resize dip at control window
        ``window``: replay throughput during the reconfiguration spike
        segment over throughput during the rest of the same window (same
        load multiplier, so the ratio isolates the spike).  None when the
        window has no spike segment."""
        here = self.replay_window == window
        spike = here & self.replay_spike
        post = here & ~self.replay_spike
        if not spike.any() or not post.any():
            return None
        rates = self.replay_rates()
        denom = float(rates[post].mean())
        return float(rates[spike].mean()) / max(denom, 1e-12)

    def plan(self) -> Tuple[Dict[str, Any], ...]:
        """The action sequence as plain data - the contract the JAX-free
        execution plane (:func:`repro.core.execution.run_autoscaled`)
        replays: ``{"window", "station", "delta"}`` per resize."""
        return tuple({"window": a.window, "station": a.station,
                      "delta": a.delta} for a in self.actions)

    def describe(self) -> str:
        pol = self.policy.describe() if self.policy else "static"
        acts = "; ".join(a.describe() for a in self.actions) or "no actions"
        return (f"{self.label or 'lane'} [{pol}]: "
                f"machine_time {self.machine_time:.2f} "
                f"(static would be {int(self.servers0.sum())}), "
                f"peak p99 {self.peak_p99():.3e}s; {acts}")


# ---------------------------------------------------------------------------
# The control loop
# ---------------------------------------------------------------------------


def _mva_population(d: np.ndarray, u_target: float, cap: int = 2048) -> int:
    """Smallest closed-loop population driving the bottleneck of demand
    row ``d`` to utilization ``u_target``, by the exact MVA recursion
    (R_k(n) = d_k (1 + q_k(n-1)), X = n / sum R, q = X R).  This anchors
    the load schedule in absolute terms: ``load[w] = 1`` means "offered
    load that fills the *initial* provisioning to ``u_target``"."""
    d = np.asarray(d, dtype=np.float64)
    d = d[d > 0.0]
    if d.size == 0:
        return 1
    d_max = float(d.max())
    q = np.zeros(d.size)
    for n in range(1, cap + 1):
        r = d * (1.0 + q)
        x = n / r.sum()
        q = x * r
        if x * d_max >= u_target:
            return n
    return cap


def _decide(policy: AutoscalePolicy, u: np.ndarray, q: np.ndarray,
            counts: np.ndarray, active: np.ndarray, eligible: np.ndarray,
            names: Sequence[str]) -> List[Tuple[int, int]]:
    """One window's resize decisions for one lane: a list of
    ``(column, delta)``.  Stations scale *independently* - the paper's
    claim, taken literally: each eligible station may gain or lose one
    server per window, so a ramp can restore the bottleneck tier while
    the same window still drains a cold one.  Drains come first
    (coldest-first), freeing budget for adds (hottest-first); when the
    machine budget binds, the coldest pending adds are dropped.  Only
    ``eligible`` stations (live-resizable on the execution plane) are
    action candidates; ``active`` stations all contribute signals and
    machine accounting."""
    adds: List[Tuple[float, int]] = []
    drains: List[Tuple[float, int]] = []
    for k in np.nonzero(eligible)[0]:
        c = int(counts[k])
        over = u[k] > policy.target_high
        backlog = (policy.queue_high > 0.0
                   and q[k] / c > policy.queue_high)
        if over or backlog:
            hi = policy.max_for(names[k])
            if hi is None or c < hi:
                adds.append((float(u[k]), int(k)))
            continue
        if c <= max(1, policy.min_for(names[k])):
            continue
        if u[k] >= policy.target_low:
            continue
        # the hysteresis guard: never drain when the predicted post-drain
        # utilization u * c / (c - 1) would leave the band upward
        if u[k] * c / (c - 1) > policy.target_high:
            continue
        drains.append((float(u[k]), int(k)))
    moves = [(k, -1) for _, k in sorted(drains)]
    total = int(counts[active].sum()) - len(moves)
    for _, k in sorted(adds, reverse=True):
        if (policy.machine_budget is not None
                and total + 1 > policy.machine_budget):
            break
        total += 1
        moves.append((k, 1))
    return moves


def autoscale_grid(
    bases: np.ndarray,
    servers: np.ndarray,
    policies: Sequence[Optional[AutoscalePolicy]],
    load: np.ndarray,
    *,
    n_clients: Optional[int] = None,
    peak_utilization: float = 0.9,
    seeds: Union[int, Sequence[int]] = 2,
    probe_steps: int = 800,
    n_steps: int = 4000,
    exponential_service: bool = False,
    station_names: Optional[Sequence[str]] = None,
    labels: Optional[Sequence[str]] = None,
    resizable: Optional[Sequence[Optional[Sequence[str]]]] = None,
    probe_kwargs: Optional[Dict[str, Any]] = None,
) -> List[AutoscaleTrace]:
    """Run the closed autoscale loop over a (config x policy) lane grid.

    ``bases[l]`` is lane *l*'s effective per-server demand row ([K]
    seconds, already divided by alpha) at its initial provisioning
    ``servers[l]``; ``policies[l]`` is its
    :class:`~repro.core.api.AutoscalePolicy` (``None`` freezes the lane:
    the static baseline every headline compares against).  ``load[w]``
    is window *w*'s offered-load multiplier.

    The load schedule needs an absolute anchor: ``load = max(load)``
    means "``peak_utilization`` of the initial provisioning's *measured*
    capacity" (one saturated probe anchors ``lambda_peak`` per lane),
    and when ``n_clients`` is None the probe population is calibrated to
    match by the exact MVA recursion.  Per window, ONE batched probe
    (:func:`simulate_transient` over all lanes, population
    ``round(n_peak * load[w] / max(load))``) measures queue depth,
    throughput and p99, while utilization is the utilization law
    ``load[w] * lambda_peak * d`` on the current counts (see the module
    docstring for why the split); each policy then resizes every
    triggered station by at most one server - stations scale
    independently - effective next window (scaling a station from ``c``
    to ``c'`` servers rescales its per-server demand by ``c / c'``).  After the horizon, every lane's action plan is lowered
    to one :func:`~repro.core.transient.reconfiguration_schedule` on a
    shared window grid and the whole (lane x seed) batch replays in ONE
    jitted device call - the policy-search shape
    :meth:`~repro.core.sweep.CompiledSweep.autoscale` exposes."""
    bases = np.atleast_2d(np.asarray(bases, dtype=np.float64))
    servers0 = np.atleast_2d(np.asarray(servers)).astype(np.int64)
    if servers0.shape != bases.shape:
        raise ValueError(
            f"servers shape {servers0.shape} != bases shape {bases.shape}")
    n_lanes, k = bases.shape
    if len(policies) != n_lanes:
        raise ValueError(f"{len(policies)} policies for {n_lanes} lanes")
    load = np.asarray(load, dtype=np.float64)
    if load.ndim != 1 or load.size < 2:
        raise ValueError("load must be a [W >= 2] multiplier vector")
    if np.any(load <= 0.0):
        raise ValueError("load multipliers must be positive")
    if station_names is None:
        names: Tuple[str, ...] = tuple(
            STATION_ORDER[i] if i < len(STATION_ORDER) else f"col{i}"
            for i in range(k))
    else:
        names = tuple(str(s) for s in station_names)
        if len(names) != k:
            raise ValueError(f"{len(names)} station names for K={k}")
    labels = (tuple(labels) if labels is not None
              else ("",) * n_lanes)
    if resizable is not None and len(resizable) != n_lanes:
        raise ValueError(
            f"{len(resizable)} resizable entries for {n_lanes} lanes")
    pk = dict(probe_kwargs or {})

    w_count = load.size
    load_norm = load / load.max()
    if not 0.0 < peak_utilization <= 1.0:
        raise ValueError(
            f"peak_utilization must be in (0, 1]: {peak_utilization}")
    if n_clients is None:
        n_clients = max(_mva_population(bases[lane], peak_utilization)
                        for lane in range(n_lanes))
    n_clients = int(n_clients)
    population = np.maximum(
        np.round(n_clients * load_norm).astype(int), 1)
    active = (servers0 > 0) & (bases > 0)
    eligible = active.copy()
    if resizable is not None:
        for lane, allowed in enumerate(resizable):
            if allowed is None:
                continue
            allow = set(str(s) for s in allowed)
            for col, nm in enumerate(names):
                if nm not in allow:
                    eligible[lane, col] = False

    # Anchor the offered rate in the engine's own units: one saturated
    # probe of the initial provisioning measures each lane's capacity
    # (real queueing included - not just 1/d_max), and "load = 1.0"
    # means peak_utilization of THAT.  A closed zero-think-time network
    # pins its bottleneck near 1 at any population, so utilization must
    # come from the utilization law u = lambda * d on this measured
    # anchor; queue depth / throughput / p99 stay per-window probe
    # measurements, where population genuinely moves them.
    n_cap = max(_mva_population(bases[lane], 0.995)
                for lane in range(n_lanes))
    d0 = np.where(active, bases, 0.0)
    cap_probe = simulate_transient(
        d0, n_clients=n_cap, seeds=seeds, n_steps=probe_steps,
        exponential_service=exponential_service, **dict(probe_kwargs or {}))
    lam_peak = peak_utilization * cap_probe.seed_mean_throughput()  # [L]
    counts = np.where(active, servers0, 0).astype(np.int64)

    counts_hist = np.zeros((w_count, n_lanes, k), dtype=np.int64)
    util = np.zeros((w_count, n_lanes, k))
    qdepth = np.zeros((w_count, n_lanes, k))
    xput = np.zeros((w_count, n_lanes))
    p99 = np.zeros((w_count, n_lanes))
    cooldown = np.zeros(n_lanes, dtype=np.int64)
    lane_actions: List[List[AutoscaleAction]] = [[] for _ in range(n_lanes)]

    for w in range(w_count):
        counts_hist[w] = counts
        with np.errstate(invalid="ignore"):
            d = np.where(active, bases * servers0 / np.maximum(counts, 1),
                         0.0)
        probe = simulate_transient(
            d, n_clients=int(population[w]), seeds=seeds,
            n_steps=probe_steps, exponential_service=exponential_service,
            **pk)
        x = probe.seed_mean_throughput()                      # [L]
        q = probe.window_queue_depth(
            np.zeros(1, dtype=np.int32))[:, :, 0, :].mean(axis=1)  # [L, K]
        util[w] = (load_norm[w] * lam_peak)[:, None] * d
        qdepth[w] = q
        xput[w] = x
        p99[w] = probe.seed_mean_p99()
        if w == w_count - 1:
            break  # a decision here could only land beyond the horizon
        for lane in range(n_lanes):
            policy = policies[lane]
            if policy is None:
                continue
            if cooldown[lane] > 0:
                cooldown[lane] -= 1
                continue
            moves = _decide(policy, util[w, lane], qdepth[w, lane],
                            counts[lane], active[lane], eligible[lane],
                            names)
            if not moves:
                continue
            for col, delta in moves:
                counts[lane, col] += delta
                lane_actions[lane].append(AutoscaleAction(
                    window=w + 1, station=names[col], column=col,
                    delta=delta, count=int(counts[lane, col]),
                    utilization=float(util[w, lane, col]),
                    queue_depth=float(qdepth[w, lane, col])))
            cooldown[lane] = policy.cooldown_windows

    # ---- one batched full-horizon replay over every lane ----
    starts = [w / w_count for w in range(w_count)]
    cuts: set = set()
    for lane in range(n_lanes):
        policy = policies[lane]
        if policy is None or policy.spike_fraction <= 0.0:
            continue
        for a in lane_actions[lane]:
            # bit-identical to reconfiguration_schedule's own span cut,
            # so every lane lands on the same refined window grid
            lo = starts[a.window]
            end = starts[a.window + 1] if a.window + 1 < w_count else 1.0
            cut = lo + policy.spike_fraction * (end - lo)
            if cut < 1.0:
                cuts.add(cut)
    extra = sorted(cuts)

    scheds, bounds = [], None
    for lane in range(n_lanes):
        policy = policies[lane]
        with np.errstate(invalid="ignore"):
            rows = [np.where(active[lane],
                             load_norm[w] * bases[lane] * servers0[lane]
                             / np.maximum(counts_hist[w, lane], 1),
                             0.0)[None, :]
                    for w in range(w_count)]
        sched, b = reconfiguration_schedule(
            rows, starts, n_steps,
            # one epoch rebuild per action window, however many stations
            # it resizes - so one whole-row spike per distinct window
            actions=[(wd, None)
                     for wd in sorted({a.window
                                       for a in lane_actions[lane]})],
            spike_factor=(policy.spike_factor if policy else 1.0),
            spike_fraction=(policy.spike_fraction if policy else 0.0),
            extra_cuts=extra)
        scheds.append(sched)
        if bounds is None:
            bounds = b
        elif not np.array_equal(bounds, b):
            raise RuntimeError("lanes disagree on the shared window grid")
    demands = np.concatenate(scheds, axis=1)          # [W', L, K]
    replay = simulate_transient(
        demands, bounds, n_clients=n_clients, seeds=seeds, n_steps=n_steps,
        exponential_service=exponential_service)

    refined = sorted(set(starts) | cuts)
    base_bounds = np.asarray([round(s * n_steps) for s in starts])
    replay_window = (np.searchsorted(base_bounds, bounds, side="right")
                     - 1).astype(np.int64)

    traces: List[AutoscaleTrace] = []
    for lane in range(n_lanes):
        policy = policies[lane]
        spike = np.zeros(len(refined), dtype=bool)
        if policy is not None and policy.spike_fraction > 0.0:
            for a in lane_actions[lane]:
                # same arithmetic as the cut generation above, so the
                # spike-end boundary compares exactly equal
                lo = starts[a.window]
                end = (starts[a.window + 1] if a.window + 1 < w_count
                       else 1.0)
                hi = lo + policy.spike_fraction * (end - lo)
                for j, f in enumerate(refined):
                    if lo <= f < hi:
                        spike[j] = True
        machines = counts_hist[:, lane, :].sum(axis=1).astype(np.float64)
        traces.append(AutoscaleTrace(
            policy=policy,
            stations=names,
            servers0=servers0[lane].copy(),
            load=load.copy(),
            population=population.copy(),
            counts=counts_hist[:, lane, :].copy(),
            actions=tuple(lane_actions[lane]),
            utilization=util[:, lane, :].copy(),
            queue_depth=qdepth[:, lane, :].copy(),
            throughput=xput[:, lane].copy(),
            p99=p99[:, lane].copy(),
            machines=machines,
            machine_time=float(machines.mean()),
            result=_slice_lane(replay, lane),
            step_bounds=np.asarray(bounds).copy(),
            replay_window=replay_window.copy(),
            replay_spike=spike,
            label=labels[lane]))
    return traces


class Controller:
    """One policy's closed loop - the scalar wrapper around
    :func:`autoscale_grid` (which see for the probe/replay mechanics).

    ``run`` consumes raw demand rows (the sweep plane's currency);
    ``run_config`` starts from a registered-variant config dict, deriving
    the per-server demand row and initial provisioning from the
    variant's own analytical model - so any registry variant autoscales
    with zero edits here."""

    def __init__(self, policy: AutoscalePolicy) -> None:
        if not isinstance(policy, AutoscalePolicy):
            raise TypeError(f"Controller needs an AutoscalePolicy, got "
                            f"{type(policy).__name__}")
        self.policy = policy

    def run(self, base: np.ndarray, servers: np.ndarray, load: np.ndarray,
            **kwargs: Any) -> AutoscaleTrace:
        """Close the loop over one lane: ``base`` [K] per-server demand
        seconds (already / alpha) at provisioning ``servers`` [K]."""
        return autoscale_grid(np.asarray(base)[None, :],
                              np.asarray(servers)[None, :],
                              [self.policy], load, **kwargs)[0]

    def run_config(self, config: Config, load: np.ndarray, *, alpha: float,
                   workload: Optional[Union[Workload, float]] = None,
                   **kwargs: Any) -> AutoscaleTrace:
        """Close the loop over one registered-variant config: demand row
        and server counts come from the variant's analytical model, and
        actions are restricted to the stations the execution plane can
        live-resize (:func:`repro.core.execution.resizable_stations` -
        the registry-derived knob map), so the emitted plan replays on a
        real cluster via :func:`~repro.core.execution.run_autoscaled`
        without translation.  Pass ``resizable=[None]`` to lift the
        restriction for purely analytical exploration."""
        from .execution import resizable_stations
        from .sweep import config_variant, model_for
        w = resolve_workload(workload, where="Controller.run_config")
        model = model_for(dict(config), w)
        d_w, d_r, servers = model.demand_slots()
        k = len(STATION_ORDER)
        row = (w.f_write * np.asarray(d_w[:k], dtype=np.float64)
               + (1.0 - w.f_write) * np.asarray(d_r[:k], dtype=np.float64))
        variant = config_variant(config)
        kwargs.setdefault("labels", [variant])
        kwargs.setdefault("resizable",
                          [resizable_stations(variant, config)])
        return self.run(row / alpha, np.asarray(servers[:k]), load, **kwargs)
