"""The shard axis: lowering sharded systems onto the existing planes.

A sharded system is N independent replicated groups, each owning a hash
partition of the key space (:class:`~repro.core.api.ShardingSpec`).  This
module holds the plane-agnostic machinery:

* **demand lowering** - a sharded deployment's demand tensor is the
  per-command table scaled by each shard's traffic fraction:
  ``d[m, s, k] = w_s * d[m, k]`` (a random command visits shard *s*'s
  stations with probability ``w_s`` - standard probabilistic-routing
  visit ratios).  Flattening the ``[M, S, K]`` tensor to ``[M, S*K]``
  lets the *unchanged* jitted MVA / fluid / transient paths evaluate
  per-shard station loads in the same single device call; the row max
  recovers the min-law ``T = min_s alpha / (w_s * max_k d[k])``.
* **routing helpers** - largest-remainder integer splits of command /
  client budgets by shard weight, and the flattened column index of a
  (shard, station) pair for transient event targeting.
* **history partitioning** - linearizability is *local*: a KV history is
  linearizable iff every per-key sub-history is (Herlihy & Wing's
  locality theorem; keys are independent objects).  The same holds for
  any coarser grouping of keys, so per-shard checks are both sound and
  complete.  :func:`partition_history` builds the sub-histories and
  :func:`check_linearizable_partitioned` runs the decomposed check.

Import discipline: numpy + stdlib only (NO JAX) - ``execution.py``
imports this module and is itself stitched into the jax-free synthetic
package used by ``scripts/check_docs_links.py``.
"""
from __future__ import annotations

from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import numpy as np

from .api import ShardingSpec, Workload
from .history import History, Operation
from .linearizability import check_linearizable

__all__ = [
    "shard_weights",
    "split_counts",
    "shard_demands",
    "flatten_shards",
    "shard_column",
    "split_weights",
    "op_key",
    "partition_ops",
    "partition_history",
    "check_linearizable_partitioned",
]


# ---------------------------------------------------------------------------
# weights + demand lowering
# ---------------------------------------------------------------------------


def shard_weights(sharding: ShardingSpec,
                  workload: Optional[Workload] = None) -> np.ndarray:
    """Per-shard traffic fractions as a float vector summing to 1."""
    return np.asarray(sharding.resolved_weights(workload), dtype=np.float64)


def split_counts(total: int, weights: Sequence[float]) -> np.ndarray:
    """Split ``total`` items into integer per-shard counts proportional to
    ``weights`` (largest-remainder method, so the counts sum exactly to
    ``total`` and no positive weight is starved below its floor)."""
    w = np.asarray(weights, dtype=np.float64)
    if w.ndim != 1 or w.size == 0:
        raise ValueError(f"weights must be a non-empty vector: {w!r}")
    w = w / w.sum()
    exact = w * int(total)
    base = np.floor(exact).astype(np.int64)
    rem = int(total) - int(base.sum())
    if rem > 0:
        order = np.argsort(-(exact - base), kind="stable")
        base[order[:rem]] += 1
    return base


def shard_demands(demands: np.ndarray, sharding: ShardingSpec,
                  workload: Optional[Workload] = None,
                  weights: Optional[Sequence[float]] = None) -> np.ndarray:
    """Expand a per-command demand table ``[..., K]`` to the sharded
    tensor ``[..., S, K]`` with ``out[..., s, k] = w_s * demands[..., k]``.

    Each shard is an independent copy of the deployment that sees only
    its traffic fraction, so per *global* command its stations do ``w_s``
    times the per-command work - visit-ratio scaling, which is exactly
    what the MVA and transient engines expect of a demand column."""
    d = np.asarray(demands, dtype=np.float64)
    w = (np.asarray(weights, dtype=np.float64) if weights is not None
         else shard_weights(sharding, workload))
    w = w / w.sum()
    return d[..., None, :] * w[:, None]


def flatten_shards(demands: np.ndarray) -> np.ndarray:
    """Collapse the shard axis of ``[..., S, K]`` into ``[..., S*K]`` so
    the tensor flows through the existing jitted single-deployment paths
    (shard *s*'s station *k* lands in column ``s*K + k``)."""
    d = np.asarray(demands, dtype=np.float64)
    if d.ndim < 2:
        raise ValueError(f"expected [..., S, K], got shape {d.shape}")
    return d.reshape(*d.shape[:-2], d.shape[-2] * d.shape[-1])


def shard_column(shard: int, station: int, n_stations: int) -> int:
    """Flattened column index of station ``station`` (an int slot index)
    on shard ``shard`` - the address space transient ``Event``s target
    after :func:`flatten_shards`."""
    if not 0 <= station < n_stations:
        raise ValueError(
            f"station index {station} outside [0, {n_stations})")
    return shard * n_stations + station


def split_weights(sharding: ShardingSpec,
                  workload: Optional[Workload] = None,
                  ) -> Tuple[np.ndarray, np.ndarray, int]:
    """Weights before/after a hot-shard split, for resharding schedules.

    Returns ``(pre, post, hot)`` over ``S + 1`` lanes: the original
    ``S`` shards plus one destination shard that carries no traffic
    before the split.  After the split the hot shard's traffic is halved,
    the freed half landing on the destination - the canonical "split the
    hot shard in two" rebalancing event."""
    w = shard_weights(sharding, workload)
    hot = int(np.argmax(w))
    pre = np.concatenate([w, [0.0]])
    post = pre.copy()
    post[hot] = w[hot] / 2.0
    post[-1] = w[hot] / 2.0
    return pre, post, hot


# ---------------------------------------------------------------------------
# op routing + history partitioning
# ---------------------------------------------------------------------------


def op_key(op: Tuple) -> Any:
    """The state-machine key an operation addresses (``("put", k, v)`` /
    ``("get", k)`` -> ``k``); None for key-less ops (register r/w)."""
    return op[1] if len(op) > 1 and op[0] in ("put", "get") else None


def partition_ops(ops: Sequence[Tuple], sharding: ShardingSpec,
                  ) -> Dict[int, List[Tuple]]:
    """Route a flat op list to shards by key hash.  Key-less ops all land
    on shard 0 (a register has a single implicit key)."""
    parts: Dict[int, List[Tuple]] = {s: [] for s in range(sharding.n_shards)}
    for op in ops:
        key = op_key(op)
        shard = sharding.shard_of(key) if key is not None else 0
        parts[shard].append(op)
    return parts


def _sub_history(ops: Sequence[Operation]) -> History:
    """A History over an op subset, preserving ids and timestamps.

    ``History.respond`` indexes ``ops[op_id]``, so sub-histories must be
    assembled by assigning ``.ops`` directly - replaying invoke/respond
    would renumber the ops."""
    h = History()
    h.ops = list(ops)
    h._next = (max(o.op_id for o in ops) + 1) if ops else 0
    return h


def partition_history(history: History,
                      part_of: Callable[[Any], Any]) -> Dict[Any, History]:
    """Partition a history by ``part_of(key)`` (e.g. ``sharding.shard_of``
    for per-shard groups, ``lambda k: k`` for per-key groups).  Key-less
    ops go to partition ``None``."""
    groups: Dict[Any, List[Operation]] = {}
    for o in history.ops:
        key = op_key(o.op)
        part = part_of(key) if key is not None else None
        groups.setdefault(part, []).append(o)
    return {part: _sub_history(ops) for part, ops in groups.items()}


def check_linearizable_partitioned(history: History,
                                   part_of: Optional[Callable] = None,
                                   sm_kind: str = "kv",
                                   max_nodes: int = 2_000_000) -> bool:
    """Decomposed linearizability: check each key partition separately.

    By locality this accepts exactly the histories the whole-history
    checker accepts (each key is an independent object; a grouping of
    keys composes per-key linearizations), but the exhaustive search is
    exponential in the *partition* size, not the history size.  Default
    partition is per-key; pass ``part_of=sharding.shard_of`` for
    per-shard groups."""
    part = part_of if part_of is not None else (lambda key: key)
    return all(
        check_linearizable(sub, sm_kind=sm_kind, max_nodes=max_nodes)
        for sub in partition_history(history, part).values())
