"""The generic execution harness: run any registered variant's *real*
cluster, check linearizability, and parity-check measured message counts
against the analytical demand table - two planes, one registry.

The paper's evidence for "compartmentalization is a technique, not a
protocol" is dual: message-count tables derived analytically *and* real
protocol executions that agree with them.  This module makes that
cross-validation loop a first-class call.  A variant whose
:class:`~repro.core.api.VariantSpec` declares an
:class:`~repro.core.api.ExecutableSpec` (its ``deployment`` factory takes
the **same canonical config dict** as its analytical factory) gets, with
zero edits to this file:

* :func:`run_variant` - drive the deployment with ``Workload``-shaped
  closed-loop traffic (write fraction, key skew, batched arrivals through
  the variant's own batchers), collect the operation history, run the
  linearizability checker, and bucket measured per-station messages per
  command into the *same* :data:`~repro.core.api.STATION_ORDER` slots the
  demand tensors use;
* :func:`validate_variant` - an analytical-vs-measured parity report per
  station (exact where the executable declares it - S-Paxos' leader is
  exactly 2 id-only msgs/cmd - within declared tolerance elsewhere);
* :func:`repro.core.analytical.calibrate_alpha` ``(measured=True)`` - the
  25k anchor derived from an executed vanilla run instead of a constant.

``benchmarks/protocol_messages.py`` is one zero-branch loop over
:func:`~repro.core.api.executable_variants` calling
:func:`validate_variant`; the per-variant physics (address -> station
bucketing, measured-parameter feedback such as Mencius' observed skip
rate, tolerances) lives in the registered :class:`ExecutableSpec`, as
data.

The built-in executables for all six shipped variants are registered at
the bottom of this module; runtime variants attach theirs with
:func:`~repro.core.api.register_executable` (or directly in
``register_variant(executable=...)``) and ride the same calls.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field, replace
from typing import Any, Dict, List, Optional, Tuple, Union

from .api import (
    Config,
    ExecutableSpec,
    STATION_ORDER,
    ShardingSpec,
    Workload,
    executable_variants,
    register_executable,
    resolve_workload,
    variant_spec,
)
from .craq import CraqDeployment
from .geo import predict_geo_latency
from .history import History
from .linearizability import check_linearizable, check_slot_order
from .mencius import MenciusDeployment, VanillaMenciusDeployment
from .protocols import (
    CompartmentalizedMultiPaxos,
    DeploymentConfig,
    UnreplicatedStateMachine,
)
from .sharding import partition_history, partition_ops
from .spaxos import SPaxosDeployment, VanillaSPaxosDeployment

__all__ = [
    "AutoscaledExecutionTrace", "ExecutionTrace", "ParityReport",
    "ShardedDeployment", "ShardedExecutionTrace", "ShardedParityReport",
    "StationParity", "default_config", "resizable_stations",
    "resize_config", "run_autoscaled", "run_sharded", "run_variant",
    "station_knob_map", "validate_sharded", "validate_variant",
    "workload_ops",
]


# ---------------------------------------------------------------------------
# Workload-shaped op streams
# ---------------------------------------------------------------------------


def workload_ops(workload: Workload, n_commands: int, seed: int = 0,
                 n_cold_keys: int = 4) -> List[Tuple]:
    """A deterministic op stream shaped by a :class:`Workload`: exactly
    ``round(n_commands * f_write)`` writes, shuffled; skewed ops
    (probability ``skew_p``) target the single hot key, the rest a small
    shared cold key space (shared keys keep the linearizability check
    non-vacuous when the stream is split across concurrent clients)."""
    rng = random.Random(seed * 0x9E3779B1 + 1)
    n_writes = round(n_commands * workload.f_write)
    writes = [True] * n_writes + [False] * (n_commands - n_writes)
    rng.shuffle(writes)
    ops: List[Tuple] = []
    for i, is_write in enumerate(writes):
        hot = workload.skew_p > 0.0 and rng.random() < workload.skew_p
        key = "hot" if hot else f"k{rng.randrange(n_cold_keys)}"
        ops.append(("put", key, i) if is_write else ("get", key))
    return ops


# ---------------------------------------------------------------------------
# ExecutionTrace: one measured run
# ---------------------------------------------------------------------------


@dataclass
class ExecutionTrace:
    """One executed, measured, checked run of a variant's deployment.

    ``station_msgs`` is measured (sent + received) messages per command
    **per server**, keyed by canonical station name - the same unit and
    vocabulary as ``DeploymentModel.demands``; server counts come from the
    variant's own demand table for the same config (for fused-role
    baselines like vanilla MultiPaxos the model's "machine" aggregates
    several deployment nodes).  ``station_totals`` / ``station_nodes``
    keep the raw accounting."""

    variant: str
    config: Config
    workload: Workload
    n_commands: int
    seed: int
    deployment: Any
    history: History
    station_msgs: Dict[str, float]
    station_totals: Dict[str, int]
    station_servers: Dict[str, int]
    station_nodes: Dict[str, int]
    steps: int
    linearizable: bool
    checker: str
    violations: Tuple[str, ...] = ()
    # geo plane (run_variant(geo=...)): the active spec, the client count
    # the latency_fn split clients by, and measured mean client latency
    # (virtual time units) per region - overall and per op class - with
    # the realized (writes, reads) counts behind each mean
    geo: Optional[Any] = None
    geo_n_clients: int = 0
    region_latency: Dict[str, float] = field(default_factory=dict)
    region_write_latency: Dict[str, float] = field(default_factory=dict)
    region_read_latency: Dict[str, float] = field(default_factory=dict)
    region_ops: Dict[str, Tuple[int, int]] = field(default_factory=dict)

    @property
    def n_writes(self) -> int:
        return sum(1 for o in self.history.ops if not o.is_read)

    @property
    def n_reads(self) -> int:
        return self.n_commands - self.n_writes

    def demand_slots(self) -> List[float]:
        """Measured per-server msgs/cmd scattered into the canonical
        :data:`STATION_ORDER` columns (zero where the deployment has no
        such component) - directly comparable to a compiled sweep row."""
        row = [0.0] * len(STATION_ORDER)
        for name, d in self.station_msgs.items():
            row[STATION_ORDER.index(name)] += d
        return row

    def describe(self) -> str:
        pairs = ", ".join(f"{s} {d:.2f}" for s, d in self.station_msgs.items())
        return (f"{self.variant}: {self.n_commands} cmds "
                f"({self.n_writes} writes) in {self.steps} deliveries; "
                f"msgs/cmd/server: {pairs}; "
                f"linearizable={self.linearizable} ({self.checker})")


def _check_history(history: History, sm_kind: str = "kv",
                   exhaustive_limit: int = 24,
                   ) -> Tuple[bool, str, Tuple[str, ...]]:
    """Linearizability verdict: exhaustive Wing-Gong search on small
    histories (ground truth), the paper's slot-order check on large ones
    (cheap, sound for slot-stamped histories).  A large history with no
    slot stamps at all (CRAQ: versions are per-key, so responses carry no
    global log position) would make the slot-order check vacuously true -
    those fall back to the exhaustive search too, which closed-loop
    histories keep cheap (branching bounded by the client count)."""
    stamped = any(o.slot is not None for o in history.complete())
    if len(history) <= exhaustive_limit or not stamped:
        ok = check_linearizable(history, sm_kind)
        return ok, "exhaustive", () if ok else ("no linearization found",)
    violations = tuple(check_slot_order(history))
    return not violations, "slot_order", violations


def _check_history_partitioned(history: History, sm_kind: str = "kv",
                               exhaustive_limit: int = 24,
                               ) -> Tuple[bool, str, Tuple[str, ...]]:
    """Per-key-partition linearizability: KV keys are independent objects,
    so by Herlihy & Wing's locality theorem a history is linearizable iff
    every per-key sub-history is - the decomposition accepts *exactly* the
    histories the whole-history checker accepts while keeping the
    exhaustive search exponential only in per-key concurrency.  Each
    partition still picks its checker by size via :func:`_check_history`
    (key-less histories fall into one partition = the whole check)."""
    parts = partition_history(history, lambda key: key)
    for part, sub in sorted(parts.items(), key=lambda kv: str(kv[0])):
        ok, checker, violations = _check_history(
            sub, sm_kind=sm_kind, exhaustive_limit=exhaustive_limit)
        if not ok:
            return False, f"per_key[{part}]/{checker}", violations
    return True, "per_key_partition", ()


def default_config(name: str, f: int = 1) -> Config:
    """The variant's default-knob config dict (the first point of its
    declared knob product) - what :func:`run_variant` uses when no config
    is given."""
    return next(iter(variant_spec(name).configs(f=f)))


def _executable_of(name: str) -> ExecutableSpec:
    spec = variant_spec(name)
    if spec.executable is None:
        raise ValueError(
            f"variant {name!r} declares no execution plane; executable "
            f"variants: {list(executable_variants())} (attach one with "
            f"register_executable)")
    return spec.executable


def _build_deployment(exe: ExecutableSpec, cfg: Config, n_clients: int,
                      seed: int, state_machine: str,
                      latency_fn: Optional[Any] = None) -> Any:
    """Instantiate the executable's deployment and zero message counters
    (setup traffic such as Phase 1 is not part of the per-command cost).
    ``latency_fn`` (a GeoSpec matrix realization) is only forwarded when
    set, so executables registered before the geo plane keep working."""
    build_cfg = {k: v for k, v in cfg.items() if k != "variant"}
    if latency_fn is not None:
        build_cfg["latency_fn"] = latency_fn
    dep = exe.deployment(**build_cfg, n_clients=n_clients, seed=seed,
                         state_machine=state_machine)
    for node in dep.net.nodes.values():
        node.msgs_sent = 0
        node.msgs_received = 0
    return dep


def _assign_ops(dep: Any, ops: List[Tuple]) -> None:
    """Split an op stream round-robin across a deployment's closed-loop
    clients."""
    per_client: List[List[Tuple]] = [[] for _ in dep.clients]
    for i, op in enumerate(ops):
        per_client[i % len(per_client)].append(op)
    for client, client_ops in zip(dep.clients, per_client):
        if client_ops:
            client.run_ops(client_ops)


def _drive(name: str, dep: Any, max_steps: int) -> int:
    steps = dep.run_to_quiescence(max_steps=max_steps)
    if not dep.all_done():
        stuck = [c.addr for c in dep.clients if not c.done]
        raise RuntimeError(
            f"run_variant({name!r}): clients {stuck} not done after "
            f"{steps} deliveries (max_steps={max_steps})")
    return steps


def _station_msgs(spec: Any, exe: ExecutableSpec, dep: Any,
                  servers: Dict[str, int], n_commands: int,
                  ) -> Tuple[Dict[str, float], Dict[str, int],
                             Dict[str, int], Dict[str, int]]:
    """Bucket measured (sent + received) messages into canonical station
    slots, per command per server."""
    totals: Dict[str, int] = {}
    nodes: Dict[str, int] = {}
    for addr, node in dep.net.nodes.items():
        if exe.station_of is not None:
            station = exe.station_of(addr, dep)
        else:
            role = addr.split("/", 1)[0]
            station = role if role in spec.stations else None
        if station is None:
            continue
        totals[station] = totals.get(station, 0) + (node.msgs_sent
                                                    + node.msgs_received)
        nodes[station] = nodes.get(station, 0) + 1
    denom = max(n_commands, 1)
    msgs = {
        station: total / denom / servers.get(station, nodes[station])
        for station, total in totals.items()
    }
    stations_present = {s: servers.get(s, nodes[s]) for s in totals}
    return msgs, totals, stations_present, nodes


def _measured_region_latency(history: History, geo: Any, n_clients: int,
                             ) -> Tuple[Dict[str, float], Dict[str, float],
                                        Dict[str, float],
                                        Dict[str, Tuple[int, int]]]:
    """Mean measured client latency per region (blended, write, read)
    plus the realized (writes, reads) counts: client ``i`` sits in
    ``geo.client_region(i, n_clients)``, its latency is the virtual-time
    span between invocation and response."""
    sums: Dict[str, List[float]] = {}
    for o in history.complete():
        r = geo.regions[geo.client_region(o.client_id, n_clients)]
        acc = sums.setdefault(r, [0.0, 0, 0.0, 0])  # [w_sum, w_n, r_sum, r_n]
        d = o.response_time - o.invoke_time
        if o.is_read:
            acc[2] += d
            acc[3] += 1
        else:
            acc[0] += d
            acc[1] += 1
    blended: Dict[str, float] = {}
    writes: Dict[str, float] = {}
    reads: Dict[str, float] = {}
    counts: Dict[str, Tuple[int, int]] = {}
    for r, (ws, wn, rs, rn) in sums.items():
        counts[r] = (wn, rn)
        blended[r] = (ws + rs) / (wn + rn)
        if wn:
            writes[r] = ws / wn
        if rn:
            reads[r] = rs / rn
    return blended, writes, reads, counts


def _trace_of(name: str, cfg: Config, w: Workload, dep: Any,
              n_commands: int, seed: int, steps: int,
              exhaustive_limit: int, state_machine: str,
              per_key: bool = False, geo: Optional[Any] = None,
              geo_n_clients: int = 0) -> ExecutionTrace:
    """Measure + check one driven deployment into an ExecutionTrace.

    ``per_key=True`` decomposes the linearizability check by key
    partition (sound *and* complete by locality - see
    :func:`repro.core.sharding.partition_history`)."""
    spec = variant_spec(name)
    exe = _executable_of(name)
    model = spec.model(cfg, w)  # server counts + station sanity check
    servers = {s.name: s.servers for s in model.stations}
    msgs, totals, stations_present, nodes = _station_msgs(
        spec, exe, dep, servers, n_commands)
    if per_key:
        ok, checker, violations = _check_history_partitioned(
            dep.history, sm_kind=state_machine,
            exhaustive_limit=exhaustive_limit)
    else:
        ok, checker, violations = _check_history(
            dep.history, sm_kind=state_machine,
            exhaustive_limit=exhaustive_limit)
    blended: Dict[str, float] = {}
    wlat: Dict[str, float] = {}
    rlat: Dict[str, float] = {}
    rops: Dict[str, Tuple[int, int]] = {}
    if geo is not None:
        blended, wlat, rlat, rops = _measured_region_latency(
            dep.history, geo, geo_n_clients)
    return ExecutionTrace(
        variant=name, config=cfg, workload=w, n_commands=n_commands,
        seed=seed, deployment=dep, history=dep.history, station_msgs=msgs,
        station_totals=totals, station_servers=stations_present,
        station_nodes=nodes, steps=steps, linearizable=ok, checker=checker,
        violations=violations, geo=geo, geo_n_clients=geo_n_clients,
        region_latency=blended, region_write_latency=wlat,
        region_read_latency=rlat, region_ops=rops)


def run_variant(name: str,
                config: Optional[Config] = None,
                workload: Optional[Union[Workload, float]] = None,
                n_commands: int = 60,
                seed: int = 0,
                n_clients: Optional[int] = None,
                max_steps: int = 2_000_000,
                exhaustive_limit: int = 24,
                jitter: float = 0.0,
                state_machine: str = "kv",
                geo: Optional[Any] = None) -> ExecutionTrace:
    """Execute one config of a registered variant end to end.

    Builds the deployment from the variant's :class:`ExecutableSpec`,
    zeroes message counters (setup traffic such as Phase 1 is not part of
    the per-command cost), splits a :func:`workload_ops` stream
    round-robin across the closed-loop clients, runs the network to
    quiescence, checks linearizability, and buckets measured per-station
    msgs/cmd into canonical station slots.  Generic over the registry:
    zero per-variant branches here.

    ``geo`` (a :class:`~repro.core.api.GeoSpec`) realizes the WAN matrix
    through the network's ``latency_fn`` hook: every message pays
    ``local_delay + one_way(region(src), region(dst))``, timers stay
    local, ``jitter`` stacks on top.  The trace then carries measured
    per-region client latency (``region_latency`` et al.) - the measured
    side of the latency parity rows ``validate_variant(geo=...)`` adds."""
    exe = _executable_of(name)
    cfg = dict(config) if config is not None else default_config(name)
    w = resolve_workload(workload, where="run_variant")
    n_cl = n_clients if n_clients is not None else exe.n_clients

    latency_fn = geo.latency_fn(n_cl) if geo is not None else None
    dep = _build_deployment(exe, cfg, n_cl, seed, state_machine,
                            latency_fn=latency_fn)
    if jitter:
        # reorder messages across links (seeded): linearizability must
        # hold regardless; message-count parity is unaffected (counts,
        # not timings)
        dep.net.jitter = jitter

    op_mix = replace(w, f_write=1.0) if exe.reads_as_writes else w
    ops = workload_ops(op_mix, n_commands, seed=seed)
    _assign_ops(dep, ops)
    steps = _drive(name, dep, max_steps)
    return _trace_of(name, cfg, w, dep, n_commands, seed, steps,
                     exhaustive_limit, state_machine, geo=geo,
                     geo_n_clients=n_cl)


# ---------------------------------------------------------------------------
# Parity: measured vs analytical, one generic loop
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StationParity:
    """One station's measured-vs-analytical comparison."""

    station: str
    measured: float
    predicted: float
    rel_err: float
    tolerance: float
    exact: bool
    ok: bool

    def describe(self) -> str:
        tag = "exact" if self.exact else f"tol {self.tolerance:g}"
        mark = "ok" if self.ok else "FAIL"
        return (f"{self.station} {self.measured:.3f}/{self.predicted:.3f} "
                f"({tag}: {mark})")


@dataclass
class ParityReport:
    """Analytical-vs-measured msgs/cmd parity for one executed config.

    ``passed`` requires every station row within its declared tolerance
    *and* the execution's history linearizable."""

    variant: str
    config: Config
    model_config: Config
    workload: Workload
    rows: Tuple[StationParity, ...]
    trace: ExecutionTrace

    @property
    def stations_ok(self) -> bool:
        return all(r.ok for r in self.rows)

    @property
    def passed(self) -> bool:
        return self.stations_ok and self.trace.linearizable

    def row(self, station: str) -> StationParity:
        for r in self.rows:
            if r.station == station:
                return r
        raise KeyError(f"no parity row for station {station!r}; have "
                       f"{[r.station for r in self.rows]}")

    def max_rel_err(self) -> float:
        return max((r.rel_err for r in self.rows), default=0.0)

    def summary(self) -> str:
        pairs = ", ".join(
            f"{r.station} {r.measured:.2f}/{r.predicted:.2f}"
            for r in self.rows)
        verdict = "parity OK" if self.passed else "PARITY FAIL"
        return (f"{verdict}: measured/modelled msgs per cmd per server: "
                f"{pairs}; linearizable={self.trace.linearizable} "
                f"({self.trace.checker})")

    def __str__(self) -> str:
        lines = [f"{self.variant} @ {self.workload.describe()}: "
                 f"{'PASS' if self.passed else 'FAIL'}"]
        lines += [f"  {r.describe()}" for r in self.rows]
        if not self.trace.linearizable:
            lines.append(f"  NOT LINEARIZABLE ({self.trace.checker}): "
                         f"{list(self.trace.violations)}")
        return "\n".join(lines)


def validate_variant(name: str,
                     config: Optional[Config] = None,
                     workload: Optional[Union[Workload, float]] = None,
                     n_commands: int = 60,
                     seed: int = 0,
                     **run_kwargs: Any) -> ParityReport:
    """Execute a variant's deployment and parity-check its measured
    per-station msgs/cmd against its analytical demand table.

    The model side is the registered factory on the *same* config -
    workload-adapted exactly as the sweep plane would
    (``VariantSpec.adapt``), then refined by the executable's
    ``model_feedback`` with statistics measured off this very run (e.g.
    Mencius' observed skip rate), so the comparison is apples-to-apples.
    One generic loop; every per-variant fact is declared data in the
    :class:`ExecutableSpec`.

    Passing ``geo=`` (forwarded to :func:`run_variant`) additionally
    emits one ``wan_latency/<region>`` row per client-bearing region:
    measured mean client latency against the critical-path prediction of
    :func:`repro.core.geo.predict_geo_latency`, blended at the region's
    *realized* write mix and judged by the executable's registered
    ``latency_tolerance``."""
    cfg = dict(config) if config is not None else default_config(name)
    w = resolve_workload(workload, where="validate_variant")
    trace = run_variant(name, cfg, w, n_commands=n_commands, seed=seed,
                        **run_kwargs)
    rows, model_cfg = _parity_rows(name, cfg, w, trace)
    if trace.geo is not None:
        rows += _geo_latency_rows(name, cfg, trace)
    return ParityReport(variant=name, config=cfg, model_config=model_cfg,
                        workload=w, rows=tuple(rows), trace=trace)


def _geo_latency_rows(name: str, cfg: Config, trace: ExecutionTrace,
                      ) -> List[StationParity]:
    """Measured-vs-predicted per-region latency rows (the latency
    analogue of the msgs/cmd parity rows).

    The prediction blends the critical-path write/read latencies at each
    region's *realized* op counts, so the comparison is not polluted by
    how the round-robin op split happened to land per region.  Variants
    whose read path rides the write path (``reads_as_writes``) were
    driven write-only, so the blend degenerates to the write path."""
    exe = _executable_of(name)
    geo = trace.geo
    predicted = predict_geo_latency(
        dict(cfg, variant=name), geo, n_clients=trace.geo_n_clients)
    rows: List[StationParity] = []
    for i, region in enumerate(geo.regions):
        counts = trace.region_ops.get(region)
        if not counts:
            continue
        wn, rn = counts
        pred = (wn * predicted.write[i] + rn * predicted.read[i]) / (wn + rn)
        m = trace.region_latency[region]
        rel = abs(m - pred) / max(abs(pred), 1e-12)
        tol = exe.latency_tolerance
        rows.append(StationParity(
            station=f"wan_latency/{region}", measured=m, predicted=pred,
            rel_err=rel, tolerance=tol, exact=False, ok=rel <= tol))
    return rows


def _parity_rows(name: str, cfg: Config, w: Workload, trace: ExecutionTrace,
                 ) -> Tuple[List[StationParity], Config]:
    """The measured-vs-table station rows for one executed trace.

    The table is blended at the *realized* write fraction of the executed
    op stream (exact mix up to rounding), so parity is not polluted by
    the generator's rounding of ``f_write * n_commands`` - nor, for a
    shard, by the hash split's per-shard mix.  Shared by
    :func:`validate_variant` and the per-shard loop of
    :func:`validate_sharded` (shard-scaled tables: per-shard msgs per
    *shard-local* command against the same per-command table)."""
    spec = variant_spec(name)
    exe = _executable_of(name)
    model_cfg = spec.adapt(cfg, w)
    if exe.model_feedback is not None:
        model_cfg = exe.model_feedback(dict(model_cfg), trace)
    realized = replace(w, f_write=trace.n_writes / max(trace.n_commands, 1))
    predicted = spec.build(model_cfg).demands(realized)

    stations = list(trace.station_msgs)
    stations += [s for s, d in predicted.items()
                 if s not in trace.station_msgs and d > 0.0]
    rows = []
    for station in sorted(stations, key=STATION_ORDER.index):
        m = trace.station_msgs.get(station, 0.0)
        p = predicted.get(station, 0.0)
        exact = station in exe.exact_stations
        tol = exe.tolerance_for(station)
        rel = abs(m - p) / max(abs(p), 1e-12)
        ok = abs(m - p) <= 1e-9 if exact else rel <= tol
        rows.append(StationParity(station=station, measured=m, predicted=p,
                                  rel_err=rel, tolerance=tol, exact=exact,
                                  ok=ok))
    return rows, model_cfg


# ---------------------------------------------------------------------------
# Sharded execution: N independent variant groups behind hash routing
# ---------------------------------------------------------------------------


class ShardedDeployment:
    """N independent registered-variant groups behind hash-based
    client-side routing.

    Each shard is a full deployment of the variant (its own network,
    clients, history), built from the *same* canonical config dict the
    analytical factory consumes - or per-shard configs, e.g. an
    :func:`~repro.core.autotune.autotune_sharded` split.  Keys route by
    ``sharding.shard_of`` (stable crc32); shards never exchange messages,
    which is what makes per-shard parity and per-key-partition
    linearizability sound (no cross-shard transaction path exists - by
    locality, per-shard checks compose).

    The per-shard networks have independent virtual clocks.  ``submit`` +
    ``run_to_quiescence`` is the whole-run flow (:func:`run_sharded`);
    live scenarios (the resharding replay) instead advance shards in
    lockstep phases via ``step_all(until=...)`` and measure completion
    deltas at phase boundaries."""

    def __init__(self, name: str, sharding: ShardingSpec,
                 config: Optional[Config] = None,
                 configs: Optional[List[Config]] = None,
                 n_clients: Optional[int] = None, seed: int = 0,
                 state_machine: str = "kv") -> None:
        exe = _executable_of(name)
        if configs is not None:
            if len(configs) != sharding.n_shards:
                raise ValueError(
                    f"{len(configs)} per-shard configs for "
                    f"{sharding.n_shards} shards")
            cfgs = [dict(c) for c in configs]
        else:
            base = dict(config) if config is not None else default_config(name)
            cfgs = [dict(base) for _ in range(sharding.n_shards)]
        self.name = name
        self.sharding = sharding
        self.configs: Tuple[Config, ...] = tuple(cfgs)
        self.seed = seed
        self.state_machine = state_machine
        n_cl = n_clients if n_clients is not None else exe.n_clients
        # distinct per-shard seeds: shards are independent systems, not
        # replicas of one seed
        self.shards: List[Any] = [
            _build_deployment(exe, cfg, n_cl, seed * 1009 + s, state_machine)
            for s, cfg in enumerate(cfgs)
        ]
        self.ops_per_shard: List[int] = [0] * sharding.n_shards

    def __len__(self) -> int:
        return len(self.shards)

    def route(self, key: Any) -> int:
        """The shard that owns ``key`` (stable hash routing)."""
        return self.sharding.shard_of(key)

    def submit(self, ops: List[Tuple]) -> Dict[int, List[Tuple]]:
        """Route an op stream to shards by key hash and assign each
        shard's slice round-robin to its closed-loop clients."""
        parts = partition_ops(ops, self.sharding)
        for s, shard_ops in parts.items():
            if shard_ops:
                _assign_ops(self.shards[s], shard_ops)
                self.ops_per_shard[s] += len(shard_ops)
        return parts

    def run_to_quiescence(self, max_steps: int = 2_000_000) -> List[int]:
        """Drain every shard's network; per-shard delivery counts."""
        return [_drive(self.name, dep, max_steps) for dep in self.shards]

    def step_all(self, until: float,
                 skip: Tuple[int, ...] = ()) -> None:
        """Advance every shard's virtual clock to ``until`` (lockstep
        phase boundary), except shards listed in ``skip`` - how a live
        replay freezes the migrating shard while the others serve."""
        for s, dep in enumerate(self.shards):
            if s not in skip:
                dep.net.run(until=until)

    def all_done(self) -> bool:
        return all(dep.all_done() for dep in self.shards)

    @property
    def histories(self) -> List[History]:
        return [dep.history for dep in self.shards]

    def completed_counts(self) -> List[int]:
        """Responses observed so far, per shard - delta these across phase
        boundaries to get completion rates without comparing timestamps
        across the shards' independent clocks."""
        return [len(dep.history.complete()) for dep in self.shards]


@dataclass
class ShardedExecutionTrace:
    """One executed, measured, checked run of a sharded system.

    ``shards[s]`` is shard *s*'s own :class:`ExecutionTrace` (station
    msgs per *shard-local* command, per-key-partition linearizability
    verdict); a shard that received no ops carries an empty trace."""

    variant: str
    sharding: ShardingSpec
    workload: Workload
    n_commands: int
    seed: int
    deployment: ShardedDeployment
    shards: Tuple[ExecutionTrace, ...]
    ops_per_shard: Tuple[int, ...]

    @property
    def linearizable(self) -> bool:
        return all(t.linearizable for t in self.shards)

    @property
    def n_writes(self) -> int:
        return sum(t.n_writes for t in self.shards)

    def describe(self) -> str:
        split = "/".join(str(n) for n in self.ops_per_shard)
        return (f"{self.variant} x {self.sharding.describe()}: "
                f"{self.n_commands} cmds split {split}; "
                f"linearizable={self.linearizable} (per-key partitions)")


def run_sharded(name: str,
                sharding: ShardingSpec,
                config: Optional[Config] = None,
                workload: Optional[Union[Workload, float]] = None,
                n_commands: int = 96,
                seed: int = 0,
                n_clients: Optional[int] = None,
                n_cold_keys: int = 16,
                max_steps: int = 2_000_000,
                exhaustive_limit: int = 24,
                state_machine: str = "kv",
                configs: Optional[List[Config]] = None,
                ) -> ShardedExecutionTrace:
    """Execute a sharded system of a registered variant end to end.

    One :func:`workload_ops` stream (a wider cold-key space than the
    single-group default, so keys actually spread across shards) is hash-
    routed to ``sharding.n_shards`` independent deployments; each shard
    runs to quiescence and is measured exactly like :func:`run_variant`,
    with linearizability checked per key partition."""
    exe = _executable_of(name)
    w = resolve_workload(workload, where="run_sharded")
    sd = ShardedDeployment(name, sharding, config=config, configs=configs,
                           n_clients=n_clients, seed=seed,
                           state_machine=state_machine)
    op_mix = replace(w, f_write=1.0) if exe.reads_as_writes else w
    ops = workload_ops(op_mix, n_commands, seed=seed,
                       n_cold_keys=n_cold_keys)
    sd.submit(ops)
    steps = sd.run_to_quiescence(max_steps=max_steps)
    traces = tuple(
        _trace_of(name, sd.configs[s], w, sd.shards[s],
                  sd.ops_per_shard[s], seed, steps[s], exhaustive_limit,
                  state_machine, per_key=True)
        for s in range(len(sd)))
    return ShardedExecutionTrace(
        variant=name, sharding=sharding, workload=w, n_commands=n_commands,
        seed=seed, deployment=sd, shards=traces,
        ops_per_shard=tuple(sd.ops_per_shard))


@dataclass
class ShardedParityReport:
    """Per-shard parity against the shard-scaled tables.

    Each populated shard gets a full :class:`ParityReport` (its table
    blended at the shard's own realized write mix); ``passed`` requires
    every shard's stations within tolerance *and* every shard's per-key
    partitions linearizable.  Empty shards (no keys hashed there) are
    skipped - they did no work to compare."""

    variant: str
    sharding: ShardingSpec
    workload: Workload
    reports: Tuple[Optional[ParityReport], ...]
    trace: ShardedExecutionTrace

    @property
    def shards_checked(self) -> int:
        return sum(1 for r in self.reports if r is not None)

    @property
    def passed(self) -> bool:
        return (self.trace.linearizable
                and self.shards_checked > 0
                and all(r.stations_ok for r in self.reports
                        if r is not None))

    def summary(self) -> str:
        verdict = "parity OK" if self.passed else "PARITY FAIL"
        per = "; ".join(
            f"s{i}: " + ("empty" if r is None else
                         f"max rel err {r.max_rel_err():.3f}")
            for i, r in enumerate(self.reports))
        return (f"{verdict} across {self.sharding.describe()} "
                f"({self.shards_checked} checked): {per}; "
                f"linearizable={self.trace.linearizable}")


def validate_sharded(name: str,
                     sharding: ShardingSpec,
                     config: Optional[Config] = None,
                     workload: Optional[Union[Workload, float]] = None,
                     n_commands: int = 96,
                     seed: int = 0,
                     **run_kwargs: Any) -> ShardedParityReport:
    """Execute a sharded system and parity-check every shard against its
    own (shard-scaled) analytical table.

    Station msgs are per shard-local command, so the comparison point is
    the same per-command table regardless of the shard's traffic share;
    the blend uses each shard's *realized* write mix (the hash split
    does not preserve the global mix per shard)."""
    w = resolve_workload(workload, where="validate_sharded")
    strace = run_sharded(name, sharding, config=config, workload=w,
                         n_commands=n_commands, seed=seed, **run_kwargs)
    reports: List[Optional[ParityReport]] = []
    for s, trace in enumerate(strace.shards):
        if trace.n_commands == 0:
            reports.append(None)
            continue
        rows, model_cfg = _parity_rows(name, strace.deployment.configs[s],
                                       w, trace)
        reports.append(ParityReport(
            variant=name, config=dict(strace.deployment.configs[s]),
            model_config=model_cfg, workload=w, rows=tuple(rows),
            trace=trace))
    return ShardedParityReport(variant=name, sharding=sharding, workload=w,
                               reports=tuple(reports), trace=strace)


# ---------------------------------------------------------------------------
# The autoscale replay: live station add/drain on a real cluster
# ---------------------------------------------------------------------------


def station_knob_map(name: str, config: Optional[Config] = None,
                     workload: Optional[Union[Workload, float]] = None,
                     ) -> Dict[str, str]:
    """Which config key resizes which station - derived from the
    registry, zero per-variant branches.

    For every single-key integer knob the variant declares, build the
    analytical model at ``knob`` and ``knob + 1`` and diff the
    per-station server counts: a knob that moves exactly one station's
    count by exactly one IS that station's resize handle
    (compartmentalized: ``n_proxy_leaders`` -> ``proxy``,
    ``n_replicas`` -> ``replica``).  Coupled knobs (acceptor grids) and
    knobs that reshape several stations (``f``) are excluded - resizing
    them is a reconfiguration, not an elastic add/drain.  Runtime
    variants get their resize handles the moment they register knobs."""
    spec = variant_spec(name)
    cfg = dict(config) if config is not None else default_config(name)
    cfg.pop("variant", None)
    w = resolve_workload(workload, where="station_knob_map")
    base_srv = spec.model(cfg, w).demand_slots()[2]
    mapping: Dict[str, str] = {}
    for kn in spec.knobs:
        if len(kn.keys) != 1:
            continue
        key = kn.keys[0]
        cur = cfg.get(key)
        if not isinstance(cur, int) or isinstance(cur, bool):
            continue
        up = dict(cfg)
        up[key] = cur + 1
        try:
            up_srv = spec.model(up, w).demand_slots()[2]
        except Exception:
            continue
        diffs = [i for i in range(len(base_srv)) if up_srv[i] != base_srv[i]]
        if (len(diffs) == 1
                and up_srv[diffs[0]] == base_srv[diffs[0]] + 1):
            mapping[STATION_ORDER[diffs[0]]] = key
    return mapping


def resizable_stations(name: str, config: Optional[Config] = None,
                       ) -> Tuple[str, ...]:
    """The stations :func:`run_autoscaled` can live-resize for this
    variant (see :func:`station_knob_map`); empty for knobless variants
    like ``unreplicated``."""
    return tuple(sorted(station_knob_map(name, config)))


def resize_config(name: str, config: Config, station: str, delta: int,
                  ) -> Config:
    """One elastic action lowered onto the config dict: the station's
    registry-derived resize knob moves by ``delta`` (floor 1)."""
    mapping = station_knob_map(name, config)
    key = mapping.get(station)
    if key is None:
        raise ValueError(
            f"variant {name!r} cannot resize station {station!r}; "
            f"resizable: {sorted(mapping) or 'none'}")
    cfg = dict(config)
    new = int(cfg[key]) + int(delta)
    if new < 1:
        raise ValueError(
            f"resize would drop {station!r} ({key}) below 1: {new}")
    cfg[key] = new
    return cfg


@dataclass
class AutoscaledExecutionTrace:
    """One autoscale plan replayed live on a real registered-variant
    cluster, epoch by epoch.

    Each resize is an epoch boundary: the old deployment drains to
    quiescence (stop routing + flush in-flight ops), a fresh deployment
    at the resized config warms by replaying the committed KV state
    (migration puts + continuity ``get`` probes, all paying virtual
    time), and traffic resumes.  ``window_rates`` include that
    reconfiguration overhead, ``serve_rates`` exclude it - their ratio
    per action window is the *measured* dip the transient plane's
    :meth:`~repro.core.autoscale.AutoscaleTrace.predicted_dip` is
    parity-checked against (``dip_rows``), within
    ``max(0.35, exe.latency_tolerance)``.  Safety is non-negotiable:
    every epoch's history is per-key-partition linearizable and every
    continuity probe returns the pre-resize committed value."""

    variant: str
    initial_config: Config
    final_config: Config
    plan: Tuple[Dict[str, Any], ...]
    load: Tuple[float, ...]            # [W] multipliers
    window_ops: Tuple[int, ...]        # [W] commands served per window
    window_rates: Tuple[float, ...]    # [W] cmds per virtual time, incl.
    serve_rates: Tuple[float, ...]     # [W] excl. reconfiguration cost
    machines: Tuple[int, ...]          # [W] provisioned servers
    machine_time: float
    epochs: Tuple[Tuple[int, Config], ...]  # (start window, config)
    dip_rows: Tuple[Dict[str, Any], ...]    # per action: measured vs
    tolerance: float                        # predicted dip ratio
    linearizable: bool
    checkers: Tuple[str, ...]          # per epoch
    continuity_ok: bool
    continuity: Tuple[Tuple[str, Any, Any], ...]  # (key, want, got)
    steps: int

    @property
    def dips_ok(self) -> bool:
        return all(r["ok"] for r in self.dip_rows)

    @property
    def passed(self) -> bool:
        return self.linearizable and self.continuity_ok and self.dips_ok

    def describe(self) -> str:
        acts = ", ".join(
            f"w{a['window']} {'+' if a['delta'] > 0 else '-'}{a['station']}"
            for a in self.plan) or "no actions"
        dips = ", ".join(
            f"w{r['window']} {r['measured']:.2f}/{r['predicted']:.2f}"
            for r in self.dip_rows if r["predicted"] is not None)
        return (f"{self.variant} autoscaled over {len(self.load)} windows "
                f"({len(self.epochs)} epochs): {acts}; machine_time "
                f"{self.machine_time:.2f}; dips meas/pred: {dips or 'n/a'}; "
                f"linearizable={self.linearizable} "
                f"continuity={self.continuity_ok}")


def _last_committed_puts(history: History) -> Dict[Any, Any]:
    """Last committed value per key, in response-time order - the state
    an epoch hands its successor."""
    last: Dict[Any, Any] = {}
    for o in sorted(history.complete(), key=lambda o: o.response_time):
        if o.op[0] == "put":
            last[o.op[1]] = o.op[2]
    return last


def run_autoscaled(name: str,
                   plan: Any,
                   load: Optional[Any] = None,
                   config: Optional[Config] = None,
                   workload: Optional[Union[Workload, float]] = None,
                   n_commands_per_window: int = 36,
                   n_clients: Optional[int] = None,
                   seed: int = 0,
                   state_machine: str = "kv",
                   exhaustive_limit: int = 24,
                   max_steps: int = 2_000_000,
                   ) -> AutoscaledExecutionTrace:
    """Replay an autoscale plan against a real registered-variant
    cluster, staying linearizable across every resize.

    ``plan`` is an :class:`~repro.core.autoscale.AutoscaleTrace` (its
    :meth:`plan`, ``load`` and per-action ``predicted_dip`` are used) or
    a plain sequence of ``{"window", "station", "delta"}`` dicts.  Each
    window serves a :func:`workload_ops` stream sized by its load
    multiplier through the live deployment; a window with an action
    first retires the old epoch - drain to quiescence, flush in-flight
    ops - then builds the resized deployment via the registry-derived
    :func:`resize_config` (zero core edits for any variant that declares
    resize knobs) and warms it by replaying committed state, with the
    whole drain+warm cost paid in measured virtual time.  The per-action
    measured dip (rate including reconfiguration cost over rate without)
    is compared to the transient plane's prediction within
    ``max(0.35, latency_tolerance)`` - the same replay-parity discipline
    as the failover and resharding replays."""
    exe = _executable_of(name)
    spec = variant_spec(name)
    w = resolve_workload(workload, where="run_autoscaled")
    cfg = dict(config) if config is not None else default_config(name)
    n_cl = n_clients if n_clients is not None else exe.n_clients
    tol = max(0.35, exe.latency_tolerance)

    predicted: Dict[int, Optional[float]] = {}
    if hasattr(plan, "plan"):                     # AutoscaleTrace duck type
        if load is None:
            load = [float(x) for x in plan.load]
        actions = list(plan.plan())
        for a in actions:
            predicted[int(a["window"])] = plan.predicted_dip(
                int(a["window"]))
        plan_rows = tuple(dict(a) for a in actions)
    else:
        plan_rows = tuple(dict(a) for a in plan)
    if load is None:
        horizon = max((int(a["window"]) for a in plan_rows), default=0) + 2
        load = [1.0] * horizon
    load = [float(x) for x in load]
    if not load or min(load) <= 0.0:
        raise ValueError("load must be a non-empty positive vector")
    peak = max(load)
    by_window: Dict[int, List[Dict[str, Any]]] = {}
    for a in plan_rows:
        wdx = int(a["window"])
        if not 0 <= wdx < len(load):
            raise ValueError(
                f"action window {wdx} outside the {len(load)}-window "
                f"horizon")
        by_window.setdefault(wdx, []).append(a)

    dep = _build_deployment(exe, cfg, n_cl, seed, state_machine)
    epochs: List[Tuple[int, Config]] = [(0, dict(cfg))]
    checkers: List[str] = []
    continuity: List[Tuple[str, Any, Any]] = []
    window_ops: List[int] = []
    window_rates: List[float] = []
    serve_rates: List[float] = []
    machines: List[int] = []
    dip_rows: List[Dict[str, Any]] = []
    lin_ok = True
    steps = 0
    committed: Dict[Any, Any] = {}

    def _retire(dep: Any) -> None:
        nonlocal lin_ok, steps
        steps += dep.run_to_quiescence(max_steps=max_steps)  # flush
        ok, checker, _ = _check_history_partitioned(
            dep.history, sm_kind=state_machine,
            exhaustive_limit=exhaustive_limit)
        lin_ok = lin_ok and ok
        checkers.append(checker)
        committed.update(_last_committed_puts(dep.history))

    op_mix = replace(w, f_write=1.0) if exe.reads_as_writes else w
    for wdx in range(len(load)):
        overhead = 0.0
        if wdx in by_window:
            _retire(dep)                         # drain + flush old epoch
            for a in by_window[wdx]:
                cfg = resize_config(name, cfg, str(a["station"]),
                                    int(a["delta"]))
            dep = _build_deployment(exe, cfg, n_cl, seed + len(epochs),
                                    state_machine)
            epochs.append((wdx, dict(cfg)))
            if committed:                        # warm: migrate state
                keys = sorted(committed, key=str)
                t0 = dep.net.now
                per = [[] for _ in dep.clients]
                for i, k in enumerate(keys):
                    per[i % len(per)].append(k)
                for client, mine in zip(dep.clients, per):
                    ops = ([("put", k, committed[k]) for k in mine]
                           + [("get", k) for k in mine])
                    if ops:
                        client.run_ops(ops)
                steps += _drive(name, dep, max_steps)
                overhead = dep.net.now - t0
                first_get: Dict[Any, Any] = {}
                for o in sorted(dep.history.complete(),
                                key=lambda o: o.response_time):
                    if o.op[0] == "get" and o.op[1] not in first_get:
                        first_get[o.op[1]] = o.result
                for k in keys:
                    continuity.append((str(k), committed[k],
                                       first_get.get(k)))
        n_ops = max(2, round(n_commands_per_window * load[wdx] / peak))
        ops = workload_ops(op_mix, n_ops,
                           seed=seed * 131 + 7 * wdx + len(epochs))
        t0 = dep.net.now
        _assign_ops(dep, ops)
        steps += _drive(name, dep, max_steps)
        serve = max(dep.net.now - t0, 1e-12)
        window_ops.append(n_ops)
        serve_rates.append(n_ops / serve)
        window_rates.append(n_ops / (serve + overhead))
        machines.append(sum(spec.model(cfg, w).demand_slots()[2]))
        if wdx in by_window:
            measured = serve / (serve + overhead)
            pred = predicted.get(wdx)
            ok = pred is None or abs(measured - pred) <= tol
            dip_rows.append({"window": wdx, "measured": measured,
                             "predicted": pred, "ok": ok})
    _retire(dep)

    cont_ok = all(got == want for _, want, got in continuity)
    return AutoscaledExecutionTrace(
        variant=name, initial_config=dict(epochs[0][1]),
        final_config=dict(cfg), plan=plan_rows, load=tuple(load),
        window_ops=tuple(window_ops), window_rates=tuple(window_rates),
        serve_rates=tuple(serve_rates), machines=tuple(machines),
        machine_time=sum(machines) / len(machines),
        epochs=tuple(epochs), dip_rows=tuple(dip_rows), tolerance=tol,
        linearizable=lin_ok, checkers=tuple(checkers),
        continuity_ok=cont_ok, continuity=tuple(continuity), steps=steps)


# ---------------------------------------------------------------------------
# Built-in execution planes (normalized behind the same canonical config
# dicts the analytical factories consume)
# ---------------------------------------------------------------------------


def _compartmentalized_deployment(f: int = 1, n_proxy_leaders: int = 10,
                                  grid_rows: int = 2, grid_cols: int = 2,
                                  n_replicas: int = 4, batch_size: int = 1,
                                  n_batchers: int = 0, n_unbatchers: int = 0,
                                  n_clients: int = 3, seed: int = 0,
                                  state_machine: str = "kv",
                                  latency_fn: Optional[Any] = None,
                                  ) -> CompartmentalizedMultiPaxos:
    # the (2f+1, 1) "grid" is the majority-quorum column: lower it to the
    # majority quorum system the deployment uses for that shape
    grid = None if (grid_rows, grid_cols) == (2 * f + 1, 1) else (grid_rows,
                                                                  grid_cols)
    cfg = DeploymentConfig(f=f, n_proxy_leaders=n_proxy_leaders, grid=grid,
                           n_replicas=n_replicas, n_batchers=n_batchers,
                           n_unbatchers=n_unbatchers, batch_size=batch_size,
                           state_machine=state_machine, seed=seed,
                           latency_fn=latency_fn)
    return CompartmentalizedMultiPaxos(cfg, n_clients=n_clients)


def _compartmentalized_feedback(model_cfg: Config,
                                trace: ExecutionTrace) -> Config:
    """Feed the *realized* batch fill into the table.

    Closed-loop traffic rarely fills configured batches: with C
    outstanding clients a size-B batcher flushes by timer at ~C commands,
    so the amortization denominator the wire actually enjoyed is
    ``n_commands / batches_flushed`` - the measured counterpart of the
    ``Workload.batch_fill`` hint (``effective_batch_size``) the sweep
    plane's adapter applies.  Unbatched configs pass through untouched."""
    if model_cfg.get("n_batchers", 0) <= 0 or model_cfg.get(
            "batch_size", 1) <= 1:
        return model_cfg
    dep = trace.deployment
    write_batches = sum(b.batch_seq for b in dep.batchers)
    read_batches = sum(b.preread_seq for b in dep.batchers)
    # the write-stream fill drives the leader/proxy/replica write path
    # (the table's headline 2/B leader term is exact against it); fall
    # back to the read-stream fill for read-only runs
    if trace.n_writes and write_batches:
        b_eff = trace.n_writes / write_batches
    elif trace.n_reads and read_batches:
        b_eff = trace.n_reads / read_batches
    else:
        return model_cfg
    return dict(model_cfg, batch_size=max(b_eff, 1.0))


def _multipaxos_deployment(f: int = 1, thrifty: bool = True,
                           n_clients: int = 2, seed: int = 0,
                           state_machine: str = "kv",
                           latency_fn: Optional[Any] = None,
                           ) -> CompartmentalizedMultiPaxos:
    # vanilla: self-broadcast leader, majority quorums, and - matching the
    # fused-server accounting of multipaxos_model - a replica per machine
    del thrifty  # the deployment always contacts thrifty majorities
    cfg = DeploymentConfig(f=f, n_proxy_leaders=0, grid=None,
                           n_replicas=2 * f + 1, state_machine=state_machine,
                           seed=seed, latency_fn=latency_fn)
    return CompartmentalizedMultiPaxos(cfg, n_clients=n_clients)


def _multipaxos_station_of(addr: str, dep: Any) -> Optional[str]:
    """Fused-server bucketing for the vanilla baseline: the model's
    ``leader`` station is machine 0 (the leader role; its colocated
    acceptor/replica role costs are the model's reply-share term) and
    ``follower`` the other 2f machines (acceptor + replica roles).  The
    standby leader objects are idle and unmapped."""
    role, _, idx = addr.partition("/")
    if role == "leader":
        return "leader" if idx == "0" else None
    if role in ("acceptor", "replica"):
        return None if idx == "0" else "follower"
    return None


def _mencius_deployment(n_leaders: int = 3, f: int = 1,
                        n_proxy_leaders: int = 10, grid_rows: int = 2,
                        grid_cols: int = 2, n_replicas: int = 4,
                        announce_interval: Optional[float] = None,
                        skip_fraction: float = 0.0, skip_batch: float = 10.0,
                        n_clients: int = 3, seed: int = 0,
                        state_machine: str = "kv",
                        latency_fn: Optional[Any] = None,
                        ) -> MenciusDeployment:
    # announce/skip knobs parameterize the *table*; the protocol's own
    # announce-every-command / range-skip behaviour is measured and fed
    # back by _mencius_feedback
    del announce_interval, skip_fraction, skip_batch
    return MenciusDeployment(n_leaders=n_leaders, f=f,
                             n_proxy_leaders=n_proxy_leaders,
                             grid=(grid_rows, grid_cols),
                             n_replicas=n_replicas, n_clients=n_clients,
                             state_machine=state_machine, seed=seed,
                             latency_fn=latency_fn)


def _mencius_feedback(model_cfg: Config, trace: ExecutionTrace) -> Config:
    """Feed the run's own slot-coordination statistics into the table:
    the correctness plane announces its frontier on every owned command
    (``announce_interval=1``, where the paper's protocol piggybacks it)
    and lagging leaders range-fill vacant slots - the effective
    ``skip_fraction`` and per-range amortization ``skip_batch`` are read
    off the run instead of assumed."""
    dep = trace.deployment
    n_ranges = dep.total_skips()
    n_slots = max(r.executed_upto for r in dep.replicas) + 1
    n_noops = max(n_slots - trace.n_writes, 0)
    cfg = dict(model_cfg, announce_interval=1.0)
    if n_noops and n_ranges:
        cfg.update(skip_fraction=n_noops / n_slots,
                   skip_batch=n_noops / n_ranges)
    return cfg


def _spaxos_deployment(n_disseminators: int = 2, n_stabilizers: int = 3,
                       f: int = 1, n_proxy_leaders: int = 3,
                       grid_rows: int = 2, grid_cols: int = 2,
                       n_replicas: int = 3, payload_factor: float = 1.0,
                       n_clients: int = 2, seed: int = 0,
                       state_machine: str = "kv",
                       latency_fn: Optional[Any] = None,
                       ) -> SPaxosDeployment:
    del payload_factor  # table-only knob: message *counts* are size-blind
    return SPaxosDeployment(f=f, n_disseminators=n_disseminators,
                            n_stabilizers=n_stabilizers,
                            n_proxy_leaders=n_proxy_leaders,
                            grid=(grid_rows, grid_cols),
                            n_replicas=n_replicas, n_clients=n_clients,
                            state_machine=state_machine, seed=seed,
                            latency_fn=latency_fn)


def _vanilla_mencius_deployment(f: int = 1,
                                announce_interval: Optional[float] = None,
                                skip_fraction: float = 0.0,
                                skip_batch: float = 10.0, n_clients: int = 3,
                                seed: int = 0, state_machine: str = "kv",
                                latency_fn: Optional[Any] = None,
                                ) -> VanillaMenciusDeployment:
    # announce/skip knobs parameterize the table; the fused servers
    # announce every command and range-fill, measured back by feedback
    del announce_interval, skip_fraction, skip_batch
    return VanillaMenciusDeployment(f=f, n_clients=n_clients,
                                    state_machine=state_machine, seed=seed,
                                    latency_fn=latency_fn)


def _vanilla_mencius_feedback(model_cfg: Config,
                              trace: ExecutionTrace) -> Config:
    """Same feedback loop as compartmentalized Mencius: the fused servers
    announce their frontier on every owned command and range-fill vacant
    slots; the table's skip knobs are read off the run."""
    dep = trace.deployment
    n_ranges = dep.total_skips()
    n_slots = max(s.executed_upto for s in dep.servers) + 1
    n_noops = max(n_slots - trace.n_writes, 0)
    cfg = dict(model_cfg, announce_interval=1.0)
    if n_noops and n_ranges:
        cfg.update(skip_fraction=n_noops / n_slots,
                   skip_batch=n_noops / n_ranges)
    return cfg


def _vanilla_spaxos_deployment(f: int = 1, payload_factor: float = 1.0,
                               n_clients: int = 3, seed: int = 0,
                               state_machine: str = "kv",
                               latency_fn: Optional[Any] = None,
                               ) -> VanillaSPaxosDeployment:
    del payload_factor  # table-only knob: message *counts* are size-blind
    return VanillaSPaxosDeployment(f=f, n_clients=n_clients,
                                   state_machine=state_machine, seed=seed,
                                   latency_fn=latency_fn)


def _vanilla_spaxos_station_of(addr: str, dep: Any) -> Optional[str]:
    """Fused-server bucketing: server 0 carries the colocated leader role
    (the model's ``leader`` machine); the other 2f are ``follower``s."""
    role, _, idx = addr.partition("/")
    if role != "server":
        return None
    return "leader" if idx == "0" else "follower"


def _craq_deployment(n_nodes: int = 3, skew_p: float = 0.0,
                     dirty_fraction: float = 0.5, n_clients: int = 2,
                     seed: int = 0, state_machine: str = "kv",
                     latency_fn: Optional[Any] = None,
                     ) -> CraqDeployment:
    # skew/dirty parameterize the table; the run's actual forwarding
    # fraction is measured and fed back by _craq_feedback
    del skew_p, dirty_fraction, state_machine  # chain nodes are always kv
    return CraqDeployment(n_nodes=n_nodes, n_clients=n_clients, seed=seed,
                          latency_fn=latency_fn)


def _craq_station_of(addr: str, dep: Any) -> Optional[str]:
    role, _, idx = addr.partition("/")
    if role != "chain":
        return None
    i = int(idx)
    if i == 0:
        return "head"
    return "tail" if i == len(dep.chain_addrs) - 1 else "chain"


def _craq_feedback(model_cfg: Config, trace: ExecutionTrace) -> Config:
    """Feed the measured dirty-read forwarding fraction into the table:
    with concurrent writers even a nominally uniform run forwards some
    reads to the tail while their key is dirty.  A *user* config that
    pins its own skew knobs keeps them (the workload adapter's
    ``dirty_fraction`` is a hint; the measured fraction replaces it)."""
    if trace.n_reads == 0 or trace.config.get("skew_p"):
        return model_cfg
    forwarded = sum(n.tail_forwards for n in trace.deployment.nodes)
    # the table's forwarded fraction is skew_p * dirty_fraction, over
    # reads that land on the k-1 non-tail nodes
    k = len(trace.deployment.chain_addrs)
    p_fwd = forwarded / trace.n_reads * k / max(k - 1, 1)
    return dict(model_cfg, skew_p=min(p_fwd, 1.0), dirty_fraction=1.0)


def _unreplicated_deployment(n_clients: int = 2, seed: int = 0,
                             state_machine: str = "kv", batch_size: int = 1,
                             n_batchers: int = 0, n_unbatchers: int = 0,
                             latency_fn: Optional[Any] = None,
                             ) -> UnreplicatedStateMachine:
    if n_batchers or n_unbatchers or batch_size != 1:
        raise ValueError("the unreplicated execution plane is unbatched; "
                         "batching knobs parameterize the table only")
    return UnreplicatedStateMachine(n_clients=n_clients, seed=seed,
                                    state_machine=state_machine,
                                    latency_fn=latency_fn)


# Parity notes per plane (all measured write-only unless stated):
# * compartmentalized / spaxos: station totals per command are
#   deterministic (random quorum/column picks move messages *within* a
#   station, never across), so tolerances are tight and the headline
#   leader counts (2 msgs/cmd; S-Paxos: 2 id-only msgs) are exact.
# * multipaxos: the fused-machine model folds the leader machine's
#   acceptor role and chosen-recv into its follower/reply terms slightly
#   differently than the wire counts them - the leader row lands within
#   ~5%, followers are exact in expectation.
# * mencius: exact once the run's announce/skip parameters are fed back;
#   the proxy row absorbs range-path edge messages.
# * craq: message-exact chain accounting; under mixed workloads the
#   measured forwarding fraction is fed back.
# * vanilla_mencius: the fused table omits the owner machine's own
#   colocated acceptor vote and chosen-recv (local facts on a fused
#   server); the wire plane lands within ~2% once skips are fed back.
# * vanilla_spaxos: wire totals match the table exactly (self-sends are
#   counted on both sides, like the model); only the thrifty quorum draw
#   moves acceptor messages between the leader and follower rows.
register_executable(
    "compartmentalized",
    deployment=_compartmentalized_deployment,
    model_feedback=_compartmentalized_feedback,
    exact_stations=("leader",),
    rel_tolerance=0.10,
    n_clients=3,
    description="CompartmentalizedMultiPaxos cluster (paper sections 3-4)",
)

register_executable(
    "multipaxos",
    deployment=_multipaxos_deployment,
    station_of=_multipaxos_station_of,
    rel_tolerance=0.10,
    reads_as_writes=True,  # the vanilla table has no read path (paper s.3)
    n_clients=2,
    description="vanilla MultiPaxos (self-broadcast leader, fused servers)",
)

register_executable(
    "mencius",
    deployment=_mencius_deployment,
    model_feedback=_mencius_feedback,
    rel_tolerance=0.10,
    station_tolerances=(("proxy", 0.25),),
    # slot-order execution waits are only partially captured by the wire
    # model (geo.py) - give the WAN latency rows extra headroom
    latency_tolerance=0.5,
    n_clients=3,
    description="MenciusDeployment (round-robin leaders + range skips)",
)

register_executable(
    "spaxos",
    deployment=_spaxos_deployment,
    exact_stations=("leader",),
    rel_tolerance=0.10,
    n_clients=2,
    description="SPaxosDeployment (id-ordering leader, data-path split)",
)

register_executable(
    "craq",
    deployment=_craq_deployment,
    station_of=_craq_station_of,
    model_feedback=_craq_feedback,
    rel_tolerance=0.10,
    n_clients=2,
    description="CraqDeployment chain (dirty reads forward to the tail)",
)

register_executable(
    "vanilla_mencius",
    deployment=_vanilla_mencius_deployment,
    model_feedback=_vanilla_mencius_feedback,
    rel_tolerance=0.10,
    reads_as_writes=True,  # the fused table has no read path (paper Fig. 25)
    latency_tolerance=0.5,  # slot-order skip echoes only partially modeled
    n_clients=3,
    description="VanillaMenciusDeployment (fused leader+acceptor+replica)",
)

register_executable(
    "vanilla_spaxos",
    deployment=_vanilla_spaxos_deployment,
    station_of=_vanilla_spaxos_station_of,
    rel_tolerance=0.10,
    reads_as_writes=True,  # the fused table has no read path (paper Fig. 27)
    n_clients=3,
    description="VanillaSPaxosDeployment (fused servers, leader on 0)",
)

register_executable(
    "unreplicated",
    deployment=_unreplicated_deployment,
    exact_stations=("server",),
    rel_tolerance=0.05,
    n_clients=2,
    description="UnreplicatedStateMachine upper bound",
)
