"""The generic execution harness: run any registered variant's *real*
cluster, check linearizability, and parity-check measured message counts
against the analytical demand table - two planes, one registry.

The paper's evidence for "compartmentalization is a technique, not a
protocol" is dual: message-count tables derived analytically *and* real
protocol executions that agree with them.  This module makes that
cross-validation loop a first-class call.  A variant whose
:class:`~repro.core.api.VariantSpec` declares an
:class:`~repro.core.api.ExecutableSpec` (its ``deployment`` factory takes
the **same canonical config dict** as its analytical factory) gets, with
zero edits to this file:

* :func:`run_variant` - drive the deployment with ``Workload``-shaped
  closed-loop traffic (write fraction, key skew, batched arrivals through
  the variant's own batchers), collect the operation history, run the
  linearizability checker, and bucket measured per-station messages per
  command into the *same* :data:`~repro.core.api.STATION_ORDER` slots the
  demand tensors use;
* :func:`validate_variant` - an analytical-vs-measured parity report per
  station (exact where the executable declares it - S-Paxos' leader is
  exactly 2 id-only msgs/cmd - within declared tolerance elsewhere);
* :func:`repro.core.analytical.calibrate_alpha` ``(measured=True)`` - the
  25k anchor derived from an executed vanilla run instead of a constant.

``benchmarks/protocol_messages.py`` is one zero-branch loop over
:func:`~repro.core.api.executable_variants` calling
:func:`validate_variant`; the per-variant physics (address -> station
bucketing, measured-parameter feedback such as Mencius' observed skip
rate, tolerances) lives in the registered :class:`ExecutableSpec`, as
data.

The built-in executables for all six shipped variants are registered at
the bottom of this module; runtime variants attach theirs with
:func:`~repro.core.api.register_executable` (or directly in
``register_variant(executable=...)``) and ride the same calls.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, replace
from typing import Any, Dict, List, Optional, Tuple, Union

from .api import (
    Config,
    ExecutableSpec,
    STATION_ORDER,
    Workload,
    executable_variants,
    register_executable,
    resolve_workload,
    variant_spec,
)
from .craq import CraqDeployment
from .history import History
from .linearizability import check_linearizable, check_slot_order
from .mencius import MenciusDeployment, VanillaMenciusDeployment
from .protocols import (
    CompartmentalizedMultiPaxos,
    DeploymentConfig,
    UnreplicatedStateMachine,
)
from .spaxos import SPaxosDeployment, VanillaSPaxosDeployment

__all__ = [
    "ExecutionTrace", "ParityReport", "StationParity", "default_config",
    "run_variant", "validate_variant", "workload_ops",
]


# ---------------------------------------------------------------------------
# Workload-shaped op streams
# ---------------------------------------------------------------------------


def workload_ops(workload: Workload, n_commands: int, seed: int = 0,
                 n_cold_keys: int = 4) -> List[Tuple]:
    """A deterministic op stream shaped by a :class:`Workload`: exactly
    ``round(n_commands * f_write)`` writes, shuffled; skewed ops
    (probability ``skew_p``) target the single hot key, the rest a small
    shared cold key space (shared keys keep the linearizability check
    non-vacuous when the stream is split across concurrent clients)."""
    rng = random.Random(seed * 0x9E3779B1 + 1)
    n_writes = round(n_commands * workload.f_write)
    writes = [True] * n_writes + [False] * (n_commands - n_writes)
    rng.shuffle(writes)
    ops: List[Tuple] = []
    for i, is_write in enumerate(writes):
        hot = workload.skew_p > 0.0 and rng.random() < workload.skew_p
        key = "hot" if hot else f"k{rng.randrange(n_cold_keys)}"
        ops.append(("put", key, i) if is_write else ("get", key))
    return ops


# ---------------------------------------------------------------------------
# ExecutionTrace: one measured run
# ---------------------------------------------------------------------------


@dataclass
class ExecutionTrace:
    """One executed, measured, checked run of a variant's deployment.

    ``station_msgs`` is measured (sent + received) messages per command
    **per server**, keyed by canonical station name - the same unit and
    vocabulary as ``DeploymentModel.demands``; server counts come from the
    variant's own demand table for the same config (for fused-role
    baselines like vanilla MultiPaxos the model's "machine" aggregates
    several deployment nodes).  ``station_totals`` / ``station_nodes``
    keep the raw accounting."""

    variant: str
    config: Config
    workload: Workload
    n_commands: int
    seed: int
    deployment: Any
    history: History
    station_msgs: Dict[str, float]
    station_totals: Dict[str, int]
    station_servers: Dict[str, int]
    station_nodes: Dict[str, int]
    steps: int
    linearizable: bool
    checker: str
    violations: Tuple[str, ...] = ()

    @property
    def n_writes(self) -> int:
        return sum(1 for o in self.history.ops if not o.is_read)

    @property
    def n_reads(self) -> int:
        return self.n_commands - self.n_writes

    def demand_slots(self) -> List[float]:
        """Measured per-server msgs/cmd scattered into the canonical
        :data:`STATION_ORDER` columns (zero where the deployment has no
        such component) - directly comparable to a compiled sweep row."""
        row = [0.0] * len(STATION_ORDER)
        for name, d in self.station_msgs.items():
            row[STATION_ORDER.index(name)] += d
        return row

    def describe(self) -> str:
        pairs = ", ".join(f"{s} {d:.2f}" for s, d in self.station_msgs.items())
        return (f"{self.variant}: {self.n_commands} cmds "
                f"({self.n_writes} writes) in {self.steps} deliveries; "
                f"msgs/cmd/server: {pairs}; "
                f"linearizable={self.linearizable} ({self.checker})")


def _check_history(history: History, sm_kind: str = "kv",
                   exhaustive_limit: int = 24,
                   ) -> Tuple[bool, str, Tuple[str, ...]]:
    """Linearizability verdict: exhaustive Wing-Gong search on small
    histories (ground truth), the paper's slot-order check on large ones
    (cheap, sound for slot-stamped histories).  A large history with no
    slot stamps at all (CRAQ: versions are per-key, so responses carry no
    global log position) would make the slot-order check vacuously true -
    those fall back to the exhaustive search too, which closed-loop
    histories keep cheap (branching bounded by the client count)."""
    stamped = any(o.slot is not None for o in history.complete())
    if len(history) <= exhaustive_limit or not stamped:
        ok = check_linearizable(history, sm_kind)
        return ok, "exhaustive", () if ok else ("no linearization found",)
    violations = tuple(check_slot_order(history))
    return not violations, "slot_order", violations


def default_config(name: str, f: int = 1) -> Config:
    """The variant's default-knob config dict (the first point of its
    declared knob product) - what :func:`run_variant` uses when no config
    is given."""
    return next(iter(variant_spec(name).configs(f=f)))


def _executable_of(name: str) -> ExecutableSpec:
    spec = variant_spec(name)
    if spec.executable is None:
        raise ValueError(
            f"variant {name!r} declares no execution plane; executable "
            f"variants: {list(executable_variants())} (attach one with "
            f"register_executable)")
    return spec.executable


def run_variant(name: str,
                config: Optional[Config] = None,
                workload: Optional[Union[Workload, float]] = None,
                n_commands: int = 60,
                seed: int = 0,
                n_clients: Optional[int] = None,
                max_steps: int = 2_000_000,
                exhaustive_limit: int = 24,
                jitter: float = 0.0,
                state_machine: str = "kv") -> ExecutionTrace:
    """Execute one config of a registered variant end to end.

    Builds the deployment from the variant's :class:`ExecutableSpec`,
    zeroes message counters (setup traffic such as Phase 1 is not part of
    the per-command cost), splits a :func:`workload_ops` stream
    round-robin across the closed-loop clients, runs the network to
    quiescence, checks linearizability, and buckets measured per-station
    msgs/cmd into canonical station slots.  Generic over the registry:
    zero per-variant branches here."""
    spec = variant_spec(name)
    exe = _executable_of(name)
    cfg = dict(config) if config is not None else default_config(name)
    w = resolve_workload(workload, where="run_variant")
    n_cl = n_clients if n_clients is not None else exe.n_clients

    model = spec.model(cfg, w)  # server counts + station sanity check
    servers = {s.name: s.servers for s in model.stations}

    build_cfg = {k: v for k, v in cfg.items() if k != "variant"}
    dep = exe.deployment(**build_cfg, n_clients=n_cl, seed=seed,
                         state_machine=state_machine)
    if jitter:
        # reorder messages across links (seeded): linearizability must
        # hold regardless; message-count parity is unaffected (counts,
        # not timings)
        dep.net.jitter = jitter
    for node in dep.net.nodes.values():
        node.msgs_sent = 0
        node.msgs_received = 0

    op_mix = replace(w, f_write=1.0) if exe.reads_as_writes else w
    ops = workload_ops(op_mix, n_commands, seed=seed)
    per_client: List[List[Tuple]] = [[] for _ in range(n_cl)]
    for i, op in enumerate(ops):
        per_client[i % n_cl].append(op)
    for client, client_ops in zip(dep.clients, per_client):
        if client_ops:
            client.run_ops(client_ops)
    steps = dep.run_to_quiescence(max_steps=max_steps)
    if not dep.all_done():
        stuck = [c.addr for c in dep.clients if not c.done]
        raise RuntimeError(
            f"run_variant({name!r}): clients {stuck} not done after "
            f"{steps} deliveries (max_steps={max_steps})")

    totals: Dict[str, int] = {}
    nodes: Dict[str, int] = {}
    for addr, node in dep.net.nodes.items():
        if exe.station_of is not None:
            station = exe.station_of(addr, dep)
        else:
            role = addr.split("/", 1)[0]
            station = role if role in spec.stations else None
        if station is None:
            continue
        totals[station] = totals.get(station, 0) + (node.msgs_sent
                                                    + node.msgs_received)
        nodes[station] = nodes.get(station, 0) + 1
    msgs = {
        station: total / n_commands / servers.get(station, nodes[station])
        for station, total in totals.items()
    }
    stations_present = {s: servers.get(s, nodes[s]) for s in totals}

    ok, checker, violations = _check_history(
        dep.history, sm_kind=state_machine, exhaustive_limit=exhaustive_limit)

    return ExecutionTrace(
        variant=name, config=cfg, workload=w, n_commands=n_commands,
        seed=seed, deployment=dep, history=dep.history, station_msgs=msgs,
        station_totals=totals, station_servers=stations_present,
        station_nodes=nodes, steps=steps, linearizable=ok, checker=checker,
        violations=violations)


# ---------------------------------------------------------------------------
# Parity: measured vs analytical, one generic loop
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class StationParity:
    """One station's measured-vs-analytical comparison."""

    station: str
    measured: float
    predicted: float
    rel_err: float
    tolerance: float
    exact: bool
    ok: bool

    def describe(self) -> str:
        tag = "exact" if self.exact else f"tol {self.tolerance:g}"
        mark = "ok" if self.ok else "FAIL"
        return (f"{self.station} {self.measured:.3f}/{self.predicted:.3f} "
                f"({tag}: {mark})")


@dataclass
class ParityReport:
    """Analytical-vs-measured msgs/cmd parity for one executed config.

    ``passed`` requires every station row within its declared tolerance
    *and* the execution's history linearizable."""

    variant: str
    config: Config
    model_config: Config
    workload: Workload
    rows: Tuple[StationParity, ...]
    trace: ExecutionTrace

    @property
    def stations_ok(self) -> bool:
        return all(r.ok for r in self.rows)

    @property
    def passed(self) -> bool:
        return self.stations_ok and self.trace.linearizable

    def row(self, station: str) -> StationParity:
        for r in self.rows:
            if r.station == station:
                return r
        raise KeyError(f"no parity row for station {station!r}; have "
                       f"{[r.station for r in self.rows]}")

    def max_rel_err(self) -> float:
        return max((r.rel_err for r in self.rows), default=0.0)

    def summary(self) -> str:
        pairs = ", ".join(
            f"{r.station} {r.measured:.2f}/{r.predicted:.2f}"
            for r in self.rows)
        verdict = "parity OK" if self.passed else "PARITY FAIL"
        return (f"{verdict}: measured/modelled msgs per cmd per server: "
                f"{pairs}; linearizable={self.trace.linearizable} "
                f"({self.trace.checker})")

    def __str__(self) -> str:
        lines = [f"{self.variant} @ {self.workload.describe()}: "
                 f"{'PASS' if self.passed else 'FAIL'}"]
        lines += [f"  {r.describe()}" for r in self.rows]
        if not self.trace.linearizable:
            lines.append(f"  NOT LINEARIZABLE ({self.trace.checker}): "
                         f"{list(self.trace.violations)}")
        return "\n".join(lines)


def validate_variant(name: str,
                     config: Optional[Config] = None,
                     workload: Optional[Union[Workload, float]] = None,
                     n_commands: int = 60,
                     seed: int = 0,
                     **run_kwargs: Any) -> ParityReport:
    """Execute a variant's deployment and parity-check its measured
    per-station msgs/cmd against its analytical demand table.

    The model side is the registered factory on the *same* config -
    workload-adapted exactly as the sweep plane would
    (``VariantSpec.adapt``), then refined by the executable's
    ``model_feedback`` with statistics measured off this very run (e.g.
    Mencius' observed skip rate), so the comparison is apples-to-apples.
    One generic loop; every per-variant fact is declared data in the
    :class:`ExecutableSpec`."""
    spec = variant_spec(name)
    exe = _executable_of(name)
    cfg = dict(config) if config is not None else default_config(name)
    w = resolve_workload(workload, where="validate_variant")
    trace = run_variant(name, cfg, w, n_commands=n_commands, seed=seed,
                        **run_kwargs)

    model_cfg = spec.adapt(cfg, w)
    if exe.model_feedback is not None:
        model_cfg = exe.model_feedback(dict(model_cfg), trace)
    # blend the table at the *realized* write fraction of the executed op
    # stream (exact mix up to rounding), so parity is not polluted by the
    # generator's rounding of f_write * n_commands
    realized = replace(w, f_write=trace.n_writes / trace.n_commands)
    predicted = spec.build(model_cfg).demands(realized)

    stations = list(trace.station_msgs)
    stations += [s for s, d in predicted.items()
                 if s not in trace.station_msgs and d > 0.0]
    rows = []
    for station in sorted(stations, key=STATION_ORDER.index):
        m = trace.station_msgs.get(station, 0.0)
        p = predicted.get(station, 0.0)
        exact = station in exe.exact_stations
        tol = exe.tolerance_for(station)
        rel = abs(m - p) / max(abs(p), 1e-12)
        ok = abs(m - p) <= 1e-9 if exact else rel <= tol
        rows.append(StationParity(station=station, measured=m, predicted=p,
                                  rel_err=rel, tolerance=tol, exact=exact,
                                  ok=ok))
    return ParityReport(variant=name, config=cfg, model_config=model_cfg,
                        workload=w, rows=tuple(rows), trace=trace)


# ---------------------------------------------------------------------------
# Built-in execution planes (normalized behind the same canonical config
# dicts the analytical factories consume)
# ---------------------------------------------------------------------------


def _compartmentalized_deployment(f: int = 1, n_proxy_leaders: int = 10,
                                  grid_rows: int = 2, grid_cols: int = 2,
                                  n_replicas: int = 4, batch_size: int = 1,
                                  n_batchers: int = 0, n_unbatchers: int = 0,
                                  n_clients: int = 3, seed: int = 0,
                                  state_machine: str = "kv",
                                  ) -> CompartmentalizedMultiPaxos:
    # the (2f+1, 1) "grid" is the majority-quorum column: lower it to the
    # majority quorum system the deployment uses for that shape
    grid = None if (grid_rows, grid_cols) == (2 * f + 1, 1) else (grid_rows,
                                                                  grid_cols)
    cfg = DeploymentConfig(f=f, n_proxy_leaders=n_proxy_leaders, grid=grid,
                           n_replicas=n_replicas, n_batchers=n_batchers,
                           n_unbatchers=n_unbatchers, batch_size=batch_size,
                           state_machine=state_machine, seed=seed)
    return CompartmentalizedMultiPaxos(cfg, n_clients=n_clients)


def _multipaxos_deployment(f: int = 1, thrifty: bool = True,
                           n_clients: int = 2, seed: int = 0,
                           state_machine: str = "kv",
                           ) -> CompartmentalizedMultiPaxos:
    # vanilla: self-broadcast leader, majority quorums, and - matching the
    # fused-server accounting of multipaxos_model - a replica per machine
    del thrifty  # the deployment always contacts thrifty majorities
    cfg = DeploymentConfig(f=f, n_proxy_leaders=0, grid=None,
                           n_replicas=2 * f + 1, state_machine=state_machine,
                           seed=seed)
    return CompartmentalizedMultiPaxos(cfg, n_clients=n_clients)


def _multipaxos_station_of(addr: str, dep: Any) -> Optional[str]:
    """Fused-server bucketing for the vanilla baseline: the model's
    ``leader`` station is machine 0 (the leader role; its colocated
    acceptor/replica role costs are the model's reply-share term) and
    ``follower`` the other 2f machines (acceptor + replica roles).  The
    standby leader objects are idle and unmapped."""
    role, _, idx = addr.partition("/")
    if role == "leader":
        return "leader" if idx == "0" else None
    if role in ("acceptor", "replica"):
        return None if idx == "0" else "follower"
    return None


def _mencius_deployment(n_leaders: int = 3, f: int = 1,
                        n_proxy_leaders: int = 10, grid_rows: int = 2,
                        grid_cols: int = 2, n_replicas: int = 4,
                        announce_interval: Optional[float] = None,
                        skip_fraction: float = 0.0, skip_batch: float = 10.0,
                        n_clients: int = 3, seed: int = 0,
                        state_machine: str = "kv") -> MenciusDeployment:
    # announce/skip knobs parameterize the *table*; the protocol's own
    # announce-every-command / range-skip behaviour is measured and fed
    # back by _mencius_feedback
    del announce_interval, skip_fraction, skip_batch
    return MenciusDeployment(n_leaders=n_leaders, f=f,
                             n_proxy_leaders=n_proxy_leaders,
                             grid=(grid_rows, grid_cols),
                             n_replicas=n_replicas, n_clients=n_clients,
                             state_machine=state_machine, seed=seed)


def _mencius_feedback(model_cfg: Config, trace: ExecutionTrace) -> Config:
    """Feed the run's own slot-coordination statistics into the table:
    the correctness plane announces its frontier on every owned command
    (``announce_interval=1``, where the paper's protocol piggybacks it)
    and lagging leaders range-fill vacant slots - the effective
    ``skip_fraction`` and per-range amortization ``skip_batch`` are read
    off the run instead of assumed."""
    dep = trace.deployment
    n_ranges = dep.total_skips()
    n_slots = max(r.executed_upto for r in dep.replicas) + 1
    n_noops = max(n_slots - trace.n_writes, 0)
    cfg = dict(model_cfg, announce_interval=1.0)
    if n_noops and n_ranges:
        cfg.update(skip_fraction=n_noops / n_slots,
                   skip_batch=n_noops / n_ranges)
    return cfg


def _spaxos_deployment(n_disseminators: int = 2, n_stabilizers: int = 3,
                       f: int = 1, n_proxy_leaders: int = 3,
                       grid_rows: int = 2, grid_cols: int = 2,
                       n_replicas: int = 3, payload_factor: float = 1.0,
                       n_clients: int = 2, seed: int = 0,
                       state_machine: str = "kv") -> SPaxosDeployment:
    del payload_factor  # table-only knob: message *counts* are size-blind
    return SPaxosDeployment(f=f, n_disseminators=n_disseminators,
                            n_stabilizers=n_stabilizers,
                            n_proxy_leaders=n_proxy_leaders,
                            grid=(grid_rows, grid_cols),
                            n_replicas=n_replicas, n_clients=n_clients,
                            state_machine=state_machine, seed=seed)


def _vanilla_mencius_deployment(f: int = 1,
                                announce_interval: Optional[float] = None,
                                skip_fraction: float = 0.0,
                                skip_batch: float = 10.0, n_clients: int = 3,
                                seed: int = 0, state_machine: str = "kv",
                                ) -> VanillaMenciusDeployment:
    # announce/skip knobs parameterize the table; the fused servers
    # announce every command and range-fill, measured back by feedback
    del announce_interval, skip_fraction, skip_batch
    return VanillaMenciusDeployment(f=f, n_clients=n_clients,
                                    state_machine=state_machine, seed=seed)


def _vanilla_mencius_feedback(model_cfg: Config,
                              trace: ExecutionTrace) -> Config:
    """Same feedback loop as compartmentalized Mencius: the fused servers
    announce their frontier on every owned command and range-fill vacant
    slots; the table's skip knobs are read off the run."""
    dep = trace.deployment
    n_ranges = dep.total_skips()
    n_slots = max(s.executed_upto for s in dep.servers) + 1
    n_noops = max(n_slots - trace.n_writes, 0)
    cfg = dict(model_cfg, announce_interval=1.0)
    if n_noops and n_ranges:
        cfg.update(skip_fraction=n_noops / n_slots,
                   skip_batch=n_noops / n_ranges)
    return cfg


def _vanilla_spaxos_deployment(f: int = 1, payload_factor: float = 1.0,
                               n_clients: int = 3, seed: int = 0,
                               state_machine: str = "kv",
                               ) -> VanillaSPaxosDeployment:
    del payload_factor  # table-only knob: message *counts* are size-blind
    return VanillaSPaxosDeployment(f=f, n_clients=n_clients,
                                   state_machine=state_machine, seed=seed)


def _vanilla_spaxos_station_of(addr: str, dep: Any) -> Optional[str]:
    """Fused-server bucketing: server 0 carries the colocated leader role
    (the model's ``leader`` machine); the other 2f are ``follower``s."""
    role, _, idx = addr.partition("/")
    if role != "server":
        return None
    return "leader" if idx == "0" else "follower"


def _craq_deployment(n_nodes: int = 3, skew_p: float = 0.0,
                     dirty_fraction: float = 0.5, n_clients: int = 2,
                     seed: int = 0, state_machine: str = "kv",
                     ) -> CraqDeployment:
    # skew/dirty parameterize the table; the run's actual forwarding
    # fraction is measured and fed back by _craq_feedback
    del skew_p, dirty_fraction, state_machine  # chain nodes are always kv
    return CraqDeployment(n_nodes=n_nodes, n_clients=n_clients, seed=seed)


def _craq_station_of(addr: str, dep: Any) -> Optional[str]:
    role, _, idx = addr.partition("/")
    if role != "chain":
        return None
    i = int(idx)
    if i == 0:
        return "head"
    return "tail" if i == len(dep.chain_addrs) - 1 else "chain"


def _craq_feedback(model_cfg: Config, trace: ExecutionTrace) -> Config:
    """Feed the measured dirty-read forwarding fraction into the table:
    with concurrent writers even a nominally uniform run forwards some
    reads to the tail while their key is dirty.  A *user* config that
    pins its own skew knobs keeps them (the workload adapter's
    ``dirty_fraction`` is a hint; the measured fraction replaces it)."""
    if trace.n_reads == 0 or trace.config.get("skew_p"):
        return model_cfg
    forwarded = sum(n.tail_forwards for n in trace.deployment.nodes)
    # the table's forwarded fraction is skew_p * dirty_fraction, over
    # reads that land on the k-1 non-tail nodes
    k = len(trace.deployment.chain_addrs)
    p_fwd = forwarded / trace.n_reads * k / max(k - 1, 1)
    return dict(model_cfg, skew_p=min(p_fwd, 1.0), dirty_fraction=1.0)


def _unreplicated_deployment(n_clients: int = 2, seed: int = 0,
                             state_machine: str = "kv", batch_size: int = 1,
                             n_batchers: int = 0, n_unbatchers: int = 0,
                             ) -> UnreplicatedStateMachine:
    if n_batchers or n_unbatchers or batch_size != 1:
        raise ValueError("the unreplicated execution plane is unbatched; "
                         "batching knobs parameterize the table only")
    return UnreplicatedStateMachine(n_clients=n_clients, seed=seed,
                                    state_machine=state_machine)


# Parity notes per plane (all measured write-only unless stated):
# * compartmentalized / spaxos: station totals per command are
#   deterministic (random quorum/column picks move messages *within* a
#   station, never across), so tolerances are tight and the headline
#   leader counts (2 msgs/cmd; S-Paxos: 2 id-only msgs) are exact.
# * multipaxos: the fused-machine model folds the leader machine's
#   acceptor role and chosen-recv into its follower/reply terms slightly
#   differently than the wire counts them - the leader row lands within
#   ~5%, followers are exact in expectation.
# * mencius: exact once the run's announce/skip parameters are fed back;
#   the proxy row absorbs range-path edge messages.
# * craq: message-exact chain accounting; under mixed workloads the
#   measured forwarding fraction is fed back.
# * vanilla_mencius: the fused table omits the owner machine's own
#   colocated acceptor vote and chosen-recv (local facts on a fused
#   server); the wire plane lands within ~2% once skips are fed back.
# * vanilla_spaxos: wire totals match the table exactly (self-sends are
#   counted on both sides, like the model); only the thrifty quorum draw
#   moves acceptor messages between the leader and follower rows.
register_executable(
    "compartmentalized",
    deployment=_compartmentalized_deployment,
    exact_stations=("leader",),
    rel_tolerance=0.10,
    n_clients=3,
    description="CompartmentalizedMultiPaxos cluster (paper sections 3-4)",
)

register_executable(
    "multipaxos",
    deployment=_multipaxos_deployment,
    station_of=_multipaxos_station_of,
    rel_tolerance=0.10,
    reads_as_writes=True,  # the vanilla table has no read path (paper s.3)
    n_clients=2,
    description="vanilla MultiPaxos (self-broadcast leader, fused servers)",
)

register_executable(
    "mencius",
    deployment=_mencius_deployment,
    model_feedback=_mencius_feedback,
    rel_tolerance=0.10,
    station_tolerances=(("proxy", 0.25),),
    n_clients=3,
    description="MenciusDeployment (round-robin leaders + range skips)",
)

register_executable(
    "spaxos",
    deployment=_spaxos_deployment,
    exact_stations=("leader",),
    rel_tolerance=0.10,
    n_clients=2,
    description="SPaxosDeployment (id-ordering leader, data-path split)",
)

register_executable(
    "craq",
    deployment=_craq_deployment,
    station_of=_craq_station_of,
    model_feedback=_craq_feedback,
    rel_tolerance=0.10,
    n_clients=2,
    description="CraqDeployment chain (dirty reads forward to the tail)",
)

register_executable(
    "vanilla_mencius",
    deployment=_vanilla_mencius_deployment,
    model_feedback=_vanilla_mencius_feedback,
    rel_tolerance=0.10,
    reads_as_writes=True,  # the fused table has no read path (paper Fig. 25)
    n_clients=3,
    description="VanillaMenciusDeployment (fused leader+acceptor+replica)",
)

register_executable(
    "vanilla_spaxos",
    deployment=_vanilla_spaxos_deployment,
    station_of=_vanilla_spaxos_station_of,
    rel_tolerance=0.10,
    reads_as_writes=True,  # the fused table has no read path (paper Fig. 27)
    n_clients=3,
    description="VanillaSPaxosDeployment (fused servers, leader on 0)",
)

register_executable(
    "unreplicated",
    deployment=_unreplicated_deployment,
    exact_stations=("server",),
    rel_tolerance=0.05,
    n_clients=2,
    description="UnreplicatedStateMachine upper bound",
)
