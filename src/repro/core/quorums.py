"""Quorum systems: majority quorums and r x w acceptor grids.

Compartmentalization 2 (paper section 3.2) decouples *read* quorums from
*write* quorums using flexible quorums [Howard et al., OPODIS 2016]: the only
requirement for safety is that every read quorum intersects every write
quorum.  Arranging the ``r * w`` acceptors in an ``r x w`` grid and taking
rows as read quorums and columns as write quorums satisfies this: every row
crosses every column.

- each acceptor handles ``1/w`` of writes  (scale writes: add columns)
- each acceptor handles ``1/r`` of reads   (scale reads:  add rows)
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import FrozenSet, List, Sequence, Tuple


class QuorumSystem:
    """Abstract quorum system over acceptor ids ``0..n-1``."""

    n: int

    def read_quorums(self) -> List[FrozenSet[int]]:
        raise NotImplementedError

    def write_quorums(self) -> List[FrozenSet[int]]:
        raise NotImplementedError

    def is_read_quorum(self, acks: Sequence[int]) -> bool:
        s = set(acks)
        return any(q <= s for q in self.read_quorums())

    def is_write_quorum(self, acks: Sequence[int]) -> bool:
        s = set(acks)
        return any(q <= s for q in self.write_quorums())

    def validate(self) -> None:
        """Safety: every read quorum intersects every write quorum."""
        for rq in self.read_quorums():
            for wq in self.write_quorums():
                if not (rq & wq):
                    raise AssertionError(
                        f"read quorum {sorted(rq)} does not intersect "
                        f"write quorum {sorted(wq)}"
                    )

    # -- load accounting used by the analytical model ----------------------
    def write_load(self) -> float:
        """Fraction of writes the busiest acceptor must process (one thrifty
        write quorum chosen uniformly at random per write)."""
        wqs = self.write_quorums()
        per = [0.0] * self.n
        for q in wqs:
            for a in q:
                per[a] += 1.0 / len(wqs)
        return max(per)

    def read_load(self) -> float:
        rqs = self.read_quorums()
        per = [0.0] * self.n
        for q in rqs:
            for a in q:
                per[a] += 1.0 / len(rqs)
        return max(per)


@dataclass(frozen=True)
class MajorityQuorums(QuorumSystem):
    """Classic 2f+1 majority quorums (reads == writes == any majority)."""

    f: int

    @property
    def n(self) -> int:  # type: ignore[override]
        return 2 * self.f + 1

    def _majorities(self) -> List[FrozenSet[int]]:
        from itertools import combinations

        k = self.f + 1
        return [frozenset(c) for c in combinations(range(self.n), k)]

    def read_quorums(self) -> List[FrozenSet[int]]:
        return self._majorities()

    def write_quorums(self) -> List[FrozenSet[int]]:
        return self._majorities()


@dataclass(frozen=True)
class GridQuorums(QuorumSystem):
    """``rows x cols`` acceptor grid; rows read, columns write.

    Acceptor ids are row-major: acceptor (i, j) has id ``i * cols + j``.
    Requires rows >= f+1 and cols >= f+1 so that an entire row (column) of
    failures can be tolerated on the opposite axis.
    """

    rows: int
    cols: int

    @property
    def n(self) -> int:  # type: ignore[override]
        return self.rows * self.cols

    def acceptor_id(self, row: int, col: int) -> int:
        return row * self.cols + col

    def row_members(self, row: int) -> FrozenSet[int]:
        return frozenset(self.acceptor_id(row, j) for j in range(self.cols))

    def col_members(self, col: int) -> FrozenSet[int]:
        return frozenset(self.acceptor_id(i, col) for i in range(self.rows))

    def read_quorums(self) -> List[FrozenSet[int]]:
        return [self.row_members(i) for i in range(self.rows)]

    def write_quorums(self) -> List[FrozenSet[int]]:
        return [self.col_members(j) for j in range(self.cols)]

    def tolerates(self, f: int) -> bool:
        """With any f acceptors down there must remain one live read quorum
        *or* recovery path; the paper requires rows, cols >= f+1 so that f
        failures cannot kill every row nor every column."""
        return self.rows >= f + 1 and self.cols >= f + 1


def pick_write_quorum(
    system: QuorumSystem, rng_value: int, dead: FrozenSet[int] = frozenset()
) -> Tuple[int, FrozenSet[int]]:
    """Thrifty write-quorum selection: deterministic in ``rng_value``.

    Skips quorums containing known-dead acceptors; raises if none is live.
    Returns (index, members).
    """
    wqs = system.write_quorums()
    k = len(wqs)
    for off in range(k):
        idx = (rng_value + off) % k
        if not (wqs[idx] & dead):
            return idx, wqs[idx]
    raise RuntimeError("no live write quorum")


def pick_read_quorum(
    system: QuorumSystem, rng_value: int, dead: FrozenSet[int] = frozenset()
) -> Tuple[int, FrozenSet[int]]:
    rqs = system.read_quorums()
    k = len(rqs)
    for off in range(k):
        idx = (rng_value + off) % k
        if not (rqs[idx] & dead):
            return idx, rqs[idx]
    raise RuntimeError("no live read quorum")
