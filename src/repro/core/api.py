"""The performance-plane public API: a pluggable protocol-variant registry
and a workload-first evaluation surface.

The paper's closing argument is that compartmentalization is "a technique,
not a protocol": practitioners should be able to apply it to *their*
protocol incrementally.  This module makes that claim executable.  A
protocol variant is not a branch in a sweep loop - it is a declarative
:class:`VariantSpec`: a name, a knob space (knob name -> value iterable,
including coupled knobs like ``(rows, cols)`` acceptor grids), a model
factory, and the station slots the variant's demand table emits.
:func:`register_variant` installs it, after which the variant rides the
entire batched stack with **zero core-file edits**:

* ``SweepSpec(variants=(..., "your_variant"))`` enumerates its knob
  product (``repro.core.sweep``),
* the canonical station vocabulary (:data:`STATION_ORDER`) grows by
  stable, append-ordered allocation, so its demand rows batch into the
  same dense tensors as every built-in protocol,
* ``autotune_variants`` searches it under a machine budget via its
  declared ``candidate_knobs``,
* ``CompiledSweep.transient`` scripts it through time,
* and - when the spec also declares an :class:`ExecutableSpec` - the
  variant's **real cluster** executes, linearizability-checks and
  measured-vs-analytical parity-checks through
  ``repro.core.execution.run_variant`` / ``validate_variant``: two
  planes, one registry.

The second abstraction is :class:`Workload`: "90% reads, Zipf-skewed on a
hot key, bursty arrivals, batches half full" is **one value passed once**
instead of an ``f_write`` scalar plus scattered kwargs.  Engines consume
the parts they understand: every engine blends write/read demand by
``f_write``; variants that declare a ``workload_adapter`` additionally
reshape their demand tables under skew or partial batch fill (CRAQ's
dirty-read forwarding, batcher amortization); the transient engine turns
``arrival="bursty"`` into scripted demand-surge windows.

This module is dependency-light on purpose (stdlib only): the registry
must be importable by tooling (``scripts/check_docs_links.py`` validates
variant names cited in the docs) without dragging in JAX.

Legacy compatibility: every evaluation entry point that used to take a
bare ``f_write=`` scalar still accepts it, funneled through
:func:`resolve_workload`, which emits a ``DeprecationWarning`` and wraps
the scalar in a :class:`Workload`.
"""
from __future__ import annotations

import contextlib
import itertools
import warnings
import zlib
from collections import abc
from dataclasses import dataclass, replace
from typing import (
    Any,
    Callable,
    Dict,
    Iterator,
    List,
    Mapping,
    Optional,
    Sequence,
    Tuple,
    Union,
)

Config = Dict[str, Any]

#: Reserved sweep-axis names a knob may not shadow (``SweepSpec`` fields
#: that are not knob value iterables).
_RESERVED_KNOB_NAMES = frozenset({"f", "variants", "knob_values"})


# ---------------------------------------------------------------------------
# Workload: the evaluation point, passed once
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class Workload:
    """A workload mix as one value: write fraction, per-key skew, arrival
    pattern and batch-fill hints.

    Fields and which engine consumes them:

    * ``f_write`` - fraction of commands that are writes.  Every engine:
      the effective demand is ``f_w * d_write + (1 - f_w) * d_read``.
    * ``skew_p`` - probability an operation targets the hot key (0 =
      uniform).  Consumed by variants whose :class:`VariantSpec` declares
      a ``workload_adapter`` (CRAQ: skewed dirty reads forward to the
      tail); key-agnostic variants ignore it - which is exactly the
      paper's Fig. 33 claim.
    * ``dirty_fraction`` - fraction of hot-key reads that find the key
      dirty (write in flight).  A hint for adapters that do not solve the
      throughput fixed point (``craq_model`` does; the sweep-axis table
      takes the hint).
    * ``arrival`` - ``"steady"`` (default) or ``"bursty"``.  The
      transient engine scripts bursty arrivals as demand-surge windows:
      during a burst every station's demand is multiplied by
      ``burst_factor`` (offered load transiently exceeds provisioned
      capacity), for ``burst_fraction`` of the run split across
      ``n_bursts`` evenly spaced surges.
    * ``batch_fill`` - fraction of batch slots that actually fill (1.0 =
      full batches).  Variants with batchers amortize downstream demand
      by the *effective* batch size ``1 + (B - 1) * batch_fill`` - under
      sparse arrivals batching buys less (paper Figs. 30-31 as a knob).
    """

    f_write: float = 1.0
    skew_p: float = 0.0
    dirty_fraction: float = 0.5
    arrival: str = "steady"
    burst_factor: float = 4.0
    burst_fraction: float = 0.25
    n_bursts: int = 3
    batch_fill: float = 1.0
    name: Optional[str] = None

    def __post_init__(self) -> None:
        for fname in ("f_write", "skew_p", "dirty_fraction", "batch_fill"):
            v = getattr(self, fname)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"Workload.{fname} must be in [0, 1]: {v}")
        if self.arrival not in ("steady", "bursty"):
            raise ValueError(
                f"Workload.arrival must be 'steady' or 'bursty': "
                f"{self.arrival!r}")
        if not 0.0 < self.burst_fraction < 1.0:
            raise ValueError(
                f"Workload.burst_fraction must be in (0, 1): "
                f"{self.burst_fraction}")
        if self.burst_factor <= 0.0:
            raise ValueError(
                f"Workload.burst_factor must be positive: {self.burst_factor}")
        if self.n_bursts < 1:
            raise ValueError(f"Workload.n_bursts must be >= 1: {self.n_bursts}")

    @property
    def f_read(self) -> float:
        return 1.0 - self.f_write

    @classmethod
    def read_mix(cls, read_fraction: float, **kwargs: Any) -> "Workload":
        """Workload from a read fraction (``read_mix(0.9)`` = 90% reads)."""
        return cls(f_write=1.0 - read_fraction, **kwargs)

    @property
    def adapts_demands(self) -> bool:
        """True when variant ``workload_adapter``s must be consulted (the
        workload reshapes demand tables beyond the write/read blend)."""
        return self.skew_p > 0.0 or self.batch_fill < 1.0

    def describe(self) -> str:
        parts = [f"{100 * self.f_read:.0f}% reads"]
        if self.skew_p > 0:
            parts.append(f"skew p={self.skew_p:g}")
        if self.arrival != "steady":
            parts.append(f"{self.arrival} x{self.burst_factor:g}")
        if self.batch_fill < 1.0:
            parts.append(f"batch fill {self.batch_fill:g}")
        label = ", ".join(parts)
        return f"{self.name} ({label})" if self.name else label


#: Common evaluation points (the paper's three workload mixes).
WRITE_ONLY = Workload(f_write=1.0, name="write_only")
MIXED_50_50 = Workload(f_write=0.5, name="50pct_reads")
READ_HEAVY = Workload(f_write=0.1, name="90pct_reads")


def as_f_write(workload_or_f: Union["Workload", float]) -> float:
    """The scalar write fraction of either a :class:`Workload` or a bare
    float (the scalar model plane's native blend parameter)."""
    if isinstance(workload_or_f, Workload):
        return workload_or_f.f_write
    return float(workload_or_f)


def resolve_workload(workload: Optional[Union["Workload", float]] = None,
                     f_write: Optional[float] = None,
                     *,
                     default: Optional["Workload"] = None,
                     where: str = "this call") -> "Workload":
    """Coerce the ``(workload, legacy f_write kwarg)`` pair to a Workload.

    The deprecation shim behind every evaluation entry point: passing the
    old ``f_write=`` scalar (or a bare float where a Workload is
    expected) still works but warns; pass ``Workload(f_write=...)``
    instead."""
    if f_write is not None:
        if workload is not None:
            raise TypeError(
                f"{where}: pass either workload= or the legacy f_write=, "
                f"not both")
        warnings.warn(
            f"{where}: f_write= is deprecated; pass "
            f"workload=Workload(f_write=...) instead",
            DeprecationWarning, stacklevel=3)
        return Workload(f_write=float(f_write))
    if workload is None:
        return default if default is not None else Workload()
    if isinstance(workload, Workload):
        return workload
    if isinstance(workload, (int, float)) and not isinstance(workload, bool):
        warnings.warn(
            f"{where}: a bare write-fraction scalar is deprecated; pass "
            f"workload=Workload(f_write=...) instead",
            DeprecationWarning, stacklevel=3)
        return Workload(f_write=float(workload))
    raise TypeError(f"{where}: expected a Workload (or legacy float), got "
                    f"{type(workload).__name__}")


# ---------------------------------------------------------------------------
# ShardingSpec: the shard axis, as one value
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ShardingSpec:
    """State partitioned across ``n_shards`` independent replicated groups.

    A sharded system runs N copies of a registered variant, each owning a
    hash partition of the key space; clients route by key.  One spec
    drives every plane:

    * **analytical / sweep / transient** - each shard ``s`` sees a
      fraction ``w_s`` of the traffic, so its station demands are the
      per-command table scaled by ``w_s`` (probabilistic-routing visit
      ratios).  The sharded demand tensor ``[M, S, K]`` flattens to
      ``[M, S*K]`` and flows through the *same* jitted MVA/fluid/scan
      paths; the bottleneck law becomes
      ``T = min_s alpha / (w_s * max_k d[k])`` - uniform weights
      multiply peak throughput by exactly ``n_shards``.
    * **execution** - ``shard_of(key)`` is stable crc32 hash routing
      (never Python's per-process randomized ``hash``), used by
      :class:`~repro.core.execution.ShardedDeployment` for client-side
      routing and by the history partitioner for per-key-partition
      linearizability checks.

    Per-shard weights reuse the :class:`Workload` skew machinery: under
    ``skew_p > 0`` the shard owning the hot key absorbs
    ``skew_p + (1 - skew_p) / S`` of the traffic (hot key plus its share
    of the uniform remainder) and every other shard
    ``(1 - skew_p) / S``.  Explicit ``weights`` override the derivation
    (they are normalized); ``hot_key`` names the key whose owner is the
    hot shard (the execution harness's hot key is ``"hot"``).
    """

    n_shards: int = 1
    weights: Optional[Tuple[float, ...]] = None
    hot_key: str = "hot"

    def __post_init__(self) -> None:
        if self.n_shards < 1:
            raise ValueError(
                f"ShardingSpec.n_shards must be >= 1: {self.n_shards}")
        if self.weights is not None:
            w = tuple(float(x) for x in self.weights)
            if len(w) != self.n_shards:
                raise ValueError(
                    f"ShardingSpec.weights must have n_shards="
                    f"{self.n_shards} entries: got {len(w)}")
            if any(x < 0.0 for x in w) or sum(w) <= 0.0:
                raise ValueError(
                    f"ShardingSpec.weights must be non-negative with a "
                    f"positive sum: {w}")
            object.__setattr__(self, "weights", w)

    def shard_of(self, key: Any) -> int:
        """Stable hash routing: which shard owns ``key``.  crc32 keeps the
        mapping identical across processes and runs (Python's builtin
        ``hash`` is randomized per process)."""
        return zlib.crc32(str(key).encode()) % self.n_shards

    @property
    def hot_shard(self) -> int:
        """The shard that owns the workload's hot key."""
        return self.shard_of(self.hot_key)

    def resolved_weights(
            self, workload: Optional["Workload"] = None) -> Tuple[float, ...]:
        """Per-shard traffic fractions, normalized to sum to 1.

        Explicit ``weights`` win; otherwise the :class:`Workload` skew
        derives them (hot shard ``skew_p + (1 - skew_p)/S``, the rest
        ``(1 - skew_p)/S``); with no skew the split is uniform."""
        s = self.n_shards
        if self.weights is not None:
            total = sum(self.weights)
            return tuple(x / total for x in self.weights)
        p = workload.skew_p if workload is not None else 0.0
        if p <= 0.0 or s == 1:
            return (1.0 / s,) * s
        base = (1.0 - p) / s
        return tuple(base + p if i == self.hot_shard else base
                     for i in range(s))

    def describe(self) -> str:
        if self.weights is not None:
            w = ", ".join(f"{x:g}" for x in self.resolved_weights())
            return f"{self.n_shards} shards (weights {w})"
        return f"{self.n_shards} shards"


#: The degenerate single-group spec (every plane's implicit default).
UNSHARDED = ShardingSpec(n_shards=1)


# ---------------------------------------------------------------------------
# GeoSpec: the geo axis, as one value
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class GeoSpec:
    """A geo-replicated deployment as one value: named regions, a
    directed per-region-pair RTT matrix, a placement (which region hosts
    each station replica) and per-region client weights.

    One spec drives every plane:

    * **analytical / sweep** - ``repro.core.geo`` lowers each registered
      variant's message flow into critical-path WAN round trips per op
      class (write commit path, read-quorum path, CRAQ chain hops),
      producing per-region latency offsets that compose with the jitted
      MVA queueing latencies (``CompiledSweep.geo_latency``);
    * **execution** - :meth:`latency_fn` realizes the same matrix on the
      deterministic message-level network, so ``run_variant`` measures
      per-region latencies that parity-check against the analytical
      critical path (``validate_variant(geo=...)``);
    * **batched execution** - ``execute_configs(geo=...)`` fans every
      config into per-region lanes (one closed-loop client population
      per region) whose latency histograms carry the WAN offsets.

    Conventions: ``rtt[i][j]`` is the *round-trip* time for a message
    leaving region ``i`` toward ``j`` and its reply, in the same
    virtual-time units as the network's ``default_latency`` (must be
    square, zero-diagonal, non-negative; asymmetric matrices are allowed
    - e.g. a healing path after a region outage - and :attr:`symmetric`
    reports whether the matrix is direction-free); a one-way hop costs
    ``local_delay + rtt/2`` (local
    hops, including self-sends, cost ``local_delay`` - the uniform
    all-zero matrix therefore reproduces today's single-delay numbers
    exactly).  ``placement`` maps a station kind (the ``role`` part of a
    ``role/<i>`` address) to a cycle of region indices: replica ``i`` of
    kind ``k`` lives in ``placement[k][i % len(placement[k])]``; kinds
    without an entry default to the round-robin cycle ``i % n_regions``.
    Clients are split into contiguous blocks by ``client_weights``
    (largest-remainder apportionment; uniform when ``None``).
    """

    regions: Tuple[str, ...]
    rtt: Tuple[Tuple[float, ...], ...]
    placement: Tuple[Tuple[str, Tuple[int, ...]], ...] = ()
    client_weights: Optional[Tuple[float, ...]] = None
    local_delay: float = 1.0

    def __post_init__(self) -> None:
        regions = tuple(str(r) for r in self.regions)
        if not regions:
            raise ValueError("GeoSpec needs at least one region")
        if len(set(regions)) != len(regions):
            raise ValueError(f"GeoSpec region names must be unique: {regions}")
        object.__setattr__(self, "regions", regions)
        n = len(regions)
        rtt = tuple(tuple(float(x) for x in row) for row in self.rtt)
        if len(rtt) != n or any(len(row) != n for row in rtt):
            raise ValueError(
                f"GeoSpec.rtt must be a {n}x{n} matrix for regions {regions}")
        for i in range(n):
            if rtt[i][i] != 0.0:
                raise ValueError(
                    f"GeoSpec.rtt diagonal must be zero: rtt[{i}][{i}]="
                    f"{rtt[i][i]}")
            for j in range(n):
                if rtt[i][j] < 0.0:
                    raise ValueError(
                        f"GeoSpec.rtt must be non-negative: rtt[{i}][{j}]="
                        f"{rtt[i][j]}")
        object.__setattr__(self, "rtt", rtt)
        placement = tuple(
            (str(kind), tuple(int(r) for r in cycle))
            for kind, cycle in self.placement)
        for kind, cycle in placement:
            if not cycle:
                raise ValueError(
                    f"GeoSpec.placement[{kind!r}] must be a non-empty "
                    f"region-index cycle")
            for r in cycle:
                if not 0 <= r < n:
                    raise ValueError(
                        f"GeoSpec.placement[{kind!r}] region index {r} out "
                        f"of range for {n} regions")
        if len(set(k for k, _ in placement)) != len(placement):
            raise ValueError("GeoSpec.placement kinds must be unique")
        object.__setattr__(self, "placement", placement)
        if self.client_weights is not None:
            w = tuple(float(x) for x in self.client_weights)
            if len(w) != n:
                raise ValueError(
                    f"GeoSpec.client_weights must have {n} entries: "
                    f"got {len(w)}")
            if any(x < 0.0 for x in w) or sum(w) <= 0.0:
                raise ValueError(
                    f"GeoSpec.client_weights must be non-negative with a "
                    f"positive sum: {w}")
            object.__setattr__(self, "client_weights", w)
        if self.local_delay < 0.0:
            raise ValueError(
                f"GeoSpec.local_delay must be non-negative: "
                f"{self.local_delay}")

    @classmethod
    def uniform(cls, n_regions: int = 3, local_delay: float = 1.0,
                **kwargs: Any) -> "GeoSpec":
        """An all-zero-RTT matrix over ``n_regions`` regions: region
        labels exist but every hop costs ``local_delay`` - byte-identical
        behaviour to a geo-less deployment."""
        names = tuple(f"r{i}" for i in range(n_regions))
        zero = tuple((0.0,) * n_regions for _ in range(n_regions))
        return cls(regions=names, rtt=zero, local_delay=local_delay,
                   **kwargs)

    @property
    def n_regions(self) -> int:
        return len(self.regions)

    @property
    def is_uniform(self) -> bool:
        """True when every inter-region RTT is zero (the degenerate case
        that must reproduce single-delay numbers exactly)."""
        return all(x == 0.0 for row in self.rtt for x in row)

    @property
    def symmetric(self) -> bool:
        """True when ``rtt[i][j] == rtt[j][i]`` for every pair - the
        direction-free case ``wan_offsets`` keeps exact.  Directed
        matrices (a congested heal path after a region outage) are
        legal; each hop reads its own directed half-RTT."""
        n = self.n_regions
        return all(self.rtt[i][j] == self.rtt[j][i]
                   for i in range(n) for j in range(i + 1, n))

    def one_way(self, i: int, j: int) -> float:
        """WAN half-RTT between regions ``i`` and ``j`` (0 for i == j);
        the *extra* delay a hop pays on top of ``local_delay``."""
        return 0.0 if i == j else self.rtt[i][j] / 2.0

    def hop_delay(self, i: int, j: int) -> float:
        """Total one-way message delay between regions ``i`` and ``j``."""
        return self.local_delay + self.one_way(i, j)

    def region_of(self, kind: str, index: int) -> int:
        """Region index hosting replica ``index`` of station ``kind``."""
        for k, cycle in self.placement:
            if k == kind:
                return cycle[index % len(cycle)]
        return index % self.n_regions

    def resolved_client_weights(self) -> Tuple[float, ...]:
        """Per-region client traffic fractions, normalized to sum to 1."""
        if self.client_weights is None:
            return (1.0 / self.n_regions,) * self.n_regions
        total = sum(self.client_weights)
        return tuple(x / total for x in self.client_weights)

    def client_counts(self, n_clients: int) -> Tuple[int, ...]:
        """How many of ``n_clients`` closed-loop clients sit in each
        region (largest-remainder apportionment of the weights)."""
        w = self.resolved_client_weights()
        quotas = [x * n_clients for x in w]
        counts = [int(q) for q in quotas]
        rem = n_clients - sum(counts)
        order = sorted(range(len(w)), key=lambda i: quotas[i] - counts[i],
                       reverse=True)
        for i in order[:rem]:
            counts[i] += 1
        return tuple(counts)

    def client_region(self, index: int, n_clients: int) -> int:
        """Region of client ``index``: clients form contiguous blocks in
        region order, sized by :meth:`client_counts`."""
        counts = self.client_counts(n_clients)
        edge = 0
        for r, c in enumerate(counts):
            edge += c
            if index < edge:
                return r
        return self.n_regions - 1

    def latency_fn(self, n_clients: int) -> Callable[[str, str], float]:
        """The network's per-message delay function realizing this spec:
        ``delay(src, dst) = local_delay + one_way(region(src),
        region(dst))``.  Client addresses split into contiguous
        per-region blocks; station addresses follow :meth:`region_of`."""
        def region_of_addr(addr: str) -> int:
            kind, _, idx = addr.partition("/")
            i = int(idx) if idx.isdigit() else 0
            if kind == "client":
                return self.client_region(i, n_clients)
            return self.region_of(kind, i)

        def delay(src: str, dst: str) -> float:
            return self.local_delay + self.one_way(
                region_of_addr(src), region_of_addr(dst))

        return delay

    def relabeled(self, perm: Sequence[int]) -> "GeoSpec":
        """The same physical deployment with regions renumbered by
        ``perm`` (``perm[new] = old``).  Placement-autotune results must
        be invariant under this transformation (up to the relabeling)."""
        p = tuple(int(i) for i in perm)
        if sorted(p) != list(range(self.n_regions)):
            raise ValueError(
                f"relabeled() needs a permutation of range({self.n_regions})"
                f": got {p}")
        inv = [0] * len(p)
        for new, old in enumerate(p):
            inv[old] = new
        return GeoSpec(
            regions=tuple(self.regions[old] for old in p),
            rtt=tuple(tuple(self.rtt[a][b] for b in p) for a in p),
            placement=tuple((kind, tuple(inv[r] for r in cycle))
                            for kind, cycle in self.placement),
            client_weights=(None if self.client_weights is None else
                            tuple(self.client_weights[old] for old in p)),
            local_delay=self.local_delay)

    def describe(self) -> str:
        w = ", ".join(f"{x:g}" for x in self.resolved_client_weights())
        return (f"{self.n_regions} regions ({', '.join(self.regions)}; "
                f"client weights {w})")


# ---------------------------------------------------------------------------
# AutoscalePolicy: the elastic-control contract, as one value
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class AutoscalePolicy:
    """An elastic-scaling policy as one declarative value.

    The policy is the *contract* the autoscale controller
    (``repro.core.autoscale.Controller``) enforces per control window:

    * ``target_low`` / ``target_high`` - the per-station utilization
      band.  A station above ``target_high`` gains one server; a station
      below ``target_low`` loses one, but only when the *predicted*
      post-drain utilization ``u * c / (c - 1)`` stays at or under
      ``target_high`` (the hysteresis guard: a drain whose inverse add
      would immediately re-trigger is never taken, so constant load
      converges to zero actions);
    * ``queue_high`` - mean queue depth per server that forces an add
      even inside the utilization band (the queue-based load-leveling
      signal; ``0`` disables it);
    * ``cooldown_windows`` - control windows that must pass after any
      action before the next one (reconfiguration has a modelled demand
      spike; back-to-back resizes would stack spikes);
    * ``min_counts`` / ``max_counts`` - per-station floors/ceilings as
      ``(station, count)`` pairs; stations without an entry fall back to
      1 / unbounded.  Floors also thread through
      ``autotune.variant_candidate_configs`` so the tuner never proposes
      a config the policy would be unable to hold;
    * ``machine_budget`` - total-machine ceiling across all stations
      (``None`` = unbounded); adds that would exceed it are skipped;
    * ``spike_factor`` / ``spike_fraction`` - the modelled cost of a
      resize: the resized station's demand is multiplied by
      ``spike_factor`` for the first ``spike_fraction`` of the window
      the action lands in (``transient.reconfiguration_schedule``).

    Stdlib-only on purpose - the policy travels to the JAX-free
    execution plane (``execution.run_autoscaled``) unchanged.
    """

    target_low: float = 0.45
    target_high: float = 0.75
    queue_high: float = 0.0
    cooldown_windows: int = 1
    min_counts: Tuple[Tuple[str, int], ...] = ()
    max_counts: Tuple[Tuple[str, int], ...] = ()
    machine_budget: Optional[int] = None
    spike_factor: float = 1.5
    spike_fraction: float = 0.25

    def __post_init__(self) -> None:
        if not 0.0 < self.target_low < self.target_high <= 1.0:
            raise ValueError(
                f"AutoscalePolicy needs 0 < target_low < target_high <= 1: "
                f"got ({self.target_low}, {self.target_high})")
        if self.queue_high < 0.0:
            raise ValueError(
                f"AutoscalePolicy.queue_high must be non-negative: "
                f"{self.queue_high}")
        if self.cooldown_windows < 0:
            raise ValueError(
                f"AutoscalePolicy.cooldown_windows must be >= 0: "
                f"{self.cooldown_windows}")
        for label, pairs in (("min_counts", self.min_counts),
                             ("max_counts", self.max_counts)):
            norm = tuple((str(s), int(c)) for s, c in pairs)
            if any(c < 1 for _, c in norm):
                raise ValueError(
                    f"AutoscalePolicy.{label} entries must be >= 1: {norm}")
            if len(set(s for s, _ in norm)) != len(norm):
                raise ValueError(
                    f"AutoscalePolicy.{label} stations must be unique: "
                    f"{norm}")
            object.__setattr__(self, label, norm)
        for s, lo in self.min_counts:
            hi = self.max_for(s)
            if hi is not None and lo > hi:
                raise ValueError(
                    f"AutoscalePolicy: min_counts[{s!r}]={lo} exceeds "
                    f"max_counts[{s!r}]={hi}")
        if self.machine_budget is not None and self.machine_budget < 1:
            raise ValueError(
                f"AutoscalePolicy.machine_budget must be >= 1 or None: "
                f"{self.machine_budget}")
        if self.spike_factor < 1.0:
            raise ValueError(
                f"AutoscalePolicy.spike_factor must be >= 1 (a resize "
                f"never makes the window cheaper): {self.spike_factor}")
        if not 0.0 <= self.spike_fraction <= 1.0:
            raise ValueError(
                f"AutoscalePolicy.spike_fraction must be in [0, 1]: "
                f"{self.spike_fraction}")

    def min_for(self, station: str) -> int:
        """The policy's floor for ``station`` (1 when unpinned)."""
        for s, c in self.min_counts:
            if s == station:
                return c
        return 1

    def max_for(self, station: str) -> Optional[int]:
        """The policy's ceiling for ``station`` (None = unbounded)."""
        for s, c in self.max_counts:
            if s == station:
                return c
        return None

    def describe(self) -> str:
        bits = [f"band [{self.target_low:g}, {self.target_high:g}]",
                f"cooldown {self.cooldown_windows}w"]
        if self.queue_high > 0.0:
            bits.append(f"queue>{self.queue_high:g}")
        if self.machine_budget is not None:
            bits.append(f"budget {self.machine_budget}")
        return ", ".join(bits)


# ---------------------------------------------------------------------------
# Knobs + VariantSpec + ExecutableSpec: a protocol variant as a declaration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ExecutableSpec:
    """The *execution plane* of a variant: how to build and account for the
    real (deterministic, message-level) cluster behind the demand table.

    A variant with an executable is evaluated on **two planes from one
    registration**: the analytical plane (its ``factory`` demand table,
    swept/batched by ``repro.core.sweep``) and the execution plane (a real
    protocol cluster driven by ``repro.core.execution.run_variant``, whose
    measured per-station messages per command are parity-checked against
    the table by ``validate_variant``).

    * ``deployment(**config, n_clients=..., seed=...)`` builds the cluster
      (a ``repro.core.protocols.BaseDeployment``) from the **same
      canonical config dict** the analytical factory consumes (model-only
      knobs such as ``payload_factor`` are accepted and ignored);
    * ``station_of(addr, deployment) -> station | None`` buckets a node
      address into the canonical station vocabulary (``None`` = not a
      station, e.g. clients).  Default: the ``role/<i>`` address prefix
      when it names a declared station;
    * ``model_feedback(model_config, trace) -> model_config`` optionally
      feeds *measured* run statistics back into the demand table before
      the parity comparison (Mencius: the observed noop-skip rate and the
      per-command frontier announcements; CRAQ: the observed dirty-read
      forwarding fraction) so the comparison is apples-to-apples;
    * ``rel_tolerance`` / ``station_tolerances`` bound the allowed
      relative error per station (data, not code - the parity loop stays
      generic); ``exact_stations`` must match to 1e-9 (S-Paxos' leader:
      exactly 2 id-only msgs/cmd);
    * ``reads_as_writes`` - the protocol has no separate read path (the
      paper's vanilla baselines: reads go through the log like writes),
      so the harness drives reads as writes to match the table;
    * ``latency_tolerance`` bounds the relative error of the measured
      per-region mean latency vs the ``repro.core.geo`` critical-path
      prediction when ``validate_variant`` runs under a :class:`GeoSpec`
      (queueing and slot-ordering waits sit on top of the WAN path, so
      these are looser than the msgs/cmd tolerances);
    * ``n_clients`` is the default closed-loop client population.
    """

    deployment: Callable[..., Any]
    station_of: Optional[Callable[[str, Any], Optional[str]]] = None
    model_feedback: Optional[Callable[[Config, Any], Config]] = None
    rel_tolerance: float = 0.15
    station_tolerances: Tuple[Tuple[str, float], ...] = ()
    exact_stations: Tuple[str, ...] = ()
    reads_as_writes: bool = False
    latency_tolerance: float = 0.35
    n_clients: int = 3
    description: str = ""

    def tolerance_for(self, station: str) -> float:
        for name, tol in self.station_tolerances:
            if name == station:
                return tol
        return self.rel_tolerance


@dataclass(frozen=True)
class Knob:
    """One axis of a variant's knob space.

    ``name`` is the public sweep-axis name (a ``SweepSpec`` field for the
    built-ins, a ``knob_values`` key for runtime variants); ``keys`` are
    the config-dict entries one value sets.  A coupled knob has several
    keys and tuple values - e.g. the acceptor grid: ``name="grids"``,
    ``keys=("grid_rows", "grid_cols")``, values like ``(2, 2)``."""

    name: str
    keys: Tuple[str, ...]
    values: Tuple[Any, ...]

    def __post_init__(self) -> None:
        if not self.keys:
            raise ValueError(f"knob {self.name!r} has no config keys")
        if self.name in _RESERVED_KNOB_NAMES:
            raise ValueError(f"knob name {self.name!r} is reserved")

    def entries(self, value: Any) -> Iterator[Tuple[str, Any]]:
        """(config key, value) pairs one knob value expands to."""
        if len(self.keys) == 1:
            yield self.keys[0], value
            return
        vt = tuple(value)
        if len(vt) != len(self.keys):
            raise ValueError(
                f"knob {self.name!r} couples {len(self.keys)} keys "
                f"{self.keys} but got value {value!r}")
        yield from zip(self.keys, vt)


def knob(name: str, values: Sequence[Any],
         keys: Optional[Sequence[str]] = None) -> Knob:
    """Convenience :class:`Knob` builder (``keys`` defaults to ``name``)."""
    return Knob(name=name, keys=tuple(keys) if keys is not None else (name,),
                values=tuple(values))


@dataclass(frozen=True)
class VariantSpec:
    """A protocol variant, declaratively.

    * ``factory(**config)`` builds the variant's ``DeploymentModel``
      (the demand table);
    * ``stations`` are the canonical slot names the table emits -
      registration allocates any new name an append-ordered column in
      :data:`STATION_ORDER`;
    * ``knobs`` is the default sweep space (``SweepSpec`` fields and
      ``knob_values`` override per-knob);
    * ``takes_f`` - configs carry the fault-tolerance parameter ``f``;
    * ``implicit_variant_key`` - configs omit the ``variant`` key (the
      default ``compartmentalized`` variant, for backward compatibility
      with pre-registry config dicts);
    * ``workload_adapter(config, workload) -> config`` - optional hook
      reshaping the config under a :class:`Workload` (skew, batch fill).
      Consulted only when ``workload.adapts_demands``; must return the
      input dict *itself* (identity, not a copy) when it has nothing to
      do - callers use that to skip rebuilding the row's model;
    * ``candidate_knobs(budget, f) -> {knob name: values}`` - optional
      knob-space generator for the budgeted cross-variant autotuner
      (``autotune_variants``); variants without one contribute their
      default knob product (a single config for knobless baselines);
    * ``executable`` - the optional :class:`ExecutableSpec` execution
      plane: declare it (here or later via :func:`register_executable`)
      and the variant's real cluster runs, linearizability-checks and
      parity-checks through ``repro.core.execution`` with zero core-file
      edits.
    """

    name: str
    factory: Callable[..., Any]
    stations: Tuple[str, ...]
    knobs: Tuple[Knob, ...] = ()
    takes_f: bool = True
    implicit_variant_key: bool = False
    workload_adapter: Optional[Callable[[Config, "Workload"], Config]] = None
    candidate_knobs: Optional[
        Callable[[int, int], Mapping[str, Sequence[Any]]]] = None
    executable: Optional[ExecutableSpec] = None
    description: str = ""

    def __post_init__(self) -> None:
        if not self.name or not self.name.replace("_", "a").isalnum():
            raise ValueError(f"variant name must be a [a-z0-9_] identifier: "
                             f"{self.name!r}")
        if not self.stations:
            raise ValueError(f"variant {self.name!r} declares no stations")
        names = [k.name for k in self.knobs]
        if len(set(names)) != len(names):
            raise ValueError(f"variant {self.name!r} has duplicate knob "
                             f"names: {names}")
        keys = [key for k in self.knobs for key in k.keys]
        if len(set(keys)) != len(keys):
            raise ValueError(f"variant {self.name!r} has overlapping knob "
                             f"config keys: {keys}")

    def knob_names(self) -> Tuple[str, ...]:
        return tuple(k.name for k in self.knobs)

    def _values_for(self, k: Knob,
                    overrides: Mapping[str, Sequence[Any]]) -> Tuple[Any, ...]:
        values = tuple(overrides.get(k.name, k.values))
        if not values:
            raise ValueError(
                f"variant {self.name!r}: knob {k.name!r} has no values")
        return values

    def configs(self, f: int = 1,
                overrides: Mapping[str, Sequence[Any]] = {},
                ) -> Iterator[Config]:
        """The variant's knob product as config dicts.

        ``overrides`` replaces any declared knob's value iterable by
        name; unknown override names are rejected (a typo'd knob name
        silently sweeping nothing is the failure mode this API exists to
        kill)."""
        unknown = set(overrides) - set(self.knob_names())
        if unknown:
            raise ValueError(
                f"variant {self.name!r} has no knob(s) {sorted(unknown)}; "
                f"declared: {list(self.knob_names())}")
        spaces = [
            [tuple(k.entries(v)) for v in self._values_for(k, overrides)]
            for k in self.knobs
        ]
        for combo in itertools.product(*spaces):
            cfg: Config = {}
            if not self.implicit_variant_key:
                cfg["variant"] = self.name
            if self.takes_f:
                cfg["f"] = f
            for entries in combo:
                cfg.update(entries)
            yield cfg

    def size(self, overrides: Mapping[str, Sequence[Any]] = {}) -> int:
        """Cardinality of :meth:`configs` - computed arithmetically from
        the knob-space cardinalities, never by enumeration."""
        n = 1
        for k in self.knobs:
            n *= len(self._values_for(k, overrides))
        return n

    def adapt(self, config: Config,
              workload: Optional["Workload"]) -> Config:
        """The config with the ``variant`` key stripped and, when the
        workload carries demand-shaping hints, the ``workload_adapter``
        applied.  Returns the *same* dict object the adapter received
        when the adapter had nothing to do (callers key off identity to
        skip model rebuilds)."""
        cfg = {k: v for k, v in config.items() if k != "variant"}
        if (workload is not None and workload.adapts_demands
                and self.workload_adapter is not None):
            return self.workload_adapter(cfg, workload)
        return cfg

    def build(self, config: Config) -> Any:
        """``factory(**config)`` plus a station check: every station the
        model emits must be declared in ``stations`` (i.e. have a
        registered column), otherwise batched lowering would die with a
        bare ``KeyError`` deep in ``demand_slots``."""
        model = self.factory(**config)
        undeclared = [s.name for s in getattr(model, "stations", ())
                      if s.name not in _STATION_SLOTS]
        if undeclared:
            raise ValueError(
                f"variant {self.name!r} built a model emitting "
                f"station(s) {undeclared} that have no registered column "
                f"- list every station name the factory can emit in "
                f"register_variant(stations=...)")
        return model

    def model(self, config: Config,
              workload: Optional["Workload"] = None) -> Any:
        """Build the deployment model for one config, optionally adapted
        to a workload (skew / batch-fill hints)."""
        return self.build(self.adapt(config, workload))


# ---------------------------------------------------------------------------
# The registry + the derived canonical station vocabulary
# ---------------------------------------------------------------------------

_REGISTRY: "Dict[str, VariantSpec]" = {}
_STATIONS: List[str] = []
_STATION_SLOTS: Dict[str, int] = {}


def _allocate_stations(names: Sequence[str]) -> None:
    for n in names:
        if n not in _STATION_SLOTS:
            _STATION_SLOTS[n] = len(_STATIONS)
            _STATIONS.append(n)


def register_variant(spec: Optional[VariantSpec] = None, *,
                     override: bool = False,
                     **kwargs: Any) -> Union[VariantSpec, Callable]:
    """Install a :class:`VariantSpec` in the registry.

    Three call shapes::

        register_variant(VariantSpec(...))            # direct
        register_variant(name=..., factory=..., ...)  # kwargs
        @register_variant(name=..., stations=..., ...)  # decorator on the
        def my_model(...): ...                          # model factory

    Station slots are allocated append-ordered and never reclaimed
    (compiled sweeps address stations by column index), so registration
    order is load-bearing only for *new* station names.  Re-registering
    an existing name requires ``override=True``."""
    if spec is None and "factory" not in kwargs:
        def _decorate(factory: Callable[..., Any]) -> Callable[..., Any]:
            register_variant(VariantSpec(factory=factory, **kwargs),
                             override=override)
            return factory
        return _decorate
    if spec is None:
        spec = VariantSpec(**kwargs)
    elif kwargs:
        raise TypeError("pass either a VariantSpec or keyword fields, "
                        "not both")
    if not isinstance(spec, VariantSpec):
        raise TypeError(f"expected a VariantSpec, got {type(spec).__name__}")
    if spec.name in _REGISTRY and not override:
        raise ValueError(
            f"variant {spec.name!r} is already registered; pass "
            f"override=True to replace it")
    _allocate_stations(spec.stations)
    _REGISTRY[spec.name] = spec
    return spec


def unregister_variant(name: str) -> None:
    """Remove a variant from the registry (tests / plugin teardown).

    Its station slots stay allocated - the vocabulary is append-only
    because compiled demand tensors address columns by index."""
    if name not in _REGISTRY:
        raise ValueError(f"variant {name!r} is not registered")
    del _REGISTRY[name]


def register_executable(name: str,
                        executable: Optional[ExecutableSpec] = None,
                        *, override: bool = False,
                        **kwargs: Any) -> ExecutableSpec:
    """Attach an execution plane to an already-registered variant.

    Either pass an :class:`ExecutableSpec` or its keyword fields.  The
    variant's :class:`VariantSpec` is replaced in the registry with one
    carrying the executable; station slots are untouched.  Replacing an
    existing executable requires ``override=True``."""
    spec = variant_spec(name)
    if executable is None:
        executable = ExecutableSpec(**kwargs)
    elif kwargs:
        raise TypeError("pass either an ExecutableSpec or keyword fields, "
                        "not both")
    if not isinstance(executable, ExecutableSpec):
        raise TypeError(
            f"expected an ExecutableSpec, got {type(executable).__name__}")
    if spec.executable is not None and not override:
        raise ValueError(
            f"variant {name!r} already declares an executable; pass "
            f"override=True to replace it")
    _REGISTRY[name] = replace(spec, executable=executable)
    return executable


def variant_spec(name: str) -> VariantSpec:
    """Look up a registered variant (ValueError names the known set)."""
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(f"unknown variant {name!r}; choose from "
                         f"{sorted(_REGISTRY)}") from None


def registered_variants() -> Tuple[str, ...]:
    """Registered variant names, in registration order."""
    return tuple(_REGISTRY)


def executable_variants() -> Tuple[str, ...]:
    """Names of variants that declare an execution plane, in registration
    order - the domain of ``repro.core.execution.run_variant`` /
    ``validate_variant`` and of the ``msgcount`` parity benchmark's
    zero-branch loop."""
    return tuple(n for n, s in _REGISTRY.items() if s.executable is not None)


@contextlib.contextmanager
def temporary_variants() -> Iterator[None]:
    """Scope runtime registrations: on exit the registry is restored to
    its entry snapshot, so a test's ``register_variant`` /
    ``register_executable`` calls cannot leak into other tests' registry
    views.  Station slots allocated inside the scope stay allocated - the
    station vocabulary is append-only because compiled demand tensors
    address columns by index (re-registering the same variant later
    reuses its columns)."""
    snapshot = dict(_REGISTRY)
    try:
        yield
    finally:
        _REGISTRY.clear()
        _REGISTRY.update(snapshot)


class _StationOrder(abc.Sequence):
    """Live, registry-derived view of the canonical station vocabulary.

    Behaves like the tuple it replaced (indexing, ``len``, iteration,
    ``.index``, containment) but grows append-ordered as variants with
    new station names register.  Existing column indices never change."""

    def __getitem__(self, i):  # supports slices like a tuple
        return tuple(_STATIONS)[i] if isinstance(i, slice) else _STATIONS[i]

    def __len__(self) -> int:
        return len(_STATIONS)

    def __contains__(self, name: object) -> bool:
        return name in _STATION_SLOTS

    def index(self, name: str, *args: Any) -> int:
        if args:  # honor tuple.index's start/stop bounds
            return tuple(_STATIONS).index(name, *args)
        try:
            return _STATION_SLOTS[name]
        except KeyError:
            raise ValueError(f"{name!r} is not a registered station") from None

    def __eq__(self, other: object) -> bool:
        return tuple(_STATIONS) == other

    def __hash__(self):  # keep usable as a dict key like the old tuple
        return hash(tuple(_STATIONS))

    def __repr__(self) -> str:
        return f"StationOrder{tuple(_STATIONS)!r}"


class _StationIndex(abc.Mapping):
    """Live ``station name -> column`` mapping (see :class:`_StationOrder`)."""

    def __getitem__(self, name: str) -> int:
        return _STATION_SLOTS[name]

    def __iter__(self) -> Iterator[str]:
        return iter(_STATIONS)

    def __len__(self) -> int:
        return len(_STATIONS)

    def __repr__(self) -> str:
        return f"StationIndex({dict(_STATION_SLOTS)!r})"


class _VariantModels(abc.Mapping):
    """Live ``variant name -> model factory`` view of the registry (the
    pre-registry ``VARIANT_MODELS`` dict, kept as a compatibility
    surface)."""

    def __getitem__(self, name: str) -> Callable[..., Any]:
        return _REGISTRY[name].factory

    def __iter__(self) -> Iterator[str]:
        return iter(_REGISTRY)

    def __len__(self) -> int:
        return len(_REGISTRY)

    def __repr__(self) -> str:
        return (f"VariantModels({{" +
                ", ".join(f"{n!r}: {s.factory.__name__}"
                          for n, s in _REGISTRY.items()) + "})")


#: Canonical station vocabulary - one fixed, append-ordered column per
#: station name any registered variant emits.  Derived from the registry;
#: import the *object* (it is live), never snapshot it at import time if
#: runtime variant registration matters to you.
STATION_ORDER = _StationOrder()

#: Live ``station name -> column index`` mapping over :data:`STATION_ORDER`.
STATION_INDEX = _StationIndex()

#: Live ``variant name -> factory`` mapping (compatibility view of the
#: registry; prefer :func:`variant_spec` for the full declaration).
VARIANT_MODELS = _VariantModels()
