"""Deterministic state machines replicated by the protocols.

Commands are tuples ``(opcode, *args)``:

KVStore:   ("put", k, v) -> "ok"     | ("get", k) -> value | None
           ("cas", k, expect, v) -> bool
Register:  ("w", v) -> "ok"          | ("r",) -> value
AppendLog: ("append", v) -> index    | ("read",) -> tuple(log)

Reads (``("get", ...)``, ``("r",)``, ``("read",)``) never modify state, which
is what makes the leaderless read path of compartmentalization 4 safe.
"""
from __future__ import annotations

import copy
from typing import Any, Dict, List, Tuple

from .messages import NOOP


class StateMachine:
    def apply(self, op: Tuple) -> Any:
        raise NotImplementedError

    def is_read(self, op: Tuple) -> bool:
        raise NotImplementedError

    def snapshot(self) -> Any:
        raise NotImplementedError

    def restore(self, snap: Any) -> None:
        raise NotImplementedError

    def apply_checked(self, op: Tuple) -> Any:
        if op and op[0] == NOOP:
            return None
        return self.apply(op)


class KVStore(StateMachine):
    """The paper's evaluation state machine: integer keys, small values."""

    def __init__(self) -> None:
        self.data: Dict[Any, Any] = {}

    def apply(self, op: Tuple) -> Any:
        code = op[0]
        if code == "put":
            _, k, v = op
            self.data[k] = v
            return "ok"
        if code == "get":
            return self.data.get(op[1])
        if code == "cas":
            _, k, expect, v = op
            if self.data.get(k) == expect:
                self.data[k] = v
                return True
            return False
        raise ValueError(f"unknown op {op!r}")

    def is_read(self, op: Tuple) -> bool:
        return op[0] == "get"

    def snapshot(self) -> Any:
        return copy.deepcopy(self.data)

    def restore(self, snap: Any) -> None:
        self.data = copy.deepcopy(snap)


class Register(StateMachine):
    """Single register - the object used in the linearizability proofs."""

    def __init__(self, initial: Any = None) -> None:
        self.value = initial

    def apply(self, op: Tuple) -> Any:
        if op[0] == "w":
            self.value = op[1]
            return "ok"
        if op[0] == "r":
            return self.value
        raise ValueError(f"unknown op {op!r}")

    def is_read(self, op: Tuple) -> bool:
        return op[0] == "r"

    def snapshot(self) -> Any:
        return self.value

    def restore(self, snap: Any) -> None:
        self.value = snap


class AppendLog(StateMachine):
    """An append-only log; handy for checking total-order properties."""

    def __init__(self) -> None:
        self.log: List[Any] = []

    def apply(self, op: Tuple) -> Any:
        if op[0] == "append":
            self.log.append(op[1])
            return len(self.log) - 1
        if op[0] == "read":
            return tuple(self.log)
        raise ValueError(f"unknown op {op!r}")

    def is_read(self, op: Tuple) -> bool:
        return op[0] == "read"

    def snapshot(self) -> Any:
        return list(self.log)

    def restore(self, snap: Any) -> None:
        self.log = list(snap)


def make_state_machine(kind: str) -> StateMachine:
    if kind == "kv":
        return KVStore()
    if kind == "register":
        return Register()
    if kind == "appendlog":
        return AppendLog()
    raise ValueError(f"unknown state machine {kind!r}")
