"""Compartmentalized Mencius (paper section 6).

Mencius round-robin partitions the log across ``m`` leaders: leader ``i``
owns slots ``{k : k % m == i}``.  A leader that lags fills its vacant slots
with noops ("skip") so replicas can keep executing in prefix order.  The
compartmentalized deployment (paper Fig. 24) reuses the MultiPaxos roles:
proxy leaders, acceptor grids, scaled replicas, and the leaderless read path.

Skips are implemented with ``Phase2aRange`` - a single message that votes for
noops in every owner-owned slot of ``[start, stop)`` - standing in for the
Coordinated Paxos sub-protocol the paper references.
"""
from __future__ import annotations

import random
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .cluster import Network, Node
from .history import History
from .messages import (
    Batch,
    Chosen,
    ChosenRange,
    ClientRequest,
    NextSlotAnnounce,
    Phase2a,
    Phase2aRange,
    Phase2b,
    Phase2bRange,
    noop_command,
)
from .protocols import BaseDeployment, DeploymentConfig
from .quorums import GridQuorums, MajorityQuorums, QuorumSystem
from .roles import Acceptor, Client, ProxyLeader, Replica
from .statemachine import make_state_machine


class MenciusLeader(Node):
    """One of ``m`` Mencius leaders; sequences only its owned slots."""

    def __init__(
        self,
        addr: str,
        leader_id: int,
        n_leaders: int,
        peers: Sequence[str],
        proxies: Sequence[str],
        seed: int = 0,
    ) -> None:
        super().__init__(addr)
        self.leader_id = leader_id
        self.n_leaders = n_leaders
        self.peers = [p for p in peers if p != addr]
        self.proxies = list(proxies)
        self.rng = random.Random(seed * 48271 + leader_id)
        # next owned slot = next_round * m + leader_id
        self.next_round = 0
        self._proxy_rr = 0
        self.ballot = 0  # every lane starts at ballot 0 (lane = leader_id)
        self.skips_issued = 0

    @property
    def next_slot(self) -> int:
        return self.next_round * self.n_leaders + self.leader_id

    def _send_to_proxy(self, msg: Any) -> None:
        proxy = self.proxies[self._proxy_rr % len(self.proxies)]
        self._proxy_rr += 1
        self.send(proxy, msg)

    def _announce(self) -> None:
        for p in self.peers:
            self.send(p, NextSlotAnnounce(leader_id=self.leader_id,
                                          next_slot=self.next_slot))

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, (ClientRequest, Batch)):
            value = msg.command if isinstance(msg, ClientRequest) else msg
            slot = self.next_slot
            self.next_round += 1
            self._send_to_proxy(Phase2a(slot=slot, ballot=self.ballot, value=value,
                                        leader_id=self.leader_id))
            self._announce()
        elif isinstance(msg, NextSlotAnnounce):
            # Lagging? fill every owned vacant slot below the peer's frontier
            # with noops so replicas are not stalled by our holes.
            if msg.next_slot > self.next_slot:
                start = self.next_slot
                stop = msg.next_slot
                self._send_to_proxy(Phase2aRange(ballot=self.ballot,
                                                 owner=self.leader_id,
                                                 start=start, stop=stop,
                                                 n_leaders=self.n_leaders))
                self.skips_issued += 1
                # advance frontier past the filled range
                while self.next_slot < stop:
                    self.next_round += 1


class MenciusDeployment(BaseDeployment):
    """Compartmentalized Mencius: m leaders + proxies + grid + replicas."""

    def __init__(
        self,
        n_leaders: int = 3,
        f: int = 1,
        n_proxy_leaders: int = 4,
        grid: Optional[Tuple[int, int]] = (2, 2),
        n_replicas: int = 3,
        n_clients: int = 3,
        state_machine: str = "kv",
        consistency: str = "linearizable",
        seed: int = 0,
        latency_fn: Optional[Callable[[str, str], float]] = None,
    ) -> None:
        self.net = Network(seed=seed, latency_fn=latency_fn)
        self.history = History()
        self.n_leaders = n_leaders

        if grid is not None:
            self.quorums: QuorumSystem = GridQuorums(rows=grid[0], cols=grid[1])
        else:
            self.quorums = MajorityQuorums(f=f)
        self.quorums.validate()

        self.acceptor_addrs = [f"acceptor/{i}" for i in range(self.quorums.n)]
        self.replica_addrs = [f"replica/{i}" for i in range(n_replicas)]
        self.proxy_addrs = [f"proxy/{i}" for i in range(n_proxy_leaders)]
        self.leader_addrs = [f"leader/{i}" for i in range(n_leaders)]

        self.acceptors = [Acceptor(a, i) for i, a in enumerate(self.acceptor_addrs)]
        self.replicas = [
            Replica(addr, i, n_replicas, make_state_machine(state_machine), seed=seed)
            for i, addr in enumerate(self.replica_addrs)
        ]
        self.proxies = [
            ProxyLeader(addr, self.acceptor_addrs, self.quorums, self.replica_addrs,
                        seed=seed)
            for addr in self.proxy_addrs
        ]
        self.leaders = [
            MenciusLeader(addr, i, n_leaders, self.leader_addrs, self.proxy_addrs,
                          seed=seed)
            for i, addr in enumerate(self.leader_addrs)
        ]
        # client i talks to leader i % m (paper: any leader)
        self.clients = [
            Client(f"client/{i}", i, self.leader_addrs[i % n_leaders],
                   self.acceptor_addrs, self.quorums, self.replica_addrs,
                   consistency=consistency, history=self.history, seed=seed)
            for i in range(n_clients)
        ]
        for group in (self.acceptors, self.replicas, self.proxies, self.leaders,
                      self.clients):
            self.net.add_nodes(group)

    def total_skips(self) -> int:
        return sum(l.skips_issued for l in self.leaders)


# ---------------------------------------------------------------------------
# Vanilla (fused-server) Mencius - paper Fig. 25 baseline
# ---------------------------------------------------------------------------


class VanillaMenciusServer(Replica):
    """One fused vanilla-Mencius server: Mencius leader + acceptor + replica
    in a single process, matching the fused accounting of
    ``vanilla_mencius_model`` (every machine plays every role; there are no
    proxies or grids).

    Phase 2 is self-broadcast to a thrifty quorum of *peer* servers - the
    machine's own acceptor vote is a local fact, exactly the cost the fused
    table omits - and ``Chosen`` goes over the wire to the ``m - 1`` peers
    while the local replica component applies directly.  All lanes run at
    ballot 0 (the failure-free baseline the table models), so the acceptor
    component reduces to voting; the replica component is inherited whole
    from :class:`~repro.core.roles.Replica` (prefix-order execution,
    slot-ownership replies, exactly-once client table).
    """

    def __init__(self, addr: str, server_id: int, n_servers: int, f: int,
                 peers: Sequence[str], state_machine, seed: int = 0) -> None:
        super().__init__(addr, server_id, n_servers, state_machine, seed=seed)
        self.server_id = server_id
        self.n_servers = n_servers
        self.quorum = f + 1  # majority f+1 among the 2f peers (valid: 2f >= f+1)
        self.peers = [p for p in peers if p != addr]
        self.lane_rng = random.Random(seed * 48271 + server_id)
        self.next_round = 0
        self.ballot = 0
        self.skips_issued = 0
        # self-broadcast phase-2 state: slot -> peer-acceptor acks
        self.pending2: Dict[int, Set[int]] = {}
        self.pending_ranges: Dict[Tuple[int, int], Set[int]] = {}
        self._proposed: Dict[int, Any] = {}  # slot -> in-flight command

    @property
    def next_slot(self) -> int:
        return self.next_round * self.n_servers + self.server_id

    def _peer_quorum(self) -> List[str]:
        return self.lane_rng.sample(self.peers, self.quorum)

    def _chose(self, slot: int, value: Any) -> None:
        """Quorum complete: wire Chosen to the peers, apply locally free."""
        for p in self.peers:
            self.send(p, Chosen(slot=slot, value=value))
        if slot not in self.log:
            self.log[slot] = value
            self._execute_ready()

    def _chose_range(self, start: int, stop: int) -> None:
        for p in self.peers:
            self.send(p, ChosenRange(owner=self.server_id, start=start,
                                     stop=stop, n_leaders=self.n_servers))
        noop = noop_command()
        for slot in range(start, stop):
            if slot % self.n_servers == self.server_id and slot not in self.log:
                self.log[slot] = noop
        self._execute_ready()

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, ClientRequest):
            slot = self.next_slot
            self.next_round += 1
            self.pending2[slot] = set()
            self._proposed[slot] = msg.command
            for p in self.peers:  # announce the new frontier (interval = 1)
                self.send(p, NextSlotAnnounce(leader_id=self.server_id,
                                              next_slot=self.next_slot))
            for p in self._peer_quorum():
                self.send(p, Phase2a(slot=slot, ballot=self.ballot,
                                     value=msg.command,
                                     leader_id=self.server_id))
        elif isinstance(msg, Phase2a):  # acceptor component: vote
            self.send(src, Phase2b(slot=msg.slot, ballot=msg.ballot,
                                   acceptor_id=self.server_id))
        elif isinstance(msg, Phase2b):
            acks = self.pending2.get(msg.slot)
            if acks is None:
                return
            acks.add(msg.acceptor_id)
            if len(acks) == self.quorum:
                del self.pending2[msg.slot]
                self._chose(msg.slot, self._proposed.pop(msg.slot))
        elif isinstance(msg, NextSlotAnnounce):
            if msg.next_slot > self.next_slot:
                start, stop = self.next_slot, msg.next_slot
                self.pending_ranges[(start, stop)] = set()
                for p in self._peer_quorum():
                    self.send(p, Phase2aRange(ballot=self.ballot,
                                              owner=self.server_id,
                                              start=start, stop=stop,
                                              n_leaders=self.n_servers))
                self.skips_issued += 1
                while self.next_slot < stop:
                    self.next_round += 1
        elif isinstance(msg, Phase2aRange):  # acceptor component: range vote
            self.send(src, Phase2bRange(ballot=msg.ballot, owner=msg.owner,
                                        start=msg.start, stop=msg.stop,
                                        acceptor_id=self.server_id))
        elif isinstance(msg, Phase2bRange):
            key = (msg.start, msg.stop)
            acks = self.pending_ranges.get(key)
            if acks is None:
                return
            acks.add(msg.acceptor_id)
            if len(acks) == self.quorum:
                del self.pending_ranges[key]
                self._chose_range(msg.start, msg.stop)
        else:  # Chosen / ChosenRange from peers -> replica component
            super().on_message(src, msg)


class VanillaMenciusDeployment(BaseDeployment):
    """m = 2f+1 fused Mencius servers, no proxies/grids (paper Fig. 25)."""

    def __init__(
        self,
        f: int = 1,
        n_clients: int = 3,
        state_machine: str = "kv",
        consistency: str = "linearizable",
        seed: int = 0,
        latency_fn: Optional[Callable[[str, str], float]] = None,
    ) -> None:
        self.net = Network(seed=seed, latency_fn=latency_fn)
        self.history = History()
        m = 2 * f + 1
        self.n_servers = m
        self.server_addrs = [f"server/{i}" for i in range(m)]
        self.servers = [
            VanillaMenciusServer(addr, i, m, f, self.server_addrs,
                                 make_state_machine(state_machine), seed=seed)
            for i, addr in enumerate(self.server_addrs)
        ]
        quorums = MajorityQuorums(f=f)
        # client i talks to server i % m; the fused table has no read path,
        # so the executable declares reads_as_writes and every op lands here
        self.clients = [
            Client(f"client/{i}", i, self.server_addrs[i % m], [], quorums,
                   [], consistency=consistency, history=self.history,
                   seed=seed)
            for i in range(n_clients)
        ]
        self.net.add_nodes(self.servers)
        self.net.add_nodes(self.clients)

    @property
    def replicas(self) -> List[VanillaMenciusServer]:
        return self.servers  # every fused server executes the log

    def total_skips(self) -> int:
        return sum(s.skips_issued for s in self.servers)
