"""ISS-style round-robin log buckets across leaders (multi-leader family).

"State-Machine Replication Scalability Made Simple" (PAPERS.md,
arXiv 2203.05681 - ISS/Mir) multiplexes the log across leaders at
*bucket* granularity: keys hash into ``n_buckets`` buckets, each bucket
is an independent FIFO lane, and bucket ownership **rotates round-robin
across leaders every ``epoch_length`` commands** so no single leader
owns a hot bucket forever.  Because buckets partition the key space,
cross-bucket commands commute - replicas execute each bucket's lane in
prefix order against a shared state machine and linearizability holds
without a global total order (the bucketing insight this module pins in
``tests/test_multileader_property.py``).

Past the leaders, the deployment is the paper's compartmentalized tail
reused verbatim: proxy leaders, an ``r x w`` acceptor grid, scaled
replicas (``repro.core.roles``).  A bucket's ``seq``-th command travels
as log slot ``seq * n_buckets + bucket`` - globally unique, decoded back
by the replicas.

Leader-station accounting per command (client entry + proxy handoff is
2 msgs; a request entering at a non-owner leader is forwarded, 2 msgs
per hop; an epoch rotation broadcasts new ownership to the other
``L - 1`` leaders, 2(L-1) msgs per rotation):

    leader   (2 + 2 phi + 2 (L-1) rho) / L     phi = forward hops/cmd,
                                               rho = rotations/cmd
    proxy    (1 + 2 col + n) / P               col = grid write column
    acceptor 2 / w                             (station total 2 col / r w)
    replica  1 + 1/n

``phi``/``rho`` depend on request timing, so the executable measures them
and feeds them back (``forward_fraction``, ``rotations_per_cmd`` model
knobs - the Mencius skip-feedback pattern); the analytical default is the
uniform-routing expectation ``phi = (L-1)/L``.  Reads travel the ordered
bucket path like writes (ISS has no leaderless read quorum), so the read
column equals the write column everywhere.
"""
from __future__ import annotations

import zlib
from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .analytical import DeploymentModel, Station
from .api import knob, register_executable, register_variant
from .cluster import Network, Node
from .history import History
from .messages import Chosen, ClientReply, ClientRequest, Command, Phase2a, is_noop
from .protocols import BaseDeployment
from .quorums import GridQuorums, MajorityQuorums, QuorumSystem
from .roles import Acceptor, Client, ProxyLeader
from .statemachine import make_state_machine


@dataclass(frozen=True)
class IssBucketOwner:
    """Rotation broadcast: ``bucket`` is owned by leader ``owner`` from
    ``next_seq`` on (epoch ``epoch``).  Sent by the outgoing owner to all
    other leaders; the incoming owner picks up the lane from it."""

    bucket: int
    owner: int
    next_seq: int
    epoch: int


def bucket_of(key: Any, n_buckets: int) -> int:
    """crc32 key hashing, same routing family as ``ShardingSpec``."""
    return zlib.crc32(str(key).encode()) % n_buckets


class IssLeader(Node):
    """One of ``L`` leaders; sequences the buckets it currently owns.

    Ownership of bucket ``b`` during epoch ``e = seq // epoch_length`` is
    leader ``(b + e) % L``.  A request for a bucket this leader does not
    own is forwarded to the believed owner (one hop per stale belief -
    measured, not modelled away)."""

    def __init__(self, addr: str, leader_id: int, n_leaders: int,
                 n_buckets: int, epoch_length: int, peers: Sequence[str],
                 proxies: Sequence[str]) -> None:
        super().__init__(addr)
        self.leader_id = leader_id
        self.n_leaders = n_leaders
        self.n_buckets = n_buckets
        self.epoch_length = epoch_length
        self.peers = [p for p in peers if p != addr]
        self.proxies = list(proxies)
        self._proxy_rr = 0
        self.ballot = 0  # failure-free: every lane runs at ballot 0
        # bucket -> next sequence number, for the buckets this leader owns
        self.owned: Dict[int, int] = {
            b: 0 for b in range(n_buckets) if b % n_leaders == leader_id}
        self.believed: Dict[int, int] = {
            b: b % n_leaders for b in range(n_buckets)}
        self.bucket_epoch: Dict[int, int] = {b: 0 for b in range(n_buckets)}
        self.forward_hops = 0
        self.rotations = 0

    def _send_to_proxy(self, msg: Any) -> None:
        proxy = self.proxies[self._proxy_rr % len(self.proxies)]
        self._proxy_rr += 1
        self.send(proxy, msg)

    def _propose(self, bucket: int, command: Command) -> None:
        seq = self.owned[bucket]
        self.owned[bucket] = seq + 1
        slot = seq * self.n_buckets + bucket
        self._send_to_proxy(Phase2a(slot=slot, ballot=self.ballot,
                                    value=command,
                                    leader_id=self.leader_id))
        if self.n_leaders > 1 and (seq + 1) % self.epoch_length == 0:
            self._rotate(bucket, seq + 1)

    def _rotate(self, bucket: int, next_seq: int) -> None:
        epoch = next_seq // self.epoch_length
        new_owner = (bucket + epoch) % self.n_leaders
        del self.owned[bucket]
        self.believed[bucket] = new_owner
        self.bucket_epoch[bucket] = epoch
        self.rotations += 1
        msg = IssBucketOwner(bucket=bucket, owner=new_owner,
                             next_seq=next_seq, epoch=epoch)
        for p in self.peers:
            self.send(p, msg)

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, ClientRequest):
            b = bucket_of(_key_of(msg.command), self.n_buckets)
            if b in self.owned:
                self._propose(b, msg.command)
            else:
                # forward to the believed owner; a stale belief costs one
                # more hop once the rotation broadcast lands
                self.forward_hops += 1
                self.send(f"leader/{self.believed[b]}", msg)
        elif isinstance(msg, IssBucketOwner):
            # rotation broadcasts carry strictly increasing epochs per
            # bucket; ignore anything stale (reordered under jitter)
            if msg.epoch > self.bucket_epoch[msg.bucket]:
                self.bucket_epoch[msg.bucket] = msg.epoch
                self.believed[msg.bucket] = msg.owner
                if msg.owner == self.leader_id:
                    self.owned[msg.bucket] = msg.next_seq


def _key_of(cmd: Command) -> Any:
    op = cmd.op
    return op[1] if len(op) > 1 else "_"


class IssReplica(Node):
    """Executes each bucket's lane in prefix order against one shared
    state machine (buckets partition keys, so lanes commute); replies for
    the slots it owns round-robin."""

    def __init__(self, addr: str, replica_index: int, n_replicas: int,
                 n_buckets: int, state_machine,
                 client_addr_fn=lambda cid: f"client/{cid}") -> None:
        super().__init__(addr)
        self.replica_index = replica_index
        self.n_replicas = n_replicas
        self.n_buckets = n_buckets
        self.sm = state_machine
        self.client_addr_fn = client_addr_fn
        self.logs: Dict[int, Dict[int, Command]] = {
            b: {} for b in range(n_buckets)}
        self.executed_upto: Dict[int, int] = {
            b: -1 for b in range(n_buckets)}
        self.executed_by_bucket: Dict[int, List[Tuple[int, Any]]] = {
            b: [] for b in range(n_buckets)}

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, Chosen):
            b = msg.slot % self.n_buckets
            seq = msg.slot // self.n_buckets
            if seq not in self.logs[b]:
                self.logs[b][seq] = msg.value
                self._execute_bucket(b)

    def _execute_bucket(self, b: int) -> None:
        log = self.logs[b]
        while (self.executed_upto[b] + 1) in log:
            seq = self.executed_upto[b] + 1
            self.executed_upto[b] = seq
            cmd = log[seq]
            result = None if is_noop(cmd) else self.sm.apply_checked(cmd.op)
            self.executed_by_bucket[b].append((seq, cmd.uid))
            slot = seq * self.n_buckets + b
            if slot % self.n_replicas == self.replica_index:
                self.send(self.client_addr_fn(cmd.client_id),
                          ClientReply(command_uid=cmd.uid, result=result,
                                      slot=None))


class IssDeployment(BaseDeployment):
    """L bucket-rotating leaders + the compartmentalized tail (proxies,
    acceptor grid, per-bucket replicas).  Client ``i`` enters at leader
    ``i % L``; the bucket routing (and its forwarding cost) is the
    protocol's own job."""

    def __init__(
        self,
        n_leaders: int = 3,
        n_buckets: int = 4,
        epoch_length: int = 4,
        f: int = 1,
        n_proxy_leaders: int = 10,
        grid: Optional[Tuple[int, int]] = (2, 2),
        n_replicas: int = 4,
        n_clients: int = 3,
        state_machine: str = "kv",
        consistency: str = "linearizable",
        seed: int = 0,
        latency_fn: Optional[Callable[[str, str], float]] = None,
    ) -> None:
        if n_buckets < 1:
            raise ValueError(f"n_buckets must be >= 1: {n_buckets}")
        if epoch_length < 1:
            raise ValueError(f"epoch_length must be >= 1: {epoch_length}")
        self.net = Network(seed=seed, latency_fn=latency_fn)
        self.history = History()
        self.n_leaders = n_leaders
        self.n_buckets = n_buckets

        if grid is not None:
            self.quorums: QuorumSystem = GridQuorums(rows=grid[0],
                                                     cols=grid[1])
        else:
            self.quorums = MajorityQuorums(f=f)
        self.quorums.validate()

        self.acceptor_addrs = [f"acceptor/{i}"
                               for i in range(self.quorums.n)]
        self.replica_addrs = [f"replica/{i}" for i in range(n_replicas)]
        self.proxy_addrs = [f"proxy/{i}" for i in range(n_proxy_leaders)]
        self.leader_addrs = [f"leader/{i}" for i in range(n_leaders)]

        self.acceptors = [Acceptor(a, i)
                          for i, a in enumerate(self.acceptor_addrs)]
        self.replicas = [
            IssReplica(addr, i, n_replicas, n_buckets,
                       make_state_machine(state_machine))
            for i, addr in enumerate(self.replica_addrs)
        ]
        self.proxies = [
            ProxyLeader(addr, self.acceptor_addrs, self.quorums,
                        self.replica_addrs, seed=seed)
            for addr in self.proxy_addrs
        ]
        self.leaders = [
            IssLeader(addr, i, n_leaders, n_buckets, epoch_length,
                      self.leader_addrs, self.proxy_addrs)
            for i, addr in enumerate(self.leader_addrs)
        ]
        # empty acceptor/replica lists: reads take the ordered bucket path
        self.clients = [
            Client(f"client/{i}", i, self.leader_addrs[i % n_leaders],
                   [], self.quorums, [], consistency=consistency,
                   history=self.history, seed=seed)
            for i in range(n_clients)
        ]
        for group in (self.acceptors, self.replicas, self.proxies,
                      self.leaders, self.clients):
            self.net.add_nodes(group)

    def total_forward_hops(self) -> int:
        return sum(l.forward_hops for l in self.leaders)

    def total_rotations(self) -> int:
        return sum(l.rotations for l in self.leaders)


# ---------------------------------------------------------------------------
# Analytical model + registration (both planes, zero core edits)
# ---------------------------------------------------------------------------


def iss_model(
    n_leaders: int = 3,
    n_buckets: int = 4,
    epoch_length: int = 4,
    f: int = 1,
    n_proxy_leaders: int = 10,
    grid_rows: int = 2,
    grid_cols: int = 2,
    n_replicas: int = 4,
    forward_fraction: Optional[float] = None,
    rotations_per_cmd: float = 0.0,
) -> DeploymentModel:
    """ISS bucket-rotation demand table (derivation in the module
    docstring).  ``n_buckets`` shapes key partitioning, not message
    counts; ``epoch_length`` enters through the measured rotation rate.
    ``forward_fraction=None`` means the uniform-routing expectation
    ``(L-1)/L``; the executable's feedback loop replaces both overhead
    knobs with measured values."""
    L = n_leaders
    if L < 1:
        raise ValueError(f"n_leaders must be >= 1: {L}")
    if n_buckets < 1:
        raise ValueError(f"n_buckets must be >= 1: {n_buckets}")
    if epoch_length < 1:
        raise ValueError(f"epoch_length must be >= 1: {epoch_length}")
    phi = (L - 1) / L if forward_fraction is None else forward_fraction
    if L == 1:
        phi, rotations_per_cmd = 0.0, 0.0
    r, w = grid_rows, grid_cols
    col = r  # write-quorum size (one grid column)
    leader = (2.0 + 2.0 * phi + 2.0 * (L - 1) * rotations_per_cmd) / L
    proxy = (1 + 2 * col + n_replicas) / max(n_proxy_leaders, 1)
    replica = 1.0 + 1.0 / n_replicas
    stations = (
        Station("leader", L, leader, leader),
        Station("proxy", max(n_proxy_leaders, 1), proxy, proxy),
        Station("acceptor", r * w, 2.0 / w, 2.0 / w),
        Station("replica", n_replicas, replica, replica),
    )
    return DeploymentModel(
        name=(f"iss(L={L},B={n_buckets},E={epoch_length},"
              f"p={n_proxy_leaders},grid={r}x{w},n={n_replicas})"),
        stations=stations,
    )


def _iss_candidates(budget: int, f: int) -> Dict[str, tuple]:
    """Coarsened candidate space under a machine budget: buckets and a
    long epoch are fixed (neither moves the failure-free demand table),
    the leader/proxy/grid/replica axes absorb the budget."""
    min_grid = f + 1
    max_proxies = max(budget - (1 + min_grid + (f + 1)), 1)
    max_replicas = max(budget - (1 + 1 + min_grid), f + 1)
    return {
        "n_leaders": tuple(range(1, min(budget, 5) + 1)),
        "n_buckets": (8,),
        "epoch_length": (64,),
        "n_proxy_leaders": tuple(range(1, min(max_proxies, 8) + 1)),
        "grids": ((2 * f + 1, 1), (f + 1, f + 1)),
        "n_replicas": tuple(range(f + 1, min(max_replicas, f + 7) + 1)),
    }


def _iss_deployment(n_leaders: int = 3, n_buckets: int = 4,
                    epoch_length: int = 4, f: int = 1,
                    n_proxy_leaders: int = 10, grid_rows: int = 2,
                    grid_cols: int = 2, n_replicas: int = 4,
                    forward_fraction: Optional[float] = None,
                    rotations_per_cmd: float = 0.0, n_clients: int = 3,
                    seed: int = 0,
                    state_machine: str = "kv",
                    latency_fn: Optional[Callable[[str, str], float]] = None,
                    ) -> IssDeployment:
    # forwarding/rotation knobs parameterize the *table*; the protocol's
    # own routing behaviour is measured and fed back by _iss_feedback
    del forward_fraction, rotations_per_cmd
    return IssDeployment(n_leaders=n_leaders, n_buckets=n_buckets,
                         epoch_length=epoch_length, f=f,
                         n_proxy_leaders=n_proxy_leaders,
                         grid=(grid_rows, grid_cols), n_replicas=n_replicas,
                         n_clients=n_clients, state_machine=state_machine,
                         seed=seed, latency_fn=latency_fn)


def _iss_feedback(model_cfg: Dict[str, Any], trace: Any) -> Dict[str, Any]:
    """Read the run's own routing statistics into the table: measured
    forward hops per command and rotation broadcasts per command, instead
    of the uniform-routing assumption."""
    dep = trace.deployment
    n = max(trace.n_commands, 1)
    return dict(model_cfg,
                forward_fraction=dep.total_forward_hops() / n,
                rotations_per_cmd=dep.total_rotations() / n)


register_variant(
    name="iss",
    factory=iss_model,
    stations=("leader", "proxy", "acceptor", "replica"),
    knobs=(
        knob("n_leaders", (3,)),
        knob("n_buckets", (4,)),
        knob("epoch_length", (4,)),
        knob("n_proxy_leaders", (10,)),
        knob("grids", ((2, 2),), keys=("grid_rows", "grid_cols")),
        knob("n_replicas", (4,)),
    ),
    takes_f=True,
    candidate_knobs=_iss_candidates,
    description="ISS/Mir round-robin log buckets rotating across leaders "
                "(arXiv 2203.05681)",
)

register_executable(
    "iss",
    deployment=_iss_deployment,
    model_feedback=_iss_feedback,
    # the tail is message-deterministic (exact at any mix); the leader
    # station carries seed-dependent forwarding/rotation timing, exact
    # only against its own run's feedback, so the batched plane (probes
    # at a different seed) gets a real tolerance
    exact_stations=("proxy", "acceptor", "replica"),
    station_tolerances=(("leader", 0.35),),
    rel_tolerance=0.10,
    n_clients=3,
    description="Bucket-rotating multi-leader log over the "
                "compartmentalized tail",
)
