"""CRAQ and Chain Replication (the paper's section 8.4 comparison).

Chain Replication [van Renesse & Schneider, OSDI'04]: nodes form a chain;
writes flow head -> tail; acks flow tail -> head; the head replies to the
client.  Reads are served by the tail only.

CRAQ [Terrace & Freedman, ATC'09]: any node may serve a read of a *clean*
key immediately; a read of a *dirty* key (unacknowledged write in flight) is
forwarded to the tail, which serves it from the latest committed version.
This is what makes CRAQ skew-sensitive (paper Fig. 33): hot keys are dirty
more often, funnelling reads to the tail.
"""
from __future__ import annotations

import random
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

from .cluster import Network, Node
from .history import History
from .messages import (
    ChainAck,
    ChainRead,
    ChainWrite,
    ClientReply,
    ClientRequest,
    Command,
    ReadReply,
    Timer,
    VersionQuery,
)
from .protocols import BaseDeployment


class ChainNode(Node):
    def __init__(self, addr: str, index: int, chain: Sequence[str],
                 reads_anywhere: bool = True) -> None:
        super().__init__(addr)
        self.index = index
        self.chain = list(chain)
        self.reads_anywhere = reads_anywhere  # True: CRAQ; False: CR (tail reads)
        # key -> list of (version, value); committed = versions <= clean_upto[key]
        self.versions: Dict[Any, List[Tuple[int, Any]]] = {}
        self.clean_upto: Dict[Any, int] = {}
        self.next_version = 0
        # head only: version -> command (for the client reply)
        self.inflight: Dict[int, Command] = {}
        self.reads_served = 0
        self.tail_forwards = 0

    # -- helpers --------------------------------------------------------------
    @property
    def is_head(self) -> bool:
        return self.index == 0

    @property
    def is_tail(self) -> bool:
        return self.index == len(self.chain) - 1

    def _next(self) -> str:
        return self.chain[self.index + 1]

    def _prev(self) -> str:
        return self.chain[self.index - 1]

    def _dirty(self, key: Any) -> bool:
        vs = self.versions.get(key)
        if not vs:
            return False
        return vs[-1][0] > self.clean_upto.get(key, -1)

    def _committed_value(self, key: Any) -> Any:
        vs = self.versions.get(key)
        if not vs:
            return None
        upto = self.clean_upto.get(key, -1)
        committed = [v for ver, v in vs if ver <= upto]
        if committed:
            return committed[-1]
        return None

    def _latest_value(self, key: Any) -> Any:
        vs = self.versions.get(key)
        return vs[-1][1] if vs else None

    def _store(self, key: Any, version: int, value: Any) -> None:
        self.versions.setdefault(key, []).append((version, value))

    def _mark_clean(self, key: Any, version: int) -> None:
        if version > self.clean_upto.get(key, -1):
            self.clean_upto[key] = version
        # garbage-collect superseded versions
        vs = self.versions.get(key, [])
        upto = self.clean_upto[key]
        committed = [(ver, v) for ver, v in vs if ver <= upto]
        rest = [(ver, v) for ver, v in vs if ver > upto]
        if committed:
            self.versions[key] = [committed[-1]] + rest

    # -- protocol ---------------------------------------------------------------
    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, ClientRequest):
            # client write enters at the head
            cmd = msg.command
            assert cmd.op[0] == "put", "chain writes are puts"
            version = self.next_version
            self.next_version += 1
            self.inflight[version] = cmd
            _, key, value = cmd.op
            self._store(key, version, value)
            if self.is_tail:  # chain of length 1
                self._mark_clean(key, version)
                self.send(f"client/{cmd.client_id}",
                          ClientReply(command_uid=cmd.uid, result="ok", slot=version))
            else:
                self.send(self._next(), ChainWrite(command=cmd, version=version))
        elif isinstance(msg, ChainWrite):
            _, key, value = msg.command.op
            self._store(key, msg.version, value)
            if self.is_tail:
                self._mark_clean(key, msg.version)
                self.send(self._prev(), ChainAck(key=key, version=msg.version))
            else:
                self.send(self._next(), msg)
        elif isinstance(msg, ChainAck):
            self._mark_clean(msg.key, msg.version)
            if self.is_head:
                cmd = self.inflight.pop(msg.version, None)
                if cmd is not None:
                    self.send(f"client/{cmd.client_id}",
                              ClientReply(command_uid=cmd.uid, result="ok",
                                          slot=msg.version))
            else:
                self.send(self._prev(), msg)
        elif isinstance(msg, ChainRead):
            cmd = msg.command
            key = cmd.op[1]
            if self.is_tail or (self.reads_anywhere and not self._dirty(key)):
                # CRAQ fast path (or tail): serve the latest committed value
                value = (self._latest_value(key) if self.is_tail
                         else self._committed_value(key))
                self.reads_served += 1
                self.send(f"client/{cmd.client_id}",
                          ReadReply(command_uid=cmd.uid, result=value,
                                    executed_slot=self.clean_upto.get(key, -1)))
            else:
                # dirty (or CR non-tail): forward to the tail
                self.tail_forwards += 1
                self.send(self.chain[-1], msg)


class CraqClient(Node):
    """Closed-loop client for chain protocols."""

    def __init__(self, addr: str, client_id: int, chain: Sequence[str],
                 history: Optional[History] = None, seed: int = 0,
                 reads_anywhere: bool = True) -> None:
        super().__init__(addr)
        self.client_id = client_id
        self.chain = list(chain)
        self.history = history
        self.rng = random.Random(seed * 7 + client_id)
        self.reads_anywhere = reads_anywhere
        # CRAQ reads are uniformly addressed.  A shuffled balanced deck
        # realizes that exactly over every window of k reads (keeping
        # measured per-node read load parity-comparable at small op
        # counts) while staying aperiodic, so the deterministic write
        # pipeline's dirty windows still get sampled.
        self._read_deck: List[int] = []
        self.seq = 0
        self.ops: List[Tuple] = []
        self.op_index = 0
        self.outstanding: Optional[Tuple] = None
        self.results: List[Any] = []

    def run_ops(self, ops: Sequence[Tuple]) -> None:
        self.ops.extend(ops)
        if self.outstanding is None:
            self.set_timer("kick", 0.0)

    def _issue_next(self) -> None:
        if self.op_index >= len(self.ops):
            self.outstanding = None
            return
        op = self.ops[self.op_index]
        self.op_index += 1
        hist_id = (self.history.invoke(self.client_id, op, self.now)
                   if self.history is not None else None)
        cmd = Command(self.client_id, self.seq, op, is_read=(op[0] == "get"))
        self.seq += 1
        self.outstanding = (cmd, hist_id)
        if op[0] == "get":
            if self.reads_anywhere:
                if not self._read_deck:
                    self._read_deck = list(range(len(self.chain)))
                    self.rng.shuffle(self._read_deck)
                node = self.chain[self._read_deck.pop()]
            else:
                node = self.chain[-1]
            self.send(node, ChainRead(command=cmd))
        else:
            self.send(self.chain[0], ClientRequest(command=cmd))

    def _complete(self, result: Any) -> None:
        if self.outstanding is None:
            return
        _, hist_id = self.outstanding
        if self.history is not None and hist_id is not None:
            self.history.respond(hist_id, result, self.now)
        self.results.append(result)
        self.outstanding = None
        self._issue_next()

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, (ClientReply, ReadReply)):
            if self.outstanding and msg.command_uid == self.outstanding[0].uid:
                result = msg.result if isinstance(msg, ReadReply) else msg.result
                self._complete(result)
        elif isinstance(msg, Timer) and msg.name == "kick":
            if self.outstanding is None:
                self._issue_next()

    @property
    def done(self) -> bool:
        return self.op_index >= len(self.ops) and self.outstanding is None


class CraqDeployment(BaseDeployment):
    def __init__(self, n_nodes: int = 3, n_clients: int = 2,
                 reads_anywhere: bool = True, seed: int = 0,
                 latency_fn: Optional[Callable[[str, str], float]] = None,
                 ) -> None:
        self.net = Network(seed=seed, latency_fn=latency_fn)
        self.history = History()
        self.chain_addrs = [f"chain/{i}" for i in range(n_nodes)]
        self.nodes = [ChainNode(a, i, self.chain_addrs, reads_anywhere)
                      for i, a in enumerate(self.chain_addrs)]
        self.clients = [
            CraqClient(f"client/{i}", i, self.chain_addrs, history=self.history,
                       seed=seed, reads_anywhere=reads_anywhere)
            for i in range(n_clients)
        ]
        self.net.add_nodes(self.nodes)
        self.net.add_nodes(self.clients)

    def tail_load_fraction(self) -> float:
        served = sum(n.reads_served for n in self.nodes)
        tail = self.nodes[-1].reads_served
        return tail / served if served else 0.0
