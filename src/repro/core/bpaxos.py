"""Bipartisan Paxos (BPaxos) - a multi-leader variant family member.

BPaxos (PAPERS.md, arXiv 2003.00331) breaks the single-sequencer ceiling
by *decoupling ordering itself*: ``n_proposers`` stateless proposers run
in parallel, and a replicated **dependency service** tracks per-key
conflicts instead of assigning log slots.  A command is committed with a
dependency set; replicas execute the resulting dependency graph in a
conflict-aware deterministic order (strongly connected components in
reverse topological order, vertex-id tie-break within a component - the
EPaxos/BPaxos execution rule).

Wire protocol (failure-free accounting path, one command):

    client -> proposer                       ClientRequest    (1 recv)
    proposer -> every dep node               DepRequest       (d sends)
    every dep node -> proposer               DepReply         (d recvs)
    proposer -> every replica                BPaxosCommit     (n sends)
    owner replica -> client                  ClientReply

The proposer commits at a **majority** of dependency replies (quorum
intersection is what makes the real-time order an edge in the graph);
the remaining replies still arrive and are counted, so every station's
msgs/cmd is exact and seed-independent:

    proposer     (1 + 2 d + n) / p      per proposer
    dep_service  2                      per dep node (recv + reply)
    replica      1 + 1/n                per replica (commit + reply share)

Reads travel the same dependency path as writes (there is no leaderless
read optimization in BPaxos), so the read column equals the write column.
Registration is the multi-leader proof of the registry thesis: two NEW
station slots (``proposer``, ``dep_service``) and both planes, with zero
core edits.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Dict, List, Optional, Sequence, Set, Tuple

from .analytical import DeploymentModel, Station
from .api import knob, register_executable, register_variant
from .cluster import Network, Node
from .history import History
from .messages import ClientReply, ClientRequest, Command, is_noop
from .protocols import BaseDeployment
from .quorums import MajorityQuorums
from .roles import Client
from .statemachine import make_state_machine

Vertex = Tuple[int, int]  # (proposer_id, proposer-local sequence)


# ---------------------------------------------------------------------------
# Messages (BPaxos-only; frozen like repro.core.messages)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class DepRequest:
    """Proposer -> dependency service: record ``vertex`` against ``key``."""

    vertex: Vertex
    key: Any


@dataclass(frozen=True)
class DepReply:
    """Dependency service -> proposer: conflicting vertices seen before."""

    vertex: Vertex
    deps: Tuple[Vertex, ...]


@dataclass(frozen=True)
class BPaxosCommit:
    """Proposer -> every replica: vertex committed with its final deps."""

    vertex: Vertex
    command: Command
    deps: Tuple[Vertex, ...]


def _conflict_key(cmd: Command) -> Any:
    """Commands conflict iff they touch the same key (reads included -
    a read must be ordered against the writes it observes)."""
    op = cmd.op
    return op[1] if len(op) > 1 else "_"


# ---------------------------------------------------------------------------
# Roles
# ---------------------------------------------------------------------------


class BPaxosProposer(Node):
    """One of ``p`` parallel proposers: assigns a globally unique vertex,
    gathers a majority of dependency replies, commits to every replica."""

    def __init__(self, addr: str, proposer_id: int,
                 dep_addrs: Sequence[str],
                 replica_addrs: Sequence[str],
                 thrifty: bool = False) -> None:
        super().__init__(addr)
        self.proposer_id = proposer_id
        self.dep_addrs = list(dep_addrs)
        self.replica_addrs = list(replica_addrs)
        self.quorum = len(self.dep_addrs) // 2 + 1
        self.thrifty = thrifty
        self.seq = 0
        # vertex -> [command, union-of-deps, n_acks, committed]
        self.pending: Dict[Vertex, List[Any]] = {}

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, ClientRequest):
            vertex = (self.proposer_id, self.seq)
            self.seq += 1
            self.pending[vertex] = [msg.command, set(), 0, False]
            key = _conflict_key(msg.command)
            if self.thrifty:
                # EPaxos-style thrifty: unicast to exactly a quorum of dep
                # nodes - a rotating window so load stays even - instead
                # of broadcasting and discarding the non-quorum replies
                d = len(self.dep_addrs)
                targets = [self.dep_addrs[(vertex[1] + j) % d]
                           for j in range(self.quorum)]
            else:
                targets = self.dep_addrs
            for t in targets:
                self.send(t, DepRequest(vertex=vertex, key=key))
        elif isinstance(msg, DepReply):
            entry = self.pending.get(msg.vertex)
            if entry is None or entry[3]:
                return  # already committed; late replies are just counted
            entry[1].update(msg.deps)
            entry[2] += 1
            if entry[2] >= self.quorum:
                entry[3] = True
                deps = tuple(sorted(entry[1] - {msg.vertex}))
                for r in self.replica_addrs:
                    self.send(r, BPaxosCommit(vertex=msg.vertex,
                                              command=entry[0], deps=deps))


class DepServiceNode(Node):
    """One of ``d = 2f+1`` dependency-service nodes: a per-key conflict
    map.  Reports the last conflicting vertex it recorded (prior ones are
    reachable transitively through that vertex's own deps)."""

    def __init__(self, addr: str) -> None:
        super().__init__(addr)
        self.last_by_key: Dict[Any, Vertex] = {}

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, DepRequest):
            prior = self.last_by_key.get(msg.key)
            deps = (prior,) if prior is not None else ()
            self.last_by_key[msg.key] = msg.vertex
            self.send(src, DepReply(vertex=msg.vertex, deps=deps))


class BPaxosReplica(Node):
    """Executes the committed dependency graph.

    A vertex is eligible once its transitive dependency closure is fully
    committed; the closure's strongly connected components are executed in
    reverse topological order with a vertex-id tie-break inside each
    component.  Every replica sees the same (vertex -> deps) mapping - the
    proposer froze the deps at commit - so the per-key execution order is
    identical everywhere; the owner replica replies."""

    def __init__(self, addr: str, replica_index: int, n_replicas: int,
                 state_machine,
                 client_addr_fn=lambda cid: f"client/{cid}") -> None:
        super().__init__(addr)
        self.replica_index = replica_index
        self.n_replicas = n_replicas
        self.sm = state_machine
        self.client_addr_fn = client_addr_fn
        self.committed: Dict[Vertex, Tuple[Command, Tuple[Vertex, ...]]] = {}
        self.executed: Set[Vertex] = set()
        self.executed_order: List[Vertex] = []
        self.key_order: Dict[Any, List[Vertex]] = {}

    def on_message(self, src: str, msg: Any) -> None:
        if isinstance(msg, BPaxosCommit):
            if msg.vertex in self.committed:
                return
            self.committed[msg.vertex] = (msg.command, msg.deps)
            self._try_execute()

    # -- dependency-graph execution ----------------------------------------
    def _ready_closure(self, root: Vertex) -> Optional[Set[Vertex]]:
        """Unexecuted vertices reachable from ``root`` through deps, or
        ``None`` if the closure hits an uncommitted vertex."""
        closure: Set[Vertex] = set()
        stack = [root]
        while stack:
            v = stack.pop()
            if v in self.executed or v in closure:
                continue
            if v not in self.committed:
                return None
            closure.add(v)
            stack.extend(self.committed[v][1])
        return closure

    def _scc_order(self, closure: Set[Vertex]) -> List[List[Vertex]]:
        """Tarjan over the closure subgraph (edges vertex -> dep).  SCCs
        come out dependencies-first; vertices inside an SCC are sorted."""
        index: Dict[Vertex, int] = {}
        low: Dict[Vertex, int] = {}
        on_stack: Set[Vertex] = set()
        stack: List[Vertex] = []
        order: List[List[Vertex]] = []
        counter = [0]

        def strongconnect(v: Vertex) -> None:
            index[v] = low[v] = counter[0]
            counter[0] += 1
            stack.append(v)
            on_stack.add(v)
            for w in self.committed[v][1]:
                if w not in closure:
                    continue
                if w not in index:
                    strongconnect(w)
                    low[v] = min(low[v], low[w])
                elif w in on_stack:
                    low[v] = min(low[v], index[w])
            if low[v] == index[v]:
                comp = []
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    comp.append(w)
                    if w == v:
                        break
                order.append(sorted(comp))

        for v in sorted(closure):
            if v not in index:
                strongconnect(v)
        return order

    def _try_execute(self) -> None:
        progress = True
        while progress:
            progress = False
            for v in sorted(self.committed):
                if v in self.executed:
                    continue
                closure = self._ready_closure(v)
                if closure is None:
                    continue
                for comp in self._scc_order(closure):
                    for u in comp:
                        self._execute_vertex(u)
                progress = True

    def _execute_vertex(self, v: Vertex) -> None:
        cmd, _ = self.committed[v]
        self.executed.add(v)
        self.executed_order.append(v)
        result = None if is_noop(cmd) else self.sm.apply_checked(cmd.op)
        self.key_order.setdefault(_conflict_key(cmd), []).append(v)
        if (v[0] + v[1]) % self.n_replicas == self.replica_index:
            self.send(self.client_addr_fn(cmd.client_id),
                      ClientReply(command_uid=cmd.uid, result=result,
                                  slot=None))


# ---------------------------------------------------------------------------
# Deployment
# ---------------------------------------------------------------------------


class BPaxosDeployment(BaseDeployment):
    """p proposers + d dependency-service nodes + n graph-executing
    replicas.  Clients route to proposer ``i % p``; every op (reads too)
    travels the dependency path, so there are no acceptors and no
    leaderless read quorums."""

    def __init__(
        self,
        n_proposers: int = 3,
        n_dep_nodes: int = 3,
        n_replicas: int = 3,
        f: int = 1,
        n_clients: int = 3,
        state_machine: str = "kv",
        consistency: str = "linearizable",
        seed: int = 0,
        thrifty: bool = False,
        latency_fn: Optional[Callable[[str, str], float]] = None,
    ) -> None:
        if n_dep_nodes < 2 * f + 1:
            raise ValueError(
                f"n_dep_nodes must be >= 2f+1 = {2 * f + 1} (dependency "
                f"quorums must intersect under f faults): {n_dep_nodes}")
        self.net = Network(seed=seed, latency_fn=latency_fn)
        self.history = History()
        self.proposer_addrs = [f"proposer/{i}" for i in range(n_proposers)]
        self.dep_addrs = [f"dep_service/{i}" for i in range(n_dep_nodes)]
        self.replica_addrs = [f"replica/{i}" for i in range(n_replicas)]
        self.dep_nodes = [DepServiceNode(a) for a in self.dep_addrs]
        self.replicas = [
            BPaxosReplica(addr, i, n_replicas,
                          make_state_machine(state_machine))
            for i, addr in enumerate(self.replica_addrs)
        ]
        self.proposers = [
            BPaxosProposer(addr, i, self.dep_addrs, self.replica_addrs,
                           thrifty=thrifty)
            for i, addr in enumerate(self.proposer_addrs)
        ]
        # empty acceptor/replica lists: reads take the proposer path too
        self.clients = [
            Client(f"client/{i}", i, self.proposer_addrs[i % n_proposers],
                   [], MajorityQuorums(f=f), [], consistency=consistency,
                   history=self.history, seed=seed)
            for i in range(n_clients)
        ]
        for group in (self.dep_nodes, self.replicas, self.proposers,
                      self.clients):
            self.net.add_nodes(group)


# ---------------------------------------------------------------------------
# Analytical model + registration (both planes, zero core edits)
# ---------------------------------------------------------------------------


def bpaxos_model(n_proposers: int = 3, n_dep_nodes: int = 3,
                 n_replicas: int = 3, f: int = 1,
                 thrifty: bool = False) -> DeploymentModel:
    """BPaxos demand table (derivation in the module docstring).

    The proposer tier scales with ``p`` - sequencing is parallel - while
    the dependency service is the protocol's structural floor: every dep
    node sees every command (2 msgs/cmd), the same ceiling the paper's
    compartmentalized leader has, but bought with parallel proposers
    instead of proxy offload.  Reads cost what writes cost.

    ``thrifty`` (EPaxos-style) unicasts DepRequest to exactly a rotating
    quorum ``q = d//2 + 1`` instead of broadcasting to all ``d``: the
    proposer stops paying for (and discarding) the ``d - q`` non-quorum
    replies, and each dep node's demand drops from 2 to ``2q/d``
    msgs/cmd - the protocol's structural floor moves."""
    p, d, n = n_proposers, n_dep_nodes, n_replicas
    if p < 1:
        raise ValueError(f"n_proposers must be >= 1: {p}")
    if d < 2 * f + 1:
        raise ValueError(
            f"n_dep_nodes must be >= 2f+1 = {2 * f + 1}: {d}")
    if n < 1:
        raise ValueError(f"n_replicas must be >= 1: {n}")
    q = d // 2 + 1 if thrifty else d
    proposer = (1.0 + 2.0 * q + n) / p
    dep = 2.0 * q / d
    replica = 1.0 + 1.0 / n
    stations = (
        Station("proposer", p, proposer, proposer),
        Station("dep_service", d, dep, dep),
        Station("replica", n, replica, replica),
    )
    tag = ",thrifty" if thrifty else ""
    return DeploymentModel(name=f"bpaxos(p={p},d={d},n={n}{tag})",
                           stations=stations)


def _bpaxos_candidates(budget: int, f: int) -> Dict[str, tuple]:
    """Candidate space under a machine budget: the dep tier is pinned at
    2f+1 (more dep replicas buy fault tolerance, not throughput), the
    proposer/replica axes absorb the rest."""
    d = 2 * f + 1
    max_prop = max(budget - d - (f + 1), 1)
    max_replicas = max(budget - d - 1, f + 1)
    return {
        "n_proposers": tuple(range(1, min(max_prop, 8) + 1)),
        "n_dep_nodes": (d,),
        "n_replicas": tuple(range(f + 1, min(max_replicas, f + 7) + 1)),
        "thrifty": (False, True),
    }


def _bpaxos_deployment(n_proposers: int = 3, n_dep_nodes: int = 3,
                       n_replicas: int = 3, f: int = 1, n_clients: int = 3,
                       seed: int = 0, state_machine: str = "kv",
                       thrifty: bool = False,
                       latency_fn: Optional[Callable[[str, str], float]]
                       = None) -> BPaxosDeployment:
    return BPaxosDeployment(n_proposers=n_proposers, n_dep_nodes=n_dep_nodes,
                            n_replicas=n_replicas, f=f, n_clients=n_clients,
                            state_machine=state_machine, seed=seed,
                            thrifty=thrifty, latency_fn=latency_fn)


register_variant(
    name="bpaxos",
    factory=bpaxos_model,
    stations=("proposer", "dep_service", "replica"),
    knobs=(
        knob("n_proposers", (3,)),
        knob("n_dep_nodes", (3,)),
        knob("n_replicas", (3,)),
        knob("thrifty", (False,)),
    ),
    takes_f=True,
    candidate_knobs=_bpaxos_candidates,
    description="Bipartisan Paxos: parallel proposers + dependency service "
                "(arXiv 2003.00331)",
)

register_executable(
    "bpaxos",
    deployment=_bpaxos_deployment,
    # the whole wire protocol is message-deterministic and seed-blind:
    # every station's msgs/cmd is exact at any mix
    exact_stations=("proposer", "dep_service", "replica"),
    rel_tolerance=0.05,
    n_clients=3,
    description="Dependency-graph commit with conflict-aware SCC execution",
)
